"""musicgen-medium — 48L d1536 24H (kv=24) d_ff=6144, decoder-only over
EnCodec tokens: 4 codebooks x vocab 2048, delay interleaving.
[arXiv:2306.05284]

The EnCodec frontend is a STUB per the assignment: inputs are the (B, S, 4)
codebook-token grid; the frame embedding is the sum of per-codebook
embeddings and the head predicts all 4 streams."""

from repro.models.config import ModelConfig

config = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    head_dim=64,
    frontend="audio_codes",
    n_codebooks=4,
    gated_mlp=False,  # standard GELU FFN (d_ff = 4 d_model)
    rope_theta=10_000.0,
    train_microbatches=8,
)
