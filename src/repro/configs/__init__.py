"""Architecture registry + per-(arch x shape) input specs.

``get_config(arch_id)`` returns the exact published configuration;
``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input of that cell (never allocates device
memory — the dry-run pattern)."""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec, applicable_shapes  # noqa: F401
from repro.models.config import ModelConfig

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "zamba2-1.2b": "zamba2_1p2b",
    "musicgen-medium": "musicgen_medium",
    "deepseek-7b": "deepseek_7b",
    "stablelm-12b": "stablelm_12b",
    "minicpm3-4b": "minicpm3_4b",
    "granite-34b": "granite_34b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "xlstm-1.3b": "xlstm_1p3b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config


def effective_microbatches(cfg: ModelConfig, shape: ShapeSpec, dp_size: int = 16) -> int:
    """Microbatch count adapted to the mesh: each microbatch's global batch
    must stay divisible by the DP width (a 2-pod mesh doubles DP, so the
    per-pod microbatch count halves while per-device activations stay
    constant)."""
    if shape.kind != "train":
        return 1
    n = min(cfg.train_microbatches, max(1, shape.global_batch // dp_size))
    while shape.global_batch % n:
        n -= 1
    return max(1, n)


def input_specs(cfg: ModelConfig, shape: ShapeSpec | str, dp_size: int = 16) -> dict:
    """ShapeDtypeStructs for the step inputs of one (arch x shape) cell.

    train:   {"tokens"/"codes"/"embeds"(+positions), "labels"}
    prefill: model inputs for the full prompt (no cache)
    decode:  one new token + "cur_index"; the cache struct comes from
             :func:`cache_specs`."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    # training batches arrive pre-split into microbatches: (N, B/N, ...)
    N = effective_microbatches(cfg, shape, dp_size)
    if N > 1:
        assert B % N == 0, (B, N)
        lead: tuple = (N, B // N)
    else:
        lead = (B,)

    specs: dict = {}
    if cfg.frontend == "audio_codes":
        specs["codes"] = jax.ShapeDtypeStruct((*lead, S, cfg.n_codebooks), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((*lead, S, cfg.n_codebooks), i32)
    elif cfg.frontend == "vision_embeds":
        specs["embeds"] = jax.ShapeDtypeStruct((*lead, S, cfg.d_model), dt)
        if shape.kind == "train":
            specs["positions"] = jax.ShapeDtypeStruct((N, 3, B // N, S), i32) \
                if N > 1 else jax.ShapeDtypeStruct((3, B, S), i32)
            specs["labels"] = jax.ShapeDtypeStruct((*lead, S), i32)
        else:
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((*lead, S), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((*lead, S), i32)
    if shape.kind == "decode":
        specs["cur_index"] = jax.ShapeDtypeStruct((), i32)
        if cfg.frontend == "vision_embeds":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, 1), i32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeSpec | str):
    """ShapeDtypeStruct pytree for the decode cache of one cell."""
    from repro.models.transformer import init_cache

    if isinstance(shape, str):
        shape = SHAPES[shape]
    assert shape.kind == "decode"
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
