"""Assigned input shapes (per-arch shape set for the LM family)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

StepKind = Literal["train", "prefill", "decode"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: StepKind
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# long_500k needs sub-quadratic context handling: runs only for the
# SSM/hybrid archs; the 8 pure full-attention archs skip it (DESIGN.md §6).
LONG_CONTEXT_ARCHS = {"zamba2-1.2b", "xlstm-1.3b"}


def applicable_shapes(arch_id: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_id in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
