"""stablelm-12b — 40L d5120 32H (GQA kv=8) d_ff=13824, vocab 100352,
parallel attention+FFN residual (stablelm-2 style). [hf:stabilityai]"""

from repro.models.config import ModelConfig

config = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    head_dim=160,
    parallel_residual=True,
    gated_mlp=True,
    rope_theta=10_000.0,
    train_microbatches=8,
)
