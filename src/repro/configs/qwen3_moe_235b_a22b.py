"""qwen3-moe-235b-a22b — 94L d4096 64H (GQA kv=4) head_dim=128,
d_ff=1536/expert, MoE 128 experts top-8, vocab 151936. [hf:Qwen/Qwen3-30B-A3B]

Largest assigned model; the qwen3 family uses an independent head_dim=128
(64 heads x 128 > d_model)."""

from repro.models.config import ModelConfig

config = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    rope_theta=1_000_000.0,
    gated_mlp=True,
    moe_group_size=512,
    train_microbatches=16,
    remat_group=2,
    fsdp=True,
    fsdp_inference=True,
    opt_moments_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
)
