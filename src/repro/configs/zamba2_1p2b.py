"""zamba2-1.2b — 38L d2048, Mamba2 backbone (ssm_state=64) with a SHARED
attention+MLP block (32H kv=32, d_ff=8192) applied at 5 interleave points.
[arXiv:2411.15242]

Assumption (documented per DESIGN.md): the shared transformer block is
invoked every ~7 backbone layers (positions 6, 13, 20, 27, 34 of the
38-layer stack), one parameter set reused at every application — the
Zamba2 shared-block pattern."""

from repro.models.config import ModelConfig

_ATTN_AT = {6, 13, 20, 27, 34}
_PATTERN = tuple("attn" if i in _ATTN_AT else "mamba" for i in range(38))

config = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    expand=2,
    d_conv=4,
    block_pattern=_PATTERN,
    shared_attn=True,
    rope_theta=10_000.0,
    train_microbatches=8,
)
