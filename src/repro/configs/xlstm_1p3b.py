"""xlstm-1.3b — 48L d2048 4H, sLSTM + mLSTM blocks, vocab 50304.
[arXiv:2405.04517]

Block mix follows the paper's 7:1 mLSTM:sLSTM ratio — sLSTM at every 8th
position (7, 15, 23, 31, 39, 47). d_ff=0 per the assignment: xLSTM blocks
carry their own up/down projections (expand=2), no separate FFN."""

from repro.models.config import ModelConfig

_SLSTM_AT = {7, 15, 23, 31, 39, 47}
_PATTERN = tuple("slstm" if i in _SLSTM_AT else "mlstm" for i in range(48))

config = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=512,
    expand=2,
    block_pattern=_PATTERN,
    train_microbatches=8,
    scan_chunk=512,
    ssm_tp=False,
)
