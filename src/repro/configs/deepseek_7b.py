"""deepseek-7b — 30L d4096 32H (MHA kv=32) d_ff=11008, vocab 102400,
llama architecture (SwiGLU, RoPE). [arXiv:2401.02954]"""

from repro.models.config import ModelConfig

config = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    head_dim=128,
    gated_mlp=True,
    rope_theta=10_000.0,
    train_microbatches=8,
)
