"""granite-34b — 88L d6144 48H MQA (kv=1) d_ff=24576, vocab 49152,
GPT-BigCode-style code model (GELU FFN). [arXiv:2405.04324]

Deepest dense stack of the pool — the pipeline-partitioning showcase."""

from repro.models.config import ModelConfig

config = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    gated_mlp=False,
    rope_theta=10_000.0,
    train_microbatches=16,
    remat_group=2,
    fsdp=True,
    fsdp_inference=False,
)
