"""minicpm3-4b — 62L d2560 40H d_ff=6400, vocab 73448, Multi-head Latent
Attention (MLA): q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v=64.
[hf:openbmb/MiniCPM3-4B]

The MLA decode cache stores only the 256-d latent + 32-d rope key per
token — the arch-level interaction with the paper's transmission-cost
model (smaller inter-stage/decode bytes)."""

from repro.models.config import ModelConfig

config = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    use_mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    head_dim=96,  # qk_nope + qk_rope (bookkeeping only; MLA paths use the split dims)
    gated_mlp=True,
    rope_theta=10_000.0,
    train_microbatches=8,
)
