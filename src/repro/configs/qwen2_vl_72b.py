"""qwen2-vl-72b — 80L d8192 64H (GQA kv=8) d_ff=29568, vocab 152064,
M-RoPE (t/h/w sections 16/24/24 over head_dim 128), dynamic resolution.
[arXiv:2409.12191]

The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch/text embeddings (B, S, d_model) plus the (3, B, S)
M-RoPE position streams."""

from repro.models.config import ModelConfig

config = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    frontend="vision_embeds",
    mrope_sections=(16, 24, 24),
    gated_mlp=True,
    rope_theta=1_000_000.0,
    train_microbatches=16,
    remat_group=2,
    fsdp=True,
    fsdp_inference=True,
    kv_cache_dtype="int8",
)
