"""Batched serving runtime with split-aware latency accounting.

The paper's system serves one inference hop-by-hop across IoT devices;
the datacenter analogue is a batched decode server whose model may be
*split* across stages. This runtime provides:

  * slot-based continuous batching: requests occupy cache slots, prefill
    fills a slot, the decode loop advances all active slots each tick and
    retires finished ones;
  * a :class:`SplitLatencyMeter` that prices every generated token against
    the paper's Eq. 7/8 cost model for a chosen split plan + link profile
    — the runtime realization of 'split point choice drives end-to-end
    latency'.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import LinkProfile
from repro.core.planner import SplitPlan
from repro.models import transformer as T
from repro.models.config import ModelConfig


class DrainTruncated(RuntimeError):
    """``run_until_drained`` hit ``max_ticks`` with work still queued or
    active. ``result`` carries the partial generations produced so far
    (a :class:`DrainResult`, ``drained=False``)."""

    def __init__(self, result: "DrainResult"):
        super().__init__(
            f"run_until_drained truncated after {result.ticks} ticks "
            f"with requests still pending")
        self.result = result


class DrainResult(dict):
    """``{rid: [tokens]}`` plus drain metadata.

    A plain ``dict`` subclass so existing callers keep indexing it, with
    ``drained`` (False = ``max_ticks`` hit with work remaining — the
    generations are PARTIAL) and ``ticks`` (server steps consumed).
    """

    def __init__(self, out: dict[int, list[int]], drained: bool, ticks: int):
        super().__init__(out)
        self.drained = drained
        self.ticks = ticks


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 16
    generated: list[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclass
class SplitLatencyMeter:
    """Accumulates modeled transmission latency for inter-segment hops.

    ``bytes_per_token``: the RAW bytes a decode step produces at a cut —
    one (B, 1, d_model) activation row (the plan's ``tx_bytes`` is the
    full-sequence prefill activation). What is actually PRICED per hop
    is single-sourced from the adopted plan: when the plan carries a
    bottleneck variant (``plan.variant`` into the manager's bank), the
    per-token payload is the variant-compressed byte count, and a
    mid-stream replan onto a different variant reprices the remaining
    hops immediately (the plan swap carries the new compression).

    Replan hook: when ``manager`` (an
    :class:`~repro.core.adaptive.AdaptiveSplitManager`) and ``protocol``
    are set, every metered hop is fed to ``manager.observe()`` — with a
    precomputed degradation surface that is an O(1) lookup, cheap enough
    to run on every token; with the manager's ``async_rebuild`` on,
    out-of-envelope drift enqueues a background surface rebuild, so the
    token loop never blocks on one — and when the manager adopts a new
    decision the meter swaps in the re-materialized plan (``replans``
    counts the swaps). If the adopted decision switched protocol, the
    meter's ``protocol`` AND pricing ``link`` follow it (the new
    protocol's base profile at the adopted chunk size): hops after a
    cross-protocol replan ride the new link, they are no longer priced
    on the abandoned one."""

    plan: SplitPlan | None = None
    link: LinkProfile | None = None
    bytes_per_token: int = 0
    hop_seconds: float = 0.0
    hops: int = 0
    manager: object | None = None  # AdaptiveSplitManager (duck-typed)
    protocol: str | None = None
    replans: int = 0

    def observe_hop(self, nbytes: int, latency_s: float,
                    retries: int = 0) -> bool:
        """Feed one externally measured hop (a device-reported transfer)
        to the manager through the same adoption-following logic the
        token loop uses: if the observation triggers a replan the meter
        swaps in the re-materialized plan, and on a cross-protocol
        adoption follows the new protocol's pricing link. Returns True
        when a replan was adopted. No-op without a manager/protocol."""
        if self.manager is None or self.protocol is None:
            return False
        decisions = len(self.manager.history)
        self.manager.observe(self.protocol, nbytes, latency_s, retries)
        if len(self.manager.history) == decisions:
            return False
        self.plan = self.manager.current_plan()
        adopted = self.manager.current
        if adopted is not None and adopted.protocol != self.protocol:
            # cross-protocol replan: hops now ride the NEW protocol's
            # link (at the adopted chunk size) — pricing them on the
            # abandoned link kept feeding the old protocol's estimator
            # forever
            self.protocol = adopted.protocol
            base = self.manager.protocols[adopted.protocol]
            self.link = replace(base, mtu_bytes=adopted.chunk_bytes)
        self.replans += 1
        return True

    def _plan_variant(self):
        """The adopted plan's bottleneck variant, resolved through the
        manager's bank (None for plain plans or meters without a
        banked manager)."""
        vi = getattr(self.plan, "variant", None)  # plans are duck-typed
        if vi is None or vi < 0:
            return None
        bank = getattr(self.manager, "variants", None)
        if bank is None:
            return None
        return bank[vi]

    def _hop_bytes(self, seg) -> int:
        """Bytes priced for one hop, single-sourced from the adopted
        plan: prefill pricing reads ``seg.tx_bytes`` (already
        variant-compressed by the planner); per-token pricing compresses
        ``bytes_per_token`` with the plan's adopted variant. A replan
        that switches variants changes this on the very next hop."""
        if not self.bytes_per_token:
            return seg.tx_bytes
        v = self._plan_variant()
        if v is None:
            return self.bytes_per_token
        return v.compressed_bytes(self.bytes_per_token)

    def on_token(self):
        if self.plan is None or self.link is None:
            return
        # while-loop (not for) so a mid-token replan adoption reprices the
        # REMAINING hops on the newly adopted plan/link instead of
        # dropping them: the old `break` undercounted hop_seconds/hops on
        # every multi-segment replan step
        hop = 0
        while self.plan is not None and hop < len(self.plan.segments) - 1:
            seg = self.plan.segments[hop]
            hop += 1
            nbytes = self._hop_bytes(seg)
            hop_s = self.link.transmission_latency_s(nbytes)
            self.hop_seconds += hop_s
            self.hops += 1
            self.observe_hop(nbytes, hop_s)


class Server:
    """Slot-based batched decode server (greedy sampling)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, meter: SplitLatencyMeter | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.meter = meter or SplitLatencyMeter()
        self.cache = T.init_cache(cfg, slots, max_seq, dtype=jnp.float32)
        self.lengths = np.zeros(slots, dtype=np.int32)  # tokens in each slot
        self.active: dict[int, Request] = {}  # slot -> request
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, inp, c: T.serve_step(cfg, p, inp, c))

    def submit(self, req: Request):
        self.queue.append(req)

    # -- internals -------------------------------------------------------------
    def _free_slot(self) -> int | None:
        for s in range(self.slots):
            if s not in self.active:
                return s
        return None

    def _prefill(self, slot: int, req: Request):
        """Feed the prompt token-by-token through the decode path (keeps a
        single compiled step; a production server would batch-prefill).

        Only the admitted slot's rows are written: every other slot rides
        at position -1, which the per-row cache writer treats as
        "write nothing". The old path broadcast each prompt token to ALL
        slots at positions 0..P-1, corrupting in-flight generations on
        every mid-decode admission."""
        tokens = np.zeros(self.slots, dtype=np.int32)
        positions = np.full(self.slots, -1, dtype=np.int32)
        for t, tok in enumerate(req.prompt):
            tokens[slot] = tok
            positions[slot] = t
            inp = self._token_inputs(tokens, positions)
            logits, self.cache = self._decode(self.params, inp, self.cache)
        self.lengths[slot] = len(req.prompt)
        self.active[slot] = req

    def _token_inputs(self, tokens_per_slot: np.ndarray,
                      positions_per_slot: np.ndarray) -> dict:
        toks = jnp.asarray(tokens_per_slot, dtype=jnp.int32)[:, None]
        pos = jnp.asarray(positions_per_slot, dtype=jnp.int32)[:, None]
        if self.cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[None], (3, *pos.shape))
        return {"tokens": toks, "positions": pos}

    def step(self) -> list[tuple[int, int]]:
        """One server tick: admit, decode one token for all active slots,
        retire finished requests. Returns [(rid, token)] emitted."""
        while self.queue and (slot := self._free_slot()) is not None:
            self._prefill(slot, self.queue.pop(0))
        if not self.active:
            return []
        # batched decode at PER-SLOT positions: slot s reads/writes its
        # cache at its own lengths[s]; idle slots ride at -1 (no cache
        # write, fully masked attention). The old single global
        # cur = max(lengths) wrote shorter slots' KV at the wrong rows
        # after staggered admissions.
        emitted = []
        tokens = np.zeros(self.slots, dtype=np.int32)
        positions = np.full(self.slots, -1, dtype=np.int32)
        for s, req in self.active.items():
            last = req.generated[-1] if req.generated else int(req.prompt[-1])
            tokens[s] = last
            positions[s] = self.lengths[s]
        logits, self.cache = self._decode(
            self.params, self._token_inputs(tokens, positions), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        if nxt.ndim > 1:  # multi-codebook heads: take stream 0
            nxt = nxt[..., 0]
        for s in list(self.active):
            req = self.active[s]
            req.generated.append(int(nxt[s]))
            emitted.append((req.rid, int(nxt[s])))
            self.meter.on_token()
            self.lengths[s] += 1
            if req.done or self.lengths[s] >= self.max_seq - 1:
                del self.active[s]
        return emitted

    def run_until_drained(self, max_ticks: int = 10_000, *,
                          on_truncate: str = "return") -> DrainResult:
        """Tick until every request retires or ``max_ticks`` elapse.

        Hitting ``max_ticks`` with work still pending used to return the
        partial generations indistinguishably from a clean drain. Now
        the truncation is surfaced: with ``on_truncate="return"`` the
        :class:`DrainResult` carries ``drained=False``; with
        ``on_truncate="raise"`` a :class:`DrainTruncated` (its
        ``result`` holds the partial output) is raised instead."""
        if on_truncate not in ("return", "raise"):
            raise ValueError(f"on_truncate must be 'return' or 'raise', "
                             f"got {on_truncate!r}")
        out: dict[int, list[int]] = {}
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            for rid, tok in self.step():
                out.setdefault(rid, []).append(tok)
            ticks += 1
        result = DrainResult(out, drained=not (self.queue or self.active),
                             ticks=ticks)
        if not result.drained and on_truncate == "raise":
            raise DrainTruncated(result)
        return result
