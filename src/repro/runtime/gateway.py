"""Fleet serving gateway — thousands of device sessions, one planner.

The paper's runtime story is ONE sensor metering hops against one plan;
the production shape is a gateway multiplexing thousands of concurrent
device sessions onto the planning stack:

* **Sessions** register/drop dynamically. Each
  :class:`GatewaySession` owns a
  :class:`~repro.runtime.server.SplitLatencyMeter` plus the per-protocol
  :class:`~repro.core.adaptive.LinkEstimator` state inside its
  :class:`~repro.core.adaptive.AdaptiveSplitManager` — per-session link
  drift, per-session decisions.
* **One shared rebuilder.** Every session's manager wires to a
  :class:`~repro.core.async_replan.RebuildHandle` view of ONE shared
  :class:`~repro.core.async_replan.SurfaceRebuilder` (via
  :class:`~repro.core.async_replan.RebuildFanout`), so fleet-wide drift
  coalesces into single batched ``build_surfaces`` calls — N drifting
  sessions cost one solve per cycle, and the PR 5 generation/swap
  semantics hold per session (a stale build is never adopted).
  Sessions bring up cheaply: the per-size surface family is prebuilt in
  ONE multi-size solve at gateway construction, managers start with
  ``initial="surface"`` (an O(1) lookup, no per-registration solve) and
  run ``offsurface_fallback="stale"`` (drift requests a rebuild and
  keeps serving the stale decision — no inline re-solves on the event
  path).
* **Bounded ingress + QoS.** Events (measured hops, token ticks) enter
  a bounded queue; past ``max_pending`` they are SHED and counted —
  admission control, not unbounded growth. Every processed observe is
  timed into per-session and fleet-global rolling windows
  (:class:`~repro.runtime.stats.QosMonitor`), and :meth:`snapshot`
  emits a :class:`~repro.runtime.stats.FleetSnapshot`: p50/p99 observe
  latency, summed adaptive counters (``surface_hits`` /
  ``exact_fallbacks`` / ``rebuild_requests`` / ``surface_swaps`` /
  ``stale_serves``), shed/build counters, and a stale-adoption audit.

``pump()`` drains the queue synchronously (deterministic tests drive
it directly); :meth:`serve` is the asyncio wrapper that pumps forever
until :meth:`stop`. Benchmarked by ``benchmarks/gateway_load.py``
(≥10k sessions under churn + drift storms).
"""

from __future__ import annotations

import asyncio
import time
from collections import deque
from dataclasses import replace
from typing import Mapping, Sequence

from repro.core.adaptive import AdaptiveSplitManager, _batched_twin
from repro.core.async_replan import RebuildFanout, SurfaceRebuilder
from repro.core.latency import LinkProfile, SplitCostModel
from repro.core.spec import PlannerService, surfaces_spec
from repro.core.surface import DEFAULT_LOSS_GRID, DEFAULT_PT_SCALES
from repro.runtime.server import SplitLatencyMeter
from repro.runtime.stats import (
    FleetSnapshot,
    QosMonitor,
    RollingWindow,
    SessionSnapshot,
)

__all__ = ["FleetGateway", "GatewaySession"]


class GatewaySession:
    """One registered device session: a latency meter wired to its own
    adaptive manager, which shares the gateway's rebuilder through a
    per-session :class:`~repro.core.async_replan.RebuildHandle`."""

    __slots__ = ("session_id", "n_devices", "manager", "meter", "handle",
                 "observes", "tokens")

    def __init__(self, session_id: str, n_devices: int,
                 manager: AdaptiveSplitManager, meter: SplitLatencyMeter,
                 handle) -> None:
        self.session_id = session_id
        self.n_devices = n_devices
        self.manager = manager
        self.meter = meter
        self.handle = handle
        self.observes = 0
        self.tokens = 0

    @property
    def protocol(self) -> str | None:
        """The protocol the session is currently priced/observed on
        (follows cross-protocol replans via the meter)."""
        return self.meter.protocol

    def observe(self, nbytes: int, latency_s: float, retries: int = 0) -> bool:
        """One device-reported hop measurement; True if it triggered a
        replan adoption."""
        self.observes += 1
        return self.meter.observe_hop(nbytes, latency_s, retries)

    def on_token(self) -> None:
        """One generated token: price every inter-segment hop on the
        session's current plan/link (feeding the estimators)."""
        self.tokens += 1
        self.meter.on_token()

    def counters(self) -> dict[str, int]:
        return self.manager.counters()

    def adoption_violations(self) -> int:
        """Stale-adoption audit: adopted generations must be strictly
        increasing per fleet size (0 = the PR 5 swap contract held)."""
        last: dict[int, int] = {}
        bad = 0
        for n, gen in self.handle.adoptions:
            if gen <= last.get(n, -1):
                bad += 1
            last[n] = gen
        return bad


class FleetGateway:
    """Asyncio serving gateway multiplexing device sessions onto one
    shared planning stack. See the module docstring for the layer map.

    ``fleet_sizes`` fixes the device-count vocabulary up front so the
    whole surface family is built in ONE multi-size ``build_surfaces``
    call; ``executor`` (anything with ``submit``, e.g.
    :class:`~repro.core.async_replan.ManualExecutor`) makes rebuild
    timing deterministic in tests. ``manager_kwargs`` pass through to
    every session's :class:`~repro.core.adaptive.AdaptiveSplitManager`
    (e.g. ``replan_threshold``, ``stale_rtol``)."""

    def __init__(
        self,
        cost_model: SplitCostModel,
        protocols: Mapping[str, LinkProfile],
        fleet_sizes: Sequence[int],
        *,
        solver: str = "beam",
        surface_grid: dict | None = None,
        executor=None,
        max_pending: int = 4096,
        session_window: int = 256,
        fleet_window: int = 8192,
        clock=time.perf_counter,
        **manager_kwargs,
    ):
        self.cost_model = cost_model
        self.protocols = dict(protocols)
        self.fleet_sizes = tuple(dict.fromkeys(int(n) for n in fleet_sizes))
        self.solver = solver
        self.surface_grid = dict(surface_grid or {})
        self.max_pending = max_pending
        self.manager_kwargs = manager_kwargs
        self._clock = clock
        batched = _batched_twin(solver)
        # the WHOLE per-size surface family in one batched solve; the
        # request is kept as a serializable PlanSpec (``plan_spec``) —
        # the same object a process-pool rebuild would ship — and the
        # family is resolved from it
        grid = dict(self.surface_grid)
        grid.setdefault("pt_scale", DEFAULT_PT_SCALES)
        grid.setdefault("loss_p", DEFAULT_LOSS_GRID)
        if "mesh_spec" in grid:  # build_surfaces spells the knob mesh_spec
            grid["mesh"] = grid.pop("mesh_spec")
        self.plan_spec = surfaces_spec(
            cost_model, self.protocols, self.fleet_sizes,
            solver=batched, **grid)
        self.surfaces = PlannerService().build_surfaces(self.plan_spec)
        self.rebuilder = SurfaceRebuilder(
            cost_model, self.protocols, solver=batched,
            executor=executor, **self.surface_grid)
        self.fanout = RebuildFanout(self.rebuilder)
        # link-independent local cost tensors, one per fleet size,
        # shared by every session of that size
        self._local_tensors = {
            n: cost_model.local_cost_tensor(n) for n in self.fleet_sizes}
        self.sessions: dict[str, GatewaySession] = {}
        self.qos = QosMonitor(key_window=session_window,
                              global_window=fleet_window)
        # token-loop wall times get their own window (the fleet p50/p99
        # in snapshots cover OBSERVE handling only)
        self.token_window = RollingWindow(fleet_window)
        self._queue: deque[tuple] = deque()
        self._running = False
        self._snapshots = 0
        self.registered_total = 0
        self.dropped_total = 0
        self.rebuild_errors = 0

    # -- session lifecycle -------------------------------------------------
    def register(self, session_id: str, n_devices: int,
                 bytes_per_token: int = 0) -> GatewaySession:
        """Bring up a session: O(1) surface-lookup initial decision (no
        per-registration solve), a fresh manager sharing the prebuilt
        surface + local tensor for its fleet size, and a meter following
        the initial decision's protocol/link."""
        if session_id in self.sessions:
            raise ValueError(f"session {session_id!r} already registered")
        if n_devices not in self.surfaces:
            raise KeyError(
                f"n_devices={n_devices} not in the gateway's prebuilt "
                f"family {self.fleet_sizes}")
        handle = self.fanout.view()
        manager = AdaptiveSplitManager(
            cost_model=self.cost_model, protocols=dict(self.protocols),
            n_devices=n_devices, solver=self.solver,
            surface=self.surfaces[n_devices],
            surface_grid=self.surface_grid or None,
            async_rebuild=handle,
            initial="surface", offsurface_fallback="stale",
            local_tensor=self._local_tensors[n_devices],
            **self.manager_kwargs)
        cur = manager.current
        if cur is None:
            raise RuntimeError(
                f"no feasible initial plan for n_devices={n_devices}")
        meter = SplitLatencyMeter(
            plan=manager.current_plan(),
            link=replace(self.protocols[cur.protocol],
                         mtu_bytes=cur.chunk_bytes),
            bytes_per_token=bytes_per_token,
            manager=manager, protocol=cur.protocol)
        sess = GatewaySession(session_id, n_devices, manager, meter, handle)
        self.sessions[session_id] = sess
        self.registered_total += 1
        self.qos.bump("registrations")
        return sess

    def drop(self, session_id: str) -> bool:
        """Remove a session (its queued events are discarded when
        pumped; its QoS window is released). False if unknown."""
        sess = self.sessions.pop(session_id, None)
        if sess is None:
            return False
        sess.manager.close()  # no-op for the shared handle, by contract
        self.qos.drop(session_id)
        self.dropped_total += 1
        self.qos.bump("drops")
        return True

    # -- event ingress (bounded, shedding) ---------------------------------
    def submit_observe(self, session_id: str, nbytes: int,
                       latency_s: float, retries: int = 0) -> bool:
        """Enqueue a device-reported hop measurement. False = SHED (queue
        at ``max_pending``) — counted, never silently dropped."""
        return self._submit(("observe", session_id, nbytes,
                             latency_s, retries))

    def submit_token(self, session_id: str) -> bool:
        """Enqueue a token-loop tick for the session."""
        return self._submit(("token", session_id))

    def _submit(self, event: tuple) -> bool:
        if len(self._queue) >= self.max_pending:
            self.qos.bump("events_shed")
            return False
        self._queue.append(event)
        self.qos.bump("events_submitted")
        return True

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- event processing --------------------------------------------------
    def pump(self, max_events: int | None = None) -> int:
        """Drain up to ``max_events`` queued events synchronously (all of
        them when None). Observe/token handling is timed into the QoS
        windows; a failed background rebuild surfacing through
        ``observe`` is counted (``rebuild_errors``) and serving
        continues on the stale surface."""
        done = 0
        while self._queue and (max_events is None or done < max_events):
            event = self._queue.popleft()
            done += 1
            sess = self.sessions.get(event[1])
            if sess is None:  # dropped while queued
                self.qos.bump("events_orphaned")
                continue
            t0 = self._clock()
            try:
                if event[0] == "observe":
                    _, sid, nbytes, latency_s, retries = event
                    sess.observe(nbytes, latency_s, retries)
                    self.qos.record(sid, self._clock() - t0)
                else:
                    sess.on_token()
                    self.qos.bump("tokens_processed")
                    self.token_window.add(self._clock() - t0)
            except RuntimeError:
                # a background rebuild failed; the session keeps serving
                # from its stale surface and the next material drift
                # re-requests (the manager reset its staleness window)
                self.rebuild_errors += 1
                self.qos.bump("rebuild_errors")
            self.qos.bump("events_processed")
        return done

    # -- asyncio surface ---------------------------------------------------
    async def serve(self, *, batch: int = 256,
                    idle_sleep_s: float = 0.001) -> None:
        """Pump the event queue forever (until :meth:`stop`): drain up
        to ``batch`` events per scheduling slice, yield to the loop
        between slices, sleep briefly when idle. Register/drop/submit
        freely from other coroutines while this runs."""
        self._running = True
        try:
            while self._running:
                n = self.pump(batch)
                if n == 0:
                    await asyncio.sleep(idle_sleep_s)
                else:
                    await asyncio.sleep(0)  # cooperative yield
        finally:
            self._running = False

    def stop(self) -> None:
        self._running = False

    # -- QoS ---------------------------------------------------------------
    def snapshot(self, include_sessions: bool = False) -> FleetSnapshot:
        """Periodic fleet snapshot. Also sweeps the fanout across every
        fleet size so completed rebuilds are published even for sizes
        whose sessions all dropped mid-build (otherwise an unclaimed
        result would keep the rebuilder's fast-path flag hot forever)."""
        for n in self.fleet_sizes:
            try:
                self.fanout.refresh(n)
            except RuntimeError:
                self.rebuild_errors += 1
                self.qos.bump("rebuild_errors")
        counters: dict[str, int] = dict(self.qos.counters)
        agg: dict[str, int] = {}
        violations = 0
        per_session: list[SessionSnapshot] = []
        for sid, sess in self.sessions.items():
            for k, v in sess.counters().items():
                agg[k] = agg.get(k, 0) + v
            violations += sess.adoption_violations()
            if include_sessions:
                p50, p99 = self.qos.key_percentiles(sid)
                per_session.append(SessionSnapshot(
                    session_id=sid, n_devices=sess.n_devices,
                    observes=sess.observes, p50_s=p50, p99_s=p99,
                    counters=sess.counters()))
        counters.update(agg)
        counters["stale_adoption_violations"] = violations
        counters["builds_started"] = self.rebuilder.builds_started
        counters["builds_completed"] = self.rebuilder.builds_completed
        counters["rebuilder_requests"] = self.rebuilder.requests
        counters["rebuilder_requests_coalesced"] = \
            self.rebuilder.requests_coalesced
        counters["queue_depth"] = len(self._queue)
        p50, p99 = self.qos.fleet_percentiles()
        self._snapshots += 1
        return FleetSnapshot(
            seq=self._snapshots, n_sessions=len(self.sessions),
            observes=self.qos.global_window.count, p50_s=p50, p99_s=p99,
            counters=counters, sessions=tuple(per_session))

    def close(self) -> None:
        """Shut the shared rebuilder down (terminal; sessions keep
        serving from their current surfaces)."""
        self.stop()
        self.fanout.shutdown()
