"""Gradient compression for data-parallel reduction (int8 + error feedback).

On a multi-pod fleet the DP gradient all-reduce crosses DCN — the paper's
lossy, bandwidth-limited hop. Int8 compression cuts those bytes 4x
(vs f32) at the cost of quantization noise; the error-feedback buffer
(Seide et al. 2014; Karimireddy et al. 2019) re-injects the residual next
step so the noise doesn't bias the trajectory.

Functional API so it composes with the jitted train step; the feedback
buffer lives in the optimizer-state pytree and shards like the params.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 round trip with error feedback.
    Returns (decompressed gradient, new error residual)."""
    g32 = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), g32 - deq


def compress_grads(grads: Any, err_state: Any) -> tuple[Any, Any]:
    """Apply int8+EF compression leaf-wise (what would cross the DCN wire
    is ``q`` + one scale per tensor — 4x fewer bytes than f32)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e


def wire_bytes(grads: Any) -> tuple[int, int]:
    """(compressed, uncompressed) bytes a DP all-reduce would move."""
    comp = sum(x.size + 4 for x in jax.tree.leaves(grads))
    raw = sum(x.size * 4 for x in jax.tree.leaves(grads))
    return comp, raw
