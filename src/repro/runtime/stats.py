"""QoS telemetry primitives for the serving gateway.

Pure-Python, allocation-light building blocks the
:mod:`repro.runtime.gateway` layers over thousands of concurrent
sessions:

* :func:`percentile` — linear-interpolation percentile identical to
  ``np.percentile(..., method="linear")`` (the default), so fleet p50/p99
  numbers are directly comparable to any NumPy-side analysis and the
  parity is unit-tested against the NumPy oracle.
* :class:`RollingWindow` — a fixed-size ring buffer of floats: O(1)
  ``add``, percentiles over the last ``maxlen`` samples. Bounded by
  construction, so 10k sessions cannot grow memory without bound.
* :class:`QosMonitor` — per-key rolling latency windows plus one
  fleet-global window and a set of monotonic counters; the gateway keys
  windows by session id and aggregates snapshots from here.

Snapshots (:class:`SessionSnapshot` / :class:`FleetSnapshot`) are frozen
value objects: safe to hand to logging/export threads while serving
continues.
"""

from __future__ import annotations

import math
from collections import Counter
from dataclasses import dataclass, field
from typing import Hashable, Iterable, Mapping, Sequence

__all__ = [
    "FleetSnapshot",
    "QosMonitor",
    "RollingWindow",
    "SessionSnapshot",
    "percentile",
]


def percentile(values: Iterable[float], q: float) -> float:
    """The ``q``-th percentile of ``values`` by linear interpolation —
    the same estimator as ``np.percentile(values, q)`` with the default
    ``method="linear"``: rank ``(n-1) * q/100`` with fractional part
    ``t`` interpolated as ``lo + (hi - lo) * t`` (NumPy's lerp form, so
    the parity test can assert exact equality, not approx)."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile of an empty sequence")
    if len(xs) == 1:
        return xs[0]
    rank = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return xs[lo]
    t = rank - lo
    return xs[lo] + (xs[hi] - xs[lo]) * t


class RollingWindow:
    """Fixed-size ring buffer of float samples.

    ``add`` is O(1); ``count`` is the LIFETIME number of samples (it
    keeps growing past ``maxlen``), while percentiles/mean cover only
    the retained last-``maxlen`` window."""

    __slots__ = ("maxlen", "count", "_buf")

    def __init__(self, maxlen: int = 256):
        if maxlen <= 0:
            raise ValueError(f"maxlen must be positive, got {maxlen}")
        self.maxlen = maxlen
        self.count = 0
        self._buf: list[float] = []

    def add(self, value: float) -> None:
        v = float(value)
        if len(self._buf) < self.maxlen:
            self._buf.append(v)
        else:
            self._buf[self.count % self.maxlen] = v
        self.count += 1

    def __len__(self) -> int:
        return len(self._buf)

    def values(self) -> tuple[float, ...]:
        """The retained samples (arbitrary order — fine for order
        statistics)."""
        return tuple(self._buf)

    def mean(self) -> float:
        if not self._buf:
            raise ValueError("mean of an empty window")
        return sum(self._buf) / len(self._buf)

    def percentile(self, q: float) -> float:
        return percentile(self._buf, q)

    def percentiles(self, qs: Sequence[float] = (50.0, 99.0)
                    ) -> tuple[float, ...]:
        xs = sorted(self._buf)
        if not xs:
            raise ValueError("percentiles of an empty window")
        out = []
        for q in qs:
            if not 0.0 <= q <= 100.0:
                raise ValueError(f"percentile q must be in [0, 100], got {q}")
            rank = (len(xs) - 1) * (q / 100.0)
            lo, hi = math.floor(rank), math.ceil(rank)
            out.append(xs[lo] if lo == hi
                       else xs[lo] + (xs[hi] - xs[lo]) * (rank - lo))
        return tuple(out)


@dataclass(frozen=True)
class SessionSnapshot:
    """One session's QoS at snapshot time: rolling observe-latency
    percentiles plus the adaptive-layer counters
    (:meth:`repro.core.adaptive.AdaptiveSplitManager.counters`)."""

    session_id: str
    n_devices: int
    observes: int
    p50_s: float
    p99_s: float
    counters: Mapping[str, int] = field(default_factory=dict)


@dataclass(frozen=True)
class FleetSnapshot:
    """Fleet-wide QoS at snapshot time. ``counters`` merges the
    gateway's own counters (events/shedding/builds) with the summed
    per-session adaptive counters; percentiles come from the global
    rolling window (NaN when nothing was recorded yet)."""

    seq: int
    n_sessions: int
    observes: int
    p50_s: float
    p99_s: float
    counters: Mapping[str, int] = field(default_factory=dict)
    sessions: tuple[SessionSnapshot, ...] = ()


class QosMonitor:
    """Per-key rolling latency windows + one global window + counters.

    The gateway records every processed observe's wall time under its
    session id; ``drop`` releases a departed session's window (bounded
    memory under churn). Counters are a plain :class:`collections.Counter`
    — monotonic, aggregatable, JSON-friendly."""

    def __init__(self, key_window: int = 256, global_window: int = 8192):
        self.key_window = key_window
        self._windows: dict[Hashable, RollingWindow] = {}
        self.global_window = RollingWindow(global_window)
        self.counters: Counter[str] = Counter()

    def record(self, key: Hashable, seconds: float) -> None:
        w = self._windows.get(key)
        if w is None:
            w = self._windows[key] = RollingWindow(self.key_window)
        w.add(seconds)
        self.global_window.add(seconds)

    def bump(self, name: str, k: int = 1) -> None:
        self.counters[name] += k

    def drop(self, key: Hashable) -> None:
        self._windows.pop(key, None)

    def window(self, key: Hashable) -> RollingWindow | None:
        return self._windows.get(key)

    def key_percentiles(self, key: Hashable,
                        qs: Sequence[float] = (50.0, 99.0)
                        ) -> tuple[float, ...]:
        w = self._windows.get(key)
        if w is None or not len(w):
            return tuple(float("nan") for _ in qs)
        return w.percentiles(qs)

    def fleet_percentiles(self, qs: Sequence[float] = (50.0, 99.0)
                          ) -> tuple[float, ...]:
        if not len(self.global_window):
            return tuple(float("nan") for _ in qs)
        return self.global_window.percentiles(qs)
