"""Fault-tolerant training loop.

Production behaviors implemented (and tested in
``tests/test_fault_tolerance.py``):

  * **checkpoint/restart** — periodic async checkpoints (params + opt
    state + step); on start, the loop restores the latest checkpoint and
    replays the data stream from the restored step (the pipeline is
    index-addressable, so restart is bitwise-exact);
  * **failure handling** — any exception mid-run leaves the newest
    checkpoint intact (atomic publish); an injectable failure hook lets
    tests kill the loop at an arbitrary step and assert exact resume;
  * **straggler mitigation** — per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA fire a mitigation callback (on a real
    fleet: re-shard/evict the slow host; here: recorded + surfaced) —
    plus optional per-step deadline;
  * **gradient compression** — opt-in int8+error-feedback on the DP
    gradients (see ``runtime/compression.py``);
  * **elastic scaling hooks** — the loop is mesh-agnostic: on restart it
    re-builds the jitted step for whatever mesh is passed, so a resumed
    run may use a different device count (checkpoints store unsharded
    host arrays).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime.compression import compress_grads, init_error_feedback


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0
    step_deadline_s: float | None = None
    grad_compression: bool = False
    seed: int = 0


@dataclass
class StepRecord:
    step: int
    loss: float
    grad_norm: float
    wall_s: float
    straggler: bool = False


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        data,
        store: CheckpointStore,
        loop_cfg: TrainLoopConfig | None = None,
        opt_cfg: AdamWConfig | None = None,
        failure_hook: Callable[[int], None] | None = None,
        straggler_hook: Callable[[StepRecord], None] | None = None,
    ):
        self.cfg = model_cfg
        self.data = data
        self.store = store
        self.loop_cfg = loop_cfg or TrainLoopConfig()
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.failure_hook = failure_hook
        self.straggler_hook = straggler_hook
        self.history: list[StepRecord] = []

        base_step = make_train_step(self.cfg, self.opt_cfg)
        if self.loop_cfg.grad_compression:
            base_step = self._with_compression()
        self._step_fn = jax.jit(base_step)

    # -- gradient-compression variant of the step ----------------------------
    def _with_compression(self):
        cfg, opt_cfg = self.cfg, self.opt_cfg
        N = cfg.train_microbatches

        def step(params, opt_state, batch):
            ef = opt_state["error_feedback"]
            inner = {k: opt_state[k] for k in ("mu", "nu", "step")}
            if N <= 1:
                loss, grads = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, batch))(params)
            else:
                def micro(acc, mb):
                    l, g = jax.value_and_grad(
                        lambda p: T.loss_fn(cfg, p, mb))(params)
                    return jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g), l

                acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                acc, losses = jax.lax.scan(micro, acc0, batch)
                grads = jax.tree.map(lambda a: a / N, acc)
                loss = jnp.mean(losses)
            grads, new_ef = compress_grads(grads, ef)
            params, inner, metrics = adamw_update(grads, inner, params, opt_cfg)
            new_state = dict(inner, error_feedback=new_ef)
            return params, new_state, {"loss": loss, **metrics}

        return step

    # -- state ----------------------------------------------------------------
    def init_state(self) -> tuple[Any, Any, int]:
        params = T.init_params(jax.random.PRNGKey(self.loop_cfg.seed), self.cfg)
        opt = adamw_init(params)
        if self.loop_cfg.grad_compression:
            opt = dict(opt, error_feedback=init_error_feedback(params))
        return params, opt, 0

    def restore_or_init(self) -> tuple[Any, Any, int]:
        params, opt, _ = self.init_state()
        if self.store.latest_step() is None:
            return params, opt, 0
        (params, opt), extra = self.store.restore((params, opt))
        return params, opt, int(extra["next_step"])

    # -- run -------------------------------------------------------------------
    def run(self, max_steps: int | None = None) -> list[StepRecord]:
        params, opt, start = self.restore_or_init()
        total = self.loop_cfg.total_steps if max_steps is None else start + max_steps
        ewma = None
        try:
            for step in range(start, total):
                if self.failure_hook is not None:
                    self.failure_hook(step)  # may raise — simulated node failure
                t0 = time.monotonic()
                batch = self.data.batch_at(step)
                params, opt, metrics = self._step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                wall = time.monotonic() - t0
                ewma = wall if ewma is None else 0.9 * ewma + 0.1 * wall
                straggler = (
                    wall > self.loop_cfg.straggler_factor * ewma
                    or (self.loop_cfg.step_deadline_s is not None
                        and wall > self.loop_cfg.step_deadline_s)
                )
                rec = StepRecord(step, loss, float(metrics["grad_norm"]), wall, straggler)
                self.history.append(rec)
                if straggler and self.straggler_hook is not None:
                    self.straggler_hook(rec)
                if (step + 1) % self.loop_cfg.ckpt_every == 0 or step + 1 == total:
                    self.store.save_async(step + 1, (params, opt),
                                          extra={"next_step": step + 1})
        finally:
            # flush the in-flight async checkpoint even when a step raises:
            # its snapshot was already taken, and losing it on a crash is
            # exactly the failure mode checkpointing exists to prevent
            self.store.wait()
        self._final = (params, opt)
        return self.history
