"""Checkpointing: pytree save/restore with step resume and retention.

Design (multi-host-shaped, single-host executed here):
  * a checkpoint is a directory ``step_<k>/`` holding one ``.npz`` per
    host-shard (this container: shard 0) plus a ``manifest.json`` with the
    step, pytree structure and integrity digests;
  * writes go to a temp dir + atomic rename — a crashed writer never
    corrupts the latest checkpoint (the fault-tolerance contract);
  * ``save_async`` offloads serialization to a background thread so the
    train loop only blocks on device->host transfer (the usual overlap);
  * retention keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


class CheckpointStore:
    def __init__(self, root: str | Path, keep: int = 3, shard_id: int = 0):
        self.root = Path(root)
        self.keep = keep
        self.shard_id = shard_id
        self.root.mkdir(parents=True, exist_ok=True)
        self._pending: threading.Thread | None = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> Path:
        """Blocking save with atomic publish."""
        arrays, _ = _flatten(tree)
        tmp = self.root / f".tmp_step_{step}_{os.getpid()}"
        final = self.root / f"step_{step}"
        tmp.mkdir(parents=True, exist_ok=True)
        shard_file = tmp / f"shard_{self.shard_id}.npz"
        np.savez(shard_file, **arrays)
        digest = zlib.crc32(shard_file.read_bytes())
        manifest = {
            "step": step,
            "n_leaves": len(arrays),
            "shards": {str(self.shard_id): f"shard_{self.shard_id}.npz"},
            "crc32": {str(self.shard_id): digest},
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._retain()
        return final

    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> None:
        """Snapshot to host, then serialize in a background thread."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # device->host now
        t = threading.Thread(target=self.save, args=(step, host_tree, extra),
                             daemon=True)
        t.start()
        self._pending = t

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -- read ----------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``; returns
        (tree, manifest.extra). Verifies shard integrity."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        shard_file = d / manifest["shards"][str(self.shard_id)]
        digest = zlib.crc32(shard_file.read_bytes())
        if digest != manifest["crc32"][str(self.shard_id)]:
            raise IOError(f"checkpoint shard corrupt at step {step}")
        arrays = np.load(shard_file)
        leaves, treedef = jax.tree.flatten(template)
        assert len(leaves) == manifest["n_leaves"], "pytree structure changed"
        restored = [arrays[f"leaf_{i}"] for i in range(len(leaves))]
        restored = [
            np.asarray(r).astype(l.dtype) if hasattr(l, "dtype") else r
            for r, l in zip(restored, leaves)
        ]
        return jax.tree.unflatten(treedef, restored), manifest.get("extra", {})

    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.root / f"step_{s}", ignore_errors=True)
