"""Step-function builders: jitted train / prefill / decode steps with
mesh shardings attached. Used by the dry-run, the trainer and the server."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import ShapeSpec, cache_specs, input_specs
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import (
    cache_sharding,
    input_sharding,
    params_sharding,
    replicated,
)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    n_microbatches: int | None = None, accum_shardings=None):
    """One optimizer step. With microbatching the batch arrives pre-split
    as (N, B/N, ...) and gradients are accumulated across a microbatch
    scan (gradient accumulation — the production activation-memory lever),
    then averaged before the AdamW update.

    ``accum_shardings``: optional NamedSharding pytree pinning the grad
    accumulator to ZeRO (DP-sharded) layout INSIDE the loop — each
    microbatch's gradient is then reduce-scattered rather than all-reduced
    (half the wire bytes) and the accumulator itself shards 1/dp."""
    opt_cfg = opt_cfg or AdamWConfig()
    N = cfg.train_microbatches if n_microbatches is None else n_microbatches

    def _pin(tree):
        if accum_shardings is None:
            return tree
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s),
            tree, accum_shardings)

    def train_step(params, opt_state, batch):
        if N <= 1:
            loss, grads = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, p, batch))(params)
        else:
            acc_dt = jnp.dtype(cfg.grad_accum_dtype)

            def micro(accum, mb):
                l, g = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, mb))(params)
                accum = jax.tree.map(
                    lambda a, gg: a + gg.astype(acc_dt), accum, g)
                return _pin(accum), l

            accum0 = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            accum, losses = jax.lax.scan(micro, accum0, batch)
            grads = jax.tree.map(lambda a: a / N, accum)
            loss = jnp.mean(losses)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    import dataclasses

    # serving prefill has no backward pass: enable causal block skipping
    cfg = dataclasses.replace(cfg, causal_skip=True)

    def prefill_step(params, batch):
        logits, _ = T.forward(cfg, params, batch)
        # serving returns just the next-token logits for the last position
        return logits[:, -1]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, inputs, cache):
        logits, cache = T.serve_step(cfg, params, inputs, cache)
        return logits, cache

    return decode_step


def abstract_state(cfg: ModelConfig, with_opt: bool = True):
    """ShapeDtypeStruct pytrees for params (and optimizer state)."""
    params = jax.eval_shape(lambda r: T.init_params(r, cfg), jax.random.PRNGKey(0))
    if not with_opt:
        return params
    opt = jax.eval_shape(
        lambda p: adamw_init(p, moments_dtype=jnp.dtype(cfg.opt_moments_dtype)),
        params)
    return params, opt


def _dp_size(mesh: Mesh) -> int:
    out = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            out *= mesh.shape[a]
    return out


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               opt_cfg: AdamWConfig | None = None):
    """Assemble (jitted_fn, example_args_structs) for one (arch x shape)
    cell with all in/out shardings bound — ready to .lower()."""
    from repro.configs import effective_microbatches

    dp = _dp_size(mesh)
    batch_struct = input_specs(cfg, shape, dp_size=dp)
    b_shard = input_sharding(cfg, mesh, batch_struct)

    if shape.kind == "train":
        params, opt = abstract_state(cfg, with_opt=True)
        p_shard = params_sharding(params, mesh, fsdp=cfg.fsdp)
        # optimizer moments always DP-sharded (ZeRO-1); XLA derives the
        # grad reduce-scatter + updated-param all-gather from the specs
        o_shard = params_sharding(opt, mesh, fsdp=True)
        fn = jax.jit(
            make_train_step(cfg, opt_cfg,
                            n_microbatches=effective_microbatches(cfg, shape, dp)),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, replicated(mesh)),
            donate_argnums=(0, 1),
        )
        return fn, (params, opt, batch_struct)

    params = abstract_state(cfg, with_opt=False)
    p_shard = params_sharding(params, mesh, fsdp=cfg.fsdp_inference)

    if shape.kind == "prefill":
        fn = jax.jit(
            make_prefill_step(cfg),
            in_shardings=(p_shard, b_shard),
            out_shardings=replicated(mesh),
        )
        return fn, (params, batch_struct)

    # decode
    cache = cache_specs(cfg, shape)
    c_shard = cache_sharding(cfg, cache, mesh, shape.global_batch)
    fn = jax.jit(
        make_decode_step(cfg),
        in_shardings=(p_shard, b_shard, c_shard),
        out_shardings=(replicated(mesh), c_shard),
        donate_argnums=(2,),
    )
    return fn, (params, batch_struct, cache)
