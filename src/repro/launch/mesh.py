"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 chips (data, model). Multi-pod: 2 pods x 256
    chips (pod, data, model) — the 'pod' axis crosses DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """A mesh over whatever devices exist locally (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
