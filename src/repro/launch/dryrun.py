import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    # The two LICM passes hoist f32 operand-converts of scanned bf16
    # weight/cache stacks out of while loops — ops that only exist in the
    # CPU lowering (TPU MXUs consume bf16 natively). Leaving them enabled
    # inflates the per-device memory estimate by full-stack f32 copies
    # (e.g. +11 GB on the 235B MoE train cell). Disabling them makes
    # memory_analysis() faithful to the TPU buffer set.
    " --xla_disable_hlo_passes=while-loop-expensive-invariant-code-motion,"
    "while-loop-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init). For each cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles the jitted step with full in/out shardings (launch.steps),
  3. ``.lower(...).compile()`` from ShapeDtypeStructs (no allocation),
  4. records ``memory_analysis()`` (proves the cell fits HBM),
     ``cost_analysis()`` (FLOPs / bytes for the roofline), and the
     per-collective byte totals parsed from the optimized HLO,
  5. writes ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --all [--multi-pod|--both]
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

# `%name = <output-shape(s)> <kind>(operands...)` — output shapes sit
# between '=' and the op mnemonic in optimized HLO.
_COLL_RE = re.compile(
    r"=\s+(\(?[\w\[\],{}\s]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += int(n * _DTYPE_BYTES[dt])
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind output-shape bytes of every collective in the optimized
    HLO (-start counted once; -done lines are the async completions)."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shapes_str)
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def _flops_and_bytes(cost: dict) -> tuple[float, float]:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return flops, byts


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        fn, args = build_cell(cfg, shape, mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from repro.parallel.hlo_analysis import weighted_collective_bytes

    coll_weighted = weighted_collective_bytes(hlo)
    flops, byts = _flops_and_bytes(cost)
    n_dev = mesh.size

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "compile_s": round(time.time() - t0, 1),
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collectives": coll,
        "collectives_weighted": coll_weighted,  # loop-trip-aware (§Roofline)
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "params": cfg.n_params,
    }
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        path = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
        path.write_text(json.dumps(record, indent=1))
    hbm = 16 * 1024**3
    print(
        f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:7s} "
        f"compile {record['compile_s']:6.1f}s  "
        f"mem/dev {record['memory']['peak_estimate_bytes'] / 1e9:6.2f} GB "
        f"({'fits' if record['memory']['peak_estimate_bytes'] < hbm else 'OVER'})  "
        f"flops/dev {flops:.3e}  coll {coll['total_bytes'] / 1e6:8.1f} MB",
        flush=True,
    )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true", help="run 16x16 and 2x16x16")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()} — "
        "XLA_FLAGS must be set before any jax import")

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in applicable_shapes(arch):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod] if not args.both else [False, True]
    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            try:
                run_cell(arch, shape, multi_pod)
            except Exception as e:  # noqa: BLE001 — report all failures at the end
                failures.append((arch, shape, multi_pod, repr(e)[:200]))
                print(f"[dryrun] FAIL {arch} {shape} multi_pod={multi_pod}: {e}",
                      flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print(f"[dryrun] all {len(cells) * len(meshes)} cells compiled OK")


if __name__ == "__main__":
    main()
