"""AdamW from scratch (no optax in this environment).

Functional API mirroring the standard formulation (Loshchilov & Hutter):
moments are stored in f32 regardless of param dtype (mixed-precision
training convention); the optimizer state shards exactly like the params
(same pytree structure), so DP/TP sharding rules apply unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float | None = 1.0


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params: Any, moments_dtype=jnp.float32) -> dict:
    """``moments_dtype=bfloat16`` halves optimizer-state HBM (used for the
    235B-scale arch where f32 moments alone are 7.4 GB/device); the update
    math still runs in f32 (cast in, cast out)."""
    zeros = lambda t: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, dtype=moments_dtype), t)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), dtype=jnp.int32)}


def adamw_update(grads: Any, state: dict, params: Any, cfg: AdamWConfig
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = cfg.lr(step) if callable(cfg.lr) else jnp.asarray(cfg.lr, jnp.float32)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def kernel(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu2 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu2 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mu_hat = mu2 / b1c
        nu_hat = nu2 / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu2.astype(mu.dtype), nu2.astype(nu.dtype))

    upd = kernel  # elementwise chain; XLA fuses and aliases donated buffers

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
