"""Learning-rate schedules (functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32) / total_steps, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return peak_lr * (final_frac + (1 - final_frac) * cos)

    return fn


def linear_warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        t = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)

    return fn
