from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine  # noqa: F401
