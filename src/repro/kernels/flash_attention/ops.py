"""Public op: flash attention in model layout (B, S, H, D)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, q_positions, kv_positions, scale,
                    block_q: int = 256, block_kv: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); GQA via head grouping.

    ``q_positions`` may be (B, Sq) (uniform across batch assumed — decode
    and prefill both satisfy this) or (Sq,)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    if q_positions.ndim == 2:
        q_positions = q_positions[0]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, k.shape[1], D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, v.shape[1], D)
    out = flash_attention_kernel(qf, kf, vf, q_positions, kv_positions,
                                 scale=scale, block_q=block_q,
                                 block_kv=block_kv, interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
