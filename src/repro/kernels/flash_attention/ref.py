"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, q_positions, kv_positions, scale):
    """Materialized-softmax reference. q: (BH, Sq, D); k/v: (BHkv, Skv, D)."""
    BH, Sq, D = q.shape
    BHkv = k.shape[0]
    group = BH // BHkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    mask = kv_positions[None, None, :] <= q_positions[None, :, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)
