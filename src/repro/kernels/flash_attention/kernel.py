"""Pallas TPU kernel: causal flash attention (block-wise online softmax).

Serving-prefill hot path: never materializes the (Sq, Skv) score matrix.
Grid = (batch*heads, q_blocks, kv_blocks) with kv innermost; the running
max/denominator/accumulator live in VMEM scratch across kv steps and the
normalized output is written on the last kv block.

Causality: fully-masked kv blocks (block start beyond the q block's last
position) are skipped via ``pl.when`` — on TPU the grid is executed
sequentially per core, so skipped blocks cost only the (tiny) predicate.
The diagonal blocks apply an elementwise position mask.

GQA is handled without materializing repeated KV heads: the kv BlockSpec
index_map maps attention head h to kv head h // group_size.

Block sizes (q 256, kv 512) x head_dim 128 give a working set of
~0.6 MB (q, k, v, p blocks + f32 accumulators) — comfortably inside VMEM
with double buffering; both are multiples of the 128-lane MXU tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, n_kv: int,
                  block_q: int, block_kv: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qpos_ref[0, :]  # (block_q,)
    k_pos = kpos_ref[0, :]  # (block_kv,)

    # skip blocks that are entirely in the causal future of this q block
    @pl.when(k_pos[0] <= q_pos[-1])
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_q", "block_kv", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,  # (BH, Sq, D)   batch*heads folded
    k: jax.Array,  # (BHkv, Skv, D)
    v: jax.Array,  # (BHkv, Skv, D)
    q_positions: jax.Array,  # (Sq,) int32
    kv_positions: jax.Array,  # (Skv,) int32
    *,
    scale: float,
    block_q: int = 256,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    group = BH // BHkv  # GQA: q heads per kv head (within the folded dim)

    bq, bkv = min(block_q, Sq), min(block_kv, Skv)
    pad_q, pad_kv = (-Sq) % bq, (-Skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        # pad with the last real position so the causal block-skip predicate
        # (which reads q_pos[-1]) stays sound; padded rows are sliced off.
        q_positions = jnp.pad(q_positions, (0, pad_q), mode="edge")
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad_kv), constant_values=2**30)
    Sqp, Skvp = q.shape[1], k.shape[1]
    n_q, n_kv = Sqp // bq, Skvp // bkv

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, n_kv=n_kv,
                          block_q=bq, block_kv=bkv),
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq), lambda h, qi, ki: (0, qi)),
            pl.BlockSpec((1, bkv), lambda h, qi, ki: (0, ki)),
            pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, qi, ki, g=group: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(
        q_positions.reshape(1, Sqp).astype(jnp.int32),
        kv_positions.reshape(1, Skvp).astype(jnp.int32),
        q, k, v,
    )
    if pad_q:
        out = out[:, :Sq]
    return out
