"""Pure-jnp oracle for the W8A8 quantized matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(a_q, w_q, a_scale, a_zp, w_scale, out_dtype=jnp.float32):
    """Exact integer-arithmetic reference (Jacob et al. CVPR'18 semantics)."""
    acc = jnp.matmul(a_q.astype(jnp.int32), w_q.astype(jnp.int32))
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    corr = a_zp.astype(jnp.int32) * colsum[None, :]
    deq = (acc - corr).astype(jnp.float32) * a_scale.astype(jnp.float32) * \
        w_scale.astype(jnp.float32)[None, :]
    return deq.astype(out_dtype)


def float_matmul_ref(a_q, w_q, a_scale, a_zp, w_scale):
    """Dequantize-then-matmul reference (same math, float order)."""
    a = (a_q.astype(jnp.float32) - a_zp.astype(jnp.float32)) * a_scale
    w = w_q.astype(jnp.float32) * w_scale[None, :]
    return a @ w


def w8a16_matmul_ref(x, w_q, w_scale):
    """Weight-only dequantize-then-matmul reference."""
    w = w_q.astype(jnp.float32) * w_scale.astype(jnp.float32)[None, :]
    return x.astype(jnp.float32) @ w
