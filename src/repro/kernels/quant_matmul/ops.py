"""Public op: quantized linear layer backed by the Pallas W8A8 kernel.

On CPU (this container) the kernel runs with ``interpret=True``; on TPU it
compiles to the MXU int8 path. ``quant_linear`` is the layer-level
convenience that quantizes activations on the fly against int8 weights
(the deployed TinyML segment hot path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, quantize
from repro.kernels.quant_matmul.kernel import quant_matmul_kernel, w8a16_matmul_kernel
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quant_matmul(a_q, w_q, a_scale, a_zp, w_scale, *, out_dtype=jnp.float32,
                 interpret: bool | None = None, **block_kw):
    """(M,K) int8 x (K,N) int8 -> (M,N) ``out_dtype``."""
    if interpret is None:
        interpret = not _on_tpu()
    return quant_matmul_kernel(a_q, w_q, jnp.asarray(a_scale), jnp.asarray(a_zp),
                               w_scale, out_dtype=out_dtype, interpret=interpret,
                               **block_kw)


def quant_linear(x: jax.Array, w: QTensor, *, use_kernel: bool = True,
                 interpret: bool | None = None) -> jax.Array:
    """x: (..., K) float; w: QTensor (K, N) int8 per-channel (axis=1).

    Quantizes activations per-tensor (asymmetric, TFLite convention) and
    runs the int8 GEMM."""
    assert w.axis in (1, None), "weights must be per-output-channel or per-tensor"
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    xa = quantize(x.reshape(-1, K), axis=None, symmetric=False)
    w_scale = (w.scale if w.axis == 1 else jnp.broadcast_to(w.scale, (w.values.shape[1],)))
    if use_kernel:
        out = quant_matmul(xa.values, w.values, xa.scale, xa.zero_point, w_scale,
                           interpret=interpret)
    else:
        out = quant_matmul_ref(xa.values, w.values, xa.scale, xa.zero_point, w_scale)
    return out.reshape(*batch_shape, -1).astype(x.dtype)


def w8a16_linear(x: jax.Array, w: QTensor, *, interpret: bool | None = None
                 ) -> jax.Array:
    """Weight-only quantized linear: float activations x int8 weights.
    w: QTensor (K, N), per-output-channel symmetric."""
    if interpret is None:
        interpret = not _on_tpu()
    assert w.axis in (1, None)
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    w_scale = (w.scale if w.axis == 1
               else jnp.broadcast_to(w.scale, (w.values.shape[1],)))
    out = w8a16_matmul_kernel(x.reshape(-1, K), w.values, w_scale,
                              interpret=interpret)
    return out.reshape(*batch_shape, -1).astype(x.dtype)
