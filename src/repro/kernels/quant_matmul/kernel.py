"""Pallas TPU kernel: W8A8 int8 GEMM with dequantization epilogue.

The paper deploys int8 post-training-quantized (TFLite) model segments;
this is the TPU-native realization of that compute path:

    C[m, n] = (sum_k (A_q[m,k] - a_zp) * W_q[k,n]) * a_scale * w_scale[n]
            = (acc[m, n] - a_zp * colsum[n]) * a_scale * w_scale[n]

where ``acc`` is the raw int8 x int8 -> int32 MXU matmul and ``colsum[n] =
sum_k W_q[k,n]`` is precomputed (the standard zero-point folding — keeps
the inner loop pure int8 GEMM).

Tiling: (bm x bk) @ (bk x bn) blocks with a VMEM int32 accumulator;
K is the innermost grid axis so the accumulator lives across K steps and
the dequant epilogue fires on the last one. Block defaults (128, 512, 128)
are MXU-aligned (multiples of 128) and keep the working set
(bm*bk + bk*bn int8 + bm*bn int32) ~ 0.4 MB << 16 MB VMEM, leaving room
for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmm_kernel(a_ref, w_ref, ascale_ref, azp_ref, wscale_ref, colsum_ref,
                o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        a_scale = ascale_ref[0, 0]
        a_zp = azp_ref[0, 0].astype(jnp.float32)
        corr = a_zp * colsum_ref[0, :].astype(jnp.float32)  # (bn,)
        w_scale = wscale_ref[0, :]  # (bn,)
        o_ref[...] = ((acc - corr[None, :]) * a_scale * w_scale[None, :]
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def quant_matmul_kernel(
    a_q: jax.Array,  # (M, K) int8
    w_q: jax.Array,  # (K, N) int8
    a_scale: jax.Array,  # scalar f32
    a_zp: jax.Array,  # scalar int32
    w_scale: jax.Array,  # (N,) f32 per-channel
    *,
    block_m: int = 128,
    block_n: int = 512,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    M, K = a_q.shape
    K2, N = w_q.shape
    assert K == K2, (a_q.shape, w_q.shape)

    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pad_m, pad_n, pad_k = (-M) % bm, (-N) % bn, (-K) % bk
    if pad_m or pad_k:
        a_q = jnp.pad(a_q, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w_q = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
    if pad_n:
        w_scale = jnp.pad(w_scale, (0, pad_n))
    Mp, Kp = a_q.shape
    _, Np = w_q.shape

    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)  # (Np,) zero-point folding
    n_m, n_n, n_k = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=n_k),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(
        a_q,
        w_q,
        a_scale.reshape(1, 1).astype(jnp.float32),
        a_zp.reshape(1, 1).astype(jnp.int32),
        w_scale.reshape(1, Np).astype(jnp.float32),
        colsum.reshape(1, Np),
    )
    if pad_m or pad_n:
        out = out[:M, :N]
    return out


# ---------------------------------------------------------------------------
# W8A16: weight-only int8 quantization (bf16/f32 activations x int8 weights)
# — the standard serving GEMM when activation quantization is too lossy.
# Dequantization happens per-tile in VMEM: w_tile.astype(f32) * scale[n].
# ---------------------------------------------------------------------------


def _w8a16_kernel(x_ref, w_ref, wscale_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # int8 -> f32 dequant (scale applied at epilogue)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...] * wscale_ref[0, :][None, :]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def w8a16_matmul_kernel(
    x: jax.Array,  # (M, K) float (bf16/f32)
    w_q: jax.Array,  # (K, N) int8
    w_scale: jax.Array,  # (N,) f32 per-channel symmetric
    *,
    block_m: int = 128,
    block_n: int = 512,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2

    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pad_m, pad_n, pad_k = (-M) % bm, (-N) % bn, (-K) % bk
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w_q = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
    if pad_n:
        w_scale = jnp.pad(w_scale, (0, pad_n))
    Mp, Kp = x.shape
    _, Np = w_q.shape
    n_m, n_n, n_k = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_w8a16_kernel, n_k=n_k),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, w_scale.reshape(1, Np).astype(jnp.float32))
    if pad_m or pad_n:
        out = out[:M, :N]
    return out
