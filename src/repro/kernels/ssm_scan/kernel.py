"""Pallas TPU kernel: chunked Mamba2/SSD scan.

The long-context (500k) hot path for the SSM/hybrid architectures. The
CUDA reference implementation is a warp-level associative scan; the
TPU-native adaptation is the chunk-parallel SSD decomposition — dense
(chunk x chunk) and (chunk x state) matmuls on the MXU, with the
inter-chunk recurrence carried *sequentially through the grid*: Pallas TPU
executes the grid in lexicographic order per core, so the running state
lives in VMEM scratch across chunk steps (same trick as the flash-attn
accumulator, applied along the time axis).

Grid: (batch*heads, n_chunks). Per step, for one (b, h):
    y_intra = (C B^T ∘ L) (dt x)          intra-chunk, L = exp(segsum(dA))
    y_state = (C ∘ exp(cum)) h_prev        carried-state contribution
    h_new   = exp(total) h_prev + (B ∘ decay_out)^T (dt x)

Chunk=128 keeps every operand 2D-tiled at (128, ds|ph) — MXU aligned for
ds, ph >= 64; the (chunk x chunk) decay matrix is 64 KB f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum(dA: jax.Array) -> jax.Array:
    """L[i, j] = sum_{j < t <= i} dA_t (lower-triangular), else -inf."""
    C = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[:, None] - cs[None, :]
    mask = jnp.tril(jnp.ones((C, C), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_kernel(x_ref, b_ref, c_ref, da_ref, dt_ref, y_ref, h_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)  # (C, ph)
    bm = b_ref[0].astype(jnp.float32)  # (C, ds)
    cm = c_ref[0].astype(jnp.float32)  # (C, ds)
    da = da_ref[0, :].astype(jnp.float32)  # (C,)
    dt = dt_ref[0, :].astype(jnp.float32)  # (C,)

    L = jnp.exp(_segsum(da))  # (C, C)
    xdt = x * dt[:, None]  # (C, ph)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (C, C)
    y_intra = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    cum = jnp.cumsum(da)  # (C,)
    decay_in = jnp.exp(cum)[:, None]  # (C, 1)
    h_prev = h_ref[...]  # (ds, ph)
    y_state = jax.lax.dot_general(cm * decay_in, h_prev, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    total = cum[-1]
    decay_out = jnp.exp(total - cum)[:, None]  # (C, 1)
    h_ref[...] = jnp.exp(total) * h_prev + jax.lax.dot_general(
        bm * decay_out, xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = (y_intra + y_state).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_kernel(
    x: jax.Array,  # (BH, S, ph) head-major inputs
    b: jax.Array,  # (BH, S, ds)
    c: jax.Array,  # (BH, S, ds)
    dA: jax.Array,  # (BH, S)  = dt * A  (negative)
    dt: jax.Array,  # (BH, S)  discretization step
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, S, ph = x.shape
    ds = b.shape[2]
    ck = min(chunk, S)
    pad = (-S) % ck
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
    Sp = x.shape[1]
    n_chunks = Sp // ck

    y = pl.pallas_call(
        _ssd_kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, ck, ph), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, ck, ds), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, ck, ds), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, ck), lambda h, i: (h, i)),
            pl.BlockSpec((1, ck), lambda h, i: (h, i)),
        ],
        out_specs=pl.BlockSpec((1, ck, ph), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, ph), x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, ph), jnp.float32)],
        interpret=interpret,
    )(x, b, c, dA, dt)
    if pad:
        y = y[:, :S]
    return y
