"""Pure-jnp oracle for the SSD scan kernel: exact sequential recurrence.

    h_t = exp(dA_t) h_{t-1} + dt_t * B_t x_t^T      (outer product, ds x ph)
    y_t = C_t . h_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, b, c, dA, dt):
    """x: (BH, S, ph); b/c: (BH, S, ds); dA/dt: (BH, S). Returns (BH, S, ph)."""
    xf = x.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    dAf = dA.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def per_head(xh, bh, ch, dah, dth):
        def step(h, inp):
            x_t, b_t, c_t, da_t, dt_t = inp
            h = jnp.exp(da_t) * h + dt_t * jnp.outer(b_t, x_t)
            y_t = c_t @ h
            return h, y_t

        h0 = jnp.zeros((bh.shape[1], xh.shape[1]), dtype=jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xh, bh, ch, dah, dth))
        return ys

    ys = jax.vmap(per_head)(xf, bf, cf, dAf, dtf)
    return ys.astype(x.dtype)
