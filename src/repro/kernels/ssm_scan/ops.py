"""Public op: chunked SSD scan in model layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssm_scan(x, b, c, dA, dt, *, chunk: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """x: (B, S, H, ph); b/c: (B, S, ds) shared across heads; dA/dt: (B, S, H).

    Returns y: (B, S, H, ph)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, ph = x.shape
    ds = b.shape[2]
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, ph)
    bf = jnp.broadcast_to(b[:, None], (B, H, S, ds)).reshape(B * H, S, ds)
    cf = jnp.broadcast_to(c[:, None], (B, H, S, ds)).reshape(B * H, S, ds)
    dAf = dA.transpose(0, 2, 1).reshape(B * H, S)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    y = ssm_scan_kernel(xf, bf, cf, dAf, dtf, chunk=chunk, interpret=interpret)
    return y.reshape(B, H, S, ph).transpose(0, 2, 1, 3)
