"""Optimized-HLO analysis: loop-aware collective-byte accounting.

``compiled.cost_analysis()`` and naive text scans count each while-loop
body ONCE, but a layer scan executes its body n_layers times (and the
microbatch scan multiplies again). This module parses the optimized HLO
into computations, extracts while-loop trip counts from their condition
computations (scan counters compare an induction variable against a
constant), and propagates multipliers through the call graph so every
collective is weighted by how many times it actually executes.

Used by the roofline benchmark for the collective term; the same weighted
walk also yields loop-aware totals for any op predicate.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\([^)]*\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALL_RE = re.compile(
    r"(?:fusion|call|conditional)\([^)]*\)[^\n]*?(?:calls=|to_apply=)%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLL_LINE_RE = re.compile(
    r"=\s+(\(?[\w\[\],{}\s]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += int(n * _DTYPE_BYTES[dt])
    return total


def split_computations(hlo: str) -> tuple[dict[str, str], str | None]:
    """(computation name -> body text, entry computation name)."""
    comps: dict[str, str] = {}
    entry: str | None = None
    name, buf, depth = None, [], 0
    for ln in hlo.splitlines():
        if name is None:
            s = ln.strip()
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{"):
                name = m.group(2)
                if m.group(1):
                    entry = name
                buf = [ln]
                depth = ln.count("{") - ln.count("}")
                if depth <= 0:
                    comps[name] = "\n".join(buf)
                    name = None
        else:
            buf.append(ln)
            depth += ln.count("{") - ln.count("}")
            if depth <= 0:
                comps[name] = "\n".join(buf)
                name = None
    return comps, entry


def trip_count(cond_body: str) -> int:
    """Heuristic scan trip count: the largest s32 constant in the loop
    condition (scan counters run 0..N with `compare(i, N), direction=LT`)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def computation_multipliers(hlo: str) -> dict[str, float]:
    """Execution-count multiplier for every computation, walking from the
    entry through call/fusion (x1) and while (x trip count) edges."""
    comps, entry = split_computations(hlo)
    if entry is None:  # fall back: treat everything as executed once
        return {k: 1.0 for k in comps}

    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for name, body in comps.items():
        for cond, wbody in _WHILE_RE.findall(body):
            n = trip_count(comps.get(cond, ""))
            edges[name].append((wbody, float(n)))
            edges[name].append((cond, float(n)))
        for callee in _CALL_RE.findall(body):
            edges[name].append((callee, 1.0))

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    work = [entry]
    seen_edges = set()
    while work:
        cur = work.pop()
        for callee, k in edges.get(cur, ()):
            key = (cur, callee, k)
            if key in seen_edges:
                continue
            seen_edges.add(key)
            mult[callee] += mult[cur] * k
            work.append(callee)
    return dict(mult)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _wire_factor(kind: str, group: int) -> float:
    """Per-device wire bytes per output byte, ring algorithms.

    all-reduce: reduce-scatter + all-gather = 2(s-1)/s x size;
    all-gather: (s-1)/s x gathered size; reduce-scatter: (s-1) x scattered
    output (= (s-1)/s x input); all-to-all: (s-1)/s; permute: 1."""
    s = max(2, group)
    return {
        "all-reduce": 2 * (s - 1) / s,
        "all-gather": (s - 1) / s,
        "reduce-scatter": float(s - 1),
        "all-to-all": (s - 1) / s,
        "collective-permute": 1.0,
    }[kind]


def weighted_collective_bytes(hlo: str) -> dict:
    """Loop-aware collective accounting: each collective's output bytes
    are multiplied by its computation's execution count. Also estimates
    per-device WIRE bytes using ring-collective factors and the replica
    group size parsed per op — the §Roofline collective-term numerator."""
    comps, _entry = split_computations(hlo)
    mult = computation_multipliers(hlo)
    out: dict[str, float] = {}
    counts: dict[str, float] = {}
    wire: dict[str, float] = {}
    for name, body in comps.items():
        m = mult.get(name, 0.0 if len(mult) > 1 else 1.0)
        if m == 0.0:
            continue
        for line in body.splitlines():
            if "-done(" in line or "-done." in line:
                continue
            lm = _COLL_LINE_RE.search(line)
            if not lm:
                continue
            b = shape_bytes(lm.group(1))
            kind = lm.group(2)
            gm = _GROUPS_RE.search(line)
            group = int(gm.group(2)) if gm else 16
            out[kind] = out.get(kind, 0.0) + b * m
            wire[kind] = wire.get(kind, 0.0) + b * m * _wire_factor(kind, group)
            counts[kind] = counts.get(kind, 0.0) + m
    return {"bytes": {k: int(v) for k, v in out.items()},
            "counts": {k: int(v) for k, v in counts.items()},
            "wire_bytes": {k: int(v) for k, v in wire.items()},
            "total_bytes": int(sum(out.values())),
            "total_wire_bytes": int(sum(wire.values()))}
