"""Sharding rules: param/batch/cache PartitionSpecs for the production mesh.

Axes:
  * ``data`` (+ ``pod`` when multi-pod) — batch / data parallelism,
  * ``model`` — tensor parallelism: attention heads, FFN hidden, experts
    (EP), vocab.

Rules are name-based and divisibility-checked: a dim is sharded only when
its size divides the mesh axis size, otherwise the rule falls through to
the next candidate dim (e.g. minicpm3's 40 heads don't divide a 16-wide
model axis — its attention shards on the fused head*dim axis instead; MQA
kv projections replicate). Leading layer-stack dims (from scan-stacked
params) are never sharded.

Long-context (batch=1) cells shard the KV-cache *sequence* dim over
``data`` instead of batch — decode attention over a sequence-sharded cache
becomes a distributed flash-decoding pattern (partial softmax + psum),
which XLA SPMD derives from these specs.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# (regex on the param path, candidate shard dims counted from the END of
# the shape, e.g. -1 = last dim). The first divisible candidate wins.
_PARAM_RULES: list[tuple[str, list[int]]] = [
    # embed table shards d_model (NOT vocab): token gathers and their
    # backward scatter-adds stay shard-local; the lm_head is the one that
    # shards vocab (where the big logits live).
    (r"embed/table$", [-1]),
    (r"lm_head/w$", [-1]),  # vocab(-heads)-parallel
    (r"attn/w[qkv]$", [-2, -1]),  # heads, else head_dim
    (r"attn/wo$", [-2]),  # fused head*dim (row-parallel)
    (r"attn/q_down$", [-1]),
    (r"attn/q_up$", [-2, -3]),  # heads, else lora rank (row-parallel)
    (r"attn/kv_down$", []),  # latent bottleneck: replicate
    (r"attn/kv_up_[kv]$", [-2, -3]),
    (r"ff/w_(in|gate)$", [-1]),  # MoE (E,d,f) -> experts; dense (d,f) -> f
    (r"ff/w_out$", [-2]),
    (r"ff/router$", []),
    (r"mixer/in_proj$", [-1]),
    (r"mixer/out_proj$", [-2]),
    (r"mixer/conv_[wb]$", []),
    (r"mixer/(A_log|D|dt_bias|f_bias)$", []),
    (r"mixer/r$", []),
    (r"norm", []),
    (r"scale$", []),
]

# MoE expert stacks: shard the expert dim (EP) in preference to f.
# These fire ONLY on rank-4 leaves (layer-stacked (L, E, d, f)): a
# layer-stacked DENSE weight is also rank 3, and letting the expert rule
# shard its dim -3 would shard the LAYER axis over 'model' — replicating
# the weights and poisoning every scan (the qwen2-vl 36 GB decode bug).
_MOE_RULES: list[tuple[str, list[int]]] = [
    (r"ff/w_(in|gate)$", [-3, -1]),
    (r"ff/w_out$", [-3, -2]),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec(path_s: str, shape: tuple[int, ...], model_axis: str,
               model_size: int) -> P:
    """PartitionSpec for one param leaf."""
    rules = _MOE_RULES + _PARAM_RULES if len(shape) >= 4 else _PARAM_RULES
    for pat, dims in rules:
        if re.search(pat, path_s):
            spec = [None] * len(shape)
            for d in dims:
                if len(shape) >= -d and shape[d] % model_size == 0 and shape[d] >= model_size:
                    spec[d] = model_axis
                    break
            return P(*spec)
    return P(*([None] * len(shape)))


def params_sharding(params: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    """NamedSharding pytree matching ``params``.

    ``fsdp=True`` additionally shards every (large) leaf over the DP axes
    on a second dim — FSDP/ZeRO-3 parameter sharding. Inside the layer
    scan, XLA SPMD then all-gathers exactly one layer's weights at a time,
    which is the FSDP execution pattern. Used for the archs whose
    model-axis-only shards exceed HBM (qwen3-moe, granite-34b,
    qwen2-vl-72b), and for optimizer moments (ZeRO-1) universally."""
    model_axis = "model"
    model_size = mesh.shape[model_axis]
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    dp_name = dp if len(dp) > 1 else (dp[0] if dp else None)

    def leaf_spec(path, x):
        spec = list(param_spec(_path_str(path), x.shape, model_axis, model_size))
        spec += [None] * (len(x.shape) - len(spec))
        if fsdp and dp_name is not None and x.size * 4 >= 2**22:
            # dim 0 of stacked-block leaves is the layer stack: skip it so
            # the scan slices stay layout-friendly
            start = 1 if len(x.shape) >= 3 else 0
            cands = sorted(range(start, len(x.shape)),
                           key=lambda d: -x.shape[d])
            for d in cands:
                if spec[d] is None and x.shape[d] % dp_size == 0 \
                        and x.shape[d] >= dp_size:
                    spec[d] = dp_name
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_sharding(mesh: Mesh, batch_size: int, ndim: int,
                   seq_dim: int | None = None, seq_len: int = 0) -> NamedSharding:
    """Shard dim 0 (batch) over the DP axes; if the batch does not divide
    them (e.g. batch=1 long-context), shard ``seq_dim`` over 'data'."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    spec = [None] * ndim
    if batch_size % dp_size == 0 and batch_size >= dp_size:
        spec[0] = dp if len(dp) > 1 else dp[0]
    elif seq_dim is not None and seq_len % mesh.shape["data"] == 0:
        spec[seq_dim] = "data"
    return NamedSharding(mesh, P(*spec))


def cache_sharding(cfg: ModelConfig, cache: Any, mesh: Mesh, batch: int) -> Any:
    """Shardings for a decode cache pytree.

    Attention k/v (or MLA latents): batch over DP if divisible, else the
    sequence dim over 'data'; head dims over 'model' when divisible.
    Recurrent states (mamba/mlstm/slstm): batch over DP if divisible; inner
    (head or channel) dim over 'model' when divisible."""
    model_size = mesh.shape["model"]
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    batch_ok = batch % dp_size == 0 and batch >= dp_size
    dp_spec = (dp if len(dp) > 1 else dp[0]) if batch_ok else None

    def leaf_spec(path, x):
        path_s = _path_str(path)
        shape = x.shape
        spec = [None] * len(shape)
        # locate the batch dim: stacked homogeneous caches are (L, B, ...);
        # heterogeneous tuples are (B, ...) per layer.
        names = [p for p in path_s.split("/")]
        stacked = len(shape) >= 2 and shape[0] != batch and shape[1] == batch
        b_dim = 1 if stacked else 0
        if names[-1] in ("k", "v", "k_scale", "v_scale") or "c_kv" in path_s \
                or "k_rope" in path_s:
            s_dim = b_dim + 1
            if batch_ok:
                spec[b_dim] = dp_spec
            elif shape[s_dim] % mesh.shape["data"] == 0:
                spec[s_dim] = "data"
            # heads dim for k/v: (…, S, Hkv, Dh); when kv heads don't
            # divide the model axis (MQA/GQA-8 on a 16-wide axis), shard
            # the sequence over 'model' instead — decode attention over a
            # seq-sharded cache is the flash-decoding split-KV pattern
            # (partial softmax + psum), and the cache memory still divides.
            h_dim = s_dim + 1
            heads_ok = (names[-1] in ("k", "v") and len(shape) >= h_dim + 1
                        and shape[h_dim] % model_size == 0
                        and shape[h_dim] >= model_size)
            if heads_ok:
                spec[h_dim] = "model"
            elif spec[s_dim] is None and shape[s_dim] % model_size == 0:
                spec[s_dim] = "model"
        else:
            # recurrent state (B, nh, ...) / (B, K-1, C) / (B, di)
            if batch_ok:
                spec[b_dim] = dp_spec
            for d in range(b_dim + 1, len(shape)):
                if shape[d] % model_size == 0 and shape[d] >= model_size:
                    spec[d] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def input_sharding(cfg: ModelConfig, mesh: Mesh, inputs: dict) -> dict:
    """Shardings for a model-input dict of ShapeDtypeStructs or arrays.

    Handles the microbatched training layout (leading N dim replicated,
    per-microbatch batch dim over DP) and each frontend's trailing dims."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    dp_name = dp if len(dp) > 1 else (dp[0] if dp else None)

    def batch_dim_of(k, v) -> int:
        if k == "positions":
            return v.ndim - 2  # (..., 3, B, S) -> B
        if k == "embeds" or (cfg.frontend == "audio_codes" and k in ("codes", "labels")):
            return v.ndim - 3  # (..., B, S, D|K)
        return v.ndim - 2  # tokens/labels: (..., B, S)

    out = {}
    for k, v in inputs.items():
        if not hasattr(v, "shape") or v.ndim == 0:
            out[k] = replicated(mesh)
            continue
        spec = [None] * v.ndim
        bd = max(0, batch_dim_of(k, v))
        if dp_name is not None and v.shape[bd] % dp_size == 0 and v.shape[bd] >= dp_size:
            spec[bd] = dp_name
        out[k] = NamedSharding(mesh, P(*spec))
    return out
