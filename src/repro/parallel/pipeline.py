"""Pipeline-parallel execution of planner-chosen splits (shard_map + ppermute).

This is the runtime counterpart of :func:`repro.core.planner.plan_pipeline`:
the beam-search split plan assigns contiguous layer ranges to pipeline
stages; this module executes them as a GPipe-style microbatch pipeline on
a mesh axis ("stage" locally, the "pod" axis in the production mesh),
rotating microbatch activations between stages with
``jax.lax.ppermute`` — the collective whose cost the paper's Eq. 7 models
(the inter-device activation hop).

Execution model (standard collective-pipelining formulation):
  * stage s holds the stacked params of its layer range (uneven plans are
    padded with identity blocks to the max stage depth);
  * M microbatches stream through S stages over M + S - 1 ticks;
  * each tick: every stage applies its blocks to its resident microbatch,
    then ppermute rotates the ring (stage s -> s+1), stage 0 injects the
    next microbatch and stage S-1 emits a finished one.

The per-tick ppermute payload is exactly ``boundary_act_bytes`` of the
plan — the quantity the beam-search objective minimizes; EXPERIMENTS.md
§Perf uses this correspondence for the planner-quality benchmark.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.planner import SplitPlan


def stage_assignment(plan: SplitPlan, n_layers: int) -> list[tuple[int, int]]:
    """[(first, last)] 0-indexed inclusive layer ranges per stage."""
    bounds = [0, *plan.splits, n_layers]
    return [(bounds[i], bounds[i + 1] - 1) for i in range(len(bounds) - 1)]


def pad_stage_params(stacked_params, ranges, max_depth: int):
    """Slice the (L, ...) stacked block params into (S, max_depth, ...)
    per-stage stacks, padding short stages with zeros + an identity mask."""
    stages = []
    masks = []
    for (a, b) in ranges:
        depth = b - a + 1
        sl = jax.tree.map(lambda t: t[a : b + 1], stacked_params)
        if depth < max_depth:
            sl = jax.tree.map(
                lambda t: jnp.concatenate(
                    [t, jnp.zeros((max_depth - depth, *t.shape[1:]), t.dtype)]),
                sl)
        stages.append(sl)
        masks.append(jnp.arange(max_depth) < depth)
    stage_stack = jax.tree.map(lambda *ts: jnp.stack(ts), *stages)
    return stage_stack, jnp.stack(masks)  # (S, max_depth, ...), (S, max_depth)


def pipelined_forward(
    block_apply: Callable,  # (layer_params, x) -> x
    stage_params,  # (S, depth, ...) stacked, stage axis sharded over mesh axis
    layer_mask: jax.Array,  # (S, depth) bool — identity for padded layers
    microbatches: jax.Array,  # (M, mb, ...) activations entering stage 0
    *,
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Run the microbatch pipeline; returns (M, mb, ...) outputs of the
    last stage. Pure collective implementation: one ppermute per tick."""
    S = mesh.shape[axis]
    M = microbatches.shape[0]
    n_ticks = M + S - 1

    def stage_fn(stage_p, mask, mb):
        # runs per-stage under shard_map: leading stage axis is local (=1)
        stage_p = jax.tree.map(lambda t: t[0], stage_p)
        mask = mask[0]
        mb = mb[0]  # (M, mbatch, ...)
        sidx = jax.lax.axis_index(axis)

        def apply_stage(x):
            def body(h, inp):
                lp, m = inp
                h2 = block_apply(lp, h)
                return jnp.where(m, h2, h), None

            x, _ = jax.lax.scan(body, x, (stage_p, mask))
            return x

        buf = jnp.zeros_like(mb[0])  # resident activation
        outputs = jnp.zeros_like(mb)

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 injects microbatch t (if any remain)
            inject = jnp.where(t < M, t, M - 1)
            buf = jnp.where(sidx == 0, mb[inject], buf)
            buf = apply_stage(buf)
            # last stage emits microbatch t - (S - 1)
            emit_t = t - (S - 1)
            do_emit = (sidx == S - 1) & (emit_t >= 0)
            outputs = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, buf, jnp.maximum(emit_t, 0), 0),
                lambda o: o,
                outputs)
            # rotate ring: s -> s+1 (the Eq.7-priced activation hop)
            buf = jax.lax.ppermute(
                buf, axis, [(i, (i + 1) % S) for i in range(S)])
            return (buf, outputs), None

        (buf, outputs), _ = jax.lax.scan(
            tick, (buf, outputs), jnp.arange(n_ticks, dtype=jnp.int32))
        # outputs live on the last stage; broadcast via psum of masked value
        outputs = jnp.where(sidx == S - 1, outputs, jnp.zeros_like(outputs))
        outputs = jax.lax.psum(outputs, axis)
        return outputs[None]

    fn = shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=P(axis),
        check_rep=False,
    )
    # microbatches replicated to every stage; take stage 0's view back
    out = fn(stage_params, layer_mask,
             jnp.broadcast_to(microbatches[None], (S, *microbatches.shape)))
    return out[0]


def run_pipeline(plan: SplitPlan, block_apply, stacked_params, n_layers: int,
                 microbatches: jax.Array, mesh: Mesh, axis: str = "stage"):
    """Convenience wrapper: plan -> padded stage stacks -> pipelined run."""
    ranges = stage_assignment(plan, n_layers)
    max_depth = max(b - a + 1 for a, b in ranges)
    stage_stack, mask = pad_stage_params(stacked_params, ranges, max_depth)
    return pipelined_forward(block_apply, stage_stack, mask, microbatches,
                             mesh=mesh, axis=axis)
