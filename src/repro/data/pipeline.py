"""Deterministic synthetic data pipeline (shardable, resumable).

Every batch is a pure function of ``(seed, step)`` — the property that
makes checkpoint/restart exact: resuming at step k regenerates the same
remaining stream with no iterator state to persist. A real deployment
swaps :class:`SyntheticLMData` for a file-backed loader with the same
``batch_at(step)`` contract (index-addressable batches are also what
deterministic-restart data services like Grain provide).

Batches are emitted in the layout the train step expects — microbatched
``(N, B/N, S)`` when configured — and can be device_put against the mesh
sharding for multi-host feeding."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class SyntheticLMData:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def _lead(self) -> tuple:
        N = self.cfg.train_microbatches
        if N > 1:
            assert self.global_batch % N == 0
            return (N, self.global_batch // N)
        return (self.global_batch,)

    def batch_at(self, step: int) -> dict:
        """The training batch for one step (tokens + next-token labels)."""
        cfg = self.cfg
        rng = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        lead = self._lead()
        if cfg.frontend == "audio_codes":
            codes = jax.random.randint(
                rng, (*lead, self.seq_len + 1, cfg.n_codebooks), 0, cfg.vocab,
                dtype=jnp.int32)
            return {"codes": codes[..., :-1, :], "labels": codes[..., 1:, :]}
        if cfg.frontend == "vision_embeds":
            k1, k2 = jax.random.split(rng)
            emb = jax.random.normal(
                k1, (*lead, self.seq_len, cfg.d_model), dtype=jnp.dtype(cfg.dtype))
            labels = jax.random.randint(k2, (*lead, self.seq_len), 0, cfg.vocab,
                                        dtype=jnp.int32)
            pos = jnp.broadcast_to(
                jnp.arange(self.seq_len, dtype=jnp.int32)[None, None],
                (3, self.global_batch // (lead[0] if len(lead) > 1 else 1)
                 if len(lead) > 1 else self.global_batch, self.seq_len))
            if len(lead) > 1:
                pos = jnp.broadcast_to(pos[None], (lead[0], *pos.shape))
            return {"embeds": emb, "positions": pos, "labels": labels}
        toks = jax.random.randint(rng, (*lead, self.seq_len + 1), 0, cfg.vocab,
                                  dtype=jnp.int32)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass(frozen=True)
class MarkovLMData(SyntheticLMData):
    """Learnable synthetic stream: a fixed random bigram process. Unlike
    iid-uniform tokens it has ~``branch`` bits/token of structure, so the
    training-loop integration test can assert the loss actually falls."""

    branch: int = 4

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        assert cfg.frontend == "none", "MarkovLMData is for token LMs"
        base = jax.random.PRNGKey(self.seed ^ 0x5EED)
        # fixed transition table: vocab -> `branch` successors
        table = jax.random.randint(base, (cfg.vocab, self.branch), 0, cfg.vocab,
                                   dtype=jnp.int32)
        rng = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        lead = self._lead()
        flat = int(jnp.prod(jnp.array(lead)))
        k0, k1 = jax.random.split(rng)
        x0 = jax.random.randint(k0, (flat,), 0, cfg.vocab, dtype=jnp.int32)
        choices = jax.random.randint(k1, (flat, self.seq_len + 1), 0, self.branch,
                                     dtype=jnp.int32)

        def step_fn(x, c):
            nxt = table[x, c]
            return nxt, nxt

        _, seq = jax.lax.scan(step_fn, x0, choices.T)
        toks = jnp.concatenate([x0[None], seq], axis=0).T  # (flat, S+2)
        toks = toks[:, : self.seq_len + 1].reshape(*lead, self.seq_len + 1)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
