"""Audio-codes utilities for the musicgen backbone (EnCodec token streams).

MusicGen's *delay pattern* (Copet et al. 2023, §2.2): codebook k of frame
t is predicted at step t + k, so all K codebooks can be decoded
autoregressively with a single transformer pass per step instead of K.
These helpers convert between the aligned (B, T, K) frame grid and the
delayed (B, T + K - 1, K) training/decoding layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def delay_pattern(codes: jax.Array, pad_id: int) -> jax.Array:
    """(B, T, K) aligned codes -> (B, T + K - 1, K) delayed layout;
    codebook k is shifted right by k steps, holes filled with ``pad_id``."""
    B, T, K = codes.shape
    out = jnp.full((B, T + K - 1, K), pad_id, dtype=codes.dtype)
    for k in range(K):
        out = out.at[:, k : k + T, k].set(codes[:, :, k])
    return out


def undelay_pattern(delayed: jax.Array, n_frames: int) -> jax.Array:
    """Inverse of :func:`delay_pattern`: (B, T + K - 1, K) -> (B, T, K)."""
    B, _, K = delayed.shape
    cols = [delayed[:, k : k + n_frames, k] for k in range(K)]
    return jnp.stack(cols, axis=-1)


def delay_mask(n_frames: int, n_codebooks: int) -> jax.Array:
    """(T + K - 1, K) bool mask of REAL (non-pad) positions in the delayed
    layout — used to exclude pad slots from the training loss."""
    S = n_frames + n_codebooks - 1
    t = jnp.arange(S)[:, None]
    k = jnp.arange(n_codebooks)[None, :]
    return (t >= k) & (t < k + n_frames)
