"""Decoder-only LM covering all assigned architectures.

One functional model, configured by :class:`ModelConfig`:

  * dense / GQA / MQA attention (deepseek-7b, stablelm-12b, granite-34b,
    musicgen-medium, qwen2-vl-72b backbones),
  * MLA latent attention (minicpm3-4b),
  * grouped MoE FFN (granite-moe-1b, qwen3-moe-235b),
  * Mamba2 + shared-attention hybrid (zamba2-1.2b),
  * mLSTM/sLSTM stacks (xlstm-1.3b),
  * audio-codes embedding (musicgen) and vision-embeds passthrough
    (qwen2-vl) modality frontends as stubs per the assignment.

Homogeneous stacks are executed with ``lax.scan`` over stacked per-layer
params (compile-time O(1) in depth — critical for the 88-94 layer
dry-runs); heterogeneous patterns (hybrid/ssm) unroll over the block
pattern with per-kind parameter stacks.

Inputs are normalized to a dict so every architecture exposes the same
``forward(params, inputs, cache)`` signature:
  tokens    (B, S) int32            — LM families
  codes     (B, S, K) int32         — musicgen (EnCodec streams)
  embeds    (B, S, D) float         — qwen2-vl (patch embeds, stub frontend)
  positions (B, S) or (3, B, S) int — rope / M-RoPE streams
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (
    Params,
    apply_attention,
    apply_embed,
    apply_lm_head,
    apply_mla,
    apply_mlp,
    apply_moe,
    cdtype,
    init_attention,
    init_embed,
    init_lm_head,
    init_mla,
    init_mlp,
    init_moe,
    init_rmsnorm,
    rmsnorm,
)

# ---------------------------------------------------------------------------
# Activation sharding (sequence parallelism at block boundaries)
# ---------------------------------------------------------------------------


def _ambient_mesh_shape() -> dict:
    """Axis sizes of the mesh active via ``with mesh:`` (empty if none)."""
    try:
        from jax._src.mesh import thread_resources

        return dict(thread_resources.env.physical_mesh.shape)
    except Exception:  # noqa: BLE001 — no mesh / internal API moved
        return {}


def maybe_constrain_act(x: jax.Array) -> jax.Array:
    """Pin layer-boundary activations (B, S, D) to batch-over-DP.

    Activation memory is controlled by microbatching + grouped remat (the
    production levers — see ModelConfig.train_microbatches/remat_group);
    boundaries stay sequence-replicated so the TP block interiors (heads /
    hidden over 'model') need no SP resharding collectives. No-op outside
    a mesh context."""
    axes = _ambient_mesh_shape()
    if not axes or x.ndim < 3:
        return x
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    spec = [None] * x.ndim
    B = x.shape[0]
    if dp and B % dp_size == 0 and B >= dp_size:
        spec[0] = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def maybe_constrain_logits(logits: jax.Array) -> jax.Array:
    """Keep logits vocab-sharded over 'model' (batch over DP). Without
    this, XLA propagates the sequence sharding from the SP block stack and
    all-gathers the full-vocab head weight plus (B, S, V) f32 logits per
    device — the dominant training-memory term after activations."""
    axes = _ambient_mesh_shape()
    if not axes:
        return logits
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    spec = [None] * logits.ndim
    B, V = logits.shape[0], logits.shape[-1]
    if dp and B % dp_size == 0 and B >= dp_size:
        spec[0] = dp if len(dp) > 1 else dp[0]
    m = axes.get("model", 1)
    if m > 1 and V % m == 0:
        spec[-1] = "model"
    return jax.lax.with_sharding_constraint(logits, P(*spec))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(rng, 4)
    if kind == "attn":
        attn = init_mla(ks[0], cfg) if cfg.use_mla else init_attention(ks[0], cfg)
        ff = init_moe(ks[1], cfg) if cfg.is_moe else init_mlp(ks[1], cfg)
        return {
            "norm1": init_rmsnorm(cfg),
            "attn": attn,
            "norm2": init_rmsnorm(cfg),
            "ff": ff,
        }
    if kind == "mamba":
        return {"norm": init_rmsnorm(cfg), "mixer": ssm.init_mamba(ks[0], cfg)}
    if kind == "mlstm":
        return {"norm": init_rmsnorm(cfg), "mixer": ssm.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"norm": init_rmsnorm(cfg), "mixer": ssm.init_slstm(ks[0], cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def apply_block(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                positions: jax.Array, cache: Params | None, *,
                decode: bool) -> tuple[jax.Array, Params | None]:
    eps = cfg.norm_eps
    if kind == "attn":
        h = rmsnorm(p["norm1"], x, eps)
        if cfg.use_mla:
            a, new_cache = apply_mla(cfg, p["attn"], h, positions, cache,
                                     absorbed=decode and cfg.mla_absorbed_decode)
        else:
            a, new_cache = apply_attention(cfg, p["attn"], h, positions, cache)
        if cfg.parallel_residual:
            f = apply_moe(cfg, p["ff"], h) if cfg.is_moe else apply_mlp(cfg, p["ff"], h)
            return x + a + f, new_cache
        x = x + a
        h2 = rmsnorm(p["norm2"], x, eps)
        f = apply_moe(cfg, p["ff"], h2) if cfg.is_moe else apply_mlp(cfg, p["ff"], h2)
        return x + f, new_cache
    if kind == "mamba":
        h = rmsnorm(p["norm"], x, eps)
        if decode:
            y, new_cache = ssm.mamba_step(cfg, p["mixer"], h, cache)
        else:
            y, new_cache = ssm.mamba_chunked(cfg, p["mixer"], h, chunk=cfg.scan_chunk), None
        return x + y, new_cache
    if kind == "mlstm":
        h = rmsnorm(p["norm"], x, eps)
        if decode:
            y, new_cache = ssm.mlstm_step(cfg, p["mixer"], h, cache)
        else:
            y, new_cache = ssm.mlstm_chunked(cfg, p["mixer"], h, chunk=cfg.scan_chunk), None
        return x + y, new_cache
    if kind == "slstm":
        h = rmsnorm(p["norm"], x, eps)
        y, new_cache = ssm.slstm_forward(cfg, p["mixer"], h, cache)
        return x + y, new_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _is_homogeneous(cfg: ModelConfig) -> bool:
    return all(k == "attn" for k in cfg.pattern) and not cfg.shared_attn


def _pattern_runs(pattern) -> list[tuple[str, int, int]]:
    """[(kind, first_occurrence_index, count)] for runs of equal kinds."""
    runs = []
    occ: dict[str, int] = {}
    i = 0
    while i < len(pattern):
        k = pattern[i]
        j = i
        while j < len(pattern) and pattern[j] == k:
            j += 1
        runs.append((k, occ.get(k, 0), j - i))
        occ[k] = occ.get(k, 0) + (j - i)
        i = j
    return runs


def init_params(rng, cfg: ModelConfig) -> Params:
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)
    params: Params = {"embed": init_embed(k_embed, cfg)}

    if _is_homogeneous(cfg):
        rngs = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = jax.vmap(lambda r: init_block(r, cfg, "attn"))(rngs)
    else:
        pattern = cfg.pattern
        kinds = list(dict.fromkeys(pattern))
        stacks: Params = {}
        for kind in kinds:
            n = sum(1 for k in pattern if k == kind)
            if kind == "attn" and cfg.shared_attn:
                stacks["attn_shared"] = init_block(
                    jax.random.fold_in(k_blocks, hash(kind) % 2**31), cfg, "attn")
            else:
                rngs = jax.random.split(
                    jax.random.fold_in(k_blocks, kinds.index(kind)), n)
                stacks[kind] = jax.vmap(lambda r, kk=kind: init_block(r, cfg, kk))(rngs)
        params["blocks"] = stacks

    params["final_norm"] = init_rmsnorm(cfg)
    params["lm_head"] = init_lm_head(k_head, cfg)
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Any:
    """Decode cache. Homogeneous attn: stacked {"k","v"} of shape
    (L, B, Smax, Hkv, Dh) (or MLA latents). Heterogeneous: tuple of
    per-layer caches following the block pattern.

    ``kv_cache_dtype="int8"`` stores KIVI-style quantized K/V (symmetric
    per-(token, head) scales alongside) — halves cache HBM vs bf16."""
    dt = dtype or cdtype(cfg)
    quant = dtype is None and cfg.kv_cache_dtype == "int8"

    def attn_cache():
        if cfg.use_mla:
            return {
                "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype=dt),
                "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype=dt),
            }
        if quant:
            return {
                "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                               dtype=jnp.int8),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                               dtype=jnp.int8),
                "k_scale": jnp.zeros((batch, max_seq, cfg.n_kv_heads),
                                     dtype=jnp.float32),
                "v_scale": jnp.zeros((batch, max_seq, cfg.n_kv_heads),
                                     dtype=jnp.float32),
            }
        return {
            "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype=dt),
            "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype=dt),
        }

    if _is_homogeneous(cfg):
        one = attn_cache()
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)), one)

    caches = []
    for kind in cfg.pattern:
        if kind == "attn":
            caches.append(attn_cache())
        elif kind == "mamba":
            caches.append(ssm.init_mamba_cache(cfg, batch, dtype=dt))
        elif kind == "mlstm":
            caches.append(ssm.init_mlstm_cache(cfg, batch))
        elif kind == "slstm":
            caches.append(ssm.init_slstm_cache(cfg, batch))
    return tuple(caches)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params: Params, inputs: dict) -> jax.Array:
    if cfg.frontend == "vision_embeds":
        # stub frontend: precomputed patch/text embeddings arrive directly
        return inputs["embeds"].astype(cdtype(cfg))
    if cfg.frontend == "audio_codes":
        return apply_embed(cfg, params["embed"], inputs["codes"])
    return apply_embed(cfg, params["embed"], inputs["tokens"])


def _default_positions(cfg: ModelConfig, B: int, S: int, offset) -> jax.Array:
    pos = offset + jnp.arange(S, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def forward(cfg: ModelConfig, params: Params, inputs: dict,
            cache: Any = None, decode: bool = False
            ) -> tuple[jax.Array, Any]:
    """Returns (logits, new_cache). ``inputs`` per the module docstring;
    optional ``inputs["positions"]`` overrides the default arange."""
    x = _embed_inputs(cfg, params, inputs)
    B, S = x.shape[:2]
    offset = inputs.get("cur_index", 0)
    positions = inputs.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S, offset)

    if _is_homogeneous(cfg):
        block_fn = functools.partial(apply_block, cfg, "attn", decode=decode)
        if cfg.remat and not decode:
            block_fn = jax.checkpoint(
                block_fn, policy=jax.checkpoint_policies.nothing_saveable)

        blocks = params["blocks"]
        if cache is None:
            g = cfg.remat_group
            x = maybe_constrain_act(x)
            if g > 1 and cfg.n_layers % g == 0 and cfg.remat and not decode:
                # grouped remat: save only every g-th layer boundary and
                # recompute the group on backward — activation storage L/g.
                grouped = jax.tree.map(
                    lambda t: t.reshape(cfg.n_layers // g, g, *t.shape[1:]),
                    blocks)

                def group_fn(h, gparams):
                    def inner(h2, lp):
                        h2, _ = apply_block(cfg, "attn", lp, h2, positions,
                                            None, decode=decode)
                        return h2, None

                    h, _ = jax.lax.scan(inner, h, gparams)
                    return h

                gfn = jax.checkpoint(
                    group_fn, policy=jax.checkpoint_policies.nothing_saveable)

                def body(h, gp):
                    return maybe_constrain_act(gfn(h, gp)), None

                x, _ = jax.lax.scan(body, x, grouped)
            else:
                def body(h, layer_params):
                    h, _ = block_fn(layer_params, h, positions, None)
                    return maybe_constrain_act(h), None

                x, _ = jax.lax.scan(body, x, blocks)
            new_cache = None
        else:
            # The stacked cache rides in the CARRY and is updated in place
            # (dynamic_update_index) rather than being scanned as xs/ys:
            # carried buffers alias across iterations, so the (huge) cache
            # is never copied or dtype-hoisted — the serving-system
            # in-place KV-update pattern. Params stay scan-xs: per-layer
            # slices keep their declared shardings.
            def body(carry, layer_params):
                h, cache_st, li = carry
                layer_cache = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, li, 0,
                                                           keepdims=False),
                    cache_st)
                h, c2 = block_fn(layer_params, h, positions, layer_cache)
                cache_st = jax.tree.map(
                    lambda c, u: jax.lax.dynamic_update_index_in_dim(
                        c, u.astype(c.dtype), li, 0),
                    cache_st, c2)
                return (h, cache_st, li + 1), None

            (x, new_cache, _), _ = jax.lax.scan(
                body, (x, cache, jnp.int32(0)), blocks)
    elif cache is None:
        # heterogeneous, no cache (train/prefill): scan over RUNS of
        # consecutive same-kind blocks (e.g. zamba2 = 5 x [6 mamba + shared
        # attn] + 3 mamba). One scan body per run keeps the HLO ~run-count
        # sized instead of layer-count sized (38 unrolled mamba blocks cost
        # 6 minutes of XLA time and pessimistic buffer liveness).
        x = maybe_constrain_act(x)
        for kind, occ0, count in _pattern_runs(cfg.pattern):
            if kind == "attn" and cfg.shared_attn:
                fn = functools.partial(apply_block, cfg, "attn", decode=decode)
                if cfg.remat:
                    fn = jax.checkpoint(
                        fn, policy=jax.checkpoint_policies.nothing_saveable)
                for _ in range(count):
                    x, _ = fn(params["blocks"]["attn_shared"], x, positions, None)
                    x = maybe_constrain_act(x)
                continue
            run_params = jax.tree.map(
                lambda t: t[occ0 : occ0 + count], params["blocks"][kind])
            fn = functools.partial(apply_block, cfg, kind, decode=decode)
            if cfg.remat:
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable)

            def body(h, lp, fn=fn):
                h, _ = fn(lp, h, positions, None)
                return maybe_constrain_act(h), None

            x, _ = jax.lax.scan(body, x, run_params)
        new_cache = None
    else:
        # heterogeneous decode: unrolled (per-block decode HLO is tiny and
        # the per-layer cache tuple keeps heterogeneous state shapes simple)
        pattern = cfg.pattern
        occ = {k: 0 for k in set(pattern)}
        new_caches = []
        for li, kind in enumerate(pattern):
            if kind == "attn" and cfg.shared_attn:
                p_block = params["blocks"]["attn_shared"]
            else:
                i = occ[kind]
                p_block = jax.tree.map(lambda t: t[i], params["blocks"][kind])
            occ[kind] = occ.get(kind, 0) + 1
            layer_cache = cache[li] if cache is not None else None
            x, c2 = apply_block(cfg, kind, p_block, x, positions, layer_cache,
                                decode=decode)
            new_caches.append(c2)
        new_cache = tuple(new_caches)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = apply_lm_head(cfg, params["lm_head"], x, params["embed"])
    return logits, new_cache


# ---------------------------------------------------------------------------
# Losses and steps
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits: (..., V) f32; labels: (...) int32.

    The gold logit is extracted with an iota-compare mask rather than
    ``take_along_axis``: on a vocab-sharded mesh the masked sum is local
    per shard (+ a scalar all-reduce), and its backward is a fused
    elementwise (softmax - onehot) — no giant scatter buffers."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1,) * (logits.ndim - 1) + (V,), logits.ndim - 1)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict) -> jax.Array:
    logits, _ = forward(cfg, params, batch)
    logits = maybe_constrain_logits(logits)
    labels = batch["labels"]
    return cross_entropy(logits, labels)


def serve_step(cfg: ModelConfig, params: Params, inputs: dict, cache: Any
               ) -> tuple[jax.Array, Any]:
    """One decode step: new token(s) + cache -> next-token logits + cache.
    ``inputs["cur_index"]`` is the write offset into the cache."""
    logits, new_cache = forward(cfg, params, inputs, cache=cache, decode=True)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Params, inputs: dict, cache: Any
            ) -> tuple[jax.Array, Any]:
    """Prefill a prompt into the cache (chunked attention path)."""
    logits, new_cache = forward(cfg, params, inputs, cache=cache, decode=False)
    return logits, new_cache
