"""Model configuration for all assigned architectures.

One frozen dataclass covers the whole zoo; family-specific fields default
off. Every config in ``repro/configs/`` instantiates this with the exact
published dimensions.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 1024  # tokens per dispatch group (GShard-style)

    # --- MLA (MiniCPM3 / DeepSeek-V2-style latent attention) ---------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- position encoding --------------------------------------------------
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl (t, h, w)

    # --- residual / block style ---------------------------------------------
    parallel_residual: bool = False  # stablelm-2: attn and mlp share the residual
    gated_mlp: bool = True  # SwiGLU (False -> GELU MLP, e.g. granite-34b)
    tie_embeddings: bool = False

    # --- SSM / hybrid --------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2
    # per-layer block kinds; None -> all "attn". e.g. zamba2 mixes "mamba"
    # with a shared "attn" block, xlstm mixes "mlstm"/"slstm".
    block_pattern: tuple[str, ...] | None = None
    shared_attn: bool = False  # zamba2: one shared param set for all attn blocks

    # --- modality frontends (STUBS per assignment) ---------------------------
    frontend: Literal["none", "audio_codes", "vision_embeds"] = "none"
    n_codebooks: int = 0  # musicgen: EnCodec streams

    # --- numerics -------------------------------------------------------------
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"  # activation/param dtype for the big runs
    remat: bool = True  # activation checkpointing per block (training)

    # --- distributed-training knobs (production memory levers) ---------------
    train_microbatches: int = 1  # gradient-accumulation microbatches per step
    remat_group: int = 1  # layers per remat group (boundaries saved = L/group)
    fsdp: bool = False  # shard params over the data axes too (FSDP/ZeRO-3)
    scan_chunk: int = 128  # mamba/mlstm chunk length (state-save granularity)
    pad_vocab_to: int = 256  # pad the LM-head vocab to a multiple (Megatron
    # convention) so logits shard over any TP width; padded slots are
    # masked to -inf and never predicted. 0 disables.
    opt_moments_dtype: str = "float32"  # bf16 halves optimizer HBM (235B arch)
    grad_accum_dtype: str = "float32"  # microbatch grad-accumulation dtype
    kv_cache_dtype: str = "bfloat16"  # "int8" = KIVI-style quantized KV cache
    # (per-token,per-head scales): halves decode-cache HBM vs bf16 — used by
    # the 72B arch whose bf16 cache + params exceed per-chip HBM
    fsdp_inference: bool = False  # FSDP params at serve time (qwen3-moe: the
    # 29 GB model-sharded params force it; dense archs keep TP-only params)

    # --- attention execution -------------------------------------------------
    q_chunk: int = 512  # chunked-attention block sizes (memory-efficient attn)
    kv_chunk: int = 1024
    use_flash_kernel: bool = False  # route attention through the Pallas kernel
    mla_absorbed_decode: bool = True  # latent-space MLA decode (perf iteration)
    causal_skip: bool = False  # dynamic-bound kv loop in prefill attention
    # (skips fully-masked causal blocks; forward-only -> serving paths)
    ssm_tp: bool = True  # tensor-parallel SSM/LSTM channels; False = pure-DP
    # mixers (xlstm: 4 heads x 1024-wide matrix memory makes channel-TP emit
    # per-chunk psums that dominate everything — see §Perf H3)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.block_pattern is not None:
            assert len(self.block_pattern) == self.n_layers, (
                f"block_pattern len {len(self.block_pattern)} != n_layers {self.n_layers}"
            )

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def vocab_padded(self) -> int:
        if not self.pad_vocab_to:
            return self.vocab
        m = self.pad_vocab_to
        return -(-self.vocab // m) * m

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.block_pattern is not None:
            return self.block_pattern
        return ("attn",) * self.n_layers

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for kind in self.pattern:
            if kind in ("attn",):
                if self.use_mla:
                    q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                        self.qk_nope_head_dim + self.qk_rope_head_dim
                    )
                    kv = d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    kv += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_head_dim + self.v_head_dim
                    )
                    o = self.n_heads * self.v_head_dim * d
                    attn = q + kv + o
                else:
                    attn = (self.n_heads + 2 * self.n_kv_heads) * hd * d
                    attn += self.n_heads * hd * d
                if self.is_moe:
                    ff = self.n_experts * (3 if self.gated_mlp else 2) * d * self.d_ff
                    ff += d * self.n_experts
                else:
                    ff = (3 if self.gated_mlp else 2) * d * self.d_ff
                total += attn + ff + 2 * d
            elif kind == "mamba":
                di = self.d_inner
                total += d * 2 * di + di * self.d_conv + 2 * di * self.ssm_state + di * d + 2 * d
            elif kind in ("mlstm", "slstm"):
                di = self.d_inner
                total += d * 4 * di + di * d + 2 * d
        return total

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.block_pattern is None else len(self._reduced_pattern())),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=128,
            vocab=128,
            head_dim=16,
            moe_group_size=32,
            q_chunk=16,
            kv_chunk=32,
            remat=False,
            dtype="float32",
            train_microbatches=1,
            remat_group=1,
            fsdp=False,
            scan_chunk=16,
        )
        if self.is_moe:
            small.update(n_experts=4, top_k=2)
        if self.use_mla:
            small.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                         qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=16)
        if self.block_pattern is not None:
            small.update(block_pattern=self._reduced_pattern())
        if self.mrope_sections is not None:
            small.update(mrope_sections=(2, 3, 3))
        small.update(overrides)
        return replace(self, **small)

    def _reduced_pattern(self) -> tuple[str, ...]:
        """First occurrences of each distinct kind, preserving order-of-mix."""
        kinds = list(dict.fromkeys(self.block_pattern))
        return tuple(kinds * 2)[:4] if len(kinds) > 1 else tuple(kinds * 2)
