"""Static layer graphs: per-layer FLOPs / parameter bytes / activation bytes.

These tables are the planner's view of a model (the paper's "measured
per-layer inference and transmission costs"). They are pure-Python shape
math — no JAX — so the planner and benchmarks stay dependency-light; the
real JAX models in ``models/*.py`` align 1:1 with these tables by layer
name, and tests assert the alignment.

Conventions:
  * ``flops`` counts multiply-adds as 2 ops.
  * ``act_bytes`` is the size of the single tensor crossing a cut placed
    *after* the node, in deployment dtype (int8 for the TinyML path,
    bf16 for the TPU path) — the paper's Eq. 1 sequential-chain view
    (Table II packet counts confirm only the main tensor is shipped).
  * ``work_bytes`` approximates the peak resident activation set for the
    node (input + output), used for device memory feasibility.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from repro.core.latency import LayerCost, ModelCostProfile


@dataclass(frozen=True)
class LayerNode:
    name: str
    flops: float
    param_count: int
    out_elems: int  # elements of the output tensor (act bytes = elems * act_dtype)
    work_elems: int  # peak resident activation elements


@dataclass(frozen=True)
class LayerGraph:
    name: str
    nodes: tuple[LayerNode, ...]
    input_elems: int

    @property
    def num_layers(self) -> int:
        return len(self.nodes)

    @property
    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    @property
    def total_params(self) -> int:
        return sum(n.param_count for n in self.nodes)

    def node_index(self, name: str) -> int:
        """1-indexed position of a named layer (for paper split points)."""
        for i, n in enumerate(self.nodes):
            if n.name == name:
                return i + 1
        raise KeyError(name)

    def cost_profile(
        self,
        flops_per_s: float,
        act_dtype_bytes: int = 1,
        param_dtype_bytes: int = 1,
    ) -> ModelCostProfile:
        """Convert to a ``ModelCostProfile`` with FLOP-proportional per-layer
        inference times at ``flops_per_s`` (the reference device rate)."""
        layers = [
            LayerCost(
                name=n.name,
                t_infer_s=n.flops / flops_per_s,
                act_bytes=n.out_elems * act_dtype_bytes,
                param_bytes=n.param_count * param_dtype_bytes,
                work_bytes=n.work_elems * act_dtype_bytes,
                flops=n.flops,
            )
            for n in self.nodes
        ]
        return ModelCostProfile(
            name=self.name, layers=tuple(layers), input_bytes=self.input_elems * act_dtype_bytes
        )


# ---------------------------------------------------------------------------
# MobileNet-V2 (paper model 1) — width multiplier, Keras block naming
# ---------------------------------------------------------------------------


def make_divisible(v: float, divisor: int = 8) -> int:
    """TF-slim channel rounding used by MobileNet width multipliers."""
    new_v = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


# (expansion t, base channels c, repeats n, first stride s)
_MBV2_GROUPS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2_graph(
    width: float = 0.35, image_size: int = 224, num_classes: int = 1000
) -> LayerGraph:
    """MobileNet-V2 flattened to its sequential sub-layer chain.

    Paper split points exist by name: ``block_2_expand`` (56x56x48 @224),
    ``block_15_project`` (7x7x56), ``block_16_project_BN`` (7x7x112)."""
    nodes: list[LayerNode] = []
    h = image_size // 2
    c_in = 3
    c1 = make_divisible(32 * width)
    in_elems = image_size * image_size * 3

    def conv(name, h_out, c_out, c_in, k, in_elems_):
        out = h_out * h_out * c_out
        nodes.append(
            LayerNode(
                name,
                flops=2.0 * h_out * h_out * c_out * c_in * k * k,
                param_count=c_in * c_out * k * k + c_out,
                out_elems=out,
                work_elems=in_elems_ + out,
            )
        )
        return out

    def dwconv(name, h_out, c, k, in_elems_):
        out = h_out * h_out * c
        nodes.append(
            LayerNode(
                name,
                flops=2.0 * h_out * h_out * c * k * k,
                param_count=c * k * k + c,
                out_elems=out,
                work_elems=in_elems_ + out,
            )
        )
        return out

    cur = conv("Conv1", h, c1, 3, 3, in_elems)
    c_in = c1
    block_id = 0
    for t, c_base, n, s in _MBV2_GROUPS:
        c_out = make_divisible(c_base * width)
        for i in range(n):
            stride = s if i == 0 else 1
            h_out = h // stride
            prefix = "expanded_conv" if block_id == 0 else f"block_{block_id}"
            if t != 1:
                cur = conv(f"{prefix}_expand", h, c_in * t, c_in, 1, cur)
                c_mid = c_in * t
            else:
                c_mid = c_in
            cur = dwconv(f"{prefix}_depthwise", h_out, c_mid, 3, cur)
            # project conv + folded BN (+ residual add when stride=1, c_in==c_out)
            cur = conv(f"{prefix}_project_BN", h_out, c_out, c_mid, 1, cur)
            h, c_in = h_out, c_out
            block_id += 1
    cur = conv("Conv_1", h, make_divisible(1280 * max(1.0, width)), c_in, 1, cur)
    c_last = make_divisible(1280 * max(1.0, width))
    # global average pool
    nodes.append(
        LayerNode("global_pool", flops=float(h * h * c_last), param_count=0,
                  out_elems=c_last, work_elems=cur + c_last)
    )
    # classifier
    nodes.append(
        LayerNode("Logits", flops=2.0 * c_last * num_classes,
                  param_count=c_last * num_classes + num_classes,
                  out_elems=num_classes, work_elems=c_last + num_classes)
    )
    return LayerGraph(f"mobilenet_v2_{width}", tuple(nodes), in_elems)


# ---------------------------------------------------------------------------
# ResNet50 (paper model 2)
# ---------------------------------------------------------------------------

_R50_STAGES = [  # (mid channels, out channels, repeats, first stride)
    (64, 256, 3, 1),
    (128, 512, 4, 2),
    (256, 1024, 6, 2),
    (512, 2048, 3, 2),
]


def resnet50_graph(image_size: int = 224, num_classes: int = 1000) -> LayerGraph:
    nodes: list[LayerNode] = []
    in_elems = image_size * image_size * 3

    def conv(name, h_out, c_out, c_in, k, in_elems_):
        out = h_out * h_out * c_out
        nodes.append(
            LayerNode(
                name,
                flops=2.0 * h_out * h_out * c_out * c_in * k * k,
                param_count=c_in * c_out * k * k + c_out,
                out_elems=out,
                work_elems=in_elems_ + out,
            )
        )
        return out

    h = image_size // 2
    cur = conv("conv1", h, 64, 3, 7, in_elems)
    h //= 2  # maxpool
    nodes.append(LayerNode("pool1", flops=float(h * h * 64 * 9), param_count=0,
                           out_elems=h * h * 64, work_elems=cur + h * h * 64))
    cur = h * h * 64
    c_in = 64
    for stage, (c_mid, c_out, n, s) in enumerate(_R50_STAGES, start=2):
        for i in range(n):
            stride = s if i == 0 else 1
            h_out = h // stride
            name = f"conv{stage}_block{i + 1}"
            cur = conv(f"{name}_1", h, c_mid, c_in, 1, cur)
            cur = conv(f"{name}_2", h_out, c_mid, c_mid, 3, cur)
            # 1x1 expand; downsample projection folded into the first block
            proj = c_in * c_out + c_out if i == 0 else 0
            out = h_out * h_out * c_out
            nodes.append(
                LayerNode(
                    f"{name}_3",
                    flops=2.0 * h_out * h_out * c_out * c_mid
                    + (2.0 * h_out * h_out * c_out * c_in if i == 0 else 0.0),
                    param_count=c_mid * c_out + c_out + proj,
                    out_elems=out,
                    work_elems=cur + out,
                )
            )
            cur = out
            h, c_in = h_out, c_out
    nodes.append(LayerNode("avg_pool", flops=float(h * h * c_in), param_count=0,
                           out_elems=c_in, work_elems=cur + c_in))
    nodes.append(LayerNode("fc", flops=2.0 * c_in * num_classes,
                           param_count=c_in * num_classes + num_classes,
                           out_elems=num_classes, work_elems=c_in + num_classes))
    return LayerGraph("resnet50", tuple(nodes), in_elems)


# ---------------------------------------------------------------------------
# Transformer-family graphs (the 10 assigned architectures)
# ---------------------------------------------------------------------------


def _attn_flops(b: int, s: int, d: int, n_heads: int, n_kv: int, head_dim: int,
                kv_len: int | None = None) -> float:
    """QKV + scores + AV + out-proj flops for one attention layer."""
    kv_len = s if kv_len is None else kv_len
    q_proj = 2.0 * b * s * d * (n_heads * head_dim)
    kv_proj = 2.0 * b * s * d * (2 * n_kv * head_dim)
    scores = 2.0 * b * n_heads * s * kv_len * head_dim
    av = 2.0 * b * n_heads * s * kv_len * head_dim
    out = 2.0 * b * s * (n_heads * head_dim) * d
    return q_proj + kv_proj + scores + av + out


def transformer_layer_graph(
    *,
    name: str,
    n_layers: int,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    vocab: int,
    batch: int,
    seq: int,
    head_dim: int | None = None,
    n_experts: int = 0,
    top_k: int = 0,
    gated_mlp: bool = True,
    kv_len: int | None = None,
    tie_embeddings: bool = False,
) -> LayerGraph:
    """Per-block layer graph for a decoder-only LM.

    Each transformer block is one node (split candidates are block
    boundaries — KV caches make intra-block cuts impractical). The
    embedding and LM head are separate nodes. ``kv_len`` models decode
    steps (s=1 query against a long cache)."""
    head_dim = head_dim or d_model // n_heads
    nodes: list[LayerNode] = []
    act = batch * seq * d_model
    in_elems = batch * seq  # token ids

    nodes.append(
        LayerNode("embed", flops=0.0, param_count=vocab * d_model,
                  out_elems=act, work_elems=batch * seq + act)
    )
    mlp_mats = 3 if gated_mlp else 2
    for i in range(n_layers):
        attn = _attn_flops(batch, seq, d_model, n_heads, n_kv_heads, head_dim, kv_len)
        if n_experts > 0:
            ff = 2.0 * batch * seq * d_model * d_ff * mlp_mats * top_k
            router = 2.0 * batch * seq * d_model * n_experts
            ff_params = n_experts * (mlp_mats * d_model * d_ff) + d_model * n_experts
            ff += router
        else:
            ff = 2.0 * batch * seq * d_model * d_ff * mlp_mats
            ff_params = mlp_mats * d_model * d_ff
        attn_params = (n_heads + 2 * n_kv_heads) * head_dim * d_model + n_heads * head_dim * d_model
        nodes.append(
            LayerNode(
                f"block_{i}",
                flops=attn + ff,
                param_count=attn_params + ff_params + 2 * d_model,
                out_elems=act,
                work_elems=2 * act,
            )
        )
    head_params = 0 if tie_embeddings else vocab * d_model
    nodes.append(
        LayerNode("lm_head", flops=2.0 * batch * seq * d_model * vocab,
                  param_count=head_params, out_elems=batch * seq * vocab,
                  work_elems=act + batch * seq * vocab)
    )
    return LayerGraph(name, tuple(nodes), in_elems)


def arch_layer_graph(cfg, batch: int, seq: int, kv_len: int | None = None,
                     act_dtype_bytes: int = 2) -> LayerGraph:
    """LayerGraph for any assigned :class:`ModelConfig` — walks the block
    pattern with per-kind FLOP/param/activation formulas. Used by the
    analytic roofline terms and by :func:`plan_pipeline` on real archs."""
    d = cfg.d_model
    nodes: list[LayerNode] = []
    act = batch * seq * d
    embed_params = cfg.vocab * d * max(1, cfg.n_codebooks)
    nodes.append(LayerNode("embed", flops=0.0, param_count=embed_params,
                           out_elems=act, work_elems=2 * act))
    for i, kind in enumerate(cfg.pattern):
        if kind == "attn":
            if cfg.use_mla:
                dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
                H = cfg.n_heads
                kv = seq if kv_len is None else kv_len
                f = 2.0 * batch * seq * (
                    d * cfg.q_lora_rank + cfg.q_lora_rank * H * (dn + dr)
                    + d * (cfg.kv_lora_rank + dr))
                # absorbed-score decode path: latent-space attention
                f += 2.0 * batch * H * seq * kv * (cfg.kv_lora_rank + dr) * 2
                f += 2.0 * batch * seq * H * dv * d
                p = (d * cfg.q_lora_rank + cfg.q_lora_rank * H * (dn + dr)
                     + d * (cfg.kv_lora_rank + dr)
                     + cfg.kv_lora_rank * H * (dn + dv) + H * dv * d)
            else:
                f = _attn_flops(batch, seq, d, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, kv_len)
                p = ((cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim * d
                     + cfg.n_heads * cfg.head_dim * d)
            if cfg.is_moe:
                mats = 3 if cfg.gated_mlp else 2
                f += 2.0 * batch * seq * d * cfg.d_ff * mats * cfg.top_k
                f += 2.0 * batch * seq * d * cfg.n_experts
                p += cfg.n_experts * mats * d * cfg.d_ff + d * cfg.n_experts
            elif cfg.d_ff:
                mats = 3 if cfg.gated_mlp else 2
                f += 2.0 * batch * seq * d * cfg.d_ff * mats
                p += mats * d * cfg.d_ff
            nodes.append(LayerNode(f"block_{i}_attn", flops=f, param_count=p + 2 * d,
                                   out_elems=act, work_elems=2 * act))
        elif kind == "mamba":
            di, ds = cfg.d_inner, cfg.ssm_state
            nh = di // cfg.ssm_head_dim
            f = 2.0 * batch * seq * (d * (2 * di + 2 * ds + nh)  # in_proj
                                     + (di + 2 * ds) * cfg.d_conv  # conv
                                     + 2 * di * ds  # scan state update + out
                                     + di * d)  # out_proj
            p = (d * (2 * di + 2 * ds + nh) + (di + 2 * ds) * cfg.d_conv
                 + 2 * nh + nh + di * d)
            nodes.append(LayerNode(f"block_{i}_mamba", flops=f, param_count=p + d,
                                   out_elems=act, work_elems=2 * act))
        elif kind in ("mlstm", "slstm"):
            di = cfg.d_inner
            f = 2.0 * batch * seq * (d * (3 * di + 2 * cfg.n_heads) + di * d)
            if kind == "mlstm":
                ph = di // cfg.n_heads
                # chunk-parallel matrix-memory terms
                f += 2.0 * batch * seq * cfg.n_heads * ph * ph * 2
            else:
                ph = di // cfg.n_heads
                f += 2.0 * batch * seq * cfg.n_heads * ph * 4 * ph
            p = d * (4 * di if kind == "slstm" else 3 * di + 2 * cfg.n_heads) + di * d
            nodes.append(LayerNode(f"block_{i}_{kind}", flops=f, param_count=p + d,
                                   out_elems=act, work_elems=2 * act))
    head_p = 0 if cfg.tie_embeddings else cfg.vocab_padded * d * max(1, cfg.n_codebooks)
    nodes.append(LayerNode(
        "lm_head",
        flops=2.0 * batch * seq * d * cfg.vocab_padded * max(1, cfg.n_codebooks),
        param_count=head_p,
        out_elems=batch * seq * cfg.vocab_padded,
        work_elems=act + batch * seq * cfg.vocab_padded))
    return LayerGraph(cfg.name, tuple(nodes), batch * seq)


def ssm_layer_graph(
    *,
    name: str,
    n_layers: int,
    d_model: int,
    d_state: int,
    vocab: int,
    batch: int,
    seq: int,
    expand: int = 2,
    conv_dim: int = 4,
) -> LayerGraph:
    """Mamba2-style SSM block chain (used for zamba2 / xlstm planning)."""
    d_inner = expand * d_model
    nodes: list[LayerNode] = []
    act = batch * seq * d_model
    nodes.append(LayerNode("embed", flops=0.0, param_count=vocab * d_model,
                           out_elems=act, work_elems=act))
    for i in range(n_layers):
        in_proj = 2.0 * batch * seq * d_model * (2 * d_inner)
        conv = 2.0 * batch * seq * d_inner * conv_dim
        scan = 2.0 * batch * seq * d_inner * d_state * 2
        out_proj = 2.0 * batch * seq * d_inner * d_model
        params = d_model * 2 * d_inner + d_inner * conv_dim + d_inner * d_state * 2 + d_inner * d_model
        nodes.append(LayerNode(f"ssm_block_{i}", flops=in_proj + conv + scan + out_proj,
                               param_count=params + 2 * d_model, out_elems=act, work_elems=2 * act))
    nodes.append(LayerNode("lm_head", flops=2.0 * batch * seq * d_model * vocab,
                           param_count=vocab * d_model, out_elems=batch * seq * vocab,
                           work_elems=act + batch * seq * vocab))
    return LayerGraph(name, tuple(nodes), batch * seq)
