"""Recurrent / state-space blocks: Mamba2 (SSD), mLSTM, sLSTM.

These power the sub-quadratic architectures (zamba2 hybrid, xlstm) and the
long_500k cells. Design notes:

* **Mamba2 (SSD)** — chunked parallel form for training/prefill (dense
  matmuls inside chunks -> MXU-friendly; inter-chunk state carried by a
  scan), plus an O(1)-per-token recurrent step for decode. This is the
  TPU-native adaptation: the CUDA kernel's warp-level scan becomes a
  chunk-parallel matmul decomposition.

* **mLSTM** — chunk-parallel linear attention with per-head scalar
  input/forget gates (GLA-style decay within/across chunks), matrix
  memory C: (B, H, Dk, Dv) carried across chunks; O(1) decode step. The
  max-stabilizer of the paper's fully-sequential form is replaced by
  log-space gate accumulation within chunks (documented simplification —
  exact for the gate magnitudes used here).

* **sLSTM** — inherently sequential scalar-memory cell with block-diagonal
  recurrent mixing; implemented as a lax.scan over time (one while loop in
  HLO), exponential gating with the stabilizer state m.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, _dense_init, cdtype, constrain


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------


def init_mamba(rng, cfg: ModelConfig) -> Params:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    dt = cdtype(cfg)
    ks = jax.random.split(rng, 5)
    return {
        # projects to [z (di), x (di), B (ds), C (ds), dt (nh)]
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * ds + nh), dtype=dt),
        "conv_w": _dense_init(ks[1], (cfg.d_conv, di + 2 * ds), scale=0.5, dtype=dt),
        "conv_b": jnp.zeros((di + 2 * ds,), dtype=dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((nh,), dtype=jnp.float32),
        "out_proj": _dense_init(ks[2], (di, d), dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along seq. x: (B, S, C); w: (K, C).
    ``state``: (B, K-1, C) trailing context from previous steps."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros_like(x[:, :0, :])
    return jax.nn.silu(out + b[None, None, :]), new_state


def _segsum(dA: jax.Array) -> jax.Array:
    """L[i, j] = sum_{j < t <= i} dA_t for j <= i else -inf. dA: (..., C)."""
    C = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j) = cs_i - cs_j
    mask = jnp.tril(jnp.ones((C, C), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba_chunked(cfg: ModelConfig, p: Params, xin: jax.Array,
                  chunk: int = 128) -> jax.Array:
    """Chunk-parallel SSD over a full sequence (training/prefill)."""
    B, S, _ = xin.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ph = cfg.ssm_head_dim

    proj = jnp.einsum("bsd,de->bse", xin, p["in_proj"],
                      preferred_element_type=jnp.float32).astype(xin.dtype)
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * ds], axis=-1)
    z = constrain(z, 2)  # d_inner -> 'model' (TP over the SSM channels)
    xBC, _ = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    x, Bmat, Cmat = jnp.split(xBC, [di, di + ds], axis=-1)
    x = constrain(x, 2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,) negative
    dA = dt * A[None, None, :]  # (B,S,nh)

    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    def rs(t, feat):  # (B, S', F) -> (n, B, C, F)
        return t.reshape(B, n_chunks, chunk, feat).transpose(1, 0, 2, 3)

    xh = rs(x, di).reshape(n_chunks, B, chunk, nh, ph)
    Bc = rs(Bmat, ds)
    Cc = rs(Cmat, ds)
    dAc = rs(dA, nh)
    dtc = rs(dt, nh)

    h0 = jnp.zeros((B, nh, ph, ds), dtype=jnp.float32)

    def body(h_prev, inp):
        xc, bc, cc, dac, dtck = inp  # per-chunk tensors
        L = jnp.exp(_segsum(dac.transpose(0, 2, 1)))  # (B, nh, C, C)
        # intra-chunk: Y = (C B^T ∘ L) (dt x)
        cb = jnp.einsum("bis,bjs->bij", cc.astype(jnp.float32), bc.astype(jnp.float32))
        scores = cb[:, None, :, :] * L  # (B, nh, C, C)
        xdt = xc.astype(jnp.float32) * dtck[..., None]  # (B, C, nh, ph)
        y_intra = jnp.einsum("bhij,bjhp->bihp", scores, xdt)
        # contribution of the carried state: y += (C_t ∘ exp(cum dA)) h_prev
        cum = jnp.cumsum(dac, axis=1)  # (B, C, nh)
        decay_in = jnp.exp(cum)  # (B, C, nh)
        y_state = jnp.einsum("bis,bhps,bih->bihp", cc.astype(jnp.float32), h_prev,
                             decay_in)
        # state update: h = exp(total) h_prev + sum_t exp(total - cum_t) dt_t B_t x_t
        total = cum[:, -1, :]  # (B, nh)
        decay_out = jnp.exp(total[:, None, :] - cum)  # (B, C, nh)
        h_new = jnp.exp(total)[:, :, None, None] * h_prev + jnp.einsum(
            "bis,bihp,bih->bhps", bc.astype(jnp.float32), xdt, decay_out)
        return h_new, y_intra + y_state

    _, ys = jax.lax.scan(body, h0, (xh, Bc, Cc, dAc, dtc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, nh, ph)
    if pad:
        y = y[:, :S]
        x = x[:, :S]
    y = y + x.reshape(B, S, nh, ph).astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(xin.dtype) * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"],
                      preferred_element_type=jnp.float32).astype(xin.dtype)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di, ds = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, ds), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di + 2 * ds), dtype=dtype),
    }


def mamba_step(cfg: ModelConfig, p: Params, xin: jax.Array, cache: Params
               ) -> tuple[jax.Array, Params]:
    """Single-token recurrent step. xin: (B, 1, D)."""
    B = xin.shape[0]
    di, ds = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ph = cfg.ssm_head_dim

    proj = jnp.einsum("bsd,de->bse", xin, p["in_proj"],
                      preferred_element_type=jnp.float32).astype(xin.dtype)
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * ds], axis=-1)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], state=cache["conv"])
    x, Bmat, Cmat = jnp.split(xBC[:, 0], [di, di + ds], axis=-1)  # (B, ·)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])  # (B,nh)
    xh = x.reshape(B, nh, ph).astype(jnp.float32)
    h = cache["h"] * dA[:, :, None, None] + jnp.einsum(
        "bs,bhp,bh->bhps", Bmat.astype(jnp.float32), xh, dt)
    y = jnp.einsum("bs,bhps->bhp", Cmat.astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(xin.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(xin.dtype)
    return out, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg: ModelConfig) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    nh = cfg.n_heads
    dt = cdtype(cfg)
    ks = jax.random.split(rng, 3)
    return {
        # q, k, v (each di) + input/forget gate logits (nh each)
        "in_proj": _dense_init(ks[0], (d, 3 * di + 2 * nh), dtype=dt),
        "out_proj": _dense_init(ks[1], (di, d), dtype=dt),
        "f_bias": jnp.full((nh,), 3.0, dtype=jnp.float32),  # open forget gates
    }


def mlstm_chunked(cfg: ModelConfig, p: Params, xin: jax.Array,
                  chunk: int = 128) -> jax.Array:
    """Chunk-parallel mLSTM: linear attention with scalar decay gates."""
    B, S, _ = xin.shape
    di, nh = cfg.d_inner, cfg.n_heads
    ph = di // nh

    proj = jnp.einsum("bsd,de->bse", xin, p["in_proj"],
                      preferred_element_type=jnp.float32).astype(xin.dtype)
    q, k, v, gates = jnp.split(proj, [di, 2 * di, 3 * di], axis=-1)
    if cfg.ssm_tp:
        q, k, v = constrain(q, 2), constrain(k, 2), constrain(v, 2)
    else:  # pure-DP mixer: keep channels replicated, no per-chunk psums
        q, k, v = constrain(q, None), constrain(k, None), constrain(v, None)
    i_log = gates[..., :nh].astype(jnp.float32)  # log input gate
    f_log = jax.nn.log_sigmoid(gates[..., nh:].astype(jnp.float32) + p["f_bias"])

    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        i_log = jnp.pad(i_log, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        f_log = jnp.pad(f_log, ((0, 0), (0, pad), (0, 0)))

    def rs(t):
        return t.reshape(B, n_chunks, chunk, nh, ph).transpose(1, 0, 2, 3, 4)

    qc, kc, vc = rs(q), rs(k), rs(v)
    ic = i_log.reshape(B, n_chunks, chunk, nh).transpose(1, 0, 2, 3)
    fc = f_log.reshape(B, n_chunks, chunk, nh).transpose(1, 0, 2, 3)
    scale = 1.0 / math.sqrt(ph)

    C0 = jnp.zeros((B, nh, ph, ph), dtype=jnp.float32)
    n0 = jnp.zeros((B, nh, ph), dtype=jnp.float32)

    def body(carry, inp):
        C, n = carry
        qk, kk, vk, ik, fk = inp
        qf = qk.astype(jnp.float32) * scale
        kf, vf = kk.astype(jnp.float32), vk.astype(jnp.float32)
        cumf = jnp.cumsum(fk, axis=1)  # (B, C, nh)
        total = cumf[:, -1, :]
        # intra-chunk decay matrix D_ij = exp(cumf_i - cumf_j + i_j), j <= i
        dmat = cumf[:, :, None, :] - cumf[:, None, :, :] + ik[:, None, :, :]
        mask = jnp.tril(jnp.ones((qk.shape[1], qk.shape[1]), dtype=bool))
        dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
        w = jnp.exp(dmat)  # (B, i, j, nh)
        s = jnp.einsum("bihp,bjhp->bijh", qf, kf)
        y_intra = jnp.einsum("bijh,bijh,bjhp->bihp", s, w, vf)
        z_intra = jnp.einsum("bijh,bijh,bjhp->bihp", s, w, jnp.ones_like(vf))[..., :1]
        # carried state: y += exp(cumf_i) q_i C ; normalizer n likewise
        din = jnp.exp(cumf)  # (B, C, nh)
        y_state = jnp.einsum("bihp,bhpq,bih->bihq", qf, C, din)
        z_state = jnp.einsum("bihp,bhp,bih->bih", qf, n, din)[..., None]
        # state update
        dout = jnp.exp(total[:, None, :] - cumf + ik)  # (B, C, nh)
        C_new = jnp.exp(total)[:, :, None, None] * C + jnp.einsum(
            "bjhp,bjhq,bjh->bhpq", kf, vf, dout)
        n_new = jnp.exp(total)[:, :, None] * n + jnp.einsum("bjhp,bjh->bhp", kf, dout)
        y = (y_intra + y_state) / jnp.maximum(jnp.abs(z_intra + z_state), 1.0)
        return (C_new, n_new), y

    _, ys = jax.lax.scan(body, (C0, n0), (qc, kc, vc, ic, fc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, di)
    if pad:
        y = y[:, :S]
    return jnp.einsum("bsd,de->bse", y.astype(xin.dtype), p["out_proj"],
                      preferred_element_type=jnp.float32).astype(xin.dtype)


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Params:
    nh, ph = cfg.n_heads, cfg.d_inner // cfg.n_heads
    return {
        "C": jnp.zeros((batch, nh, ph, ph), dtype=jnp.float32),
        "n": jnp.zeros((batch, nh, ph), dtype=jnp.float32),
    }


def mlstm_step(cfg: ModelConfig, p: Params, xin: jax.Array, cache: Params
               ) -> tuple[jax.Array, Params]:
    """O(1) decode step. xin: (B, 1, D)."""
    B = xin.shape[0]
    di, nh = cfg.d_inner, cfg.n_heads
    ph = di // nh
    proj = jnp.einsum("bsd,de->bse", xin, p["in_proj"],
                      preferred_element_type=jnp.float32).astype(xin.dtype)
    q, k, v, gates = jnp.split(proj[:, 0], [di, 2 * di, 3 * di], axis=-1)
    i_g = jnp.exp(gates[..., :nh].astype(jnp.float32))
    f_g = jax.nn.sigmoid(gates[..., nh:].astype(jnp.float32) + p["f_bias"])
    qh = q.reshape(B, nh, ph).astype(jnp.float32) / math.sqrt(ph)
    kh = k.reshape(B, nh, ph).astype(jnp.float32)
    vh = v.reshape(B, nh, ph).astype(jnp.float32)
    C = cache["C"] * f_g[:, :, None, None] + i_g[:, :, None, None] * jnp.einsum(
        "bhp,bhq->bhpq", kh, vh)
    n = cache["n"] * f_g[:, :, None] + i_g[:, :, None] * kh
    y = jnp.einsum("bhp,bhpq->bhq", qh, C)
    z = jnp.abs(jnp.einsum("bhp,bhp->bh", qh, n))[..., None]
    y = (y / jnp.maximum(z, 1.0)).reshape(B, 1, di)
    out = jnp.einsum("bsd,de->bse", y.astype(xin.dtype), p["out_proj"],
                     preferred_element_type=jnp.float32).astype(xin.dtype)
    return out, {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block, sequential)
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg: ModelConfig) -> Params:
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.n_heads
    ph = di // nh
    dt = cdtype(cfg)
    ks = jax.random.split(rng, 3)
    return {
        "w_in": _dense_init(ks[0], (d, 4 * di), dtype=dt),  # i, f, z, o pre-acts
        "r": _dense_init(ks[1], (nh, ph, 4 * ph), scale=1.0 / math.sqrt(ph),
                         dtype=jnp.float32),
        "out_proj": _dense_init(ks[2], (di, d), dtype=dt),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Params:
    di = cfg.d_inner
    z = jnp.zeros((batch, di), dtype=jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z - 10.0}


def _slstm_cell(cfg: ModelConfig, p: Params, wx_t: jax.Array, state: Params
                ) -> tuple[Params, jax.Array]:
    """One sLSTM time step with exponential gating + stabilizer m."""
    B = wx_t.shape[0]
    di, nh = cfg.d_inner, cfg.n_heads
    ph = di // nh
    h_prev = state["h"].reshape(B, nh, ph)
    rec = jnp.einsum("bhp,hpq->bhq", h_prev, p["r"]).reshape(B, 4 * di)
    pre = wx_t.astype(jnp.float32) + rec
    i_r, f_r, z_r, o_r = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_r + state["m"], i_r)
    i_g = jnp.exp(i_r - m_new)
    f_g = jnp.exp(f_r + state["m"] - m_new)
    c = f_g * state["c"] + i_g * jnp.tanh(z_r)
    n = f_g * state["n"] + i_g
    h = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}, h


def slstm_forward(cfg: ModelConfig, p: Params, xin: jax.Array,
                  cache: Params | None = None
                  ) -> tuple[jax.Array, Params]:
    """Sequence or single-step sLSTM. xin: (B, S, D)."""
    B, S, _ = xin.shape
    wx = jnp.einsum("bsd,de->bse", xin, p["w_in"],
                    preferred_element_type=jnp.float32)
    state = cache or init_slstm_cache(cfg, B)

    def step(st, wx_t):
        st2, h = _slstm_cell(cfg, p, wx_t, st)
        return st2, h

    state, hs = jax.lax.scan(step, state, jnp.swapaxes(wx, 0, 1))
    y = jnp.swapaxes(hs, 0, 1)  # (B, S, di)
    out = jnp.einsum("bsd,de->bse", y.astype(xin.dtype), p["out_proj"],
                     preferred_element_type=jnp.float32).astype(xin.dtype)
    return out, state
