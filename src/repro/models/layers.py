"""Neural-net primitives for the model zoo (pure functional JAX).

Conventions:
  * params are nested dicts of jax.Array leaves; init fns take (rng, cfg).
  * activations: (batch, seq, d_model); attention heads: (B, S, H, Dh).
  * matmuls accumulate in f32 (``preferred_element_type``), norms/softmax
    computed in f32 and cast back to the working dtype.
  * attention is memory-efficient by construction: q>1 paths use an
    online-softmax scan over KV chunks (the 32k-prefill cells would
    otherwise materialize 32k x 32k score matrices); q==1 decode paths use
    plain O(S) attention which XLA shards cleanly (including
    sequence-sharded KV caches for the 500k-context cells).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = dict


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Logical-axis sharding constraints (MaxText-style): every major
# intermediate is pinned so XLA SPMD cannot drift into replicating heads /
# hidden dims at scale. All helpers no-op outside a mesh context and skip
# non-divisible dims.
# ---------------------------------------------------------------------------


def _mesh_axes() -> dict:
    try:
        from jax._src.mesh import thread_resources

        return dict(thread_resources.env.physical_mesh.shape)
    except Exception:  # noqa: BLE001
        return {}


def _dp_spec(axes: dict, B: int):
    dp = tuple(a for a in ("pod", "data") if a in axes)
    size = 1
    for a in dp:
        size *= axes[a]
    if dp and B % size == 0 and B >= size:
        return dp if len(dp) > 1 else dp[0]
    return None


def constrain(x: jax.Array, model_dim: int | None) -> jax.Array:
    """Pin (batch -> DP axes, ``model_dim`` -> 'model' if divisible)."""
    axes = _mesh_axes()
    if not axes:
        return x
    from jax.sharding import PartitionSpec as P

    spec = [None] * x.ndim
    spec[0] = _dp_spec(axes, x.shape[0])
    m = axes.get("model", 1)
    if model_dim is not None and m > 1:
        d = model_dim % x.ndim
        if x.shape[d] % m == 0 and x.shape[d] >= m:
            spec[d] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_param(w: jax.Array, model_dim: int) -> jax.Array:
    """Pin a weight's tensor-parallel dim to 'model', leaving every other
    dim UNCONSTRAINED (so FSDP data-sharding survives). Without this the
    SPMD partitioner sometimes decides to all-gather multi-GB weights
    inside the layer loop (observed on the 72B MLP stacks at decode)."""
    axes = _mesh_axes()
    m = axes.get("model", 1)
    d = model_dim % w.ndim
    if not axes or m <= 1 or w.shape[d] % m or w.shape[d] < m:
        return w
    from jax.sharding import PartitionSpec as P

    spec: list = [P.UNCONSTRAINED] * w.ndim
    spec[d] = "model"
    return jax.lax.with_sharding_constraint(w, P(*spec))


def _dense_init(rng, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(cfg: ModelConfig, d: int | None = None) -> Params:
    return {"scale": jnp.ones((d or cfg.d_model,), dtype=cdtype(cfg))}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"]


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for half the head dim."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, int, int] | None = None) -> jax.Array:
    """Rotate (B, S, H, Dh). ``positions``: (B, S) for standard RoPE or
    (3, B, S) for M-RoPE (Qwen2-VL), where the Dh/2 frequency slots are
    split into (t, h, w) sections each driven by its own position stream."""
    B = x.shape[0]
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)  # (dh/2,)
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)  # (B, S)
        angles = pos[..., None] * inv[None, None, :]  # (B, S, dh/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs positions (3, B, S)"
        sec = mrope_sections
        assert sum(sec) == dh // 2, f"M-RoPE sections {sec} must sum to {dh // 2}"
        pos = positions.astype(jnp.float32)  # (3, B, S)
        section_id = jnp.repeat(jnp.arange(3), jnp.array(sec), total_repeat_length=dh // 2)
        pos_per_freq = pos[section_id]  # (dh/2, B, S)
        angles = jnp.moveaxis(pos_per_freq, 0, -1) * inv  # (B, S, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, dh/2)
    sin = jnp.sin(angles)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def plain_attention(q, k, v, *, q_positions, kv_positions, scale) -> jax.Array:
    """O(Sq*Skv) attention with causal position masking (decode path).

    q: (B, Sq, H, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv) — Dk and
    Dv may differ (MLA). GQA by head-group reshape."""
    B, Sq, H, Dk = q.shape
    Hkv, Dv = k.shape[2], v.shape[3]
    G = H // Hkv
    qr = q.reshape(B, Sq, Hkv, G, Dk)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qr, k, preferred_element_type=jnp.float32)
    s = s * scale
    mask = kv_positions[None, None, :] <= q_positions[:, :, None]  # (B?,Sq,Skv)
    mask = mask[:, :, None, None, :] if mask.ndim == 3 else mask[None, :, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, Dv).astype(q.dtype)


def chunked_attention(q, k, v, *, q_positions, kv_positions, scale,
                      kv_chunk: int, q_chunk: int = 512,
                      causal_skip: bool = False) -> jax.Array:
    """Online-softmax attention, tiled over BOTH query and KV chunks
    (flash-style, pure JAX). Never materializes more than a
    (q_chunk x kv_chunk) score block per (batch, head); differentiable.

    q: (B, Sq, H, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv);
    q_positions: (B, Sq); kv_positions: (Skv,).

    Memory discipline: both scans iterate over chunk INDICES and
    dynamic-slice in place — no transposed chunk copies, no f32 upcasts of
    the full tensors (matmuls run in the storage dtype with f32
    accumulation via ``preferred_element_type``, the MXU convention)."""
    B, Sq, H, Dk = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // Hkv

    n_kv = -(-Skv // kv_chunk)
    pad_kv = n_kv * kv_chunk - Skv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad_kv), constant_values=2**30)

    qc = min(q_chunk, Sq)
    n_q = -(-Sq // qc)
    pad_q = n_q * qc - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad_q)), mode="edge")

    def q_block(qi):
        qs = qi * qc
        qch = jax.lax.dynamic_slice_in_dim(q, qs, qc, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(q_positions, qs, qc, axis=1)
        qr = qch.reshape(B, qc, Hkv, G, Dk)

        m0 = jnp.full((B, qc, Hkv, G), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, qc, Hkv, G), dtype=jnp.float32)
        a0 = jnp.zeros((B, qc, Hkv, G, Dv), dtype=jnp.float32)

        def body(carry, ci):
            m, l, acc = carry
            start = ci * kv_chunk
            kch = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
            vch = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
            pch = jax.lax.dynamic_slice_in_dim(kv_positions, start, kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qr, kch,
                           preferred_element_type=jnp.float32) * scale
            mask = pch[None, None, :] <= qpos[:, :, None]  # (B, qc, C)
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(v.dtype), vch,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        if causal_skip:
            # Causal self-attention: kv blocks past this q block's last
            # position are fully masked — skip them with a DYNAMIC loop
            # bound (~2x fewer attention FLOPs at steady state). fori_loop
            # with a traced bound is forward-only: used by the serving
            # paths (prefill), not training (scan keeps the bwd pass).
            hi = jnp.max(qpos)  # last real q position in this block
            n_needed = jnp.minimum(
                jnp.int32(n_kv), (hi.astype(jnp.int32) + kv_chunk) // kv_chunk)
            (m, l, acc) = jax.lax.fori_loop(
                0, n_needed, lambda ci, c: body(c, ci)[0], (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0), jnp.arange(n_kv, dtype=jnp.int32))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, qc, H, Dv).astype(q.dtype)

    if n_q == 1:
        out = q_block(jnp.int32(0))
    else:
        _, blocks = jax.lax.scan(
            lambda _, qi: (None, q_block(qi)), None,
            jnp.arange(n_q, dtype=jnp.int32))
        out = jnp.moveaxis(blocks, 0, 1).reshape(B, n_q * qc, H, Dv)
    if pad_q:
        out = out[:, :Sq]
    return out


def attention_core(cfg: ModelConfig, q, k, v, q_positions, kv_positions) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    if cfg.use_flash_kernel and q.shape[1] > 8:
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(q, k, v, q_positions=q_positions,
                               kv_positions=kv_positions, scale=scale)
    if q.shape[1] <= 8:  # decode: O(S) memory already, no chunking needed
        return plain_attention(q, k, v, q_positions=q_positions,
                               kv_positions=kv_positions, scale=scale)
    return chunked_attention(q, k, v, q_positions=q_positions,
                             kv_positions=kv_positions, scale=scale,
                             kv_chunk=cfg.kv_chunk, q_chunk=cfg.q_chunk,
                             causal_skip=cfg.causal_skip)


# ---------------------------------------------------------------------------
# GQA attention layer (with optional KV cache)
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig) -> Params:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cdtype(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "wq": _dense_init(ks[0], (d, H, Dh), dtype=dt),
        "wk": _dense_init(ks[1], (d, Hkv, Dh), dtype=dt),
        "wv": _dense_init(ks[2], (d, Hkv, Dh), dtype=dt),
        "wo": _dense_init(ks[3], (H * Dh, d), scale=1.0 / math.sqrt(H * Dh), dtype=dt),
    }


def _cache_writer(pos_ids: jax.Array, S: int, s_max: int):
    """KV-cache update function for a step writing ``S`` new positions.

    Decode steps (``S == 1``) write PER ROW: batch row ``b`` lands at
    ``pos_ids[b, 0]`` via a one-hot masked select, so slots in a batched
    server can sit at different sequence positions — and a negative
    position (idle / non-admitted slot) matches no cache row at all, i.e.
    writes nothing. The previous uniform ``dynamic_update_slice`` at
    ``pos_ids[0, 0]`` stamped every row at slot 0's position, which is
    how a mid-decode admission clobbered other slots' caches.

    Multi-token steps (prefill, ``S > 1``) keep the uniform-offset slice
    write: all rows advance together from ``pos_ids[0, 0]``."""
    if S == 1:
        hit = jnp.arange(s_max, dtype=jnp.int32)[None, :] == pos_ids[:, :1]

        def upd(c, u):
            mask = hit.reshape(hit.shape + (1,) * (u.ndim - 2))
            return jnp.where(mask, u.astype(c.dtype), c)
    else:
        offset = pos_ids[0, 0]

        def upd(c, u):
            return jax.lax.dynamic_update_slice_in_dim(
                c, u.astype(c.dtype), offset, axis=1)
    return upd


def apply_attention(cfg: ModelConfig, p: Params, x: jax.Array,
                    positions: jax.Array, cache: Params | None = None
                    ) -> tuple[jax.Array, Params | None]:
    """x: (B, S, D). ``positions``: (B, S) or (3, B, S) for M-RoPE.
    ``cache``: {"k","v": (B, Smax, Hkv, Dh)} updated at ``positions``.
    Returns (out, new_cache)."""
    B, S, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = constrain(q, 2)  # heads -> 'model' (tensor parallel attention)
    k = constrain(k, 2)
    v = constrain(v, 2)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    # scalar (B, S) position ids for masking (M-RoPE masks on the t stream)
    pos_ids = positions[0] if positions.ndim == 3 else positions

    if cache is not None:
        upd = _cache_writer(pos_ids, S, cache["k"].shape[1])
        if "k_scale" in cache:
            # quantized KV cache: symmetric int8 per (token, head)
            def q8(t):
                amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
                scale = jnp.where(amax > 0, amax / 127.0, 1.0)
                vals = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                                -127, 127).astype(jnp.int8)
                return vals, scale

            kq, ks = q8(k)
            vq, vs = q8(v)
            new_cache = {"k": upd(cache["k"], kq), "v": upd(cache["v"], vq),
                         "k_scale": upd(cache["k_scale"], ks),
                         "v_scale": upd(cache["v_scale"], vs)}
            ck = (new_cache["k"].astype(x.dtype)
                  * new_cache["k_scale"][..., None].astype(x.dtype))
            cv = (new_cache["v"].astype(x.dtype)
                  * new_cache["v_scale"][..., None].astype(x.dtype))
        else:
            ck = upd(cache["k"], k)
            cv = upd(cache["v"], v)
            new_cache = {"k": ck, "v": cv}
        kv_positions = jnp.arange(ck.shape[1], dtype=jnp.int32)
        out = attention_core(cfg, q, ck, cv, pos_ids, kv_positions)
    else:
        kv_positions = jnp.arange(S, dtype=jnp.int32)
        out = attention_core(cfg, q, k, v, pos_ids, kv_positions)
        new_cache = None
    out = constrain(out, 2)
    y = jnp.einsum("bsf,fd->bsd", out.reshape(B, S, H * Dh),
                   p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def init_mla(rng, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = cdtype(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "q_down": _dense_init(ks[0], (d, qlr), dtype=dt),
        "q_up": _dense_init(ks[1], (qlr, H, dn + dr), dtype=dt),
        "kv_down": _dense_init(ks[2], (d, kvlr + dr), dtype=dt),
        "kv_up_k": _dense_init(ks[3], (kvlr, H, dn), dtype=dt),
        "kv_up_v": _dense_init(ks[4], (kvlr, H, dv), dtype=dt),
        "wo": _dense_init(ks[5], (H * dv, d), dtype=dt),
    }


def apply_mla(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array,
              cache: Params | None = None, absorbed: bool = False
              ) -> tuple[jax.Array, Params | None]:
    """Latent attention. The KV cache stores only the compressed latent
    (B, S, kv_lora_rank) plus the shared rope key (B, S, rope_dim) — the
    MLA memory win. ``absorbed=True`` computes scores in latent space
    (the optimized decode path; never expands per-head K/V)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    pos_ids = positions[0] if positions.ndim == 3 else positions

    q_lat = jnp.einsum("bsd,dr->bsr", x, p["q_down"], preferred_element_type=jnp.float32
                       ).astype(x.dtype)
    q = jnp.einsum("bsr,rhe->bshe", q_lat, p["q_up"], preferred_element_type=jnp.float32
                   ).astype(x.dtype)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["kv_down"], preferred_element_type=jnp.float32
                    ).astype(x.dtype)
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        upd = _cache_writer(pos_ids, S, cache["c_kv"].shape[1])
        c_kv = upd(cache["c_kv"], c_kv)
        k_rope = upd(cache["k_rope"], k_rope)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        new_cache = None
    Skv = c_kv.shape[1]
    kv_positions = jnp.arange(Skv, dtype=jnp.int32)
    scale = 1.0 / math.sqrt(dn + dr)

    if absorbed:
        # score = (q_nope^T W_uk) c + q_rope^T k_rope, all in latent space
        q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, p["kv_up_k"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
        s = jnp.einsum("bshr,bkr->bshk", q_abs, c_kv, preferred_element_type=jnp.float32)
        s += jnp.einsum("bshe,bke->bshk", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
        s = s * scale
        mask = kv_positions[None, None, :] <= pos_ids[:, :, None]
        s = jnp.where(mask[:, :, None, :], s, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bshk,bkr->bshr", prob.astype(x.dtype), c_kv,
                           preferred_element_type=jnp.float32).astype(x.dtype)
        out = jnp.einsum("bshr,rhe->bshe", o_lat, p["kv_up_v"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        k_nope = jnp.einsum("bkr,rhe->bkhe", c_kv, p["kv_up_k"],
                            preferred_element_type=jnp.float32).astype(x.dtype)
        v = jnp.einsum("bkr,rhe->bkhe", c_kv, p["kv_up_v"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (*k_rope.shape[:2], H, dr))
        k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        if S > 8:
            out = chunked_attention(q_full, k_full, v, q_positions=pos_ids,
                                    kv_positions=kv_positions, scale=scale,
                                    kv_chunk=cfg.kv_chunk, q_chunk=cfg.q_chunk)
        else:
            out = plain_attention(q_full, k_full, v, q_positions=pos_ids,
                                  kv_positions=kv_positions, scale=scale)
    y = jnp.einsum("bsf,fd->bsd", out.reshape(B, S, H * dv), p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = cdtype(cfg)
    ks = jax.random.split(rng, 3)
    p = {
        "w_in": _dense_init(ks[0], (d, f), dtype=dt),
        "w_out": _dense_init(ks[1], (f, d), dtype=dt),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _dense_init(ks[2], (d, f), dtype=dt)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = constrain(h, 2)  # hidden f -> 'model' (Megatron column-parallel)
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = jax.nn.silu(constrain(g, 2)) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture-of-Experts (GShard-style grouped dense dispatch, EP-shardable)
# ---------------------------------------------------------------------------


def init_moe(rng, cfg: ModelConfig) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cdtype(cfg)
    ks = jax.random.split(rng, 4)
    p = {
        "router": _dense_init(ks[0], (d, E), dtype=jnp.float32),
        "w_in": _dense_init(ks[1], (E, d, f), dtype=dt),
        "w_out": _dense_init(ks[2], (E, f, d), dtype=dt),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _dense_init(ks[3], (E, d, f), dtype=dt)
    return p


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Top-k routed experts with capacity-bounded grouped dispatch.

    Tokens are processed in groups of ``moe_group_size``; per group, each
    expert accepts at most C = ceil(g * top_k / E * capacity_factor)
    tokens (overflow dropped — GShard semantics). Experts are stacked
    (E, d, f) so EP shards them over the 'model' mesh axis."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(cfg.moe_group_size, T)
    n_groups = -(-T // g)
    pad = n_groups * g - T
    xf = x.reshape(T, D)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(n_groups, g, D)

    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (n, g, E)
    top_p, top_i = jax.lax.top_k(probs, K)  # (n, g, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    C = max(1, int(math.ceil(g * K / E * cfg.moe_capacity_factor)))
    # slot-major expert masks: (n, K, g, E)
    masks = jax.nn.one_hot(jnp.swapaxes(top_i, 1, 2), E, dtype=jnp.int32)
    flat = masks.reshape(xg.shape[0], K * g, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1  # position in expert queue
    pos = pos.reshape(masks.shape)  # (n, K, g, E)
    keep = (pos >= 0) & (pos < C)
    gates = jnp.swapaxes(top_p, 1, 2).astype(xg.dtype)  # (n, K, g)
    # accumulate dispatch/combine one top-k slot at a time: materializing
    # the full (n, K, g, E, C) one-hot would dominate training memory
    # (e.g. 5.4 GB/device for granite-moe train_4k)
    dispatch = jnp.zeros((xg.shape[0], g, E, C), dtype=xg.dtype)
    combine = jnp.zeros((xg.shape[0], g, E, C), dtype=xg.dtype)
    for j in range(K):
        d_j = jax.nn.one_hot(pos[:, j], C, dtype=xg.dtype)
        d_j = d_j * keep[:, j][..., None].astype(xg.dtype)  # (n, g, E, C)
        d_j = constrain(d_j, 2)
        dispatch = dispatch + d_j
        combine = combine + d_j * gates[:, j][:, :, None, None]
    dispatch = constrain(dispatch, 2)
    combine = constrain(combine, 2)

    expert_in = jnp.einsum("ngec,ngd->necd", dispatch, xg,
                           preferred_element_type=jnp.float32).astype(xg.dtype)
    expert_in = constrain(expert_in, 1)  # experts -> 'model' (EP)
    h = jnp.einsum("necd,edf->necf", expert_in, p["w_in"],
                   preferred_element_type=jnp.float32).astype(xg.dtype)
    h = constrain(h, 1)
    if cfg.gated_mlp:
        gate = jnp.einsum("necd,edf->necf", expert_in,
                          p["w_gate"],
                          preferred_element_type=jnp.float32).astype(xg.dtype)
        h = jax.nn.silu(constrain(gate, 1)) * h
    else:
        h = jax.nn.gelu(h)
    expert_out = jnp.einsum("necf,efd->necd", h, p["w_out"],
                            preferred_element_type=jnp.float32).astype(xg.dtype)
    expert_out = constrain(expert_out, 1)
    out = jnp.einsum("ngec,necd->ngd", combine, expert_out,
                     preferred_element_type=jnp.float32).astype(xg.dtype)
    out = out.reshape(n_groups * g, D)
    if pad:
        out = out[:T]
    return out.reshape(B, S, D)


def moe_aux_loss(cfg: ModelConfig, router_probs: jax.Array, top_idx: jax.Array) -> jax.Array:
    """Switch-style load-balancing loss (mean fraction * mean prob * E)."""
    E = cfg.n_experts
    frac = jnp.mean(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    prob = jnp.mean(router_probs, axis=tuple(range(router_probs.ndim - 1)))
    return jnp.sum(frac * prob) * E


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embed(rng, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    n_tables = max(1, cfg.n_codebooks)
    table = _dense_init(rng, (n_tables * cfg.vocab, cfg.d_model), scale=0.02, dtype=dt)
    return {"table": table}


def apply_embed(cfg: ModelConfig, p: Params, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) int32, or (B, S, n_codebooks) for audio codes
    (musicgen: the frame embedding is the sum over codebook embeddings)."""
    if cfg.n_codebooks and tokens.ndim == 3:
        offsets = (jnp.arange(cfg.n_codebooks, dtype=tokens.dtype) * cfg.vocab)
        emb = jnp.take(p["table"], tokens + offsets[None, None, :], axis=0)
        return jnp.sum(emb, axis=2)
    return jnp.take(p["table"], tokens, axis=0)


def init_lm_head(rng, cfg: ModelConfig) -> Params:
    if cfg.tie_embeddings:
        return {}
    dt = cdtype(cfg)
    n_heads = max(1, cfg.n_codebooks)
    return {"w": _dense_init(rng, (cfg.d_model, n_heads * cfg.vocab_padded),
                             scale=0.02, dtype=dt)}


def apply_lm_head(cfg: ModelConfig, p: Params, x: jax.Array,
                  embed_params: Params | None = None) -> jax.Array:
    """Logits over the PADDED vocab (multiple of ``pad_vocab_to`` so they
    shard over any TP width); padded slots are masked to -inf — they never
    win argmax and contribute ~0 to the softmax normalizer."""
    if cfg.tie_embeddings:
        w = embed_params["table"].T
    else:
        w = p["w"]
    Vp = cfg.vocab_padded
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    if cfg.n_codebooks:
        B, S = x.shape[:2]
        logits = logits.reshape(B, S, cfg.n_codebooks, Vp)
    if Vp > cfg.vocab:
        slot = jax.lax.broadcasted_iota(jnp.int32, (Vp,), 0)
        logits = jnp.where(slot < cfg.vocab, logits, -1e30)
    return logits
