"""Shared conv primitives for the paper's CNN models (inference path).

BatchNorm is folded into per-channel (scale, bias) applied after the conv
— the deployed TFLite-int8 graph form the paper benchmarks. NHWC layout.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def init_conv(rng, k: int, c_in: int, c_out: int, depthwise: bool = False) -> dict:
    if depthwise:
        shape = (k, k, 1, c_in)  # HWIO with feature_group_count = c_in
        fan_in = k * k
    else:
        shape = (k, k, c_in, c_out)
        fan_in = k * k * c_in
    w = jax.random.normal(rng, shape, dtype=jnp.float32) * math.sqrt(2.0 / fan_in)
    return {"w": w, "scale": jnp.ones((c_out if not depthwise else c_in,)),
            "bias": jnp.zeros((c_out if not depthwise else c_in,))}


def conv2d(p: dict, x: jax.Array, stride: int = 1, depthwise: bool = False,
           act: str = "relu6") -> jax.Array:
    k = p["w"].shape[0]
    pad = ((k - 1) // 2, k // 2)
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=(pad, pad),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=(x.shape[-1] if depthwise else 1),
    )
    y = y * p["scale"] + p["bias"]
    if act == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    elif act == "relu":
        y = jax.nn.relu(y)
    return y


def max_pool(x: jax.Array, k: int = 3, stride: int = 2) -> jax.Array:
    pad = ((k - 1) // 2, k // 2)
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1),
        (pad, pad) and ((0, 0), pad, pad, (0, 0)))


def global_avg_pool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


def init_dense(rng, d_in: int, d_out: int) -> dict:
    w = jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) / math.sqrt(d_in)
    return {"w": w, "b": jnp.zeros((d_out,))}


def dense(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]
