"""ResNet50 (paper model 2) as a sequential layer-list model.

Layer names align 1:1 with :func:`repro.models.graph.resnet50_graph`.
Bottleneck residuals are carried explicitly; the downsample projection of
each stage's first block is folded into its ``_3`` unit (as in the cost
table)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cnn_common import (
    conv2d,
    dense,
    global_avg_pool,
    init_conv,
    init_dense,
    max_pool,
)
from repro.models.graph import _R50_STAGES


class ResNet50:
    def __init__(self, image_size: int = 224, num_classes: int = 1000):
        self.image_size = image_size
        self.num_classes = num_classes
        self._build()

    def _build(self):
        specs: list[tuple[str, str, dict]] = []
        specs.append(("conv1", "conv", dict(k=7, c_in=3, c_out=64, stride=2, act="relu")))
        specs.append(("pool1", "maxpool", {}))
        c_in = 64
        for stage, (c_mid, c_out, n, s) in enumerate(_R50_STAGES, start=2):
            for i in range(n):
                stride = s if i == 0 else 1
                name = f"conv{stage}_block{i + 1}"
                specs.append((f"{name}_1", "b1",
                              dict(k=1, c_in=c_in, c_out=c_mid, stride=1)))
                specs.append((f"{name}_2", "b2",
                              dict(k=3, c_in=c_mid, c_out=c_mid, stride=stride)))
                specs.append((f"{name}_3", "b3",
                              dict(k=1, c_in=c_mid, c_out=c_out,
                                   proj=(i == 0), proj_c_in=c_in, stride=stride)))
                c_in = c_out
        specs.append(("avg_pool", "pool", {}))
        specs.append(("fc", "dense", dict(d_in=c_in, d_out=self.num_classes)))
        self._specs = specs
        self.layer_names = [name for name, _, _ in specs]

    def init(self, rng: jax.Array) -> dict:
        params = {}
        for i, (name, kind, m) in enumerate(self._specs):
            r = jax.random.fold_in(rng, i)
            if kind in ("conv", "b1", "b2"):
                params[name] = init_conv(r, m["k"], m["c_in"], m["c_out"])
            elif kind == "b3":
                p = {"main": init_conv(r, m["k"], m["c_in"], m["c_out"])}
                if m["proj"]:
                    p["proj"] = init_conv(jax.random.fold_in(r, 1), 1,
                                          m["proj_c_in"], m["c_out"])
                params[name] = p
            elif kind == "dense":
                params[name] = init_dense(r, m["d_in"], m["d_out"])
            else:
                params[name] = {}
        return params

    def apply_layer(self, name: str, p: dict, carry):
        kind, m = next((k, mm) for n, k, mm in self._specs if n == name)
        if isinstance(carry, jax.Array):
            carry = {"h": carry}
        h = carry["h"]
        if kind == "conv":
            return {"h": conv2d(p, h, stride=m["stride"], act=m.get("act", "relu"))}
        if kind == "maxpool":
            return {"h": max_pool(h, 3, 2)}
        if kind == "b1":
            return {"h": conv2d(p, h, stride=1, act="relu"), "res": h}
        if kind == "b2":
            return {"h": conv2d(p, h, stride=m["stride"], act="relu"),
                    "res": carry["res"]}
        if kind == "b3":
            y = conv2d(p["main"], h, stride=1, act="none")
            res = carry["res"]
            if m["proj"]:
                res = conv2d(p["proj"], res, stride=m["stride"], act="none")
            return {"h": jax.nn.relu(y + res)}
        if kind == "pool":
            return {"h": global_avg_pool(h)}
        if kind == "dense":
            return {"h": dense(p, h)}
        raise ValueError(kind)

    def input_shape(self, batch: int = 1):
        return (batch, self.image_size, self.image_size, 3)
