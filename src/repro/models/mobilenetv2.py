"""MobileNet-V2 (paper model 1) as a sequential layer-list model.

Layer names align 1:1 with :func:`repro.models.graph.mobilenet_v2_graph`
so the split executor, the cost model, and the real forward pass share the
same chain indices — including the paper's split points ``block_2_expand``,
``block_15_project_BN`` and ``block_16_project_BN``.

Residual skip connections are carried through the chain explicitly: the
carry is ``{"h": main, "res": skip}``. At an intra-block cut the live set
is therefore (main + skip) — the paper's Table II counts only the main
tensor, which matches its 'Part 2 constructs the remaining layers
sequentially' deployment (the cross-cut skip is dropped there); we keep
the skip so split execution stays exactly equal to the unsplit model, and
report the byte-count delta in the benchmark.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cnn_common import (
    conv2d,
    dense,
    global_avg_pool,
    init_conv,
    init_dense,
)
from repro.models.graph import _MBV2_GROUPS, make_divisible


class MobileNetV2:
    def __init__(self, width: float = 0.35, image_size: int = 224,
                 num_classes: int = 1000):
        self.width = width
        self.image_size = image_size
        self.num_classes = num_classes
        self._build()

    def _build(self):
        # (name, kind, dict(meta)) in chain order; mirrors graph.py exactly
        specs: list[tuple[str, str, dict]] = []
        c1 = make_divisible(32 * self.width)
        specs.append(("Conv1", "conv", dict(k=3, c_in=3, c_out=c1, stride=2)))
        c_in = c1
        block_id = 0
        for t, c_base, n, s in _MBV2_GROUPS:
            c_out = make_divisible(c_base * self.width)
            for i in range(n):
                stride = s if i == 0 else 1
                prefix = "expanded_conv" if block_id == 0 else f"block_{block_id}"
                residual = stride == 1 and c_in == c_out
                c_mid = c_in * t
                if t != 1:
                    specs.append((f"{prefix}_expand", "expand",
                                  dict(k=1, c_in=c_in, c_out=c_mid, stride=1,
                                       residual=residual)))
                specs.append((f"{prefix}_depthwise", "dw",
                              dict(k=3, c=c_mid, stride=stride,
                                   residual=residual and t == 1)))
                specs.append((f"{prefix}_project_BN", "project",
                              dict(k=1, c_in=c_mid, c_out=c_out, stride=1,
                                   residual=residual)))
                c_in = c_out
                block_id += 1
        c_last = make_divisible(1280 * max(1.0, self.width))
        specs.append(("Conv_1", "conv", dict(k=1, c_in=c_in, c_out=c_last, stride=1)))
        specs.append(("global_pool", "pool", {}))
        specs.append(("Logits", "dense", dict(d_in=c_last, d_out=self.num_classes)))
        self._specs = specs
        self.layer_names = [name for name, _, _ in specs]

    # -- SequentialModel protocol -------------------------------------------
    def init(self, rng: jax.Array) -> dict:
        params = {}
        for i, (name, kind, m) in enumerate(self._specs):
            r = jax.random.fold_in(rng, i)
            if kind in ("conv", "expand", "project"):
                params[name] = init_conv(r, m["k"], m["c_in"], m["c_out"])
            elif kind == "dw":
                params[name] = init_conv(r, m["k"], m["c"], m["c"], depthwise=True)
            elif kind == "dense":
                params[name] = init_dense(r, m["d_in"], m["d_out"])
            else:
                params[name] = {}
        return params

    def apply_layer(self, name: str, p: dict, carry):
        kind, m = next((k, mm) for n, k, mm in self._specs if n == name)
        if isinstance(carry, jax.Array):  # input image
            carry = {"h": carry}
        h = carry["h"]
        if kind == "conv":
            h = conv2d(p, h, stride=m["stride"])
            return {"h": h}
        if kind == "expand":
            out = {"h": conv2d(p, h, stride=1)}
            if m["residual"]:
                out["res"] = h
            return out
        if kind == "dw":
            out = {"h": conv2d(p, h, stride=m["stride"], depthwise=True)}
            if m.get("residual"):
                out["res"] = h
            elif "res" in carry:
                out["res"] = carry["res"]
            return out
        if kind == "project":
            y = conv2d(p, h, stride=1, act="none")
            if m["residual"]:
                y = y + carry["res"]
            return {"h": y}
        if kind == "pool":
            return {"h": global_avg_pool(h)}
        if kind == "dense":
            return {"h": dense(p, h)}
        raise ValueError(kind)

    def input_shape(self, batch: int = 1):
        return (batch, self.image_size, self.image_size, 3)
