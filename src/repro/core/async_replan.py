"""Async surface replanning — stale-while-revalidate rebuilds.

A :class:`~repro.core.surface.DegradationSurface` covers a precomputed
envelope of link conditions. When an estimate drifts *outside* that
envelope the adaptive manager used to fall back to an exact batched
re-solve on EVERY ``observe()`` — correct, but the solver becomes the
hot loop again at precisely the moment the link is degrading. Rebuilding
the surface synchronously would be worse: a full (protocol ×
packet-time × loss) grid solve stalls the serving loop for the whole
build.

This module makes rebuilds *asynchronous* (stale-while-revalidate):

* :class:`SurfaceRebuilder` — a generation-versioned rebuild queue.
  Out-of-envelope estimates ``request()`` a rebuild re-centered on the
  drifted state (:func:`recentered_axes`); the build runs
  ``build_surfaces`` on a background executor while ``observe()`` keeps
  answering from the current (stale) surface, with a *bounded*
  exact-single-point fallback for the in-flight window. Triggers are
  debounced/coalesced: any number of drift events while a build is in
  flight queue at most ONE follow-up build, and a shared rebuilder
  batches every requester's fleet size into ONE multi-scenario
  ``build_surfaces`` call per cycle (the all-k solve answers them all).

* **Atomic swap-on-ready** — a completed build is adopted on the
  caller's next ``poll()``: a single reference swap, versioned by
  build generation so a stale build can never replace a newer one.
  Adoption parity is a contract: the adopted surface is the value of
  ``build_surfaces`` for the recorded :class:`RebuildRequest` — the
  SAME call a synchronous rebuild would have made — so async-adopted
  surfaces are node-identical to their synchronous twins
  (``tests/test_async_replan.py`` and the ``async`` section of
  ``benchmarks/surface_replan.py`` assert exact ``==``).

* :class:`ManualExecutor` — a deterministic in-thread executor for
  tests and benchmarks: submitted builds queue until ``run_next()`` /
  ``run_all()``, so "while a rebuild is in flight" is an exact program
  state, not a race. The default executor is a single worker thread.

* **Out-of-process rebuilds** — pass a
  ``concurrent.futures.ProcessPoolExecutor`` as ``executor`` and the
  build leaves the serving process entirely: the request is resolved
  to a serializable :class:`~repro.core.spec.PlanSpec`
  (:meth:`SurfaceRebuilder.spec_for`) that pickles to the worker,
  which runs :func:`repro.core.spec.build_surfaces_from_spec` — the
  SAME planner-tier call every in-process build makes — and ships the
  surface family back. Generation/swap adoption semantics are
  identical to the thread path (the done-callback publishes under the
  same lock), so process-built surfaces are node-identical to their
  in-process twins.

The executor contract (:class:`RebuildExecutor`): ``submit()`` is
REQUIRED, ``shutdown()`` is OPTIONAL — :class:`ManualExecutor` has
none, and :meth:`SurfaceRebuilder.shutdown` must not assume one.
A dead executor (e.g. an already-terminated process pool) makes
``submit`` raise; the rebuilder stashes that error and re-raises it
from the next ``poll()`` like any failed build — the serving loop
keeps answering from the stale surface either way.

Thread model: ``request()``/``poll()`` are called from the serving
thread and take a small lock only on state transitions (a fast
lock-free precheck keeps the steady-state poll at one attribute read);
the build job runs on the executor and publishes results under the
same lock. The lock is REENTRANT because a process-pool done-callback
can fire inline on the submitting thread (future already finished)
while ``_launch_locked`` still holds it. Build errors are stashed and
re-raised from the next ``poll()`` so a failing rebuild surfaces in
the serving loop instead of dying silently on a worker.
"""

from __future__ import annotations

import threading
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Mapping, Protocol, Sequence

from repro.core.latency import LinkProfile, SplitCostModel
from repro.core.surface import (
    DEFAULT_LOSS_GRID,
    DEFAULT_PT_SCALES,
    LOSS_CLAMP,
    DegradationSurface,
    _resolve_axes,
)

__all__ = [
    "ManualExecutor",
    "RebuildExecutor",
    "RebuildFanout",
    "RebuildHandle",
    "RebuildRequest",
    "SurfaceRebuilder",
    "recentered_axes",
]

_StateMap = Mapping[str, tuple[float, float]]


class RebuildExecutor(Protocol):
    """What :class:`SurfaceRebuilder` requires of an ``executor``.

    ``submit(fn, *args)`` is the WHOLE required surface — thread pools,
    process pools, and :class:`ManualExecutor` all provide it. Anything
    else is optional: ``shutdown()`` in particular is NOT part of the
    contract (:class:`ManualExecutor` has none), so the rebuilder's own
    :meth:`~SurfaceRebuilder.shutdown` probes for it and tolerates
    executors that are already terminated. ``submit`` may raise (dead
    pool); the rebuilder treats that as a failed build."""

    def submit(self, fn: Callable, /, *args):  # pragma: no cover - protocol
        ...


class ManualExecutor:
    """Deterministic executor: jobs queue until explicitly run.

    ``submit(fn)`` appends; nothing executes until the *caller* invokes
    :meth:`run_next` / :meth:`run_all` (on the calling thread). This
    makes "a rebuild is in flight" an exact, inspectable program state
    — the async tests and the benchmark's in-flight window use it so
    no test ever sleeps or races."""

    def __init__(self):
        self.jobs: list[Callable[[], None]] = []
        self.submitted = 0
        self.executed = 0

    def submit(self, fn: Callable[[], None]) -> None:
        self.jobs.append(fn)
        self.submitted += 1

    def pending(self) -> int:
        """Jobs submitted but not yet run (the in-flight count)."""
        return len(self.jobs)

    def run_next(self) -> bool:
        """Run the oldest pending job; False if none were pending."""
        if not self.jobs:
            return False
        fn = self.jobs.pop(0)
        fn()
        self.executed += 1
        return True

    def run_all(self) -> int:
        """Drain the queue (including jobs enqueued by running jobs)."""
        n = 0
        while self.run_next():
            n += 1
        return n


def recentered_axes(
    protocols: Mapping[str, LinkProfile],
    states: _StateMap | Sequence[_StateMap],
    pt_scale: Sequence[float] = DEFAULT_PT_SCALES,
    loss_p: Sequence[float | None] | None = DEFAULT_LOSS_GRID,
    pt_pad: Sequence[float] = (0.25, 0.5, 1.0, 2.0, 4.0),
    loss_pad: float = 2.0,
) -> tuple[tuple[float, ...], tuple[float | None, ...]]:
    """Surface axes re-centered on drifted estimator states.

    The base grid (``pt_scale`` × ``loss_p``, the manager's configured
    envelope) is EXTENDED — never replaced — with nodes around each
    drifted state: per drifted protocol the packet-time ratio
    ``estimate / nominal`` times each ``pt_pad`` factor joins the scale
    axis, and the drifted loss (plus a ``loss_pad`` headroom multiple,
    capped at the 0.9 link clamp) joins the loss axis. Because
    ``max(pt_pad) >= 1`` and the exact drifted loss is included, every
    requested state is inside the rebuilt surface's envelope, so the
    first post-swap lookup is a surface hit.

    ``states`` is one ``{protocol: (packet_time_s, loss)}`` mapping or a
    sequence of them (a shared rebuilder merges every requester's
    states into one axis set). ``None`` entries in ``loss_p`` keep the
    per-protocol base-loss convention of
    :func:`~repro.core.surface.build_surfaces`."""
    if max(pt_pad) < 1.0:
        raise ValueError(f"max(pt_pad) must be >= 1 so the drifted state "
                         f"lands inside the rebuilt envelope (got {pt_pad})")
    state_maps: Sequence[_StateMap]
    if isinstance(states, Mapping):
        state_maps = (states,)
    else:
        state_maps = tuple(states)
    scales = {float(s) for s in pt_scale}
    has_none = False
    losses: set[float] = set()
    for lp in (loss_p if loss_p is not None else (None,)):
        if lp is None:
            has_none = True
        else:
            losses.add(float(lp))
    for st in state_maps:
        for name, (pt, lp) in st.items():
            base = protocols[name]
            ratio = pt / base.packet_time_s()
            scales.update(ratio * f for f in pt_pad)
            losses.add(min(float(lp), LOSS_CLAMP))
            if loss_pad and lp > 0:
                losses.add(min(float(lp) * loss_pad, LOSS_CLAMP))
    pts = tuple(sorted(s for s in scales if s > 0))
    loss_axis = (None,) * has_none + tuple(sorted(losses))
    return pts, loss_axis


@dataclass(frozen=True)
class RebuildRequest:
    """One versioned rebuild: WHAT the background build will compute.

    ``generation`` orders adoptions (a completed build is only adopted
    while it is still the newest for its fleet size); ``sizes`` are
    every fleet size batched into this build's single
    ``build_surfaces`` call; ``pt_scale``/``loss_p`` are the re-centered
    axes. ``envelopes`` caches each protocol's resolved
    (packet-time max, loss min, loss max) so in-flight coverage checks
    never re-derive axes."""

    generation: int
    sizes: tuple[int, ...]
    pt_scale: tuple[float, ...]
    loss_p: tuple[float | None, ...]
    envelopes: Mapping[str, tuple[float, float, float]] = field(hash=False)

    def covers(self, states: _StateMap) -> bool:
        """Will the surface being built contain ``states``? Below-floor
        packet times and above-``LOSS_CLAMP`` losses clamp inside,
        exactly like :meth:`DegradationSurface.in_envelope
        <repro.core.surface.DegradationSurface.in_envelope>`."""
        for name, (pt, lp) in states.items():
            pt_hi, lo_lo, lo_hi = self.envelopes[name]
            if pt > pt_hi or not lo_lo <= min(lp, LOSS_CLAMP) <= lo_hi:
                return False
        return True


class SurfaceRebuilder:
    """Generation-versioned background surface rebuilds.

    One rebuilder serves one or many
    :class:`~repro.core.adaptive.AdaptiveSplitManager` instances (a
    fleet shares one). The caller contract is two non-blocking calls
    from the serving loop:

    * ``request(n_devices, states)`` — record that ``states`` left the
      envelope. Requests are QUEUED, not built inline; while a build is
      in flight, any number of further requests coalesce into at most
      one queued follow-up (per-protocol targets merge), and requests
      already covered by the in-flight build's axes are dropped.
    * ``poll(n_devices)`` — launch the queued build if nothing is in
      flight AND the caller's own size is queued (a fleet observing
      round-robin therefore queues every drifted size before the first
      requester polls again: one cycle's requests from EVERY manager
      batch into ONE multi-size ``build_surfaces`` call), and return
      the newest completed surface for ``n_devices`` exactly once —
      the atomic swap-on-ready. Returns ``None`` on the (fast,
      lock-free) common path.

    ``executor`` needs only ``submit(fn)`` (see :class:`RebuildExecutor`
    — ``shutdown()`` is optional and probed for, never assumed): the
    default is a single-worker thread pool; pass a
    :class:`ManualExecutor` for deterministic tests, or a
    ``ProcessPoolExecutor`` to move builds out of the serving process —
    the request then travels as a pickled
    :class:`~repro.core.spec.PlanSpec` (:meth:`spec_for`) and the
    worker runs :func:`~repro.core.spec.build_surfaces_from_spec`.
    Constructor kwargs mirror
    :func:`~repro.core.surface.build_surfaces` (``pt_scale``/``loss_p``
    are the BASE axes every rebuild extends; ``backend`` etc. pass
    through), so an adopted surface is node-identical to the same
    ``build_surfaces`` call made synchronously — :meth:`build_sync`
    replays exactly that call for parity checks."""

    def __init__(
        self,
        cost_model: SplitCostModel,
        protocols: Mapping[str, LinkProfile],
        solver: str = "batched_beam",
        backend: str = "numpy",
        beam_width: int = 8,
        chunk_candidates: Sequence[int] | None = None,
        pt_scale: Sequence[float] = DEFAULT_PT_SCALES,
        loss_p: Sequence[float | None] | None = DEFAULT_LOSS_GRID,
        pt_pad: Sequence[float] = (0.25, 0.5, 1.0, 2.0, 4.0),
        loss_pad: float = 2.0,
        executor=None,
        max_queued_states: int = 8,
        energy_budget: float | None = None,
        variants=None,
        accuracy_floor: float | None = None,
    ):
        self.cost_model = cost_model
        self.protocols = dict(protocols)
        self.solver = solver
        self.backend = backend
        self.beam_width = beam_width
        self.chunk_candidates = chunk_candidates
        self.energy_budget = energy_budget
        # bottleneck-variant bank + accuracy floor: rebuilt surfaces keep
        # deciding (split, variant) jointly, like the surface they replace
        self.variants = None if variants is None else tuple(variants)
        self.accuracy_floor = accuracy_floor
        self.pt_scale = tuple(pt_scale)
        self.loss_p = None if loss_p is None else tuple(loss_p)
        self.pt_pad = tuple(pt_pad)
        self.loss_pad = loss_pad
        self._executor = executor
        self._own_executor = False
        self._closed = False
        # REENTRANT: a process-pool done-callback runs inline on the
        # submitting thread when the future already finished, i.e.
        # while _launch_locked still holds this lock
        self._lock = threading.RLock()
        self.max_queued_states = max_queued_states
        # per fleet size: a bounded LIST of drifted state maps (one per
        # distinct requester this cycle) — a single merged dict lost all
        # but the last requester's target, so a fleet of sessions drifting
        # to different points rebuilt a surface centered on only one of
        # them. Overflow past max_queued_states merges into the last
        # entry by per-protocol max (the envelope-dominant direction),
        # bounding the rebuilt grid size.
        self._queued: dict[int, list[dict[str, tuple[float, float]]]] = {}
        self._inflight: RebuildRequest | None = None
        self._results: dict[int, tuple[int, DegradationSurface]] = {}
        self._adopted_gen: dict[int, int] = {}
        self._error: BaseException | None = None
        # lock-free precheck for poll(): True only when poll might have
        # work (queued build to launch, result to adopt, error to raise)
        self._maybe_actionable = False
        self.generation = 0
        self.builds_started = 0
        self.builds_completed = 0
        self.requests = 0
        self.requests_coalesced = 0
        self.last_request: RebuildRequest | None = None

    # -- serving-loop API --------------------------------------------------
    def request(self, n_devices: int, states: _StateMap) -> str:
        """Record a drift-triggered rebuild for fleet size ``n_devices``
        re-centered on ``states``. Never builds inline. Returns the
        disposition: ``"queued"`` (new queue entry — the next ``poll``
        launches it), ``"coalesced"`` (merged into an existing queue
        entry), or ``"inflight"`` (already covered by the build in
        flight)."""
        with self._lock:
            self.requests += 1
            if (self._inflight is not None
                    and n_devices in self._inflight.sizes
                    and self._inflight.covers(states)):
                self.requests_coalesced += 1
                return "inflight"
            pending = self._queued.get(n_devices)
            if pending is not None:
                if len(pending) < self.max_queued_states:
                    pending.append(dict(states))
                else:  # bounded: fold into the last entry, per-protocol max
                    last = pending[-1]
                    for name, (pt, lp) in states.items():
                        pt0, lp0 = last.get(name, (pt, lp))
                        last[name] = (max(pt0, pt), max(lp0, lp))
                self.requests_coalesced += 1
                return "coalesced"
            self._queued[n_devices] = [dict(states)]
            self._maybe_actionable = True
            return "queued"

    def poll(self, n_devices: int) -> DegradationSurface | None:
        """Launch any queued build (if idle) and hand over the newest
        completed surface for ``n_devices`` exactly once. The common
        no-op path is a single attribute read — safe on every
        ``observe()``."""
        got = self.poll_versioned(n_devices)
        return None if got is None else got[1]

    def poll_versioned(
        self, n_devices: int,
    ) -> tuple[int, DegradationSurface] | None:
        """:meth:`poll`, but the handover is ``(generation, surface)`` so
        a redistributing consumer (:class:`RebuildFanout`) can order
        adoptions downstream. Same exactly-once / newest-only
        semantics."""
        if not self._maybe_actionable:
            return None
        with self._lock:
            if self._error is not None:
                err, self._error = self._error, None
                self._refresh_actionable_locked()
                raise RuntimeError(
                    "async surface rebuild failed; the serving loop must "
                    "decide whether to keep the stale surface") from err
            # launch only when the CALLER's size is among the queued
            # ones: in a fleet observing round-robin, every drifted
            # manager requests before the first requester polls again,
            # so one cycle's drift coalesces into ONE multi-size build
            if self._inflight is None and n_devices in self._queued:
                self._launch_locked()
            out = None
            got = self._results.get(n_devices)
            if got is not None:
                gen, surf = got
                del self._results[n_devices]
                if gen > self._adopted_gen.get(n_devices, -1):
                    self._adopted_gen[n_devices] = gen
                    out = (gen, surf)
            self._refresh_actionable_locked()
            return out

    def inflight(self) -> RebuildRequest | None:
        """The build currently running (None when idle)."""
        return self._inflight

    def shutdown(self) -> None:
        """Stop rebuilding, TERMINALLY: no further build ever launches
        (queued requests stay queued; completed results remain
        adoptable). Waits for and releases the internally created
        executor; injected executors are left to their owner. The
        executor contract makes ``shutdown`` optional
        (:class:`RebuildExecutor`), so this probes for it and tolerates
        executors that are already terminated — e.g. a process pool
        whose workers died. Idempotent — also the completion barrier
        deterministic thread tests use."""
        with self._lock:
            self._closed = True
            if not self._own_executor:
                return
            ex, self._executor = self._executor, None
            self._own_executor = False
        stop = getattr(ex, "shutdown", None)
        if stop is None:
            return
        try:
            stop(wait=True)
        except Exception:  # already-terminated/broken pool: nothing to stop
            pass

    # -- build machinery ---------------------------------------------------
    def spec_for(self, req: RebuildRequest):
        """The serializable :class:`~repro.core.spec.PlanSpec` a request
        resolves to — the rebuilder config plus the request's
        re-centered axes. This is the value that crosses the process
        boundary in pool mode, and
        :func:`~repro.core.spec.build_surfaces_from_spec` on it is the
        EXACT call every in-process build makes too."""
        from repro.core.spec import surfaces_spec

        return surfaces_spec(
            self.cost_model, self.protocols, req.sizes,
            pt_scale=req.pt_scale, loss_p=req.loss_p,
            solver=self.solver, backend=self.backend,
            beam_width=self.beam_width,
            chunk_candidates=self.chunk_candidates,
            energy_budget=self.energy_budget,
            variants=self.variants,
            accuracy_floor=self.accuracy_floor,
        )

    def build_sync(self, req: RebuildRequest) -> dict[int, DegradationSurface]:
        """The EXACT planner-tier call a request resolves to — shared by
        the background job (thread AND process mode) and by parity
        checks, so an async-adopted surface is node-identical to this
        synchronous value by construction."""
        from repro.core.spec import build_surfaces_from_spec

        return build_surfaces_from_spec(self.spec_for(req))

    def _resolved_envelopes(
        self, pt_scale: tuple[float, ...], loss_p: tuple[float | None, ...],
    ) -> dict[str, tuple[float, float, float]]:
        """Per-protocol (pt max, loss min, loss max) exactly as
        ``build_surfaces`` will resolve the axes — via the SAME
        :func:`repro.core.surface._resolve_axes` helper, so a coverage
        prediction can never drift from what the build produces."""
        env = {}
        for name, base in self.protocols.items():
            pts, losses = _resolve_axes(base, pt_scale, loss_p)
            env[name] = (pts[-1], losses[0], losses[-1])
        return env

    def _launch_locked(self) -> None:
        if self._closed:  # terminal: never resurrect an executor
            return
        sizes = tuple(sorted(self._queued))
        pts, losses = recentered_axes(
            self.protocols,
            tuple(st for lst in self._queued.values() for st in lst),
            pt_scale=self.pt_scale, loss_p=self.loss_p,
            pt_pad=self.pt_pad, loss_pad=self.loss_pad)
        self._queued.clear()
        self.generation += 1
        req = RebuildRequest(
            generation=self.generation, sizes=sizes,
            pt_scale=pts, loss_p=losses,
            envelopes=self._resolved_envelopes(pts, losses))
        self._inflight = req
        self.last_request = req
        self.builds_started += 1
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="surface-rebuild")
            self._own_executor = True
        try:
            if isinstance(self._executor, ProcessPoolExecutor):
                # lambdas (and bound methods over a live rebuilder)
                # don't pickle: ship the spec JSON to the module-level
                # worker and publish from the done-callback in THIS
                # process. The callback may run inline (RLock).
                from repro.core.spec import build_surfaces_from_spec

                fut = self._executor.submit(
                    build_surfaces_from_spec, self.spec_for(req).to_json())
                fut.add_done_callback(
                    lambda f, req=req: self._finish_future(req, f))
            else:
                self._executor.submit(lambda: self._run_build(req))
        except BaseException as e:  # noqa: BLE001 - dead/broken pool
            # submit on a terminated pool raises in the SERVING thread;
            # surface it like any failed build instead of crashing the
            # poll that launched us (the serving loop keeps the stale
            # surface)
            self._fail_locked(e)

    def _run_build(self, req: RebuildRequest) -> None:
        try:
            surfaces = self.build_sync(req)
        except BaseException as e:  # noqa: BLE001 - surfaced via poll()
            with self._lock:
                self._fail_locked(e)
            return
        with self._lock:
            self._publish_locked(req, surfaces)

    def _finish_future(self, req: RebuildRequest, fut) -> None:
        """Done-callback for process-pool builds: publish the shipped
        surfaces (or the worker's exception) with the same
        generation/swap semantics as :meth:`_run_build`."""
        try:
            surfaces = fut.result()
        except BaseException as e:  # noqa: BLE001 - surfaced via poll()
            with self._lock:
                self._fail_locked(e)
            return
        with self._lock:
            self._publish_locked(req, surfaces)

    def _fail_locked(self, err: BaseException) -> None:
        self._error = err
        self._inflight = None
        self._maybe_actionable = True

    def _publish_locked(
        self, req: RebuildRequest,
        surfaces: Mapping[int, DegradationSurface],
    ) -> None:
        for n, surf in surfaces.items():
            self._results[n] = (req.generation, surf)
        self._inflight = None
        self.builds_completed += 1
        self._maybe_actionable = True

    def _refresh_actionable_locked(self) -> None:
        self._maybe_actionable = (
            bool(self._results)
            or self._error is not None
            or (not self._closed and self._inflight is None
                and bool(self._queued))
        )


class RebuildFanout:
    """Multiplexes ONE :class:`SurfaceRebuilder` across MANY consumers.

    ``SurfaceRebuilder.poll`` hands each completed surface out exactly
    once per fleet size — correct for one manager per size, but a
    serving gateway runs THOUSANDS of sessions sharing one rebuilder,
    and every session must see every adopted surface. The fanout is the
    rebuilder's sole consumer (via :meth:`SurfaceRebuilder.poll_versioned`)
    and redistributes: completed builds land in a shared
    ``{n_devices: (generation, surface)}`` map, and each
    :meth:`view` hands out a :class:`RebuildHandle` that adopts from
    that map independently — newest-generation-only per consumer, so a
    stale build can never replace a newer one for ANY session (the PR 5
    generation/swap semantics, per handle).

    ``seq`` bumps whenever the shared map changes; handles use it for a
    lock-free "anything new since I looked?" precheck, keeping the
    per-session steady-state poll at two attribute reads."""

    def __init__(self, rebuilder: SurfaceRebuilder):
        self.rebuilder = rebuilder
        self._lock = threading.Lock()
        self._latest: dict[int, tuple[int, DegradationSurface]] = {}
        self.seq = 0

    def refresh(self, n_devices: int) -> bool:
        """Drain the rebuilder's exactly-once handover for ``n_devices``
        into the shared map (launching any queued build, per the
        ``poll`` contract). True if the map changed."""
        got = self.rebuilder.poll_versioned(n_devices)
        if got is None:
            return False
        gen, surf = got
        with self._lock:
            cur = self._latest.get(n_devices)
            if cur is not None and cur[0] >= gen:
                return False
            self._latest[n_devices] = (gen, surf)
            self.seq += 1
        return True

    def latest(self, n_devices: int) -> tuple[int, DegradationSurface] | None:
        """Newest completed (generation, surface) for ``n_devices``."""
        return self._latest.get(n_devices)

    def view(self) -> "RebuildHandle":
        """A new per-consumer adoption view (one per session)."""
        return RebuildHandle(self)

    def shutdown(self) -> None:
        """Shut the underlying rebuilder down (terminal)."""
        self.rebuilder.shutdown()


class RebuildHandle:
    """One consumer's view of a shared :class:`RebuildFanout`.

    Implements the same duck-typed contract
    :class:`~repro.core.adaptive.AdaptiveSplitManager` drives its
    rebuilder with — ``request(n, states)`` / ``poll(n)`` /
    ``shutdown()`` — so a session manager wires to a handle exactly as
    it would to a private :class:`SurfaceRebuilder`:

    * ``request`` forwards to the shared rebuilder (where the whole
      fleet's drift coalesces into one multi-size build per cycle);
    * ``poll`` adopts from the fanout's shared map at most once per
      generation per fleet size (``adoptions`` records every
      ``(n_devices, generation)`` handover, strictly increasing in
      generation per size — the zero-stale-adoption audit trail);
    * ``shutdown`` is a no-op: the fanout's owner closes the shared
      rebuilder once, not once per session."""

    def __init__(self, fanout: RebuildFanout):
        self._fanout = fanout
        self._seen_seq = -1
        self._adopted_gen: dict[int, int] = {}
        self.adoptions: list[tuple[int, int]] = []

    def request(self, n_devices: int, states: _StateMap) -> str:
        return self._fanout.rebuilder.request(n_devices, states)

    def poll(self, n_devices: int) -> DegradationSurface | None:
        fo = self._fanout
        # lock-free steady state: nothing actionable on the rebuilder
        # AND nothing new in the shared map since this handle looked
        if not fo.rebuilder._maybe_actionable and fo.seq == self._seen_seq:
            return None
        fo.refresh(n_devices)
        self._seen_seq = fo.seq
        got = fo.latest(n_devices)
        if got is None:
            return None
        gen, surf = got
        if gen <= self._adopted_gen.get(n_devices, -1):
            return None
        self._adopted_gen[n_devices] = gen
        self.adoptions.append((n_devices, gen))
        return surf

    def shutdown(self) -> None:
        """No-op — see the class docstring."""
