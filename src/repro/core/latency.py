"""Split-inference latency model — Eqs. (4)-(8) of Jenhani et al. 2025.

The model decomposes end-to-end split-inference latency into

  T_inference(s; r) = T_d(s) + T_tr(s, r)                          (Eq. 8)

where ``s = (s_1, ..., s_{N-1})`` are the split points partitioning an
L-layer model across N devices,

  T_d(s)  = sum_i  T_load_i + T_ta_i + T_infer_i + T_iab_i         (Eq. 4-5)
  T_tr(s) = sum_i  K_{s_i} * ( MTU / (r (1-p)) + T_prop + T_ack )  (Eq. 6-7)
  K_{s_i} = ceil( L_{s_i} / MTU )        (packets for activation bytes)

All times are in **seconds**, all sizes in **bytes**.

The same model is reused for the TPU adaptation: a "device" becomes a
pipeline stage (a slice of a pod) and a "link" becomes an interconnect
tier (ICI intra-pod / DCN inter-pod); see ``profiles.py``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import cached_property
from typing import Callable, Sequence

import numpy as np

INF = float("inf")

#: Recognized cost channels for the stacked multi-channel tensor export
#: (``segment_cost_tensor(n, channels=...)`` and
#: ``sweep.stack_cost_tensors(..., channels=...)``), in canonical order.
COST_CHANNELS = ("latency", "energy")


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkProfile:
    """A (wireless or interconnect) link, per Table I / Eq. 7.

    ``rate_bytes_per_s`` is the serialization rate ``r``; ``loss_p`` the
    packet-loss probability ``p``; ``t_prop_s``/``t_ack_s`` per-packet
    propagation and acknowledgment overheads. ``t_setup_s`` is the one-time
    protocol/session setup and ``t_feedback_s`` the prediction-return delay
    (both enter the RTT, Table IV, not the per-hop Eq. 7).

    ``tx_power_w``/``rx_power_w`` are the radio draw while transmitting /
    receiving; they feed the **energy** cost channel
    (:meth:`SplitCostModel.segment_energy_j`) and default to 0 so
    latency-only profiles are unchanged."""

    name: str
    mtu_bytes: int
    rate_bytes_per_s: float
    loss_p: float = 0.0
    t_prop_s: float = 0.0
    t_ack_s: float = 0.0
    t_setup_s: float = 0.0
    t_feedback_s: float = 0.0
    max_devices: int | None = None
    tx_power_w: float = 0.0
    rx_power_w: float = 0.0

    def packets(self, nbytes: int) -> int:
        """K = ceil(L / MTU) — number of MTU-limited packets (Eq. 7)."""
        if nbytes <= 0:
            return 0
        return math.ceil(nbytes / self.mtu_bytes)

    def packet_time_s(self) -> float:
        """Expected per-packet time: MTU/(r(1-p)) + T_prop + T_ack."""
        return (
            self.mtu_bytes / (self.rate_bytes_per_s * (1.0 - self.loss_p))
            + self.t_prop_s
            + self.t_ack_s
        )

    def transmission_latency_s(self, nbytes: int) -> float:
        """Eq. 7: expected time to move ``nbytes`` across this link."""
        return self.packets(nbytes) * self.packet_time_s()


@dataclass(frozen=True)
class DeviceProfile:
    """A compute device (IoT node or TPU stage), per Eq. 4 and Table III.

    Device-local latency for a segment holding ``param_bytes`` of weights
    and producing ``act_bytes`` of activations:

      T_load  = t_model_load_s + param_bytes * model_load_s_per_byte
      T_ta    = t_tensor_alloc_s + work_bytes * tensor_alloc_s_per_byte
      T_infer = sum over segment layers of per-layer inference time
                (from the ``ModelCostProfile``) * compute_scale
      T_iab   = t_buffer_s + act_bytes * buffer_s_per_byte

    ``mem_limit_bytes``: hard feasibility budget (SRAM+PSRAM on ESP32-S3,
    HBM per chip-group on TPU). Segments exceeding it cost +inf — this is
    what produces the ResNet50 infeasibility fluctuations in Fig. 3.

    ``active_power_w``: compute draw while the device works on its local
    segment; feeds the energy channel (E_local = P_active * T_local) and
    defaults to 0 so latency-only profiles are unchanged."""

    name: str
    compute_scale: float = 1.0
    t_model_load_s: float = 0.0
    model_load_s_per_byte: float = 0.0
    t_input_load_s: float = 0.0
    t_tensor_alloc_s: float = 0.0
    tensor_alloc_s_per_byte: float = 0.0
    t_buffer_s: float = 0.0
    buffer_s_per_byte: float = 0.0
    mem_limit_bytes: float | None = None
    active_power_w: float = 0.0

    def local_latency_s(
        self,
        infer_s: float,
        param_bytes: int,
        act_bytes: int,
        work_bytes: int,
        is_first: bool = False,
    ) -> float:
        """Eq. 4 for one device; +inf if the segment does not fit."""
        if self.mem_limit_bytes is not None and param_bytes + work_bytes > self.mem_limit_bytes:
            return INF
        t = self.t_model_load_s + param_bytes * self.model_load_s_per_byte
        t += self.t_tensor_alloc_s + work_bytes * self.tensor_alloc_s_per_byte
        t += infer_s * self.compute_scale
        t += self.t_buffer_s + act_bytes * self.buffer_s_per_byte
        if is_first:
            t += self.t_input_load_s
        return t


@dataclass(frozen=True)
class ContentionModel:
    """Shared-channel contention: ``transmitters`` devices time-share one
    physical channel, so each sees ``mac_efficiency / transmitters`` of the
    nominal serialization rate (SplitMAC-style TDMA schedule;
    ``mac_efficiency`` < 1 models MAC/backoff overhead of sharing).

    ``transmitters <= 1`` is the uncontended fast path: :meth:`apply`
    returns the link object **unchanged** (the same object, not a copy), so
    a contention group of size 1 is bit-identical to no contention model at
    all — the property suite pins this."""

    transmitters: int = 1
    mac_efficiency: float = 1.0

    def __post_init__(self):
        if self.transmitters < 1:
            raise ValueError(f"transmitters must be >= 1, got {self.transmitters}")
        if not (0.0 < self.mac_efficiency <= 1.0):
            raise ValueError(
                f"mac_efficiency must be in (0, 1], got {self.mac_efficiency}")

    def rate_scale(self) -> float:
        """Fraction of the nominal rate each transmitter sees (1.0 alone)."""
        if self.transmitters <= 1:
            return 1.0
        return self.mac_efficiency / self.transmitters

    def apply(self, link: LinkProfile) -> LinkProfile:
        """Effective link under this schedule; the *same* object at scale 1."""
        scale = self.rate_scale()
        if scale == 1.0:
            return link
        return replace(link, rate_bytes_per_s=link.rate_bytes_per_s * scale)


@dataclass(frozen=True)
class BottleneckVariant:
    """One bottleneck-compression variant of a model (the COMSPLIT /
    NAS-for-split-computing axis): a learned encoder at the cut shrinks
    the activation payload by ``compression_factor`` at the price of
    extra sensor-side compute (the encoder) and a lower
    ``accuracy_proxy``. The decision variable of the planners grows from
    "split point" to "(split point, variant)".

    Semantics at a cut carrying ``nbytes`` of raw activation:

    * the radio moves :meth:`compressed_bytes` ``= ceil(nbytes /
      compression_factor)`` bytes (packetized per Eq. 7 as usual);
    * the transmitting device first spends :meth:`encoder_time_s`
      ``= encoder_t_s + nbytes * encoder_s_per_byte`` running the
      encoder (charged as latency on the cut and as
      ``active_power_w * encoder_time`` on the energy channel);
    * the device-local segment cost is otherwise UNCHANGED — the output
      buffer still holds the raw activation (the encoder reads it), so
      the device-local cost tensor stays variant-independent and the
      fused ``local + TX`` decomposition of the Pallas DP backend
      survives: compression and encoder time ride entirely in the
      per-cut transmission vector.

    ``accuracy_proxy`` is a unitless relative-accuracy column (1.0 for
    the identity variant); it never enters the latency/energy arithmetic
    and exists for Pareto-frontier emission and accuracy-floor masking
    (``min latency s.t. accuracy_proxy >= floor``).

    The identity variant (factor 1, no encoder cost) is the degenerate
    fast path: every consumer treats it exactly like "no variant", so
    single-variant runs are bit-identical to the historical outputs —
    the property suite pins this."""

    name: str = "identity"
    compression_factor: float = 1.0
    encoder_t_s: float = 0.0
    encoder_s_per_byte: float = 0.0
    accuracy_proxy: float = 1.0

    def __post_init__(self):
        if not self.compression_factor >= 1.0:
            raise ValueError(
                f"compression_factor must be >= 1, got {self.compression_factor}")
        if self.encoder_t_s < 0.0 or self.encoder_s_per_byte < 0.0:
            raise ValueError("encoder costs must be >= 0")
        if not self.accuracy_proxy >= 0.0:
            raise ValueError(
                f"accuracy_proxy must be >= 0, got {self.accuracy_proxy}")

    @property
    def is_identity(self) -> bool:
        """True when this variant changes nothing (the degenerate path)."""
        return (self.compression_factor == 1.0
                and self.encoder_t_s == 0.0
                and self.encoder_s_per_byte == 0.0)

    def compressed_bytes(self, nbytes: int) -> int:
        """Payload bytes the radio actually moves for ``nbytes`` of raw
        activation at the cut."""
        if nbytes <= 0 or self.compression_factor == 1.0:
            return int(nbytes)
        return math.ceil(nbytes / self.compression_factor)

    def encoder_time_s(self, nbytes: int) -> float:
        """Sensor-side encoder latency for ``nbytes`` of raw activation
        (0 when nothing crosses the cut)."""
        if nbytes <= 0:
            return 0.0
        return self.encoder_t_s + nbytes * self.encoder_s_per_byte


#: The degenerate no-op variant (factor 1, free encoder, accuracy 1.0).
IDENTITY_VARIANT = BottleneckVariant()


def bottleneck_variant(
    compression_factor: float,
    *,
    encoder_t_s: float = 0.0,
    encoder_s_per_byte: float = 0.0,
    accuracy_drop_per_octave: float = 0.03,
    name: str | None = None,
) -> BottleneckVariant:
    """Build one :class:`BottleneckVariant` from a compression factor.

    The encoder cost and accuracy drop both scale with the bottleneck
    *depth* ``log2(compression_factor)``: each halving of the payload
    adds one encoder stage (``encoder_t_s``/``encoder_s_per_byte`` are
    per-octave rates) and costs ``accuracy_drop_per_octave`` of relative
    accuracy (floored at 0). A factor of 1 yields the exact
    :data:`IDENTITY_VARIANT` semantics (zero encoder cost, accuracy
    1.0)."""
    if not compression_factor >= 1.0:
        raise ValueError(
            f"compression_factor must be >= 1, got {compression_factor}")
    octaves = math.log2(compression_factor)
    return BottleneckVariant(
        name=name or ("identity" if compression_factor == 1.0
                      else f"cx{compression_factor:g}"),
        compression_factor=compression_factor,
        encoder_t_s=encoder_t_s * octaves,
        encoder_s_per_byte=encoder_s_per_byte * octaves,
        accuracy_proxy=max(0.0, 1.0 - accuracy_drop_per_octave * octaves),
    )


def bottleneck_variants(
    compression_factors: Sequence[float], **kwargs
) -> tuple[BottleneckVariant, ...]:
    """A variant bank: one :func:`bottleneck_variant` per factor."""
    return tuple(bottleneck_variant(f, **kwargs) for f in compression_factors)


@dataclass(frozen=True)
class LayerCost:
    """Static per-layer cost record (one node of the sequential chain Eq. 1)."""

    name: str
    t_infer_s: float  # inference time on the reference device (compute_scale=1)
    act_bytes: int  # bytes of the layer's output activation (the tensor crossing a cut here)
    param_bytes: int  # weight bytes attributable to this layer
    work_bytes: int = 0  # peak working-set bytes while executing this layer
    flops: float = 0.0  # arithmetic work (used by analytic/TPU profiles)


@dataclass(frozen=True)
class ModelCostProfile:
    """The per-layer cost table the planner consumes (the paper's 'measured
    per-layer inference and transmission costs')."""

    name: str
    layers: tuple[LayerCost, ...]
    input_bytes: int = 0

    def __post_init__(self):
        object.__setattr__(self, "layers", tuple(self.layers))

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    # -- prefix sums for O(1) segment queries ------------------------------
    def _prefix(self, key: Callable[[LayerCost], float]) -> list[float]:
        cache_name = f"_prefix_{id(key)}"
        out = [0.0]
        for lc in self.layers:
            out.append(out[-1] + key(lc))
        return out

    def segment_infer_s(self, a: int, b: int) -> float:
        """Sum of per-layer inference times for layers [a, b] (1-indexed inclusive)."""
        return sum(lc.t_infer_s for lc in self.layers[a - 1 : b])

    def segment_param_bytes(self, a: int, b: int) -> int:
        return sum(lc.param_bytes for lc in self.layers[a - 1 : b])

    def segment_work_bytes(self, a: int, b: int) -> int:
        seg = self.layers[a - 1 : b]
        return max((lc.work_bytes for lc in seg), default=0)

    def segment_flops(self, a: int, b: int) -> float:
        return sum(lc.flops for lc in self.layers[a - 1 : b])

    def boundary_act_bytes(self, b: int) -> int:
        """Bytes crossing a cut after layer ``b`` (1-indexed); 0 at b=0/L."""
        if b <= 0:
            return self.input_bytes
        if b >= self.num_layers:
            return 0
        return self.layers[b - 1].act_bytes

    # -- dense per-segment arrays (vectorized planning / sweep engine) ------
    @cached_property
    def segment_arrays(self) -> "SegmentArrays":
        """Dense segment-cost arrays; entry ``[a-1, b-1]`` covers layers
        ``[a, b]`` (1-indexed inclusive), lower triangle (a > b) is 0/unused.

        Bit-exactness contract: row-wise ``np.cumsum`` accumulates
        left-to-right exactly like the Python ``sum`` in
        :meth:`segment_infer_s`, so every upper-triangle entry equals the
        scalar query bit-for-bit. This is what lets the batched solvers in
        :mod:`repro.core.sweep` certify against the scalar oracle."""
        L = self.num_layers
        t_infer = np.array([lc.t_infer_s for lc in self.layers], dtype=np.float64)
        p_bytes = np.array([lc.param_bytes for lc in self.layers], dtype=np.int64)
        w_bytes = np.array([lc.work_bytes for lc in self.layers], dtype=np.int64)
        flops = np.array([lc.flops for lc in self.layers], dtype=np.float64)

        infer = np.zeros((L, L), dtype=np.float64)
        param = np.zeros((L, L), dtype=np.int64)
        work = np.zeros((L, L), dtype=np.int64)
        fl = np.zeros((L, L), dtype=np.float64)
        for a in range(L):
            infer[a, a:] = np.cumsum(t_infer[a:])
            param[a, a:] = np.cumsum(p_bytes[a:])
            work[a, a:] = np.maximum.accumulate(w_bytes[a:])
            fl[a, a:] = np.cumsum(flops[a:])

        boundary = np.zeros(L + 1, dtype=np.int64)
        boundary[0] = self.input_bytes
        if L > 1:
            boundary[1:L] = np.array(
                [lc.act_bytes for lc in self.layers[: L - 1]], dtype=np.int64
            )
        return SegmentArrays(
            infer_s=infer, param_bytes=param, work_bytes=work, flops=fl,
            boundary_act_bytes=boundary,
        )


@dataclass(frozen=True)
class SegmentArrays:
    """Dense 0-indexed segment arrays exported by
    :attr:`ModelCostProfile.segment_arrays` (see its docstring for the
    indexing and bit-exactness contract)."""

    infer_s: np.ndarray  # (L, L) float64, [a-1, b-1] = sum of t_infer over [a, b]
    param_bytes: np.ndarray  # (L, L) int64
    work_bytes: np.ndarray  # (L, L) int64 (max over the segment)
    flops: np.ndarray  # (L, L) float64
    boundary_act_bytes: np.ndarray  # (L+1,) int64; [b] = bytes crossing the cut after layer b


# ---------------------------------------------------------------------------
# Segment and end-to-end cost (Eq. 8 and CostSegment of Alg. 1-3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SplitCostModel:
    """Binds a ``ModelCostProfile`` to device and link profiles and exposes
    ``CostSegment(a, b, k)`` (Alg. 1-3) and the end-to-end objective (Eq. 8).

    ``objective``:
      * ``"sum"``        — paper-faithful Eq. 5: total latency is the sum of
                           all device-local and transmission latencies
                           (single request traversing the chain).
      * ``"bottleneck"`` — steady-state pipeline throughput: the slowest
                           stage (compute+transmit) bounds the system; used
                           by the TPU pipeline planner.

    ``contention``: optional shared-channel schedule; when set, every
    transmission price (latency *and* energy) uses
    :attr:`effective_link` — the nominal link with its rate scaled by
    :meth:`ContentionModel.rate_scale`. ``None`` (and a group of size 1)
    is bit-identical to the historical uncontended path.

    ``variant``: optional :class:`BottleneckVariant`. When set, every
    cut prices the *compressed* payload (airtime at
    :meth:`BottleneckVariant.compressed_bytes`) plus the sensor-side
    encoder time; the energy channel adds ``active_power_w *
    encoder_time`` on the transmitting device and radio airtimes shrink
    with the payload. Device-local segment costs are untouched (the
    output buffer holds the raw activation the encoder reads), so
    :meth:`local_cost_tensor` is variant-independent and the sweep
    engine's fused ``local + TX`` decomposition survives. ``None`` and
    the identity variant are bit-identical to the historical path.
    """

    profile: ModelCostProfile
    devices: Sequence[DeviceProfile]
    link: LinkProfile
    objective: str = "sum"
    include_setup: bool = False  # add per-hop link setup into segment costs
    contention: ContentionModel | None = None
    variant: BottleneckVariant | None = None

    def __post_init__(self):
        if self.objective not in ("sum", "bottleneck"):
            raise ValueError(f"unknown objective {self.objective!r}")

    @property
    def effective_link(self) -> LinkProfile:
        """The link every transmission price sees (contention applied).

        With ``contention=None`` (or a size-1 group) this is ``self.link``
        itself — the identical object — so the default path is bit-exact."""
        if self.contention is None:
            return self.link
        return self.contention.apply(self.link)

    @property
    def _active_variant(self) -> BottleneckVariant | None:
        """The variant when it changes anything; None for the identity
        (so every degenerate path takes the exact historical code)."""
        v = self.variant
        if v is None or v.is_identity:
            return None
        return v

    def cut_payload_bytes(self, b: int) -> int:
        """Bytes actually crossing the cut after layer ``b`` — the
        variant-compressed payload (raw boundary bytes without one)."""
        act = self.profile.boundary_act_bytes(b)
        v = self._active_variant
        return act if v is None else v.compressed_bytes(act)

    def cut_cost_s(self, b: int) -> float:
        """Latency charged at the cut after layer ``b``, excluding
        per-hop setup: airtime of the (variant-compressed) payload plus
        the variant's encoder time. 0 outside ``1 <= b < L``."""
        if not 1 <= b < self.profile.num_layers:
            return 0.0
        link = self.effective_link
        act = self.profile.boundary_act_bytes(b)
        v = self._active_variant
        if v is None:
            return link.transmission_latency_s(act)
        return (link.transmission_latency_s(v.compressed_bytes(act))
                + v.encoder_time_s(act))

    def device(self, k: int) -> DeviceProfile:
        """Device executing segment k (1-indexed). A single profile may be
        broadcast over any N."""
        if len(self.devices) == 1:
            return self.devices[0]
        return self.devices[k - 1]

    # -- CostSegment(a, b, k): layers [a..b] on device k --------------------
    def segment_cost_s(self, a: int, b: int, k: int, *, n_devices: int | None = None) -> float:
        """Latency contribution of assigning layers [a, b] to device k,
        'including both local inference and transmission costs' (Sec. IV-B).

        Transmission is charged for the activation leaving layer ``b``
        unless ``b == L`` (the prediction return is the link feedback delay,
        charged once in ``end_to_end_s``)."""
        prof = self.profile
        L = prof.num_layers
        if not (1 <= a <= b <= L):
            return INF
        dev = self.device(k)
        local = dev.local_latency_s(
            infer_s=prof.segment_infer_s(a, b),
            param_bytes=prof.segment_param_bytes(a, b),
            act_bytes=prof.boundary_act_bytes(b),
            work_bytes=prof.segment_work_bytes(a, b),
            is_first=(k == 1),
        )
        if local == INF:
            return INF
        tx = 0.0
        if b < L:
            link = self.effective_link
            act = prof.boundary_act_bytes(b)
            v = self._active_variant
            if v is None:
                tx = link.transmission_latency_s(act)
            else:
                tx = link.transmission_latency_s(v.compressed_bytes(act))
            if self.include_setup:
                tx += link.t_setup_s
            if v is not None:
                tx += v.encoder_time_s(act)
        return local + tx

    # -- energy channel: Joules for CostSegment(a, b, k) --------------------
    def segment_energy_j(self, a: int, b: int, k: int, *, n_devices: int | None = None) -> float:
        """Energy (Joules) of assigning layers [a, b] to device k:

          E = P_active * T_local + P_tx * T_tx(out) + P_rx * T_rx(in)

        where T_tx prices the activation leaving layer ``b`` (0 at b = L)
        and T_rx the activation *entering* at the cut after layer ``a - 1``
        (0 for the head device, which loads the input locally). Airtime
        uses the contention-scaled :attr:`effective_link`; per-hop setup is
        never charged (it is a latency, not a radio-on interval). +inf
        mirrors :meth:`segment_cost_s` infeasibility exactly."""
        prof = self.profile
        L = prof.num_layers
        if not (1 <= a <= b <= L):
            return INF
        dev = self.device(k)
        local = dev.local_latency_s(
            infer_s=prof.segment_infer_s(a, b),
            param_bytes=prof.segment_param_bytes(a, b),
            act_bytes=prof.boundary_act_bytes(b),
            work_bytes=prof.segment_work_bytes(a, b),
            is_first=(k == 1),
        )
        if local == INF:
            return INF
        link = self.effective_link
        v = self._active_variant
        e = dev.active_power_w * local
        if v is not None and b < L:
            # the transmitting device runs the bottleneck encoder at
            # compute draw before the radio turns on
            e = e + dev.active_power_w * v.encoder_time_s(prof.boundary_act_bytes(b))
        e = e + link.tx_power_w * (
            link.transmission_latency_s(self.cut_payload_bytes(b)) if b < L else 0.0
        )
        e = e + link.rx_power_w * (
            link.transmission_latency_s(self.cut_payload_bytes(a - 1)) if a > 1 else 0.0
        )
        return e

    def energy_segment_fn(self) -> Callable[[int, int, int], float]:
        """The per-segment energy callable consumed by the scalar solvers
        (``energy_fn=`` in :mod:`repro.core.solvers`)."""
        return self.segment_energy_j

    # -- Eq. 8 over a full configuration ------------------------------------
    def end_to_end_s(self, splits: Sequence[int], *, with_overheads: bool = True) -> float:
        """T_inference(s; r) for split points ``splits = (s_1..s_{N-1})``.

        ``with_overheads`` adds the one-time protocol setup and the
        prediction feedback delay (the Table-IV RTT decomposition)."""
        L = self.profile.num_layers
        bounds = [0, *splits, L]
        n = len(bounds) - 1
        for i in range(n):
            if not bounds[i] < bounds[i + 1]:
                return INF
        seg_costs = [
            self.segment_cost_s(bounds[i] + 1, bounds[i + 1], i + 1, n_devices=n)
            for i in range(n)
        ]
        if any(c == INF for c in seg_costs):
            return INF
        if self.objective == "bottleneck":
            total = max(seg_costs)
        else:
            total = sum(seg_costs)
        if with_overheads:
            link = self.effective_link
            total += link.t_setup_s + link.t_feedback_s
        return total

    def cost_segment_fn(self) -> Callable[[int, int, int], float]:
        """The ``CostSegment`` callable consumed by the solvers."""
        return self.segment_cost_s

    # -- dense tensor export (the sweep-engine fast path) --------------------
    def _local_cost_matrix(self, dev: DeviceProfile, is_first: bool) -> np.ndarray:
        """(L, L) float64 of device-local latency for every segment [a, b]
        on ``dev``; +inf where the segment is invalid (a > b) or does not
        fit memory. Mirrors :meth:`DeviceProfile.local_latency_s` operation
        by operation so entries are bit-identical to the scalar path."""
        seg = self.profile.segment_arrays
        L = self.profile.num_layers
        act = seg.boundary_act_bytes[1:]  # [b-1] = bytes leaving layer b (0 at b=L)
        t = dev.t_model_load_s + seg.param_bytes * dev.model_load_s_per_byte
        t = t + (dev.t_tensor_alloc_s + seg.work_bytes * dev.tensor_alloc_s_per_byte)
        t = t + seg.infer_s * dev.compute_scale
        t = t + (dev.t_buffer_s + act[None, :] * dev.buffer_s_per_byte)
        if is_first:
            t = t + dev.t_input_load_s
        invalid = np.tril(np.ones((L, L), dtype=bool), k=-1)  # a > b
        if dev.mem_limit_bytes is not None:
            invalid |= (seg.param_bytes + seg.work_bytes) > dev.mem_limit_bytes
        return np.where(invalid, INF, t)

    def _tx_time_vector(self) -> np.ndarray:
        """(L,) float64 raw expected airtime: ``[b-1]`` = time on the
        (contention-scaled) link for the activation leaving layer ``b``
        (0 at b = L). No setup — this is the radio-on interval shared by
        the latency and energy channels."""
        seg = self.profile.segment_arrays
        link = self.effective_link
        act = seg.boundary_act_bytes[1:].astype(np.float64)
        v = self._active_variant
        if v is not None:
            # same ceil arithmetic as BottleneckVariant.compressed_bytes,
            # so packet counts match the scalar path bit-for-bit
            act = np.where(act > 0, np.ceil(act / v.compression_factor), 0.0)
        packets = np.where(act > 0, np.ceil(act / link.mtu_bytes), 0.0)
        tx = packets * link.packet_time_s()
        tx[-1] = 0.0  # no transmission after the final layer
        return tx

    def _encoder_time_vector(self) -> np.ndarray:
        """(L,) float64; ``[b-1]`` = variant encoder time for the raw
        activation leaving layer ``b`` (all zeros without a variant;
        0 at b = L). Mirrors :meth:`BottleneckVariant.encoder_time_s`."""
        L = self.profile.num_layers
        v = self._active_variant
        if v is None:
            return np.zeros(L, dtype=np.float64)
        act = self.profile.segment_arrays.boundary_act_bytes[1:].astype(np.float64)
        enc = np.where(act > 0, v.encoder_t_s + act * v.encoder_s_per_byte, 0.0)
        enc[-1] = 0.0
        return enc

    def transmission_cost_vector(self) -> np.ndarray:
        """(L,) float64; ``[b-1]`` = link cost charged when cutting after
        layer ``b`` (0 at b = L). Identical arithmetic to
        :meth:`LinkProfile.transmission_latency_s` (+ setup when
        ``include_setup``); with a variant, airtime prices the
        compressed payload and the encoder time is added last, matching
        :meth:`segment_cost_s` operation order."""
        tx = self._tx_time_vector()
        if self.include_setup:
            tx = tx + self.effective_link.t_setup_s  # charged on every cut (b < L)
            tx[-1] = 0.0
        if self._active_variant is not None:
            tx = tx + self._encoder_time_vector()
        return tx

    def local_cost_tensor(self, n_devices: int) -> np.ndarray:
        """(N, L, L) float64 of device-local segment costs, ``[k-1, a-1,
        b-1]`` = local part of ``segment_cost_s(a, b, k)``."""
        L = self.profile.num_layers
        out = np.empty((n_devices, L, L), dtype=np.float64)
        out[0] = self._local_cost_matrix(self.device(1), is_first=True)
        generic: np.ndarray | None = None
        for k in range(2, n_devices + 1):
            if len(self.devices) == 1:
                if generic is None:
                    generic = self._local_cost_matrix(self.devices[0], is_first=False)
                out[k - 1] = generic
            else:
                out[k - 1] = self._local_cost_matrix(self.device(k), is_first=False)
        return out

    def segment_cost_tensor(
        self, n_devices: int, channels: Sequence[str] | None = None
    ) -> np.ndarray:
        """Dense ``C[k-1, a-1, b-1] == segment_cost_s(a, b, k)`` tensor of
        shape (N, L, L), float64, +inf at invalid/infeasible segments.

        Entries are bit-identical to the scalar per-call path — the
        batched solvers in :mod:`repro.core.sweep` consume these tensors
        and certify their results against the scalar oracle.

        ``channels``: optional sequence drawn from :data:`COST_CHANNELS`
        (``"latency"``, ``"energy"``). When given, returns a stacked
        ``C[ch, k-1, a-1, b-1]`` tensor of shape (len(channels), N, L, L);
        each channel slice is bit-identical to the corresponding
        single-channel export (``segment_cost_tensor(n)`` /
        :meth:`energy_cost_tensor`)."""
        if channels is not None:
            return np.stack(
                [self._channel_tensor(ch, n_devices) for ch in channels]
            )
        local = self.local_cost_tensor(n_devices)
        tx = self.transmission_cost_vector()
        return local + tx[None, None, :]

    def energy_cost_tensor(self, n_devices: int) -> np.ndarray:
        """Dense ``E[k-1, a-1, b-1] == segment_energy_j(a, b, k)`` tensor
        of shape (N, L, L) Joules, +inf exactly where the latency tensor is
        +inf. Mirrors :meth:`segment_energy_j` operation by operation
        (power * airtime, tx then rx) so entries are bit-identical to the
        scalar path."""
        L = self.profile.num_layers
        local = self.local_cost_tensor(n_devices)
        power = np.array(
            [self.device(k).active_power_w for k in range(1, n_devices + 1)],
            dtype=np.float64,
        )
        with np.errstate(invalid="ignore"):
            e = np.where(np.isfinite(local), power[:, None, None] * local, INF)
        link = self.effective_link
        if self._active_variant is not None:
            # encoder energy on the transmitting device, in the same
            # position as the scalar path (after P*local, before radio)
            enc = self._encoder_time_vector()
            e = e + power[:, None, None] * enc[None, None, :]
        tx_t = self._tx_time_vector()  # [b-1] = airtime of the cut after b
        rx_t = np.zeros(L, dtype=np.float64)
        rx_t[1:] = tx_t[: L - 1]  # [a-1] = airtime of the cut entering at a
        e = e + (link.tx_power_w * tx_t)[None, None, :]
        e = e + (link.rx_power_w * rx_t)[None, :, None]
        return e

    def _channel_tensor(self, channel: str, n_devices: int) -> np.ndarray:
        if channel == "latency":
            return self.segment_cost_tensor(n_devices)
        if channel == "energy":
            return self.energy_cost_tensor(n_devices)
        raise ValueError(
            f"unknown cost channel {channel!r}; expected one of {COST_CHANNELS}")


# ---------------------------------------------------------------------------
# RTT decomposition (Table III / IV reproduction)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RTTBreakdown:
    setup_s: float
    device_s: tuple[float, ...]
    transmission_s: tuple[float, ...]
    feedback_s: float

    @property
    def rtt_s(self) -> float:
        return self.setup_s + sum(self.device_s) + sum(self.transmission_s) + self.feedback_s


def rtt_breakdown(model: SplitCostModel, splits: Sequence[int]) -> RTTBreakdown:
    """Full RTT decomposition for a split configuration (Tables III-IV)."""
    prof = model.profile
    L = prof.num_layers
    link = model.effective_link
    bounds = [0, *splits, L]
    n = len(bounds) - 1
    dev_times, tx_times = [], []
    for i in range(n):
        a, b, k = bounds[i] + 1, bounds[i + 1], i + 1
        dev = model.device(k)
        dev_times.append(
            dev.local_latency_s(
                infer_s=prof.segment_infer_s(a, b),
                param_bytes=prof.segment_param_bytes(a, b),
                act_bytes=prof.boundary_act_bytes(b),
                work_bytes=prof.segment_work_bytes(a, b),
                is_first=(k == 1),
            )
        )
        if b < L:
            # cut_cost_s prices the variant-compressed payload + encoder
            # (bit-identical to the raw airtime without a variant)
            tx_times.append(model.cut_cost_s(b))
    return RTTBreakdown(
        setup_s=link.t_setup_s,
        device_s=tuple(dev_times),
        transmission_s=tuple(tx_times),
        feedback_s=link.t_feedback_s,
    )


def scale_profile(profile: ModelCostProfile, infer_total_s: float) -> ModelCostProfile:
    """Rescale per-layer inference times so they sum to ``infer_total_s``
    (used to calibrate analytic FLOP-proportional tables to a measured
    end-to-end inference time, Table III)."""
    cur = sum(lc.t_infer_s for lc in profile.layers)
    if cur <= 0:
        raise ValueError("profile has no inference time to scale")
    f = infer_total_s / cur
    return replace(
        profile,
        layers=tuple(replace(lc, t_infer_s=lc.t_infer_s * f) for lc in profile.layers),
    )
