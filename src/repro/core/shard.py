"""Sharded scenario-axis sweeps: one stacked tensor, every local device.

The batched sweep engine prices a fleet's what-if grid in one array
pass — but that pass still lives on one device. Fleet-scale grids
(millions of scenarios; the ROADMAP north star) outgrow a single
accelerator long before they outgrow the DP itself, and the scenario
axis is embarrassingly parallel: scenario ``s``'s recurrence never
reads scenario ``t``. This module partitions exactly that axis:

* :func:`sharded_dp_tables` — the stacked ``C[S, N, L, L]`` tensor is
  padded to a multiple of the shard count, split over a 1-D device
  mesh with ``shard_map`` (``jax.shard_map`` on modern JAX,
  ``jax.experimental.shard_map`` on 0.4/0.5), and each
  shard runs the SAME vmapped ``lax.scan`` DP kernel the single-device
  JAX backend runs (:func:`repro.core.sweep._dp_jax_kernel` — shared
  by construction, so per-scenario arithmetic is identical and results
  are node-identical to ``backend="jax"``). Padding rows are replicas
  of the last real scenario and are dropped before anything reads
  them. ``kernel="pallas"`` swaps in the dense-mode Pallas tile kernel
  (:mod:`repro.core.pallas_dp`) per shard — bit-identical again, so
  the two compose for free.
* :func:`sharded_optimal_dp` — the :class:`~repro.core.sweep.
  BatchedSolverResult` wrapper: the full solver contract (per-scenario
  ``n_devices`` frozen-row subsetting, ``return_all_k``, the shared
  timing scope) over the sharded tables.

Entry points up the stack: ``batched_optimal_dp(backend="sharded")``,
``sweep(grid, backend="sharded")``, ``plan_split_batch(...,
backend="sharded")``, and ``build_surfaces(..., backend="sharded")``
all route here — a later multi-host mesh is a backend swap, not a
rewrite.

CPU testing: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(set BEFORE jax imports) splits the host into 8 XLA devices; the CI
``multi-device`` job and ``tests/test_shard.py`` subprocess tests run
exactly that. With one visible device the sharded path degenerates to
the single-device JAX backend plus a no-op mesh — always safe to call.

Precision follows the active JAX config like the single-device
backend: float32 by default (equal-cost tie-breaks may differ from the
float64 oracle), float64 — with scalar-oracle tie-break parity — when
``jax.config.jax_enable_x64`` is on.

Bottleneck-variant banks ride the same partition: a joint
(split, variant) solve folds the variant axis into the scenario axis
(:func:`repro.core.sweep.solve_variant_bank` reshapes ``(V, S, N, L,
L)`` to ``(V*S, N, L, L)`` variant-major) BEFORE dispatch, so the
shards see an ordinary — just ``V×`` taller — scenario batch and the
per-scenario independence that justifies the mesh is untouched.
"""

from __future__ import annotations

import functools
import time
from typing import Sequence

import numpy as np

from repro.core import sweep as SW
from repro.core.spec import MeshSpec

__all__ = [
    "mesh_from_spec",
    "scenario_shards",
    "sharded_dp_tables",
    "sharded_optimal_dp",
]


def scenario_shards(n_shards: int | None = None) -> int:
    """The shard count a sharded solve will use.

    ``None`` means every local JAX device (1 on a plain CPU host;
    ``--xla_force_host_platform_device_count=D`` makes it ``D``). An
    explicit ``n_shards`` must not exceed the local device count —
    fewer is allowed (e.g. benchmarking weak scaling on a wide host)."""
    import jax

    avail = jax.local_device_count()
    if n_shards is None:
        return avail
    if not 1 <= n_shards <= avail:
        raise ValueError(
            f"n_shards={n_shards} out of range [1, {avail}] "
            f"(local JAX devices: {avail})")
    return int(n_shards)


def _pad_to_multiple(S: int, n_shards: int) -> int:
    """Rows to append so ``S + pad`` divides evenly into ``n_shards``
    equal shards (0 when it already does) — arbitrary scenario counts
    ride a fixed mesh by replica-padding, never by dropping work."""
    return (-S) % n_shards


# jax.distributed.initialize is once-per-process; flipped the first time
# a distributed MeshSpec resolves so repeat solves don't re-initialize.
_DISTRIBUTED_READY = False


def _ensure_distributed(mesh_spec: MeshSpec) -> None:
    """Bring up ``jax.distributed`` from a ``kind="distributed"`` spec.

    A spec with ``coordinator=None`` asserts the environment already
    initialized the runtime (e.g. a multi-host launcher did it before
    importing us); otherwise the spec's coordinator/process fields are
    the ``jax.distributed.initialize`` arguments. Idempotent."""
    global _DISTRIBUTED_READY
    if _DISTRIBUTED_READY:
        return
    if mesh_spec.coordinator is not None:
        import jax

        jax.distributed.initialize(
            coordinator_address=mesh_spec.coordinator,
            num_processes=mesh_spec.num_processes,
            process_id=mesh_spec.process_id,
        )
    _DISTRIBUTED_READY = True


def _resolve_shards(mesh_spec: MeshSpec | None, n_shards: int | None) -> int:
    """Shard count for a solve: explicit ``n_shards`` wins, then the
    spec's ``n_shards``, then every device the spec's mesh can see
    (local devices for ``kind="local"``/no spec, the GLOBAL device list
    for ``kind="distributed"``)."""
    if mesh_spec is None or mesh_spec.kind == "local":
        want = n_shards if n_shards is not None else (
            None if mesh_spec is None else mesh_spec.n_shards)
        return scenario_shards(want)
    _ensure_distributed(mesh_spec)
    import jax

    avail = len(jax.devices())
    want = n_shards if n_shards is not None else mesh_spec.n_shards
    if want is None:
        return avail
    if not 1 <= want <= avail:
        raise ValueError(
            f"n_shards={want} out of range [1, {avail}] "
            f"(global JAX devices: {avail})")
    return int(want)


def mesh_from_spec(mesh_spec: MeshSpec | None = None,
                   n_shards: int | None = None):
    """The 1-D scenario mesh a :class:`~repro.core.spec.MeshSpec`
    describes — THE multi-host seam.

    ``None`` or ``kind="local"`` builds exactly the historical mesh
    (the first ``n_shards`` LOCAL devices), so the single-host default
    is node-identical to the pre-spec sharded path by construction.
    ``kind="distributed"`` initializes ``jax.distributed`` from the
    spec (:func:`_ensure_distributed`) and spans the GLOBAL device
    list — scenario-axis partitioning already pads to any mesh, so
    multi-host is a device-list swap, not a new kernel."""
    import jax
    from jax.sharding import Mesh

    axis = "s" if mesh_spec is None else mesh_spec.axis
    if mesh_spec is None or mesh_spec.kind == "local":
        devices = jax.local_devices()
    else:
        _ensure_distributed(mesh_spec)
        devices = jax.devices()
    if n_shards is not None:
        devices = devices[:n_shards]
    return Mesh(np.array(devices), (axis,))


@functools.lru_cache(maxsize=None)
def _sharded_dp_solver(combine: str, n_shards: int, kernel: str = "jax",
                       block_s: int = 0, interpret: bool = False,
                       mesh_spec: MeshSpec | None = None):
    """Jitted ``shard_map`` wrapper over the shared DP kernel for one
    (combine, shard-count, kernel, mesh) tuple. Cached like the
    single-device solver (:func:`repro.core.sweep._dp_jax_solver`):
    repeat same-shape calls reuse the compiled executable, no retrace
    (:class:`~repro.core.spec.MeshSpec` is frozen/hashable, so it keys
    the cache like any other compile-relevant knob).

    ``kernel="jax"`` maps the vmapped ``lax.scan`` kernel;
    ``kernel="pallas"`` maps the dense-mode Pallas kernel
    (:func:`repro.core.pallas_dp._raw_pallas_fn` — each shard traces
    the exact single-device tile program, so sharded-pallas answers are
    node-identical to single-device pallas, which is node-identical to
    jax). ``block_s``/``interpret`` apply to the pallas kernel only."""
    import jax

    try:  # jax >= 0.6: shard_map's public home
        from jax import shard_map
    except ImportError:  # jax 0.4/0.5 (this container pins 0.4.37)
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    rep_kwargs = {}
    if kernel == "jax":
        fn = SW._dp_jax_kernel(combine)  # the SAME per-scenario math
    elif kernel == "pallas":
        from repro.core import pallas_dp as PD

        fn = PD._raw_pallas_fn("dense", combine, block_s, interpret)
        # pallas_call has no shard_map replication rule; the check is
        # moot anyway — every in/out spec partitions along "s"
        rep_kwargs = {"check_rep": False}
    else:
        raise ValueError(f"unknown shard kernel {kernel!r}; "
                         f"options: ['jax', 'pallas']")
    mesh = mesh_from_spec(mesh_spec, n_shards)
    axis = "s" if mesh_spec is None else mesh_spec.axis
    sharded = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
        **rep_kwargs,
    )
    return jax.jit(sharded)


def sharded_dp_tables(
    C: np.ndarray,
    combine: str = "sum",
    ns: np.ndarray | None = None,
    n_shards: int | None = None,
    kernel: str = "jax",
    block_s: int | None = None,
    interpret: bool | None = None,
    mesh_spec: MeshSpec | None = None,
):
    """(dp_per_k, parents) DP tables with the scenario axis sharded.

    The multi-device twin of :func:`repro.core.sweep._dp_jax` — same
    return contract, same frozen-row ``ns`` semantics, node-identical
    outputs (sharding partitions scenarios across devices; each
    scenario's float operation sequence is untouched). Scenario counts
    that do not divide the shard count are padded with replicas of the
    last scenario (an already-valid input row, so padding introduces no
    new inf/nan patterns) and the padding rows are sliced off before
    returning.

    ``kernel="pallas"`` runs the dense-mode Pallas tile kernel inside
    each shard instead of the ``lax.scan`` kernel (the two are
    bit-identical — :mod:`repro.core.pallas_dp`): inputs are +inf-padded
    to the lane tile in ``L`` and replica-padded so every shard holds a
    whole number of scenario blocks; ``block_s``/``interpret`` are the
    pallas knobs (``None`` = the pallas defaults).

    ``mesh_spec`` (a :class:`~repro.core.spec.MeshSpec`) names the
    device mesh: ``None``/local specs keep the historical local mesh
    (node-identical by construction — :func:`mesh_from_spec`);
    ``kind="distributed"`` spans the global multi-host device list."""
    Sn, N, L, _ = C.shape
    shards = _resolve_shards(mesh_spec, n_shards)
    ns_arr = np.full(Sn, N, dtype=np.int64) if ns is None \
        else np.asarray(ns, dtype=np.int64)
    if kernel == "pallas":
        from repro.core import pallas_dp as PD

        if N == 1 or Sn == 0:  # kernel-free cases: no scenario tiles
            return PD.pallas_dp_tables(C, combine, ns=ns_arr,
                                       block_s=block_s, interpret=interpret)
        import jax

        bs, itp = PD._resolve_opts(block_s, interpret)
        dtype = jax.dtypes.canonicalize_dtype(np.float64)
        Lp = PD._pad_lanes(L)
        Sp = Sn + _pad_to_multiple(Sn, shards * bs)  # whole blocks/shard
        Cp = np.full((Sp, N, Lp, Lp), float("inf"), dtype=np.float64)
        Cp[:Sn, :, :L, :L] = C
        if Sp > Sn:
            Cp[Sn:] = Cp[Sn - 1]
        nsp = PD._pad_ns_column(ns_arr, Sn, Sp)
        import jax.numpy as jnp

        solver = _sharded_dp_solver(combine, shards, "pallas", bs, itp,
                                    mesh_spec=mesh_spec)
        dp0, dps, args = solver(jnp.asarray(Cp, dtype=dtype),
                                jnp.asarray(nsp))
        dp0 = np.asarray(dp0)[:Sn, :L]
        dps = np.asarray(dps)[:Sn, :, :L]
        args = np.asarray(args)[:Sn, :, :L]
        return SW._dp_tables_to_numpy(dp0, dps, args, Sn, N, L)
    pad = _pad_to_multiple(Sn, shards)
    if pad:
        C = np.concatenate([C, np.repeat(C[-1:], pad, axis=0)], axis=0)
        ns_arr = np.concatenate([ns_arr, np.repeat(ns_arr[-1:], pad)])
    import jax.numpy as jnp

    solver = _sharded_dp_solver(combine, shards, kernel,
                                mesh_spec=mesh_spec)
    dp0, dps, args = solver(jnp.asarray(C), jnp.asarray(ns_arr))
    dp0, dps, args = np.asarray(dp0), np.asarray(dps), np.asarray(args)
    if pad:
        dp0, dps, args = dp0[:Sn], dps[:Sn], args[:Sn]
    return SW._dp_tables_to_numpy(dp0, dps, args, Sn, N, L)


def sharded_optimal_dp(
    C: np.ndarray,
    combine: str = "sum",
    return_all_k: bool = False,
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    n_shards: int | None = None,
    kernel: str = "jax",
    mesh_spec: MeshSpec | None = None,
):
    """Exact split DP with the scenario axis sharded over local devices.

    The standalone entry point behind
    ``batched_optimal_dp(backend="sharded")`` — same arguments and
    return types as :func:`repro.core.sweep.batched_optimal_dp`, plus
    ``n_shards`` to pin the shard count (default: every local JAX
    device; see :func:`scenario_shards`) and ``kernel`` to pick the
    per-shard tile program (``"jax"`` or ``"pallas"`` — see
    :func:`sharded_dp_tables`; both are node-identical). Per-scenario
    ``n_devices`` and ``return_all_k`` carry the full solver contract;
    results are node-identical to the single-device JAX backend and
    cost-close to the NumPy float64 oracle (bit-identical under an x64
    JAX config)."""
    Sn, N, L, ns = SW._validate_dp_inputs(C, return_all_k, n_devices)
    t0 = time.perf_counter()
    dp_per_k, parents = sharded_dp_tables(C, combine, ns=ns,
                                          n_shards=n_shards, kernel=kernel,
                                          mesh_spec=mesh_spec)
    return SW._results_from_dp_tables(dp_per_k, parents, L, N, Sn,
                                      "sharded", ns, return_all_k, t0)
