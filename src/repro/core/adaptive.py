"""Adaptive split management — the paper's stated future work, built.

  "Future work will build a dynamic, adaptive framework that selects
   protocols, activation chunk sizes, and split points at runtime based
   on network conditions, and device resources."  (Sec. VI)

Three pieces:

* :class:`LinkEstimator` — online EWMA estimation of per-packet time and
  loss from observed hop latencies (the runtime's view of "network
  conditions"); exposes a re-fitted :class:`LinkProfile`.

* :func:`optimize_chunk_size` — per-protocol activation chunk-size
  selection: Eq. 7 is piecewise in ceil(L/chunk), so the best chunk for a
  given split plan is NOT always the MTU when per-packet overhead is
  amortized differently across the plan's cut sizes (the Table II
  1460-vs-1200 inversion).

* :class:`AdaptiveSplitManager` — holds the current plan; every
  ``observe()`` feeds hop measurements to the estimator. The hot loop is
  an O(1) lookup into a precomputed
  :class:`~repro.core.surface.DegradationSurface` (best plan + tuned
  chunk per (packet-time × loss) node, latency bilinearly interpolated
  between nodes) followed by a hysteresis check; an exact Beam-Search
  re-solve runs only when an estimate leaves the surface's precomputed
  envelope (or when no surface is configured). Hysteresis prevents plan
  thrash; every decision is recorded for audit.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Sequence

import numpy as np

from repro.core import solvers as S
from repro.core import sweep as SW
from repro.core.async_replan import SurfaceRebuilder
from repro.core.latency import BottleneckVariant, LinkProfile, SplitCostModel
from repro.core.planner import SplitPlan, _build_plan, plan_split, plans_from_batched
from repro.core.surface import (  # noqa: F401  (optimize_chunk_size re-exported)
    DegradationSurface,
    build_surface,
    build_surfaces,
    optimize_chunk_size,
    refit_link,
)


def _batched_twin(solver: str) -> str:
    """Scalar solver name → its batched twin (identity for names that
    are already batched or have no twin). The SINGLE source of this
    mapping — shared by :meth:`AdaptiveSplitManager._batched_solver_name`
    and :func:`fleet_managers`."""
    return {"beam": "batched_beam", "optimal_dp": "batched_dp",
            "greedy": "batched_greedy"}.get(solver, solver)


class LinkEstimator:
    """EWMA estimate of a link's effective per-packet time and loss.

    ``loss_warmup`` seeds the loss EWMA with that many *virtual prior
    observations*: the effective step size ramps from
    ``alpha/(1+loss_warmup)`` up to ``alpha`` as real observations
    accumulate, so one lucky retry-free hop early in the run cannot
    erase a calibrated loss prior (it used to decay the prior by a full
    ``alpha`` fraction on the very first observation)."""

    def __init__(self, base: LinkProfile, alpha: float = 0.2,
                 loss_warmup: int = 5):
        self.base = base
        self.alpha = alpha
        self.loss_warmup = loss_warmup
        self._packet_time_s = base.packet_time_s()
        self._loss = base.loss_p
        self.n_obs = 0

    @property
    def packet_time_estimate(self) -> float:
        """Current per-packet-time estimate (the surface's first axis)."""
        return self._packet_time_s

    @property
    def loss_estimate(self) -> float:
        """Current loss estimate (the surface's second axis)."""
        return self._loss

    def observe_hop(self, nbytes: int, latency_s: float, retries: int = 0):
        """One observed transfer: ``nbytes`` took ``latency_s`` with
        ``retries`` retransmissions."""
        k = max(1, self.base.packets(nbytes))
        per_packet = latency_s / k
        self._packet_time_s = (1 - self.alpha) * self._packet_time_s \
            + self.alpha * per_packet
        obs_loss = retries / (k + retries) if retries else 0.0
        # warm-up-damped step: the prior counts as `loss_warmup` virtual
        # observations until enough real ones accumulate
        a = self.alpha * (self.n_obs + 1) / (self.n_obs + 1 + self.loss_warmup)
        self._loss = (1 - a) * self._loss + a * obs_loss
        self.n_obs += 1

    def current_profile(self) -> LinkProfile:
        """The base profile re-fitted to the observed per-packet time.
        The serialization term keeps the base rate; the residual moves
        into the ack/overhead term (and the loss estimate). Shared with
        surface construction via :func:`repro.core.surface.refit_link`
        so surface nodes reproduce this mapping bit-for-bit."""
        return refit_link(self.base, self._packet_time_s, self._loss)


@dataclass
class PlanDecision:
    step: int
    protocol: str
    chunk_bytes: int
    splits: tuple[int, ...]
    predicted_latency_s: float
    reason: str
    # index into the manager's bottleneck-variant bank (0 = the bank's
    # first entry, and also the value when no bank is configured)
    variant: int = 0


@dataclass
class AdaptiveSplitManager:
    """Runtime re-planning over (protocol x chunk size x split points).

    ``surface`` controls the ``observe()`` hot path:

    * ``"auto"`` (default) — precompute a
      :class:`~repro.core.surface.DegradationSurface` at construction;
      ``observe()`` is then a surface lookup + hysteresis check, with an
      exact re-solve only when an estimate leaves the surface envelope.
    * a prebuilt :class:`DegradationSurface` — use it as-is.
    * ``None`` — legacy behavior: a full batched re-solve on every
      ``observe()`` (the benchmark baseline).

    ``async_rebuild`` controls what happens when estimates leave the
    surface envelope (requires a surface — raises otherwise):

    * ``False``/``None`` (default) — synchronous behavior: every
      out-of-envelope ``observe()`` blocks on an exact batched re-solve
      and the surface is never rebuilt.
    * ``True`` — stale-while-revalidate: drift enqueues a re-centered
      surface rebuild on a background
      :class:`~repro.core.async_replan.SurfaceRebuilder` (single worker
      thread) while ``observe()`` keeps serving from the stale surface;
      the exact re-solve runs only when the estimate has moved
      materially (``stale_rtol``/``stale_loss_tol``) since the last
      one, bounding the in-flight fallback cost. The rebuilt surface is
      swapped in atomically on a later ``observe()``
      (``surface_swaps`` counts adoptions, ``rebuild_requests`` the
      drift triggers, ``stale_serves`` the observes answered from the
      stale decision while a rebuild was pending).
    * an executor (anything with ``submit(fn)``, e.g.
      :class:`~repro.core.async_replan.ManualExecutor`) — as ``True``
      but builds run on the injected executor (deterministic tests).
    * a prebuilt :class:`~repro.core.async_replan.SurfaceRebuilder` —
      share one rebuilder across managers; a whole fleet's drifted
      scenarios then batch into ONE multi-size solve per cycle (see
      :func:`fleet_managers`).
    """

    cost_model: SplitCostModel  # device/profile side (protocol swapped in)
    protocols: dict[str, LinkProfile]
    n_devices: int
    replan_threshold: float = 0.10  # re-plan when >10% better is available
    solver: str = "beam"
    surface: DegradationSurface | str | None = "auto"
    # extra kwargs for build_surface — including backend="jax"/"sharded"
    # to build the surface on the sharded sweep engine (solver
    # "optimal_dp" only; note the f32 node-parity caveat in
    # docs/architecture.md)
    surface_grid: dict | None = None
    # async out-of-envelope handling: False/None (sync re-solve), True
    # (background thread), an executor with submit(), a shared
    # SurfaceRebuilder, or any rebuilder-like object with
    # request()/poll() (e.g. a RebuildHandle view of a shared fanout) —
    # see the class docstring
    async_rebuild: object | bool | None = None
    # staleness window for the in-flight fallback: the exact re-solve
    # repeats only when the estimate moved more than this since the
    # last one (relative on packet time, absolute on loss)
    stale_rtol: float = 0.10
    stale_loss_tol: float = 0.02
    # how the FIRST decision is made: "resolve" (exact batched solve —
    # the certified default) or "surface" (O(1) lookup on the prebuilt
    # surface at the base estimator state; falls back to the exact
    # solve when no surface hit exists). "surface" is what lets a
    # gateway register thousands of sessions without one full solve
    # per registration.
    initial: str = "resolve"
    # out-of-envelope policy when a rebuilder is attached: "exact"
    # (bounded inline re-solves, the PR 5 behavior) or "stale" (NEVER
    # re-solve inline once a decision exists — request a rebuild and
    # keep serving the stale decision until the swap; the only inline
    # solve left is the bootstrap when no decision exists yet)
    offsurface_fallback: str = "exact"
    # injected link-independent device-local cost tensor (shared across
    # a fleet of same-size managers); None = build lazily per manager
    local_tensor: object | None = None
    # optional per-device Joule cap: every re-plan (batched or scalar)
    # masks over-budget segments to +inf, so decisions minimize latency
    # subject to the budget (see repro.core.sweep.apply_energy_budget)
    energy_budget: float | None = None
    # optional bottleneck-variant bank: every re-plan (surface, batched,
    # or scalar) then decides (split, variant) jointly, the adopted
    # decision records the winning bank index, and all pricing — chunk
    # tuning, hysteresis, the fast path — runs on the winning variant's
    # compressed cut bytes + encoder cost
    variants: Sequence[BottleneckVariant] | None = None
    # with a bank: mask entries whose accuracy_proxy is below the floor
    # before every solve (min latency s.t. accuracy >= floor)
    accuracy_floor: float | None = None
    history: list[PlanDecision] = field(default_factory=list)

    def __post_init__(self):
        L = self.cost_model.profile.num_layers
        if not 1 <= self.n_devices <= L:
            raise ValueError(f"n_devices={self.n_devices} out of range for L={L}")
        if self.variants is not None:
            self.variants = tuple(self.variants)
            if not self.variants:
                raise ValueError("variants bank must not be empty")
        if self.accuracy_floor is not None and self.variants is None:
            raise ValueError("accuracy_floor requires a variants bank")
        self.estimators = {name: LinkEstimator(link)
                           for name, link in self.protocols.items()}
        self._step = 0
        self._local_tensor = None  # built lazily; link-independent
        self._fast = None  # precomputed current-plan latency coefficients
        self.surface_hits = 0
        self.exact_fallbacks = 0
        if self.surface == "auto":
            batched = self._batched_solver_name()
            if batched in SW.BATCHED_SOLVERS:
                from repro.core.spec import PlannerService

                self.surface = PlannerService().build_surfaces(
                    self.surface_spec())[self.n_devices]
            else:
                # scalar-only solvers (first_fit, random_fit, ...) have no
                # batched twin to precompute with: keep the legacy
                # re-solve-per-observe path instead of refusing to start
                self.surface = None
        if self.initial not in ("resolve", "surface"):
            raise ValueError(f"initial must be 'resolve' or 'surface', "
                             f"got {self.initial!r}")
        if self.offsurface_fallback not in ("exact", "stale"):
            raise ValueError(f"offsurface_fallback must be 'exact' or "
                             f"'stale', got {self.offsurface_fallback!r}")
        self.rebuild_requests = 0
        self.surface_swaps = 0
        self.stale_serves = 0
        self._rebuilder = None
        self._fallback_state: dict[str, tuple[float, float]] | None = None
        if self.async_rebuild:
            if self.surface is None:
                raise ValueError(
                    f"async_rebuild needs a degradation surface to "
                    f"revalidate; solver {self.solver!r} has no batched "
                    f"twin (or surface=None was forced)")
            if self._is_rebuilder_like(self.async_rebuild):
                self._rebuilder = self.async_rebuild
            else:
                rebuild_kwargs = dict(self.surface_grid or {})
                rebuild_kwargs.setdefault("energy_budget", self.energy_budget)
                rebuild_kwargs.setdefault("variants", self.variants)
                rebuild_kwargs.setdefault("accuracy_floor", self.accuracy_floor)
                self._rebuilder = SurfaceRebuilder(
                    self.cost_model, self.protocols,
                    solver=self._batched_solver_name(),
                    executor=(None if self.async_rebuild is True
                              else self.async_rebuild),
                    **rebuild_kwargs,
                )
        self.current: PlanDecision | None = None
        if self.initial == "surface" \
                and isinstance(self.surface, DegradationSurface):
            states = {name: (est.packet_time_estimate, est.loss_estimate)
                      for name, est in self.estimators.items()}
            hit = self.surface.best_lookup(states)
            if hit is not None:
                self.surface_hits += 1
                self._adopt(hit.protocol, hit.splits, hit.chunk_bytes,
                            hit.latency_s, "initial [surface]",
                            variant=hit.variant)
        if self.current is None:
            self._replan("initial")

    def surface_spec(self):
        """The :class:`~repro.core.spec.PlanSpec` this manager's
        ``surface="auto"`` build resolves to: the ``surface_grid`` axes
        (defaulted like :func:`~repro.core.surface.build_surface`) plus
        the manager's energy budget, variant bank and accuracy floor.
        ``PlannerService().build_surfaces(spec)[self.n_devices]`` is
        exactly the surface the constructor adopts — the serializable
        form of this manager's planning request."""
        from repro.core.spec import surfaces_spec
        from repro.core.surface import DEFAULT_LOSS_GRID, DEFAULT_PT_SCALES

        grid = dict(self.surface_grid or {})
        grid.setdefault("energy_budget", self.energy_budget)
        grid.setdefault("variants", self.variants)
        grid.setdefault("accuracy_floor", self.accuracy_floor)
        grid.setdefault("pt_scale", DEFAULT_PT_SCALES)
        grid.setdefault("loss_p", DEFAULT_LOSS_GRID)
        return surfaces_spec(
            self.cost_model, self.protocols, (self.n_devices,),
            solver=self._batched_solver_name(), **grid)

    @staticmethod
    def _is_rebuilder_like(obj: object) -> bool:
        """Anything speaking the rebuilder protocol — ``request(n,
        states)`` + ``poll(n)`` — is wired directly (a shared
        :class:`SurfaceRebuilder`, or a
        :class:`~repro.core.async_replan.RebuildHandle` view of a shared
        fanout). Executors only have ``submit``."""
        return callable(getattr(obj, "request", None)) \
            and callable(getattr(obj, "poll", None))

    # -- runtime feedback ------------------------------------------------------
    def observe(self, protocol: str, nbytes: int, latency_s: float,
                retries: int = 0):
        """Feed one observed hop; may trigger a re-plan.

        With a surface this is O(1): per-protocol grid lookups + one
        hysteresis comparison. The solver only runs when an estimate
        leaves the surface envelope (``exact_fallbacks`` counts those) —
        and with ``async_rebuild`` even that is bounded: drift enqueues
        a background rebuild and the in-flight window is served from
        the stale decision (``stale_serves``) unless the estimate keeps
        moving materially."""
        self._step += 1
        self.estimators[protocol].observe_hop(nbytes, latency_s, retries)
        if self._rebuilder is not None:
            self._adopt_ready_surface()
        if self.surface is None:
            self._observe_resolve()
            return
        # single-sourced on the estimate accessors — the SAME view
        # _observe_resolve prices via current_profile(); building states
        # from the raw EWMA fields here once let the envelope lookup and
        # the re-solve disagree during the loss warm-up window
        states = {name: (est.packet_time_estimate, est.loss_estimate)
                  for name, est in self.estimators.items()}
        hit = self.surface.best_lookup(states)
        if hit is None:  # outside the envelope (or nothing feasible on it)
            self._observe_off_surface(states)
            return
        self.surface_hits += 1
        if self._fallback_state is not None:
            self._fallback_state = None  # back inside: next drift re-solves
        if self.current is None:
            self._adopt(hit.protocol, hit.splits, hit.chunk_bytes,
                        hit.latency_s, "initial", variant=hit.variant)
            return
        cur = self.current
        if (hit.protocol == cur.protocol and hit.splits == cur.splits
                and hit.chunk_bytes == cur.chunk_bytes
                and hit.variant == cur.variant):
            # already on the surface's decision: nothing to adopt (and the
            # interpolated latency may disagree with the exact current-plan
            # estimate mid-cell, which must not re-record the same plan)
            return
        pt, lp = states[cur.protocol]
        cur_lat = self._fast_current_latency(pt, lp)
        if hit.latency_s < cur_lat * (1 - self.replan_threshold):
            self._adopt(hit.protocol, hit.splits, hit.chunk_bytes,
                        hit.latency_s,
                        f"estimated {cur_lat:.3f}s -> {hit.latency_s:.3f}s "
                        f"available", variant=hit.variant)

    def _observe_off_surface(self, states: dict[str, tuple[float, float]]):
        """An estimate left the surface envelope. Synchronous mode: exact
        re-solve every time. Async mode (stale-while-revalidate): enqueue
        a re-centered rebuild on material movement and otherwise keep
        serving the current (stale) decision — the exact re-solve runs
        once per material drift step, not once per observe."""
        if self._rebuilder is not None:
            moved = self._states_moved(states)
            if moved:
                self.rebuild_requests += 1
                self._rebuilder.request(self.n_devices, states)
            if self.offsurface_fallback == "stale":
                # never re-solve inline once a decision exists: the
                # drift was requested above (debounced by the staleness
                # window) and the stale decision keeps serving until
                # the rebuilt surface swaps in
                if moved:
                    self._fallback_state = dict(states)
                if self.current is not None:
                    self.stale_serves += 1
                    return
            elif not moved:
                if self.current is not None:
                    self.stale_serves += 1
                    return
        self.exact_fallbacks += 1
        self._observe_resolve(reason_suffix=" [envelope re-solve]")
        self._fallback_state = dict(states)

    def _states_moved(self, states: dict[str, tuple[float, float]]) -> bool:
        """Has any estimate moved materially since the last exact
        fallback re-solve? (The staleness window: within it, the stale
        decision keeps serving.)"""
        prev = self._fallback_state
        if prev is None:
            return True
        for name, (pt, lp) in states.items():
            pt0, lp0 = prev[name]
            if abs(pt - pt0) > self.stale_rtol * pt0 \
                    or abs(lp - lp0) > self.stale_loss_tol:
                return True
        return False

    def _adopt_ready_surface(self):
        """Atomic swap-on-ready: if the rebuilder finished a NEWER
        surface for this fleet size, adopt it (one reference swap) and
        reset the staleness window. A rebuild FAILURE also resets the
        window before propagating — otherwise a settled estimate would
        sit inside the staleness tolerance forever and the failed
        rebuild would never be re-requested."""
        try:
            ready = self._rebuilder.poll(self.n_devices)
        except Exception:
            self._fallback_state = None  # next drifted observe re-requests
            raise
        if ready is not None:
            self.surface = ready
            self.surface_swaps += 1
            self._fallback_state = None

    @property
    def rebuilder(self):
        """The async rebuilder in use (None in synchronous mode). For a
        fleet this is the SHARED rebuilder (or a per-session
        :class:`~repro.core.async_replan.RebuildHandle` view of it) —
        shut the shared one down once when the fleet retires."""
        return self._rebuilder

    def counters(self) -> dict[str, int]:
        """Snapshot of the adaptive-path counters (plain ints — safe to
        aggregate across a fleet)."""
        return {
            "surface_hits": self.surface_hits,
            "exact_fallbacks": self.exact_fallbacks,
            "rebuild_requests": self.rebuild_requests,
            "surface_swaps": self.surface_swaps,
            "stale_serves": self.stale_serves,
            "replans": len(self.history),
        }

    def close(self):
        """Release the background rebuild executor this manager created
        (``async_rebuild=True`` or an injected executor). A SHARED
        rebuilder-like object (a ``SurfaceRebuilder`` or a
        ``RebuildHandle``) is left running — its owner closes it
        (``RebuildHandle.shutdown`` is a no-op anyway). Safe to call
        repeatedly; the manager keeps serving from its current surface
        afterwards."""
        if self._rebuilder is not None \
                and not self._is_rebuilder_like(self.async_rebuild):
            self._rebuilder.shutdown()

    def _observe_resolve(self, reason_suffix: str = ""):
        """The legacy per-observe path: full batched re-solve."""
        best_name, best_splits, best_chunk, best_lat, best_vi = \
            self._best_available()
        if best_name is None:
            return
        if self.current is None:
            self._adopt(best_name, best_splits, best_chunk, best_lat,
                        "initial", variant=best_vi)
            return
        cur_lat = self._current_latency_under_estimates()
        if best_lat < cur_lat * (1 - self.replan_threshold):
            self._adopt(best_name, best_splits, best_chunk, best_lat,
                        f"estimated {cur_lat:.3f}s -> {best_lat:.3f}s "
                        f"available{reason_suffix}", variant=best_vi)

    # -- internals ---------------------------------------------------------------
    def _batched_solver_name(self) -> str:
        return _batched_twin(self.solver)

    def _model_for(self, link: LinkProfile) -> SplitCostModel:
        return replace(self.cost_model, link=link)

    def _ensure_local_tensor(self) -> np.ndarray:
        if self._local_tensor is None:
            if self.local_tensor is not None:  # fleet-shared injection
                self._local_tensor = self.local_tensor
            else:
                self._local_tensor = \
                    self.cost_model.local_cost_tensor(self.n_devices)
        return self._local_tensor

    def _batched_plans(self, links, solver: str) -> list[SplitPlan]:
        """One batched solve across all protocols, reusing the
        link-independent device-local tensor (built once per manager —
        the bank never touches it: a variant reprices only the cut, so
        with ``variants`` the scenario axis just grows variant-major,
        exactly like surface construction, and folds back per link)."""
        local = self._ensure_local_tensor()
        models = [self._model_for(lk) for lk in links]
        bank = self.variants
        if bank is None:
            node_models = models
        else:
            node_models = [replace(m, variant=v) for v in bank for m in models]
        TX = np.stack([m.transmission_cost_vector() for m in node_models])
        if self.accuracy_floor is not None:
            # same TX-row masking as build_surfaces: +inf rows knock the
            # below-floor variant blocks out on every solve path
            acc = np.array([v.accuracy_proxy for v in bank])
            floor_mask = acc < float(self.accuracy_floor)
            if floor_mask.any():
                TX = np.where(
                    np.repeat(floor_mask, len(models))[:, None],
                    float("inf"), TX)
        C = local[None, :, :, :] + TX[:, None, None, :]
        if self.energy_budget is not None:
            E = np.stack([m.energy_cost_tensor(self.n_devices)
                          for m in node_models])
            C = SW.apply_energy_budget(C, E, self.energy_budget)
        combine = "max" if self.cost_model.objective == "bottleneck" else "sum"
        res = SW.solve_batched(C, solver=solver, combine=combine)
        if bank is not None and len(bank) > 1:
            res, _ = SW._fold_variant_axis(res, len(bank), len(models))
        elif bank is not None:
            res = replace(res, variant=np.where(
                res.feasible, 0, -1).astype(np.int64))
        return plans_from_batched(models, res, self.n_devices,
                                  variants=bank)

    def _variant_model(self, model: SplitCostModel,
                       vi: int | None) -> SplitCostModel:
        """``model`` carrying bank entry ``vi`` (unchanged without a
        bank or for sentinel/identity indices — the historical object)."""
        if self.variants is None or vi is None or vi < 0:
            return model
        return replace(model, variant=self.variants[vi])

    def _best_available(self):
        """Re-plan every protocol in ONE batched tensor pass (the sweep
        engine), then tune each winner's activation chunk size. This is
        the exact path the degradation surface precomputes; at surface
        grid nodes both produce identical decisions. With a variant
        bank each plan arrives on its winning variant's model, so the
        cut bytes driving chunk tuning are compressed and the priced
        latency includes the encoder cost."""
        best = (None, (), 0, float("inf"), 0)
        names = list(self.estimators.keys())
        links = [self.estimators[n].current_profile() for n in names]
        solver = self._batched_solver_name()
        if solver in ("batched_beam", "batched_dp", "batched_greedy"):
            plans = self._batched_plans(links, solver)
        else:  # fall back to the scalar oracle path
            plans = [plan_split(self._model_for(lk), self.n_devices,
                                solver=self.solver,
                                energy_budget=self.energy_budget,
                                variants=self.variants,
                                accuracy_floor=self.accuracy_floor)
                     for lk in links]
        for name, link, plan in zip(names, links, plans):
            if not plan.splits and self.n_devices > 1:
                continue
            cuts = [seg.tx_bytes for seg in plan.segments[:-1]]
            chunk, _ = optimize_chunk_size(link, cuts)
            tuned = replace(link, mtu_bytes=chunk)
            vi = plan.variant if plan.variant is not None else 0
            lat = self._variant_model(self._model_for(tuned),
                                      plan.variant).end_to_end_s(plan.splits)
            if lat < best[3]:
                best = (name, plan.splits, chunk, lat, max(vi, 0))
        return best

    def _current_latency_under_estimates(self) -> float:
        cur = self.current
        link = self.estimators[cur.protocol].current_profile()
        tuned = replace(link, mtu_bytes=cur.chunk_bytes)
        return self._variant_model(self._model_for(tuned),
                                   cur.variant).end_to_end_s(cur.splits)

    def _fast_current_latency(self, packet_time_s: float, loss: float) -> float:
        """The current plan's latency under estimator state
        ``(packet_time_s, loss)`` from precomputed coefficients —
        bit-identical to :meth:`_current_latency_under_estimates` (same
        refit clamps, same float operation order as ``end_to_end_s``)
        without rebuilding links, models, or segment sums per observe."""
        f = self._fast
        if f is None:
            return self._current_latency_under_estimates()
        serial = f["mtu"] / (f["rate"] * (1.0 - max(loss, 0.0)))
        t_ack = max(0.0, packet_time_s - serial - f["t_prop"])
        ptime = (f["chunk"] / (f["rate"] * (1.0 - min(loss, 0.9)))
                 + f["t_prop"] + t_ack)
        locs, Ks, encs = f["locs"], f["Ks"], f["encs"]
        segs = []
        for i, loc in enumerate(locs):
            if i < len(Ks):
                tx = Ks[i] * ptime
                if f["include_setup"]:
                    tx += f["setup"]
                if encs is not None:
                    # variant encoder cost: added after setup, matching
                    # SplitCostModel.segment_cost_s float op order
                    tx += encs[i]
                segs.append(loc + tx)
            else:
                segs.append(loc)
        total = max(segs) if f["bottleneck"] else sum(segs)
        total += f["setup"] + f["feedback"]
        return total

    def _prime_fast_path(self):
        """Precompute the current plan's latency coefficients: per-device
        local costs (from the bit-exact local tensor), per-cut packet
        counts under the adopted chunk size (of the adopted variant's
        COMPRESSED payload), and the variant's per-cut encoder times
        (``None`` without an active variant, keeping the historical
        coefficient set byte-for-byte)."""
        cur = self.current
        base = self.protocols[cur.protocol]
        prof = self.cost_model.profile
        vmodel = self._variant_model(self.cost_model, cur.variant)
        v = vmodel._active_variant
        L = prof.num_layers
        local = self._ensure_local_tensor()
        bounds = [0, *cur.splits, L]
        locs = [float(local[i, bounds[i], bounds[i + 1] - 1])
                for i in range(len(bounds) - 1)]
        Ks = []
        encs = None if v is None else []
        for b in cur.splits:
            payload = vmodel.cut_payload_bytes(b)
            Ks.append(math.ceil(payload / cur.chunk_bytes) if payload > 0 else 0)
            if v is not None:
                encs.append(v.encoder_time_s(prof.boundary_act_bytes(b)))
        self._fast = {
            "locs": locs, "Ks": Ks, "encs": encs, "chunk": cur.chunk_bytes,
            "mtu": base.mtu_bytes, "rate": base.rate_bytes_per_s,
            "t_prop": base.t_prop_s, "setup": base.t_setup_s,
            "feedback": base.t_feedback_s,
            "include_setup": self.cost_model.include_setup,
            "bottleneck": self.cost_model.objective == "bottleneck",
        }

    def current_plan(self) -> SplitPlan | None:
        """Materialize the current decision as a planner
        :class:`SplitPlan` (for runtime consumers like the serving
        meter's replan hook)."""
        if self.current is None:
            return None
        cur = self.current
        link = self.estimators[cur.protocol].current_profile()
        tuned = replace(link, mtu_bytes=cur.chunk_bytes)
        model = self._variant_model(self._model_for(tuned), cur.variant)
        result = S.SolverResult(
            solver="surface" if self.surface is not None else self.solver,
            splits=cur.splits,
            cost_s=model.end_to_end_s(cur.splits, with_overheads=False),
            wall_time_s=0.0, nodes_expanded=0,
            variant=None if self.variants is None else cur.variant,
        )
        return _build_plan(model, result, self.n_devices)

    def _adopt(self, name, splits: tuple[int, ...], chunk: int, lat: float,
               reason: str, variant: int = 0):
        self.current = PlanDecision(self._step, name, chunk, tuple(splits),
                                    lat, reason, variant=variant)
        self.history.append(self.current)
        self._prime_fast_path()

    def _replan(self, reason: str):
        name, splits, chunk, lat, vi = self._best_available()
        if name is not None:
            self._adopt(name, splits, chunk, lat, reason, variant=vi)


def fleet_managers(
    cost_model: SplitCostModel,
    protocols: dict[str, LinkProfile],
    n_devices: Sequence[int],
    solver: str = "beam",
    surface_grid: dict | None = None,
    async_rebuild: object | bool | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
    accuracy_floor: float | None = None,
    **manager_kwargs,
) -> dict[int, AdaptiveSplitManager]:
    """Adaptive managers for a heterogeneous fleet of deployments — one
    per fleet size in ``n_devices`` — with ALL their degradation
    surfaces precomputed in ONE batched solver pass.

    Building each manager with ``surface="auto"`` would re-solve the
    whole (protocol × packet-time × loss) grid once per fleet size;
    this constructor instead calls
    :func:`repro.core.surface.build_surfaces` (all-k DP / per-scenario
    fleet-size beam) and hands every manager its prebuilt surface, so a
    mixed-size deployment pays one solve. Device heterogeneity rides
    along: ``cost_model.devices`` may hold per-position profiles (device
    ``k`` of every fleet runs ``cost_model.device(k)``, as in
    :class:`~repro.core.latency.SplitCostModel`).

    ``surface_grid`` passes extra axes/kwargs to ``build_surfaces``
    (like ``AdaptiveSplitManager.surface_grid``); ``manager_kwargs``
    reach each :class:`AdaptiveSplitManager` (e.g.
    ``replan_threshold``). Duplicate sizes collapse; returned dict is
    keyed by fleet size in first-seen order.

    ``async_rebuild`` (``True`` or an executor) gives the WHOLE fleet
    ONE shared :class:`~repro.core.async_replan.SurfaceRebuilder`:
    every manager's drifted scenarios queue on it and each rebuild
    cycle batches all pending fleet sizes into a single multi-size
    ``build_surfaces`` solve (the same all-k pass the initial family
    build uses) — N drifting managers cost one solve, not N.

    ``variants``/``accuracy_floor`` give the whole fleet one
    bottleneck-variant bank: the shared family build, the shared
    rebuilder, and every manager's re-solve path all decide
    (split, variant) jointly from the same bank (the single-source
    guarantee — a fleet can never mix banked surfaces with unbanked
    re-solves)."""
    sizes = tuple(dict.fromkeys(int(n) for n in n_devices))
    batched = _batched_twin(solver)
    if batched not in SW.BATCHED_SOLVERS:
        raise ValueError(
            f"solver {solver!r} has no batched twin to precompute "
            f"surfaces with; options: beam, optimal_dp, greedy, "
            f"{', '.join(sorted(SW.BATCHED_SOLVERS))}")
    grid_kwargs = dict(surface_grid or {})
    grid_kwargs.setdefault("variants", variants)
    grid_kwargs.setdefault("accuracy_floor", accuracy_floor)
    surfaces = build_surfaces(cost_model, protocols, sizes,
                              solver=batched, **grid_kwargs)
    rebuilder: object | bool | None = async_rebuild
    if async_rebuild and not isinstance(async_rebuild, SurfaceRebuilder):
        rebuilder = SurfaceRebuilder(
            cost_model, dict(protocols), solver=batched,
            executor=None if async_rebuild is True else async_rebuild,
            **grid_kwargs,
        )
    return {
        n: AdaptiveSplitManager(
            cost_model=cost_model, protocols=dict(protocols), n_devices=n,
            solver=solver, surface=surfaces[n], async_rebuild=rebuilder,
            variants=grid_kwargs["variants"],
            accuracy_floor=grid_kwargs["accuracy_floor"],
            **manager_kwargs)
        for n in sizes
    }


def surface_parity_report(manager: AdaptiveSplitManager) -> list[str]:
    """Node-by-node oracle-equivalence check (the acceptance contract):
    force the estimator state to every surface grid node and compare the
    exact re-solve decision against the stored node — exact ``==`` on
    splits, tuned chunk, and latency. Empty list = parity. Shared by
    ``benchmarks/surface_replan.py`` and ``tests/test_surface.py`` so
    the two gates can never drift apart. Estimator states are restored
    afterwards."""
    surface = manager.surface
    if not isinstance(surface, DegradationSurface):
        raise ValueError("manager has no degradation surface to certify")
    solver = manager._batched_solver_name()
    mismatches: list[str] = []
    for name, ps in surface.protocols.items():
        est = manager.estimators[name]
        saved = (est._packet_time_s, est._loss)
        for i, pt in enumerate(ps.packet_time_s):
            for j, lp in enumerate(ps.loss_p):
                est._packet_time_s = pt
                est._loss = lp
                link = est.current_profile()
                plan = manager._batched_plans([link], solver)[0]
                node = ps.node(i, j)
                if plan.splits != node.splits:
                    mismatches.append(f"{name}@({pt:.6g},{lp:g}): splits "
                                      f"{plan.splits} vs {node.splits}")
                    continue
                if not plan.splits and manager.n_devices > 1:
                    continue  # infeasible on both sides: nothing to price
                plan_vi = plan.variant if plan.variant is not None else 0
                if max(plan_vi, 0) != node.variant:
                    mismatches.append(f"{name}@({pt:.6g},{lp:g}): variant "
                                      f"{plan_vi} vs {node.variant}")
                    continue
                cuts = [seg.tx_bytes for seg in plan.segments[:-1]]
                chunk, _ = optimize_chunk_size(link, cuts)
                lat = manager._variant_model(
                    manager._model_for(replace(link, mtu_bytes=chunk)),
                    plan.variant).end_to_end_s(plan.splits)
                if chunk != node.chunk_bytes or lat != node.node_latency_s:
                    mismatches.append(
                        f"{name}@({pt:.6g},{lp:g}): chunk/lat ({chunk},{lat}) "
                        f"vs ({node.chunk_bytes},{node.node_latency_s})")
        est._packet_time_s, est._loss = saved
    return mismatches
