"""Adaptive split management — the paper's stated future work, built.

  "Future work will build a dynamic, adaptive framework that selects
   protocols, activation chunk sizes, and split points at runtime based
   on network conditions, and device resources."  (Sec. VI)

Three pieces:

* :class:`LinkEstimator` — online EWMA estimation of per-packet time and
  loss from observed hop latencies (the runtime's view of "network
  conditions"); exposes a re-fitted :class:`LinkProfile`.

* :func:`optimize_chunk_size` — per-protocol activation chunk-size
  selection: Eq. 7 is piecewise in ceil(L/chunk), so the best chunk for a
  given split plan is NOT always the MTU when per-packet overhead is
  amortized differently across the plan's cut sizes (the Table II
  1460-vs-1200 inversion).

* :class:`AdaptiveSplitManager` — holds the current plan; every
  ``observe()`` feeds hop measurements to the estimator; when the
  estimated end-to-end latency of the current plan drifts more than
  ``replan_threshold`` from the best achievable plan (re-solved with Beam
  Search over protocols x chunk sizes), it re-plans. Hysteresis prevents
  plan thrash; every decision is recorded for audit.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Sequence

import numpy as np

from repro.core import sweep as SW
from repro.core.latency import LinkProfile, SplitCostModel
from repro.core.planner import SplitPlan, plan_split, plans_from_batched


class LinkEstimator:
    """EWMA estimate of a link's effective per-packet time and loss."""

    def __init__(self, base: LinkProfile, alpha: float = 0.2):
        self.base = base
        self.alpha = alpha
        self._packet_time_s = base.packet_time_s()
        self._loss = base.loss_p
        self.n_obs = 0

    def observe_hop(self, nbytes: int, latency_s: float, retries: int = 0):
        """One observed transfer: ``nbytes`` took ``latency_s`` with
        ``retries`` retransmissions."""
        k = max(1, self.base.packets(nbytes))
        per_packet = latency_s / k
        self._packet_time_s = (1 - self.alpha) * self._packet_time_s \
            + self.alpha * per_packet
        obs_loss = retries / (k + retries) if retries else 0.0
        self._loss = (1 - self.alpha) * self._loss + self.alpha * obs_loss
        self.n_obs += 1

    def current_profile(self) -> LinkProfile:
        """The base profile re-fitted to the observed per-packet time.
        The serialization term keeps the base rate; the residual moves
        into the ack/overhead term (and the loss estimate)."""
        serial = self.base.mtu_bytes / (
            self.base.rate_bytes_per_s * (1.0 - max(self._loss, 0.0)))
        t_ack = max(0.0, self._packet_time_s - serial - self.base.t_prop_s)
        return replace(self.base, t_ack_s=t_ack, loss_p=min(self._loss, 0.9))


def optimize_chunk_size(
    link: LinkProfile,
    cut_bytes: Sequence[int],
    chunk_candidates: Sequence[int] | None = None,
) -> tuple[int, float]:
    """Best activation chunk size for a set of cut sizes (Eq. 7 summed
    over the plan's hops). Candidates default to divisors-of-MTU-ish
    steps below the protocol MTU."""
    if chunk_candidates is None:
        mtu = link.mtu_bytes
        chunk_candidates = sorted({mtu, mtu * 3 // 4, mtu // 2, 1200, 250}
                                  & set(range(1, mtu + 1))
                                  | {mtu})
        chunk_candidates = [c for c in chunk_candidates if 0 < c <= mtu]
    best = (link.mtu_bytes, float("inf"))
    for chunk in chunk_candidates:
        trial = replace(link, mtu_bytes=chunk)
        total = sum(trial.transmission_latency_s(b) for b in cut_bytes)
        if total < best[1]:
            best = (chunk, total)
    return best


@dataclass
class PlanDecision:
    step: int
    protocol: str
    chunk_bytes: int
    splits: tuple[int, ...]
    predicted_latency_s: float
    reason: str


@dataclass
class AdaptiveSplitManager:
    """Runtime re-planning over (protocol x chunk size x split points)."""

    cost_model: SplitCostModel  # device/profile side (protocol swapped in)
    protocols: dict[str, LinkProfile]
    n_devices: int
    replan_threshold: float = 0.10  # re-plan when >10% better is available
    solver: str = "beam"
    history: list[PlanDecision] = field(default_factory=list)

    def __post_init__(self):
        L = self.cost_model.profile.num_layers
        if not 1 <= self.n_devices <= L:
            raise ValueError(f"n_devices={self.n_devices} out of range for L={L}")
        self.estimators = {name: LinkEstimator(link)
                           for name, link in self.protocols.items()}
        self._step = 0
        self._local_tensor = None  # built lazily; link-independent
        self.current: PlanDecision | None = None
        self._replan("initial")

    # -- runtime feedback ------------------------------------------------------
    def observe(self, protocol: str, nbytes: int, latency_s: float,
                retries: int = 0):
        """Feed one observed hop; may trigger a re-plan."""
        self._step += 1
        self.estimators[protocol].observe_hop(nbytes, latency_s, retries)
        best_name, best_plan, best_chunk, best_lat = self._best_available()
        if self.current is None:
            self._adopt(best_name, best_plan, best_chunk, best_lat, "initial")
            return
        cur_lat = self._current_latency_under_estimates()
        if best_lat < cur_lat * (1 - self.replan_threshold):
            self._adopt(best_name, best_plan, best_chunk, best_lat,
                        f"estimated {cur_lat:.3f}s -> {best_lat:.3f}s available")

    # -- internals ---------------------------------------------------------------
    def _model_for(self, link: LinkProfile) -> SplitCostModel:
        return replace(self.cost_model, link=link)

    def _batched_plans(self, links, solver: str) -> list[SplitPlan]:
        """One batched solve across all protocols, reusing the
        link-independent device-local tensor (built once per manager —
        ``observe()`` is the hot loop, and only the transmission vector
        changes as the estimators drift)."""
        if self._local_tensor is None:
            self._local_tensor = self.cost_model.local_cost_tensor(self.n_devices)
        models = [self._model_for(lk) for lk in links]
        TX = np.stack([m.transmission_cost_vector() for m in models])
        C = self._local_tensor[None, :, :, :] + TX[:, None, None, :]
        combine = "max" if self.cost_model.objective == "bottleneck" else "sum"
        res = SW.solve_batched(C, solver=solver, combine=combine)
        return plans_from_batched(models, res, self.n_devices)

    def _best_available(self):
        """Re-plan every protocol in ONE batched tensor pass (the sweep
        engine), then tune each winner's activation chunk size. The
        per-protocol scalar re-solve this replaces was the hot loop of
        ``observe()`` — fleet controllers call it on every measurement."""
        best = (None, None, 0, float("inf"))
        names = list(self.estimators.keys())
        links = [self.estimators[n].current_profile() for n in names]
        solver = ("batched_beam" if self.solver == "beam"
                  else "batched_dp" if self.solver == "optimal_dp"
                  else self.solver)
        if solver in ("batched_beam", "batched_dp", "batched_greedy"):
            plans = self._batched_plans(links, solver)
        else:  # fall back to the scalar oracle path
            plans = [plan_split(self._model_for(lk), self.n_devices,
                                solver=self.solver) for lk in links]
        for name, link, plan in zip(names, links, plans):
            if not plan.splits and self.n_devices > 1:
                continue
            cuts = [seg.tx_bytes for seg in plan.segments[:-1]]
            chunk, _ = optimize_chunk_size(link, cuts)
            tuned = replace(link, mtu_bytes=chunk)
            lat = self._model_for(tuned).end_to_end_s(plan.splits)
            if lat < best[3]:
                best = (name, plan, chunk, lat)
        return best

    def _current_latency_under_estimates(self) -> float:
        cur = self.current
        link = self.estimators[cur.protocol].current_profile()
        tuned = replace(link, mtu_bytes=cur.chunk_bytes)
        return self._model_for(tuned).end_to_end_s(cur.splits)

    def _adopt(self, name, plan: SplitPlan, chunk: int, lat: float, reason: str):
        self.current = PlanDecision(self._step, name, chunk, plan.splits,
                                    lat, reason)
        self.history.append(self.current)

    def _replan(self, reason: str):
        name, plan, chunk, lat = self._best_available()
        if name is not None:
            self._adopt(name, plan, chunk, lat, reason)
