"""Measured device/link profiles (paper Tables I-IV) and TPU v5e profiles.

Calibration notes (all constants traceable to the paper):

* **Packet counts** follow exactly from activation byte sizes and MTUs
  (Table I): e.g. block_2_expand = 56*56*48 = 150528 B int8 ->
  ceil(150528/1460) = 104 UDP packets (Table II row 2). BLE's MTU is 512 B
  (GATT); Table II's 603-packet BLE row corresponds to app-level 250 B
  chunking — we keep MTU=512 and note the discrepancy in the benchmark.

* **Per-packet times** are least-squares fits of Eq. 7 to the Table II
  block_15_project / block_16_project_BN rows (the block_2_expand rows are
  dominated by ESP32 TCP-buffer stalls the paper itself flags as
  anomalous):
      UDP      0.78 ms/packet   (serialization-only at ~1.87 MB/s)
      TCP      4.71 ms/packet   (UDP serialization + 3.93 ms ack overhead)
      ESP-NOW  3.1455 ms/packet (2 ms @1 Mbps PHY + 1.1455 ms MAC ack)
      BLE     26.6  ms/packet   (2.05 ms @2 Mbps PHY + 24.5 ms conn-interval)

* **Setup / feedback** delays are Table IV verbatim.

* **ESP32-S3 compute** is FLOP-proportional, calibrated piecewise so that
  the block_16_project_BN split reproduces Table III exactly
  (device 1 inference 3053.75 ms, device 2 inference 437 ms).

* **Sanity**: with these constants the model reproduces the Table IV RTTs
  within ~2% for all four protocols (see tests/test_paper_fidelity.py).

TPU v5e constants (the adaptation targets): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI (16 GiB HBM). Inter-pod DCN is modeled as
a lossy, higher-latency link — the direct analogue of the paper's lossy
wireless hop (same Eq. 7, different constants).
"""

from __future__ import annotations

from dataclasses import replace

from typing import Sequence

from repro.core.latency import (
    BottleneckVariant,
    DeviceProfile,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
    bottleneck_variants,
)

# NOTE: repro.models.graph is imported lazily inside the builder functions
# below — models.graph itself depends on repro.core.latency, and importing
# it at module scope would create a cycle through repro.core.__init__.

# ---------------------------------------------------------------------------
# Wireless protocol profiles (Tables I, II, IV)
# ---------------------------------------------------------------------------

UDP = LinkProfile(
    name="udp",
    mtu_bytes=1460,
    rate_bytes_per_s=1460 / 0.78e-3,  # 0.78 ms serialization per packet
    loss_p=0.0,
    t_prop_s=0.0,
    t_ack_s=0.0,
    t_setup_s=2.1349,
    t_feedback_s=0.649e-3,
    max_devices=None,
)

TCP = LinkProfile(
    name="tcp",
    mtu_bytes=1460,
    rate_bytes_per_s=1460 / 0.78e-3,
    loss_p=0.0,
    t_prop_s=0.0,
    t_ack_s=3.93e-3,  # ack + retransmission overhead per packet
    t_setup_s=2.590623,
    t_feedback_s=2.645e-3,
    max_devices=10,
)

ESP_NOW = LinkProfile(
    name="esp_now",
    mtu_bytes=250,
    rate_bytes_per_s=125_000.0,  # 1 Mbps ESP-NOW PHY -> 2 ms per 250 B packet
    loss_p=0.0,
    t_prop_s=0.0,
    t_ack_s=1.1455e-3,  # MAC-level ack, no connection handshake
    t_setup_s=48e-3,
    t_feedback_s=1.115e-3,
    max_devices=20,
)

BLE = LinkProfile(
    name="ble",
    mtu_bytes=512,
    rate_bytes_per_s=250_000.0,  # 2 Mbps PHY -> 2.05 ms serialization
    loss_p=0.0,
    t_prop_s=0.0,
    t_ack_s=24.5e-3,  # connection-interval + GATT overhead per packet
    t_setup_s=6.37852,
    t_feedback_s=24.550e-3,
    max_devices=7,
)

PROTOCOLS: dict[str, LinkProfile] = {p.name: p for p in (UDP, TCP, ESP_NOW, BLE)}

# Chunk-size variants exercised by Table II (bytes-per-chunk column).
TABLE2_CHUNKS: dict[str, tuple[int, ...]] = {
    "udp": (1472, 1460, 1200),
    "tcp": (1472, 1460, 1200),
    "esp_now": (250,),
    "ble": (512,),
}


# ---------------------------------------------------------------------------
# ESP32-S3 device profile (Table III)
# ---------------------------------------------------------------------------

# Piecewise-calibrated inference totals at the block_16_project_BN split.
MBV2_PART1_INFER_S = 3.05375  # device 1 (camera node)
MBV2_PART2_INFER_S = 0.437  # device 2 (classifier node)
MBV2_SPLIT_LAYER = "block_16_project_BN"

ESP32_MEM_LIMIT_BYTES = 8.5e6  # 8 MB PSRAM + 0.5 MB SRAM

# Tensor-arena allocation: affine fit to Table III (43 ms @ 753 KB peak
# arena on device 1, 10 ms @ 68 KB on device 2 — peak in+out activation
# bytes of the largest layer in each segment).
_ALLOC_BASE_S = 6.7113e-3
_ALLOC_PER_BYTE_S = 4.822e-8

ESP32 = DeviceProfile(
    name="esp32_s3",
    compute_scale=1.0,
    t_model_load_s=0.01e-3,  # Table III: 0.0001-0.01 ms (memory-mapped flash)
    model_load_s_per_byte=0.0,
    t_input_load_s=9.8e-3,  # camera frame read, first device only
    t_tensor_alloc_s=_ALLOC_BASE_S,
    tensor_alloc_s_per_byte=_ALLOC_PER_BYTE_S,
    t_buffer_s=0.0,
    buffer_s_per_byte=3.6e-9,  # 0.02 ms for the 5488 B block_16 activation
    mem_limit_bytes=ESP32_MEM_LIMIT_BYTES,
)


def _piecewise_calibrate(
    profile: ModelCostProfile, split_layer: str, t1_s: float, t2_s: float
) -> ModelCostProfile:
    """Rescale per-layer FLOP-proportional times so the two parts of the
    paper's two-device split sum to the measured totals (Table III)."""
    idx = next(i for i, lc in enumerate(profile.layers) if lc.name == split_layer) + 1
    part1 = sum(lc.t_infer_s for lc in profile.layers[:idx])
    part2 = sum(lc.t_infer_s for lc in profile.layers[idx:])
    f1 = t1_s / part1
    f2 = t2_s / part2
    new_layers = tuple(
        replace(lc, t_infer_s=lc.t_infer_s * (f1 if i < idx else f2))
        for i, lc in enumerate(profile.layers)
    )
    return replace(profile, layers=new_layers)


def esp32_flops_per_s() -> float:
    """Effective ESP32-S3 int8 TFLM throughput implied by Table III."""
    from repro.models.graph import mobilenet_v2_graph

    g = mobilenet_v2_graph(width=0.35, image_size=224)
    return g.total_flops / (MBV2_PART1_INFER_S + MBV2_PART2_INFER_S)


def mobilenet_cost_profile() -> ModelCostProfile:
    """MobileNet-V2 0.35 per-layer costs on ESP32-S3, Table-III calibrated."""
    from repro.models.graph import mobilenet_v2_graph

    g = mobilenet_v2_graph(width=0.35, image_size=224)
    prof = g.cost_profile(flops_per_s=esp32_flops_per_s(), act_dtype_bytes=1, param_dtype_bytes=1)
    return _piecewise_calibrate(prof, MBV2_SPLIT_LAYER, MBV2_PART1_INFER_S, MBV2_PART2_INFER_S)


def resnet50_cost_profile() -> ModelCostProfile:
    """ResNet50 per-layer costs on ESP32-S3 (FLOP-proportional at the
    MobileNet-calibrated rate; no per-part measurement exists in the paper)."""
    from repro.models.graph import resnet50_graph

    g = resnet50_graph(image_size=224)
    return g.cost_profile(flops_per_s=esp32_flops_per_s(), act_dtype_bytes=1, param_dtype_bytes=1)


def paper_cost_model(
    model: str = "mobilenet_v2",
    protocol: str = "esp_now",
    objective: str = "sum",
) -> SplitCostModel:
    """The paper's experimental configuration as a ready SplitCostModel."""
    prof = mobilenet_cost_profile() if model.startswith("mobilenet") else resnet50_cost_profile()
    return SplitCostModel(
        profile=prof, devices=(ESP32,), link=PROTOCOLS[protocol], objective=objective
    )


# ---------------------------------------------------------------------------
# Bottleneck variant bank (split-computing feature compression)
# ---------------------------------------------------------------------------

# The split-computing exemplars ship a feature_compression_factor at the
# cut (×4 in the reference client); ×1 keeps the paper's uncompressed
# baseline in the bank so every joint solve can still pick it.
PAPER_COMPRESSION_FACTORS: tuple[float, ...] = (1.0, 2.0, 4.0)


def esp32_variant_bank(
    factors: Sequence[float] = PAPER_COMPRESSION_FACTORS,
    encoder_flops_per_byte: float = 16.0,
    accuracy_drop_per_octave: float = 0.03,
) -> tuple[BottleneckVariant, ...]:
    """Bottleneck-variant bank priced at the ESP32-S3's calibrated rate.

    Each factor becomes a :class:`repro.core.latency.BottleneckVariant`
    whose encoder cost is ``encoder_flops_per_byte`` of extra
    sensor-side work per raw activation byte (a small 1×1-conv
    bottleneck head), converted to seconds with
    :func:`esp32_flops_per_s` — so the latency the joint
    (split, variant) solvers trade against the shrunken payload uses
    the same device calibration as the per-layer costs. Factor 1.0
    yields the identity variant (no encoder, accuracy proxy 1.0): the
    bit-exact uncompressed path."""
    per_byte = encoder_flops_per_byte / esp32_flops_per_s()
    return bottleneck_variants(
        factors,
        encoder_s_per_byte=per_byte,
        accuracy_drop_per_octave=accuracy_drop_per_octave,
    )


# ---------------------------------------------------------------------------
# TPU v5e profiles (hardware-adaptation targets)
# ---------------------------------------------------------------------------

TPU_PEAK_FLOPS = 197e12  # bf16 per chip
TPU_HBM_BW = 819e9  # bytes/s per chip
TPU_HBM_BYTES = 16 * 1024**3
TPU_ICI_BW = 4.9e10  # bytes/s per link (~50 GB/s)
TPU_DCN_BW = 2.5e10  # bytes/s per pod-pair (inter-pod)


def tpu_stage_device(n_chips: int, mem_fraction: float = 0.9) -> DeviceProfile:
    """A pipeline stage made of ``n_chips`` v5e chips.

    Per-layer inference times in TPU cost profiles are produced
    analytically (max of compute and memory roofline terms); the stage
    device then just scales by the chip count."""
    return DeviceProfile(
        name=f"tpu_v5e_x{n_chips}",
        compute_scale=1.0 / n_chips,
        t_model_load_s=0.0,
        t_tensor_alloc_s=0.0,
        mem_limit_bytes=n_chips * TPU_HBM_BYTES * mem_fraction,
    )


ICI = LinkProfile(
    name="ici",
    mtu_bytes=4 * 1024 * 1024,  # collective chunk granularity
    rate_bytes_per_s=TPU_ICI_BW,
    loss_p=0.0,
    t_prop_s=1e-6,
    t_ack_s=0.0,
    t_setup_s=0.0,
    t_feedback_s=1e-6,
)

DCN = LinkProfile(
    name="dcn",
    mtu_bytes=1024 * 1024,
    rate_bytes_per_s=TPU_DCN_BW,
    loss_p=1e-4,  # retransmission-equivalent derating (lossy fabric)
    t_prop_s=10e-6,
    t_ack_s=5e-6,
    t_setup_s=1e-3,  # per-session connection warm-up
    t_feedback_s=10e-6,
)

TPU_LINKS: dict[str, LinkProfile] = {"ici": ICI, "dcn": DCN}


def tpu_layer_time_s(flops: float, bytes_moved: float, n_chips: int = 1) -> float:
    """Analytic per-layer time: max of the compute and memory roofline terms."""
    return max(flops / (n_chips * TPU_PEAK_FLOPS), bytes_moved / (n_chips * TPU_HBM_BW))
