"""Split-point planner: model graph -> cost model -> solved split plan.

Two entry points:

* :func:`plan_split` — the paper's IoT scenario: an L-layer model, N
  devices, one wireless protocol; minimizes Eq. 8 with the chosen solver.

* :func:`plan_pipeline` — the TPU adaptation: partition a transformer
  block-chain into pipeline stages across pods/chip-groups, with
  inter-stage activation traffic costed on an interconnect tier (ICI/DCN)
  via the *same* Eq. 7 packetized-link model. Objective defaults to
  ``bottleneck`` (steady-state pipeline throughput).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core import solvers as S
from repro.core import sweep as SW  # no cycle: sweep depends only on latency/solvers
from repro.core.latency import (
    BottleneckVariant,
    DeviceProfile,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
    rtt_breakdown,
)
from repro.core.profiles import ICI, tpu_layer_time_s, tpu_stage_device

if TYPE_CHECKING:  # avoid the core <-> models import cycle at runtime
    from repro.models.graph import LayerGraph


@dataclass(frozen=True)
class SegmentPlan:
    device: int  # 1-indexed device/stage
    first_layer: int  # 1-indexed inclusive
    last_layer: int
    layer_names: tuple[str, ...]
    infer_s: float
    param_bytes: int
    tx_bytes: int  # activation bytes leaving this segment (0 for the last)
    cost_s: float


@dataclass(frozen=True)
class SplitPlan:
    model: str
    solver: str
    n_devices: int
    splits: tuple[int, ...]
    segments: tuple[SegmentPlan, ...]
    total_latency_s: float  # Eq. 8 incl. setup + feedback
    objective_cost_s: float  # solver objective (no overheads)
    planner_time_s: float
    nodes_expanded: int
    # joint (split, variant) solves report the adopted bottleneck
    # variant: its bank index and accuracy proxy. None / 1.0 for plain
    # single-variant plans (the historical shape).
    variant: int | None = None
    accuracy_proxy: float = 1.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _build_plan(
    model: SplitCostModel, result: S.SolverResult, n_devices: int
) -> SplitPlan:
    prof = model.profile
    L = prof.num_layers
    bounds = [0, *result.splits, L]
    segments = []
    for i in range(len(bounds) - 1):
        a, b = bounds[i] + 1, bounds[i + 1]
        segments.append(
            SegmentPlan(
                device=i + 1,
                first_layer=a,
                last_layer=b,
                layer_names=tuple(lc.name for lc in prof.layers[a - 1 : b]),
                infer_s=prof.segment_infer_s(a, b),
                param_bytes=prof.segment_param_bytes(a, b),
                # bytes that actually cross the cut: the model's variant
                # (if any) compresses the boundary activation, and the
                # runtime prices hops from exactly this field
                tx_bytes=model.cut_payload_bytes(b) if b < L else 0,
                cost_s=model.segment_cost_s(a, b, i + 1),
            )
        )
    total = model.end_to_end_s(result.splits, with_overheads=True) if result.feasible else float("inf")
    v = model._active_variant
    return SplitPlan(
        model=prof.name,
        solver=result.solver,
        n_devices=n_devices,
        splits=result.splits,
        segments=tuple(segments),
        total_latency_s=total,
        objective_cost_s=result.cost_s,
        planner_time_s=result.wall_time_s,
        nodes_expanded=result.nodes_expanded,
        variant=result.variant,
        accuracy_proxy=1.0 if v is None else v.accuracy_proxy,
    )


def plan_split(
    cost_model: SplitCostModel,
    n_devices: int,
    solver: str = "beam",
    energy_budget: float | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
    accuracy_floor: float | None = None,
    **solver_kwargs,
) -> SplitPlan:
    """Solve Eq. 9 for the given cost model and device count.

    ``solver`` accepts the scalar algorithms in
    :data:`repro.core.solvers.SOLVERS` plus the vectorized engines
    (``"batched_dp"``, ``"batched_beam"``, ``"batched_greedy"``) which
    run on the dense cost tensor in one array pass instead of a Python
    segment loop. ``batched_dp``/``batched_greedy`` are bit-identical
    to their scalar oracles; ``batched_beam`` is bit-identical except
    on exact floating-point cost ties (see its docstring).

    ``energy_budget`` caps every device's segment energy in Joules:
    scalar solvers see over-budget segments as +inf via
    :func:`repro.core.solvers.budget_masked` (the model's own
    :meth:`SplitCostModel.segment_energy_j` prices them); batched
    solvers mask the stacked tensor the same way
    (:func:`repro.core.sweep.apply_energy_budget`).

    ``variants``: optional bottleneck-variant bank (see
    :func:`repro.core.profiles.esp32_variant_bank`). The solve then
    jointly optimizes (split point, variant) — scalar solvers via their
    ``variants=`` dispatch, batched solvers via
    :func:`repro.core.sweep.solve_variant_bank` — and the returned
    plan's ``variant`` / ``accuracy_proxy`` report the adopted variant,
    with every ``tx_bytes`` priced at its compressed payload.
    ``accuracy_floor`` (requires ``variants``) masks variants whose
    ``accuracy_proxy`` falls below the floor: ``min latency s.t.
    accuracy_proxy >= floor``."""
    L = cost_model.profile.num_layers
    if not 1 <= n_devices <= L:
        raise ValueError(f"n_devices={n_devices} out of range for L={L}")
    if accuracy_floor is not None and variants is None:
        raise ValueError("accuracy_floor requires a variants bank")
    if solver in SW.BATCHED_SOLVERS:
        return plan_split_batch([cost_model], n_devices, solver=solver,
                                energy_budget=energy_budget,
                                variants=variants,
                                accuracy_floor=accuracy_floor,
                                **solver_kwargs)[0]
    fn = S.SOLVERS[solver]
    combine = "max" if cost_model.objective == "bottleneck" else "sum"
    if variants is not None:
        bank_models = [dataclasses.replace(cost_model, variant=v)
                       for v in variants]
        insts = [
            S.VariantInstance(
                cost_fn=m.cost_segment_fn(),
                energy_fn=(m.energy_segment_fn()
                           if energy_budget is not None else None),
                accuracy_proxy=v.accuracy_proxy,
            )
            for m, v in zip(bank_models, variants)
        ]
        result = fn(None, L, n_devices, combine=combine,
                    energy_budget=energy_budget, variants=insts,
                    accuracy_floor=accuracy_floor, **solver_kwargs)
        chosen = (cost_model if result.variant is None
                  else bank_models[result.variant])
        return _build_plan(chosen, result, n_devices)
    if energy_budget is not None:
        solver_kwargs = dict(solver_kwargs,
                             energy_fn=cost_model.energy_segment_fn(),
                             energy_budget=energy_budget)
    result = fn(
        cost_model.cost_segment_fn(),
        L,
        n_devices,
        combine=combine,
        **solver_kwargs,
    )
    return _build_plan(cost_model, result, n_devices)


def plan_split_batch(
    cost_models: Sequence[SplitCostModel],
    n_devices: int | Sequence[int],
    solver: str = "batched_dp",
    backend: str = "numpy",
    energy_budget: float | Sequence[float] | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
    accuracy_floor: float | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> list[SplitPlan]:
    """Kwarg shim over the planner tier for cost-model batches: builds a
    :class:`repro.core.spec.PlanSpec` (:func:`repro.core.spec.
    models_spec` — the cost models travel alongside as the operand) and
    resolves it via :class:`repro.core.spec.PlannerService`, so kwarg
    and spec callers run the same implementation
    (:func:`_plan_split_batch_impl`) with bit-identical plans. See the
    impl for the planning semantics."""
    from repro.core.spec import PlannerService, models_spec  # lazy

    spec = models_spec(
        cost_models, n_devices=n_devices, solver=solver, backend=backend,
        energy_budget=energy_budget, variants=variants,
        accuracy_floor=accuracy_floor, mesh=mesh_spec, **solver_kwargs)
    return PlannerService().plan(spec, cost_models)


def _plan_split_batch_impl(
    cost_models: Sequence[SplitCostModel],
    n_devices: int | Sequence[int],
    solver: str = "batched_dp",
    backend: str = "numpy",
    energy_budget: float | Sequence[float] | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
    accuracy_floor: float | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> list[SplitPlan]:
    """Plan many scenarios in one batched pass over stacked cost tensors.

    All ``cost_models`` must share a layer count (same model graph;
    links/devices/objectives may differ per scenario — the fleet
    what-if case, including heterogeneous device mixes: each cost
    model carries its own device tuple into its tensor slice).
    ``n_devices`` may be a single fleet size or one per cost model
    (heterogeneous fleet sizes batch in the same pass; the tensor is
    stacked at the largest size and each scenario reads its own
    prefix). Returns one :class:`SplitPlan` per input, in order. The
    amortization is the point: S scenarios cost one tensor solve
    instead of S Python-loop DP runs (see ``benchmarks/sweep_grid.py``).

    ``backend``: a :data:`repro.core.sweep.DP_BACKENDS` key —
    ``"numpy"`` (bit-parity float64 default), ``"jax"``, ``"sharded"``
    (scenario axis over the local JAX device mesh —
    :mod:`repro.core.shard`), or ``"pallas"`` (scenario-tiled Pallas
    kernel — :mod:`repro.core.pallas_dp`), for ``solver="batched_dp"``
    only.

    ``energy_budget``: optional per-device Joule cap — a scalar for all
    scenarios or one per cost model. Segments whose energy (each
    model's own :meth:`SplitCostModel.energy_cost_tensor`) exceeds the
    budget are masked to +inf before the solve
    (:func:`repro.core.sweep.apply_energy_budget`), so plans minimize
    latency subject to the budget on every backend.

    ``variants`` / ``accuracy_floor``: joint (split, variant) solves —
    the stacked tensor grows a variant axis and
    :func:`repro.core.sweep.solve_variant_bank` folds it into the
    scenario batch; see :func:`plan_split`."""
    if not cost_models:
        return []
    if accuracy_floor is not None and variants is None:
        raise ValueError("accuracy_floor requires a variants bank")
    L = cost_models[0].profile.num_layers
    if isinstance(n_devices, int):
        n_list = [n_devices] * len(cost_models)
    else:
        n_list = [int(n) for n in n_devices]
        if len(n_list) != len(cost_models):
            raise ValueError(
                f"n_devices has {len(n_list)} entries for "
                f"{len(cost_models)} cost models")
    for n in n_list:
        if not 1 <= n <= L:  # same contract as plan_split
            raise ValueError(f"n_devices={n} out of range for L={L}")
    objectives = {m.objective for m in cost_models}
    if len(objectives) != 1:
        raise ValueError(f"cost_models mix objectives {sorted(objectives)}")
    combine = "max" if cost_models[0].objective == "bottleneck" else "sum"
    # per-model export sizes: each cost model's device tuple only has to
    # cover its OWN fleet (smaller fleets get +inf-padded device slices
    # the solvers never read)
    n_arg = n_devices if isinstance(n_devices, int) else n_list
    ns = None if isinstance(n_devices, int) else np.asarray(n_list, np.int64)
    if variants is not None:
        C = SW.stack_cost_tensors(cost_models, n_arg, variants=variants)
        if energy_budget is not None:
            # one energy tensor per variant slice (encoder Joules differ),
            # each masked exactly like the single-variant path
            C = np.stack([
                SW.apply_energy_budget(
                    C[vi],
                    SW.stack_cost_tensors(
                        [dataclasses.replace(m, variant=v)
                         for m in cost_models],
                        n_arg, channels=("energy",))[0],
                    energy_budget)
                for vi, v in enumerate(variants)
            ])
        res = SW.solve_variant_bank(
            C, solver=solver, combine=combine, backend=backend, n_devices=ns,
            accuracy_proxy=[v.accuracy_proxy for v in variants],
            accuracy_floor=accuracy_floor, mesh_spec=mesh_spec,
            **solver_kwargs)
        return plans_from_batched(cost_models, res, n_list,
                                  nodes_expanded=int(np.prod(C.shape[2:])),
                                  variants=variants)
    C = SW.stack_cost_tensors(cost_models, n_arg)
    if energy_budget is not None:
        E = SW.stack_cost_tensors(cost_models, n_arg, channels=("energy",))[0]
        C = SW.apply_energy_budget(C, E, energy_budget)
    res = SW.solve_batched(C, solver=solver, combine=combine, backend=backend,
                           n_devices=ns, mesh_spec=mesh_spec, **solver_kwargs)
    return plans_from_batched(cost_models, res, n_list,
                              nodes_expanded=int(np.prod(C.shape[1:])))


def plans_from_batched(
    cost_models: Sequence[SplitCostModel],
    res,  # sweep.BatchedSolverResult
    n_devices: int | Sequence[int],
    nodes_expanded: int = 0,
    variants: Sequence[BottleneckVariant] | None = None,
) -> list[SplitPlan]:
    """Materialize per-scenario :class:`SplitPlan`\\ s from one batched
    solver result (shared by the planner and the adaptive manager).
    ``n_devices``: one fleet size for all scenarios, or one per
    scenario. When the result came from a variant-bank solve
    (``res.variant`` set) pass the same ``variants`` bank: each plan is
    then built on its winning variant's cost model, so segment costs
    and ``tx_bytes`` price the compressed cut."""
    if isinstance(n_devices, int):
        n_list = [n_devices] * len(cost_models)
    else:
        n_list = [int(n) for n in n_devices]
    wall = res.wall_time_s / max(1, len(cost_models))
    plans = []
    for i, m in enumerate(cost_models):
        vi = None
        if res.variant is not None:
            vi = int(res.variant[i])
            if vi >= 0 and variants is not None:
                m = dataclasses.replace(m, variant=variants[vi])
        sr = S.SolverResult(
            solver=res.solver,
            splits=res.splits_tuple(i),
            cost_s=float(res.cost_s[i]),
            wall_time_s=wall,
            nodes_expanded=nodes_expanded,
            variant=None if vi is None or vi < 0 else vi,
        )
        plans.append(_build_plan(m, sr, n_list[i]))
    return plans


def plan_surface(
    cost_model: SplitCostModel,
    protocols: "dict[str, LinkProfile]",
    n_devices: int,
    **kwargs,
):
    """Precompute a :class:`~repro.core.surface.DegradationSurface`: the
    best plan, tuned chunk, and latency for every (protocol ×
    packet-time × loss) link condition, solved in one batched
    sweep-engine pass. The adaptive manager consumes it for O(1)
    ``observe()`` replanning; see :mod:`repro.core.surface`."""
    from repro.core.surface import build_surface  # lazy: keeps import light

    return build_surface(cost_model, protocols, n_devices, **kwargs)


def compare_solvers(
    cost_model: SplitCostModel,
    n_devices: int,
    solvers: Sequence[str] = ("beam", "greedy", "first_fit", "random_fit", "brute_force"),
    **per_solver_kwargs,
) -> dict[str, SplitPlan]:
    """Run several solvers on the same instance (Figs. 3-4)."""
    out = {}
    for name in solvers:
        kwargs = per_solver_kwargs.get(name, {}) if per_solver_kwargs else {}
        out[name] = plan_split(cost_model, n_devices, solver=name, **kwargs)
    return out


# ---------------------------------------------------------------------------
# TPU pipeline planning (the beyond-paper integration)
# ---------------------------------------------------------------------------


def tpu_cost_profile(
    graph: "LayerGraph",
    *,
    act_dtype_bytes: int = 2,
    param_dtype_bytes: int = 2,
    chips_per_stage: int = 1,
) -> ModelCostProfile:
    """Analytic per-layer TPU times: max(compute, memory) roofline terms.

    ``bytes_moved`` per layer approximates params read once plus
    activations in+out (training adds backward traffic uniformly — a
    constant factor that does not move split decisions)."""
    from repro.core.latency import LayerCost

    layers = []
    for n in graph.nodes:
        bytes_moved = (
            n.param_count * param_dtype_bytes + n.work_elems * act_dtype_bytes
        )
        layers.append(
            LayerCost(
                name=n.name,
                t_infer_s=tpu_layer_time_s(n.flops, bytes_moved, chips_per_stage),
                act_bytes=n.out_elems * act_dtype_bytes,
                param_bytes=n.param_count * param_dtype_bytes,
                work_bytes=n.work_elems * act_dtype_bytes,
                flops=n.flops,
            )
        )
    return ModelCostProfile(
        name=graph.name, layers=tuple(layers), input_bytes=graph.input_elems * act_dtype_bytes
    )


def plan_pipeline(
    graph: "LayerGraph",
    n_stages: int,
    *,
    chips_per_stage: int = 1,
    link: LinkProfile = ICI,
    solver: str = "beam",
    act_dtype_bytes: int = 2,
    objective: str = "bottleneck",
    **solver_kwargs,
) -> SplitPlan:
    if solver == "beam":
        # memory-cliff instances (segments that barely fit a stage) need a
        # wider beam than the paper's IoT cases; still < 100 ms to plan
        solver_kwargs.setdefault("beam_width", 16)
    """Beam-search pipeline-stage boundaries for a transformer block chain.

    This is the paper's split-point optimization re-targeted at TPU
    pipeline parallelism: stages are chip groups, the link is ICI (intra
    pod) or DCN (across pods), and the objective is the steady-state
    bottleneck stage time."""
    prof = tpu_cost_profile(
        graph, act_dtype_bytes=act_dtype_bytes, chips_per_stage=chips_per_stage
    )
    model = SplitCostModel(
        profile=prof,
        devices=(tpu_stage_device(chips_per_stage),),
        link=link,
        objective=objective,
    )
    return plan_split(model, n_stages, solver=solver, **solver_kwargs)


def uniform_split(L: int, n_devices: int) -> tuple[int, ...]:
    """Equal-layer-count baseline split (what a naive PP config does)."""
    return tuple(round(L * i / n_devices) for i in range(1, n_devices))
