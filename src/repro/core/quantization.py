"""TFLite-style int8 post-training quantization (Jacob et al., CVPR'18).

The paper deploys each model segment as an int8-quantized TFLite blob and
ships int8 intermediate activations between devices (Table II byte counts
= tensor elements x 1 byte). This module provides:

* affine per-tensor / per-channel quantization ``q = round(x/scale) + zp``
  with int8 storage and exact round-trip semantics,
* weight-set quantization for a params pytree (per-output-channel for
  matmul/conv kernels, per-tensor otherwise),
* activation wire-format quantize/dequantize used by the split executor at
  segment boundaries (this is what 'transmitting the intermediate
  activation' means on the wire),
* fake-quant helpers for accuracy evaluation.

The compute hot path (int8 x int8 -> int32 GEMM with dequant epilogue)
lives in ``repro.kernels.quant_matmul``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -128, 127


@dataclass(frozen=True)
class QTensor:
    """An int8-quantized tensor: ``x ~= (values - zero_point) * scale``."""

    values: jax.Array  # int8
    scale: jax.Array  # f32, scalar or per-axis
    zero_point: jax.Array  # int32, same shape as scale
    axis: int | None = None  # quantization axis (None = per-tensor)

    @property
    def nbytes(self) -> int:
        """Wire size: int8 payload (scale/zp are negligible header)."""
        return int(self.values.size)

    def dequantize(self) -> jax.Array:
        scale, zp = self.scale, self.zero_point
        if self.axis is not None:
            shape = [1] * self.values.ndim
            shape[self.axis] = -1
            scale = scale.reshape(shape)
            zp = zp.reshape(shape)
        return (self.values.astype(jnp.float32) - zp.astype(jnp.float32)) * scale


def _affine_params(x_min: jax.Array, x_max: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Scale/zero-point for asymmetric int8 covering [x_min, x_max]."""
    x_min = jnp.minimum(x_min, 0.0)
    x_max = jnp.maximum(x_max, 0.0)
    scale = (x_max - x_min) / float(INT8_MAX - INT8_MIN)
    scale = jnp.where(scale <= 0, 1.0, scale)
    zp = jnp.clip(jnp.round(INT8_MIN - x_min / scale), INT8_MIN, INT8_MAX).astype(jnp.int32)
    return scale.astype(jnp.float32), zp


def quantize(x: jax.Array, axis: int | None = None, symmetric: bool = False) -> QTensor:
    """Quantize to int8. ``axis`` selects per-channel scales (weights);
    ``symmetric`` forces zero_point = 0 (TFLite weight convention)."""
    x = x.astype(jnp.float32)
    if axis is None:
        x_min, x_max = jnp.min(x), jnp.max(x)
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        x_min = jnp.min(x, axis=reduce_axes)
        x_max = jnp.max(x, axis=reduce_axes)
    if symmetric:
        amax = jnp.maximum(jnp.abs(x_min), jnp.abs(x_max))
        scale = jnp.where(amax <= 0, 1.0, amax / INT8_MAX).astype(jnp.float32)
        zp = jnp.zeros_like(scale, dtype=jnp.int32)
    else:
        scale, zp = _affine_params(x_min, x_max)
    if axis is not None:
        shape = [1] * x.ndim
        shape[axis] = -1
        s_b, z_b = scale.reshape(shape), zp.reshape(shape)
    else:
        s_b, z_b = scale, zp
    q = jnp.clip(jnp.round(x / s_b) + z_b, INT8_MIN, INT8_MAX).astype(jnp.int8)
    return QTensor(values=q, scale=scale, zero_point=zp, axis=axis)


def fake_quant(x: jax.Array, axis: int | None = None, symmetric: bool = False) -> jax.Array:
    """Quantize-dequantize round trip (accuracy-degradation studies)."""
    return quantize(x, axis=axis, symmetric=symmetric).dequantize().astype(x.dtype)


def quantize_params(params: Any, channel_axis_rank: int = 2) -> Any:
    """Quantize every float leaf of a params pytree.

    Leaves with rank >= ``channel_axis_rank`` (matmul/conv kernels) use
    symmetric per-output-channel scales (last axis, the TFLite
    convention); vectors (biases, norm scales) stay float32 — TFLite keeps
    biases int32 at scale_in*scale_w, which round-trips exactly, so f32 is
    the faithful storage-equivalent here."""

    def quant_leaf(x):
        if not isinstance(x, jax.Array) or not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        if x.ndim >= channel_axis_rank:
            return quantize(x, axis=x.ndim - 1, symmetric=True)
        return x

    return jax.tree.map(quant_leaf, params)


def dequantize_params(params: Any) -> Any:
    return jax.tree.map(
        lambda x: x.dequantize() if isinstance(x, QTensor) else x,
        params,
        is_leaf=lambda x: isinstance(x, QTensor),
    )


def param_bytes(params: Any) -> int:
    """Deployed size of a (possibly quantized) params pytree in bytes."""
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes + leaf.scale.size * 4 + leaf.zero_point.size * 4
        elif isinstance(leaf, jax.Array):
            total += leaf.size * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# Wire format for split-boundary activations
# ---------------------------------------------------------------------------


def encode_activation(x: jax.Array) -> QTensor:
    """Quantize an intermediate activation for transmission (per-tensor
    asymmetric — the TFLite activation convention)."""
    return quantize(x, axis=None, symmetric=False)


def decode_activation(qt: QTensor, dtype=jnp.float32) -> jax.Array:
    return qt.dequantize().astype(dtype)
