"""Vectorized fleet-scale scenario sweeps over stacked cost tensors.

The scalar planner answers one question at a time: *given* a model, a
protocol, a fleet size, and a link state, where do we cut? Fleet
operation asks thousands of these questions continuously — every
protocol × loss-rate × bandwidth × fleet-size combination is a what-if
the controller must price before committing (COMSPLIT-style
communication-aware re-planning). This module amortizes them:

* :func:`batched_optimal_dp` — the exact O(L² N) split DP, run over a
  stacked scenario axis in one array pass (NumPy float64, bit-identical
  to :func:`repro.core.solvers.optimal_dp`; optional JAX
  ``vmap``/``lax.scan`` backend for accelerators).
* :func:`batched_beam_search` / :func:`batched_greedy_search` — the
  paper's Algorithm 1/2 heuristics vectorized over scenarios,
  semantics-faithful to the scalar implementations (same pruning,
  dominance, and windows; greedy is bit-identical always, beam is
  bit-identical except under exact floating-point cost ties, where
  truncation may keep a different equally-ranked candidate).
* :func:`batched_total_cost` — score candidate split *sets* across every
  scenario at once (plan-portfolio evaluation / warm starts).
* :class:`ScenarioGrid` / :func:`sweep` — the fleet API: declare a grid
  of (model × link × fleet size × loss × rate) scenarios, get back a
  :class:`SweepResult` table of per-scenario best splits, cost
  breakdowns, and solver wall time.

Conventions
-----------
A stacked cost tensor ``C`` has shape ``(S, N, L, L)`` with
``C[s, k-1, a-1, b-1] = CostSegment(a, b, k)`` for scenario ``s``
(+inf marks invalid or memory-infeasible segments) — exactly what
:meth:`repro.core.latency.SplitCostModel.segment_cost_tensor` exports.
Split points are 1-indexed layer boundaries, matching the scalar
solvers.

The scalar solvers remain the oracle: every batched solver here is
property-tested to return bit-identical best splits (see
``tests/test_sweep.py``).
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, replace
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.latency import (
    DeviceProfile,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
)
from repro.core import solvers as S

INF = float("inf")

__all__ = [
    "BatchedSolverResult",
    "Scenario",
    "ScenarioGrid",
    "SweepResult",
    "SweepRow",
    "batched_beam_search",
    "batched_greedy_search",
    "batched_optimal_dp",
    "batched_total_cost",
    "stack_cost_tensors",
    "sweep",
    "sweep_scalar",
]


# ---------------------------------------------------------------------------
# Tensor utilities
# ---------------------------------------------------------------------------


def stack_cost_tensors(models: Sequence[SplitCostModel], n_devices: int) -> np.ndarray:
    """Stack per-scenario cost tensors into ``(S, N, L, L)``.

    All models must share the same layer count ``L`` (same model graph;
    links/devices may differ) — that is what makes the scenario axis
    dense."""
    tensors = [m.segment_cost_tensor(n_devices) for m in models]
    Ls = {t.shape[-1] for t in tensors}
    if len(Ls) != 1:
        raise ValueError(f"scenario tensors disagree on L: {sorted(Ls)}")
    return np.stack(tensors, axis=0)


def _combine_ufunc(combine: str):
    if combine == "sum":
        return np.add
    if combine == "max":
        return np.maximum
    raise ValueError(f"unknown combine {combine!r}")


def batched_total_cost(
    C: np.ndarray, splits: np.ndarray, combine: str = "sum"
) -> np.ndarray:
    """Score candidate split sets across every scenario at once.

    ``C``: (S, N, L, L) stacked cost tensor; ``splits``: (M, N-1) int
    array of candidate configurations (1-indexed boundaries). Returns
    (S, M) combined costs, +inf for invalid/infeasible candidates —
    the batched counterpart of :func:`repro.core.solvers.total_cost`."""
    Sn, N, L, _ = C.shape
    splits = np.asarray(splits, dtype=np.int64)
    if splits.ndim == 1:
        splits = splits[None, :]
    M = splits.shape[0]
    if splits.shape[1] != N - 1:
        raise ValueError(f"splits must have N-1={N - 1} columns, got {splits.shape}")
    bounds = np.concatenate(
        [np.zeros((M, 1), np.int64), splits, np.full((M, 1), L, np.int64)], axis=1
    )  # (M, N+1)
    valid = np.all(bounds[:, 1:] > bounds[:, :-1], axis=1)  # strictly increasing
    safe = np.clip(bounds, 0, L)
    k_idx = np.arange(N)[None, :]  # (1, N)
    a_idx = np.clip(safe[:, :-1], 0, L - 1)  # segment start boundary (a-1 index)
    b_idx = np.clip(safe[:, 1:] - 1, 0, L - 1)
    seg = C[:, k_idx, a_idx, b_idx]  # (S, M, N)
    if combine == "sum":
        total = np.cumsum(seg, axis=2)[:, :, -1]  # sequential, matches scalar sum
    else:
        total = np.max(seg, axis=2)
    total = np.where(valid[None, :], total, INF)
    return total


def _per_scenario_total_cost(
    C: np.ndarray, splits: np.ndarray, combine: str = "sum"
) -> np.ndarray:
    """Combined cost of scenario ``s``'s OWN configuration ``splits[s]``
    (shape (S, N-1) -> (S,)); +inf for non-increasing bounds."""
    Sn, N, L, _ = C.shape
    bounds = np.concatenate(
        [np.zeros((Sn, 1), np.int64), np.asarray(splits, np.int64),
         np.full((Sn, 1), L, np.int64)], axis=1,
    )
    valid = np.all(bounds[:, 1:] > bounds[:, :-1], axis=1)
    a_idx = np.clip(bounds[:, :-1], 0, L - 1)
    b_idx = np.clip(bounds[:, 1:] - 1, 0, L - 1)
    seg = C[np.arange(Sn)[:, None], np.arange(N)[None, :], a_idx, b_idx]  # (S, N)
    total = np.cumsum(seg, axis=1)[:, -1] if combine == "sum" else seg.max(axis=1)
    return np.where(valid, total, INF)


# ---------------------------------------------------------------------------
# Batched exact DP
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BatchedSolverResult:
    """Result of one batched solve over ``S`` stacked scenarios."""

    solver: str
    backend: str
    n_devices: int
    splits: np.ndarray  # (S, N-1) int64, -1 where infeasible
    cost_s: np.ndarray  # (S,) float64 combined objective cost
    feasible: np.ndarray  # (S,) bool
    wall_time_s: float  # one batched pass for ALL scenarios

    @property
    def n_scenarios(self) -> int:
        return int(self.cost_s.shape[0])

    def splits_tuple(self, s: int) -> tuple[int, ...]:
        """Scenario ``s``'s splits in scalar-solver form.

        () when the solver produced no configuration; like the scalar
        greedy, a full configuration whose total is +inf keeps its split
        points (``feasible[s]`` is the authoritative flag)."""
        if self.splits.shape[1] and (self.splits[s] < 0).any():
            return ()
        return tuple(int(x) for x in self.splits[s])


def _reconstruct_splits(
    parents: np.ndarray, cost: np.ndarray, L: int, n_devices: int
) -> tuple[np.ndarray, np.ndarray]:
    """Walk DP parent pointers back from boundary L (batched)."""
    Sn = cost.shape[0]
    feas = np.isfinite(cost)
    splits = np.full((Sn, max(n_devices - 1, 0)), -1, dtype=np.int64)
    b = np.full(Sn, L, dtype=np.int64)
    rows = np.arange(Sn)
    for k in range(n_devices, 1, -1):
        a = parents[rows, k - 2, np.clip(b - 1, 0, L - 1)]
        a = np.where(feas, a, -1)
        splits[:, k - 2] = a
        b = np.clip(np.where(feas, a, 1), 1, L)
    return splits, feas


def _dp_numpy(C: np.ndarray, combine: str):
    """(dp_per_k, parents): dp_per_k[k-1] is the (S, L) DP table after k
    devices; parents[s, k-2, b-1] the argmin boundary. Bit-identical
    arithmetic and tie-breaking (first minimum) to the scalar DP."""
    Sn, N, L, _ = C.shape
    comb = _combine_ufunc(combine)
    dp = C[:, 0, 0, :].copy()  # k=1: layers [1..b] on device 1
    dp_per_k = [dp]
    parents = np.full((Sn, max(N - 1, 0), L), -1, dtype=np.int64)
    for k in range(2, N + 1):
        # cand[s, a-1, b-1] = comb(dp[s, a], C[s, k, a+1, b]) for a=1..L-1
        cand = comb(dp[:, : L - 1, None], C[:, k - 1, 1:L, :])
        ndp = cand.min(axis=1)
        arg = cand.argmin(axis=1) + 1  # boundary a, 1-indexed
        parents[:, k - 2, :] = np.where(np.isfinite(ndp), arg, -1)
        dp = ndp
        dp_per_k.append(dp)
    return dp_per_k, parents


def _dp_jax(C: np.ndarray, combine: str):
    """JAX backend: ``vmap`` over the scenario axis, ``lax.scan`` over
    devices. Float precision follows the active JAX config (float32 by
    default) — use the NumPy backend when bit-exact parity with the
    scalar float64 oracle is required."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    Sn, N, L, _ = C.shape

    def one(Cs):  # (N, L, L) for one scenario
        dp0 = Cs[0, 0, :]

        def step(dp, Ck):
            if combine == "sum":
                cand = dp[: L - 1, None] + Ck[1:L, :]
            else:
                cand = jnp.maximum(dp[: L - 1, None], Ck[1:L, :])
            ndp = jnp.min(cand, axis=0)
            arg = jnp.where(jnp.isfinite(ndp), jnp.argmin(cand, axis=0) + 1, -1)
            return ndp, (ndp, arg)

        _, (dps, args) = lax.scan(step, dp0, Cs[1:N])
        return dp0, dps, args

    dp0, dps, args = jax.jit(jax.vmap(one))(jnp.asarray(C))
    dp0 = np.asarray(dp0, dtype=np.float64)
    dp_per_k = [dp0] + [np.asarray(dps[:, i], dtype=np.float64) for i in range(N - 1)]
    parents = np.asarray(args, dtype=np.int64)  # (S, N-1, L) from the vmapped scan
    if N == 1:
        parents = np.full((Sn, 0, L), -1, dtype=np.int64)
    return dp_per_k, parents


def batched_optimal_dp(
    C: np.ndarray,
    combine: str = "sum",
    backend: str = "numpy",
    return_all_k: bool = False,
):
    """Exact split DP over a stacked cost tensor — one pass, every scenario.

    ``C``: (S, N, L, L). Returns a :class:`BatchedSolverResult` for
    ``N`` devices, or (when ``return_all_k``) a dict ``{n: result}`` for
    every fleet size ``n = 1..N`` — the DP table at device ``k`` already
    answers the ``k``-device question, so a whole fleet-size axis costs
    one solve.

    ``backend="numpy"`` is bit-identical to the scalar
    :func:`repro.core.solvers.optimal_dp` (same float64 operation order,
    same first-minimum tie-breaking). ``backend="jax"`` runs the same
    recurrence as a ``vmap``-ed ``lax.scan`` for accelerator execution."""
    if C.ndim != 4:
        raise ValueError(f"C must be (S, N, L, L), got shape {C.shape}")
    Sn, N, L, L2 = C.shape
    if L != L2:
        raise ValueError(f"C must be square in (a, b), got {C.shape}")
    t0 = time.perf_counter()
    if backend == "numpy":
        dp_per_k, parents = _dp_numpy(C, combine)
    elif backend == "jax":
        dp_per_k, parents = _dp_jax(C, combine)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    wall = time.perf_counter() - t0

    def result_for(n: int) -> BatchedSolverResult:
        cost = dp_per_k[n - 1][:, L - 1].astype(np.float64, copy=True)
        splits, feas = _reconstruct_splits(parents, cost, L, n)
        return BatchedSolverResult(
            solver="batched_dp", backend=backend, n_devices=n,
            splits=splits, cost_s=cost, feasible=feas, wall_time_s=wall,
        )

    if return_all_k:
        return {n: result_for(n) for n in range(1, N + 1)}
    return result_for(N)


# ---------------------------------------------------------------------------
# Feasibility lookahead (vectorized _min_devices_suffix)
# ---------------------------------------------------------------------------


def _min_devices_suffix_batched(C: np.ndarray) -> np.ndarray:
    """need[s, j] = minimum devices that can host layers [j..L] feasibly
    (+inf if none) — the vectorized twin of
    :func:`repro.core.solvers._min_devices_suffix` (probe device k=2,
    falling back to k=1 when only one device slice exists)."""
    Sn, N, L, _ = C.shape
    probe = min(1, N - 1)  # k=2 slice when available
    feas = np.isfinite(C[:, probe])  # (S, L, L): [j-1, b-1]
    need = np.full((Sn, L + 2), INF)
    need[:, L + 1] = 0.0
    rows = np.arange(Sn)
    for j in range(L, 0, -1):
        row = feas[:, j - 1, :]  # (S, L), feasibility of [j..b]
        any_feas = row.any(axis=1)
        b_max = L - 1 - np.argmax(row[:, ::-1], axis=1)  # 0-indexed; junk if none
        greedy_next = need[rows, np.clip(b_max + 2, 0, L + 1)]
        greedy_ok = any_feas & np.isfinite(greedy_next)
        # fallback: scan all feasible extents b in [j, L]
        nxt = need[:, j + 1 : L + 2]  # (S, L-j+1), need[b+1] for b=j..L
        ext = np.where(row[:, j - 1 :] & np.isfinite(nxt), 1.0 + nxt, INF)
        fb = ext.min(axis=1)
        need[:, j] = np.where(greedy_ok, 1.0 + greedy_next, fb)
    return need


# ---------------------------------------------------------------------------
# Batched Algorithm 2 — Greedy
# ---------------------------------------------------------------------------


def batched_greedy_search(
    C: np.ndarray,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
) -> BatchedSolverResult:
    """Algorithm 2 vectorized over the scenario axis; semantics-faithful
    to :func:`repro.core.solvers.greedy_search` (same window, lookahead
    pruning, and lowest-index tie-breaking)."""
    Sn, N, L, _ = C.shape
    t0 = time.perf_counter()
    need = _min_devices_suffix_batched(C) if feasibility_lookahead else None
    rows = np.arange(Sn)
    pos = np.zeros(Sn, dtype=np.int64)  # last chosen boundary (0 = start)
    alive = np.ones(Sn, dtype=bool)
    splits = np.full((Sn, max(N - 1, 0)), -1, dtype=np.int64)
    j_idx = np.arange(L)[None, :]
    for k in range(1, N):
        row = C[rows, k - 1, np.clip(pos, 0, L - 1), :]  # (S, L): nxt = j+1
        mask = j_idx > (L - 1 - (N - k))  # nxt > L-(N-k)
        if need is not None:
            mask = mask | (need[:, 2:] > N - k)  # need[nxt+1] vs devices left
        row = np.where(mask, INF, row)
        best = row.min(axis=1)
        nxt = row.argmin(axis=1) + 1  # first minimum = lowest nxt, like scalar
        alive = alive & np.isfinite(best)
        splits[:, k - 1] = np.where(alive, nxt, -1)
        pos = np.where(alive, nxt, pos)
    cost = np.where(alive, _per_scenario_total_cost(C, np.maximum(splits, 1), combine), INF)
    feas = np.isfinite(cost)
    return BatchedSolverResult(
        solver="batched_greedy", backend="numpy", n_devices=N,
        splits=splits, cost_s=cost, feasible=feas,
        wall_time_s=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Batched Algorithm 1 — Beam Search
# ---------------------------------------------------------------------------


def batched_beam_search(
    C: np.ndarray,
    beam_width: int = 8,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
) -> BatchedSolverResult:
    """Algorithm 1 vectorized over the scenario axis.

    Faithful to :func:`repro.core.solvers.beam_search`: the same
    admissible completion bound ranks candidates before truncation, the
    same per-position dominance collapses ties (first-seen beam order
    wins), and the suffix-packability lookahead prunes dead ends. On
    instances without exact floating-point cost ties it returns
    bit-identical splits to the scalar solver; under exact ties the
    truncation order differs (landing-position vs generation order) and
    either beam may keep the luckier candidate — only ``batched_dp``
    carries an unconditional bit-parity guarantee."""
    Sn, N, L, _ = C.shape
    t0 = time.perf_counter()
    comb = _combine_ufunc(combine)
    need = _min_devices_suffix_batched(C) if feasibility_lookahead else None
    W = beam_width
    rows = np.arange(Sn)

    # beam state: slot arrays ordered by the scalar solver's ranking
    cost = np.full((Sn, 1), 0.0)
    pos = np.zeros((Sn, 1), dtype=np.int64)
    hist = np.full((Sn, 1, N), -1, dtype=np.int64)  # chosen boundaries per slot

    for k in range(1, N + 1):
        w_cur = cost.shape[1]
        # extension costs E[s, w, j]: segment (pos+1 .. j+1) on device k
        Ck = C[:, k - 1]  # (S, L, L)
        seg = np.take_along_axis(Ck, np.clip(pos, 0, L - 1)[:, :, None], axis=1)
        E = comb(cost[:, :, None], seg)  # (S, w, L)
        E = np.where(np.isfinite(cost)[:, :, None], E, INF)
        j_idx = np.arange(L)[None, None, :]
        if k == N:
            E = np.where(j_idx == L - 1, E, INF)  # s_N = L pinned
        else:
            E = np.where(j_idx > L - 1 - (N - k), INF, E)
            if need is not None:
                E = np.where(need[:, None, 2:] > N - k, INF, E)
        # dominance: best slot per landing position (ties -> lowest slot,
        # i.e. scalar generation order)
        D = E.min(axis=1)  # (S, L)
        back = E.argmin(axis=1)  # (S, L)
        # ranking: admissible completion bound (scalar's truncation key)
        if k < N:
            # scalar's completion_bound(nxt, k): the whole suffix [nxt+1..L]
            # as ONE segment on device min(k+1, N) lower-bounds any further
            # segmentation (superadditive costs); INF -> 0 (feasibility is
            # the lookahead's job). Candidate j lands at boundary nxt=j+1,
            # so its suffix starts at layer j+2 -> start index j+1.
            whole = C[:, min(k, N - 1), :, L - 1]  # (S, L) indexed by start-1
            bound = np.where(np.isfinite(whole), whole, 0.0)
            bshift = np.concatenate([bound[:, 1:], np.zeros((Sn, 1))], axis=1)
            bshift[:, L - 1] = 0.0  # nxt = L: empty suffix
            if combine == "max":
                key = np.maximum(D, bshift / (N - k))
            else:
                key = D + bshift
            key = np.where(np.isfinite(D), key, INF)
        else:
            key = D
        order = np.argsort(key, axis=1, kind="stable")[:, :W]  # (S, <=W)
        new_cost = np.take_along_axis(D, order, axis=1)
        new_pos = order + 1  # boundary after layer j+1 (1-indexed)
        slot = np.take_along_axis(back, order, axis=1)  # predecessor slot
        new_hist = hist[rows[:, None], slot]  # (S, W', N)
        new_hist = new_hist.copy()
        new_hist[:, :, k - 1] = np.where(np.isfinite(new_cost), new_pos, -1)
        dead = ~np.isfinite(new_cost)
        cost = np.where(dead, INF, new_cost)
        pos = np.where(dead, 0, new_pos)
        hist = new_hist

    best_cost = cost[:, 0]
    feas = np.isfinite(best_cost)
    splits = np.where(feas[:, None], hist[:, 0, : N - 1], -1)
    return BatchedSolverResult(
        solver="batched_beam", backend="numpy", n_devices=N,
        splits=splits, cost_s=np.where(feas, best_cost, INF),
        feasible=feas, wall_time_s=time.perf_counter() - t0,
    )


BATCHED_SOLVERS: dict[str, Callable[..., BatchedSolverResult]] = {
    "batched_dp": batched_optimal_dp,
    "batched_beam": batched_beam_search,
    "batched_greedy": batched_greedy_search,
}


def solve_batched(
    C: np.ndarray,
    solver: str = "batched_dp",
    combine: str = "sum",
    backend: str = "numpy",
    **solver_kwargs,
) -> BatchedSolverResult:
    """The single dispatch point for batched solves over a stacked tensor
    (used by :func:`sweep`, ``planner.plan_split_batch``, and the
    adaptive manager — one place to extend when adding a solver)."""
    if solver == "batched_dp":
        return batched_optimal_dp(C, combine=combine, backend=backend,
                                  **solver_kwargs)
    if solver in ("batched_beam", "batched_greedy"):
        if backend != "numpy":
            raise ValueError(f"{solver} supports backend='numpy' only")
        fn = batched_beam_search if solver == "batched_beam" else batched_greedy_search
        return fn(C, combine=combine, **solver_kwargs)
    raise ValueError(f"unknown batched solver {solver!r}; "
                     f"options: {sorted(BATCHED_SOLVERS)}")

# batched solver name -> the scalar oracle it must match bit-for-bit
SCALAR_ORACLES: dict[str, str] = {
    "batched_dp": "optimal_dp",
    "batched_beam": "beam",
    "batched_greedy": "greedy",
}


# ---------------------------------------------------------------------------
# ScenarioGrid — the fleet-sweep API
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """One point of a :class:`ScenarioGrid` (a what-if the planner prices)."""

    model: str
    protocol: str
    n_devices: int
    loss_p: float | None  # None -> protocol default
    rate_scale: float  # multiplier on the link serialization rate

    def describe(self) -> str:
        loss = "base" if self.loss_p is None else f"p={self.loss_p:g}"
        return (f"{self.model}/{self.protocol} N={self.n_devices} "
                f"{loss} rate×{self.rate_scale:g}")


@dataclass(frozen=True)
class ScenarioGrid:
    """A dense grid of split-planning scenarios:
    models × links × fleet sizes × loss rates × rate scales.

    ``models`` maps names to :class:`ModelCostProfile`; ``links`` maps
    protocol names to :class:`LinkProfile`. ``devices`` is the device
    profile tuple shared by all scenarios (a single profile broadcasts
    over any fleet size, as in the paper's homogeneous ESP32 fleet)."""

    models: Mapping[str, ModelCostProfile]
    links: Mapping[str, LinkProfile]
    n_devices: tuple[int, ...]
    loss_p: tuple[float | None, ...] = (None,)
    rate_scale: tuple[float, ...] = (1.0,)
    devices: tuple[DeviceProfile, ...] = ()
    objective: str = "sum"

    def __post_init__(self):
        if not self.devices:
            raise ValueError("ScenarioGrid requires at least one DeviceProfile")
        for field_name in ("n_devices", "loss_p", "rate_scale"):
            object.__setattr__(self, field_name, tuple(getattr(self, field_name)))
        object.__setattr__(self, "models", dict(self.models))
        object.__setattr__(self, "links", dict(self.links))

    @property
    def size(self) -> int:
        return (len(self.models) * len(self.links) * len(self.n_devices)
                * len(self.loss_p) * len(self.rate_scale))

    def scenarios(self) -> list[Scenario]:
        """Deterministic enumeration order: model-major, then fleet size,
        then protocol × loss × rate (the link axes batch densely)."""
        return [
            Scenario(m, p, n, lp, rs)
            for m in self.models
            for n in self.n_devices
            for p in self.links
            for lp in self.loss_p
            for rs in self.rate_scale
        ]

    def link_variant(self, sc: Scenario) -> LinkProfile:
        link = self.links[sc.protocol]
        changes: dict = {}
        if sc.loss_p is not None:
            changes["loss_p"] = sc.loss_p
        if sc.rate_scale != 1.0:
            changes["rate_bytes_per_s"] = link.rate_bytes_per_s * sc.rate_scale
        return replace(link, **changes) if changes else link

    def cost_model(self, sc: Scenario) -> SplitCostModel:
        """The scalar-oracle :class:`SplitCostModel` for one scenario."""
        return SplitCostModel(
            profile=self.models[sc.model], devices=self.devices,
            link=self.link_variant(sc), objective=self.objective,
        )

    def degradation_surface(self, model: str | None = None,
                            n_devices: int | None = None, **kwargs):
        """Precompute a :class:`~repro.core.surface.DegradationSurface`
        whose packet-time/loss axes derive from this grid's
        ``rate_scale``/``loss_p`` axes (the sweep's link what-ifs become
        the runtime's O(1) replanning lookup table)."""
        from repro.core.surface import DegradationSurface  # lazy: no cycle

        return DegradationSurface.from_scenario_grid(
            self, model=model, n_devices=n_devices, **kwargs)


@dataclass(frozen=True)
class SweepRow:
    """Per-scenario best plan from a sweep."""

    scenario: Scenario
    splits: tuple[int, ...]
    feasible: bool
    objective_cost_s: float  # solver objective (no setup/feedback)
    total_latency_s: float  # Eq. 8 incl. link setup + feedback overheads
    device_s: float  # summed device-local segment latency
    transmission_s: float  # summed cut transmission latency
    solver_wall_s: float  # this scenario's share of the batched solve

    def to_dict(self) -> dict:
        d = dict(self.scenario.__dict__)
        d.update(
            splits=list(self.splits), feasible=self.feasible,
            objective_cost_s=self.objective_cost_s,
            total_latency_s=self.total_latency_s,
            device_s=self.device_s, transmission_s=self.transmission_s,
            solver_wall_s=self.solver_wall_s,
        )
        return d


@dataclass(frozen=True)
class SweepResult:
    """Dense sweep output: one row per scenario, grid order preserved."""

    rows: tuple[SweepRow, ...]
    solver: str
    backend: str
    solve_time_s: float  # batched solver passes only
    build_time_s: float  # cost-tensor assembly

    @property
    def n_scenarios(self) -> int:
        return len(self.rows)

    @property
    def scenarios_per_sec(self) -> float:
        total = self.solve_time_s + self.build_time_s
        return self.n_scenarios / total if total > 0 else INF

    def best(self, **filters) -> SweepRow:
        """Lowest-latency feasible row among those matching scenario-field
        filters, e.g. ``best(model="mobilenet_v2", n_devices=4)``."""
        pool = [
            r for r in self.rows
            if r.feasible
            and all(getattr(r.scenario, k) == v for k, v in filters.items())
        ]
        if not pool:
            raise LookupError(f"no feasible scenario matches {filters!r}")
        return min(pool, key=lambda r: r.total_latency_s)

    def to_dicts(self) -> list[dict]:
        return [r.to_dict() for r in self.rows]

    def to_json(self, indent: int | None = None) -> str:
        def _clean(v):
            return None if isinstance(v, float) and not np.isfinite(v) else v

        payload = {
            "solver": self.solver, "backend": self.backend,
            "n_scenarios": self.n_scenarios,
            "solve_time_s": self.solve_time_s, "build_time_s": self.build_time_s,
            "scenarios_per_sec": self.scenarios_per_sec,
            "rows": [{k: _clean(v) for k, v in d.items()} for d in self.to_dicts()],
        }
        return json.dumps(payload, indent=indent)

    def to_csv(self) -> str:
        cols = ["model", "protocol", "n_devices", "loss_p", "rate_scale",
                "feasible", "splits", "objective_cost_s", "total_latency_s",
                "device_s", "transmission_s", "solver_wall_s"]
        lines = [",".join(cols)]
        for d in self.to_dicts():
            d["splits"] = "|".join(str(x) for x in d["splits"])
            lines.append(",".join(str(d[c]) for c in cols))
        return "\n".join(lines) + "\n"


def _group_tx_vectors(
    grid: ScenarioGrid, profile: ModelCostProfile, group: list[Scenario]
) -> np.ndarray:
    """(S_g, L) transmission-cost vectors, amortizing packet counts per
    protocol (K depends only on MTU) against per-scenario packet times."""
    L = profile.num_layers
    act = profile.segment_arrays.boundary_act_bytes[1:].astype(np.float64)
    packets_by_mtu: dict[int, np.ndarray] = {}
    out = np.empty((len(group), L))
    for i, sc in enumerate(group):
        link = grid.link_variant(sc)
        K = packets_by_mtu.get(link.mtu_bytes)
        if K is None:
            K = np.where(act > 0, np.ceil(act / link.mtu_bytes), 0.0)
            packets_by_mtu[link.mtu_bytes] = K
        tx = K * link.packet_time_s()
        tx[-1] = 0.0
        out[i] = tx
    return out


def sweep(
    grid: ScenarioGrid,
    solver: str = "batched_dp",
    backend: str = "numpy",
    beam_width: int = 8,
) -> SweepResult:
    """Plan every scenario of ``grid`` in batched passes.

    Scenarios are grouped by (model, fleet size); within a group the
    device-local cost tensor is built once and the link axes (protocol ×
    loss × rate) stack into one ``(S_g, N, L, L)`` tensor solved in a
    single array pass. With ``solver="batched_dp"`` the returned splits
    are bit-identical to running the scalar ``optimal_dp`` per scenario
    (the property-test contract)."""
    if solver not in BATCHED_SOLVERS:
        raise ValueError(f"unknown batched solver {solver!r}; "
                         f"options: {sorted(BATCHED_SOLVERS)}")
    combine = "max" if grid.objective == "bottleneck" else "sum"
    order = grid.scenarios()
    # group scenarios (preserving order within groups) by (model, N)
    groups: dict[tuple[str, int], list[int]] = {}
    for idx, sc in enumerate(order):
        groups.setdefault((sc.model, sc.n_devices), []).append(idx)

    rows: dict[int, SweepRow] = {}
    build_time = 0.0
    solve_time = 0.0
    # one device-local tensor per model at the LARGEST fleet size; smaller
    # fleets are prefixes of it (device k's matrix does not depend on N)
    max_n: dict[str, int] = {}
    for model_name, n in groups:
        max_n[model_name] = max(n, max_n.get(model_name, 0))
    local_cache: dict[str, np.ndarray] = {}
    for (model_name, n), idxs in groups.items():
        profile = grid.models[model_name]
        L = profile.num_layers
        group = [order[i] for i in idxs]
        t0 = time.perf_counter()
        full = local_cache.get(model_name)
        if full is None:
            base_model = SplitCostModel(
                profile=profile, devices=grid.devices,
                link=next(iter(grid.links.values())), objective=grid.objective,
            )
            full = base_model.local_cost_tensor(max_n[model_name])
            local_cache[model_name] = full
        local = full[:n]
        TX = _group_tx_vectors(grid, profile, group)  # (S_g, L)
        C = local[None, :, :, :] + TX[:, None, None, :]
        build_time += time.perf_counter() - t0

        kwargs = {"beam_width": beam_width} if solver == "batched_beam" else {}
        res = solve_batched(C, solver=solver, combine=combine,
                            backend=backend if solver == "batched_dp" else "numpy",
                            **kwargs)
        solve_time += res.wall_time_s
        per_scn_wall = res.wall_time_s / max(1, len(group))

        # cost breakdowns from the same tensors (no scalar re-walks)
        for gi, (idx, sc) in enumerate(zip(idxs, group)):
            splits_t = res.splits_tuple(gi)
            feasible = bool(res.feasible[gi])
            link = grid.link_variant(sc)
            if splits_t or n == 1:
                bounds = [0, *splits_t, L] if feasible else None
            else:
                bounds = None
            if feasible and bounds is not None:
                tx_total = float(np.sum(TX[gi, [b - 1 for b in bounds[1:-1]]])) \
                    if len(bounds) > 2 else 0.0
                obj = float(res.cost_s[gi])
                # device/transmission totals summed over all segments; for
                # the "sum" objective device_s + transmission_s == objective
                seg_sum = float(sum(C[gi, i, bounds[i], bounds[i + 1] - 1]
                                    for i in range(len(bounds) - 1)))
                device_s = seg_sum - tx_total
                total = obj + link.t_setup_s + link.t_feedback_s
                rows[idx] = SweepRow(
                    scenario=sc, splits=splits_t, feasible=True,
                    objective_cost_s=obj, total_latency_s=total,
                    device_s=device_s, transmission_s=tx_total,
                    solver_wall_s=per_scn_wall,
                )
            else:
                rows[idx] = SweepRow(
                    scenario=sc, splits=splits_t, feasible=False,
                    objective_cost_s=INF, total_latency_s=INF,
                    device_s=INF, transmission_s=INF,
                    solver_wall_s=per_scn_wall,
                )
    ordered = tuple(rows[i] for i in range(len(order)))
    return SweepResult(rows=ordered, solver=solver, backend=backend,
                       solve_time_s=solve_time, build_time_s=build_time)


def sweep_scalar(grid: ScenarioGrid, solver: str = "optimal_dp") -> SweepResult:
    """The un-batched reference: one scalar solve per scenario (the
    per-scenario Python loop the batched engine replaces). Used as the
    parity oracle in tests and the baseline in benchmark speedup
    reporting."""
    combine = "max" if grid.objective == "bottleneck" else "sum"
    rows = []
    solve_time = 0.0
    build_time = 0.0
    for sc in grid.scenarios():
        t0 = time.perf_counter()
        m = grid.cost_model(sc)
        L = m.profile.num_layers
        fn = m.cost_segment_fn()
        build_time += time.perf_counter() - t0
        res = S.SOLVERS[solver](fn, L, sc.n_devices, combine=combine)
        solve_time += res.wall_time_s
        feasible = res.feasible
        if feasible:
            link = grid.link_variant(sc)
            bounds = [0, *res.splits, L]
            tx_total = sum(
                link.transmission_latency_s(m.profile.boundary_act_bytes(b))
                for b in bounds[1:-1]
            )
            obj = res.cost_s
            seg_sum = S.total_cost(fn, res.splits, L, "sum")
            device_s = seg_sum - tx_total
            rows.append(SweepRow(
                scenario=sc, splits=res.splits, feasible=True,
                objective_cost_s=obj,
                total_latency_s=obj + link.t_setup_s + link.t_feedback_s,
                device_s=device_s, transmission_s=tx_total,
                solver_wall_s=res.wall_time_s,
            ))
        else:
            rows.append(SweepRow(
                scenario=sc, splits=res.splits, feasible=False,
                objective_cost_s=INF, total_latency_s=INF, device_s=INF,
                transmission_s=INF, solver_wall_s=res.wall_time_s,
            ))
    return SweepResult(rows=tuple(rows), solver=solver, backend="scalar",
                       solve_time_s=solve_time, build_time_s=build_time)


def parity_report(batched: SweepResult, scalar: SweepResult) -> list[str]:
    """Human-readable mismatch list between two sweeps of the same grid
    (empty = bit-identical splits everywhere, the acceptance contract)."""
    if batched.n_scenarios != scalar.n_scenarios:
        return [f"scenario count differs: {batched.n_scenarios} vs {scalar.n_scenarios}"]
    out = []
    for rb, rs in zip(batched.rows, scalar.rows):
        if tuple(rb.splits) != tuple(rs.splits) or rb.feasible != rs.feasible:
            out.append(f"{rb.scenario.describe()}: batched {rb.splits} "
                       f"vs scalar {rs.splits}")
    return out
