"""Vectorized fleet-scale scenario sweeps over stacked cost tensors.

The scalar planner answers one question at a time: *given* a model, a
protocol, a fleet size, and a link state, where do we cut? Fleet
operation asks thousands of these questions continuously — every
protocol × loss-rate × bandwidth × fleet-size combination is a what-if
the controller must price before committing (COMSPLIT-style
communication-aware re-planning). This module amortizes them:

* :func:`batched_optimal_dp` — the exact O(L² N) split DP, run over a
  stacked scenario axis in one array pass (NumPy float64, bit-identical
  to :func:`repro.core.solvers.optimal_dp`; optional JAX
  ``vmap``/``lax.scan`` backend for accelerators, a ``"sharded"``
  backend that partitions the scenario axis over every local JAX
  device — :mod:`repro.core.shard` — and a ``"pallas"`` backend that
  fuses cost construction into a scenario-tiled kernel so ``C`` is
  never materialized — :mod:`repro.core.pallas_dp`; the
  :data:`DP_BACKENDS` registry is the single source for the set).
* :func:`batched_beam_search` / :func:`batched_greedy_search` — the
  paper's Algorithm 1/2 heuristics vectorized over scenarios,
  semantics-faithful to the scalar implementations (same pruning,
  dominance, and windows; greedy is bit-identical always, beam is
  bit-identical except under exact floating-point cost ties, where
  truncation may keep a different equally-ranked candidate).
* :func:`batched_total_cost` — score candidate split *sets* across every
  scenario at once (plan-portfolio evaluation / warm starts).
* :class:`ScenarioGrid` / :func:`sweep` — the fleet API: declare a grid
  of (model × link × fleet size × loss × rate) scenarios, get back a
  :class:`SweepResult` table of per-scenario best splits, cost
  breakdowns, and solver wall time.

Conventions
-----------
A stacked cost tensor ``C`` has shape ``(S, N, L, L)`` with
``C[s, k-1, a-1, b-1] = CostSegment(a, b, k)`` for scenario ``s``
(+inf marks invalid or memory-infeasible segments) — exactly what
:meth:`repro.core.latency.SplitCostModel.segment_cost_tensor` exports.
Split points are 1-indexed layer boundaries, matching the scalar
solvers.

Fleet-size and device heterogeneity batch too: every batched solver
accepts a per-scenario ``n_devices`` vector (scenario ``s`` is solved
for ``n_devices[s]`` devices, reading only ``C[s, :n_devices[s]]``),
:func:`batched_beam_search_all_k` answers every fleet size in one
vectorized pass, and :class:`ScenarioGrid` scenarios may draw their
per-device profiles from a named ``device_mixes`` bank (heterogeneous
fleets — COMSPLIT-style mixed device classes — batch in the same
tensor pass as homogeneous ones).

The scalar solvers remain the oracle: every batched solver here is
property-tested to return bit-identical best splits (see
``tests/test_sweep.py`` and ``tests/test_solver_properties.py``).

Import invariant (do not "simplify" away): ``repro.core`` re-exports
the *names* defined here but deliberately NOT the :func:`sweep`
function itself — the attribute ``repro.core.sweep`` must keep
resolving to this submodule (``import repro.core.sweep as SW`` and
``importlib.import_module("repro.core.sweep")`` both rely on it; a
shadowing function once broke the planner). Get the function with
``from repro.core.sweep import sweep``.
"""

from __future__ import annotations

import functools
import json
import time
from dataclasses import dataclass, replace
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.latency import (
    COST_CHANNELS,
    BottleneckVariant,
    ContentionModel,
    DeviceProfile,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
    bottleneck_variant,
)
from repro.core import solvers as S

INF = float("inf")

__all__ = [
    "DP_BACKENDS",
    "BatchedSolverResult",
    "ParetoFrontier",
    "Scenario",
    "ScenarioGrid",
    "SweepResult",
    "SweepRow",
    "apply_accuracy_floor",
    "apply_energy_budget",
    "batched_beam_search",
    "batched_beam_search_all_k",
    "batched_greedy_search",
    "batched_greedy_search_all_k",
    "batched_optimal_dp",
    "batched_total_cost",
    "combine_channels",
    "pareto_frontier",
    "solve_multi_channel",
    "solve_variant_bank",
    "stack_cost_tensors",
    "sweep",
    "sweep_scalar",
]


# ---------------------------------------------------------------------------
# Tensor utilities
# ---------------------------------------------------------------------------


def stack_cost_tensors(
    models: Sequence[SplitCostModel],
    n_devices: int | Sequence[int],
    channels: Sequence[str] | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
) -> np.ndarray:
    """Stack per-scenario cost tensors into ``(S, N, L, L)``.

    All models must share the same layer count ``L`` (same model graph;
    links/devices may differ) — that is what makes the scenario axis
    dense. ``n_devices`` may be one fleet size for all models or one
    per model: each tensor is then exported at its OWN size (so a
    model's device tuple only has to cover its own fleet) and padded
    with +inf device slices up to the largest — slices the solvers
    never read under a matching per-scenario ``n_devices`` vector.

    ``channels``: optional sequence drawn from
    :data:`repro.core.latency.COST_CHANNELS`. When given, the result is
    the stacked multi-channel tensor ``C[ch, s, k-1, a-1, b-1]`` of
    shape (len(channels), S, N, L, L); each channel slice is
    bit-identical to the single-channel stack of that channel (the
    degenerate one-channel case therefore IS the historical tensor).

    ``variants``: optional bottleneck-variant bank (see
    :class:`repro.core.latency.BottleneckVariant`). When given, the
    result grows a leading variant axis — ``C[v, s, k-1, a-1, b-1]`` of
    shape (V, S, N, L, L) — where slice ``v`` is the stack of
    ``replace(m, variant=variants[v])`` tensors, i.e. each variant
    reprices the cut payload (compressed bytes + encoder time) while
    the local compute term is shared. Slice 0 of an identity-leading
    bank is bit-identical to the variant-free stack. Mutually exclusive
    with ``channels`` (mask/solve one concern at a time; energy budgets
    under a variant bank stack the energy channel per variant). Feed
    the result to :func:`solve_variant_bank`."""
    if channels is not None and variants is not None:
        raise ValueError("stack_cost_tensors: channels and variants are "
                         "mutually exclusive; stack channels per variant")
    if variants is not None:
        if not variants:
            raise ValueError("variants bank must not be empty")
        return np.stack([
            stack_cost_tensors([replace(m, variant=v) for m in models],
                               n_devices)
            for v in variants
        ], axis=0)
    if isinstance(n_devices, (int, np.integer)):
        n_list = [int(n_devices)] * len(models)
    else:
        n_list = [int(n) for n in n_devices]
        if len(n_list) != len(models):
            raise ValueError(f"n_devices has {len(n_list)} entries for "
                             f"{len(models)} models")
    if not models:
        raise ValueError("stack_cost_tensors needs at least one model")
    n_max = max(n_list)
    tensors = []
    for m, n in zip(models, n_list):
        t = m.segment_cost_tensor(n, channels=channels)
        if n < n_max:
            pad_axis = 0 if channels is None else 1
            pad_shape = list(t.shape)
            pad_shape[pad_axis] = n_max - n
            t = np.concatenate([t, np.full(tuple(pad_shape), INF)],
                               axis=pad_axis)
        tensors.append(t)
    Ls = {t.shape[-1] for t in tensors}
    if len(Ls) != 1:
        raise ValueError(f"scenario tensors disagree on L: {sorted(Ls)}")
    return np.stack(tensors, axis=0 if channels is None else 1)


def _combine_ufunc(combine: str):
    if combine == "sum":
        return np.add
    if combine == "max":
        return np.maximum
    raise ValueError(f"unknown combine {combine!r}")


def _normalize_ns(n_devices, Sn: int, N: int) -> np.ndarray:
    """Per-scenario fleet sizes as an (S,) int64 vector.

    ``None`` means every scenario uses the tensor's full device axis
    ``N``; a scalar broadcasts; a vector must have one entry in
    ``[1, N]`` per scenario (scenario ``s`` then reads only the
    ``C[s, :n_devices[s]]`` prefix — device ``k``'s cost matrix never
    depends on the fleet size, so prefixes of one stacked tensor are
    exact sub-problems)."""
    if n_devices is None:
        return np.full(Sn, N, dtype=np.int64)
    ns = np.asarray(n_devices, dtype=np.int64)
    if ns.ndim == 0:
        ns = np.full(Sn, int(ns), dtype=np.int64)
    if ns.shape != (Sn,):
        raise ValueError(
            f"n_devices must be None, a scalar, or shape ({Sn},); got {ns.shape}")
    if ns.size and (int(ns.min()) < 1 or int(ns.max()) > N):
        raise ValueError(
            f"per-scenario n_devices must lie in [1, {N}], "
            f"got [{int(ns.min())}, {int(ns.max())}]")
    return ns


def batched_total_cost(
    C: np.ndarray, splits: np.ndarray, combine: str = "sum"
) -> np.ndarray:
    """Score candidate split sets across every scenario at once.

    ``C``: (S, N, L, L) stacked cost tensor; ``splits``: (M, N-1) int
    array of candidate configurations (1-indexed boundaries). Returns
    (S, M) combined costs, +inf for invalid/infeasible candidates —
    the batched counterpart of :func:`repro.core.solvers.total_cost`."""
    Sn, N, L, _ = C.shape
    splits = np.asarray(splits, dtype=np.int64)
    if splits.ndim == 1:
        splits = splits[None, :]
    M = splits.shape[0]
    if splits.shape[1] != N - 1:
        raise ValueError(f"splits must have N-1={N - 1} columns, got {splits.shape}")
    bounds = np.concatenate(
        [np.zeros((M, 1), np.int64), splits, np.full((M, 1), L, np.int64)], axis=1
    )  # (M, N+1)
    valid = np.all(bounds[:, 1:] > bounds[:, :-1], axis=1)  # strictly increasing
    safe = np.clip(bounds, 0, L)
    k_idx = np.arange(N)[None, :]  # (1, N)
    a_idx = np.clip(safe[:, :-1], 0, L - 1)  # segment start boundary (a-1 index)
    b_idx = np.clip(safe[:, 1:] - 1, 0, L - 1)
    seg = C[:, k_idx, a_idx, b_idx]  # (S, M, N)
    if combine == "sum":
        total = np.cumsum(seg, axis=2)[:, :, -1]  # sequential, matches scalar sum
    else:
        total = np.max(seg, axis=2)
    total = np.where(valid[None, :], total, INF)
    return total


def _per_scenario_total_cost(
    C: np.ndarray,
    splits: np.ndarray,
    combine: str = "sum",
    n_devices_s: np.ndarray | None = None,
) -> np.ndarray:
    """Combined cost of scenario ``s``'s OWN configuration ``splits[s]``
    (shape (S, N-1) -> (S,)); +inf for non-increasing bounds.

    With ``n_devices_s`` only scenario ``s``'s first ``n_s - 1`` split
    columns are read; trailing boundaries collapse to ``L`` and the
    dead segments contribute the combine identity (``+0.0`` for sum —
    bit-preserving on the non-negative costs the latency model emits —
    and ``-inf`` for max), so totals stay bit-identical to a scalar
    walk over the live segments only."""
    Sn, N, L, _ = C.shape
    ns = _normalize_ns(n_devices_s, Sn, N)
    splits = np.asarray(splits, np.int64)
    j = np.arange(1, N)[None, :]  # boundary number of split column j-1
    mid = np.where(j <= ns[:, None] - 1, splits, L)
    bounds = np.concatenate(
        [np.zeros((Sn, 1), np.int64), mid, np.full((Sn, 1), L, np.int64)],
        axis=1,
    )  # (S, N+1)
    live = np.arange(N)[None, :] < ns[:, None]  # (S, N) live segments
    valid = np.all(np.where(live, bounds[:, 1:] > bounds[:, :-1], True), axis=1)
    a_idx = np.clip(bounds[:, :-1], 0, L - 1)
    b_idx = np.clip(bounds[:, 1:] - 1, 0, L - 1)
    seg = C[np.arange(Sn)[:, None], np.arange(N)[None, :], a_idx, b_idx]  # (S, N)
    if combine == "sum":
        seg = np.where(live, seg, 0.0)
        total = np.cumsum(seg, axis=1)[:, -1]  # sequential, matches scalar sum
    else:
        seg = np.where(live, seg, -INF)
        total = seg.max(axis=1)
    return np.where(valid, total, INF)


# ---------------------------------------------------------------------------
# Batched exact DP
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BatchedSolverResult:
    """Result of one batched solve over ``S`` stacked scenarios.

    ``n_devices`` is the solved fleet size (the tensor's device-axis
    length). When the solve carried a per-scenario fleet-size vector,
    ``n_devices_s`` holds it and scenario ``s``'s configuration spans
    only its first ``n_devices_s[s] - 1`` split columns (the rest stay
    ``-1`` padding, which :meth:`splits_tuple` never reads).

    ``wall_time_s`` has ONE timing scope across every solver
    constructor (DP / beam / greedy, every backend, per-k and all-k):
    the full batched solve from solver entry through result
    reconstruction and cost extraction, excluding input validation and
    cost-tensor assembly (``SweepResult.build_time_s`` tracks that).
    All-k results share a single family wall — the one pass priced
    every fleet size, so per-size attribution would be fiction. This
    is what makes ``BENCH_sweep.json`` sections comparable across
    solvers and backends; on JAX backends the first same-shape call
    additionally pays trace+compile (cached afterwards — see
    :func:`_dp_jax_solver`)."""

    solver: str
    backend: str  # a DP_BACKENDS key for batched_dp; "numpy" otherwise
    n_devices: int
    splits: np.ndarray  # (S, N-1) int64, -1 where infeasible/padding
    cost_s: np.ndarray  # (S,) float64 combined objective cost
    feasible: np.ndarray  # (S,) bool
    wall_time_s: float  # one batched pass for ALL scenarios (see above)
    n_devices_s: np.ndarray | None = None  # (S,) per-scenario fleet sizes
    # multi-channel solves (solve_multi_channel) additionally report the
    # chosen plan's per-channel totals: channel_cost_s[ch, s] combined
    # over channel ch's own combine mode. None on single-channel solves.
    channels: tuple[str, ...] | None = None
    channel_cost_s: np.ndarray | None = None  # (n_channels, S) float64
    # variant-bank solves (solve_variant_bank) report the winning
    # bottleneck variant per scenario: variant[s] is the bank index of
    # the adopted variant (-1 where no variant is feasible). None on
    # plain single-variant solves.
    variant: np.ndarray | None = None  # (S,) int64

    @property
    def n_scenarios(self) -> int:
        return int(self.cost_s.shape[0])

    def splits_tuple(self, s: int) -> tuple[int, ...]:
        """Scenario ``s``'s splits in scalar-solver form.

        () when the solver produced no configuration; like the scalar
        greedy, a full configuration whose total is +inf keeps its split
        points (``feasible[s]`` is the authoritative flag)."""
        width = self.n_devices - 1
        if self.n_devices_s is not None:
            width = int(self.n_devices_s[s]) - 1
        row = self.splits[s, :width]
        if width and (row < 0).any():
            return ()
        return tuple(int(x) for x in row)


def _reconstruct_splits(
    parents: np.ndarray,
    cost: np.ndarray,
    L: int,
    n_devices: int,
    ns: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Walk DP parent pointers back from boundary L (batched).

    With ``ns`` (per-scenario fleet sizes) scenario ``s`` starts its
    walk at its own final device ``ns[s]``; columns beyond
    ``ns[s] - 1`` stay ``-1`` padding."""
    Sn = cost.shape[0]
    feas = np.isfinite(cost)
    splits = np.full((Sn, max(n_devices - 1, 0)), -1, dtype=np.int64)
    b = np.full(Sn, L, dtype=np.int64)
    rows = np.arange(Sn)
    for k in range(n_devices, 1, -1):
        a = parents[rows, k - 2, np.clip(b - 1, 0, L - 1)]
        a = np.where(feas, a, -1)
        if ns is None:
            splits[:, k - 2] = a
            b = np.clip(np.where(feas, a, 1), 1, L)
        else:
            act = ns >= k
            splits[:, k - 2] = np.where(act, a, -1)
            b = np.where(act, np.clip(np.where(feas, a, 1), 1, L), b)
    return splits, feas


def _dp_numpy(C: np.ndarray, combine: str, ns: np.ndarray | None = None):
    """(dp_per_k, parents): dp_per_k[k-1] is the (S, L) DP table after k
    devices; parents[s, k-2, b-1] the argmin boundary. Bit-identical
    arithmetic and tie-breaking (first minimum) to the scalar DP.

    With ``ns`` (per-scenario fleet sizes) only still-active rows are
    advanced at each device step — frozen rows carry stale table values
    past their own ``n_s``, which no caller reads (reconstruction and
    cost extraction stop at each scenario's own fleet size)."""
    Sn, N, L, _ = C.shape
    comb = _combine_ufunc(combine)
    dp = C[:, 0, 0, :].copy()  # k=1: layers [1..b] on device 1
    dp_per_k = [dp]
    parents = np.full((Sn, max(N - 1, 0), L), -1, dtype=np.int64)
    for k in range(2, N + 1):
        act = None if ns is None else np.flatnonzero(ns >= k)
        if act is not None and act.size == 0:
            break
        if act is None or act.size == Sn:
            # cand[s, a-1, b-1] = comb(dp[s, a], C[s, k, a+1, b]), a=1..L-1
            cand = comb(dp[:, : L - 1, None], C[:, k - 1, 1:L, :])
            ndp = cand.min(axis=1)
            arg = cand.argmin(axis=1) + 1  # boundary a, 1-indexed
            parents[:, k - 2, :] = np.where(np.isfinite(ndp), arg, -1)
            dp = ndp
        else:
            cand = comb(dp[act][:, : L - 1, None], C[act, k - 1, 1:L, :])
            ndp_a = cand.min(axis=1)
            arg = cand.argmin(axis=1) + 1
            parents[act, k - 2, :] = np.where(np.isfinite(ndp_a), arg, -1)
            dp = dp.copy()
            dp[act] = ndp_a
        dp_per_k.append(dp)
    return dp_per_k, parents


# Incremented every time the JAX DP kernel is (re)traced; a same-shape
# repeat call must leave it unchanged (the jit-cache regression test in
# tests/test_shard.py reads it — wall-clock compile timing is flaky,
# trace counting is deterministic).
_DP_JAX_TRACE_COUNT = 0


@functools.lru_cache(maxsize=None)
def _dp_jax_kernel(combine: str):
    """The raw (unjitted) vmapped DP kernel for one combine mode.

    Shared by the single-process jit wrapper (:func:`_dp_jax_solver`)
    and the multi-device ``shard_map`` wrapper in
    :mod:`repro.core.shard` — both paths MUST run this exact function
    so sharded and single-device answers stay node-identical (same
    per-scenario float operation order; sharding only partitions the
    scenario axis, never the arithmetic).

    The kernel carries the full solver contract:
      * per-scenario fleet sizes — device step ``k`` freezes every
        scenario with ``n_s < k`` (``dp``/parents stop advancing, the
        NumPy path's frozen-row semantics), so +inf or garbage device
        slices beyond a scenario's own fleet size are never read into
        a live row;
      * all-k — the stacked per-device tables are returned, so the
        table after ``k`` devices answers the ``k``-device question.
    """
    import jax.numpy as jnp
    from jax import lax, vmap

    def one(Cs, n_s):  # (N, L, L) tensor + fleet size for one scenario
        N, L = Cs.shape[0], Cs.shape[-1]
        dp0 = Cs[0, 0, :]

        def step(dp, xs):
            Ck, k = xs
            if combine == "sum":
                cand = dp[: L - 1, None] + Ck[1:L, :]
            else:
                cand = jnp.maximum(dp[: L - 1, None], Ck[1:L, :])
            ndp = jnp.min(cand, axis=0)
            arg = jnp.where(jnp.isfinite(ndp), jnp.argmin(cand, axis=0) + 1, -1)
            # frozen-row subsetting: a scenario whose fleet completed at
            # n_s < k carries its stale table forward (exactly what the
            # NumPy path's active-subset indexing does); its parents
            # stay -1. Result selection reads table n_s - 1, so the
            # stale rows are never observed.
            act = k <= n_s
            ndp = jnp.where(act, ndp, dp)
            arg = jnp.where(act, arg, -1)
            return ndp, (ndp, arg)

        ks = jnp.arange(2, N + 1)
        _, (dps, args) = lax.scan(step, dp0, (Cs[1:N], ks))
        return dp0, dps, args

    def solve(C, ns):
        global _DP_JAX_TRACE_COUNT
        _DP_JAX_TRACE_COUNT += 1  # Python side effect: runs at trace only
        return vmap(one)(C, ns)

    return solve


@functools.lru_cache(maxsize=None)
def _dp_jax_solver(combine: str):
    """Jitted single-process entry to :func:`_dp_jax_kernel`.

    Cached per combine mode; ``jax.jit``'s own executable cache keys on
    the input shape/dtype, so two same-shape calls compile exactly once
    (the second call pays no retrace — regression-tested via
    :data:`_DP_JAX_TRACE_COUNT`)."""
    import jax

    return jax.jit(_dp_jax_kernel(combine))


def _dp_jax(C: np.ndarray, combine: str, ns: np.ndarray | None = None):
    """JAX backend: ``vmap`` over the scenario axis, ``lax.scan`` over
    devices — same return contract as :func:`_dp_numpy`, including the
    frozen-row semantics under a per-scenario ``ns`` vector.

    Precision follows the active JAX config: float32 by default (equal
    -cost tie-breaks may then differ from the float64 oracle at ~1e-16
    regret), float64 when ``jax.config.jax_enable_x64`` is on — an
    x64-configured run recovers scalar-oracle tie-break parity because
    the kernel mirrors the NumPy operation order and first-minimum
    argmin. The NumPy backend remains the *contractual* bit-parity
    path; x64 parity is verified but not load-bearing."""
    import jax.numpy as jnp

    Sn, N, L, _ = C.shape
    ns_arr = np.full(Sn, N, dtype=np.int64) if ns is None else ns
    solver = _dp_jax_solver(combine)
    dp0, dps, args = solver(jnp.asarray(C), jnp.asarray(ns_arr))
    return _dp_tables_to_numpy(dp0, dps, args, Sn, N, L)


def _dp_tables_to_numpy(dp0, dps, args, Sn: int, N: int, L: int):
    """Device DP outputs -> the (dp_per_k, parents) host format every
    result-selection path consumes (shared with :mod:`repro.core.shard`)."""
    dp0 = np.asarray(dp0, dtype=np.float64)
    dp_per_k = [dp0] + [np.asarray(dps[:, i], dtype=np.float64) for i in range(N - 1)]
    parents = np.asarray(args, dtype=np.int64)  # (S, N-1, L) from the vmapped scan
    if N == 1:
        parents = np.full((Sn, 0, L), -1, dtype=np.int64)
    return dp_per_k, parents


def _validate_dp_inputs(C, return_all_k, n_devices):
    """Shared exact-DP input validation -> (Sn, N, L, ns). The single
    source for every DP entry point (``batched_optimal_dp`` and
    :func:`repro.core.shard.sharded_optimal_dp`) so their contracts
    cannot drift."""
    if C.ndim != 4:
        raise ValueError(f"C must be (S, N, L, L), got shape {C.shape}")
    Sn, N, L, L2 = C.shape
    if L != L2:
        raise ValueError(f"C must be square in (a, b), got {C.shape}")
    if return_all_k and n_devices is not None:
        raise ValueError("return_all_k and per-scenario n_devices are "
                         "mutually exclusive")
    ns = None if n_devices is None else _normalize_ns(n_devices, Sn, N)
    return Sn, N, L, ns


def _dp_tables_numpy(C, combine, ns):
    return _dp_numpy(C, combine, ns=ns)


def _dp_tables_jax(C, combine, ns):
    return _dp_jax(C, combine, ns=ns)


def _dp_tables_sharded(C, combine, ns, mesh_spec=None):
    from repro.core import shard as _shard  # lazy: no import cycle

    return _shard.sharded_dp_tables(C, combine, ns=ns, mesh_spec=mesh_spec)


def _dp_tables_pallas(C, combine, ns):
    from repro.core import pallas_dp as _pallas  # lazy: no import cycle

    return _pallas.pallas_dp_tables(C, combine, ns=ns)


# DP backend registry — THE single source of truth for which backends
# exist. Every consumer (the dispatch below, the unknown-backend error,
# BatchedSolverResult.backend values, the docs backend matrix, the CI
# matrix) keys off this dict, so adding a backend is one entry here plus
# its tables function. Each entry maps C -> (dp_per_k, parents) with the
# shared frozen-row ``ns`` contract; result selection is common
# (:func:`_results_from_dp_tables`).
DP_BACKENDS: dict[str, Callable] = {
    "numpy": _dp_tables_numpy,      # float64, the bit-parity oracle path
    "jax": _dp_tables_jax,          # vmap + lax.scan, single device
    "sharded": _dp_tables_sharded,  # scenario axis over the device mesh
    "pallas": _dp_tables_pallas,    # fused-construction Pallas kernel
}


def batched_optimal_dp(
    C: np.ndarray,
    combine: str = "sum",
    backend: str = "numpy",
    return_all_k: bool = False,
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    mesh_spec=None,
):
    """Exact split DP over a stacked cost tensor — one pass, every scenario.

    Args:
      C: ``(S, N, L, L)`` stacked cost tensor (+inf = infeasible).
      combine: ``"sum"`` (Eq. 5 latency) or ``"max"`` (bottleneck).
      backend: a :data:`DP_BACKENDS` key — ``"numpy"`` (float64, the
        bit-parity path), ``"jax"``, ``"sharded"``
        (:mod:`repro.core.shard`), or ``"pallas"``
        (:mod:`repro.core.pallas_dp`).
      return_all_k: return a dict ``{n: result}`` for every fleet size
        ``n = 1..N`` — the DP table at device ``k`` already answers the
        ``k``-device question, so a whole fleet-size axis costs one
        solve (the all-k trick).
      n_devices: optional per-scenario fleet sizes (see
        :func:`_normalize_ns`); scenario ``s`` is then solved for
        ``n_devices[s]`` devices in the same pass (heterogeneous fleet
        sizes batch like any other scenario axis). Mutually exclusive
        with ``return_all_k``.
      mesh_spec: optional :class:`repro.core.spec.MeshSpec` describing
        the device mesh for ``backend="sharded"`` (other backends
        reject it). ``None`` keeps the historical local mesh.

    Returns a :class:`BatchedSolverResult` (or the all-k dict).

    ``backend="numpy"`` is bit-identical to the scalar
    :func:`repro.core.solvers.optimal_dp` (same float64 operation order,
    same first-minimum tie-breaking). ``backend="jax"`` runs the same
    recurrence as a ``vmap``-ed ``lax.scan`` for accelerator execution —
    float32 by default, so equal-cost tie-breaks may differ (an
    x64-enabled JAX config recovers tie-break parity; see
    :func:`_dp_jax`). ``backend="sharded"`` partitions the scenario
    axis over the local JAX device mesh (:mod:`repro.core.shard`) and
    is node-identical to ``backend="jax"`` by construction.
    ``backend="pallas"`` runs the scenario-tiled Pallas kernel
    (:mod:`repro.core.pallas_dp`; interpret mode off-TPU) and is
    bit-identical to ``backend="jax"`` — tables and parents — since the
    dense-mode kernel reorders no arithmetic. Every backend honors
    per-scenario ``n_devices`` with the same frozen-row semantics and
    supports ``return_all_k``."""
    Sn, N, L, ns = _validate_dp_inputs(C, return_all_k, n_devices)
    t0 = time.perf_counter()
    try:
        tables_fn = DP_BACKENDS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"options: {sorted(DP_BACKENDS)}") from None
    if mesh_spec is not None:
        if backend != "sharded":
            raise ValueError(
                f"mesh_spec is a backend='sharded' knob; got "
                f"backend={backend!r}")
        dp_per_k, parents = tables_fn(C, combine, ns, mesh_spec=mesh_spec)
    else:
        dp_per_k, parents = tables_fn(C, combine, ns)
    return _results_from_dp_tables(dp_per_k, parents, L, N, Sn, backend,
                                   ns, return_all_k, t0)


def _results_from_dp_tables(
    dp_per_k: list[np.ndarray],
    parents: np.ndarray,
    L: int,
    N: int,
    Sn: int,
    backend: str,
    ns: np.ndarray | None,
    return_all_k: bool,
    t0: float,
) -> BatchedSolverResult | dict[int, BatchedSolverResult]:
    """Shared DP result selection + reconstruction (all backends).

    ``wall_time_s`` is stamped AFTER reconstruction so every DP result
    reports the same timing scope as the other solver constructors
    (see :class:`BatchedSolverResult`); all-k results share one wall."""

    def result_for(n: int) -> BatchedSolverResult:
        cost = dp_per_k[n - 1][:, L - 1].astype(np.float64, copy=True)
        splits, feas = _reconstruct_splits(parents, cost, L, n)
        return BatchedSolverResult(
            solver="batched_dp", backend=backend, n_devices=n,
            splits=splits, cost_s=cost, feasible=feas, wall_time_s=0.0,
        )

    if return_all_k:
        out = {n: result_for(n) for n in range(1, N + 1)}
        wall = time.perf_counter() - t0
        return {n: replace(r, wall_time_s=wall) for n, r in out.items()}
    if ns is not None:
        dpk = np.stack([d[:, L - 1] for d in dp_per_k])  # (N, S)
        cost = dpk[ns - 1, np.arange(Sn)].astype(np.float64, copy=True)
        splits, feas = _reconstruct_splits(parents, cost, L, N, ns=ns)
        return BatchedSolverResult(
            solver="batched_dp", backend=backend, n_devices=N,
            splits=splits, cost_s=cost, feasible=feas,
            wall_time_s=time.perf_counter() - t0, n_devices_s=ns,
        )
    return replace(result_for(N), wall_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Feasibility lookahead (vectorized _min_devices_suffix)
# ---------------------------------------------------------------------------


def _min_devices_suffix_batched(C: np.ndarray) -> np.ndarray:
    """need[s, j] = minimum devices that can host layers [j..L] feasibly
    (+inf if none) — the vectorized twin of
    :func:`repro.core.solvers._min_devices_suffix` (probe device k=2,
    falling back to k=1 when only one device slice exists).

    Depends only on the probe slice, so callers that tile one base
    tensor across a fleet-size axis may compute it once and pass it to
    the solvers as ``need_table`` (``np.tile`` over the block axis)."""
    Sn, N, L, _ = C.shape
    probe = min(1, N - 1)  # k=2 slice when available
    feas = np.isfinite(C[:, probe])  # (S, L, L): [j-1, b-1]
    need = np.full((Sn, L + 2), INF)
    need[:, L + 1] = 0.0
    rows = np.arange(Sn)
    for j in range(L, 0, -1):
        row = feas[:, j - 1, :]  # (S, L), feasibility of [j..b]
        any_feas = row.any(axis=1)
        b_max = L - 1 - np.argmax(row[:, ::-1], axis=1)  # 0-indexed; junk if none
        greedy_next = need[rows, np.clip(b_max + 2, 0, L + 1)]
        greedy_ok = any_feas & np.isfinite(greedy_next)
        # fallback: scan all feasible extents b in [j, L]
        nxt = need[:, j + 1 : L + 2]  # (S, L-j+1), need[b+1] for b=j..L
        ext = np.where(row[:, j - 1 :] & np.isfinite(nxt), 1.0 + nxt, INF)
        fb = ext.min(axis=1)
        need[:, j] = np.where(greedy_ok, 1.0 + greedy_next, fb)
    return need


# ---------------------------------------------------------------------------
# Batched Algorithm 2 — Greedy
# ---------------------------------------------------------------------------


def batched_greedy_search(
    C: np.ndarray,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    need_table: np.ndarray | None = None,
) -> BatchedSolverResult:
    """Algorithm 2 vectorized over the scenario axis; semantics-faithful
    to :func:`repro.core.solvers.greedy_search` (same window, lookahead
    pruning, and lowest-index tie-breaking). Bit-identical to the scalar
    greedy — always, including under exact cost ties.

    ``n_devices`` optionally gives each scenario its own fleet size
    (see :func:`_normalize_ns`): a scenario freezes after choosing its
    ``n_s - 1`` splits while larger fleets keep extending, so mixed
    fleet sizes batch in one pass. ``need_table`` optionally supplies a
    precomputed :func:`_min_devices_suffix_batched` result (see its
    docstring; advanced callers that tile a base tensor)."""
    Sn, N, L, _ = C.shape
    t0 = time.perf_counter()
    ns = _normalize_ns(n_devices, Sn, N)
    if not feasibility_lookahead:
        need = None
    else:
        need = need_table if need_table is not None \
            else _min_devices_suffix_batched(C)
    pos = np.zeros(Sn, dtype=np.int64)  # last chosen boundary (0 = start)
    alive = np.ones(Sn, dtype=bool)
    splits = np.full((Sn, max(N - 1, 0)), -1, dtype=np.int64)
    j_idx = np.arange(L)[None, :]
    for k in range(1, N):
        # only scenarios still choosing a k-th split do any work (frozen
        # smaller fleets cost nothing — the folded fleet-size axis does
        # the same array work as per-size passes)
        act = np.flatnonzero(k <= ns - 1)
        if act.size == 0:
            break
        rem = ns[act] - k  # devices left after device k
        row = C[act, k - 1, np.clip(pos[act], 0, L - 1), :]  # (Sa, L)
        mask = j_idx > (L - 1 - rem[:, None])  # nxt > L-(n_s-k)
        if need is not None:
            mask = mask | (need[act, 2:] > rem[:, None])  # need[nxt+1]
        row = np.where(mask, INF, row)
        best = row.min(axis=1)
        nxt = row.argmin(axis=1) + 1  # first minimum = lowest nxt, like scalar
        alive_a = alive[act] & np.isfinite(best)
        alive[act] = alive_a
        splits[act, k - 1] = np.where(alive_a, nxt, -1)
        pos[act] = np.where(alive_a, nxt, pos[act])
    cost = np.where(
        alive,
        _per_scenario_total_cost(C, np.maximum(splits, 1), combine, ns),
        INF,
    )
    feas = np.isfinite(cost)
    return BatchedSolverResult(
        solver="batched_greedy", backend="numpy", n_devices=N,
        splits=splits, cost_s=cost, feasible=feas,
        wall_time_s=time.perf_counter() - t0,
        n_devices_s=None if n_devices is None else ns,
    )


def batched_greedy_search_all_k(
    C: np.ndarray,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
    fleet_sizes: Sequence[int] | None = None,
) -> dict[int, BatchedSolverResult]:
    """Greedy-solve every fleet size in ONE batched pass: ``{n: result}``.

    Same block construction as :func:`batched_beam_search_all_k` (fleet
    sizes as a leading block axis over the SHARED base tensor, active
    blocks a descending prefix, one suffix-packability table); each
    result is element-wise identical to
    ``batched_greedy_search(C[:, :n])`` — and therefore bit-identical
    to the scalar greedy."""
    Sn, N, L, _ = C.shape
    sizes = tuple(fleet_sizes) if fleet_sizes is not None else tuple(range(1, N + 1))
    if len(set(sizes)) != len(sizes):
        raise ValueError(f"fleet_sizes has duplicates: {sizes}")
    for n in sizes:
        if not 1 <= n <= N:
            raise ValueError(f"fleet size {n} out of range [1, {N}]")
    t0 = time.perf_counter()
    need = _min_devices_suffix_batched(C) if feasibility_lookahead else None
    desc = tuple(sorted(sizes, reverse=True))
    B = len(desc)
    n_max = desc[0]
    sz = np.asarray(desc, dtype=np.int64)

    pos = np.zeros((B, Sn), dtype=np.int64)
    alive = np.ones((B, Sn), dtype=bool)
    splits = np.full((B, Sn, max(n_max - 1, 0)), -1, dtype=np.int64)
    j_idx = np.arange(L)[None, None, :]
    for k in range(1, n_max):
        nb = int((sz - 1 >= k).sum())  # blocks still choosing a k-th split
        if nb == 0:
            break
        rem = (sz[:nb] - k)[:, None, None]
        Ck = C[:, k - 1]  # (Sn, L, L) view shared by every block
        row = np.take_along_axis(
            Ck[None], np.clip(pos[:nb], 0, L - 1)[:, :, None, None],
            axis=2)[:, :, 0, :]  # (nb, Sn, L)
        mask = j_idx > (L - 1 - rem)
        if need is not None:
            mask = mask | (need[None, :, 2:] > rem)
        row = np.where(mask, INF, row)
        best = row.min(axis=2)
        nxt = row.argmin(axis=2) + 1  # first minimum = lowest nxt
        alive_a = alive[:nb] & np.isfinite(best)
        alive[:nb] = alive_a
        splits[:nb, :, k - 1] = np.where(alive_a, nxt, -1)
        pos[:nb] = np.where(alive_a, nxt, pos[:nb])

    out: dict[int, BatchedSolverResult] = {}
    for b, n in enumerate(desc):
        spl = splits[b, :, : max(n - 1, 0)].copy()
        cost = np.where(
            alive[b],
            _per_scenario_total_cost(C[:, :n], np.maximum(spl, 1), combine),
            INF,
        )
        feas = np.isfinite(cost)
        out[n] = BatchedSolverResult(
            solver="batched_greedy", backend="numpy", n_devices=n,
            splits=spl, cost_s=cost, feasible=feas, wall_time_s=0.0,
        )
    # one shared family wall, stamped after cost extraction (the
    # BatchedSolverResult timing-scope contract)
    wall = time.perf_counter() - t0
    return {n: replace(out[n], wall_time_s=wall) for n in sizes}


# ---------------------------------------------------------------------------
# Batched Algorithm 1 — Beam Search
# ---------------------------------------------------------------------------


def batched_beam_search(
    C: np.ndarray,
    beam_width: int = 8,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    need_table: np.ndarray | None = None,
) -> BatchedSolverResult:
    """Algorithm 1 vectorized over the scenario axis.

    Faithful to :func:`repro.core.solvers.beam_search`: the same
    admissible completion bound ranks candidates before truncation, the
    same per-position dominance collapses ties (first-seen beam order
    wins), and the suffix-packability lookahead prunes dead ends. On
    instances without exact floating-point cost ties it returns
    bit-identical splits to the scalar solver; under exact ties the
    truncation order differs (landing-position vs generation order) and
    either beam may keep the luckier candidate — only ``batched_dp``
    carries an unconditional bit-parity guarantee.

    ``n_devices`` optionally gives each scenario its own fleet size
    (see :func:`_normalize_ns`). Scenario ``s`` pins its final segment
    to end at ``L`` on its own last device ``n_s`` and freezes while
    larger fleets keep extending — every per-scenario window, lookahead
    threshold, and completion bound uses ``n_s``, so each scenario's
    beam evolves exactly as a standalone ``n_s``-device solve.
    ``need_table``: optional precomputed
    :func:`_min_devices_suffix_batched` result (see its docstring)."""
    Sn, N, L, _ = C.shape
    t0 = time.perf_counter()
    comb = _combine_ufunc(combine)
    if not feasibility_lookahead:
        need = None
    else:
        need = need_table if need_table is not None \
            else _min_devices_suffix_batched(C)
    W = beam_width
    rows = np.arange(Sn)
    ns = _normalize_ns(n_devices, Sn, N)

    # beam state: slot arrays ordered by the scalar solver's ranking
    cost = np.full((Sn, 1), 0.0)
    pos = np.zeros((Sn, 1), dtype=np.int64)
    hist = np.full((Sn, 1, N), -1, dtype=np.int64)  # chosen boundaries per slot

    for k in range(1, N + 1):
        # scenarios whose fleet already completed (k > n_s) are frozen:
        # each step processes only the still-active row subset, so a
        # folded fleet-size axis costs the same array work as per-size
        # passes (row s runs exactly n_s steps)
        act = np.flatnonzero(ns >= k)
        if act.size == 0:
            break
        full = act.size == Sn
        nsa = ns if full else ns[act]
        costa = cost if full else cost[act]
        posa = pos if full else pos[act]
        Sa = act.size
        rem = nsa - k  # devices left after device k; 0 = finishing
        finishing = rem == 0
        fin3 = finishing[:, None, None]
        # extension costs E[s, w, j]: segment (pos+1 .. j+1) on device k
        Ck = C[:, k - 1] if full else C[act, k - 1]  # (Sa, L, L)
        seg = np.take_along_axis(Ck, np.clip(posa, 0, L - 1)[:, :, None],
                                 axis=1)
        E = comb(costa[:, :, None], seg)  # (Sa, w, L)
        E = np.where(np.isfinite(costa)[:, :, None], E, INF)
        j_idx = np.arange(L)[None, None, :]
        # k == n_s: s_N = L pinned; k < n_s: window + lookahead pruning
        E = np.where(fin3 & (j_idx != L - 1), INF, E)
        E = np.where(~fin3 & (j_idx > L - 1 - rem[:, None, None]), INF, E)
        if need is not None:
            needa = need if full else need[act]
            E = np.where(~fin3 & (needa[:, None, 2:] > rem[:, None, None]),
                         INF, E)
        # dominance: best slot per landing position (ties -> lowest slot,
        # i.e. scalar generation order)
        D = E.min(axis=1)  # (Sa, L)
        back = E.argmin(axis=1)  # (Sa, L)
        # ranking: admissible completion bound (scalar's truncation key).
        # scalar's completion_bound(nxt, k): the whole suffix [nxt+1..L]
        # as ONE segment on device min(k+1, n_s) lower-bounds any further
        # segmentation (superadditive costs); INF -> 0 (feasibility is
        # the lookahead's job). Candidate j lands at boundary nxt=j+1,
        # so its suffix starts at layer j+2 -> start index j+1.
        whole = C[act, np.minimum(k, nsa - 1), :, L - 1]  # (Sa, L) by start-1
        bound = np.where(np.isfinite(whole), whole, 0.0)
        bshift = np.concatenate([bound[:, 1:], np.zeros((Sa, 1))], axis=1)
        bshift[:, L - 1] = 0.0  # nxt = L: empty suffix
        if combine == "max":
            mid = np.maximum(D, bshift / np.maximum(rem, 1)[:, None])
        else:
            mid = D + bshift
        key = np.where(finishing[:, None], D,
                       np.where(np.isfinite(D), mid, INF))
        order = np.argsort(key, axis=1, kind="stable")[:, :W]  # (Sa, <=W)
        new_cost = np.take_along_axis(D, order, axis=1)
        new_pos = order + 1  # boundary after layer j+1 (1-indexed)
        slot = np.take_along_axis(back, order, axis=1)  # predecessor slot
        hista = hist[act[:, None], slot]  # (Sa, W', N)
        hista[:, :, k - 1] = np.where(np.isfinite(new_cost), new_pos, -1)
        dead = ~np.isfinite(new_cost)
        new_cost = np.where(dead, INF, new_cost)
        new_pos = np.where(dead, 0, new_pos)
        if k == 1:
            # slot count grows 1 -> min(W, L) this step; every scenario
            # is active at its first device, so adopt directly
            cost, pos, hist = new_cost, new_pos, hista
        else:
            cost[act] = new_cost
            pos[act] = new_pos
            hist[act] = hista

    best_cost = cost[:, 0]
    feas = np.isfinite(best_cost)
    width_ok = np.arange(max(N - 1, 0))[None, :] < (ns[:, None] - 1)
    splits = np.where(feas[:, None] & width_ok, hist[:, 0, : N - 1], -1)
    return BatchedSolverResult(
        solver="batched_beam", backend="numpy", n_devices=N,
        splits=splits, cost_s=np.where(feas, best_cost, INF),
        feasible=feas, wall_time_s=time.perf_counter() - t0,
        n_devices_s=None if n_devices is None else ns,
    )


def batched_beam_search_all_k(
    C: np.ndarray,
    beam_width: int = 8,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
    fleet_sizes: Sequence[int] | None = None,
) -> dict[int, BatchedSolverResult]:
    """Beam-solve every fleet size in ONE batched pass: ``{n: result}``.

    The all-k counterpart of ``batched_optimal_dp(return_all_k=True)``
    for Algorithm 1 (including the bottleneck objective). Unlike the
    DP — whose table at device ``k`` *is* the ``k``-device answer —
    beams for different fleet sizes genuinely diverge (the truncation
    key, window, and lookahead all depend on the devices remaining), so
    sharing one beam would break bit-parity with the per-``k`` solver.
    Instead the fleet-size axis is folded into the scenario axis: the
    tensor is viewed once per requested size and a single vectorized
    recursion solves all of them, with no per-``N`` Python re-solve
    loop. Each returned result is element-wise identical (``==`` on
    splits, cost, feasibility) to ``batched_beam_search(C[:, :n])``.

    ``fleet_sizes`` defaults to every ``n = 1..N``; pass a subset to
    solve only those.

    Implementation: fleet sizes become a leading *block* axis over the
    SAME base tensor (descending, so the still-active blocks at step
    ``k`` are a contiguous prefix) — no ``len(fleet_sizes)``-fold
    tensor copy, one shared suffix-packability table, and per-step
    work proportional to the blocks still extending."""
    Sn, N, L, _ = C.shape
    sizes = tuple(fleet_sizes) if fleet_sizes is not None else tuple(range(1, N + 1))
    if len(set(sizes)) != len(sizes):
        raise ValueError(f"fleet_sizes has duplicates: {sizes}")
    for n in sizes:
        if not 1 <= n <= N:
            raise ValueError(f"fleet size {n} out of range [1, {N}]")
    t0 = time.perf_counter()
    comb = _combine_ufunc(combine)
    need = _min_devices_suffix_batched(C) if feasibility_lookahead else None
    W = beam_width
    desc = tuple(sorted(sizes, reverse=True))  # active blocks = prefix
    B = len(desc)
    n_max = desc[0]
    sz = np.asarray(desc, dtype=np.int64)

    # block-major beam state: [b, s, w(, boundary)]
    cost = np.full((B, Sn, 1), 0.0)
    pos = np.zeros((B, Sn, 1), dtype=np.int64)
    hist = np.full((B, Sn, 1, n_max), -1, dtype=np.int64)

    for k in range(1, n_max + 1):
        nb = int((sz >= k).sum())  # active blocks: a prefix (descending)
        if nb == 0:
            break
        rem = (sz[:nb] - k)[:, None, None, None]  # 0 = finishing block
        fin4 = rem == 0
        costa = cost[:nb]
        Ck = C[:, k - 1]  # (Sn, L, L) view shared by every block
        seg = np.take_along_axis(
            Ck[None], np.clip(pos[:nb], 0, L - 1)[:, :, :, None], axis=2)
        E = comb(costa[:, :, :, None], seg)  # (nb, Sn, w, L)
        E = np.where(np.isfinite(costa)[:, :, :, None], E, INF)
        j_idx = np.arange(L)[None, None, None, :]
        # k == n: s_N = L pinned; k < n: window + lookahead pruning
        E = np.where(fin4 & (j_idx != L - 1), INF, E)
        E = np.where(~fin4 & (j_idx > L - 1 - rem), INF, E)
        if need is not None:
            E = np.where(~fin4 & (need[None, :, None, 2:] > rem), INF, E)
        # dominance: best slot per landing position (ties -> lowest slot)
        D = E.min(axis=2)  # (nb, Sn, L)
        back = E.argmin(axis=2)
        # ranking: admissible completion bound, per block (suffix device
        # min(k+1, n) differs across fleet sizes)
        whole = np.stack([C[:, min(k, n - 1), :, L - 1]
                          for n in desc[:nb]])  # (nb, Sn, L)
        bound = np.where(np.isfinite(whole), whole, 0.0)
        bshift = np.concatenate(
            [bound[:, :, 1:], np.zeros((nb, Sn, 1))], axis=2)
        bshift[:, :, L - 1] = 0.0  # nxt = L: empty suffix
        rem3 = rem[:, :, :, 0]
        if combine == "max":
            mid = np.maximum(D, bshift / np.maximum(rem3, 1))
        else:
            mid = D + bshift
        key = np.where(fin4[:, :, :, 0], D,
                       np.where(np.isfinite(D), mid, INF))
        order = np.argsort(key, axis=2, kind="stable")[:, :, :W]
        new_cost = np.take_along_axis(D, order, axis=2)
        new_pos = order + 1
        slot = np.take_along_axis(back, order, axis=2)
        new_hist = np.take_along_axis(hist[:nb], slot[:, :, :, None], axis=2)
        new_hist[:, :, :, k - 1] = np.where(np.isfinite(new_cost),
                                            new_pos, -1)
        dead = ~np.isfinite(new_cost)
        new_cost = np.where(dead, INF, new_cost)
        new_pos = np.where(dead, 0, new_pos)
        if k == 1:
            cost, pos, hist = new_cost, new_pos, new_hist
        else:
            cost[:nb] = new_cost
            pos[:nb] = new_pos
            hist[:nb] = new_hist

    out: dict[int, BatchedSolverResult] = {}
    for b, n in enumerate(desc):
        best_cost = cost[b, :, 0].copy()
        feas = np.isfinite(best_cost)
        splits = np.where(feas[:, None], hist[b, :, 0, : n - 1], -1)
        out[n] = BatchedSolverResult(
            solver="batched_beam", backend="numpy", n_devices=n,
            splits=splits, cost_s=np.where(feas, best_cost, INF),
            feasible=feas, wall_time_s=0.0,
        )
    # one shared family wall, stamped after reconstruction (the
    # BatchedSolverResult timing-scope contract)
    wall = time.perf_counter() - t0
    return {n: replace(out[n], wall_time_s=wall) for n in sizes}


BATCHED_SOLVERS: dict[str, Callable[..., BatchedSolverResult]] = {
    "batched_dp": batched_optimal_dp,
    "batched_beam": batched_beam_search,
    "batched_greedy": batched_greedy_search,
}


def solve_batched(
    C: np.ndarray,
    solver: str = "batched_dp",
    combine: str = "sum",
    backend: str = "numpy",
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> BatchedSolverResult:
    """The single dispatch point for batched solves over a stacked tensor
    (used by :func:`sweep`, ``planner.plan_split_batch``, the surface
    builder, and the adaptive manager — one place to extend when adding
    a solver). ``n_devices`` (optional per-scenario fleet sizes) is
    threaded to every solver, so heterogeneous fleet sizes batch
    uniformly regardless of algorithm.

    This kwarg signature is a thin shim over the planner tier: it
    constructs a :class:`repro.core.spec.PlanSpec` and resolves it via
    :class:`repro.core.spec.PlannerService`, so kwarg callers and spec
    callers run the SAME implementation (:func:`_solve_batched_impl`)
    and get bit-identical results (property-tested across all four
    :data:`DP_BACKENDS`). ``mesh_spec`` optionally names the
    ``backend="sharded"`` device mesh (see
    :class:`repro.core.spec.MeshSpec`)."""
    from repro.core.spec import PlannerService, tensor_spec  # lazy: tier below

    spec = tensor_spec(C, solver=solver, combine=combine, backend=backend,
                       n_devices=n_devices, mesh=mesh_spec, **solver_kwargs)
    return PlannerService().solve(spec, C)


def _solve_batched_impl(
    C: np.ndarray,
    solver: str = "batched_dp",
    combine: str = "sum",
    backend: str = "numpy",
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> BatchedSolverResult:
    """The retained dispatch body behind :func:`solve_batched` —
    called ONLY by :meth:`repro.core.spec.PlannerService.solve` so the
    spec path and the kwargs path cannot diverge."""
    if solver == "batched_dp":
        return batched_optimal_dp(C, combine=combine, backend=backend,
                                  n_devices=n_devices, mesh_spec=mesh_spec,
                                  **solver_kwargs)
    if solver in ("batched_beam", "batched_greedy"):
        if backend != "numpy":
            raise ValueError(f"{solver} supports backend='numpy' only")
        if mesh_spec is not None:
            raise ValueError(
                f"mesh_spec is a backend='sharded' knob; {solver} "
                f"runs on numpy only")
        fn = batched_beam_search if solver == "batched_beam" else batched_greedy_search
        return fn(C, combine=combine, n_devices=n_devices, **solver_kwargs)
    raise ValueError(f"unknown batched solver {solver!r}; "
                     f"options: {sorted(BATCHED_SOLVERS)}")

# batched solver name -> the scalar oracle it must match bit-for-bit
SCALAR_ORACLES: dict[str, str] = {
    "batched_dp": "optimal_dp",
    "batched_beam": "beam",
    "batched_greedy": "greedy",
}


# ---------------------------------------------------------------------------
# Multi-channel solves (latency + energy; budgets and weighted combines)
# ---------------------------------------------------------------------------


def apply_energy_budget(
    C: np.ndarray,
    E: np.ndarray,
    energy_budget: float | np.ndarray | Sequence[float] | None,
) -> np.ndarray:
    """Mask the latency tensor ``C`` to +inf wherever the matching energy
    tensor ``E`` exceeds the per-device ``energy_budget``.

    Because every device executes exactly one segment, a per-device
    Joule budget is exactly a per-segment constraint — the masked tensor
    is an ordinary ``(S, N, L, L)`` cost tensor every existing backend
    (numpy / jax / sharded / pallas dense) solves unchanged, and the
    frozen-row ``n_devices`` machinery applies as-is.

    ``energy_budget``: ``None`` or +inf means unconstrained (``C`` is
    returned untouched — the identical object, keeping the degenerate
    path bit-exact); a scalar applies to every scenario; an ``(S,)``
    vector gives each scenario its own budget. The comparison is the
    same strict ``E > budget`` the scalar
    :func:`repro.core.solvers.budget_masked` wrapper uses."""
    if energy_budget is None:
        return C
    b = np.asarray(energy_budget, dtype=np.float64)
    if b.ndim == 0:
        if float(b) == INF:
            return C
        b = np.full(C.shape[0], float(b))
    if b.shape != (C.shape[0],):
        raise ValueError(
            f"energy_budget must be None, a scalar, or shape "
            f"({C.shape[0]},); got {b.shape}")
    if E.shape != C.shape:
        raise ValueError(f"energy tensor shape {E.shape} != cost tensor "
                         f"shape {C.shape}")
    return np.where(E > b[:, None, None, None], INF, C)


def combine_channels(
    C: np.ndarray, weights: Sequence[float]
) -> np.ndarray:
    """Scalarize a stacked multi-channel tensor ``C[ch, ...]`` into one
    cost tensor ``sum_ch weights[ch] * C[ch]`` (weighted latency×energy
    combine). Entries where ANY channel is non-finite scalarize to +inf
    (a zero weight must not resurrect an infeasible segment via
    ``0 * inf``)."""
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1 or w.shape[0] != C.shape[0]:
        raise ValueError(f"weights must have one entry per channel "
                         f"({C.shape[0]}), got shape {w.shape}")
    finite = np.isfinite(C).all(axis=0)
    with np.errstate(invalid="ignore"):
        eff = np.tensordot(w, np.where(np.isfinite(C), C, 0.0), axes=1)
    return np.where(finite, eff, INF)


def solve_multi_channel(
    C: np.ndarray,
    channels: Sequence[str] = COST_CHANNELS,
    solver: str = "batched_dp",
    combine: str = "sum",
    backend: str = "numpy",
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    energy_budget: float | np.ndarray | Sequence[float] | None = None,
    channel_weights: Sequence[float] | None = None,
    channel_combines: Sequence[str] | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> BatchedSolverResult:
    """Kwarg shim over the planner tier for multi-channel solves: builds
    a :class:`repro.core.spec.PlanSpec` and resolves it via
    :class:`repro.core.spec.PlannerService` — same implementation as
    the spec path (:func:`_solve_multi_channel_impl`), bit-identical
    results. See the impl for the solve semantics."""
    from repro.core.spec import PlannerService, channels_spec  # lazy

    spec = channels_spec(
        C, channels=channels, solver=solver, combine=combine,
        backend=backend, n_devices=n_devices, energy_budget=energy_budget,
        channel_weights=channel_weights, channel_combines=channel_combines,
        mesh=mesh_spec, **solver_kwargs)
    return PlannerService().solve_multi_channel(spec, C)


def _solve_multi_channel_impl(
    C: np.ndarray,
    channels: Sequence[str] = COST_CHANNELS,
    solver: str = "batched_dp",
    combine: str = "sum",
    backend: str = "numpy",
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    energy_budget: float | np.ndarray | Sequence[float] | None = None,
    channel_weights: Sequence[float] | None = None,
    channel_combines: Sequence[str] | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> BatchedSolverResult:
    """Multi-objective batched solve over a stacked channel tensor
    ``C[ch, s, k-1, a-1, b-1]`` (see :func:`stack_cost_tensors` with
    ``channels=``).

    Modes (composable):
      * **degenerate** — one channel, no budget, no weights: dispatches
        to :func:`solve_batched` on ``C[0]`` untouched, so the result is
        bit-exact (``==`` splits and costs) vs the single-channel path
        on every backend; the property suite pins this.
      * **budget** — ``energy_budget`` masks the latency channel to +inf
        wherever the ``"energy"`` channel exceeds the per-device budget
        (:func:`apply_energy_budget`), then minimizes latency: the
        paper-adjacent "minimize latency s.t. per-device energy" mode,
        zero-regret vs the budget-filtered scalar enumeration oracle.
      * **weighted** — ``channel_weights`` scalarizes the channels
        (:func:`combine_channels`) before the solve; may be combined
        with ``energy_budget`` (mask applies after scalarization).

    ``channel_combines`` gives each channel its own combine mode for the
    reported per-channel totals (default: the solve's ``combine`` for
    the latency channel, ``"sum"`` for energy — Joules add across
    devices even under a bottleneck latency objective). The result's
    ``channel_cost_s[ch, s]`` reports channel ``ch``'s total for the
    CHOSEN plan (not a per-channel optimum)."""
    C = np.asarray(C, dtype=np.float64)
    if C.ndim != 5:
        raise ValueError(f"C must be (n_channels, S, N, L, L), got {C.shape}")
    channels = tuple(channels)
    if C.shape[0] != len(channels):
        raise ValueError(f"C has {C.shape[0]} channel slices for "
                         f"{len(channels)} channel names {channels!r}")
    if solver_kwargs.get("return_all_k"):
        raise ValueError("solve_multi_channel does not support return_all_k")
    if len(channels) == 1 and energy_budget is None and channel_weights is None:
        return solve_batched(C[0], solver=solver, combine=combine,
                             backend=backend, n_devices=n_devices,
                             mesh_spec=mesh_spec, **solver_kwargs)
    try:
        lat = channels.index("latency")
    except ValueError:
        raise ValueError(f"channels {channels!r} lack a 'latency' entry") \
            from None
    if channel_weights is not None:
        C_eff = combine_channels(C, channel_weights)
    else:
        C_eff = C[lat]
    if energy_budget is not None:
        try:
            en = channels.index("energy")
        except ValueError:
            raise ValueError(f"energy_budget given but channels {channels!r} "
                             f"lack an 'energy' entry") from None
        C_eff = apply_energy_budget(C_eff, C[en], energy_budget)
    res = solve_batched(C_eff, solver=solver, combine=combine,
                        backend=backend, n_devices=n_devices,
                        mesh_spec=mesh_spec, **solver_kwargs)
    if channel_combines is None:
        channel_combines = tuple(
            combine if ch == "latency" else "sum" for ch in channels)
    safe_splits = np.maximum(res.splits, 1)
    per_ch = np.stack([
        np.where(res.feasible,
                 _per_scenario_total_cost(C[i], safe_splits, cmb,
                                          res.n_devices_s),
                 INF)
        for i, cmb in enumerate(channel_combines)
    ])
    return replace(res, channels=channels, channel_cost_s=per_ch)


# ---------------------------------------------------------------------------
# Variant-bank solves (joint split × bottleneck-variant decisions)
# ---------------------------------------------------------------------------


def apply_accuracy_floor(
    C: np.ndarray,
    accuracy_proxy: np.ndarray | Sequence[float] | None,
    accuracy_floor: float | None,
) -> np.ndarray:
    """Mask whole variant slices of a stacked variant tensor
    ``C[v, s, k-1, a-1, b-1]`` to +inf wherever the variant's
    ``accuracy_proxy`` falls below ``accuracy_floor``.

    This is the accuracy-constrained planning mode — ``min latency
    s.t. accuracy_proxy >= floor`` — expressed exactly like
    :func:`apply_energy_budget`: the constraint becomes +inf entries in
    an ordinary cost tensor every existing backend solves unchanged.
    ``accuracy_floor=None`` means unconstrained (``C`` is returned
    untouched — the identical object, keeping the degenerate path
    bit-exact); the comparison is the same strict inequality the scalar
    :func:`repro.core.solvers._best_variant` dispatcher uses
    (``accuracy_proxy < floor`` masks)."""
    if accuracy_floor is None:
        return C
    if accuracy_proxy is None:
        raise ValueError("accuracy_floor given without accuracy_proxy")
    acc = np.asarray(accuracy_proxy, dtype=np.float64)
    if acc.ndim != 1 or acc.shape[0] != C.shape[0]:
        raise ValueError(
            f"accuracy_proxy must have one entry per variant "
            f"({C.shape[0]},); got shape {acc.shape}")
    mask = acc < float(accuracy_floor)
    if not mask.any():
        return C
    return np.where(mask[:, None, None, None, None], INF, C)


def _fold_variant_axis(
    res: BatchedSolverResult, V: int, Sn: int
) -> tuple[BatchedSolverResult, np.ndarray]:
    """Collapse a variant-major folded solve (``V*Sn`` scenarios, index
    ``v*Sn + s``) back to ``Sn`` scenarios: per-scenario argmin over the
    ``V`` stacked costs. ``np.argmin`` keeps the FIRST minimum — the
    lowest variant index on exact ties, matching the scalar
    ``_best_variant`` strict-``<`` loop. Returns the folded result
    (``variant`` set, -1 where infeasible) and the winning row indices
    into the folded scenario axis (callers gather per-node data — e.g.
    the winning variant's cost-tensor rows — with them)."""
    cost_vs = res.cost_s.reshape(V, Sn)
    v_star = np.argmin(cost_vs, axis=0)
    s_idx = np.arange(Sn)
    rows = v_star * Sn + s_idx
    feasible = res.feasible[rows]
    variant = np.where(feasible, v_star, -1).astype(np.int64)
    folded = BatchedSolverResult(
        solver=res.solver,
        backend=res.backend,
        n_devices=res.n_devices,
        splits=res.splits[rows],
        cost_s=cost_vs[v_star, s_idx],
        feasible=feasible,
        wall_time_s=res.wall_time_s,
        n_devices_s=(None if res.n_devices_s is None
                     else res.n_devices_s[rows]),
        variant=variant,
    )
    return folded, rows


def solve_variant_bank(
    C: np.ndarray,
    solver: str = "batched_dp",
    combine: str = "sum",
    backend: str = "numpy",
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    accuracy_proxy: np.ndarray | Sequence[float] | None = None,
    accuracy_floor: float | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> BatchedSolverResult:
    """Kwarg shim over the planner tier for joint (split, variant)
    solves: builds a :class:`repro.core.spec.PlanSpec` and resolves it
    via :class:`repro.core.spec.PlannerService` — same implementation
    as the spec path (:func:`_solve_variant_bank_impl`), bit-identical
    results. See the impl for the solve semantics."""
    from repro.core.spec import PlannerService, variant_bank_spec  # lazy

    spec = variant_bank_spec(
        C, solver=solver, combine=combine, backend=backend,
        n_devices=n_devices, accuracy_proxy=accuracy_proxy,
        accuracy_floor=accuracy_floor, mesh=mesh_spec, **solver_kwargs)
    return PlannerService().solve_variant_bank(spec, C)


def _solve_variant_bank_impl(
    C: np.ndarray,
    solver: str = "batched_dp",
    combine: str = "sum",
    backend: str = "numpy",
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    accuracy_proxy: np.ndarray | Sequence[float] | None = None,
    accuracy_floor: float | None = None,
    mesh_spec=None,
    **solver_kwargs,
) -> BatchedSolverResult:
    """Jointly optimize ``(split point, bottleneck variant)`` over a
    stacked variant tensor ``C[v, s, k-1, a-1, b-1]`` (see
    :func:`stack_cost_tensors` with ``variants=``).

    The variant axis folds into the scenario axis — the ``(V, S, N, L,
    L)`` tensor reshapes (C-order, variant-major) to ``(V*S, N, L, L)``
    and ONE batched solve prices every (variant, scenario) pair; the
    per-scenario winner is then the argmin over the ``V`` stacked
    costs. ``np.argmin`` keeps the FIRST minimum, i.e. the
    lowest-index variant on exact cost ties — the same strict-``<``
    tie-break the scalar :func:`repro.core.solvers._best_variant` loop
    applies, so batched and scalar joint solves agree bitwise.

    Degenerate dispatch: ``V == 1`` (after any ``accuracy_floor``
    masking ``V == 1`` stays one slice) solves ``C[0]`` via
    :func:`solve_batched` untouched, so single-variant runs are
    bit-exact vs the historical path on every backend; the property
    suite pins this for all four ``DP_BACKENDS``.

    ``accuracy_proxy`` (one entry per variant) + ``accuracy_floor``
    enable accuracy-constrained planning via
    :func:`apply_accuracy_floor`. The result's ``variant[s]`` is the
    winning bank index (-1 where no variant is feasible); ``splits``,
    ``cost_s`` and ``feasible`` describe the winning variant's plan."""
    C = np.asarray(C, dtype=np.float64)
    if C.ndim != 5:
        raise ValueError(f"C must be (n_variants, S, N, L, L), got {C.shape}")
    if solver_kwargs.get("return_all_k"):
        raise ValueError("solve_variant_bank does not support return_all_k")
    V, Sn, N, L, _ = C.shape
    acc = None
    if accuracy_proxy is not None:
        acc = np.asarray(accuracy_proxy, dtype=np.float64)
    C = apply_accuracy_floor(C, acc, accuracy_floor)
    if V == 1:
        res = solve_batched(C[0], solver=solver, combine=combine,
                            backend=backend, n_devices=n_devices,
                            mesh_spec=mesh_spec, **solver_kwargs)
        variant = np.where(res.feasible, 0, -1).astype(np.int64)
        return replace(res, variant=variant)
    ns = _normalize_ns(n_devices, Sn, N) if n_devices is not None else None
    folded_ns = None if ns is None else np.tile(ns, V)
    res = solve_batched(C.reshape(V * Sn, N, L, L), solver=solver,
                        combine=combine, backend=backend,
                        n_devices=folded_ns, mesh_spec=mesh_spec,
                        **solver_kwargs)
    folded, _ = _fold_variant_axis(res, V, Sn)
    return folded


# ---------------------------------------------------------------------------
# ScenarioGrid — the fleet-sweep API
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """One point of a :class:`ScenarioGrid` (a what-if the planner prices).

    ``mix`` names the device mix this scenario's fleet draws from
    (``None`` = the grid's shared ``devices`` tuple, the paper's
    homogeneous ESP32 fleet).

    ``contention`` is the number of devices time-sharing the scenario's
    physical channel (1 = uncontended, the historical bit-exact path);
    ``energy_budget`` the per-device Joule cap (``None`` =
    unconstrained)."""

    model: str
    protocol: str
    n_devices: int
    loss_p: float | None  # None -> protocol default
    rate_scale: float  # multiplier on the link serialization rate
    mix: str | None = None  # device-mix name (None -> grid.devices)
    contention: int = 1  # concurrent transmitters sharing the channel
    energy_budget: float | None = None  # per-device Joule cap
    compression: float = 1.0  # bottleneck compression factor (1.0 = identity)

    def describe(self) -> str:
        loss = "base" if self.loss_p is None else f"p={self.loss_p:g}"
        mix = "" if self.mix is None else f" mix={self.mix}"
        con = "" if self.contention <= 1 else f" tx={self.contention}"
        eb = "" if self.energy_budget is None else f" E<={self.energy_budget:g}J"
        cx = "" if self.compression == 1.0 else f" cx{self.compression:g}"
        return (f"{self.model}/{self.protocol} N={self.n_devices} "
                f"{loss} rate×{self.rate_scale:g}{mix}{con}{eb}{cx}")


@dataclass(frozen=True)
class ScenarioGrid:
    """A dense grid of split-planning scenarios:
    models × device mixes × fleet sizes × links × loss rates × rate scales.

    ``models`` maps names to :class:`ModelCostProfile`; ``links`` maps
    protocol names to :class:`LinkProfile`. ``devices`` is the device
    profile tuple shared by all scenarios (a single profile broadcasts
    over any fleet size, as in the paper's homogeneous ESP32 fleet).

    ``device_mixes`` optionally adds a heterogeneous-fleet axis: it maps
    mix names to device-profile tuples and every mix becomes one more
    scenario coordinate (``Scenario.mix``). Within a mix, device ``k``
    runs profile ``mix[k-1]`` (a length-1 mix broadcasts like
    ``devices``); a multi-profile mix must cover the grid's largest
    fleet size. When ``device_mixes`` is set, ``devices`` may be empty
    — scenarios then always carry a mix. Mixed fleets batch in the same
    tensor pass as homogeneous ones: :func:`sweep` gathers each
    scenario's per-device cost matrices from a per-profile bank instead
    of rebuilding them per scenario.

    ``contention_groups`` adds a shared-channel axis: each entry is a
    number of devices time-sharing one physical channel (every
    transmitter then sees ``mac_efficiency / group`` of the nominal rate
    — see :class:`repro.core.latency.ContentionModel`; group 1 is the
    uncontended bit-exact default). ``energy_budgets`` adds a per-device
    Joule-cap axis (``None`` = unconstrained): budgeted scenarios
    minimize latency over the splits whose every segment fits the
    budget.

    ``compression_factors`` adds the bottleneck-variant axis: each
    entry is a compression factor applied at the cut (factor 1.0 is
    the identity variant — the bit-exact historical path). Non-identity
    factors build a :class:`repro.core.latency.BottleneckVariant` via
    :func:`repro.core.latency.bottleneck_variant` with the grid's
    ``variant_encoder_t_s`` / ``variant_encoder_s_per_byte`` /
    ``variant_accuracy_drop`` knobs: the cut payload shrinks to
    ``ceil(bytes / factor)``, sensor-side compute grows by the encoder
    cost, and the scenario's plan carries the variant's
    ``accuracy_proxy`` — the latency-vs-accuracy trade
    :meth:`SweepResult.pareto` extracts frontiers from."""

    models: Mapping[str, ModelCostProfile]
    links: Mapping[str, LinkProfile]
    n_devices: tuple[int, ...]
    loss_p: tuple[float | None, ...] = (None,)
    rate_scale: tuple[float, ...] = (1.0,)
    devices: tuple[DeviceProfile, ...] = ()
    objective: str = "sum"
    device_mixes: Mapping[str, tuple[DeviceProfile, ...]] | None = None
    contention_groups: tuple[int, ...] = (1,)
    energy_budgets: tuple[float | None, ...] = (None,)
    mac_efficiency: float = 1.0  # shared-channel MAC efficiency (see above)
    compression_factors: tuple[float, ...] = (1.0,)
    variant_encoder_t_s: float = 0.0  # fixed encoder latency per cut
    variant_encoder_s_per_byte: float = 0.0  # linear encoder latency per byte
    variant_accuracy_drop: float = 0.03  # accuracy-proxy drop per octave

    def __post_init__(self):
        if not self.devices and not self.device_mixes:
            raise ValueError("ScenarioGrid requires devices or device_mixes")
        for field_name in ("n_devices", "loss_p", "rate_scale",
                           "contention_groups", "energy_budgets",
                           "compression_factors"):
            object.__setattr__(self, field_name, tuple(getattr(self, field_name)))
        for g in self.contention_groups:
            if g < 1:
                raise ValueError(f"contention group must be >= 1, got {g}")
        for cf in self.compression_factors:
            if cf < 1.0:
                raise ValueError(
                    f"compression factor must be >= 1, got {cf}")
        object.__setattr__(self, "models", dict(self.models))
        object.__setattr__(self, "links", dict(self.links))
        if self.device_mixes is not None:
            mixes = {name: tuple(m) for name, m in dict(self.device_mixes).items()}
            n_max = max(self.n_devices) if self.n_devices else 0
            for name, m in mixes.items():
                if not m:
                    raise ValueError(f"device mix {name!r} is empty")
                if 1 < len(m) < n_max:
                    raise ValueError(
                        f"device mix {name!r} has {len(m)} profiles but the "
                        f"grid asks for up to {n_max} devices (a single "
                        f"profile broadcasts; several must cover every "
                        f"fleet size)")
            object.__setattr__(self, "device_mixes", mixes)

    @property
    def mix_names(self) -> tuple[str | None, ...]:
        """The device-mix axis. ``(None,)`` when the grid is homogeneous;
        with ``device_mixes`` set, the named mixes — plus a leading
        ``None`` entry for the shared ``devices`` fleet when that is
        also provided (so declaring mixes never silently drops the
        homogeneous baseline)."""
        if self.device_mixes:
            base: tuple[str | None, ...] = (None,) if self.devices else ()
            return base + tuple(self.device_mixes)
        return (None,)

    @property
    def size(self) -> int:
        return (len(self.models) * len(self.links) * len(self.n_devices)
                * len(self.loss_p) * len(self.rate_scale)
                * len(self.mix_names) * len(self.contention_groups)
                * len(self.energy_budgets) * len(self.compression_factors))

    def scenarios(self) -> list[Scenario]:
        """Deterministic enumeration order: model-major, then device mix,
        then fleet size, then protocol × loss × rate × contention ×
        energy budget × compression (the link axes batch densely)."""
        return [
            Scenario(m, p, n, lp, rs, mix=mx, contention=cg, energy_budget=eb,
                     compression=cf)
            for m in self.models
            for mx in self.mix_names
            for n in self.n_devices
            for p in self.links
            for lp in self.loss_p
            for rs in self.rate_scale
            for cg in self.contention_groups
            for eb in self.energy_budgets
            for cf in self.compression_factors
        ]

    def link_variant(self, sc: Scenario) -> LinkProfile:
        """The scenario's link: the protocol's base profile with the
        scenario's loss (``None`` keeps the protocol's base loss) and
        rate scale applied."""
        link = self.links[sc.protocol]
        changes: dict = {}
        if sc.loss_p is not None:
            changes["loss_p"] = sc.loss_p
        if sc.rate_scale != 1.0:
            changes["rate_bytes_per_s"] = link.rate_bytes_per_s * sc.rate_scale
        return replace(link, **changes) if changes else link

    def contention_model(self, sc: Scenario) -> ContentionModel | None:
        """The scenario's shared-channel schedule (``None`` for the
        uncontended group of 1 — the bit-exact historical path)."""
        if sc.contention <= 1:
            return None
        return ContentionModel(transmitters=sc.contention,
                               mac_efficiency=self.mac_efficiency)

    def effective_link(self, sc: Scenario) -> LinkProfile:
        """:meth:`link_variant` with the scenario's contention applied —
        the link every transmission price (batched and scalar) sees."""
        link = self.link_variant(sc)
        con = self.contention_model(sc)
        return link if con is None else con.apply(link)

    def devices_for(self, sc: Scenario) -> tuple[DeviceProfile, ...]:
        """The device-profile tuple scenario ``sc``'s fleet runs on
        (its named mix, or the grid's shared ``devices``)."""
        if sc.mix is not None:
            return self.device_mixes[sc.mix]
        return self.devices

    def variant_for(self, sc: Scenario) -> BottleneckVariant | None:
        """The scenario's bottleneck variant (``None`` for compression
        factor 1.0 — the bit-exact historical path), built from the
        grid's encoder/accuracy knobs."""
        if sc.compression == 1.0:
            return None
        return bottleneck_variant(
            sc.compression,
            encoder_t_s=self.variant_encoder_t_s,
            encoder_s_per_byte=self.variant_encoder_s_per_byte,
            accuracy_drop_per_octave=self.variant_accuracy_drop,
        )

    def accuracy_for(self, sc: Scenario) -> float:
        """The scenario's accuracy proxy (1.0 for the identity variant)."""
        v = self.variant_for(sc)
        return 1.0 if v is None else v.accuracy_proxy

    def cost_model(self, sc: Scenario) -> SplitCostModel:
        """The scalar-oracle :class:`SplitCostModel` for one scenario."""
        return SplitCostModel(
            profile=self.models[sc.model], devices=self.devices_for(sc),
            link=self.link_variant(sc), objective=self.objective,
            contention=self.contention_model(sc),
            variant=self.variant_for(sc),
        )

    def degradation_surface(self, model: str | None = None,
                            n_devices: int | None = None,
                            mix: str | None = None, **kwargs):
        """Precompute a :class:`~repro.core.surface.DegradationSurface`
        whose packet-time/loss axes derive from this grid's
        ``rate_scale``/``loss_p`` axes (the sweep's link what-ifs become
        the runtime's O(1) replanning lookup table). ``n_devices``
        defaults to the grid's largest fleet size; ``mix`` selects a
        device mix (see :meth:`devices_for` semantics)."""
        from repro.core.surface import DegradationSurface  # lazy: no cycle

        return DegradationSurface.from_scenario_grid(
            self, model=model, n_devices=n_devices, mix=mix, **kwargs)

    def degradation_surfaces(self, model: str | None = None,
                             n_devices: Sequence[int] | None = None,
                             mix: str | None = None, **kwargs):
        """Precompute surfaces for SEVERAL fleet sizes — one per entry
        of ``n_devices`` (default: this grid's whole ``n_devices``
        axis) — in ONE batched solver pass (no per-N re-solve loop; see
        :func:`repro.core.surface.build_surfaces`). Returns
        ``{n: DegradationSurface}``."""
        from repro.core import surface as SF  # lazy: no cycle

        cost_model, pt_scales, losses = SF._grid_surface_args(self, model, mix)
        sizes = tuple(n_devices) if n_devices is not None else self.n_devices
        return SF.build_surfaces(
            cost_model, self.links, sizes,
            pt_scale=pt_scales, loss_p=losses, **kwargs)


@dataclass(frozen=True)
class SweepRow:
    """Per-scenario best plan from a sweep."""

    scenario: Scenario
    splits: tuple[int, ...]
    feasible: bool
    objective_cost_s: float  # solver objective (no setup/feedback)
    total_latency_s: float  # Eq. 8 incl. link setup + feedback overheads
    device_s: float  # summed device-local segment latency
    transmission_s: float  # summed cut transmission + encoder latency
    solver_wall_s: float  # this scenario's share of the batched solve
    accuracy_proxy: float = 1.0  # the scenario variant's accuracy proxy

    def to_dict(self) -> dict:
        d = dict(self.scenario.__dict__)
        d.update(
            splits=list(self.splits), feasible=self.feasible,
            objective_cost_s=self.objective_cost_s,
            total_latency_s=self.total_latency_s,
            device_s=self.device_s, transmission_s=self.transmission_s,
            solver_wall_s=self.solver_wall_s,
            accuracy_proxy=self.accuracy_proxy,
        )
        return d


@dataclass(frozen=True)
class SweepResult:
    """Dense sweep output: one row per scenario, grid order preserved."""

    rows: tuple[SweepRow, ...]
    solver: str
    backend: str
    solve_time_s: float  # batched solver passes only
    build_time_s: float  # cost-tensor assembly

    @property
    def n_scenarios(self) -> int:
        return len(self.rows)

    @property
    def scenarios_per_sec(self) -> float:
        total = self.solve_time_s + self.build_time_s
        return self.n_scenarios / total if total > 0 else INF

    def best(self, **filters) -> SweepRow:
        """Lowest-latency feasible row among those matching scenario-field
        filters, e.g. ``best(model="mobilenet_v2", n_devices=4)``."""
        pool = [
            r for r in self.rows
            if r.feasible
            and all(getattr(r.scenario, k) == v for k, v in filters.items())
        ]
        if not pool:
            raise LookupError(f"no feasible scenario matches {filters!r}")
        return min(pool, key=lambda r: r.total_latency_s)

    def to_dicts(self) -> list[dict]:
        return [r.to_dict() for r in self.rows]

    def to_json(self, indent: int | None = None) -> str:
        def _clean(v):
            return None if isinstance(v, float) and not np.isfinite(v) else v

        payload = {
            "solver": self.solver, "backend": self.backend,
            "n_scenarios": self.n_scenarios,
            "solve_time_s": self.solve_time_s, "build_time_s": self.build_time_s,
            "scenarios_per_sec": self.scenarios_per_sec,
            "rows": [{k: _clean(v) for k, v in d.items()} for d in self.to_dicts()],
        }
        return json.dumps(payload, indent=indent)

    def to_csv(self) -> str:
        cols = ["model", "protocol", "n_devices", "loss_p", "rate_scale",
                "mix", "contention", "energy_budget", "compression",
                "feasible", "splits", "objective_cost_s", "total_latency_s",
                "accuracy_proxy", "device_s", "transmission_s",
                "solver_wall_s"]
        lines = [",".join(cols)]
        for d in self.to_dicts():
            d["splits"] = "|".join(str(x) for x in d["splits"])
            lines.append(",".join(str(d[c]) for c in cols))
        return "\n".join(lines) + "\n"

    def pareto(
        self, by: Sequence[str] = ("model", "protocol", "n_devices")
    ) -> dict[tuple, "ParetoFrontier"]:
        """Latency-vs-accuracy Pareto frontiers, one per distinct value
        of the ``by`` scenario fields (default: per model × protocol ×
        fleet size). Within each group the non-dominated set over
        ``(total_latency_s, accuracy_proxy)`` is extracted by
        :func:`pareto_frontier`; rows differing only in compression
        factor (and any other swept axes not named in ``by``) compete
        in the same frontier."""
        by = tuple(by)
        groups: dict[tuple, list[SweepRow]] = {}
        for r in self.rows:
            key = tuple(getattr(r.scenario, k) for k in by)
            groups.setdefault(key, []).append(r)
        return {key: ParetoFrontier(by=by, key=key, rows=pareto_frontier(g))
                for key, g in groups.items()}


def pareto_frontier(rows: Sequence[SweepRow]) -> tuple[SweepRow, ...]:
    """The non-dominated subset of ``rows`` under minimize
    ``total_latency_s`` / maximize ``accuracy_proxy``.

    Row ``r`` is dominated iff some other row has latency <= and
    accuracy >= with at least one strict inequality; exact duplicates
    on both axes all survive (neither dominates the other). Infeasible
    rows never enter the frontier. The extraction is the O(n^2)
    pairwise definition verbatim — frontier sizes are small and the
    semantics stay visibly identical to the brute-force oracle the
    property suite compares against. Result is sorted by ascending
    latency (descending accuracy on ties)."""
    feas = [r for r in rows if r.feasible]
    front = []
    for r in feas:
        dominated = False
        for o in feas:
            if (o.total_latency_s <= r.total_latency_s
                    and o.accuracy_proxy >= r.accuracy_proxy
                    and (o.total_latency_s < r.total_latency_s
                         or o.accuracy_proxy > r.accuracy_proxy)):
                dominated = True
                break
        if not dominated:
            front.append(r)
    front.sort(key=lambda r: (r.total_latency_s, -r.accuracy_proxy))
    return tuple(front)


@dataclass(frozen=True)
class ParetoFrontier:
    """One group's latency-vs-accuracy frontier (see
    :meth:`SweepResult.pareto`): the non-dominated rows, sorted by
    ascending latency."""

    by: tuple[str, ...]  # the scenario fields the group was keyed on
    key: tuple  # this group's values for those fields
    rows: tuple[SweepRow, ...]  # non-dominated, ascending latency

    @property
    def n_points(self) -> int:
        return len(self.rows)

    def to_csv(self) -> str:
        cols = list(self.by) + ["compression", "accuracy_proxy",
                                "total_latency_s", "splits"]
        lines = [",".join(cols)]
        for r in self.rows:
            vals = [str(getattr(r.scenario, k)) for k in self.by]
            vals += [str(r.scenario.compression), str(r.accuracy_proxy),
                     str(r.total_latency_s),
                     "|".join(str(x) for x in r.splits)]
            lines.append(",".join(vals))
        return "\n".join(lines) + "\n"


def _group_tx_vectors(
    grid: ScenarioGrid, profile: ModelCostProfile, group: list[Scenario]
) -> tuple[np.ndarray, np.ndarray | None, np.ndarray | None]:
    """(S_g, L) transmission-cost vectors, amortizing packet counts per
    (MTU, compression factor) against per-scenario packet times.
    Airtime is priced on each scenario's contention-scaled effective
    link, matching the scalar oracle's :attr:`SplitCostModel.effective_link`;
    a scenario with a bottleneck variant prices K on the compressed cut
    bytes and adds the encoder-time vector, matching
    :meth:`SplitCostModel.transmission_cost_vector` term-for-term.

    Returns ``(TX, AIR, ENC)``. ``TX`` is what the latency tensor adds
    (airtime + encoder time). ``AIR``/``ENC`` split that into pure
    airtime and encoder time for the energy tensor, which prices them
    at different powers (radio vs device); both are ``None`` when no
    scenario in the group carries a variant — the historical
    single-array path, bit-exact because identity rows never see a
    ``+ 0.0``."""
    L = profile.num_layers
    act_raw = profile.segment_arrays.boundary_act_bytes[1:].astype(np.float64)
    variants = [grid.variant_for(sc) for sc in group]
    any_variant = any(v is not None for v in variants)
    packets_by_key: dict[tuple[int, float], np.ndarray] = {}
    enc_by_factor: dict[float, np.ndarray] = {}
    out = np.empty((len(group), L))
    air_out = np.empty((len(group), L)) if any_variant else None
    enc_out = np.zeros((len(group), L)) if any_variant else None
    for i, (sc, v) in enumerate(zip(group, variants)):
        link = grid.effective_link(sc)
        factor = 1.0 if v is None else v.compression_factor
        K = packets_by_key.get((link.mtu_bytes, factor))
        if K is None:
            if v is None:
                act = act_raw
            else:
                act = np.where(act_raw > 0,
                               np.ceil(act_raw / v.compression_factor), 0.0)
            K = np.where(act > 0, np.ceil(act / link.mtu_bytes), 0.0)
            packets_by_key[(link.mtu_bytes, factor)] = K
        tx = K * link.packet_time_s()
        tx[-1] = 0.0
        if air_out is not None:
            air_out[i] = tx
        if v is not None:
            enc = enc_by_factor.get(factor)
            if enc is None:
                enc = np.where(act_raw > 0,
                               v.encoder_t_s + act_raw * v.encoder_s_per_byte,
                               0.0)
                enc[-1] = 0.0
                enc_by_factor[factor] = enc
            enc_out[i] = enc
            tx = tx + enc
        out[i] = tx
    return out, air_out, enc_out


def _group_energy_tensor(
    grid: ScenarioGrid,
    group: list[Scenario],
    bank: np.ndarray,
    bank_rows: Mapping[tuple[DeviceProfile, bool], int],
    bank_idx: np.ndarray,
    AIR: np.ndarray,
    ENC: np.ndarray | None = None,
) -> np.ndarray:
    """(S_g, N_max, L, L) energy tensor for one sweep group, assembled
    from the SAME profile bank and transmission vectors as the latency
    tensor — entry ``[gi, k-1, a-1, b-1]`` is bit-identical to the
    scenario's own :meth:`SplitCostModel.energy_cost_tensor` (same
    power × airtime products in the same order) for every live device
    slot ``k <= n_s``; filler slots beyond a scenario's fleet size carry
    bank-row-0 garbage the solvers never read, like the latency tensor.

    ``AIR`` is the pure-airtime vector stack (radio-priced at
    tx/rx power); ``ENC``, when a scenario carries a bottleneck
    variant, holds the encoder-time vectors priced at the transmitting
    device's active power — the same decomposition the scalar
    :meth:`SplitCostModel.segment_energy_j` applies."""
    L = AIR.shape[1]
    row_power = np.zeros(len(bank), dtype=np.float64)
    for (dev, _is_first), row in bank_rows.items():
        row_power[row] = dev.active_power_w
    with np.errstate(invalid="ignore"):
        e_bank = np.where(np.isfinite(bank),
                          row_power[:, None, None] * bank, INF)
    E = e_bank[bank_idx]  # (S_g, N_max, L, L)
    if ENC is not None:
        pw = row_power[bank_idx]  # (S_g, N_max) per-slot active power
        E = E + pw[:, :, None, None] * ENC[:, None, None, :]
    rx_t = np.zeros_like(AIR)
    rx_t[:, 1:] = AIR[:, : L - 1]  # [a-1] = airtime of the cut entering at a
    tx_p = np.array([grid.effective_link(sc).tx_power_w for sc in group])
    rx_p = np.array([grid.effective_link(sc).rx_power_w for sc in group])
    E = E + (tx_p[:, None] * AIR)[:, None, None, :]
    E = E + (rx_p[:, None] * rx_t)[:, None, :, None]
    return E


def sweep(
    grid: ScenarioGrid,
    solver: str = "batched_dp",
    backend: str = "numpy",
    beam_width: int = 8,
) -> SweepResult:
    """Plan every scenario of ``grid`` in batched passes.

    Args:
      grid: the scenario grid to price.
      solver: one of :data:`BATCHED_SOLVERS` (``batched_dp`` /
        ``batched_beam`` / ``batched_greedy``).
      backend: a :data:`DP_BACKENDS` key — ``"numpy"`` (bit-parity
        float64), ``"jax"``, ``"sharded"`` (scenario axis partitioned
        over the local JAX device mesh; see :mod:`repro.core.shard`),
        or ``"pallas"`` (cost construction fused into the kernel from
        the profile bank + transmission vectors, ``C`` never
        materialized; see :mod:`repro.core.pallas_dp`) — all but
        ``"numpy"`` for ``batched_dp`` only.
      beam_width: beam width when ``solver="batched_beam"``.

    Returns a :class:`SweepResult` with one :class:`SweepRow` per
    scenario, in grid enumeration order.

    Scenarios are grouped by model; within a group every fleet size and
    device mix stacks into one ``(S_g, N_max, L, L)`` tensor — each
    scenario's per-device cost matrices are gathered from a bank with
    one entry per distinct ``(DeviceProfile, is_first)`` pair, smaller
    fleets ride the same tensor via the per-scenario ``n_devices``
    vector (device slices beyond a scenario's own fleet size hold
    arbitrary finite filler — bank row 0 — which the solvers are
    guaranteed never to read; do NOT rely on them being +inf), and
    the link axes (protocol × loss × rate) batch densely. One solver
    pass prices the whole group: heterogeneous fleet sizes AND device
    mixes no longer force per-(model, N) re-solve loops.

    Invariants:
      * With ``solver="batched_dp"`` (and ``batched_greedy``) the
        returned splits are bit-identical to running the scalar oracle
        per scenario — the property-test contract
        (``tests/test_solver_properties.py``); ``batched_beam`` matches
        except under exact floating-point cost ties.
      * Row order always equals ``grid.scenarios()`` order regardless
        of grouping."""
    if solver not in BATCHED_SOLVERS:
        raise ValueError(f"unknown batched solver {solver!r}; "
                         f"options: {sorted(BATCHED_SOLVERS)}")
    if backend != "numpy" and solver != "batched_dp":
        # same contract as build_surfaces/solve_batched: never silently
        # downgrade a requested backend (the SweepResult records it)
        raise ValueError(f"{solver} supports backend='numpy' only "
                         f"(got {backend!r})")
    combine = "max" if grid.objective == "bottleneck" else "sum"
    order = grid.scenarios()
    # group scenarios (preserving order within groups) by model; fleet
    # size and device mix are per-scenario data, not group keys
    groups: dict[str, list[int]] = {}
    for idx, sc in enumerate(order):
        groups.setdefault(sc.model, []).append(idx)

    rows: dict[int, SweepRow] = {}
    build_time = 0.0
    solve_time = 0.0
    for model_name, idxs in groups.items():
        profile = grid.models[model_name]
        L = profile.num_layers
        group = [order[i] for i in idxs]
        t0 = time.perf_counter()
        n_max = max(sc.n_devices for sc in group)
        ns = np.array([sc.n_devices for sc in group], dtype=np.int64)
        base_model = SplitCostModel(
            profile=profile, devices=grid.devices_for(group[0]),
            link=next(iter(grid.links.values())), objective=grid.objective,
        )
        # profile bank: one local matrix per (device profile, is-first);
        # every scenario's tensor is ONE vectorized gather over the
        # stacked bank, so heterogeneous mixes cost O(bank) matrix
        # builds + a single fancy-index, not O(S) Python copies
        bank_rows: dict[tuple[DeviceProfile, bool], int] = {}
        bank_mats: list[np.ndarray] = []

        def bank_index(dev: DeviceProfile, is_first: bool) -> int:
            key = (dev, is_first)
            row = bank_rows.get(key)
            if row is None:
                row = len(bank_mats)
                bank_rows[key] = row
                bank_mats.append(base_model._local_cost_matrix(dev, is_first))
            return row

        bank_idx = np.zeros((len(group), n_max), dtype=np.int64)
        for gi, sc in enumerate(group):
            devs = grid.devices_for(sc)
            for k in range(1, sc.n_devices + 1):
                dev = devs[0] if len(devs) == 1 else devs[k - 1]
                bank_idx[gi, k - 1] = bank_index(dev, k == 1)
            # device slots beyond a scenario's own fleet size keep row 0
            # filler: the solvers never read them (the per-scenario
            # n_devices vector masks every k > n_s)
        # TX = airtime + encoder time per scenario (AIR/ENC split them
        # out for energy pricing; None when the group is all-identity)
        TX, AIR, ENC = _group_tx_vectors(grid, profile, group)  # (S_g, L)
        bank = np.stack(bank_mats)
        budgets = np.array(
            [INF if sc.energy_budget is None else float(sc.energy_budget)
             for sc in group])
        budgeted = bool(np.isfinite(budgets).any())
        if backend == "pallas" and not budgeted:
            # fused path: the kernel builds C[s,k] = bank[idx] + TX[s]
            # inside each reduction step — the (S_g, N, L, L) tensor is
            # never materialized, on host or device
            build_time += time.perf_counter() - t0
            from repro.core import pallas_dp as _pallas  # lazy, like shard

            res = _pallas.pallas_fused_optimal_dp(
                bank, bank_idx, TX, combine=combine, n_devices=ns)
        else:
            if bool((bank_idx == bank_idx[0]).all()):
                # homogeneous group (every scenario the same device
                # stack): broadcast one local tensor, don't gather S copies
                local = bank[bank_idx[0]]  # (N_max, L, L)
                C = local[None, :, :, :] + TX[:, None, None, :]
            else:
                C = bank[bank_idx]  # (S_g, N_max, L, L) gather
                C += TX[:, None, None, :]
            if budgeted:
                # energy budgets mask the latency tensor before dispatch,
                # so every backend — pallas included, in dense mode on
                # the materialized masked tensor — solves unchanged
                E = _group_energy_tensor(grid, group, bank, bank_rows,
                                         bank_idx,
                                         AIR if AIR is not None else TX, ENC)
                C = apply_energy_budget(C, E, budgets)
            build_time += time.perf_counter() - t0

            kwargs = {"beam_width": beam_width} if solver == "batched_beam" else {}
            res = solve_batched(C, solver=solver, combine=combine,
                                backend=backend, n_devices=ns, **kwargs)
        solve_time += res.wall_time_s
        per_scn_wall = res.wall_time_s / max(1, len(group))

        # cost breakdowns from the same tensors (no scalar re-walks)
        for gi, (idx, sc) in enumerate(zip(idxs, group)):
            n = sc.n_devices
            splits_t = res.splits_tuple(gi)
            feasible = bool(res.feasible[gi])
            link = grid.effective_link(sc)
            if splits_t or n == 1:
                bounds = [0, *splits_t, L] if feasible else None
            else:
                bounds = None
            if feasible and bounds is not None:
                tx_total = float(np.sum(TX[gi, [b - 1 for b in bounds[1:-1]]])) \
                    if len(bounds) > 2 else 0.0
                obj = float(res.cost_s[gi])
                # device/transmission totals summed over all segments; for
                # the "sum" objective device_s + transmission_s == objective.
                # Priced from the bank + TX decomposition (bitwise equal to
                # the C entries, which are built as exactly this f64 sum) so
                # the pallas path needs no materialized tensor either.
                seg_sum = float(sum(
                    bank[bank_idx[gi, i], bounds[i], bounds[i + 1] - 1]
                    + TX[gi, bounds[i + 1] - 1]
                    for i in range(len(bounds) - 1)))
                device_s = seg_sum - tx_total
                total = obj + link.t_setup_s + link.t_feedback_s
                rows[idx] = SweepRow(
                    scenario=sc, splits=splits_t, feasible=True,
                    objective_cost_s=obj, total_latency_s=total,
                    device_s=device_s, transmission_s=tx_total,
                    solver_wall_s=per_scn_wall,
                    accuracy_proxy=grid.accuracy_for(sc),
                )
            else:
                rows[idx] = SweepRow(
                    scenario=sc, splits=splits_t, feasible=False,
                    objective_cost_s=INF, total_latency_s=INF,
                    device_s=INF, transmission_s=INF,
                    solver_wall_s=per_scn_wall,
                    accuracy_proxy=grid.accuracy_for(sc),
                )
    ordered = tuple(rows[i] for i in range(len(order)))
    return SweepResult(rows=ordered, solver=solver, backend=backend,
                       solve_time_s=solve_time, build_time_s=build_time)


def sweep_scalar(grid: ScenarioGrid, solver: str = "optimal_dp") -> SweepResult:
    """The un-batched reference: one scalar solve per scenario (the
    per-scenario Python loop the batched engine replaces). Used as the
    parity oracle in tests and the baseline in benchmark speedup
    reporting. Device mixes flow through :meth:`ScenarioGrid.cost_model`
    (each scenario's :class:`SplitCostModel` carries its own fleet), so
    this loop is also the heterogeneous-fleet oracle."""
    combine = "max" if grid.objective == "bottleneck" else "sum"
    rows = []
    solve_time = 0.0
    build_time = 0.0
    for sc in grid.scenarios():
        t0 = time.perf_counter()
        m = grid.cost_model(sc)
        L = m.profile.num_layers
        fn = m.cost_segment_fn()
        build_time += time.perf_counter() - t0
        kwargs = {}
        if sc.energy_budget is not None:
            # the scalar solvers mask cost_fn by the same strict
            # per-segment comparison the batched path applies to the
            # stacked tensors, so parity holds under budgets too
            kwargs = dict(energy_fn=m.energy_segment_fn(),
                          energy_budget=sc.energy_budget)
        res = S.SOLVERS[solver](fn, L, sc.n_devices, combine=combine, **kwargs)
        solve_time += res.wall_time_s
        feasible = res.feasible
        if feasible:
            link = grid.effective_link(sc)
            bounds = [0, *res.splits, L]
            # cut_cost_s = compressed airtime + encoder time (identical
            # to the bare airtime for identity-variant scenarios)
            tx_total = sum(m.cut_cost_s(b) for b in bounds[1:-1])
            obj = res.cost_s
            seg_sum = S.total_cost(fn, res.splits, L, "sum")
            device_s = seg_sum - tx_total
            rows.append(SweepRow(
                scenario=sc, splits=res.splits, feasible=True,
                objective_cost_s=obj,
                total_latency_s=obj + link.t_setup_s + link.t_feedback_s,
                device_s=device_s, transmission_s=tx_total,
                solver_wall_s=res.wall_time_s,
                accuracy_proxy=grid.accuracy_for(sc),
            ))
        else:
            rows.append(SweepRow(
                scenario=sc, splits=res.splits, feasible=False,
                objective_cost_s=INF, total_latency_s=INF, device_s=INF,
                transmission_s=INF, solver_wall_s=res.wall_time_s,
                accuracy_proxy=grid.accuracy_for(sc),
            ))
    return SweepResult(rows=tuple(rows), solver=solver, backend="scalar",
                       solve_time_s=solve_time, build_time_s=build_time)


def parity_report(batched: SweepResult, scalar: SweepResult) -> list[str]:
    """Human-readable mismatch list between two sweeps of the same grid
    (empty = bit-identical splits everywhere, the acceptance contract)."""
    if batched.n_scenarios != scalar.n_scenarios:
        return [f"scenario count differs: {batched.n_scenarios} vs {scalar.n_scenarios}"]
    out = []
    for rb, rs in zip(batched.rows, scalar.rows):
        if tuple(rb.splits) != tuple(rs.splits) or rb.feasible != rs.feasible:
            out.append(f"{rb.scenario.describe()}: batched {rb.splits} "
                       f"vs scalar {rs.splits}")
    return out
