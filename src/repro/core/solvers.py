"""Split-point selection algorithms (Sec. IV-B, Algorithms 1-3).

All solvers minimize

    C(s) = combine_i CostSegment(s_{i-1}+1, s_i, i)          (Eq. 10)

over split configurations ``s = (s_1, ..., s_{N-1})`` with
``s_0 = 0 < s_1 < ... < s_{N-1} < s_N = L`` (Eq. 3), where
``combine`` is ``sum`` (paper-faithful, Eq. 5) or ``max`` (steady-state
pipeline bottleneck, used by the TPU planner).

Solvers take an opaque ``cost_fn(a, b, k) -> seconds`` so they are testable
against synthetic cost structures; segment costs are memoized since brute
force revisits each O(L^2) segment many times.

Every solver additionally accepts an optional **energy budget**
(``energy_fn(a, b, k) -> Joules`` + scalar ``energy_budget``): segments
whose energy exceeds the per-device budget are masked to +inf *before*
memoization, so search, pruning and feasibility lookahead all operate on
the constrained instance (see :func:`budget_masked`). Because every
device executes exactly one segment, the per-device constraint is exactly
this per-segment mask — ``brute_force`` on the masked instance is the
"enumerate, filter by budget, take min latency" oracle the batched
multi-channel solvers are property-tested against.

Implementation notes vs. the paper's pseudocode:
  * Alg. 1 line 5 iterates ``next in [pos+1, L-(N-k)]`` for every k≤N. At
    the final iteration (k = N) the segment must end exactly at L
    (``s_N = L``, Eq. 3); the pseudocode's open range would let incomplete
    configurations (cheaper, fewer layers) win line 12. We pin
    ``next = L`` at k = N — the obviously intended semantics.
  * Alg. 2/3 select N-1 split points; the cost of the implicit final
    segment [s_{N-1}+1, L] on device N is added to the reported total so
    totals are comparable across solvers.
"""

from __future__ import annotations

import heapq
import itertools
import random
import time
from dataclasses import dataclass, replace
from typing import Callable, Sequence

INF = float("inf")

CostFn = Callable[[int, int, int], float]


@dataclass(frozen=True)
class SolverResult:
    solver: str
    splits: tuple[int, ...]  # (s_1 .. s_{N-1})
    cost_s: float  # combined segment cost (no setup/feedback overheads)
    wall_time_s: float  # planner processing time (Figs. 3-4 right axes)
    nodes_expanded: int  # segment-cost evaluations (unique, memoized)
    variant: int | None = None  # winning variant index (None: no variant axis)

    @property
    def feasible(self) -> bool:
        return self.cost_s < INF


class _Memo:
    """Memoizing wrapper counting unique CostSegment evaluations."""

    def __init__(self, cost_fn: CostFn):
        self._fn = cost_fn
        self._cache: dict[tuple[int, int, int], float] = {}

    def __call__(self, a: int, b: int, k: int) -> float:
        key = (a, b, k)
        hit = self._cache.get(key)
        if hit is None:
            hit = self._fn(a, b, k)
            self._cache[key] = hit
        return hit

    @property
    def evals(self) -> int:
        return len(self._cache)


def budget_masked(
    cost_fn: CostFn,
    energy_fn: CostFn | None,
    energy_budget: float | None,
) -> CostFn:
    """``cost_fn`` with +inf wherever the segment's energy exceeds the
    per-device ``energy_budget``. With no energy model or no (finite)
    budget the original callable is returned unchanged, so the
    unconstrained path is bit-identical to the historical one."""
    if energy_fn is None or energy_budget is None or energy_budget == INF:
        return cost_fn

    def fn(a: int, b: int, k: int) -> float:
        if energy_fn(a, b, k) > energy_budget:
            return INF
        return cost_fn(a, b, k)

    return fn


@dataclass(frozen=True)
class VariantInstance:
    """One member of a model-variant bank at the scalar-solver level:
    the variant's own ``CostSegment`` callable (compressed payload +
    encoder already priced in), its energy callable (optional; encoder
    energy included), and its unitless accuracy proxy.

    The solvers stay opaque-callable pure: they never see
    :class:`~repro.core.latency.BottleneckVariant` objects, only the
    per-variant cost functions — build instances with
    ``VariantInstance(replace(model, variant=v).cost_segment_fn(), ...)``
    or let :func:`repro.core.planner.plan_split` do it."""

    cost_fn: CostFn
    energy_fn: CostFn | None = None
    accuracy_proxy: float = 1.0


def _as_variant(v) -> VariantInstance:
    return v if isinstance(v, VariantInstance) else VariantInstance(cost_fn=v)


def _best_variant(
    solver_fn: Callable[..., "SolverResult"],
    name: str,
    variants: Sequence["VariantInstance | CostFn"],
    accuracy_floor: float | None,
    L: int,
    N: int,
    energy_budget: float | None,
    **solver_kwargs,
) -> "SolverResult":
    """(split point, variant) joint optimization: run ``solver_fn`` once
    per bank member and keep the cheapest, preferring the LOWEST variant
    index on exact cost ties (the batched engine's first-minimum argmin
    over the stacked variant axis matches this tie-break bit-for-bit).

    ``accuracy_floor`` masks variants with ``accuracy_proxy < floor``
    before the solve — the variant-axis mirror of
    :func:`budget_masked`'s per-segment +inf masking. A bank whose every
    member is masked (or infeasible) yields the usual infeasible result
    with ``variant=None``."""
    if not variants:
        raise ValueError("variants must name at least one bank member")
    t0 = time.perf_counter()
    best: SolverResult | None = None
    best_idx: int | None = None
    nodes = 0
    for idx, entry in enumerate(_as_variant(v) for v in variants):
        if accuracy_floor is not None and entry.accuracy_proxy < accuracy_floor:
            continue
        res = solver_fn(entry.cost_fn, L, N, energy_fn=entry.energy_fn,
                        energy_budget=energy_budget, **solver_kwargs)
        nodes += res.nodes_expanded
        if res.feasible and (best is None or res.cost_s < best.cost_s):
            best, best_idx = res, idx
    wall = time.perf_counter() - t0
    if best is None:
        return SolverResult(name, (), INF, wall, nodes, variant=None)
    return replace(best, wall_time_s=wall, nodes_expanded=nodes,
                   variant=best_idx)


def total_energy(energy_fn: CostFn, splits: Sequence[int], L: int) -> float:
    """Total Joules of a full configuration (energy is additive across
    segments; the *constraint* is per-segment — see :func:`budget_masked`)."""
    bounds = [0, *splits, L]
    acc = 0.0
    for i in range(len(bounds) - 1):
        a, b = bounds[i] + 1, bounds[i + 1]
        if a > b:
            return INF
        e = energy_fn(a, b, i + 1)
        if e == INF:
            return INF
        acc += e
    return acc


def _combine_fn(combine: str) -> Callable[[float, float], float]:
    if combine == "sum":
        return lambda acc, c: acc + c
    if combine == "max":
        return max
    raise ValueError(f"unknown combine {combine!r}")


def _min_devices_suffix(cost_fn: CostFn, L: int, probe_k: int = 2) -> list[float]:
    """need[j] = minimum devices that can host layers [j..L] feasibly.

    Feasibility (finite cost) is prefix-monotone in segment extension in the
    latency model (memory grows with the segment), so greedily taking the
    longest feasible segment is optimal. Used as admissible lookahead: a
    partial configuration ending at ``pos`` with ``m`` devices left is a
    dead end iff need[pos+1] > m.

    This is a beyond-paper fix: the paper's Alg. 1-3 as written dead-end on
    memory-constrained instances (e.g. ResNet50 on ESP32-S3, Fig. 3) because
    they prune/pick without checking that the suffix remains packable."""
    need: list[float] = [INF] * (L + 2)
    need[L + 1] = 0.0
    for j in range(L, 0, -1):
        b_max = None
        for b in range(L, j - 1, -1):
            if cost_fn(j, b, probe_k) < INF:
                b_max = b
                break
        if b_max is None or need[b_max + 1] == INF:
            # greedy longest may strand the remainder only if *no* extent
            # works; fall back to scanning all feasible extents.
            best = INF
            for b in range(j, L + 1):
                if cost_fn(j, b, probe_k) < INF and need[b + 1] != INF:
                    best = min(best, 1.0 + need[b + 1])
            need[j] = best
        else:
            need[j] = 1.0 + need[b_max + 1]
    return need


def total_cost(cost_fn: CostFn, splits: Sequence[int], L: int, combine: str = "sum") -> float:
    """Combined cost of a full configuration."""
    comb = _combine_fn(combine)
    bounds = [0, *splits, L]
    acc = 0.0
    for i in range(len(bounds) - 1):
        a, b = bounds[i] + 1, bounds[i + 1]
        if a > b:
            return INF
        c = cost_fn(a, b, i + 1)
        if c == INF:
            return INF
        acc = comb(acc, c) if i else c
    return acc


# ---------------------------------------------------------------------------
# Algorithm 1 — Beam Search
# ---------------------------------------------------------------------------


def beam_search(
    cost_fn: CostFn,
    L: int,
    N: int,
    beam_width: int = 8,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
    dominance: bool = True,
    *,
    energy_fn: CostFn | None = None,
    energy_budget: float | None = None,
    variants: Sequence[VariantInstance | CostFn] | None = None,
    accuracy_floor: float | None = None,
) -> SolverResult:
    """Beam Search for split-point optimization (Algorithm 1).

    ``variants`` switches on the (split point, variant) joint decision:
    the bank's per-variant cost/energy callables supersede
    ``cost_fn``/``energy_fn`` (pass ``cost_fn=None``) and the result
    reports the winning bank index in ``SolverResult.variant``;
    ``accuracy_floor`` masks bank members below it (see
    :func:`_best_variant`).

    Maintains the top-``beam_width`` partial configurations by cumulative
    cost; at iteration k each candidate ``(pos, cost, splits)`` is extended
    with every feasible next split ``next in [pos+1, L-(N-k)]`` (exactly L
    at k = N). ``feasibility_lookahead`` additionally prunes extensions
    whose suffix cannot be packed onto the remaining devices (see
    :func:`_min_devices_suffix`).

    ``dominance`` (beyond-paper): two partial configurations at the same
    ``pos`` after the same number of segments are interchangeable for the
    suffix — the cheaper one dominates for BOTH combine semantics. Keeping
    only the best candidate per position before truncation removes the
    degenerate ties that otherwise fill the beam under the ``max``
    (bottleneck) objective, where every short-prefix candidate scores the
    same low cumulative max.

    Pruning additionally ranks candidates by an ADMISSIBLE completion
    bound (A*-style): segment costs are superadditive (splitting adds
    per-segment overheads and cut transmissions), so the cost of the whole
    suffix as one segment lower-bounds the sum of any segmentation, and
    suffix/(N-k) lower-bounds its max. Without this, max-combine beams
    systematically favor short prefixes (low running max) and miss
    balanced optima."""
    if variants is not None:
        return _best_variant(
            beam_search, "beam", variants, accuracy_floor, L, N,
            energy_budget, beam_width=beam_width, combine=combine,
            feasibility_lookahead=feasibility_lookahead, dominance=dominance)
    t0 = time.perf_counter()
    memo = _Memo(budget_masked(cost_fn, energy_fn, energy_budget))
    comb = _combine_fn(combine)
    need = _min_devices_suffix(memo, L) if feasibility_lookahead else None

    def completion_bound(pos: int, k: int) -> float:
        """Admissible lower bound on the combined cost of layers
        [pos+1, L] split across devices k+1..N."""
        if pos >= L:
            return 0.0
        rem = N - k
        whole = memo(pos + 1, L, min(k + 1, N))
        if whole == INF:
            return 0.0  # feasibility handled by the lookahead
        return whole / rem if combine == "max" else whole

    # candidates: (cumulative_cost, pos, splits_tuple)
    beam: list[tuple[float, int, tuple[int, ...]]] = [(0.0, 0, ())]
    for k in range(1, N + 1):
        new: list[tuple[float, int, tuple[int, ...]]] = []
        for cost, pos, splits in beam:
            lo = pos + 1
            hi = L - (N - k)
            nxt_range = (L,) if k == N else range(lo, hi + 1)
            for nxt in nxt_range:
                if nxt < lo:
                    continue
                c_seg = memo(pos + 1, nxt, k)
                if c_seg == INF:
                    continue
                if need is not None and nxt < L and need[nxt + 1] > N - k:
                    continue  # dead end: suffix cannot fit remaining devices
                # costs are non-negative, so comb(0, c) == c for both combines
                new.append((comb(cost, c_seg), nxt, splits + (nxt,)))
        if not new:
            return SolverResult("beam", (), INF, time.perf_counter() - t0, memo.evals)
        if dominance:
            best_by_pos: dict[int, tuple[float, int, tuple[int, ...]]] = {}
            for cand in new:
                cur = best_by_pos.get(cand[1])
                if cur is None or cand[0] < cur[0]:
                    best_by_pos[cand[1]] = cand
            new = list(best_by_pos.values())
        if k < N:
            new.sort(key=lambda t: comb(t[0], completion_bound(t[1], k)))
            beam = new[:beam_width]
        else:
            beam = heapq.nsmallest(beam_width, new, key=lambda t: t[0])

    best_cost, _, best_splits = min(beam, key=lambda t: t[0])
    return SolverResult(
        "beam", best_splits[:-1], best_cost, time.perf_counter() - t0, memo.evals
    )


# ---------------------------------------------------------------------------
# Algorithm 2 — Greedy Search
# ---------------------------------------------------------------------------


def greedy_search(
    cost_fn: CostFn,
    L: int,
    N: int,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
    *,
    energy_fn: CostFn | None = None,
    energy_budget: float | None = None,
    variants: Sequence[VariantInstance | CostFn] | None = None,
    accuracy_floor: float | None = None,
) -> SolverResult:
    """Greedy Search (Algorithm 2): at step k pick the split minimizing the
    immediate segment cost (Eq. 11). ``variants``/``accuracy_floor``:
    joint (split, variant) decision as in :func:`beam_search`."""
    if variants is not None:
        return _best_variant(
            greedy_search, "greedy", variants, accuracy_floor, L, N,
            energy_budget, combine=combine,
            feasibility_lookahead=feasibility_lookahead)
    t0 = time.perf_counter()
    memo = _Memo(budget_masked(cost_fn, energy_fn, energy_budget))
    need = _min_devices_suffix(memo, L) if feasibility_lookahead else None
    pos = 0
    splits: list[int] = []
    for k in range(1, N):
        best_next, best_cost = None, INF
        for nxt in range(pos + 1, L - (N - k) + 1):
            c = memo(pos + 1, nxt, k)
            if need is not None and need[nxt + 1] > N - k:
                continue
            if c < best_cost:
                best_cost, best_next = c, nxt
        if best_next is None:
            return SolverResult("greedy", (), INF, time.perf_counter() - t0, memo.evals)
        splits.append(best_next)
        pos = best_next
    cost = total_cost(memo, splits, L, combine)
    return SolverResult("greedy", tuple(splits), cost, time.perf_counter() - t0, memo.evals)


# ---------------------------------------------------------------------------
# Algorithm 3 — First-Fit Search
# ---------------------------------------------------------------------------


def first_fit_search(
    cost_fn: CostFn,
    L: int,
    N: int,
    thresholds: Sequence[float] | float | None = None,
    combine: str = "sum",
    feasibility_lookahead: bool = True,
    *,
    energy_fn: CostFn | None = None,
    energy_budget: float | None = None,
    variants: Sequence[VariantInstance | CostFn] | None = None,
    accuracy_floor: float | None = None,
) -> SolverResult:
    """First-Fit Search (Algorithm 3): scan left-to-right and accept the
    first split whose segment cost is within the device-k threshold tau_k;
    fall back to the last feasible position otherwise.

    When ``thresholds`` is None, tau_k defaults to the single-device
    whole-model cost divided by N (a uniform-share budget). When the whole
    model does not fit one device (cost INF), the budget falls back to the
    per-device sum of longest-feasible-segment costs.

    ``variants``/``accuracy_floor``: joint (split, variant) decision as
    in :func:`beam_search`."""
    if variants is not None:
        return _best_variant(
            first_fit_search, "first_fit", variants, accuracy_floor, L, N,
            energy_budget, thresholds=thresholds, combine=combine,
            feasibility_lookahead=feasibility_lookahead)
    t0 = time.perf_counter()
    memo = _Memo(budget_masked(cost_fn, energy_fn, energy_budget))
    need = _min_devices_suffix(memo, L) if feasibility_lookahead else None
    if thresholds is None:
        whole = memo(1, L, 1)
        if whole == INF:
            # infeasible-on-one-device models: budget = mean feasible-segment cost
            finite = [memo(a, a, 2) for a in range(1, L + 1)]
            finite = [c for c in finite if c < INF]
            whole = (sum(finite) if finite else 1.0) * 1.5
        thresholds = [whole / N] * N
    elif isinstance(thresholds, (int, float)):
        thresholds = [float(thresholds)] * N

    pos = 0
    splits: list[int] = []
    for k in range(1, N):
        chosen = False
        last_feasible = None
        for nxt in range(pos + 1, L - (N - k) + 1):
            c = memo(pos + 1, nxt, k)
            if c == INF or (need is not None and need[nxt + 1] > N - k):
                continue
            last_feasible = nxt
            if c <= thresholds[k - 1]:
                splits.append(nxt)
                pos = nxt
                chosen = True
                break
        if not chosen:
            # Alg. 3 line 14: 'the last feasible split point before
            # violating the device constraint'.
            fallback = last_feasible if last_feasible is not None else L - (N - k)
            splits.append(fallback)
            pos = fallback
    cost = total_cost(memo, splits, L, combine)
    return SolverResult("first_fit", tuple(splits), cost, time.perf_counter() - t0, memo.evals)


# ---------------------------------------------------------------------------
# Baselines — Random-Fit and Brute-Force (Fig. 4)
# ---------------------------------------------------------------------------


def random_fit(
    cost_fn: CostFn,
    L: int,
    N: int,
    trials: int = 1,
    seed: int = 0,
    combine: str = "sum",
    *,
    energy_fn: CostFn | None = None,
    energy_budget: float | None = None,
    variants: Sequence[VariantInstance | CostFn] | None = None,
    accuracy_floor: float | None = None,
) -> SolverResult:
    """Random-Fit: draw ``trials`` uniformly random valid configurations and
    keep the best (the paper's Random-Fit baseline corresponds to trials=1).
    ``variants``/``accuracy_floor``: joint (split, variant) decision as in
    :func:`beam_search` (every bank member sees the same draws — a paired
    comparison)."""
    if variants is not None:
        return _best_variant(
            random_fit, "random_fit", variants, accuracy_floor, L, N,
            energy_budget, trials=trials, seed=seed, combine=combine)
    t0 = time.perf_counter()
    memo = _Memo(budget_masked(cost_fn, energy_fn, energy_budget))
    rng = random.Random(seed)
    best: tuple[float, tuple[int, ...]] = (INF, ())
    for _ in range(max(1, trials)):
        splits = tuple(sorted(rng.sample(range(1, L), N - 1))) if N > 1 else ()
        c = total_cost(memo, splits, L, combine)
        if c < best[0]:
            best = (c, splits)
    return SolverResult("random_fit", best[1], best[0], time.perf_counter() - t0, memo.evals)


def brute_force(
    cost_fn: CostFn,
    L: int,
    N: int,
    combine: str = "sum",
    max_candidates: int | None = None,
    *,
    energy_fn: CostFn | None = None,
    energy_budget: float | None = None,
    variants: Sequence[VariantInstance | CostFn] | None = None,
    accuracy_floor: float | None = None,
) -> SolverResult:
    """Brute-Force: enumerate all C(L-1, N-1) configurations (Fig. 4).

    ``max_candidates`` optionally caps the enumeration (the paper reports
    ~7857 s for 6 devices; the cap keeps CI runs bounded while preserving
    exactness whenever the space is smaller than the cap).

    With ``energy_fn``/``energy_budget`` this is the budget-filtered
    enumeration oracle: every configuration containing an over-budget
    segment totals +inf and can never win. With ``variants`` it is the
    full (split, variant) enumeration oracle the batched variant-bank
    engine is property-tested against."""
    if variants is not None:
        return _best_variant(
            brute_force, "brute_force", variants, accuracy_floor, L, N,
            energy_budget, combine=combine, max_candidates=max_candidates)
    t0 = time.perf_counter()
    memo = _Memo(budget_masked(cost_fn, energy_fn, energy_budget))
    best: tuple[float, tuple[int, ...]] = (INF, ())
    n_seen = 0
    for combo in itertools.combinations(range(1, L), N - 1):
        n_seen += 1
        if max_candidates is not None and n_seen > max_candidates:
            break
        c = total_cost(memo, combo, L, combine)
        if c < best[0]:
            best = (c, combo)
    return SolverResult("brute_force", best[1], best[0], time.perf_counter() - t0, memo.evals)


# ---------------------------------------------------------------------------
# Exact DP (beyond-paper): O(L^2 N) optimum for both objectives
# ---------------------------------------------------------------------------


def optimal_dp(
    cost_fn: CostFn,
    L: int,
    N: int,
    combine: str = "sum",
    *,
    energy_fn: CostFn | None = None,
    energy_budget: float | None = None,
    variants: Sequence[VariantInstance | CostFn] | None = None,
    accuracy_floor: float | None = None,
) -> SolverResult:
    """Exact optimum via dynamic programming (beyond-paper reference).

    dp[k][b] = best combined cost of placing layers [1..b] on devices
    [1..k]; transition over the last segment start. Both ``sum`` and
    ``max`` combine are decomposable. Used to (a) certify Beam Search
    quality in tests and (b) give the TPU planner an exact fallback at
    interactive speeds (the full Brute-Force table of Fig. 4 is
    exponential; DP is quadratic). ``variants``/``accuracy_floor``:
    joint (split, variant) decision as in :func:`beam_search` — the DP
    runs once per bank member, exactly optimal per variant, so the
    banked result is exactly optimal over the joint space."""
    if variants is not None:
        return _best_variant(
            optimal_dp, "optimal_dp", variants, accuracy_floor, L, N,
            energy_budget, combine=combine)
    t0 = time.perf_counter()
    memo = _Memo(budget_masked(cost_fn, energy_fn, energy_budget))
    comb = _combine_fn(combine)

    # dp[b] after k devices; parent pointers for reconstruction
    dp = [INF] * (L + 1)
    parent: list[list[int]] = [[-1] * (L + 1) for _ in range(N + 1)]
    for b in range(1, L + 1):
        dp[b] = memo(1, b, 1)
    for k in range(2, N + 1):
        ndp = [INF] * (L + 1)
        for b in range(k, L + 1):
            best, arg = INF, -1
            for a in range(k - 1, b):
                if dp[a] == INF:
                    continue
                c_seg = memo(a + 1, b, k)
                if c_seg == INF:
                    continue
                cand = comb(dp[a], c_seg)
                if cand < best:
                    best, arg = cand, a
            ndp[b] = best
            parent[k][b] = arg
        dp = ndp

    if dp[L] == INF:
        return SolverResult("optimal_dp", (), INF, time.perf_counter() - t0, memo.evals)

    splits: list[int] = []
    b = L
    for k in range(N, 1, -1):
        a = parent[k][b]
        splits.append(a)
        b = a
    splits.reverse()
    return SolverResult("optimal_dp", tuple(splits), dp[L], time.perf_counter() - t0, memo.evals)


SOLVERS: dict[str, Callable[..., SolverResult]] = {
    "beam": beam_search,
    "greedy": greedy_search,
    "first_fit": first_fit_search,
    "random_fit": random_fit,
    "brute_force": brute_force,
    "optimal_dp": optimal_dp,
}
