"""Pallas fused cost-construction + DP kernel (``backend="pallas"``).

The batched JAX backend (:func:`repro.core.sweep._dp_jax`) consumes a
fully materialized ``C[S, N, L, L]`` cost tensor: every scenario's
per-device segment-cost matrix is built on the host, shipped to the
accelerator, and round-tripped through HBM before the recurrence reads
each entry exactly once. At fleet scale the tensor build rivals the
solve itself (BENCH_sweep.json) and the ``S`` axis — the one axis
related work multiplies (per-device channels, heterogeneous platforms)
— pays for bandwidth, not math.

This module moves the construction INSIDE the kernel. The cost tensor
decomposes exactly as the sweep engine already assembles it::

    C[s, k, a, b] = local[k, a, b] + tx[s, b]

where ``local`` is the link-independent per-device local-cost stack
(``(N, L, L)``, from the ``(DeviceProfile, is_first)`` bank) and ``tx``
is the per-scenario transmission vector (``(S, L)``). A Pallas kernel
tiles the scenario axis over a 1-D grid; each grid step holds one
``(block_s, L)`` DP row tile plus the shared ``local`` stack in
VMEM and fuses ``local + tx`` into the ``min``/``argmin`` reduction of
device step ``k`` — the 4-D ``C`` tensor never exists, on host or
device. Per-scenario VMEM footprint is ``O(N * L^2)`` for the shared
stack plus ``O(block_s * L)`` rows, not ``O(S * N * L^2)``.

Two kernel modes share one body:

* **dense** — consumes a prebuilt ``C`` (the :func:`repro.core.sweep.
  batched_optimal_dp` seam takes a tensor, so ``backend="pallas"``
  must too). Arithmetic is ordered exactly like the JAX backend's
  ``vmap``/``lax.scan`` kernel, so dense-mode tables and parents are
  bit-identical to ``backend="jax"`` — the property-test contract.
* **fused** — consumes ``(local, tx)`` (or a ``(bank, bank_idx, tx)``
  triple for heterogeneous device mixes) and never materializes ``C``.
  The only arithmetic difference from the jax backend is construction
  rounding: fused computes ``f32(local) + f32(tx)`` where the dense
  path computes ``f32(local64 + tx64)`` — a <=1 ulp cost wobble. Plan
  nodes are therefore identical EXCEPT under exact-cost ties, where
  the wobble may break the tie toward a different equally-optimal
  plan (zero float64-repriced regret — the same class of divergence
  the float32 jax backend already shows against the float64 oracle;
  ``benchmarks/sweep_grid.py --backend pallas`` verifies every
  divergent node is such a tie). Costs are always allclose.

Tiling: ``L`` is +inf-padded to the 128-lane float32 tile and ``S`` is
replica-padded to a ``block_s`` multiple (default 8, the float32
sublane tile). Padding is semantically invisible — +inf candidates
never win a first-minimum ``argmin``, replica rows are sliced off
before anything reads them.

CPU/CI: Pallas lowers to Mosaic on TPU; elsewhere the ``interpret=``
escape hatch (default ON off-TPU, see :func:`pallas_interpret_default`)
runs the same kernel through the Pallas interpreter — identical
numerics and tie-breaks, no speedup. The CI ``pallas`` job asserts
correctness in interpret mode; the >=10x fusion win is a real-hardware
claim.

Entry points up the stack: ``batched_optimal_dp(backend="pallas")``
(dense), ``sweep(grid, backend="pallas")`` and ``build_surfaces(...,
backend="pallas")`` (fused, via :func:`pallas_fused_optimal_dp`), and
``sharded_dp_tables(kernel="pallas")`` (dense kernel under
``shard_map`` — sharding partitions the scenario grid axis, the
per-tile math is untouched).

Precision follows the active JAX config like every JAX-side backend:
float32 by default, float64 when ``jax.config.jax_enable_x64`` is on.
"""

from __future__ import annotations

import functools
import time
from typing import Sequence

import numpy as np

from repro.core import sweep as SW

__all__ = [
    "LANE",
    "DEFAULT_BLOCK_S",
    "pallas_interpret_default",
    "pallas_dp_tables",
    "pallas_fused_dp_tables",
    "pallas_optimal_dp",
    "pallas_fused_optimal_dp",
]

INF = float("inf")

# float32 TPU tile: 8 sublanes x 128 lanes. L pads to the lane multiple,
# the scenario grid steps in sublane-multiple blocks.
LANE = 128
DEFAULT_BLOCK_S = 8

# Incremented every time the pallas solver is (re)traced; a same-shape
# repeat call must leave it unchanged (jit-cache regression test in
# tests/test_pallas_dp.py — same pattern as sweep._DP_JAX_TRACE_COUNT).
_PALLAS_TRACE_COUNT = 0


def pallas_interpret_default() -> bool:
    """Whether ``interpret=None`` means interpret mode: True off-TPU.

    On TPU the kernel compiles through Mosaic; everywhere else (CPU CI,
    GPU hosts without a Triton lowering for this kernel) the Pallas
    interpreter runs the same tile program with identical numerics."""
    import jax

    return jax.default_backend() != "tpu"


def _pad_lanes(L: int) -> int:
    """L padded up to the 128-lane tile multiple (min one full lane)."""
    return max(LANE, -(-L // LANE) * LANE)


def _pad_rows(S: int, block_s: int) -> int:
    """S padded up to a whole number of scenario blocks."""
    return -(-S // block_s) * block_s


def _dp_step_tile(dp, ck_shift, ns, k, combine):
    """One fused device step on a scenario tile — the Pallas twin of the
    ``lax.scan`` body in :func:`repro.core.sweep._dp_jax_kernel`.

    ``dp`` is the ``(T, L)`` running table, ``ck_shift[t, a, b]`` the
    segment cost of layers ``[a+2, b+1]`` on device ``k`` (already
    boundary-shifted so candidate ``a`` aligns with parent ``a + 1``),
    ``ns`` the ``(T, 1)`` per-scenario fleet sizes. Candidate order,
    first-minimum ``argmin`` and the frozen-row mask mirror the jax
    kernel exactly — +inf-padded lanes never win, scenarios whose fleet
    completed at ``n_s < k`` carry their stale table forward."""
    import jax.numpy as jnp

    if combine == "sum":
        cand = dp[:, :, None] + ck_shift
    else:
        cand = jnp.maximum(dp[:, :, None], ck_shift)
    ndp = jnp.min(cand, axis=1)
    arg = jnp.where(jnp.isfinite(ndp),
                    jnp.argmin(cand, axis=1).astype(jnp.int32) + 1, -1)
    act = ns >= k
    ndp = jnp.where(act, ndp, dp)
    arg = jnp.where(act, arg, -1)
    return ndp, arg


def _dense_kernel(N: int, Lp: int, combine: str):
    """Kernel body for a prebuilt per-tile cost tensor ``C``."""
    import jax.numpy as jnp

    def kernel(C_ref, ns_ref, dp0_ref, dps_ref, args_ref):
        ns = ns_ref[...]            # (T, 1) int32
        dp = C_ref[:, 0, 0, :]      # (T, Lp): device-1 row, a == 0
        dp0_ref[...] = dp
        for k in range(2, N + 1):   # unrolled: N is small and static
            ck = C_ref[:, k - 1]    # (T, Lp, Lp)
            ck_shift = jnp.concatenate(
                [ck[:, 1:], jnp.full((ck.shape[0], 1, Lp), INF, ck.dtype)],
                axis=1)
            dp, arg = _dp_step_tile(dp, ck_shift, ns, k, combine)
            dps_ref[:, k - 2, :] = dp
            args_ref[:, k - 2, :] = arg

    return kernel


def _fused_kernel(N: int, Lp: int, combine: str):
    """Kernel body fusing ``C = local + tx`` into the recurrence.

    ``local`` (the shared ``(N, Lp, Lp)`` per-device stack) and ``tx``
    (the ``(T, Lp)`` per-tile transmission rows) are the ONLY inputs —
    each device step materializes one boundary-shifted ``(T, Lp, Lp)``
    candidate slab in VMEM registers and reduces it immediately; the
    full ``C[S, N, L, L]`` tensor never exists."""
    import jax.numpy as jnp

    def kernel(local_ref, tx_ref, ns_ref, dp0_ref, dps_ref, args_ref):
        tx = tx_ref[...]            # (T, Lp)
        ns = ns_ref[...]            # (T, 1) int32
        # device-1 row fused on the fly: C[s, 0, 0, b] = local[0,0,b]+tx[s,b]
        dp = local_ref[0, 0, :][None, :] + tx
        dp0_ref[...] = dp
        for k in range(2, N + 1):
            ck = local_ref[k - 1]   # (Lp, Lp), shared across the tile
            ck_shift = jnp.concatenate(
                [ck[1:], jnp.full((1, Lp), INF, ck.dtype)], axis=0)
            ckf = ck_shift[None, :, :] + tx[:, None, :]
            dp, arg = _dp_step_tile(dp, ckf, ns, k, combine)
            dps_ref[:, k - 2, :] = dp
            args_ref[:, k - 2, :] = arg

    return kernel


def _raw_pallas_fn(mode: str, combine: str, block_s: int, interpret: bool):
    """The traceable (unjitted) pallas_call wrapper for one kernel mode.

    Shape-polymorphic: the ``pallas_call`` (grid, block specs, output
    shapes) is constructed at trace time from the operand shapes, so one
    wrapper serves every (S, N, L) — jit re-specializes per shape like
    every other backend. Shared with :mod:`repro.core.shard` for
    ``kernel="pallas"`` sharded solves (each shard traces this exact
    function, so sharded and single-device pallas answers stay
    node-identical). Callers pass pre-padded operands: ``Lp`` a lane
    multiple (+inf padding), ``Sp`` a ``block_s`` multiple (replica
    rows), ``ns`` as an ``(Sp, 1)`` int32 column."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    if mode == "dense":

        def fn(Cp, nsp):
            Sp, N, Lp, _ = Cp.shape
            return pl.pallas_call(
                _dense_kernel(N, Lp, combine),
                grid=(Sp // block_s,),
                in_specs=[
                    pl.BlockSpec((block_s, N, Lp, Lp),
                                 lambda i: (i, 0, 0, 0)),
                    pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
                ],
                out_specs=[
                    pl.BlockSpec((block_s, Lp), lambda i: (i, 0)),
                    pl.BlockSpec((block_s, N - 1, Lp), lambda i: (i, 0, 0)),
                    pl.BlockSpec((block_s, N - 1, Lp), lambda i: (i, 0, 0)),
                ],
                out_shape=[
                    jax.ShapeDtypeStruct((Sp, Lp), Cp.dtype),
                    jax.ShapeDtypeStruct((Sp, N - 1, Lp), Cp.dtype),
                    jax.ShapeDtypeStruct((Sp, N - 1, Lp), jnp.int32),
                ],
                interpret=interpret,
            )(Cp, nsp)

        return fn

    if mode == "fused":

        def fn(localp, txp, nsp):
            N, Lp, _ = localp.shape
            Sp = txp.shape[0]
            return pl.pallas_call(
                _fused_kernel(N, Lp, combine),
                grid=(Sp // block_s,),
                in_specs=[
                    # the local stack rides along whole: same block every
                    # grid step (index map pins it), so it loads once
                    pl.BlockSpec((N, Lp, Lp), lambda i: (0, 0, 0)),
                    pl.BlockSpec((block_s, Lp), lambda i: (i, 0)),
                    pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
                ],
                out_specs=[
                    pl.BlockSpec((block_s, Lp), lambda i: (i, 0)),
                    pl.BlockSpec((block_s, N - 1, Lp), lambda i: (i, 0, 0)),
                    pl.BlockSpec((block_s, N - 1, Lp), lambda i: (i, 0, 0)),
                ],
                out_shape=[
                    jax.ShapeDtypeStruct((Sp, Lp), localp.dtype),
                    jax.ShapeDtypeStruct((Sp, N - 1, Lp), localp.dtype),
                    jax.ShapeDtypeStruct((Sp, N - 1, Lp), jnp.int32),
                ],
                interpret=interpret,
            )(localp, txp, nsp)

        return fn

    raise ValueError(f"unknown pallas kernel mode {mode!r}")


@functools.lru_cache(maxsize=None)
def _pallas_dp_solver(mode: str, combine: str, block_s: int,
                      interpret: bool):
    """Jitted entry to :func:`_raw_pallas_fn`, cached per configuration.

    ``jax.jit``'s executable cache keys on operand shapes, so two
    same-shape calls compile exactly once (regression-tested via
    :data:`_PALLAS_TRACE_COUNT`, the :data:`repro.core.sweep.
    _DP_JAX_TRACE_COUNT` pattern)."""
    import jax

    fn = _raw_pallas_fn(mode, combine, block_s, interpret)

    def solve(*operands):
        global _PALLAS_TRACE_COUNT
        _PALLAS_TRACE_COUNT += 1  # Python side effect: runs at trace only
        return fn(*operands)

    return jax.jit(solve)


def _resolve_opts(block_s: int | None, interpret: bool | None):
    bs = DEFAULT_BLOCK_S if block_s is None else int(block_s)
    if bs < 1:
        raise ValueError(f"block_s must be >= 1, got {block_s}")
    itp = pallas_interpret_default() if interpret is None else bool(interpret)
    return bs, itp


def _pad_ns_column(ns_arr: np.ndarray, Sn: int, Sp: int) -> np.ndarray:
    nsp = np.zeros((Sp, 1), dtype=np.int32)
    nsp[:Sn, 0] = ns_arr
    if Sp > Sn:
        nsp[Sn:, 0] = ns_arr[-1]  # replica rows keep a valid fleet size
    return nsp


def _trivial_tables(dp0, Sn: int, N: int, L: int, dtype):
    """Host-side tables for the kernel-free cases (N == 1 or S == 0)."""
    dps = np.zeros((Sn, max(N - 1, 0), L), dtype=dtype)
    args = np.full((Sn, max(N - 1, 0), L), -1, dtype=np.int32)
    return SW._dp_tables_to_numpy(dp0, dps, args, Sn, N, L)


def pallas_dp_tables(
    C: np.ndarray,
    combine: str = "sum",
    ns: np.ndarray | None = None,
    *,
    block_s: int | None = None,
    interpret: bool | None = None,
):
    """(dp_per_k, parents) DP tables from the dense-mode Pallas kernel.

    The pallas twin of :func:`repro.core.sweep._dp_jax` — same return
    contract, same frozen-row ``ns`` semantics, and bit-identical
    tables AND parents (dense mode reorders no arithmetic; it only
    tiles the scenario axis). ``L`` is +inf-padded to the 128-lane
    tile, ``S`` replica-padded to a ``block_s`` multiple; padding is
    sliced off before returning. ``interpret=None`` resolves via
    :func:`pallas_interpret_default`."""
    C = np.asarray(C, dtype=np.float64)
    Sn, N, L, _ = C.shape
    ns_arr = np.full(Sn, N, dtype=np.int64) if ns is None \
        else np.asarray(ns, dtype=np.int64)
    import jax

    dtype = jax.dtypes.canonicalize_dtype(np.float64)
    if N == 1 or Sn == 0:
        # no recurrence to run: device-1 row IS the answer (cast like the
        # jit boundary would), and an empty scenario axis has no tiles
        return _trivial_tables(C[:, 0, 0, :].astype(dtype), Sn, N, L, dtype)
    bs, itp = _resolve_opts(block_s, interpret)
    Lp, Sp = _pad_lanes(L), _pad_rows(Sn, bs)
    Cp = np.full((Sp, N, Lp, Lp), INF, dtype=np.float64)
    Cp[:Sn, :, :L, :L] = C
    if Sp > Sn:
        Cp[Sn:] = Cp[Sn - 1]  # replica rows: already-valid inputs
    nsp = _pad_ns_column(ns_arr, Sn, Sp)
    import jax.numpy as jnp

    solver = _pallas_dp_solver("dense", combine, bs, itp)
    dp0, dps, args = solver(jnp.asarray(Cp, dtype=dtype), jnp.asarray(nsp))
    dp0 = np.asarray(dp0)[:Sn, :L]
    dps = np.asarray(dps)[:Sn, :, :L]
    args = np.asarray(args)[:Sn, :, :L]
    return SW._dp_tables_to_numpy(dp0, dps, args, Sn, N, L)


def _fused_tables_arrays(local, tx, ns_arr, combine, bs, itp, dtype):
    """Unpadded (dp0, dps, args) from the fused kernel; N >= 2, S >= 1."""
    N, L, _ = local.shape
    Sn = tx.shape[0]
    Lp, Sp = _pad_lanes(L), _pad_rows(Sn, bs)
    localp = np.full((N, Lp, Lp), INF, dtype=np.float64)
    localp[:, :L, :L] = local
    txp = np.zeros((Sp, Lp), dtype=np.float64)
    txp[:Sn, :L] = tx
    if Sp > Sn:
        txp[Sn:] = txp[Sn - 1]
    nsp = _pad_ns_column(ns_arr, Sn, Sp)
    import jax.numpy as jnp

    solver = _pallas_dp_solver("fused", combine, bs, itp)
    dp0, dps, args = solver(jnp.asarray(localp, dtype=dtype),
                            jnp.asarray(txp, dtype=dtype),
                            jnp.asarray(nsp))
    return (np.asarray(dp0)[:Sn, :L],
            np.asarray(dps)[:Sn, :, :L],
            np.asarray(args)[:Sn, :, :L])


def _fused_dp0_host(local, tx, dtype):
    """The N == 1 fused answer, cast exactly like the jit boundary."""
    return local[0, 0, :].astype(dtype)[None, :] + tx.astype(dtype)


def pallas_fused_dp_tables(
    local: np.ndarray,
    tx: np.ndarray,
    combine: str = "sum",
    ns: np.ndarray | None = None,
    *,
    block_s: int | None = None,
    interpret: bool | None = None,
):
    """(dp_per_k, parents) DP tables WITHOUT ever materializing ``C``.

    ``local`` is the shared per-device local-cost stack ``(N, L, L)``
    (``SplitCostModel.local_cost_tensor``), ``tx`` the per-scenario
    transmission vectors ``(S, L)``; the kernel fuses
    ``C[s,k] = local[k] + tx[s]`` into each reduction step. Plan nodes
    (parents) match the dense path exactly except under exact-cost
    ties; dp costs may differ by construction rounding (<=1 ulp per
    entry — see the module docstring). Heterogeneous device mixes go
    through
    :func:`pallas_fused_optimal_dp`, which subgroups scenarios by
    device stack before calling this."""
    local = np.asarray(local, dtype=np.float64)
    tx = np.asarray(tx, dtype=np.float64)
    if local.ndim != 3 or local.shape[1] != local.shape[2]:
        raise ValueError(f"local must be (N, L, L), got {local.shape}")
    N, L, _ = local.shape
    if tx.ndim != 2 or tx.shape[1] != L:
        raise ValueError(f"tx must be (S, {L}), got {tx.shape}")
    Sn = tx.shape[0]
    ns_arr = np.full(Sn, N, dtype=np.int64) if ns is None \
        else np.asarray(ns, dtype=np.int64)
    import jax

    dtype = jax.dtypes.canonicalize_dtype(np.float64)
    if N == 1 or Sn == 0:
        return _trivial_tables(_fused_dp0_host(local, tx, dtype),
                               Sn, N, L, dtype)
    bs, itp = _resolve_opts(block_s, interpret)
    dp0, dps, args = _fused_tables_arrays(local, tx, ns_arr, combine,
                                          bs, itp, dtype)
    return SW._dp_tables_to_numpy(dp0, dps, args, Sn, N, L)


def pallas_optimal_dp(
    C: np.ndarray,
    combine: str = "sum",
    return_all_k: bool = False,
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    *,
    block_s: int | None = None,
    interpret: bool | None = None,
):
    """Exact split DP on the dense-mode Pallas kernel.

    The standalone entry behind ``batched_optimal_dp(backend="pallas")``
    — same arguments and return types, plus the pallas knobs
    (``block_s`` scenario tile, ``interpret`` escape hatch). Carries the
    full solver contract (per-scenario ``n_devices`` frozen rows,
    ``return_all_k``, the shared timing scope) and is node-identical to
    ``backend="jax"``: bit-equal tables, bit-equal parents."""
    Sn, N, L, ns = SW._validate_dp_inputs(C, return_all_k, n_devices)
    t0 = time.perf_counter()
    dp_per_k, parents = pallas_dp_tables(C, combine, ns=ns,
                                         block_s=block_s,
                                         interpret=interpret)
    return SW._results_from_dp_tables(dp_per_k, parents, L, N, Sn,
                                      "pallas", ns, return_all_k, t0)


def pallas_fused_optimal_dp(
    bank: np.ndarray,
    bank_idx: np.ndarray | None,
    tx: np.ndarray,
    combine: str = "sum",
    return_all_k: bool = False,
    n_devices: np.ndarray | Sequence[int] | int | None = None,
    *,
    block_s: int | None = None,
    interpret: bool | None = None,
):
    """Exact split DP from compact profiles — ``C`` is never built.

    The fused entry behind ``sweep(grid, backend="pallas")`` and
    ``build_surfaces(..., backend="pallas")``:

    Args:
      bank: ``(B, L, L)`` local-cost bank (one matrix per distinct
        ``(DeviceProfile, is_first)`` pair, the sweep engine's profile
        bank) — or, when ``bank_idx is None``, the shared per-device
        ``(N, L, L)`` local stack itself (the homogeneous / surface
        case).
      bank_idx: ``(S, N)`` integer rows into ``bank`` (scenario ``s``'s
        device ``k`` uses ``bank[bank_idx[s, k]]``), or ``None``.
      tx: ``(S, L)`` per-scenario transmission vectors.
      combine / return_all_k / n_devices: the
        :func:`repro.core.sweep.batched_optimal_dp` solver contract.

    Heterogeneous mixes are subgrouped by distinct device stack (device
    slots at or beyond a scenario's own ``n_devices`` are dead filler
    and are canonicalized first, so mixes differing only in dead slots
    share a launch); each subgroup runs one fused kernel pass and the
    tables scatter back into grid order. The bank is small by
    construction — distinct stacks, not scenarios, bound the subgroup
    count.

    Bottleneck variants need NO kernel change: a variant reprices only
    the cut (compressed airtime + encoder time), both functions of the
    boundary layer ``b`` alone, so the sweep engine folds them into the
    per-scenario ``tx`` rows and the ``local + tx[s, b]`` decomposition
    above — and hence this kernel — holds verbatim. Joint
    (split, variant) solves fold the variant axis into the scenario
    axis upstream (:func:`repro.core.sweep.solve_variant_bank`); this
    entry only ever sees a flat scenario batch."""
    bank = np.asarray(bank, dtype=np.float64)
    tx = np.asarray(tx, dtype=np.float64)
    if tx.ndim != 2:
        raise ValueError(f"tx must be (S, L), got {tx.shape}")
    Sn, L = tx.shape
    if bank.ndim != 3 or bank.shape[1:] != (L, L):
        raise ValueError(f"bank must be (B, {L}, {L}), got {bank.shape}")

    if bank_idx is None:
        N = bank.shape[0]
        if return_all_k and n_devices is not None:
            raise ValueError("return_all_k and per-scenario n_devices "
                             "are mutually exclusive")
        ns = None if n_devices is None else SW._normalize_ns(n_devices, Sn, N)
        t0 = time.perf_counter()
        dp_per_k, parents = pallas_fused_dp_tables(
            bank, tx, combine, ns=ns, block_s=block_s, interpret=interpret)
        return SW._results_from_dp_tables(dp_per_k, parents, L, N, Sn,
                                          "pallas", ns, return_all_k, t0)

    bank_idx = np.asarray(bank_idx, dtype=np.int64)
    if bank_idx.ndim != 2 or bank_idx.shape[0] != Sn:
        raise ValueError(
            f"bank_idx must be ({Sn}, N), got {bank_idx.shape}")
    N = bank_idx.shape[1]
    if return_all_k and n_devices is not None:
        raise ValueError("return_all_k and per-scenario n_devices "
                         "are mutually exclusive")
    ns = None if n_devices is None else SW._normalize_ns(n_devices, Sn, N)
    import jax

    dtype = jax.dtypes.canonicalize_dtype(np.float64)
    t0 = time.perf_counter()
    ns_arr = np.full(Sn, N, dtype=np.int64) if ns is None else ns
    if Sn == 0 or N == 1:
        dp0 = np.empty((Sn, L), dtype=dtype)
        for s in range(Sn):
            dp0[s] = _fused_dp0_host(bank[bank_idx[s]], tx[s:s + 1],
                                     dtype)[0]
        dp_per_k, parents = _trivial_tables(dp0, Sn, N, L, dtype)
        return SW._results_from_dp_tables(dp_per_k, parents, L, N, Sn,
                                          "pallas", ns, return_all_k, t0)
    bs, itp = _resolve_opts(block_s, interpret)
    # canonicalize dead device slots (>= a scenario's own fleet size) to
    # row 0 so stacks differing only there share one kernel launch —
    # the solvers never read those slots (frozen-row contract)
    canon = bank_idx.copy()
    canon[np.arange(N)[None, :] >= ns_arr[:, None]] = 0
    stacks, inv = np.unique(canon, axis=0, return_inverse=True)
    dp0_all = np.empty((Sn, L), dtype=dtype)
    dps_all = np.empty((Sn, N - 1, L), dtype=dtype)
    args_all = np.empty((Sn, N - 1, L), dtype=np.int32)
    for u in range(stacks.shape[0]):
        sel = np.flatnonzero(inv == u)
        d0, dv, ag = _fused_tables_arrays(
            bank[stacks[u]], tx[sel], ns_arr[sel], combine, bs, itp, dtype)
        dp0_all[sel], dps_all[sel], args_all[sel] = d0, dv, ag
    dp_per_k, parents = SW._dp_tables_to_numpy(dp0_all, dps_all, args_all,
                                               Sn, N, L)
    return SW._results_from_dp_tables(dp_per_k, parents, L, N, Sn,
                                      "pallas", ns, return_all_k, t0)
