"""Core: the paper's contribution — split-latency model, solvers, planner.

Public API:
  latency    — Eq. 4-8 cost model (LinkProfile / DeviceProfile / SplitCostModel)
  solvers    — beam / greedy / first_fit / random_fit / brute_force / optimal_dp
  planner    — plan_split (IoT), plan_pipeline (TPU PP), compare_solvers
  profiles   — paper-calibrated ESP32 + protocol tables; TPU v5e constants
  executor   — run_split / run_unsplit segment execution with wire simulation
  quantization — int8 PTQ + activation wire format
"""

from repro.core.latency import (  # noqa: F401
    DeviceProfile,
    LayerCost,
    LinkProfile,
    ModelCostProfile,
    RTTBreakdown,
    SplitCostModel,
    rtt_breakdown,
)
from repro.core.planner import (  # noqa: F401
    SegmentPlan,
    SplitPlan,
    compare_solvers,
    plan_pipeline,
    plan_split,
    tpu_cost_profile,
    uniform_split,
)
from repro.core.solvers import (  # noqa: F401
    SOLVERS,
    SolverResult,
    beam_search,
    brute_force,
    first_fit_search,
    greedy_search,
    optimal_dp,
    random_fit,
    total_cost,
)
