"""Core: the paper's contribution — split-latency model, solvers, planner.

Public API (documented in ``docs/api.md``; layer map in
``docs/architecture.md``):
  latency    — Eq. 4-8 cost model (LinkProfile / DeviceProfile / SplitCostModel)
  spec       — the planner tier: PlanSpec (one serializable planning
               request; exact JSON round-trip), PlannerService (spec ->
               batched engines; every kwarg entry point routes through
               it), MeshSpec (single/multi-host shard mesh seam),
               build_surfaces_from_spec (process-pool rebuild worker)
  solvers    — beam / greedy / first_fit / random_fit / brute_force / optimal_dp
  planner    — plan_split (IoT), plan_pipeline (TPU PP), compare_solvers,
               plan_split_batch (vectorized fleet planning, heterogeneous
               fleet sizes + device mixes)
  sweep      — batched solvers over stacked C[k,a,b] cost tensors +
               ScenarioGrid fleet sweeps (protocol x mix x fleet x loss
               x rate x compression), all-k beam, per-scenario fleet-size
               vectors, variant-bank solves + Pareto frontier emission
  shard      — scenario-axis sharding over the local JAX device mesh
               (shard_map + pad/unpad; backend="sharded" everywhere the
               batched DP runs)
  pallas_dp  — Pallas kernel fusing cost-tensor construction with the
               DP recurrence in scenario tiles (backend="pallas"; C is
               never materialized; interpret mode off-TPU)
  surface    — precomputed degradation surfaces (per-protocol packet-time
               x loss grids -> best plan + switch points + interpolation)
               for O(1) adaptive replanning; build_surfaces solves every
               fleet size in one batched pass
  async_replan — stale-while-revalidate surface rebuilds: SurfaceRebuilder
               runs re-centered build_surfaces on a background executor,
               generation-versioned atomic swap-on-ready
  adaptive   — LinkEstimator + AdaptiveSplitManager runtime replanning;
               fleet_managers for mixed-fleet-size deployments
  profiles   — paper-calibrated ESP32 + protocol tables; TPU v5e constants
  executor   — run_split / run_unsplit segment execution with wire simulation
  quantization — int8 PTQ + activation wire format
"""

from repro.core.latency import (  # noqa: F401
    COST_CHANNELS,
    BottleneckVariant,
    ContentionModel,
    DeviceProfile,
    LayerCost,
    LinkProfile,
    ModelCostProfile,
    RTTBreakdown,
    SplitCostModel,
    bottleneck_variant,
    bottleneck_variants,
    rtt_breakdown,
)
# NOTE: `repro.core.spec` sits below every layer it orchestrates (it
# imports only latency at module scope; the engines load lazily inside
# PlannerService), so it comes right after latency here.
from repro.core.spec import (  # noqa: F401
    MeshSpec,
    PlanSpec,
    PlannerService,
    ScenarioRef,
    SurfaceAxes,
    build_surfaces_from_spec,
)
from repro.core.planner import (  # noqa: F401
    SegmentPlan,
    SplitPlan,
    compare_solvers,
    plan_pipeline,
    plan_split,
    plan_split_batch,
    plan_surface,
    tpu_cost_profile,
    uniform_split,
)
# NOTE: like sweep below, `repro.core.surface` must keep resolving to the
# submodule — only names are re-exported here, never a shadowing function.
from repro.core.surface import (  # noqa: F401
    DegradationSurface,
    ProtocolSurface,
    SurfaceLookup,
    SwitchPoint,
    build_surface,
    build_surfaces,
    refit_link,
)
# NOTE: the sweep() entry point itself is deliberately NOT re-exported
# here — `repro.core.sweep` must keep resolving to the submodule
# (`from repro.core.sweep import sweep` for the function).
from repro.core.sweep import (  # noqa: F401
    DP_BACKENDS,
    BatchedSolverResult,
    ParetoFrontier,
    Scenario,
    ScenarioGrid,
    SweepResult,
    SweepRow,
    batched_beam_search,
    batched_beam_search_all_k,
    batched_greedy_search,
    batched_greedy_search_all_k,
    batched_optimal_dp,
    batched_total_cost,
    apply_accuracy_floor,
    apply_energy_budget,
    combine_channels,
    pareto_frontier,
    solve_multi_channel,
    solve_variant_bank,
    stack_cost_tensors,
    sweep_scalar,
)
# NOTE: `repro.core.shard` likewise stays a submodule attribute (it
# imports sweep, so it must come after it here). Importing these names
# is cheap — JAX loads lazily, on the first sharded solve.
from repro.core.shard import (  # noqa: F401
    mesh_from_spec,
    scenario_shards,
    sharded_dp_tables,
    sharded_optimal_dp,
)
# NOTE: `repro.core.pallas_dp` likewise stays a submodule attribute (it
# imports sweep too). JAX/Pallas load lazily, on the first pallas solve.
from repro.core.pallas_dp import (  # noqa: F401
    pallas_dp_tables,
    pallas_fused_dp_tables,
    pallas_fused_optimal_dp,
    pallas_interpret_default,
    pallas_optimal_dp,
)
from repro.core.solvers import (  # noqa: F401
    SOLVERS,
    SolverResult,
    VariantInstance,
    beam_search,
    brute_force,
    budget_masked,
    first_fit_search,
    greedy_search,
    optimal_dp,
    random_fit,
    total_cost,
    total_energy,
)
# NOTE: `repro.core.async_replan` likewise stays a submodule attribute;
# it imports surface, so it must come after it (and before adaptive,
# which imports it).
from repro.core.async_replan import (  # noqa: F401
    ManualExecutor,
    RebuildFanout,
    RebuildHandle,
    RebuildRequest,
    SurfaceRebuilder,
    recentered_axes,
)
# NOTE: `repro.core.adaptive` likewise stays a submodule attribute; it
# imports planner/surface/sweep/async_replan, so it must come after
# them here.
from repro.core.adaptive import (  # noqa: F401
    AdaptiveSplitManager,
    LinkEstimator,
    PlanDecision,
    fleet_managers,
    optimize_chunk_size,
    surface_parity_report,
)
