"""Planner tier: one serializable request contract through every layer.

Nine PRs of features threaded their knobs (``backend=``, ``n_devices=``,
``variants=``, ``accuracy_floor=``, ``energy_budget=``, ``channels=``,
contention, mesh shape) hand-by-hand through ``solve_batched`` /
``solve_multi_channel`` / ``solve_variant_bank``, ``plan_split_batch``,
``build_surface(s)``, ``SurfaceRebuilder``, ``AdaptiveSplitManager`` and
``FleetGateway``. A request that lives in kwargs cannot be serialized,
and a request that cannot be serialized cannot cross a process boundary
— which blocks exactly the two ROADMAP scale seams (process-pool
rebuilds and a multi-host planner mesh). This module is the control
plane those seams hang off:

* :class:`PlanSpec` — a frozen, declarative description of ONE planning
  request: what to solve (scenario tensor shape / embedded surface
  problem), how (solver + backend + combine + mesh), and under which
  constraints (fleet-size vector, channel weights, energy budget,
  variant bank, accuracy floor). ``to_json``/``from_json`` round-trip
  every field exactly — finite floats bit-exact via ``repr``, non-finite
  floats through an explicit ``{"__float__": ...}`` tag so the payload
  is strict, NaN-free JSON — and the spec pickles, so it crosses both
  ``json`` and ``multiprocessing`` boundaries.

* :class:`PlannerService` — the execution tier that owns dispatch: it
  resolves a spec (plus its big operands — a stacked cost tensor, a
  list of cost models) to the existing batched implementations. The
  public kwarg entry points up the stack are thin shims that construct
  a spec and delegate here, so the spec path and the kwargs path are
  the SAME code and bit-identical by construction (property-tested in
  ``tests/test_spec.py`` across all four ``DP_BACKENDS``).

* :class:`MeshSpec` — the multi-host seam for ``backend="sharded"``:
  the shard mesh is constructed from the spec
  (:func:`repro.core.shard.mesh_from_spec`) instead of hard-coding
  ``jax.local_devices()``. The single-host default is node-identical to
  the historical local mesh; ``kind="distributed"`` initializes
  ``jax.distributed`` from the spec's coordinator fields.

* :func:`build_surfaces_from_spec` — the module-level (hence picklable)
  worker a :class:`~repro.core.async_replan.SurfaceRebuilder` submits
  to a ``ProcessPoolExecutor``: the spec ships to the worker process,
  the surfaces ship back, and the generation/swap semantics in the
  parent are untouched.

Import discipline: this module imports only the leaf cost-model layer
(:mod:`repro.core.latency`) at module scope; the solver/surface layers
load lazily inside :class:`PlannerService` methods, so ``spec`` sits
below every layer it orchestrates and anything can import it.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.latency import (
    COST_CHANNELS,
    BottleneckVariant,
    ContentionModel,
    DeviceProfile,
    LayerCost,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
)

__all__ = [
    "MeshSpec",
    "PlanSpec",
    "PlannerService",
    "ScenarioRef",
    "SurfaceAxes",
    "build_surfaces_from_spec",
    "solve_from_json",
]


@dataclass(frozen=True)
class MeshSpec:
    """How to build the ``backend="sharded"`` device mesh.

    ``kind="local"`` (default) is today's mesh: the first ``n_shards``
    local JAX devices (``None`` = all of them), node-identical to the
    pre-spec sharded path by construction. ``kind="distributed"`` is
    the multi-host seam: ``jax.distributed.initialize`` runs once from
    ``coordinator``/``num_processes``/``process_id`` (all ``None``
    means the environment — e.g. a launcher — already initialized it)
    and the mesh spans the GLOBAL device list. Hashable, so solver
    caches key on it like any other compile-relevant knob."""

    kind: str = "local"  # "local" | "distributed"
    n_shards: int | None = None
    axis: str = "s"
    coordinator: str | None = None  # "host:port" for jax.distributed
    num_processes: int | None = None
    process_id: int | None = None

    def __post_init__(self):
        if self.kind not in ("local", "distributed"):
            raise ValueError(f"unknown mesh kind {self.kind!r}; "
                             f"options: ['local', 'distributed']")


@dataclass(frozen=True)
class ScenarioRef:
    """What a spec's scenario axis refers to.

    ``kind`` names the operand family the service expects alongside the
    spec: ``"tensor"`` (a stacked ``(S, N, L, L)`` cost tensor),
    ``"channels"`` (``(ch, S, N, L, L)``), ``"variant_bank"``
    (``(V, S, N, L, L)``), ``"models"`` (a list of cost models), or
    ``"surface"`` (no operand — the problem is embedded in the spec's
    ``cost_model``/``protocols``/``surface`` fields). ``shape`` pins the
    operand shape for validation at resolve time."""

    kind: str
    shape: tuple[int, ...] | None = None
    count: int | None = None

    _KINDS = ("tensor", "channels", "variant_bank", "models", "surface")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown scenario kind {self.kind!r}; "
                             f"options: {list(self._KINDS)}")


@dataclass(frozen=True)
class SurfaceAxes:
    """The (packet-time × loss) grid axes of a surface-building spec.

    ``loss_p`` keeps the :func:`~repro.core.surface.build_surfaces`
    convention: ``None`` entries resolve to each protocol's base loss;
    a ``None`` axis means base loss only. ``chunk_candidates`` are the
    explicit activation-chunk candidates (``None`` = per-protocol
    defaults)."""

    pt_scale: tuple[float, ...]
    loss_p: tuple[float | None, ...] | None
    chunk_candidates: tuple[int, ...] | None = None


@dataclass(frozen=True)
class PlanSpec:
    """One declarative, serializable planning request.

    Every field is a frozen primitive / tuple / registered frozen
    dataclass, so the spec round-trips exactly through
    :meth:`to_json`/:meth:`from_json` AND through ``pickle`` — the
    contract that lets a request cross a process boundary. Construct
    directly, or via the builders (:func:`tensor_spec`,
    :func:`channels_spec`, :func:`variant_bank_spec`,
    :func:`models_spec`, :func:`surfaces_spec`) the kwarg shims use.

    ``n_devices`` is the fleet-size vector: ``None`` (tensor width),
    one ``int`` for every scenario, or a per-scenario tuple.
    ``solver_options`` carries solver-specific kwargs (``beam_width``,
    ``return_all_k``, ...) as sorted ``(key, value)`` pairs so the spec
    stays hashable-by-field and order-insensitive."""

    solver: str = "batched_dp"
    backend: str = "numpy"
    combine: str = "sum"
    scenario: ScenarioRef | None = None
    n_devices: int | tuple[int, ...] | None = None
    channels: tuple[str, ...] | None = None
    channel_weights: tuple[float, ...] | None = None
    channel_combines: tuple[str, ...] | None = None
    energy_budget: float | tuple[float, ...] | None = None
    variants: tuple[BottleneckVariant, ...] | None = None
    accuracy_proxy: tuple[float, ...] | None = None
    accuracy_floor: float | None = None
    cost_model: SplitCostModel | None = None
    protocols: tuple[tuple[str, LinkProfile], ...] | None = None
    surface: SurfaceAxes | None = None
    mesh: MeshSpec | None = None
    solver_options: tuple[tuple[str, object], ...] = ()

    def options(self) -> dict:
        """``solver_options`` as a plain kwargs dict."""
        return dict(self.solver_options)

    def to_json(self) -> str:
        """Strict (NaN-free) JSON encoding; exact field round-trip via
        :meth:`from_json`. Finite floats survive bit-for-bit (``repr``
        round-trip); non-finite floats are tagged
        ``{"__float__": "inf"|"-inf"|"nan"}`` so ``allow_nan=False``
        always holds."""
        return json.dumps(_encode(self), sort_keys=True, allow_nan=False)

    @classmethod
    def from_json(cls, payload: str) -> "PlanSpec":
        obj = _decode(json.loads(payload, parse_constant=_reject_constant))
        if not isinstance(obj, cls):
            raise ValueError(
                f"payload decodes to {type(obj).__name__}, not PlanSpec")
        return obj


# ---------------------------------------------------------------------------
# JSON codec (tagged, recursive, NaN-free)
# ---------------------------------------------------------------------------

# every dataclass a PlanSpec may embed, by name. Decoding instantiates
# ONLY these types — an unknown __type__ tag is an error, not an eval.
_SPEC_TYPES: dict[str, type] = {
    cls.__name__: cls
    for cls in (
        LayerCost,
        DeviceProfile,
        LinkProfile,
        ContentionModel,
        BottleneckVariant,
        ModelCostProfile,
        SplitCostModel,
        ScenarioRef,
        SurfaceAxes,
        MeshSpec,
        PlanSpec,
    )
}


def _reject_constant(token: str):
    raise ValueError(f"non-strict JSON constant {token!r} in PlanSpec "
                     f"payload (the codec tags non-finite floats)")


def _encode(obj):
    if obj is None or isinstance(obj, (bool, str)):
        return obj
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        f = float(obj)
        if math.isfinite(f):
            return f
        tag = "nan" if math.isnan(f) else ("inf" if f > 0 else "-inf")
        return {"__float__": tag}
    if isinstance(obj, tuple):
        return {"__tuple__": [_encode(v) for v in obj]}
    if isinstance(obj, list):
        return [_encode(v) for v in obj]
    name = type(obj).__name__
    if dataclasses.is_dataclass(obj) and _SPEC_TYPES.get(name) is type(obj):
        out: dict = {"__type__": name}
        for f in dataclasses.fields(obj):
            out[f.name] = _encode(getattr(obj, f.name))
        return out
    raise TypeError(f"PlanSpec JSON codec cannot encode "
                    f"{type(obj).__name__}: {obj!r}")


_FLOAT_TAGS = {"nan": float("nan"), "inf": float("inf"),
               "-inf": float("-inf")}


def _decode(obj):
    if isinstance(obj, dict):
        if set(obj) == {"__float__"}:
            return _FLOAT_TAGS[obj["__float__"]]
        if set(obj) == {"__tuple__"}:
            return tuple(_decode(v) for v in obj["__tuple__"])
        if "__type__" in obj:
            try:
                cls = _SPEC_TYPES[obj["__type__"]]
            except KeyError:
                raise ValueError(f"unknown PlanSpec type tag "
                                 f"{obj['__type__']!r}") from None
            return cls(**{k: _decode(v) for k, v in obj.items()
                          if k != "__type__"})
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


# ---------------------------------------------------------------------------
# Normalization: kwargs values -> frozen spec fields, value-preserving
# ---------------------------------------------------------------------------


def _norm_n(n) -> int | tuple[int, ...] | None:
    """Fleet sizes -> None / int / tuple[int, ...]. Value-preserving:
    the solver re-derives the exact same ``np.int64`` vector from the
    tuple, so spec-path results stay bit-identical."""
    if n is None or isinstance(n, (int, np.integer)):
        return None if n is None else int(n)
    return tuple(int(v) for v in np.asarray(n).reshape(-1))


def _norm_budget(b) -> float | tuple[float, ...] | None:
    if b is None:
        return None
    arr = np.asarray(b, dtype=np.float64)
    if arr.ndim == 0:
        return float(arr)
    return tuple(float(v) for v in arr)


def _norm_floats(seq) -> tuple[float, ...] | None:
    if seq is None:
        return None
    return tuple(float(v) for v in np.asarray(seq, dtype=np.float64))


def _norm_loss(loss_p) -> tuple[float | None, ...] | None:
    if loss_p is None:
        return None
    return tuple(None if lp is None else float(lp) for lp in loss_p)


def _norm_options(options: Mapping[str, object]) -> tuple:
    return tuple(sorted(options.items()))


def _norm_variants(variants) -> tuple[BottleneckVariant, ...] | None:
    return None if variants is None else tuple(variants)


# ---------------------------------------------------------------------------
# Spec builders — what the kwarg shims construct
# ---------------------------------------------------------------------------


def tensor_spec(C, *, solver="batched_dp", combine="sum", backend="numpy",
                n_devices=None, mesh=None, **options) -> PlanSpec:
    """Spec for a plain batched solve over a stacked ``(S, N, L, L)``
    tensor (the :func:`repro.core.sweep.solve_batched` contract)."""
    return PlanSpec(
        solver=solver, backend=backend, combine=combine,
        scenario=ScenarioRef(kind="tensor",
                             shape=tuple(int(d) for d in np.shape(C))),
        n_devices=_norm_n(n_devices), mesh=mesh,
        solver_options=_norm_options(options),
    )


def channels_spec(C, *, channels=COST_CHANNELS, solver="batched_dp",
                  combine="sum", backend="numpy", n_devices=None,
                  energy_budget=None, channel_weights=None,
                  channel_combines=None, mesh=None, **options) -> PlanSpec:
    """Spec for a multi-channel solve over ``(ch, S, N, L, L)`` (the
    :func:`repro.core.sweep.solve_multi_channel` contract)."""
    return PlanSpec(
        solver=solver, backend=backend, combine=combine,
        scenario=ScenarioRef(kind="channels",
                             shape=tuple(int(d) for d in np.shape(C))),
        n_devices=_norm_n(n_devices),
        channels=tuple(channels),
        channel_weights=_norm_floats(channel_weights),
        channel_combines=(None if channel_combines is None
                          else tuple(channel_combines)),
        energy_budget=_norm_budget(energy_budget), mesh=mesh,
        solver_options=_norm_options(options),
    )


def variant_bank_spec(C, *, solver="batched_dp", combine="sum",
                      backend="numpy", n_devices=None, accuracy_proxy=None,
                      accuracy_floor=None, mesh=None, **options) -> PlanSpec:
    """Spec for a joint (split, variant) solve over ``(V, S, N, L, L)``
    (the :func:`repro.core.sweep.solve_variant_bank` contract)."""
    return PlanSpec(
        solver=solver, backend=backend, combine=combine,
        scenario=ScenarioRef(kind="variant_bank",
                             shape=tuple(int(d) for d in np.shape(C))),
        n_devices=_norm_n(n_devices),
        accuracy_proxy=_norm_floats(accuracy_proxy),
        accuracy_floor=(None if accuracy_floor is None
                        else float(accuracy_floor)),
        mesh=mesh, solver_options=_norm_options(options),
    )


def models_spec(cost_models, *, n_devices, solver="batched_dp",
                backend="numpy", energy_budget=None, variants=None,
                accuracy_floor=None, mesh=None, **options) -> PlanSpec:
    """Spec for a cost-model batch (the
    :func:`repro.core.planner.plan_split_batch` contract). The models
    travel ALONGSIDE the spec (they are the big operand); the spec
    records the request shape."""
    combine = "sum"
    if cost_models and cost_models[0].objective == "bottleneck":
        combine = "max"
    return PlanSpec(
        solver=solver, backend=backend, combine=combine,
        scenario=ScenarioRef(kind="models", count=len(cost_models)),
        n_devices=_norm_n(n_devices),
        energy_budget=_norm_budget(energy_budget),
        variants=_norm_variants(variants),
        accuracy_floor=(None if accuracy_floor is None
                        else float(accuracy_floor)),
        mesh=mesh, solver_options=_norm_options(options),
    )


def surfaces_spec(cost_model, protocols, sizes, *, pt_scale, loss_p,
                  solver="batched_beam", backend="numpy", beam_width=8,
                  chunk_candidates=None, energy_budget=None, variants=None,
                  accuracy_floor=None, mesh=None) -> PlanSpec:
    """Spec for a surface-family build (the
    :func:`repro.core.surface.build_surfaces` contract). Unlike the
    tensor specs this one is SELF-CONTAINED — cost model, protocol
    links, and grid axes are all spec fields — which is exactly what
    lets a rebuild cross a process boundary
    (:func:`build_surfaces_from_spec`)."""
    if isinstance(protocols, Mapping):
        proto_pairs = tuple(protocols.items())
    else:
        proto_pairs = tuple(protocols)
    combine = "max" if cost_model.objective == "bottleneck" else "sum"
    return PlanSpec(
        solver=solver, backend=backend, combine=combine,
        scenario=ScenarioRef(kind="surface"),
        n_devices=tuple(int(n) for n in sizes),
        energy_budget=_norm_budget(energy_budget),
        variants=_norm_variants(variants),
        accuracy_floor=(None if accuracy_floor is None
                        else float(accuracy_floor)),
        cost_model=cost_model,
        protocols=proto_pairs,
        surface=SurfaceAxes(
            pt_scale=tuple(float(s) for s in pt_scale),
            loss_p=_norm_loss(loss_p),
            chunk_candidates=(None if chunk_candidates is None
                              else tuple(int(c) for c in chunk_candidates)),
        ),
        mesh=mesh,
        solver_options=(("beam_width", int(beam_width)),),
    )


# ---------------------------------------------------------------------------
# PlannerService — the execution tier
# ---------------------------------------------------------------------------


class PlannerService:
    """Resolves a :class:`PlanSpec` to the batched planning engines.

    The service owns dispatch: the public kwarg entry points
    (``solve_batched``/``solve_multi_channel``/``solve_variant_bank``,
    ``plan_split_batch``, ``build_surfaces``) are shims that build a
    spec and call one of these methods, and the methods call the single
    retained implementation — so spec-path and kwargs-path results are
    the same code path and bit-identical by construction. Stateless and
    cheap: construct freely (one per call site is fine)."""

    # -- operand validation -------------------------------------------------
    @staticmethod
    def _check_operand(spec: PlanSpec, kind: str, shape=None) -> None:
        ref = spec.scenario
        if ref is None:
            return  # hand-built spec without a ref: trust the caller
        if ref.kind != kind:
            raise ValueError(f"spec scenario kind {ref.kind!r} does not "
                             f"match operand kind {kind!r}")
        if shape is not None and ref.shape is not None \
                and tuple(ref.shape) != tuple(shape):
            raise ValueError(f"spec scenario shape {ref.shape} does not "
                             f"match operand shape {tuple(shape)}")

    # -- solves over stacked tensors ---------------------------------------
    def solve(self, spec: PlanSpec, C):
        """Resolve a ``"tensor"`` spec against its stacked cost tensor."""
        from repro.core import sweep as SW

        self._check_operand(spec, "tensor", np.shape(C))
        return SW._solve_batched_impl(
            C, solver=spec.solver, combine=spec.combine,
            backend=spec.backend, n_devices=spec.n_devices,
            mesh_spec=spec.mesh, **spec.options())

    def solve_multi_channel(self, spec: PlanSpec, C):
        """Resolve a ``"channels"`` spec against ``(ch, S, N, L, L)``."""
        from repro.core import sweep as SW

        self._check_operand(spec, "channels", np.shape(C))
        return SW._solve_multi_channel_impl(
            C, channels=spec.channels or COST_CHANNELS,
            solver=spec.solver, combine=spec.combine, backend=spec.backend,
            n_devices=spec.n_devices, energy_budget=spec.energy_budget,
            channel_weights=spec.channel_weights,
            channel_combines=spec.channel_combines,
            mesh_spec=spec.mesh, **spec.options())

    def solve_variant_bank(self, spec: PlanSpec, C):
        """Resolve a ``"variant_bank"`` spec against ``(V, S, N, L, L)``."""
        from repro.core import sweep as SW

        self._check_operand(spec, "variant_bank", np.shape(C))
        return SW._solve_variant_bank_impl(
            C, solver=spec.solver, combine=spec.combine,
            backend=spec.backend, n_devices=spec.n_devices,
            accuracy_proxy=spec.accuracy_proxy,
            accuracy_floor=spec.accuracy_floor,
            mesh_spec=spec.mesh, **spec.options())

    # -- cost-model batches --------------------------------------------------
    def plan(self, spec: PlanSpec, cost_models: Sequence[SplitCostModel]):
        """Resolve a ``"models"`` spec against its cost-model batch."""
        from repro.core import planner as PL

        self._check_operand(spec, "models")
        if spec.scenario is not None and spec.scenario.count is not None \
                and spec.scenario.count != len(cost_models):
            raise ValueError(
                f"spec records {spec.scenario.count} cost models, got "
                f"{len(cost_models)}")
        n = spec.n_devices
        if n is None:
            raise ValueError("a 'models' spec needs n_devices")
        return PL._plan_split_batch_impl(
            cost_models, n, solver=spec.solver, backend=spec.backend,
            energy_budget=spec.energy_budget, variants=spec.variants,
            accuracy_floor=spec.accuracy_floor, mesh_spec=spec.mesh,
            **spec.options())

    # -- surface families ----------------------------------------------------
    def build_surfaces(self, spec: PlanSpec):
        """Resolve a self-contained ``"surface"`` spec to the surface
        family ``{n_devices: DegradationSurface}``."""
        from repro.core import surface as SF

        self._check_operand(spec, "surface")
        if spec.cost_model is None or spec.protocols is None \
                or spec.surface is None:
            raise ValueError("a 'surface' spec needs cost_model, protocols "
                             "and surface axes")
        opts = spec.options()
        return SF._build_surfaces_impl(
            spec.cost_model, dict(spec.protocols), spec.n_devices,
            pt_scale=spec.surface.pt_scale, loss_p=spec.surface.loss_p,
            solver=spec.solver, backend=spec.backend,
            beam_width=int(opts.get("beam_width", 8)),
            chunk_candidates=spec.surface.chunk_candidates,
            energy_budget=spec.energy_budget, variants=spec.variants,
            accuracy_floor=spec.accuracy_floor, mesh_spec=spec.mesh)


# ---------------------------------------------------------------------------
# Process-boundary workers (module-level => picklable)
# ---------------------------------------------------------------------------


def build_surfaces_from_spec(spec: PlanSpec | str):
    """Build a surface family from a spec — THE process-pool rebuild
    worker. Module-level so ``ProcessPoolExecutor`` can pickle it;
    accepts either a :class:`PlanSpec` (pickled across the boundary) or
    its :meth:`~PlanSpec.to_json` payload. Returns the
    ``{n_devices: DegradationSurface}`` family, which pickles back to
    the parent for the ordinary generation/swap adoption path."""
    if isinstance(spec, str):
        spec = PlanSpec.from_json(spec)
    return PlannerService().build_surfaces(spec)


def solve_from_json(payload: str, C):
    """Solve a JSON-encoded ``"tensor"`` spec against ``C`` — the
    subprocess twin of :meth:`PlannerService.solve`, used by the
    spec-pickling parity tests and :mod:`benchmarks.planner_scale` to
    prove an out-of-process solve is bitwise identical to the
    in-process one."""
    return PlannerService().solve(PlanSpec.from_json(payload), C)
