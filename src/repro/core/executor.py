"""Split-plan executor: actually run a partitioned model segment-by-segment.

This is the runtime counterpart of the planner — it takes a
:class:`~repro.core.planner.SplitPlan` (or raw split points) and a
*sequential layer-list model* and executes each segment as if on its own
device, simulating the device hop at every boundary:

  1. run layers [s_{i-1}+1 .. s_i] on "device" i,
  2. quantize the boundary activation to the int8 wire format,
  3. account packets / expected transmission time on the link profile,
  4. dequantize on "device" i+1 and continue.

Correctness property (tested): with ``quantize_wire=False`` the split
execution is bit-identical to the unsplit forward pass for any split
configuration — split inference must not change the function.

A sequential layer-list model is any object with:
  * ``layer_names`` — ordered list of L layer names,
  * ``init(rng)``   — params dict keyed by layer name,
  * ``apply_layer(name, params, x)`` — apply one layer.
CNNs with residual blocks fold the skip into block-level layers so the
chain is truly sequential (the paper's Eq. 1 view).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Protocol, Sequence

import jax
import jax.numpy as jnp

from repro.core.latency import LinkProfile
from repro.core.quantization import decode_activation, encode_activation


class SequentialModel(Protocol):
    layer_names: Sequence[str]

    def init(self, rng: jax.Array) -> dict: ...

    def apply_layer(self, name: str, params: Any, x: jax.Array) -> jax.Array: ...


@dataclass
class HopRecord:
    boundary_layer: str
    nbytes: int
    n_packets: int
    sim_latency_s: float


@dataclass
class ExecutionTrace:
    hops: list[HopRecord] = field(default_factory=list)

    @property
    def total_tx_bytes(self) -> int:
        return sum(h.nbytes for h in self.hops)

    @property
    def total_tx_latency_s(self) -> float:
        return sum(h.sim_latency_s for h in self.hops)


def segment_bounds(splits: Sequence[int], num_layers: int) -> list[tuple[int, int]]:
    """[(first, last)] 1-indexed inclusive segments from split points."""
    bounds = [0, *splits, num_layers]
    out = []
    for i in range(len(bounds) - 1):
        if not bounds[i] < bounds[i + 1]:
            raise ValueError(f"invalid splits {splits} for L={num_layers}")
        out.append((bounds[i] + 1, bounds[i + 1]))
    return out


def _wire_encode(carry):
    """Ship the live carry across a device hop: int8-quantize every float
    leaf (the TinyML wire format), return (decoded carry, wire bytes)."""
    leaves, treedef = jax.tree.flatten(carry)
    nbytes = 0
    out = []
    for leaf in leaves:
        qt = encode_activation(leaf)
        nbytes += qt.nbytes
        out.append(decode_activation(qt, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, out), nbytes


def _carry_bytes(carry) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(carry))


def run_split(
    model: SequentialModel,
    params: dict,
    x,
    splits: Sequence[int],
    *,
    link: LinkProfile | None = None,
    quantize_wire: bool = False,
):
    """Execute the model partitioned at ``splits``, simulating device hops.

    The carry ``x`` may be any pytree (CNN blocks carry the residual skip
    alongside the main tensor). ``quantize_wire=True`` ships int8
    activations (the deployed TinyML wire format); ``False`` ships the
    float tensors (used for the exactness property). Returns
    ``(final_carry, ExecutionTrace)``."""
    names = list(model.layer_names)
    trace = ExecutionTrace()
    for seg_idx, (a, b) in enumerate(segment_bounds(splits, len(names))):
        for li in range(a, b + 1):
            name = names[li - 1]
            x = model.apply_layer(name, params[name], x)
        is_last = b == len(names)
        if not is_last:
            if quantize_wire:
                x, nbytes = _wire_encode(x)
            else:
                nbytes = _carry_bytes(x)
            if link is not None:
                trace.hops.append(
                    HopRecord(
                        boundary_layer=names[b - 1],
                        nbytes=nbytes,
                        n_packets=link.packets(nbytes),
                        sim_latency_s=link.transmission_latency_s(nbytes),
                    )
                )
            else:
                trace.hops.append(HopRecord(names[b - 1], nbytes, 0, 0.0))
    return x, trace


def run_unsplit(model: SequentialModel, params: dict, x):
    """Reference forward pass (no partitioning)."""
    for name in model.layer_names:
        x = model.apply_layer(name, params[name], x)
    return x
