"""Precomputed degradation surfaces for O(1) adaptive re-planning.

The adaptive manager's ``observe()`` used to re-solve Beam Search over
every protocol on every hop measurement — a fleet controller calls it on
every packet, so the solver was the hot loop. But the solver's *input*
only drifts along two axes per protocol: the estimated per-packet time
and the estimated loss rate (everything else — the model, the devices,
the protocol constants — is fixed at deployment). That makes the whole
decision problem precomputable:

* :class:`DegradationSurface` — for each protocol, a dense
  (packet-time × loss) grid of link conditions; at every node the best
  plan (splits + tuned activation chunk), its end-to-end latency, and
  the runner-up plan from the protocol's plan portfolio. All nodes of
  all protocols are solved in ONE batched sweep-engine pass
  (:func:`repro.core.sweep.solve_batched` over a stacked cost tensor).

* *Switch points* — the link-condition boundaries where the argmin plan
  changes between adjacent grid nodes. These are the degradation
  thresholds the paper's Sec. VI future work asks for: "at what point
  does the optimal split move / the protocol switch pay?"

* Bilinear interpolation of latency between grid nodes, so the runtime
  gets a continuous latency estimate from a discrete surface.

* :func:`build_surfaces` — surface *families* for several fleet sizes
  in ONE batched solve (all-k DP table sharing; all-k beam/greedy
  block batching) — no per-N re-solve loop on any solver path.

At a grid node the stored decision is **exactly** what the legacy
re-solve path would compute for the same estimator state (same solver,
same chunk tuning, same ``end_to_end_s`` floats — the benchmark
``benchmarks/surface_replan.py`` asserts ``==`` parity node-by-node on
the NumPy float64 path). Between nodes the plan comes from the nearest
node and the latency from bilinear interpolation; outside the grid's
envelope the runtime falls back to an exact re-solve.
"""

from __future__ import annotations

import time
from bisect import bisect_right
from dataclasses import dataclass, replace
from typing import Mapping, Sequence

import numpy as np

from repro.core import sweep as SW
from repro.core.latency import BottleneckVariant, LinkProfile, SplitCostModel

INF = float("inf")

# Loss estimates at or above this map to the identical re-fitted link
# (the refit_link clamp), so surface queries clamp the loss coordinate
# here EXACTLY — the loss-axis mirror of the packet-time saturation
# floor. Keep in sync with nothing: refit_link below is the single
# source and everything else reads this constant.
LOSS_CLAMP = 0.9

__all__ = [
    "DEFAULT_LOSS_GRID",
    "DEFAULT_PT_SCALES",
    "DegradationSurface",
    "ProtocolSurface",
    "SurfaceLookup",
    "SwitchPoint",
    "build_surface",
    "build_surfaces",
    "optimize_chunk_size",
    "refit_link",
]

# Default envelope: packet time from nominal up to 512x degradation
# (geometric — the adaptive example's deepest phase is 400x), loss from
# the clean channel up to 30%.
DEFAULT_PT_SCALES: tuple[float, ...] = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0,
                                        64.0, 128.0, 256.0, 512.0)
DEFAULT_LOSS_GRID: tuple[float, ...] = (0.0, 0.05, 0.10, 0.20, 0.30)


def refit_link(base: LinkProfile, packet_time_s: float,
               loss_p: float) -> LinkProfile:
    """Map an estimator state (per-packet time, loss) onto ``base``.

    Args:
      base: the protocol's deployment-time :class:`LinkProfile`.
      packet_time_s: estimated expected per-packet time.
      loss_p: estimated loss probability, clamped into
        ``[0, LOSS_CLAMP]`` BEFORE any arithmetic — every estimate at
        or above the clamp maps to the identical link, so surface
        lookups may clamp the loss coordinate exactly (the loss-axis
        mirror of the packet-time saturation floor).

    Returns the base profile re-fitted so that
    ``profile.packet_time_s()`` reproduces the estimate: the
    serialization term keeps the base rate, the residual moves into the
    ack/overhead term (floored at 0 — estimates faster than loss-free
    serialization + propagation saturate, which is why surface axes
    include that floor as their minimum).

    Invariant (single-sourcing): this function is the ONLY
    estimator-state → :class:`LinkProfile` mapping. Both
    :meth:`LinkEstimator.current_profile
    <repro.core.adaptive.LinkEstimator.current_profile>` and surface
    construction call it, so a surface node's link reproduces the
    estimator's re-fitted profile bit-for-bit at the same state.
    Changing either caller to do its own mapping (or editing this
    arithmetic in one place only) breaks the node-exact ``==`` parity
    that ``tests/test_surface.py`` and ``benchmarks/surface_replan.py``
    assert."""
    loss = min(max(loss_p, 0.0), LOSS_CLAMP)
    serial = base.mtu_bytes / (base.rate_bytes_per_s * (1.0 - loss))
    t_ack = max(0.0, packet_time_s - serial - base.t_prop_s)
    return replace(base, t_ack_s=t_ack, loss_p=loss)


def optimize_chunk_size(
    link: LinkProfile,
    cut_bytes: Sequence[int],
    chunk_candidates: Sequence[int] | None = None,
) -> tuple[int, float]:
    """Best activation chunk size for a set of cut sizes (Eq. 7 summed
    over the plan's hops). Candidates default to divisors-of-MTU-ish
    steps below the protocol MTU."""
    if chunk_candidates is None:
        mtu = link.mtu_bytes
        chunk_candidates = sorted({mtu, mtu * 3 // 4, mtu // 2, 1200, 250}
                                  & set(range(1, mtu + 1))
                                  | {mtu})
        chunk_candidates = [c for c in chunk_candidates if 0 < c <= mtu]
    best = (link.mtu_bytes, float("inf"))
    for chunk in chunk_candidates:
        trial = replace(link, mtu_bytes=chunk)
        total = sum(trial.transmission_latency_s(b) for b in cut_bytes)
        if total < best[1]:
            best = (chunk, total)
    return best


# ---------------------------------------------------------------------------
# Surface data model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SwitchPoint:
    """A link-condition boundary where the argmin plan changes.

    The plan flips somewhere between ``lo`` and ``hi`` on ``axis``
    (holding the other coordinate at ``fixed``); ``plan_lo``/``plan_hi``
    are the best splits on either side."""

    protocol: str
    axis: str  # "packet_time_s" | "loss_p"
    fixed: float  # the other coordinate's grid value
    lo: float
    hi: float
    plan_lo: tuple[int, ...]
    plan_hi: tuple[int, ...]


@dataclass(frozen=True)
class SurfaceLookup:
    """One surface query: the nearest node's decision plus the
    bilinearly interpolated latency at the exact query point."""

    protocol: str
    splits: tuple[int, ...]
    chunk_bytes: int
    latency_s: float  # bilinear interpolation at the query point
    node_latency_s: float  # the nearest node's stored latency
    feasible: bool
    in_envelope: bool
    # the node's adopted bottleneck variant: its index into the bank the
    # surface was built with (0 = the bank's first entry, and also the
    # value on surfaces built without a bank — the single-variant case)
    variant: int = 0


@dataclass(frozen=True)
class ProtocolSurface:
    """One protocol's (packet-time × loss) decision grid."""

    protocol: str
    base: LinkProfile
    packet_time_s: tuple[float, ...]  # (T,) ascending
    loss_p: tuple[float, ...]  # (G,) ascending
    splits: np.ndarray  # (T, G, N-1) int64, -1 where infeasible
    chunk_bytes: np.ndarray  # (T, G) int64
    latency_s: np.ndarray  # (T, G) float64, +inf where infeasible
    runner_splits: np.ndarray  # (T, G, N-1) int64, -1 where absent
    runner_latency_s: np.ndarray  # (T, G) float64, +inf where absent
    # per-node winning bottleneck-variant indices into the bank the
    # surface was built with; None on surfaces built without a bank
    variant: np.ndarray | None = None  # (T, G) int64

    def __post_init__(self):
        # hot-path caches: plain-Python node decisions and latency rows so
        # lookups never touch numpy scalars (observe() calls this per hop)
        T, G = len(self.packet_time_s), len(self.loss_p)
        nodes = [[None] * G for _ in range(T)]
        lat = [[0.0] * G for _ in range(T)]
        for i in range(T):
            for j in range(G):
                z = float(self.latency_s[i, j])
                sp = self.splits[i, j]
                feas = not (sp.size and (sp < 0).any()) and np.isfinite(z)
                vi = 0 if self.variant is None else int(self.variant[i, j])
                nodes[i][j] = SurfaceLookup(
                    protocol=self.protocol,
                    splits=tuple(int(x) for x in sp) if feas else (),
                    chunk_bytes=int(self.chunk_bytes[i, j]),
                    latency_s=z, node_latency_s=z,
                    feasible=feas, in_envelope=True,
                    variant=max(vi, 0),
                )
                lat[i][j] = z
        object.__setattr__(self, "_nodes", nodes)
        object.__setattr__(self, "_lat", lat)

    @property
    def n_nodes(self) -> int:
        return len(self.packet_time_s) * len(self.loss_p)

    def node(self, i: int, j: int) -> SurfaceLookup:
        return self._nodes[i][j]


def _cell(axis: Sequence[float], x: float,
          clamp_low: bool = False) -> tuple[int, int, float, bool]:
    """Bracket ``x`` in ``axis``: (i0, i1, weight toward i1, inside).

    Clamps outside the envelope (weight 0, ``inside=False``). At an
    exact node the weight is exactly 0.0, so interpolation returns the
    node value bitwise. ``clamp_low`` treats below-minimum queries as
    inside — used for the packet-time axis, whose minimum is the
    :func:`refit_link` saturation floor (every packet time at or below
    it maps to the identical link, so the clamp is exact, not an
    approximation)."""
    if x <= axis[0]:
        return 0, 0, 0.0, clamp_low or x == axis[0]
    if x >= axis[-1]:
        n = len(axis) - 1
        return n, n, 0.0, x == axis[-1]
    i = bisect_right(axis, x) - 1  # axis[i] <= x < axis[i+1]
    if axis[i] == x:
        return i, i, 0.0, True
    return i, i + 1, (x - axis[i]) / (axis[i + 1] - axis[i]), True


def _bilinear(z, i0, i1, wt, j0, j1, wl) -> float:
    """Weighted corner sum over nested-list rows, skipping zero-weight
    corners so an infeasible (+inf) corner outside the active cell edge
    cannot poison an on-node or on-edge query with inf*0 = nan."""
    acc = 0.0
    r0, r1 = z[i0], z[i1]
    for w, zz in (((1 - wt) * (1 - wl), r0[j0]),
                  (wt * (1 - wl), r1[j0]),
                  ((1 - wt) * wl, r0[j1]),
                  (wt * wl, r1[j1])):
        if w:
            acc += w * zz
    return acc


@dataclass(frozen=True)
class DegradationSurface:
    """Per-protocol degradation surfaces + cross-protocol argmin lookup."""

    protocols: Mapping[str, ProtocolSurface]
    n_devices: int
    solver: str
    build_time_s: float
    solve_time_s: float  # batched sweep-engine passes only

    def __post_init__(self):
        object.__setattr__(self, "protocols", dict(self.protocols))
        object.__setattr__(self, "_env", {
            name: (p.packet_time_s[0], p.packet_time_s[-1],
                   p.loss_p[0], p.loss_p[-1])
            for name, p in self.protocols.items()
        })

    @property
    def n_nodes(self) -> int:
        return sum(p.n_nodes for p in self.protocols.values())

    def envelope(self, protocol: str) -> tuple[tuple[float, float],
                                               tuple[float, float]]:
        plo, phi, llo, lhi = self._env[protocol]
        return ((plo, phi), (llo, lhi))

    def in_envelope(self, protocol: str, packet_time_s: float,
                    loss_p: float) -> bool:
        """Below-minimum packet times count as inside: the axis minimum
        is the refit saturation floor, below which every estimate maps
        to the same link (see :func:`_cell`'s ``clamp_low``). Loss is
        clamped at ``LOSS_CLAMP`` the same way: every estimate at or
        above it re-fits to the identical link, so an axis reaching the
        clamp covers all heavier loss exactly."""
        plo, phi, llo, lhi = self._env[protocol]
        loss = min(loss_p, LOSS_CLAMP)
        return packet_time_s <= phi and llo <= loss <= lhi

    def covers(self, states: Mapping[str, tuple[float, float]]) -> bool:
        """True when EVERY ``{protocol: (packet_time_s, loss_p)}`` state
        is inside its protocol's envelope — the condition under which
        :meth:`best_lookup` can rank protocols without a re-solve (the
        async rebuilder re-centers axes precisely so the drifted states
        satisfy this on the rebuilt surface)."""
        return all(self.in_envelope(name, pt, lp)
                   for name, (pt, lp) in states.items())

    def lookup(self, protocol: str, packet_time_s: float,
               loss_p: float) -> SurfaceLookup:
        """Nearest-node plan + bilinearly interpolated latency."""
        p = self.protocols[protocol]
        i0, i1, wt, ok_t = _cell(p.packet_time_s, packet_time_s,
                                 clamp_low=True)
        j0, j1, wl, ok_l = _cell(p.loss_p, min(loss_p, LOSS_CLAMP))
        ni = i1 if wt >= 0.5 else i0
        nj = j1 if wl >= 0.5 else j0
        node = p._nodes[ni][nj]
        lat = _bilinear(p._lat, i0, i1, wt, j0, j1, wl)
        if lat == node.latency_s and ok_t and ok_l:
            return node  # on-node query: hand back the cached decision
        return replace(node, latency_s=lat, in_envelope=ok_t and ok_l)

    def latency_at(self, protocol: str, packet_time_s: float,
                   loss_p: float) -> float:
        """Bilinear latency interpolation at an arbitrary link state."""
        return self.lookup(protocol, packet_time_s, loss_p).latency_s

    def best_lookup(
        self, states: Mapping[str, tuple[float, float]]
    ) -> SurfaceLookup | None:
        """Argmin over protocols, each queried at its own estimator
        state ``(packet_time_s, loss_p)`` — the O(1) replacement for the
        per-observe re-solve. Returns None when ANY state has left its
        protocol's envelope (the precomputed decisions can no longer
        rank that protocol, so the caller must re-solve exactly) or when
        no queried node is feasible."""
        best_lat = INF
        best: SurfaceLookup | None = None
        for name, (pt, lp) in states.items():
            p = self.protocols[name]
            i0, i1, wt, ok_t = _cell(p.packet_time_s, pt, clamp_low=True)
            j0, j1, wl, ok_l = _cell(p.loss_p, min(lp, LOSS_CLAMP))
            if not (ok_t and ok_l):
                return None
            node = p._nodes[i1 if wt >= 0.5 else i0][j1 if wl >= 0.5 else j0]
            if not node.feasible:
                continue
            lat = _bilinear(p._lat, i0, i1, wt, j0, j1, wl)
            if lat < best_lat:
                best_lat, best = lat, node
        if best is None or best_lat == best.latency_s:
            return best
        return replace(best, latency_s=best_lat)

    # -- switch points ------------------------------------------------------
    def switch_points(self, protocol: str | None = None) -> list[SwitchPoint]:
        """Boundaries between adjacent grid nodes where the best plan
        changes — the precomputed 'when does the split move' thresholds.
        Feasibility boundaries are not plan switches: pairs with an
        infeasible side are skipped rather than reported with the ``-1``
        sentinel as a phantom plan."""
        names = [protocol] if protocol is not None else list(self.protocols)
        out: list[SwitchPoint] = []
        for name in names:
            p = self.protocols[name]
            T, G = len(p.packet_time_s), len(p.loss_p)

            def plan(i, j):
                node = p._nodes[i][j]
                return node.splits if node.feasible else None

            for j in range(G):
                for i in range(T - 1):
                    a, b = plan(i, j), plan(i + 1, j)
                    if a is not None and b is not None and a != b:
                        out.append(SwitchPoint(
                            name, "packet_time_s", p.loss_p[j],
                            p.packet_time_s[i], p.packet_time_s[i + 1], a, b))
            for i in range(T):
                for j in range(G - 1):
                    a, b = plan(i, j), plan(i, j + 1)
                    if a is not None and b is not None and a != b:
                        out.append(SwitchPoint(
                            name, "loss_p", p.packet_time_s[i],
                            p.loss_p[j], p.loss_p[j + 1], a, b))
        return out

    # -- construction -------------------------------------------------------
    @classmethod
    def from_scenario_grid(
        cls,
        grid,  # sweep.ScenarioGrid
        model: str | None = None,
        n_devices: int | None = None,
        mix: str | None = None,
        **kwargs,
    ) -> "DegradationSurface":
        """Build a surface whose axes come from a
        :class:`~repro.core.sweep.ScenarioGrid`'s link axes: packet
        times from the grid's ``rate_scale`` values, losses from its
        ``loss_p`` values (None → each protocol's base loss).
        ``n_devices`` defaults to the grid's largest fleet size; ``mix``
        selects one of the grid's ``device_mixes`` (defaults to the
        shared ``devices`` fleet, or the grid's only mix)."""
        if n_devices is None:
            n_devices = max(grid.n_devices)
        cost_model, pt_scales, losses = _grid_surface_args(grid, model, mix)
        return build_surface(
            cost_model, grid.links, n_devices,
            pt_scale=pt_scales, loss_p=losses,
            **kwargs,
        )


def _grid_surface_args(grid, model: str | None, mix: str | None):
    """Shared ScenarioGrid → surface-axis derivation (single- and
    multi-N construction paths must never drift apart)."""
    if model is None:
        if len(grid.models) != 1:
            raise ValueError(
                f"grid has models {sorted(grid.models)}; pass model=...")
        model = next(iter(grid.models))
    if mix is None and not grid.devices:
        if len(grid.device_mixes or {}) != 1:
            raise ValueError(
                f"grid has device mixes {sorted(grid.device_mixes or {})} "
                f"and no shared devices; pass mix=...")
        mix = next(iter(grid.device_mixes))
    if mix is not None:
        if not grid.device_mixes:
            raise ValueError(
                f"mix={mix!r} given but the grid has no device_mixes")
        if mix not in grid.device_mixes:
            raise ValueError(f"unknown device mix {mix!r}; "
                             f"options: {sorted(grid.device_mixes)}")
        devices = grid.device_mixes[mix]
    else:
        devices = tuple(grid.devices)
    cost_model = SplitCostModel(
        profile=grid.models[model], devices=devices,
        link=next(iter(grid.links.values())), objective=grid.objective,
    )
    # rate_scale scales the serialization rate; for the surface axis we
    # take 1/rs as the packet-time scale (exact for overhead-free links,
    # a conservative envelope otherwise). None loss entries pass through
    # and resolve to each protocol's base loss, like link_variant.
    pt_scales = tuple(sorted({1.0 / rs for rs in grid.rate_scale}))
    return cost_model, pt_scales or DEFAULT_PT_SCALES, tuple(grid.loss_p)


def build_surface(
    cost_model: SplitCostModel,
    protocols: Mapping[str, LinkProfile],
    n_devices: int,
    pt_scale: Sequence[float] = DEFAULT_PT_SCALES,
    loss_p: Sequence[float | None] | None = DEFAULT_LOSS_GRID,
    solver: str = "batched_beam",
    backend: str = "numpy",
    beam_width: int = 8,
    chunk_candidates: Sequence[int] | None = None,
    energy_budget: float | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
    accuracy_floor: float | None = None,
) -> DegradationSurface:
    """Precompute a :class:`DegradationSurface` with the sweep engine.

    For every protocol, a (packet-time × loss) grid of estimator states
    is mapped onto link profiles (:func:`refit_link`), their
    transmission vectors are stacked against the shared device-local
    cost tensor, and ALL nodes of ALL protocols are solved in one
    batched pass. Each node's winning plan then gets its activation
    chunk tuned and its end-to-end latency priced exactly as the legacy
    per-observe path would — the stored decision at a node IS the
    re-solve decision for that state.

    Args:
      cost_model: device/model side of the problem (its link is ignored;
        ``protocols`` supplies the links). Heterogeneous per-device
        fleets work: device ``k`` is ``cost_model.device(k)``.
      protocols: name → base :class:`LinkProfile` for every candidate
        protocol.
      n_devices: the fleet size to plan for. For several fleet sizes at
        once use :func:`build_surfaces` (one batched solve for all).
      pt_scale: multipliers on each protocol's nominal
        :meth:`~repro.core.latency.LinkProfile.packet_time_s`; the
        refit saturation floor is always added as the axis minimum.
      loss_p: absolute loss values; ``None`` entries resolve to each
        protocol's base loss (``loss_p=None`` → base loss only) — the
        same convention as :meth:`ScenarioGrid.link_variant
        <repro.core.sweep.ScenarioGrid.link_variant>`.
      solver: a :data:`repro.core.sweep.BATCHED_SOLVERS` name.
      backend: solver backend for ``solver="batched_dp"`` (a
        :data:`repro.core.sweep.DP_BACKENDS` key): ``"numpy"`` (default
        — the node-exact ``==`` parity path), ``"jax"``, ``"sharded"``
        (scenario axis over the local JAX device mesh;
        :mod:`repro.core.shard`), or ``"pallas"`` (the fused kernel
        solves straight from the local stack + transmission vectors —
        :mod:`repro.core.pallas_dp`). Non-NumPy backends run float32 by
        default, so node decisions are cost-close rather than
        bit-identical to the re-solve oracle unless JAX x64 is enabled.
      beam_width: Algorithm-1 width when ``solver="batched_beam"``.
      chunk_candidates: explicit activation-chunk candidates for
        :func:`optimize_chunk_size` (None → per-protocol defaults).
      energy_budget: optional per-device Joule cap. Segments whose
        energy (:meth:`SplitCostModel.energy_cost_tensor
        <repro.core.latency.SplitCostModel.energy_cost_tensor>` at each
        node's link) exceeds the budget are masked to +inf before the
        batched solve, so every surface node minimizes latency subject
        to the budget (:func:`repro.core.sweep.apply_energy_budget`).
        The pallas backend falls back to its dense mode when a budget
        is set (the fused kernel prices raw local + TX only).
      variants: optional bottleneck-variant bank. Every node then
        decides (split, variant) jointly — the variant axis folds into
        the node axis (one batched solve still prices everything, fused
        pallas path included) and each node stores the winning bank
        index (``SurfaceLookup.variant``), with chunk tuning and
        latency priced on the winning variant's compressed cuts.
      accuracy_floor: with ``variants``, masks bank entries whose
        ``accuracy_proxy`` is below the floor before the solve
        (:func:`repro.core.sweep.apply_accuracy_floor`) — every node
        then minimizes latency subject to the accuracy constraint.

    Returns the surface for ``n_devices`` (node decisions bit-identical
    to the legacy re-solve at every grid node on the default NumPy
    backend)."""
    return build_surfaces(
        cost_model, protocols, (n_devices,), pt_scale=pt_scale,
        loss_p=loss_p, solver=solver, backend=backend,
        beam_width=beam_width, chunk_candidates=chunk_candidates,
        energy_budget=energy_budget, variants=variants,
        accuracy_floor=accuracy_floor,
    )[n_devices]


def _resolve_axes(
    base: LinkProfile,
    pt_scale: Sequence[float],
    loss_p: Sequence[float | None] | None,
) -> tuple[tuple[float, ...], tuple[float, ...]]:
    """One protocol's resolved (packet-time, loss) axes — the SINGLE
    source of the scale→axis mapping, shared by surface construction
    and the async rebuilder's envelope prediction
    (:meth:`repro.core.async_replan.RebuildRequest.covers` must agree
    with what ``build_surfaces`` will actually build).

    The packet-time axis minimum is the refit saturation floor
    (loss-free serialization + propagation): :func:`refit_link` maps
    every packet time at or below it to the identical link, so
    estimates that run FASTER than the loss-inflated nominal stay on
    the surface (clamped exactly) instead of forcing re-solve
    fallbacks. ``None`` loss entries resolve to the protocol's base
    loss (the :meth:`ScenarioGrid.link_variant
    <repro.core.sweep.ScenarioGrid.link_variant>` convention)."""
    floor = base.mtu_bytes / base.rate_bytes_per_s + base.t_prop_s
    pts = tuple(sorted({base.packet_time_s() * s for s in pt_scale}
                       | {floor}))
    losses = tuple(sorted(
        {base.loss_p} if loss_p is None
        else {base.loss_p if lp is None else lp for lp in loss_p}))
    return pts, losses


def build_surfaces(
    cost_model: SplitCostModel,
    protocols: Mapping[str, LinkProfile],
    n_devices: Sequence[int],
    pt_scale: Sequence[float] = DEFAULT_PT_SCALES,
    loss_p: Sequence[float | None] | None = DEFAULT_LOSS_GRID,
    solver: str = "batched_beam",
    backend: str = "numpy",
    beam_width: int = 8,
    chunk_candidates: Sequence[int] | None = None,
    energy_budget: float | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
    accuracy_floor: float | None = None,
    mesh_spec=None,
) -> dict[int, DegradationSurface]:
    """Kwarg shim over the planner tier for surface-family builds: the
    whole request becomes ONE self-contained
    :class:`repro.core.spec.PlanSpec` (:func:`repro.core.spec.
    surfaces_spec` — cost model, protocol links, and grid axes are all
    spec fields) resolved by :class:`repro.core.spec.PlannerService`,
    so a kwarg build, a spec build, and an out-of-process rebuild
    (:func:`repro.core.spec.build_surfaces_from_spec`) all run the same
    implementation (:func:`_build_surfaces_impl`) and return
    node-identical families. See the impl for the build semantics."""
    from repro.core.spec import PlannerService, surfaces_spec  # lazy

    spec = surfaces_spec(
        cost_model, protocols, n_devices, pt_scale=pt_scale, loss_p=loss_p,
        solver=solver, backend=backend, beam_width=beam_width,
        chunk_candidates=chunk_candidates, energy_budget=energy_budget,
        variants=variants, accuracy_floor=accuracy_floor, mesh=mesh_spec)
    return PlannerService().build_surfaces(spec)


def _build_surfaces_impl(
    cost_model: SplitCostModel,
    protocols: Mapping[str, LinkProfile],
    n_devices: Sequence[int],
    pt_scale: Sequence[float] = DEFAULT_PT_SCALES,
    loss_p: Sequence[float | None] | None = DEFAULT_LOSS_GRID,
    solver: str = "batched_beam",
    backend: str = "numpy",
    beam_width: int = 8,
    chunk_candidates: Sequence[int] | None = None,
    energy_budget: float | None = None,
    variants: Sequence[BottleneckVariant] | None = None,
    accuracy_floor: float | None = None,
    mesh_spec=None,
) -> dict[int, DegradationSurface]:
    """Precompute surfaces for SEVERAL fleet sizes in one batched solve.

    The multi-N entry point behind :func:`build_surface` (which requests
    one size): all (protocol × packet-time × loss) nodes of ALL
    requested fleet sizes are solved in a single batched solver pass —
    the all-k DP table answers every size at once for
    ``solver="batched_dp"``, and for beam/greedy the fleet-size axis
    folds into the scenario axis with a per-scenario ``n_devices``
    vector (see :func:`repro.core.sweep.batched_beam_search_all_k`).
    There is no per-N re-solve loop on any solver path.

    Every returned surface is node-for-node identical to calling
    :func:`build_surface` with that single fleet size (the property
    suite asserts exact ``==``). ``build_time_s``/``solve_time_s`` on
    each surface record the SHARED family build (one pass), not a
    per-size cost. ``backend`` selects the DP backend (``"jax"`` /
    ``"sharded"`` / ``"pallas"`` accepted for ``solver="batched_dp"``
    only — see :func:`build_surface` for the parity caveat; the pallas
    path hands the fused kernel ``local`` + ``TX`` and never ships the
    stacked tensor to the device). Args otherwise as in
    :func:`build_surface`.

    With a ``variants`` bank the node axis grows variant-major —
    ``TX`` stacks one block of node rows per bank entry, every solver
    path (fused pallas included) prices the folded batch untouched, and
    the per-(fleet-size, node) winner is the argmin over the bank
    (:func:`repro.core.sweep._fold_variant_axis`, the same
    lowest-index tie-break as every other joint solve)."""
    if solver not in SW.BATCHED_SOLVERS:
        raise ValueError(f"unknown batched solver {solver!r}; "
                         f"options: {sorted(SW.BATCHED_SOLVERS)}")
    if backend != "numpy" and solver != "batched_dp":
        raise ValueError(f"{solver} supports backend='numpy' only "
                         f"(got {backend!r})")
    sizes = tuple(n_devices)
    if not sizes:
        raise ValueError("n_devices must name at least one fleet size")
    if len(set(sizes)) != len(sizes):
        raise ValueError(f"n_devices has duplicates: {sizes}")
    for n in sizes:
        if n < 1:
            raise ValueError(f"fleet size must be >= 1, got {n}")
    bank = tuple(variants) if variants is not None else None
    if bank is not None and not bank:
        raise ValueError("variants bank must not be empty")
    if accuracy_floor is not None and bank is None:
        raise ValueError("accuracy_floor requires a variants bank")
    n_max = max(sizes)
    t0 = time.perf_counter()
    combine = "max" if cost_model.objective == "bottleneck" else "sum"
    # link-independent device-local tensor at the largest size; smaller
    # fleets are prefixes (device k's matrix does not depend on N).
    # Bottleneck variants never touch it — a variant reprices only the
    # cut, so the bank folds entirely into the TX rows below.
    local = cost_model.local_cost_tensor(n_max)

    # node enumeration: protocol-major, then packet time, then loss
    axes: dict[str, tuple[tuple[float, ...], tuple[float, ...]]] = {}
    links: list[LinkProfile] = []
    for name, base in protocols.items():
        pts, losses = _resolve_axes(base, pt_scale, loss_p)
        axes[name] = (pts, losses)
        for pt in pts:
            for lp in losses:
                links.append(refit_link(base, pt, lp))
    n_nodes_total = len(links)

    # with a variant bank the node axis grows variant-major: one block
    # of TX rows per bank entry (folded index v * n_nodes + node)
    node_models = ([cost_model] if bank is None
                   else [replace(cost_model, variant=v) for v in bank])
    TX = np.stack([
        replace(m, link=lk).transmission_cost_vector()
        for m in node_models
        for lk in links
    ])  # (V * S, L); plain (S, L) without a bank
    if accuracy_floor is not None:
        # mask below-floor variants in the TX rows (not just C): +inf
        # rows make every segment of the variant block infeasible on
        # EVERY solve path, the fused pallas kernel — which consumes TX
        # directly — included. Same strict comparison as
        # :func:`repro.core.sweep.apply_accuracy_floor`.
        acc = np.array([v.accuracy_proxy for v in bank])
        floor_mask = acc < float(accuracy_floor)
        if floor_mask.any():
            TX = np.where(
                np.repeat(floor_mask, n_nodes_total)[:, None], INF, TX)
    C = local[None, :, :, :] + TX[:, None, None, :]
    if energy_budget is not None:
        # per-node energy tensors (each node's own re-fitted link, each
        # variant's own encoder Joules) mask over-budget segments to
        # +inf; the DP then minimizes latency subject to the budget on
        # every backend
        E = np.stack([
            replace(m, link=lk).energy_cost_tensor(n_max)
            for m in node_models
            for lk in links
        ])
        C = SW.apply_energy_budget(C, E, energy_budget)
    kwargs = {"beam_width": beam_width} if solver == "batched_beam" else {}

    # ONE batched pass answers every requested fleet size
    res_by_n: dict[int, SW.BatchedSolverResult]
    if solver == "batched_dp":
        # all-k trick: the DP table at device k IS the k-device answer
        # (on every backend — the jax/sharded/pallas kernels return the
        # whole per-device table stack)
        if backend == "pallas" and energy_budget is None:
            # fused kernel: the solve consumes (local, TX) directly and
            # never ships C to the device (the host-side C above only
            # prices assembled nodes / chunk tuning). Budgeted runs
            # take the dense branch below — the fused kernel prices
            # raw local + TX and cannot see the energy mask.
            from repro.core import pallas_dp as _pallas

            all_k = _pallas.pallas_fused_optimal_dp(
                local, None, TX, combine=combine, return_all_k=True)
        else:
            all_k = SW.batched_optimal_dp(C, combine=combine,
                                          backend=backend,
                                          return_all_k=True,
                                          mesh_spec=mesh_spec)
        res_by_n = {n: all_k[n] for n in sizes}
        solve_time = all_k[n_max].wall_time_s
    elif solver == "batched_beam":
        # all-k beam: fleet sizes as blocks over the shared tensor
        res_by_n = SW.batched_beam_search_all_k(
            C, combine=combine, fleet_sizes=sizes, **kwargs)
        solve_time = res_by_n[n_max].wall_time_s
    else:
        # all-k greedy: same block construction as the beam
        res_by_n = SW.batched_greedy_search_all_k(
            C, combine=combine, fleet_sizes=sizes, **kwargs)
        solve_time = res_by_n[n_max].wall_time_s

    C_by_n: dict[int, np.ndarray] = {}
    if bank is not None and len(bank) > 1:
        # collapse the variant-major fold per fleet size: different
        # fleet sizes may adopt different variants at the same node, so
        # each size gets its own winner rows (and the winning variant's
        # C rows for runner-up portfolio scoring)
        for n in sizes:
            folded, win_rows = SW._fold_variant_axis(
                res_by_n[n], len(bank), n_nodes_total)
            res_by_n[n] = folded
            C_by_n[n] = C[win_rows]

    assembled = {
        n: _assemble_protocol_surfaces(
            cost_model, protocols, axes, links, C_by_n.get(n, C),
            res_by_n[n], n, combine, chunk_candidates, variants=bank)
        for n in sizes
    }
    # shared family wall: every surface reports the one batched build
    wall = time.perf_counter() - t0
    return {
        n: DegradationSurface(
            protocols=assembled[n], n_devices=n, solver=solver,
            build_time_s=wall, solve_time_s=solve_time,
        )
        for n in sizes
    }


def _assemble_protocol_surfaces(
    cost_model: SplitCostModel,
    protocols: Mapping[str, LinkProfile],
    axes: Mapping[str, tuple[tuple[float, ...], tuple[float, ...]]],
    links: Sequence[LinkProfile],
    C: np.ndarray,
    res: "SW.BatchedSolverResult",
    n_devices: int,
    combine: str,
    chunk_candidates: Sequence[int] | None,
    variants: Sequence[BottleneckVariant] | None = None,
) -> dict[str, ProtocolSurface]:
    """Per-node pricing for one fleet size: chunk-tune and price each
    node's winning plan (the legacy adoption arithmetic, so node
    decisions stay bit-identical to a re-solve) and pick its runner-up
    from the protocol's plan portfolio. With a ``variants`` bank the
    node's winning variant model prices everything — chunk tuning sees
    the compressed cut bytes, latency includes the encoder cost, and
    the node records the winning bank index."""
    bank_models = (None if variants is None
                   else [replace(cost_model, variant=v) for v in variants])

    def node_model(vi: int) -> SplitCostModel:
        return cost_model if bank_models is None else bank_models[vi]

    def tuned_latency(lk: LinkProfile, splits: tuple[int, ...],
                      model: SplitCostModel) -> tuple[int, float]:
        """Chunk-tune a plan and price it — the legacy adoption
        arithmetic, on the node's winning variant model (compressed cut
        bytes drive the chunk choice)."""
        cuts = [model.cut_payload_bytes(b) for b in splits]
        chunk, _ = optimize_chunk_size(lk, cuts, chunk_candidates)
        tuned = replace(lk, mtu_bytes=chunk)
        lat = replace(model, link=tuned).end_to_end_s(splits)
        return chunk, lat

    surfaces: dict[str, ProtocolSurface] = {}
    s = 0
    for name, base in protocols.items():
        pts, losses = axes[name]
        T, G = len(pts), len(losses)
        n_nodes = T * G
        node_links = links[s:s + n_nodes]
        node_res_lo = s
        splits = np.full((T, G, max(n_devices - 1, 0)), -1, dtype=np.int64)
        chunks = np.zeros((T, G), dtype=np.int64)
        lats = np.full((T, G), INF)
        run_splits = np.full_like(splits, -1)
        run_lats = np.full((T, G), INF)
        var_grid = (None if variants is None
                    else np.zeros((T, G), dtype=np.int64))

        # plan portfolio: the distinct feasible plans across this
        # protocol's nodes, scored on every node in one batched pass —
        # the per-node runner-up comes from this portfolio
        portfolio: list[tuple[int, ...]] = []
        seen: set[tuple[int, ...]] = set()
        for g in range(n_nodes):
            sp = res.splits_tuple(node_res_lo + g)
            if (sp or n_devices == 1) and bool(res.feasible[node_res_lo + g]):
                if sp not in seen:
                    seen.add(sp)
                    portfolio.append(sp)
        port_cost = None
        if len(portfolio) >= 2 and n_devices > 1:
            cand = np.array(portfolio, dtype=np.int64)  # (M, n-1)
            port_cost = SW.batched_total_cost(
                C[node_res_lo:node_res_lo + n_nodes, :n_devices],
                cand, combine)  # (S_g, M)

        for i in range(T):
            for j in range(G):
                g = i * G + j
                ridx = node_res_lo + g
                if not bool(res.feasible[ridx]):
                    continue
                sp = res.splits_tuple(ridx)
                if not sp and n_devices > 1:
                    continue
                lk = node_links[g]
                vi = 0
                if res.variant is not None:
                    vi = max(int(res.variant[ridx]), 0)
                if var_grid is not None:
                    var_grid[i, j] = vi
                model = node_model(vi)
                chunk, lat = tuned_latency(lk, sp, model)
                splits[i, j] = np.asarray(sp, dtype=np.int64)
                chunks[i, j] = chunk
                lats[i, j] = lat
                if port_cost is not None:
                    # runner-up: cheapest portfolio plan that is not the
                    # winner, chunk-tuned and priced like the winner
                    # (under the node's winning variant model — the
                    # variant is the node's decision, the runner-up
                    # only hedges the split)
                    order = np.argsort(port_cost[g], kind="stable")
                    for m in order:
                        alt = portfolio[int(m)]
                        if alt != sp and np.isfinite(port_cost[g, m]):
                            r_chunk, r_lat = tuned_latency(lk, alt, model)
                            run_splits[i, j] = np.asarray(alt, dtype=np.int64)
                            run_lats[i, j] = r_lat
                            break
        surfaces[name] = ProtocolSurface(
            protocol=name, base=base, packet_time_s=pts, loss_p=losses,
            splits=splits, chunk_bytes=chunks, latency_s=lats,
            runner_splits=run_splits, runner_latency_s=run_lats,
            variant=var_grid,
        )
        s += n_nodes
    return surfaces
