"""Adaptive runtime re-planning — the paper's future-work section, live.

Simulates a deployment where network conditions drift: the
AdaptiveSplitManager watches observed hop latencies, re-splits the model
when the link degrades, and switches protocols only when the degradation
is deep enough to overcome the alternatives' setup costs (Table IV).

Run: PYTHONPATH=src python examples/adaptive_replanning.py
"""

from repro.core.adaptive import AdaptiveSplitManager
from repro.core.profiles import ESP_NOW, PROTOCOLS, paper_cost_model


def main():
    mgr = AdaptiveSplitManager(
        cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
        protocols=dict(PROTOCOLS),
        n_devices=2,
        replan_threshold=0.10,
    )
    d = mgr.current
    print(f"t=0    plan: {d.protocol} chunk={d.chunk_bytes}B splits={d.splits} "
          f"predicted {d.predicted_latency_s:.3f}s ({d.reason})")

    nbytes = 5488  # the paper's block_16_project_BN activation

    def run_phase(label, factor, steps):
        lat = factor * ESP_NOW.transmission_latency_s(nbytes)
        for _ in range(steps):
            mgr.observe("esp_now", nbytes, lat)
        d = mgr.current
        print(f"{label:6s} ESP-NOW at {factor:3.0f}x nominal -> plan: {d.protocol} "
              f"chunk={d.chunk_bytes}B splits={d.splits} "
              f"predicted {d.predicted_latency_s:.3f}s")

    run_phase("t=1", 1, 30)     # healthy: no change
    run_phase("t=2", 50, 60)    # degraded: re-split absorbs it (cheaper cut)
    run_phase("t=3", 400, 120)  # collapsed: protocol switch finally pays

    print("\ndecision log:")
    for d in mgr.history:
        print(f"  step {d.step:4d}: {d.protocol:8s} splits={d.splits} "
              f"chunk={d.chunk_bytes}B predicted={d.predicted_latency_s:.3f}s "
              f"({d.reason})")


if __name__ == "__main__":
    main()
