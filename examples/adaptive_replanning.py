"""Adaptive runtime re-planning — the paper's future-work section, live.

Simulates a deployment where network conditions drift: the
AdaptiveSplitManager watches observed hop latencies, re-splits the model
when the link degrades, and switches protocols only when the degradation
is deep enough to overcome the alternatives' setup costs (Table IV).

The manager's hot loop is a precomputed DegradationSurface: every
(protocol x packet-time x loss) link condition was solved ONCE with the
batched sweep engine at startup, so each observe() is an O(1) grid
lookup + hysteresis check instead of a Beam-Search re-solve — the
surface also reports the *switch points* where the optimal plan changes.

The second act drives the link BEYOND the surface envelope with
async_rebuild on: observe() keeps serving from the stale surface
(stale-while-revalidate) while a re-centered rebuild runs "in the
background" — here on a deterministic ManualExecutor so the in-flight
window is visible — and a later observe() atomically swaps the rebuilt
surface in, restoring the O(1) path at the new operating point.

Run: PYTHONPATH=src python examples/adaptive_replanning.py
"""

import time

from repro.core.adaptive import AdaptiveSplitManager
from repro.core.async_replan import ManualExecutor
from repro.core.profiles import ESP_NOW, PROTOCOLS, paper_cost_model


def main():
    t0 = time.perf_counter()
    mgr = AdaptiveSplitManager(
        cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
        protocols=dict(PROTOCOLS),
        n_devices=2,
        replan_threshold=0.10,
    )
    build_s = time.perf_counter() - t0
    surf = mgr.surface
    print(f"degradation surface: {surf.n_nodes} nodes "
          f"({len(surf.protocols)} protocols), "
          f"{len(surf.switch_points())} switch points, "
          f"built in {build_s * 1e3:.0f} ms (one batched sweep pass)")
    for sp in surf.switch_points()[:5]:
        print(f"  switch[{sp.protocol}] {sp.axis}: {sp.lo:.4g} -> {sp.hi:.4g} "
              f"(other axis @ {sp.fixed:g}): plan {sp.plan_lo} -> {sp.plan_hi}")

    d = mgr.current
    print(f"t=0    plan: {d.protocol} chunk={d.chunk_bytes}B splits={d.splits} "
          f"predicted {d.predicted_latency_s:.3f}s ({d.reason})")

    nbytes = 5488  # the paper's block_16_project_BN activation

    def run_phase(label, factor, steps):
        lat = factor * ESP_NOW.transmission_latency_s(nbytes)
        t0 = time.perf_counter()
        for _ in range(steps):
            mgr.observe("esp_now", nbytes, lat)
        us = (time.perf_counter() - t0) / steps * 1e6
        d = mgr.current
        print(f"{label:6s} ESP-NOW at {factor:3.0f}x nominal -> plan: {d.protocol} "
              f"chunk={d.chunk_bytes}B splits={d.splits} "
              f"predicted {d.predicted_latency_s:.3f}s "
              f"[{us:.0f} us/observe]")

    run_phase("t=1", 1, 30)     # healthy: no change
    run_phase("t=2", 50, 60)    # degraded: surface absorbs it in-protocol
    run_phase("t=3", 400, 120)  # collapsed: protocol switch finally pays

    print(f"\nsurface hits: {mgr.surface_hits}  "
          f"exact envelope fallbacks: {mgr.exact_fallbacks}")
    print("decision log:")
    for d in mgr.history:
        print(f"  step {d.step:4d}: {d.protocol:8s} splits={d.splits} "
              f"chunk={d.chunk_bytes}B predicted={d.predicted_latency_s:.3f}s "
              f"({d.reason})")

    # -- act two: drift past the envelope, rebuild without blocking --------
    print("\n--- async stale-while-revalidate (drift beyond the envelope) ---")
    ex = ManualExecutor()
    amgr = AdaptiveSplitManager(
        cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
        protocols=dict(PROTOCOLS), n_devices=2,
        surface_grid={"pt_scale": (1.0, 4.0, 16.0), "loss_p": (0.0, 0.1)},
        async_rebuild=ex,  # deterministic executor: WE run the build
    )
    deep = 3000 * ESP_NOW.transmission_latency_s(nbytes)  # 3000x nominal
    for _ in range(120):
        amgr.observe("esp_now", nbytes, deep)
    print(f"in-flight: {amgr.stale_serves} observes served from the STALE "
          f"surface, {amgr.exact_fallbacks} bounded exact fallbacks, "
          f"{ex.pending()} rebuild queued (envelope max was 16x nominal)")
    while ex.pending():  # "background" build completes; next observe swaps
        ex.run_all()
        amgr.observe("esp_now", nbytes, deep)
    h0 = amgr.surface_hits
    for _ in range(30):
        amgr.observe("esp_now", nbytes, deep)
    d = amgr.current
    print(f"adopted {amgr.surface_swaps} rebuilt surface(s) "
          f"(generation {amgr._rebuilder.generation}); O(1) lookups are "
          f"back: {amgr.surface_hits - h0}/30 hits at the new operating "
          f"point -> plan {d.protocol} splits={d.splits}")


if __name__ == "__main__":
    main()
