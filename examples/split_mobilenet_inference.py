"""Split MobileNet-V2 inference across simulated IoT devices — the
paper's full experiment, end to end:

  * every protocol (UDP / TCP / ESP-NOW / BLE),
  * every solver (beam / greedy / first-fit / random / DP optimum),
  * real split execution with int8 wire quantization,
  * RTT decomposition matching Table IV.

Run: PYTHONPATH=src python examples/split_mobilenet_inference.py
"""

import jax
import jax.numpy as jnp

from repro.core.executor import run_split, run_unsplit
from repro.core.latency import rtt_breakdown
from repro.core.planner import compare_solvers, plan_split
from repro.core.profiles import PROTOCOLS, paper_cost_model
from repro.models.mobilenetv2 import MobileNetV2

N_DEVICES = 4


def main():
    print(f"=== planning splits for {N_DEVICES} devices, all protocols ===")
    best = {}
    for proto in PROTOCOLS:
        m = paper_cost_model("mobilenet_v2", proto)
        plan = plan_split(m, N_DEVICES, solver="beam")
        best[proto] = plan
        br = rtt_breakdown(m, plan.splits)
        print(f"{proto:8s} splits={plan.splits} RTT={br.rtt_s:.3f}s "
              f"(setup {br.setup_s * 1e3:.0f}ms, tx {sum(br.transmission_s) * 1e3:.1f}ms)")
    winner = min(best, key=lambda p: best[p].total_latency_s)
    print(f"-> best protocol: {winner} (paper: esp_now)\n")

    print("=== solver comparison on the winner ===")
    m = paper_cost_model("mobilenet_v2", winner)
    plans = compare_solvers(m, N_DEVICES,
                            solvers=("beam", "greedy", "first_fit",
                                     "random_fit", "optimal_dp"))
    for name, plan in plans.items():
        print(f"{name:10s} latency {plan.total_latency_s:.3f}s "
              f"planner {plan.planner_time_s * 1e3:.1f}ms splits={plan.splits}")

    print("\n=== executing the beam split with int8 wire ===")
    model = MobileNetV2(width=0.35, image_size=96)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(7), model.input_shape(4))
    ref = run_unsplit(model, params, x)
    out, trace = run_split(model, params, x, plans["beam"].splits,
                           link=PROTOCOLS[winner], quantize_wire=True)
    top1 = jnp.mean((jnp.argmax(out["h"], -1) == jnp.argmax(ref["h"], -1))
                    .astype(jnp.float32))
    print(f"top-1 agreement across batch: {float(top1) * 100:.0f}%")
    print(f"hops: {[(h.boundary_layer, h.n_packets) for h in trace.hops]}")
    print(f"modeled tx latency: {trace.total_tx_latency_s * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
