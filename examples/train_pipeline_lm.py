"""End-to-end training driver: a ~100M-param LM trained for a few hundred
steps with the fault-tolerant runtime, on a learnable synthetic stream.

Demonstrates the full substrate: config -> data pipeline -> microbatched
train step -> checkpointing (with one simulated crash + exact resume) ->
beam-search pipeline planning for the same model on a TPU cost profile.

Run: PYTHONPATH=src python examples/train_pipeline_lm.py [--steps 300]
"""

import argparse
import tempfile

import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.core.planner import plan_pipeline
from repro.core.profiles import DCN, ICI
from repro.data.pipeline import MarkovLMData
from repro.models.config import ModelConfig
from repro.models.graph import arch_layer_graph
from repro.runtime.train_loop import Trainer, TrainLoopConfig

# ~100M params: 12L x d512 (embeddings dominate at vocab 8192)
CFG = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=8, d_ff=2048, vocab=8192, head_dim=64, dtype="float32",
    remat=False, kv_chunk=128, q_chunk=128, pad_vocab_to=0,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=CFG.vocab,
                    help="shrink for quick CPU demos (learning needs "
                         "tokens ~ vocab x branch x 10)")
    args = ap.parse_args()

    import dataclasses

    cfg = dataclasses.replace(CFG, vocab=args.vocab)
    print(f"model: {cfg.name} ~{cfg.n_params / 1e6:.0f}M params (vocab {cfg.vocab})")
    data = MarkovLMData(cfg, global_batch=args.batch, seq_len=args.seq, branch=4)

    from repro.optim import AdamWConfig

    opt_cfg = AdamWConfig(lr=1e-3)
    with tempfile.TemporaryDirectory() as tmp:
        store = CheckpointStore(tmp, keep=2)
        loop = TrainLoopConfig(total_steps=args.steps,
                               ckpt_every=max(5, args.steps // 6), log_every=25)

        # phase 1: train, then simulate a node failure at 60% progress
        crash_at = int(args.steps * 0.6)

        class Crash(RuntimeError):
            pass

        def failure(step):
            if step == crash_at:
                print(f"!! injected node failure at step {step}")
                raise Crash()

        t = Trainer(cfg, data, store, loop, opt_cfg=opt_cfg, failure_hook=failure)
        try:
            t.run()
        except Crash:
            pass
        print(f"restarting from checkpoint step {store.latest_step()}")

        # phase 2: resume to completion — the loop restores and continues
        t2 = Trainer(cfg, data, store, loop, opt_cfg=opt_cfg)
        hist = t2.run()
        losses = [r.loss for r in t2.history]
        print(f"resumed at step {hist[0].step}; finished {hist[-1].step + 1} steps")
        first = np.mean(losses[:10])
        last = np.mean(losses[-10:])
        print(f"loss: {first:.3f} -> {last:.3f} "
              f"({'LEARNING' if last < first - 0.05 else 'no progress?!'})")
        stragglers = [r.step for r in hist if r.straggler]
        if stragglers:
            print(f"straggler steps flagged: {stragglers[:5]}...")

    # phase 3: how would the paper's planner pipeline THIS model on TPU?
    g = arch_layer_graph(cfg, batch=256, seq=4096)
    for link in (ICI, DCN):
        plan = plan_pipeline(g, n_stages=4, chips_per_stage=4, link=link)
        print(f"beam PP plan over {link.name}: splits={plan.splits} "
              f"bottleneck={plan.objective_cost_s * 1e3:.2f} ms/stage")


if __name__ == "__main__":
    main()
