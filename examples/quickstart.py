"""Quickstart: plan a split, run it, account the wire — in 60 lines.

Reproduces the paper's core loop end to end on CPU:
  1. build the MobileNet-V2 cost profile calibrated to the paper's
     ESP32-S3 measurements,
  2. beam-search the optimal split for 3 devices over ESP-NOW,
  3. actually execute the split model and verify it equals the unsplit
     forward pass,
  4. price every hop with the Eq. 7 packetized-link model.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.executor import run_split, run_unsplit
from repro.core.planner import plan_split
from repro.core.profiles import ESP_NOW, paper_cost_model
from repro.models.mobilenetv2 import MobileNetV2


def main():
    # 1. the paper's experimental configuration as a cost model
    cost_model = paper_cost_model("mobilenet_v2", protocol="esp_now")

    # 2. beam-search split points for 3 devices (Algorithm 1)
    plan = plan_split(cost_model, n_devices=3, solver="beam", beam_width=8)
    print(f"split points: {plan.splits}")
    for seg in plan.segments:
        print(f"  device {seg.device}: layers {seg.first_layer}..{seg.last_layer} "
              f"({seg.layer_names[0]} .. {seg.layer_names[-1]}), "
              f"infer {seg.infer_s * 1e3:.0f} ms, ships {seg.tx_bytes} B")
    print(f"predicted end-to-end latency: {plan.total_latency_s:.3f} s "
          f"(planner took {plan.planner_time_s * 1e3:.1f} ms)")

    # 3. execute the split for real (small input for CPU speed)
    model = MobileNetV2(width=0.35, image_size=96)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), model.input_shape(1))
    ref = run_unsplit(model, params, x)
    out, trace = run_split(model, params, x, plan.splits, link=ESP_NOW,
                           quantize_wire=True)
    agree = jnp.argmax(out["h"]) == jnp.argmax(ref["h"])
    print(f"split executes correctly: top-1 agreement = {bool(agree)}")

    # 4. wire accounting per hop
    for hop in trace.hops:
        print(f"  hop after {hop.boundary_layer}: {hop.nbytes} B -> "
              f"{hop.n_packets} packets -> {hop.sim_latency_s * 1e3:.1f} ms on air")
    print(f"total modeled transmission: {trace.total_tx_latency_s * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
