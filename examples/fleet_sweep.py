"""Fleet sweep: price every protocol / fleet-size / link-condition
what-if in one vectorized pass, then read off operating policy.

The paper plans one configuration at a time. A fleet controller needs
the whole decision surface — "which protocol and split should a fleet
of N devices use if the link degrades to X?" — refreshed continuously.
This example sweeps a 256-point grid (4 protocols × 4 fleet sizes ×
4 loss rates × 4 bandwidth scales) for MobileNet-V2 on ESP32-S3 in a
few milliseconds and prints:

  1. the best protocol + split per fleet size under nominal conditions,
  2. how the best plan shifts as the link degrades (the re-planning
     surface the AdaptiveSplitManager walks at runtime),
  3. how heterogeneous device mixes (a fast gateway tail, degraded
     nodes) move the optimal split — priced in the SAME batched pass,
  4. engine throughput vs the scalar per-scenario loop.

Run: PYTHONPATH=src python examples/fleet_sweep.py
"""

from __future__ import annotations

import time
from dataclasses import replace

from repro.core.profiles import ESP32, PROTOCOLS, mobilenet_cost_profile
from repro.core.sweep import ScenarioGrid, sweep


def main():
    grid = ScenarioGrid(
        models={"mobilenet_v2": mobilenet_cost_profile()},
        links=dict(PROTOCOLS),
        n_devices=(2, 3, 4, 5),
        loss_p=(None, 0.01, 0.05, 0.10),
        rate_scale=(1.0, 0.5, 0.25, 0.125),
        devices=(ESP32,),
        # heterogeneous what-ifs ride the same batched pass: a fleet
        # whose tail node is a 4x-faster gateway, and one downgraded
        # to half-speed ESP32s (mix=None keeps the homogeneous fleet)
        device_mixes={
            "gateway_tail": (ESP32, ESP32, ESP32, ESP32,
                             replace(ESP32, name="gateway",
                                     compute_scale=0.25,
                                     mem_limit_bytes=None)),
            "slow_nodes": (replace(ESP32, name="esp32_half",
                                   compute_scale=2.0),),
        },
    )
    t0 = time.perf_counter()
    result = sweep(grid, solver="batched_dp")
    wall = time.perf_counter() - t0
    print(f"swept {result.n_scenarios} scenarios in {wall * 1e3:.1f} ms "
          f"({result.scenarios_per_sec:,.0f} scenarios/s)")

    print("\n-- best protocol per fleet size (nominal link, homogeneous) --")
    for n in grid.n_devices:
        rows = [r for r in result.rows
                if r.feasible and r.scenario.n_devices == n
                and r.scenario.mix is None
                and r.scenario.loss_p is None and r.scenario.rate_scale == 1.0]
        if not rows:
            print(f"  N={n}: no feasible plan")
            continue
        best = min(rows, key=lambda r: r.total_latency_s)
        print(f"  N={n}: {best.scenario.protocol:8s} splits={best.splits} "
              f"latency {best.total_latency_s:.3f}s "
              f"(device {best.device_s:.3f}s + tx {best.transmission_s:.3f}s)")

    print("\n-- degradation surface (N=3): best plan vs link condition --")
    print(f"  {'rate×':>6s} {'loss':>5s}  protocol  splits -> latency")
    for rs in grid.rate_scale:
        for lp in grid.loss_p:
            rows = [r for r in result.rows
                    if r.feasible and r.scenario.n_devices == 3
                    and r.scenario.mix is None
                    and r.scenario.loss_p == lp and r.scenario.rate_scale == rs]
            if not rows:
                continue
            best = min(rows, key=lambda r: r.total_latency_s)
            loss = "base" if lp is None else f"{lp:.2f}"
            print(f"  {rs:>6g} {loss:>5s}  {best.scenario.protocol:8s} "
                  f"{str(best.splits):14s} -> {best.total_latency_s:.3f}s")

    # protocol switch points: where does the argmin protocol change?
    switches = set()
    for rs in grid.rate_scale:
        prev = None
        for lp in (p for p in grid.loss_p):
            rows = [r for r in result.rows
                    if r.feasible and r.scenario.n_devices == 3
                    and r.scenario.mix is None
                    and r.scenario.loss_p == lp and r.scenario.rate_scale == rs]
            if not rows:
                continue
            proto = min(rows, key=lambda r: r.total_latency_s).scenario.protocol
            if prev is not None and proto != prev:
                switches.add((rs, lp, prev, proto))
            prev = proto
    if switches:
        print("\nprotocol switch points (rate×, loss): " + ", ".join(
            f"{rs}x/{lp}: {a}->{b}" for rs, lp, a, b in sorted(
                switches, key=str)))
    else:
        print("\nno protocol switches across this grid "
              "(one protocol dominates everywhere)")

    print("\n-- heterogeneous fleets (N=5, nominal link) --")
    for mx in grid.mix_names:
        rows = [r for r in result.rows
                if r.feasible and r.scenario.n_devices == 5
                and r.scenario.mix == mx
                and r.scenario.loss_p is None and r.scenario.rate_scale == 1.0]
        if not rows:
            print(f"  {mx or 'homogeneous'}: no feasible plan")
            continue
        best = min(rows, key=lambda r: r.total_latency_s)
        print(f"  {mx or 'homogeneous':13s} {best.scenario.protocol:8s} "
              f"splits={best.splits} latency {best.total_latency_s:.3f}s")


if __name__ == "__main__":
    main()
