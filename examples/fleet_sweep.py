"""Fleet sweep: price every protocol / fleet-size / link-condition
what-if in one vectorized pass, then read off operating policy.

The paper plans one configuration at a time. A fleet controller needs
the whole decision surface — "which protocol and split should a fleet
of N devices use if the link degrades to X?" — refreshed continuously.
This example sweeps a 256-point grid (4 protocols × 4 fleet sizes ×
4 loss rates × 4 bandwidth scales) for MobileNet-V2 on ESP32-S3 in a
few milliseconds and prints:

  1. the best protocol + split per fleet size under nominal conditions,
  2. how the best plan shifts as the link degrades (the re-planning
     surface the AdaptiveSplitManager walks at runtime),
  3. how heterogeneous device mixes (a fast gateway tail, degraded
     nodes) move the optimal split — priced in the SAME batched pass,
  4. engine throughput vs the scalar per-scenario loop,
  5. shared-channel contention + per-device energy budgets: a second
     grid with `contention_groups=` / `energy_budgets=` axes shows how
     concurrent transmitters and Joule caps move the optimal plan.

Run: PYTHONPATH=src python examples/fleet_sweep.py
"""

from __future__ import annotations

import time
from dataclasses import replace

from repro.core.profiles import ESP32, PROTOCOLS, mobilenet_cost_profile
from repro.core.sweep import ScenarioGrid, sweep


def main():
    grid = ScenarioGrid(
        models={"mobilenet_v2": mobilenet_cost_profile()},
        links=dict(PROTOCOLS),
        n_devices=(2, 3, 4, 5),
        loss_p=(None, 0.01, 0.05, 0.10),
        rate_scale=(1.0, 0.5, 0.25, 0.125),
        devices=(ESP32,),
        # heterogeneous what-ifs ride the same batched pass: a fleet
        # whose tail node is a 4x-faster gateway, and one downgraded
        # to half-speed ESP32s (mix=None keeps the homogeneous fleet)
        device_mixes={
            "gateway_tail": (ESP32, ESP32, ESP32, ESP32,
                             replace(ESP32, name="gateway",
                                     compute_scale=0.25,
                                     mem_limit_bytes=None)),
            "slow_nodes": (replace(ESP32, name="esp32_half",
                                   compute_scale=2.0),),
        },
    )
    t0 = time.perf_counter()
    result = sweep(grid, solver="batched_dp")
    wall = time.perf_counter() - t0
    print(f"swept {result.n_scenarios} scenarios in {wall * 1e3:.1f} ms "
          f"({result.scenarios_per_sec:,.0f} scenarios/s)")

    print("\n-- best protocol per fleet size (nominal link, homogeneous) --")
    for n in grid.n_devices:
        rows = [r for r in result.rows
                if r.feasible and r.scenario.n_devices == n
                and r.scenario.mix is None
                and r.scenario.loss_p is None and r.scenario.rate_scale == 1.0]
        if not rows:
            print(f"  N={n}: no feasible plan")
            continue
        best = min(rows, key=lambda r: r.total_latency_s)
        print(f"  N={n}: {best.scenario.protocol:8s} splits={best.splits} "
              f"latency {best.total_latency_s:.3f}s "
              f"(device {best.device_s:.3f}s + tx {best.transmission_s:.3f}s)")

    print("\n-- degradation surface (N=3): best plan vs link condition --")
    print(f"  {'rate×':>6s} {'loss':>5s}  protocol  splits -> latency")
    for rs in grid.rate_scale:
        for lp in grid.loss_p:
            rows = [r for r in result.rows
                    if r.feasible and r.scenario.n_devices == 3
                    and r.scenario.mix is None
                    and r.scenario.loss_p == lp and r.scenario.rate_scale == rs]
            if not rows:
                continue
            best = min(rows, key=lambda r: r.total_latency_s)
            loss = "base" if lp is None else f"{lp:.2f}"
            print(f"  {rs:>6g} {loss:>5s}  {best.scenario.protocol:8s} "
                  f"{str(best.splits):14s} -> {best.total_latency_s:.3f}s")

    # protocol switch points: where does the argmin protocol change?
    switches = set()
    for rs in grid.rate_scale:
        prev = None
        for lp in (p for p in grid.loss_p):
            rows = [r for r in result.rows
                    if r.feasible and r.scenario.n_devices == 3
                    and r.scenario.mix is None
                    and r.scenario.loss_p == lp and r.scenario.rate_scale == rs]
            if not rows:
                continue
            proto = min(rows, key=lambda r: r.total_latency_s).scenario.protocol
            if prev is not None and proto != prev:
                switches.add((rs, lp, prev, proto))
            prev = proto
    if switches:
        print("\nprotocol switch points (rate×, loss): " + ", ".join(
            f"{rs}x/{lp}: {a}->{b}" for rs, lp, a, b in sorted(
                switches, key=str)))
    else:
        print("\nno protocol switches across this grid "
              "(one protocol dominates everywhere)")

    print("\n-- heterogeneous fleets (N=5, nominal link) --")
    for mx in grid.mix_names:
        rows = [r for r in result.rows
                if r.feasible and r.scenario.n_devices == 5
                and r.scenario.mix == mx
                and r.scenario.loss_p is None and r.scenario.rate_scale == 1.0]
        if not rows:
            print(f"  {mx or 'homogeneous'}: no feasible plan")
            continue
        best = min(rows, key=lambda r: r.total_latency_s)
        print(f"  {mx or 'homogeneous':13s} {best.scenario.protocol:8s} "
              f"splits={best.splits} latency {best.total_latency_s:.3f}s")

    contention_and_budget()


def contention_and_budget():
    """Multi-channel what-ifs: shared-channel contention scales the
    effective link rate, per-device Joule budgets mask over-budget
    segments before the solve — both just extra grid axes priced in
    the same batched pass."""
    import numpy as np

    # energy is opt-in: give the radio and the MCU non-zero powers
    dev = replace(ESP32, active_power_w=0.5)
    links = {name: replace(lk, tx_power_w=0.24, rx_power_w=0.12)
             for name, lk in PROTOCOLS.items()}
    # pick a Joule cap that actually binds: the 60th percentile of the
    # per-segment energy tensor under the nominal protocol
    probe = ScenarioGrid(models={"mobilenet_v2": mobilenet_cost_profile()},
                         links={"esp_now": links["esp_now"]},
                         n_devices=(3,), devices=(dev,))
    E = probe.cost_model(next(iter(probe.scenarios()))).energy_cost_tensor(3)
    cap = float(np.percentile(E[np.isfinite(E)], 60.0))

    grid = ScenarioGrid(
        models={"mobilenet_v2": mobilenet_cost_profile()},
        links=links,
        n_devices=(3,),
        devices=(dev,),
        contention_groups=(1, 2, 4),   # concurrent transmitters sharing
        mac_efficiency=0.9,            # ...the channel at 90% MAC efficiency
        energy_budgets=(None, cap),    # uncapped vs binding Joule budget
    )
    result = sweep(grid, solver="batched_dp")

    print(f"\n-- contention × energy budget (N=3, {grid.size} scenarios, "
          f"cap {cap:.2f} J/device) --")
    print(f"  {'tx':>3s} {'budget':>7s}  protocol  splits -> latency"
          f"   (energy/device)")
    for cg in grid.contention_groups:
        for eb in grid.energy_budgets:
            rows = [r for r in result.rows
                    if r.feasible and r.scenario.contention == cg
                    and r.scenario.energy_budget == eb]
            if not rows:
                print(f"  {cg:>3d} {'cap' if eb else 'none':>7s}  infeasible")
                continue
            best = min(rows, key=lambda r: r.total_latency_s)
            m = grid.cost_model(best.scenario)
            efn = m.energy_segment_fn()
            L = m.profile.num_layers
            bounds = (0,) + tuple(best.splits) + (L,)
            e_max = max(efn(bounds[k] + 1, bounds[k + 1], k + 1)
                        for k in range(3))
            print(f"  {cg:>3d} {'cap' if eb else 'none':>7s}  "
                  f"{best.scenario.protocol:8s} {str(best.splits):10s} "
                  f"-> {best.total_latency_s:.3f}s   (max {e_max:.2f} J)")


if __name__ == "__main__":
    main()
