"""Latency-vs-accuracy Pareto frontiers over the bottleneck-compression
axis, per protocol, for both paper models.

The paper plans "where to split"; bottleneck compression (a learned
encoder at the cut — the COMSPLIT axis) adds "how hard to squeeze the
cut": each compression factor shrinks the radio payload, costs the
sensor extra encoder compute, and gives up a slice of accuracy. The
planner's decision variable becomes (split point, variant), and the
interesting output is no longer one number but a FRONTIER — the
non-dominated latency/accuracy trade-offs an operator can pick from.

This example sweeps MobileNet-V2 and ResNet50 across every protocol
with `ScenarioGrid(compression_factors=...)` (the variant axis folds
into the same batched pass as everything else), emits the per
model × protocol frontiers with `SweepResult.pareto()`, and prints:

  1. each frontier — latency, accuracy proxy, compression, splits —
     with the dominated rows it filtered out,
  2. where compression actually pays: the latency saved at each
     accuracy step-down vs the full-accuracy identity plan,
  3. accuracy-constrained planning: the cheapest plan subject to
     `accuracy_proxy >= floor`, read straight off the frontier,
  4. the same floor answered by the solver itself
     (`plan_split(variants=..., accuracy_floor=...)`) — the two agree.

Run: PYTHONPATH=src python examples/pareto_frontier.py
"""

from __future__ import annotations

import time

from repro.core.planner import plan_split
from repro.core.profiles import (
    ESP32,
    PAPER_COMPRESSION_FACTORS,
    PROTOCOLS,
    esp32_flops_per_s,
    esp32_variant_bank,
    mobilenet_cost_profile,
    paper_cost_model,
    resnet50_cost_profile,
)
from repro.core.sweep import ScenarioGrid, sweep

N_DEVICES = 3  # mobilenet fits 3 ESP32s; resnet50 needs the N=5 rows
ACCURACY_FLOOR = 0.95


def main():
    grid = ScenarioGrid(
        models={"mobilenet_v2": mobilenet_cost_profile(),
                "resnet50": resnet50_cost_profile()},
        links=dict(PROTOCOLS),
        n_devices=(N_DEVICES, 5),
        devices=(ESP32,),
        compression_factors=PAPER_COMPRESSION_FACTORS,
        # price the encoder like esp32_variant_bank does (16 flops per
        # raw activation byte at the calibrated ESP32 rate), so the
        # sweep and the scalar plan_split(variants=...) check below see
        # the same bank
        variant_encoder_s_per_byte=16.0 / esp32_flops_per_s(),
    )
    t0 = time.perf_counter()
    result = sweep(grid, solver="batched_dp")
    fronts = result.pareto()
    wall = time.perf_counter() - t0
    print(f"swept {result.n_scenarios} (model, protocol, variant) "
          f"scenarios and extracted {len(fronts)} frontiers "
          f"in {wall * 1e3:.1f} ms")

    for (model, proto, n), front in sorted(fronts.items()):
        group = [r for r in result.rows if r.feasible
                 and r.scenario.model == model
                 and r.scenario.protocol == proto
                 and r.scenario.n_devices == n]
        if not group:
            continue  # e.g. resnet50 does not fit N=3 ESP32 memories
        print(f"\n-- {model} / {proto} (N={n}): "
              f"{front.n_points} of {len(group)} variants on the frontier --")
        print(f"   {'cx':>4s} {'accuracy':>8s} {'latency':>9s}  splits")
        on_front = set(map(id, front.rows))
        for row in sorted(group, key=lambda r: r.total_latency_s):
            mark = "*" if id(row) in on_front else " "
            print(f" {mark} {row.scenario.compression:>4g} "
                  f"{row.accuracy_proxy:>8.3f} "
                  f"{row.total_latency_s:>8.3f}s  {row.splits}")

        # what each accuracy step-down buys vs the identity plan
        ident = next((r for r in front.rows
                      if r.scenario.compression == 1.0), None)
        if ident is not None:
            for row in front.rows:
                if row is ident:
                    continue
                saved = ident.total_latency_s - row.total_latency_s
                print(f"   cx{row.scenario.compression:g} saves "
                      f"{saved:.3f}s ({saved / ident.total_latency_s:.0%}) "
                      f"for {ident.accuracy_proxy - row.accuracy_proxy:.3f} "
                      f"accuracy")

    # accuracy-constrained planning: frontier read vs solver answer
    print(f"\n-- cheapest plan s.t. accuracy >= {ACCURACY_FLOOR} "
          f"(mobilenet_v2, N={N_DEVICES}) --")
    bank = esp32_variant_bank()
    for proto in sorted(PROTOCOLS):
        front = fronts[("mobilenet_v2", proto, N_DEVICES)]
        ok = [r for r in front.rows if r.accuracy_proxy >= ACCURACY_FLOOR]
        if not ok:
            print(f"  {proto:8s} no plan meets the floor")
            continue
        pick = min(ok, key=lambda r: r.total_latency_s)

        plan = plan_split(paper_cost_model("mobilenet_v2", proto),
                          N_DEVICES, solver="optimal_dp",
                          variants=bank, accuracy_floor=ACCURACY_FLOOR)
        assert plan.splits == pick.splits, (proto, plan.splits, pick.splits)
        assert abs(plan.total_latency_s - pick.total_latency_s) < 1e-9
        print(f"  {proto:8s} cx{pick.scenario.compression:<4g} "
              f"splits={pick.splits} latency {pick.total_latency_s:.3f}s "
              f"accuracy {pick.accuracy_proxy:.3f} "
              f"(solver agrees: variant={plan.variant})")


if __name__ == "__main__":
    main()
