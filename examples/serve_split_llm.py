"""Batched LM serving with split-aware latency accounting.

A small decoder-only LM served through the slot-based continuous-batching
runtime; the paper's planner chooses where to split the model across two
'devices' and the per-token hop cost is accounted with the Eq. 7 link
model — the LLM-serving analogue of the paper's camera-to-classifier
pipeline.

Run: PYTHONPATH=src python examples/serve_split_llm.py
"""

import time

import jax
import numpy as np

from repro.core.planner import plan_pipeline
from repro.core.profiles import ESP_NOW, ICI
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.graph import arch_layer_graph
from repro.runtime.server import Request, Server, SplitLatencyMeter

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab=512, head_dim=32, dtype="float32",
    remat=False, kv_chunk=64, pad_vocab_to=0,
)


def main():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    print(f"serving {CFG.name} ({CFG.n_params / 1e6:.1f}M params)")

    # plan the 2-way split of this model (block granularity, ICI link)
    g = arch_layer_graph(CFG, batch=4, seq=256)
    plan = plan_pipeline(g, n_stages=2, chips_per_stage=1, link=ICI)
    print(f"planner split: {plan.splits} "
          f"(bottleneck {plan.objective_cost_s * 1e6:.1f} us/stage)")

    # price per-token hops like the paper (one d_model row per decode step)
    meter = SplitLatencyMeter(plan=plan, link=ESP_NOW,
                              bytes_per_token=CFG.d_model * 2)
    server = Server(CFG, params, slots=4, max_seq=128, meter=meter)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(8):
        prompt = rng.integers(0, CFG.vocab, size=rng.integers(4, 12))
        server.submit(Request(rid=rid, prompt=prompt.astype(np.int32),
                              max_new_tokens=12))
    results = server.run_until_drained()
    wall = time.perf_counter() - t0

    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total_tokens} tokens "
          f"in {wall:.2f}s ({total_tokens / wall:.1f} tok/s on CPU)")
    for rid in sorted(results)[:3]:
        print(f"  req {rid}: {results[rid][:8]}...")
    print(f"modeled split-hop overhead: {meter.hops} hops, "
          f"{meter.hop_seconds:.3f} s total "
          f"({meter.hop_seconds / max(1, total_tokens) * 1e3:.2f} ms/token on ESP-NOW)")


if __name__ == "__main__":
    main()
