"""Multi-channel cost-contract property suite (contention + energy).

Three families of properties pin the PR-8 contract:

* **Degenerate bit-exactness** — ``solve_multi_channel`` with one
  channel, no budget, and no weights must return bit-identical (``==``
  on splits AND costs) results to ``solve_batched`` on the raw latency
  tensor, for every batched solver, both combine modes, per-scenario
  fleet-size vectors, and every DP backend (numpy / jax / sharded /
  pallas).
* **Budget zero-regret** — the budget-constrained batched solve must
  match the brute-force scalar oracle (enumerate all splits, drop any
  with an over-budget segment, take the latency min) on every random
  draw up to L=8, N=4: same feasibility, same cost, and a chosen plan
  whose every segment is within budget.
* **Metamorphic invariance** — scaling all energy costs and the budget
  by the same power-of-two factor leaves the chosen plan unchanged
  (power-of-two so the strict ``E > budget`` comparison is float-exact
  under scaling).

Plus contention regressions: a 2-transmitter shared channel never
prices cheaper than the same link uncontended, and a contention group
of size 1 is bit-identical to the uncontended path (the default-path
refactor guard).

Strategy arguments are keyword-bound in every ``@given`` (the vendored
minihypothesis shim binds positional strategies to the RIGHTMOST
parameters; keyword binding is explicit and reorder-proof).
"""

import math
from dataclasses import replace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import solvers as S
from repro.core import sweep as SW
from repro.core.latency import COST_CHANNELS, ContentionModel
from repro.core.profiles import ESP32, PROTOCOLS, paper_cost_model

INF = float("inf")


def tensor_cost_fn(T, L):
    """Scalar cost fn reading dense ``T[k-1, a-1, b-1]`` (the oracle's
    view of the exact same numbers the batched solver sees)."""

    def fn(a, b, k):
        if not (1 <= a <= b <= L) or k < 1 or k > T.shape[0]:
            return INF
        return float(T[k - 1, a - 1, b - 1])

    return fn


def energized_model(tx_power_w=0.24, rx_power_w=0.12, active_power_w=0.5):
    """The paper model with non-zero powers so the energy channel is
    live (defaults are 0.0 — energy is opt-in)."""
    m = paper_cost_model("mobilenet_v2", "esp_now")
    return replace(
        m,
        link=replace(m.link, tx_power_w=tx_power_w, rx_power_w=rx_power_w),
        devices=tuple(replace(d, active_power_w=active_power_w)
                      for d in m.devices),
    )


@st.composite
def channel_tensors(draw, max_L=8, max_N=4, max_scenarios=4):
    """Random (2, S, N, L, L) latency+energy stacks with sprinkled
    infeasibility on the latency channel (mirroring mem-limit masking)
    and strictly positive energies."""
    L = draw(st.integers(3, max_L))
    N = draw(st.integers(1, min(max_N, L)))
    Sn = draw(st.integers(1, max_scenarios))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    lat = rng.uniform(0.01, 100.0, size=(Sn, N, L, L))
    en = rng.uniform(0.001, 10.0, size=(Sn, N, L, L))
    lat[:, :, np.tril_indices(L, -1)[0], np.tril_indices(L, -1)[1]] = INF
    # sprinkle infeasibility on ~10% of the upper triangle
    mask = rng.rand(Sn, N, L, L) < 0.1
    lat = np.where(mask, INF, lat)
    return np.stack([lat, en]), L, N, Sn, seed


class TestDegenerateBitExactness:
    """solve_multi_channel's 1-channel path must be the identity."""

    @given(data=st.data())
    @settings(max_examples=25)
    def test_numpy_all_solvers_all_combines(self, data):
        C, L, N, Sn, seed = data.draw(channel_tensors())
        rng = np.random.RandomState(seed + 1)
        ns = rng.randint(1, N + 1, size=Sn).astype(np.int64)
        solver = data.draw(st.sampled_from(sorted(SW.BATCHED_SOLVERS)))
        combine = data.draw(st.sampled_from(("sum", "max")))
        use_ns = data.draw(st.booleans())
        kw = {"n_devices": ns} if use_ns else {}
        ref = SW.solve_batched(C[0], solver=solver, combine=combine, **kw)
        got = SW.solve_multi_channel(
            C[:1], channels=("latency",), solver=solver, combine=combine,
            **kw)
        assert np.array_equal(got.splits, ref.splits)
        assert np.array_equal(got.cost_s, ref.cost_s)  # bit-exact, == not allclose
        assert np.array_equal(got.feasible, ref.feasible)

    @pytest.mark.parametrize("backend", ["numpy", "jax", "sharded", "pallas"])
    @pytest.mark.parametrize("combine", ["sum", "max"])
    def test_every_backend_both_combines(self, backend, combine):
        rng = np.random.RandomState(7)
        Sn, N, L = 5, 3, 9
        lat = rng.uniform(0.01, 100.0, size=(Sn, N, L, L))
        lat[:, :, np.tril_indices(L, -1)[0], np.tril_indices(L, -1)[1]] = INF
        C = np.stack([lat, rng.uniform(0.001, 10.0, size=(Sn, N, L, L))])
        ns = rng.randint(1, N + 1, size=Sn).astype(np.int64)
        for kw in ({}, {"n_devices": ns}):
            ref = SW.solve_batched(C[0], combine=combine, backend=backend,
                                   **kw)
            got = SW.solve_multi_channel(C[:1], channels=("latency",),
                                         combine=combine, backend=backend,
                                         **kw)
            assert np.array_equal(got.splits, ref.splits)
            assert np.array_equal(got.cost_s, ref.cost_s)
            assert np.array_equal(got.feasible, ref.feasible)

    def test_model_stack_degenerate_matches_plain_path(self):
        m = energized_model()
        C = SW.stack_cost_tensors([m], 3, channels=COST_CHANNELS)
        ref = SW.solve_batched(m.segment_cost_tensor(3)[None])
        got = SW.solve_multi_channel(C[:1], channels=("latency",))
        assert np.array_equal(got.splits, ref.splits)
        assert np.array_equal(got.cost_s, ref.cost_s)


class TestEnergyScalarTensorParity:
    """energy_cost_tensor entries == segment_energy_j, bit-for-bit."""

    @given(data=st.data())
    @settings(max_examples=10)
    def test_tensor_matches_scalar_everywhere(self, data):
        m = energized_model(
            tx_power_w=data.draw(st.floats(0.0, 2.0, allow_nan=False,
                                           allow_infinity=False)),
            rx_power_w=data.draw(st.floats(0.0, 2.0, allow_nan=False,
                                           allow_infinity=False)),
            active_power_w=data.draw(st.floats(0.0, 5.0, allow_nan=False,
                                               allow_infinity=False)),
        )
        N = data.draw(st.integers(1, 3))
        L = m.profile.num_layers
        E = m.energy_cost_tensor(N)
        for k in range(1, N + 1):
            for a in range(1, L + 1):
                for b in range(a, L + 1):
                    scalar = m.segment_energy_j(a, b, k)
                    tensor = E[k - 1, a - 1, b - 1]
                    assert scalar == tensor or (
                        math.isinf(scalar) and math.isinf(tensor))


class TestBudgetZeroRegret:
    """Budget-constrained batched solve == brute-force filtered oracle."""

    @given(data=st.data())
    @settings(max_examples=25)
    def test_matches_brute_force_oracle(self, data):
        C, L, N, Sn, seed = data.draw(channel_tensors(max_L=8, max_N=4))
        # budgets spanning infeasible -> slack regimes
        q = data.draw(st.sampled_from((5.0, 30.0, 60.0, 90.0, 100.0)))
        budget = float(np.percentile(C[1], q))
        res = SW.solve_multi_channel(C, energy_budget=budget)
        for s in range(Sn):
            fn = tensor_cost_fn(C[0, s], L)
            efn = tensor_cost_fn(C[1, s], L)
            oracle = S.brute_force(fn, L, N, combine="sum",
                                   energy_fn=efn, energy_budget=budget)
            feasible = math.isfinite(oracle.cost_s)
            assert bool(res.feasible[s]) == feasible
            if not feasible:
                continue
            assert res.cost_s[s] == oracle.cost_s  # zero regret, bitwise
            splits = tuple(int(x) for x in res.splits[s][:N - 1])
            bounds = (0,) + splits + (L,)
            for k in range(N):
                e = efn(bounds[k] + 1, bounds[k + 1], k + 1)
                assert e <= budget
            assert res.channel_cost_s is not None
            total_e = sum(efn(bounds[k] + 1, bounds[k + 1], k + 1)
                          for k in range(N))
            assert math.isclose(res.channel_cost_s[1][s], total_e,
                                rel_tol=1e-12)

    @given(data=st.data())
    @settings(max_examples=15)
    def test_scalar_solvers_respect_budget(self, data):
        C, L, N, Sn, seed = data.draw(channel_tensors(max_L=7, max_N=3,
                                                      max_scenarios=1))
        budget = float(np.percentile(C[1], 50.0))
        fn = tensor_cost_fn(C[0, 0], L)
        efn = tensor_cost_fn(C[1, 0], L)
        oracle = S.brute_force(fn, L, N, combine="sum",
                               energy_fn=efn, energy_budget=budget)
        dp = S.optimal_dp(fn, L, N, combine="sum",
                          energy_fn=efn, energy_budget=budget)
        assert dp.cost_s == oracle.cost_s
        if math.isfinite(oracle.cost_s):
            assert S.total_energy(efn, dp.splits, L) <= N * budget
        for name in ("beam", "greedy", "first_fit"):
            r = S.SOLVERS[name](fn, L, N, combine="sum",
                                energy_fn=efn, energy_budget=budget)
            if math.isfinite(r.cost_s):
                # heuristics may be suboptimal but never over budget
                bounds = (0,) + tuple(r.splits) + (L,)
                for k in range(N):
                    assert efn(bounds[k] + 1, bounds[k + 1], k + 1) <= budget
                assert r.cost_s >= oracle.cost_s


class TestMetamorphicScaling:
    """Scaling energies and budget together never changes the plan."""

    @given(data=st.data())
    @settings(max_examples=25)
    def test_power_of_two_energy_scaling_is_invariant(self, data):
        C, L, N, Sn, seed = data.draw(channel_tensors())
        budget = float(np.percentile(C[1], 60.0))
        factor = data.draw(st.sampled_from((0.25, 0.5, 2.0, 8.0, 64.0)))
        res = SW.solve_multi_channel(C, energy_budget=budget)
        C2 = np.stack([C[0], C[1] * factor])
        res2 = SW.solve_multi_channel(C2, energy_budget=budget * factor)
        assert np.array_equal(res.splits, res2.splits)
        assert np.array_equal(res.cost_s, res2.cost_s)
        assert np.array_equal(res.feasible, res2.feasible)

    def test_model_level_scaling_is_invariant(self):
        m = energized_model()
        E = m.energy_cost_tensor(3)
        budget = float(np.percentile(E[np.isfinite(E)], 60.0))
        C = SW.stack_cost_tensors([m], 3, channels=COST_CHANNELS)
        res = SW.solve_multi_channel(C, energy_budget=budget)
        s = 8.0  # power of two: float-exact under scaling
        m2 = replace(
            m,
            link=replace(m.link, tx_power_w=m.link.tx_power_w * s,
                         rx_power_w=m.link.rx_power_w * s),
            devices=tuple(replace(d, active_power_w=d.active_power_w * s)
                          for d in m.devices),
        )
        C2 = SW.stack_cost_tensors([m2], 3, channels=COST_CHANNELS)
        res2 = SW.solve_multi_channel(C2, energy_budget=budget * s)
        assert np.array_equal(res.splits, res2.splits)
        assert np.array_equal(res.cost_s, res2.cost_s)


class TestContentionRegression:
    """Shared-channel pricing: monotone in transmitters, identity at 1."""

    def test_two_transmitters_never_cheaper(self):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        shared = replace(m, contention=ContentionModel(transmitters=2))
        tx0 = m.transmission_cost_vector()
        tx2 = shared.transmission_cost_vector()
        assert (tx2 >= tx0).all()
        for n in (1, 2, 3):
            r0 = S.optimal_dp(m.cost_segment_fn(), m.profile.num_layers, n)
            r2 = S.optimal_dp(shared.cost_segment_fn(),
                              m.profile.num_layers, n)
            assert r2.cost_s >= r0.cost_s

    def test_more_transmitters_monotone(self):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        prev = S.optimal_dp(m.cost_segment_fn(), m.profile.num_layers, 3)
        for tx in (2, 4, 8):
            cur = S.optimal_dp(
                replace(m, contention=ContentionModel(transmitters=tx))
                .cost_segment_fn(),
                m.profile.num_layers, 3)
            assert cur.cost_s >= prev.cost_s
            prev = cur

    def test_group_of_one_is_bit_identical(self):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        solo = replace(m, contention=ContentionModel(transmitters=1))
        assert solo.effective_link is m.link  # the SAME object
        assert np.array_equal(solo.transmission_cost_vector(),
                              m.transmission_cost_vector())
        assert np.array_equal(solo.segment_cost_tensor(3),
                              m.segment_cost_tensor(3))
        L = m.profile.num_layers
        for a, b, k in ((1, L, 1), (1, 5, 1), (6, L, 2)):
            assert solo.segment_cost_s(a, b, k) == m.segment_cost_s(a, b, k)
            assert solo.segment_energy_j(a, b, k) == m.segment_energy_j(a, b, k)

    def test_mac_efficiency_bounds(self):
        with pytest.raises(ValueError):
            ContentionModel(transmitters=0)
        with pytest.raises(ValueError):
            ContentionModel(transmitters=2, mac_efficiency=0.0)
        with pytest.raises(ValueError):
            ContentionModel(transmitters=2, mac_efficiency=1.5)
        assert ContentionModel(transmitters=4,
                               mac_efficiency=0.8).rate_scale() == 0.2

    def test_grid_contention_axis(self):
        grid = SW.ScenarioGrid(
            models={"mobilenet_v2":
                    paper_cost_model("mobilenet_v2", "esp_now").profile},
            links={"esp_now": PROTOCOLS["esp_now"]},
            n_devices=(2,),
            devices=(ESP32,),
            contention_groups=(1, 2),
        )
        assert grid.size == 2
        res = SW.sweep(grid)
        by_cg = {r.scenario.contention: r for r in res.rows}
        assert by_cg[2].objective_cost_s >= by_cg[1].objective_cost_s
        # cg=1 rows are bit-identical to a grid without the axis
        base = SW.sweep(SW.ScenarioGrid(
            models={"mobilenet_v2":
                    paper_cost_model("mobilenet_v2", "esp_now").profile},
            links={"esp_now": PROTOCOLS["esp_now"]},
            n_devices=(2,),
            devices=(ESP32,),
        ))
        assert by_cg[1].splits == base.rows[0].splits
        assert by_cg[1].objective_cost_s == base.rows[0].objective_cost_s


class TestGridEnergyBudgetAxis:
    """ScenarioGrid energy_budgets axis: batched == scalar oracle."""

    def test_budgeted_sweep_matches_scalar(self):
        m = energized_model()
        E = m.energy_cost_tensor(3)
        tight = float(np.percentile(E[np.isfinite(E)], 60.0))
        grid = SW.ScenarioGrid(
            models={"mobilenet_v2": m.profile},
            links={"esp_now": replace(PROTOCOLS["esp_now"],
                                      tx_power_w=m.link.tx_power_w,
                                      rx_power_w=m.link.rx_power_w)},
            n_devices=(2, 3),
            devices=m.devices,
            energy_budgets=(None, tight),
        )
        assert grid.size == 4
        batched = SW.sweep(grid)
        scalar = SW.sweep_scalar(grid, solver="optimal_dp")
        for rb, rs in zip(batched.rows, scalar.rows):
            assert rb.scenario.energy_budget == rs.scenario.energy_budget
            assert rb.splits == rs.splits
            assert rb.objective_cost_s == rs.objective_cost_s
        # the budget must bind for at least one scenario
        by_budget = {}
        for r in batched.rows:
            key = (r.scenario.n_devices, r.scenario.energy_budget is None)
            by_budget[key] = r
        assert any(
            by_budget[(n, False)].objective_cost_s
            > by_budget[(n, True)].objective_cost_s
            for n in (2, 3)
        ) or any(not by_budget[(n, False)].feasible for n in (2, 3))
