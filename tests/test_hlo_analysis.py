"""Loop-aware HLO analysis tests (the roofline collective-term machinery)."""

import textwrap

import pytest

from repro.parallel.hlo_analysis import (
    computation_multipliers,
    shape_bytes,
    split_computations,
    trip_count,
    weighted_collective_bytes,
)

FAKE_HLO = textwrap.dedent("""\
    HloModule jit_step

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %r = f32[] add(%a, %b)
    }

    %cond (p: (s32[], f32[4,8])) -> pred[] {
      %p = (s32[], f32[4,8]) parameter(0)
      %c = s32[] constant(12)
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    %body (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
      %p = (s32[], f32[4,8]) parameter(0)
      %x = f32[4,8] get-tuple-element(%p), index=1
      %ar = f32[4,8]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %t = (s32[], f32[4,8]) tuple(%i, %ar)
    }

    ENTRY %main (x: f32[4,8]) -> f32[4,8] {
      %x = f32[4,8] parameter(0)
      %ag = f32[64,8]{1,0} all-gather(%x), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
      %zero = s32[] constant(0)
      %t0 = (s32[], f32[4,8]) tuple(%zero, %x)
      %w = (s32[], f32[4,8]) while(%t0), condition=%cond, body=%body
      ROOT %out = f32[4,8] get-tuple-element(%w), index=1
    }
""")


class TestParsing:
    def test_split_computations(self):
        comps, entry = split_computations(FAKE_HLO)
        assert entry == "main"
        assert set(comps) >= {"add", "cond", "body", "main"}

    def test_trip_count_from_condition(self):
        comps, _ = split_computations(FAKE_HLO)
        assert trip_count(comps["cond"]) == 12

    def test_multipliers(self):
        mult = computation_multipliers(FAKE_HLO)
        assert mult["main"] == 1.0
        assert mult["body"] == 12.0
        assert mult["cond"] == 12.0
        # reduction computations (to_apply of collectives) carry no
        # collectives themselves; they are not walked.

    def test_shape_bytes(self):
        assert shape_bytes("f32[4,8]") == 128
        assert shape_bytes("(bf16[2,2], s8[10])") == 18

    def test_weighted_bytes(self):
        res = weighted_collective_bytes(FAKE_HLO)
        # in-loop all-reduce: 128 B x 12 trips; entry all-gather: 2048 B x 1
        assert res["bytes"]["all-reduce"] == 128 * 12
        assert res["bytes"]["all-gather"] == 64 * 8 * 4
        assert res["counts"]["all-reduce"] == 12
        # wire: AR ring 2(s-1)/s with s=16; AG (s-1)/s
        assert res["wire_bytes"]["all-reduce"] == int(128 * 12 * 2 * 15 / 16)
        assert res["wire_bytes"]["all-gather"] == int(2048 * 15 / 16)
