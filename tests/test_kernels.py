"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in ``interpret=True`` on CPU (the TPU lowering is
exercised structurally by the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant_matmul.kernel import quant_matmul_kernel, w8a16_matmul_kernel
from repro.kernels.quant_matmul.ops import quant_linear, w8a16_linear
from repro.kernels.quant_matmul.ref import (float_matmul_ref, quant_matmul_ref,
                                             w8a16_matmul_ref)
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


class TestQuantMatmul:
    @pytest.mark.parametrize("shape", [
        (64, 64, 64), (128, 256, 512), (100, 200, 300), (1, 64, 17),
        (256, 128, 128), (33, 65, 129),
    ])
    def test_matches_integer_reference_exactly(self, shape):
        M, K, N = shape
        rng = np.random.default_rng(M * K + N)
        a = jnp.asarray(rng.integers(-128, 128, (M, K), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (K, N), dtype=np.int8))
        a_scale, a_zp = jnp.float32(0.03), jnp.int32(-5)
        w_scale = jnp.asarray(rng.uniform(0.001, 0.1, N), dtype=jnp.float32)
        out = quant_matmul_kernel(a, w, a_scale, a_zp, w_scale, interpret=True)
        ref = quant_matmul_ref(a, w, a_scale, a_zp, w_scale)
        np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
    def test_out_dtypes(self, out_dtype):
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.integers(-128, 128, (64, 64), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (64, 64), dtype=np.int8))
        w_scale = jnp.full((64,), 0.02, dtype=jnp.float32)
        out = quant_matmul_kernel(a, w, jnp.float32(0.1), jnp.int32(0), w_scale,
                                  out_dtype=out_dtype, interpret=True)
        assert out.dtype == out_dtype
        ref = quant_matmul_ref(a, w, jnp.float32(0.1), jnp.int32(0), w_scale)
        np.testing.assert_allclose(out.astype(jnp.float32), ref,
                                   rtol=1e-2 if out_dtype == jnp.bfloat16 else 1e-6)

    def test_integer_vs_float_reference_consistent(self):
        """The zero-point-folded integer math equals dequantize-then-matmul."""
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.integers(-128, 128, (32, 48), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (48, 16), dtype=np.int8))
        w_scale = jnp.asarray(rng.uniform(0.01, 0.1, 16), dtype=jnp.float32)
        i_ref = quant_matmul_ref(a, w, jnp.float32(0.05), jnp.int32(4), w_scale)
        f_ref = float_matmul_ref(a, w, jnp.float32(0.05), jnp.int32(4), w_scale)
        np.testing.assert_allclose(i_ref, f_ref, rtol=1e-4, atol=1e-4)

    def test_quant_linear_close_to_float_linear(self):
        """End-to-end: int8 path approximates the float matmul within the
        quantization noise floor."""
        rng = jax.random.PRNGKey(0)
        x = jax.random.normal(rng, (8, 128))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (128, 64)) * 0.1
        wq = quantize(w, axis=1, symmetric=True)
        out = quant_linear(x, wq, interpret=True)
        ref = x @ w
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel < 0.02, rel


class TestW8A16Matmul:
    @pytest.mark.parametrize("shape", [(64, 64, 64), (100, 200, 300), (1, 128, 32),
                                       (256, 128, 512)])
    def test_matches_reference(self, shape):
        M, K, N = shape
        rng = np.random.default_rng(M + K + N)
        x = jnp.asarray(rng.normal(size=(M, K)), dtype=jnp.float32)
        w = jnp.asarray(rng.integers(-128, 128, (K, N)), dtype=np.int8)
        ws = jnp.asarray(rng.uniform(0.001, 0.05, N), dtype=jnp.float32)
        out = w8a16_matmul_kernel(x, w, ws, interpret=True)
        ref = w8a16_matmul_ref(x, w, ws)
        # k-block accumulation order differs from the monolithic matmul
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_bf16_activations(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(32, 64)), dtype=jnp.bfloat16)
        w = jnp.asarray(rng.integers(-128, 128, (64, 48)), dtype=np.int8)
        ws = jnp.full((48,), 0.02, dtype=jnp.float32)
        out = w8a16_matmul_kernel(x, w, ws, interpret=True)
        ref = w8a16_matmul_ref(x.astype(jnp.float32), w, ws)
        np.testing.assert_allclose(out, ref, rtol=2e-2, atol=2e-2)

    def test_layer_level_close_to_float(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
        wf = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 0.1
        wq = quantize(wf, axis=1, symmetric=True)
        out = w8a16_linear(x, wq, interpret=True)
        rel = float(jnp.linalg.norm(out - x @ wf) / jnp.linalg.norm(x @ wf))
        assert rel < 0.01


class TestFlashAttention:
    @pytest.mark.parametrize("cfg", [
        # (B, Sq, Skv, H, Hkv, D, bq, bkv)
        (2, 128, 128, 4, 2, 32, 32, 64),
        (1, 64, 64, 4, 4, 64, 16, 16),
        (2, 100, 100, 4, 1, 32, 32, 32),   # MQA + ragged
        (1, 1, 256, 8, 2, 64, 8, 64),      # decode-shaped
        (1, 96, 200, 2, 2, 16, 32, 64),    # q suffix of longer kv
        (1, 256, 256, 2, 2, 128, 128, 128),  # MXU-aligned blocks
    ])
    def test_matches_reference(self, cfg):
        B, Sq, Skv, H, Hkv, D, bq, bkv = cfg
        ks = jax.random.split(jax.random.PRNGKey(Sq + Skv), 3)
        q = jax.random.normal(ks[0], (B, Sq, H, D), dtype=jnp.float32)
        k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype=jnp.float32)
        v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype=jnp.float32)
        qpos = jnp.arange(Skv - Sq, Skv, dtype=jnp.int32)
        kpos = jnp.arange(Skv, dtype=jnp.int32)
        out = flash_attention(q, k, v, q_positions=jnp.tile(qpos[None], (B, 1)),
                              kv_positions=kpos, scale=D**-0.5,
                              block_q=bq, block_kv=bkv, interpret=True)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
        ref = attention_ref(qf, kf, vf, qpos, kpos, D**-0.5)
        ref = ref.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=2e-5)

    def test_bf16_inputs(self):
        B, S, H, D = 1, 128, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dtype=jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, S, H, D), dtype=jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, S, H, D), dtype=jnp.bfloat16)
        pos = jnp.arange(S, dtype=jnp.int32)
        out = flash_attention(q, k, v, q_positions=jnp.tile(pos[None], (B, 1)),
                              kv_positions=pos, scale=D**-0.5, block_q=32,
                              block_kv=64, interpret=True)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        ref = attention_ref(qf.astype(jnp.float32), kf.astype(jnp.float32),
                            vf.astype(jnp.float32), pos, pos, D**-0.5)
        ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=2e-2, atol=2e-2)

    def test_matches_model_chunked_attention(self):
        """Kernel vs the model's pure-JAX chunked attention (two
        independent flash implementations must agree)."""
        from repro.models.layers import chunked_attention

        B, S, H, Hkv, D = 2, 96, 4, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        pos = jnp.arange(S, dtype=jnp.int32)
        a = flash_attention(q, k, v, q_positions=jnp.tile(pos[None], (B, 1)),
                            kv_positions=pos, scale=D**-0.5, block_q=32,
                            block_kv=32, interpret=True)
        b = chunked_attention(q, k, v, q_positions=jnp.tile(pos[None], (B, 1)),
                              kv_positions=pos, scale=D**-0.5, kv_chunk=16)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


class TestSSMScan:
    @pytest.mark.parametrize("cfg", [
        # (BH, S, ph, ds, chunk)
        (4, 64, 16, 8, 16), (2, 128, 32, 16, 32), (3, 100, 16, 8, 32),
        (1, 256, 64, 64, 128), (2, 37, 8, 8, 16),
    ])
    def test_matches_sequential_recurrence(self, cfg):
        BH, S, ph, ds, ck = cfg
        ks = jax.random.split(jax.random.PRNGKey(S * ph), 5)
        x = jax.random.normal(ks[0], (BH, S, ph))
        b = jax.random.normal(ks[1], (BH, S, ds)) * 0.5
        c = jax.random.normal(ks[2], (BH, S, ds)) * 0.5
        dA = -jax.nn.softplus(jax.random.normal(ks[3], (BH, S)))
        dt = jax.nn.softplus(jax.random.normal(ks[4], (BH, S)))
        out = ssm_scan_kernel(x, b, c, dA, dt, chunk=ck, interpret=True)
        ref = ssm_scan_ref(x, b, c, dA, dt)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4)

    def test_model_layout_op(self):
        B, S, H, ph, ds = 2, 64, 3, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (B, S, H, ph))
        b = jax.random.normal(ks[1], (B, S, ds)) * 0.5
        c = jax.random.normal(ks[2], (B, S, ds)) * 0.5
        dA = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        dt = jax.nn.softplus(jax.random.normal(ks[4], (B, S, H)))
        out = ssm_scan(x, b, c, dA, dt, chunk=16, interpret=True)
        assert out.shape == (B, S, H, ph)
        # oracle in folded layout
        xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, ph)
        bf = jnp.broadcast_to(b[:, None], (B, H, S, ds)).reshape(B * H, S, ds)
        cf = jnp.broadcast_to(c[:, None], (B, H, S, ds)).reshape(B * H, S, ds)
        dAf = dA.transpose(0, 2, 1).reshape(B * H, S)
        dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
        ref = ssm_scan_ref(xf, bf, cf, dAf, dtf).reshape(B, H, S, ph).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4)

    def test_long_sequence_stability(self):
        """Decay keeps the state bounded over long scans (no overflow)."""
        BH, S, ph, ds = 1, 1024, 8, 8
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        x = jax.random.normal(ks[0], (BH, S, ph))
        b = jax.random.normal(ks[1], (BH, S, ds)) * 0.3
        c = jax.random.normal(ks[2], (BH, S, ds)) * 0.3
        dA = -jax.nn.softplus(jax.random.normal(ks[3], (BH, S)) + 1.0)
        dt = jax.nn.softplus(jax.random.normal(ks[4], (BH, S)))
        out = ssm_scan_kernel(x, b, c, dA, dt, chunk=128, interpret=True)
        assert bool(jnp.all(jnp.isfinite(out)))
