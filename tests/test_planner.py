"""Planner-level property tests: plan validity, objective semantics,
TPU pipeline planning, and the arch layer-graph invariants."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.core.latency import DeviceProfile, LayerCost, LinkProfile, ModelCostProfile, SplitCostModel
from repro.core.planner import plan_pipeline, plan_split, tpu_cost_profile, uniform_split
from repro.core.profiles import DCN, ICI, paper_cost_model
from repro.models.graph import arch_layer_graph


def toy_model(L=10, objective="sum"):
    layers = [LayerCost(f"l{i}", 0.01 * (i + 1), 100 * (i + 1), 50, 200, 1e6)
              for i in range(L)]
    prof = ModelCostProfile("toy", tuple(layers), input_bytes=64)
    link = LinkProfile("lk", 64, 1e5, t_setup_s=0.1, t_feedback_s=0.01)
    return SplitCostModel(prof, (DeviceProfile("d"),), link, objective=objective)


class TestPlanValidity:
    @given(st.integers(1, 8), st.sampled_from(["beam", "greedy", "first_fit",
                                               "optimal_dp", "random_fit"]))
    @settings(max_examples=40, deadline=None)
    def test_segments_partition_the_layer_chain(self, n, solver):
        m = toy_model(12)
        plan = plan_split(m, n, solver=solver)
        assert len(plan.segments) == n
        # contiguous cover of [1, L]
        assert plan.segments[0].first_layer == 1
        assert plan.segments[-1].last_layer == 12
        for a, b in zip(plan.segments, plan.segments[1:]):
            assert b.first_layer == a.last_layer + 1
        # last segment ships nothing
        assert plan.segments[-1].tx_bytes == 0

    def test_objective_cost_consistency_sum(self):
        m = toy_model(10, "sum")
        plan = plan_split(m, 3, solver="optimal_dp")
        recomputed = sum(s.cost_s for s in plan.segments)
        assert plan.objective_cost_s == pytest.approx(recomputed)

    def test_objective_cost_consistency_bottleneck(self):
        m = toy_model(10, "bottleneck")
        plan = plan_split(m, 3, solver="optimal_dp")
        assert plan.objective_cost_s == pytest.approx(
            max(s.cost_s for s in plan.segments))

    def test_bottleneck_optimum_at_most_sum_optimum(self):
        ms = toy_model(10, "sum")
        mb = toy_model(10, "bottleneck")
        ps = plan_split(ms, 3, solver="optimal_dp")
        pb = plan_split(mb, 3, solver="optimal_dp")
        assert pb.objective_cost_s <= ps.objective_cost_s + 1e-12

    def test_more_devices_never_helps_sum_objective(self):
        """With per-device overheads and transmission costs, adding devices
        monotonically increases the paper's sum objective on MobileNetV2
        (Fig. 3's rising curves)."""
        m = paper_cost_model("mobilenet_v2", "esp_now")
        costs = [plan_split(m, n, solver="optimal_dp").total_latency_s
                 for n in (1, 2, 4, 6)]
        assert all(a <= b + 1e-9 for a, b in zip(costs, costs[1:]))


class TestPipelinePlanning:
    @pytest.mark.parametrize("arch", ["granite-34b", "zamba2-1.2b"])
    def test_beam_no_worse_than_uniform(self, arch):
        g = arch_layer_graph(get_config(arch), batch=8, seq=1024)
        for link in (ICI, DCN):
            plan = plan_pipeline(g, 4, chips_per_stage=4, link=link)
            prof = tpu_cost_profile(g, chips_per_stage=4)
            from repro.core.profiles import tpu_stage_device

            model = SplitCostModel(prof, (tpu_stage_device(4),), link,
                                   objective="bottleneck")
            uni = model.end_to_end_s(uniform_split(prof.num_layers, 4),
                                     with_overheads=False)
            assert plan.objective_cost_s <= uni + 1e-12

    def test_beam_matches_dp_on_all_archs(self):
        """Beam (B=8) finds the exact bottleneck optimum on every assigned
        arch's block chain (the Fig. 4 claim at datacenter scale)."""
        for arch in ARCH_IDS:
            g = arch_layer_graph(get_config(arch), batch=4, seq=512)
            beam = plan_pipeline(g, 4, link=ICI, solver="beam")  # B=16 default
            opt = plan_pipeline(g, 4, link=ICI, solver="optimal_dp")
            assert beam.objective_cost_s <= opt.objective_cost_s * 1.02, arch


class TestArchLayerGraph:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_graph_invariants(self, arch):
        cfg = get_config(arch)
        g = arch_layer_graph(cfg, batch=2, seq=128)
        assert g.num_layers == cfg.n_layers + 2  # embed + blocks + head
        assert all(n.flops >= 0 and n.param_count >= 0 for n in g.nodes)
        assert all(n.out_elems > 0 for n in g.nodes)
        # params roughly match the config estimate (within 25% — the graph
        # includes per-layer norms/bias detail the estimate rounds away)
        assert g.total_params == pytest.approx(cfg.n_params, rel=0.25)

    def test_decode_graph_scales_with_kv(self):
        cfg = get_config("deepseek-7b")
        g1 = arch_layer_graph(cfg, batch=4, seq=1, kv_len=1024)
        g2 = arch_layer_graph(cfg, batch=4, seq=1, kv_len=4096)
        assert g2.total_flops > g1.total_flops
