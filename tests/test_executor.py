"""Split-executor property tests: split execution must equal the unsplit
model for ANY valid split configuration (the core correctness invariant
of split inference), plus wire-accounting consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.executor import run_split, run_unsplit, segment_bounds
from repro.core.profiles import ESP_NOW, UDP
from repro.models.mobilenetv2 import MobileNetV2
from repro.models.resnet50 import ResNet50


@pytest.fixture(scope="module")
def mbv2():
    model = MobileNetV2(width=0.35, image_size=64)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), model.input_shape(2))
    ref = run_unsplit(model, params, x)
    return model, params, x, ref


class TestSplitEqualsUnsplit:
    @given(st.data())
    @settings(max_examples=15, deadline=None)
    def test_any_split_configuration_mbv2(self, mbv2, data):
        model, params, x, ref = mbv2
        L = len(model.layer_names)
        n = data.draw(st.integers(2, 5))
        splits = tuple(sorted(data.draw(
            st.sets(st.integers(1, L - 1), min_size=n - 1, max_size=n - 1))))
        out, trace = run_split(model, params, x, splits)
        np.testing.assert_array_equal(out["h"], ref["h"])
        assert len(trace.hops) == n - 1

    def test_paper_split_points(self, mbv2):
        model, params, x, ref = mbv2
        g_idx = [model.layer_names.index(n) + 1 for n in
                 ("block_2_expand", "block_15_project_BN", "block_16_project_BN")]
        out, _ = run_split(model, params, x, tuple(sorted(g_idx)))
        np.testing.assert_array_equal(out["h"], ref["h"])

    def test_resnet50_block_splits(self):
        model = ResNet50(image_size=64)
        params = model.init(jax.random.PRNGKey(2))
        x = jax.random.normal(jax.random.PRNGKey(3), model.input_shape(1))
        ref = run_unsplit(model, params, x)
        out, _ = run_split(model, params, x, (5, 20, 35, 50))
        np.testing.assert_array_equal(out["h"], ref["h"])


class TestWireAccounting:
    def test_bytes_include_live_residuals(self, mbv2):
        """Cutting inside a residual block ships main + skip tensors —
        16.7% more than the paper's main-tensor-only count at
        block_2_expand (documented fidelity note)."""
        model, params, x, ref = mbv2
        idx = model.layer_names.index("block_2_expand") + 1
        _, trace = run_split(model, params, x, (idx,), quantize_wire=True)
        h, w = 16, 16  # 64px input -> 16x16 at this depth
        main = 2 * h * w * 48
        skip = 2 * h * w * 8
        assert trace.hops[0].nbytes == main + skip

    def test_block_boundary_matches_paper_bytes(self, mbv2):
        """At block_16_project_BN the residual is consumed: the wire holds
        exactly the main tensor (paper's Table II convention)."""
        model, params, x, ref = mbv2
        idx = model.layer_names.index("block_16_project_BN") + 1
        _, trace = run_split(model, params, x, (idx,), quantize_wire=True)
        assert trace.hops[0].nbytes == 2 * 2 * 2 * 112  # 64px -> 2x2 spatial

    def test_packets_and_latency_consistent_with_link(self, mbv2):
        model, params, x, _ = mbv2
        for link in (ESP_NOW, UDP):
            _, trace = run_split(model, params, x, (30,), link=link,
                                 quantize_wire=True)
            hop = trace.hops[0]
            assert hop.n_packets == link.packets(hop.nbytes)
            assert hop.sim_latency_s == pytest.approx(
                link.transmission_latency_s(hop.nbytes))

    def test_segment_bounds_validation(self):
        with pytest.raises(ValueError):
            segment_bounds((5, 3), 10)  # not increasing
        assert segment_bounds((3,), 5) == [(1, 3), (4, 5)]
