"""Property-based batched-vs-scalar solver parity suite.

The sweep engine's contract is that every batched solver returns
bit-identical best splits (and costs) to its scalar oracle
(:data:`repro.core.sweep.SCALAR_ORACLES`) on the NumPy float64 path.
This suite drives that contract harder than the targeted tests in
``test_sweep.py``: random dense ``C[k, a, b]`` tensors with sprinkled
infeasibility, every solver, both combine modes, and every fleet size
the tensor supports.

Strategy arguments are keyword-bound in every ``@given`` below: the
vendored minihypothesis shim binds positional strategies to the
RIGHTMOST parameters (as real hypothesis does), and keyword binding
makes the pairing explicit and immune to signature reordering.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import solvers as S
from repro.core import sweep as SW

INF = float("inf")


@st.composite
def dense_tensors(draw, max_devices=5, min_scenarios=2, max_scenarios=6):
    """Random stacked cost tensors (S, N, L, L): continuous uniform
    costs (exact float ties have probability zero, so even beam's
    tie-sensitive truncation must match the scalar solver bitwise),
    a sprinkle of +inf infeasibility, and an always-invalid lower
    triangle."""
    L = draw(st.integers(3, 10))
    N = draw(st.integers(1, min(max_devices, L)))
    Sn = draw(st.integers(min_scenarios, max_scenarios))
    seed = draw(st.integers(0, 2**31 - 1))
    inf_frac = draw(st.floats(0.0, 0.35))
    rng = np.random.RandomState(seed)
    C = rng.uniform(0.01, 100.0, size=(Sn, N, L, L))
    C[rng.uniform(size=C.shape) < inf_frac] = INF
    C[:, :, np.tril(np.ones((L, L), bool), k=-1)] = INF
    return C


def scalar_fn(Cs):
    """Scalar cost_fn view of one scenario's (N, L, L) tensor."""
    Nn, L = Cs.shape[0], Cs.shape[-1]

    def fn(a, b, k):
        if not (1 <= a <= b <= L):
            return INF
        return float(Cs[min(k, Nn) - 1, a - 1, b - 1])

    return fn


def assert_bit_identical(scalar_res, batched_res, s):
    assert scalar_res.splits == batched_res.splits_tuple(s)
    if math.isinf(scalar_res.cost_s):
        assert math.isinf(batched_res.cost_s[s])
    else:
        assert scalar_res.cost_s == batched_res.cost_s[s]  # exact ==, not approx


class TestBatchedSolverParity:
    """Every batched solver == its scalar oracle, across combine modes."""

    @pytest.mark.parametrize("solver", sorted(SW.SCALAR_ORACLES))
    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=30, deadline=None)
    def test_matches_scalar_oracle(self, solver, C, combine):
        oracle = S.SOLVERS[SW.SCALAR_ORACLES[solver]]
        Sn, N, L, _ = C.shape
        res = SW.solve_batched(C, solver=solver, combine=combine)
        assert res.splits.shape == (Sn, N - 1)
        for s in range(Sn):
            assert_bit_identical(oracle(scalar_fn(C[s]), L, N,
                                        combine=combine), res, s)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           width=st.sampled_from([1, 2, 3, 8, 32]))
    @settings(max_examples=30, deadline=None)
    def test_beam_matches_scalar_across_widths(self, C, combine, width):
        Sn, N, L, _ = C.shape
        res = SW.batched_beam_search(C, beam_width=width, combine=combine)
        for s in range(Sn):
            assert_bit_identical(
                S.beam_search(scalar_fn(C[s]), L, N, beam_width=width,
                              combine=combine), res, s)


class TestFleetSizeAxis:
    """Parity must hold for every fleet size a tensor supports, and the
    all-k DP must agree with independent per-k solves."""

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=20, deadline=None)
    def test_every_fleet_size_prefix(self, C, combine):
        Sn, N, L, _ = C.shape
        for n in range(1, N + 1):
            Cn = C[:, :n]
            res = SW.batched_optimal_dp(Cn, combine=combine)
            for s in range(Sn):
                assert_bit_identical(
                    S.optimal_dp(scalar_fn(Cn[s]), L, n, combine=combine),
                    res, s)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=20, deadline=None)
    def test_all_k_dp_matches_scalar_per_k(self, C, combine):
        Sn, N, L, _ = C.shape
        all_k = SW.batched_optimal_dp(C, combine=combine, return_all_k=True)
        assert sorted(all_k) == list(range(1, N + 1))
        for n, res in all_k.items():
            for s in range(Sn):
                assert_bit_identical(
                    S.optimal_dp(scalar_fn(C[s, :n]), L, n, combine=combine),
                    res, s)


class TestPerScenarioFleetSizes:
    """Heterogeneous fleet sizes batch in one pass: scenario ``s`` solved
    for its own ``n_devices[s]`` must equal a standalone solve of the
    ``C[s, :n_s]`` prefix."""

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_dp_and_greedy_match_scalar_oracle(self, C, combine, seed):
        Sn, N, L, _ = C.shape
        ns = np.random.RandomState(seed).randint(1, N + 1, size=Sn)
        for solver in ("batched_dp", "batched_greedy"):
            oracle = S.SOLVERS[SW.SCALAR_ORACLES[solver]]
            res = SW.solve_batched(C, solver=solver, combine=combine,
                                   n_devices=ns)
            assert res.n_devices_s is not None
            for s in range(Sn):
                n = int(ns[s])
                assert_bit_identical(
                    oracle(scalar_fn(C[s, :n]), L, n, combine=combine),
                    res, s)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           width=st.sampled_from([1, 2, 8]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_beam_matches_standalone_batched_beam(self, C, combine, width,
                                                  seed):
        """The per-scenario-n beam is element-wise identical to solving
        each scenario's prefix tensor alone — including under exact
        cost ties (same arithmetic, unlike the scalar-beam caveat)."""
        Sn, N, L, _ = C.shape
        ns = np.random.RandomState(seed).randint(1, N + 1, size=Sn)
        het = SW.batched_beam_search(C, beam_width=width, combine=combine,
                                     n_devices=ns)
        for s in range(Sn):
            n = int(ns[s])
            per = SW.batched_beam_search(C[s:s + 1, :n], beam_width=width,
                                         combine=combine)
            assert per.splits_tuple(0) == het.splits_tuple(s)
            if math.isinf(per.cost_s[0]):
                assert math.isinf(het.cost_s[s])
            else:
                assert per.cost_s[0] == het.cost_s[s]

    def test_n_devices_validation(self):
        C = np.full((3, 2, 4, 4), 1.0)
        with pytest.raises(ValueError):
            SW.batched_optimal_dp(C, n_devices=[1, 2])  # wrong length
        with pytest.raises(ValueError):
            SW.batched_optimal_dp(C, n_devices=[1, 2, 3])  # 3 > N
        with pytest.raises(ValueError):
            SW.batched_optimal_dp(C, n_devices=[0, 1, 2])  # 0 < 1
        with pytest.raises(ValueError):
            SW.batched_optimal_dp(C, n_devices=[1, 2, 2], return_all_k=True)


class TestAllKBeam:
    """One batched beam pass answers every fleet size — and each answer
    equals the per-k batched beam exactly (the all-k beam contract)."""

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           width=st.sampled_from([1, 2, 8]))
    @settings(max_examples=25, deadline=None)
    def test_all_k_matches_per_k_beam(self, C, combine, width):
        Sn, N, L, _ = C.shape
        all_k = SW.batched_beam_search_all_k(C, beam_width=width,
                                             combine=combine)
        assert sorted(all_k) == list(range(1, N + 1))
        for n, res in all_k.items():
            per = SW.batched_beam_search(C[:, :n], beam_width=width,
                                         combine=combine)
            assert res.n_devices == n
            assert np.array_equal(res.splits, per.splits)
            fin = np.isfinite(per.cost_s)
            assert np.array_equal(fin, np.isfinite(res.cost_s))
            assert (res.cost_s[fin] == per.cost_s[fin]).all()
            assert np.array_equal(res.feasible, per.feasible)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=20, deadline=None)
    def test_all_k_greedy_matches_per_k_greedy(self, C, combine):
        """The block all-k greedy carries the same contract — and since
        per-k greedy is bit-identical to the scalar solver, so is every
        all-k block."""
        Sn, N, L, _ = C.shape
        all_k = SW.batched_greedy_search_all_k(C, combine=combine)
        assert sorted(all_k) == list(range(1, N + 1))
        for n, res in all_k.items():
            per = SW.batched_greedy_search(C[:, :n], combine=combine)
            assert np.array_equal(res.splits, per.splits)
            fin = np.isfinite(per.cost_s)
            assert np.array_equal(fin, np.isfinite(res.cost_s))
            assert (res.cost_s[fin] == per.cost_s[fin]).all()
            assert np.array_equal(res.feasible, per.feasible)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=10, deadline=None)
    def test_subset_fleet_sizes(self, C, combine):
        Sn, N, L, _ = C.shape
        sizes = sorted({1, N})
        sub = SW.batched_beam_search_all_k(C, combine=combine,
                                           fleet_sizes=sizes)
        assert sorted(sub) == sizes
        for n in sizes:
            per = SW.batched_beam_search(C[:, :n], combine=combine)
            assert np.array_equal(sub[n].splits, per.splits)

    def test_fleet_sizes_validated(self):
        C = np.full((2, 3, 5, 5), 1.0)
        with pytest.raises(ValueError):
            SW.batched_beam_search_all_k(C, fleet_sizes=(2, 2))
        with pytest.raises(ValueError):
            SW.batched_beam_search_all_k(C, fleet_sizes=(0,))
        with pytest.raises(ValueError):
            SW.batched_beam_search_all_k(C, fleet_sizes=(4,))


class TestJaxBackendContract:
    """``backend="jax"`` (and the sharded path riding the same kernel)
    now carries the full solver contract. Float32 rounding may break
    exact-cost near-ties differently from the float64 oracle, so these
    properties assert what survives any rounding: identical
    feasibility, cost parity within f32 tolerance, and zero regret of
    the reported splits when re-priced in float64. (Bitwise x64 parity
    and fixed-seed splits equality live in ``tests/test_shard.py``.)"""

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_per_scenario_n_devices_with_inf_padding(self, C, combine, seed):
        """Frozen-row subsetting on the JAX backend: +inf device slices
        beyond each scenario's own fleet size (stack_cost_tensors
        padding) never poison a live row."""
        Sn, N, L, _ = C.shape
        ns = np.random.RandomState(seed).randint(1, N + 1, size=Sn)
        C = C.copy()
        for s in range(Sn):
            C[s, ns[s]:] = INF
        a = SW.batched_optimal_dp(C, combine=combine, n_devices=ns)
        b = SW.batched_optimal_dp(C, combine=combine, n_devices=ns,
                                  backend="jax")
        assert np.array_equal(a.feasible, b.feasible)
        fin = a.feasible
        assert np.allclose(a.cost_s[fin], b.cost_s[fin], rtol=1e-4)
        for s in np.flatnonzero(fin):
            n = int(ns[s])
            repriced = S.total_cost(scalar_fn(C[s, :n]),
                                    b.splits_tuple(s), L, combine)
            assert repriced <= float(a.cost_s[s]) * (1 + 1e-4)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=10, deadline=None)
    def test_all_k_jax_matches_numpy_all_k(self, C, combine):
        Sn, N, L, _ = C.shape
        ref = SW.batched_optimal_dp(C, combine=combine, return_all_k=True)
        got = SW.batched_optimal_dp(C, combine=combine, return_all_k=True,
                                    backend="jax")
        assert sorted(got) == sorted(ref)
        for n in ref:
            assert np.array_equal(ref[n].feasible, got[n].feasible)
            fin = ref[n].feasible
            assert np.allclose(ref[n].cost_s[fin], got[n].cost_s[fin],
                               rtol=1e-4)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_sharded_node_identical_to_jax(self, C, combine, seed):
        """The acceptance contract, as a property: the sharded path is
        node-identical (exact ==) to the single-device JAX path — same
        kernel, same per-scenario arithmetic, only the scenario axis is
        partitioned."""
        from repro.core import shard as SH

        Sn, N, L, _ = C.shape
        ns = np.random.RandomState(seed).randint(1, N + 1, size=Sn)
        for kw in ({}, {"n_devices": ns}):
            b = SW.batched_optimal_dp(C, combine=combine, backend="jax", **kw)
            c = SH.sharded_optimal_dp(C, combine=combine, **kw)
            assert np.array_equal(b.splits, c.splits)
            assert np.array_equal(b.cost_s, c.cost_s)
            assert np.array_equal(b.feasible, c.feasible)


class TestPallasBackendContract:
    """``backend="pallas"`` (dense mode, interpret on CPU) carries the
    full solver contract. The dense kernel reorders no arithmetic —
    it only tiles the scenario axis — so beyond the rounding-robust
    properties the jax backend gets, pallas owes a STRONGER one:
    node-identity (exact ``==`` on splits, costs, feasibility) to
    ``backend="jax"``. Fused-mode (construction folded into the
    kernel) parity lives in ``tests/test_pallas_dp.py``."""

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_per_scenario_n_devices_with_inf_padding(self, C, combine, seed):
        """Frozen-row subsetting on the pallas backend: +inf device
        slices beyond each scenario's own fleet size never poison a
        live row (same property as the jax class above)."""
        Sn, N, L, _ = C.shape
        ns = np.random.RandomState(seed).randint(1, N + 1, size=Sn)
        C = C.copy()
        for s in range(Sn):
            C[s, ns[s]:] = INF
        a = SW.batched_optimal_dp(C, combine=combine, n_devices=ns)
        b = SW.batched_optimal_dp(C, combine=combine, n_devices=ns,
                                  backend="pallas")
        assert np.array_equal(a.feasible, b.feasible)
        fin = a.feasible
        assert np.allclose(a.cost_s[fin], b.cost_s[fin], rtol=1e-4)
        for s in np.flatnonzero(fin):
            n = int(ns[s])
            repriced = S.total_cost(scalar_fn(C[s, :n]),
                                    b.splits_tuple(s), L, combine)
            assert repriced <= float(a.cost_s[s]) * (1 + 1e-4)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=8, deadline=None)
    def test_all_k_pallas_matches_numpy_all_k(self, C, combine):
        Sn, N, L, _ = C.shape
        ref = SW.batched_optimal_dp(C, combine=combine, return_all_k=True)
        got = SW.batched_optimal_dp(C, combine=combine, return_all_k=True,
                                    backend="pallas")
        assert sorted(got) == sorted(ref)
        for n in ref:
            assert np.array_equal(ref[n].feasible, got[n].feasible)
            fin = ref[n].feasible
            assert np.allclose(ref[n].cost_s[fin], got[n].cost_s[fin],
                               rtol=1e-4)

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_pallas_node_identical_to_jax(self, C, combine, seed):
        """The acceptance contract, as a property: dense pallas is
        node-identical (exact ==) to the single-device JAX path —
        identical per-scenario float operation order, +inf lane
        padding and replica rows are never observed."""
        Sn, N, L, _ = C.shape
        ns = np.random.RandomState(seed).randint(1, N + 1, size=Sn)
        for kw in ({}, {"n_devices": ns}):
            b = SW.batched_optimal_dp(C, combine=combine, backend="jax", **kw)
            p = SW.batched_optimal_dp(C, combine=combine, backend="pallas",
                                      **kw)
            assert p.backend == "pallas"
            assert np.array_equal(b.splits, p.splits)
            assert np.array_equal(b.cost_s, p.cost_s)
            assert np.array_equal(b.feasible, p.feasible)


class TestSolverInvariants:
    """Cross-solver dominance properties the oracle relationship implies."""

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=25, deadline=None)
    def test_dp_lower_bounds_heuristics(self, C, combine):
        dp = SW.batched_optimal_dp(C, combine=combine)
        for heur in (SW.batched_beam_search(C, combine=combine),
                     SW.batched_greedy_search(C, combine=combine)):
            # exact DP is never beaten; a feasible heuristic answer
            # implies DP found one too
            assert (dp.cost_s <= heur.cost_s + 1e-12).all()
            assert (dp.feasible | ~heur.feasible).all()

    @given(C=dense_tensors(), combine=st.sampled_from(["sum", "max"]))
    @settings(max_examples=25, deadline=None)
    def test_reported_cost_matches_reported_splits(self, C, combine):
        """The (splits, cost) pair must be self-consistent: re-pricing
        the returned configuration reproduces the returned cost."""
        Sn, N, L, _ = C.shape
        res = SW.batched_optimal_dp(C, combine=combine)
        for s in range(Sn):
            if not res.feasible[s]:
                continue
            fn = scalar_fn(C[s])
            repriced = S.total_cost(fn, res.splits_tuple(s), L, combine)
            assert repriced == pytest.approx(float(res.cost_s[s]), rel=1e-12)

    @given(C=dense_tensors(), scale=st.floats(0.5, 4.0))
    @settings(max_examples=15, deadline=None)
    def test_uniform_scaling_preserves_argmin(self, C, scale):
        """Scaling every cost by a positive constant cannot move the
        argmin under sum-combine (metamorphic sanity check for the DP)."""
        a = SW.batched_optimal_dp(C, combine="sum")
        b = SW.batched_optimal_dp(np.where(np.isfinite(C), C * scale, INF),
                                  combine="sum")
        assert np.array_equal(a.feasible, b.feasible)
        assert np.array_equal(a.splits[a.feasible], b.splits[b.feasible])
