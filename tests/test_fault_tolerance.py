"""Fault-tolerance and runtime tests: checkpoint/restart exactness,
failure injection, straggler detection, gradient compression, data
determinism."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import MarkovLMData, SyntheticLMData
from repro.models.config import ModelConfig
from repro.runtime.compression import compress_grads, init_error_feedback, wire_bytes
from repro.runtime.train_loop import StepRecord, Trainer, TrainLoopConfig

CFG = ModelConfig("tiny", "dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                  d_ff=64, vocab=64, head_dim=8, dtype="float32", remat=False,
                  kv_chunk=16, pad_vocab_to=0)


def make_data(cfg=CFG, batch=4, seq=16, seed=0):
    return SyntheticLMData(cfg, global_batch=batch, seq_len=seq, seed=seed)


class TestDataPipeline:
    def test_deterministic_and_index_addressable(self):
        d1, d2 = make_data(seed=7), make_data(seed=7)
        b1, b2 = d1.batch_at(13), d2.batch_at(13)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_different_steps_differ(self):
        d = make_data()
        assert not np.array_equal(d.batch_at(0)["tokens"], d.batch_at(1)["tokens"])

    def test_labels_are_shifted_tokens(self):
        b = make_data().batch_at(0)
        # tokens/labels come from a single (S+1) stream
        assert b["tokens"].shape == b["labels"].shape

    def test_microbatched_layout(self):
        cfg = ModelConfig(**{**CFG.__dict__, "train_microbatches": 2,
                             "name": "mb", "block_pattern": None})
        d = SyntheticLMData(cfg, global_batch=4, seq_len=8)
        b = d.batch_at(0)
        assert b["tokens"].shape == (2, 2, 8)

    def test_markov_stream_is_learnable_structure(self):
        cfg = CFG
        d = MarkovLMData(cfg, global_batch=2, seq_len=32, branch=2)
        b = d.batch_at(0)
        assert b["tokens"].shape == (2, 32)
        # successor sets are constrained: with branch=2, consecutive-token
        # pairs repeat far more than uniform chance
        toks = np.asarray(d.batch_at(1)["tokens"]).ravel()
        pairs = set(zip(toks[:-1], toks[1:]))
        assert len(pairs) < 0.9 * (len(toks) - 1) or len(toks) < 40


class TestCheckpointStore:
    def test_save_restore_roundtrip(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"a": jnp.arange(5, dtype=jnp.float32), "b": {"c": jnp.ones((2, 2))}}
        store.save(3, tree, extra={"next_step": 3})
        restored, extra = store.restore(tree)
        np.testing.assert_array_equal(restored["a"], tree["a"])
        assert extra["next_step"] == 3

    def test_retention(self, tmp_path):
        store = CheckpointStore(tmp_path, keep=2)
        tree = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            store.save(s, tree)
        assert store.steps() == [3, 4]

    def test_corruption_detected(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"x": jnp.arange(10.0)}
        path = store.save(1, tree)
        shard = path / "shard_0.npz"
        data = bytearray(shard.read_bytes())
        data[len(data) // 2] ^= 0xFF
        shard.write_bytes(bytes(data))
        with pytest.raises(IOError):
            store.restore(tree)

    def test_async_save(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"x": jnp.arange(100.0)}
        store.save_async(5, tree, extra={"next_step": 5})
        store.wait()
        restored, _ = store.restore(tree)
        np.testing.assert_array_equal(restored["x"], tree["x"])


class TestTrainerFaultTolerance:
    def test_loss_decreases_on_markov_data(self, tmp_path):
        data = MarkovLMData(CFG, global_batch=8, seq_len=32, branch=2)
        t = Trainer(CFG, data, CheckpointStore(tmp_path),
                    TrainLoopConfig(total_steps=30, ckpt_every=50))
        hist = t.run()
        first = np.mean([r.loss for r in hist[:5]])
        last = np.mean([r.loss for r in hist[-5:]])
        assert last < first - 0.1, (first, last)

    def test_crash_and_exact_resume(self, tmp_path):
        """Kill the run at step 12; a resumed trainer must produce the
        exact same losses as an uninterrupted run (checkpoint + replayable
        data = bitwise restart)."""
        data = make_data(batch=4, seq=16)
        store_a = CheckpointStore(tmp_path / "a")
        ref = Trainer(CFG, data, store_a, TrainLoopConfig(total_steps=20, ckpt_every=5))
        ref_hist = ref.run()

        store_b = CheckpointStore(tmp_path / "b")

        class Boom(RuntimeError):
            pass

        def fail_at_12(step):
            if step == 12:
                raise Boom()

        crashing = Trainer(CFG, data, store_b,
                           TrainLoopConfig(total_steps=20, ckpt_every=5),
                           failure_hook=fail_at_12)
        with pytest.raises(Boom):
            crashing.run()
        assert store_b.latest_step() == 10  # last periodic checkpoint survived

        resumed = Trainer(CFG, data, store_b,
                          TrainLoopConfig(total_steps=20, ckpt_every=5))
        res_hist = resumed.run()
        assert res_hist[0].step == 10
        ref_tail = {r.step: r.loss for r in ref_hist if r.step >= 10}
        for r in res_hist:
            assert math.isclose(r.loss, ref_tail[r.step], rel_tol=1e-5), (
                r.step, r.loss, ref_tail[r.step])

    def test_straggler_detection_fires(self, tmp_path):
        data = make_data()
        seen = []
        t = Trainer(CFG, data, CheckpointStore(tmp_path),
                    TrainLoopConfig(total_steps=6, ckpt_every=100,
                                    step_deadline_s=0.0),  # everything is late
                    straggler_hook=seen.append)
        t.run()
        assert len(seen) >= 5
        assert all(isinstance(r, StepRecord) and r.straggler for r in seen)

    def test_grad_compression_still_learns(self, tmp_path):
        data = MarkovLMData(CFG, global_batch=8, seq_len=32, branch=2)
        t = Trainer(CFG, data, CheckpointStore(tmp_path),
                    TrainLoopConfig(total_steps=30, ckpt_every=50,
                                    grad_compression=True))
        hist = t.run()
        first = np.mean([r.loss for r in hist[:5]])
        last = np.mean([r.loss for r in hist[-5:]])
        assert last < first - 0.1, (first, last)


class TestGradCompression:
    def test_error_feedback_reduces_bias(self):
        """With EF, the sum of compressed grads over steps tracks the true
        sum (residual is carried, not dropped)."""
        rng = jax.random.PRNGKey(0)
        g_true = [jax.random.normal(jax.random.fold_in(rng, i), (64,)) * 0.1
                  for i in range(20)]
        ef = {"g": jnp.zeros((64,))}
        total_comp = jnp.zeros((64,))
        for g in g_true:
            out, ef = compress_grads({"g": g}, ef)
            total_comp += out["g"]
        total_true = sum(g_true)
        # compressed sum within one final-residual of the true sum
        resid = jnp.max(jnp.abs(total_comp + ef["g"] - total_true))
        assert float(resid) < 1e-4

    def test_wire_savings_4x(self):
        grads = {"w": jnp.zeros((128, 128)), "b": jnp.zeros((128,))}
        comp, raw = wire_bytes(grads)
        assert raw / comp > 3.9
