"""Paper-fidelity tests: the cost model must reproduce the paper's own
measurements (Tables II-IV) and the qualitative claims of Figs. 3-4."""

import math

import pytest

from repro.core.latency import rtt_breakdown
from repro.core.planner import compare_solvers, plan_split
from repro.core.profiles import (
    ESP32,
    MBV2_PART1_INFER_S,
    MBV2_PART2_INFER_S,
    PROTOCOLS,
    mobilenet_cost_profile,
    paper_cost_model,
    resnet50_cost_profile,
)
from repro.models.graph import mobilenet_v2_graph, resnet50_graph

# Activation byte sizes at the paper's three split points (int8).
ACT_BYTES = {
    "block_2_expand": 56 * 56 * 48,  # 150528
    "block_15_project_BN": 7 * 7 * 56,  # 2744
    "block_16_project_BN": 7 * 7 * 112,  # 5488
}

# Table II ground truth: protocol -> split -> (latency_ms, n_packets)
TABLE2 = {
    "udp": {"block_2_expand": (83.9, 104), "block_15_project_BN": (1.4, 2),
            "block_16_project_BN": (3.2, 4)},
    "tcp": {"block_2_expand": (563.3, 104), "block_15_project_BN": (8.5, 2),
            "block_16_project_BN": (19.3, 4)},
    "esp_now": {"block_2_expand": (1897.0, 603), "block_15_project_BN": (34.6, 11),
                "block_16_project_BN": (69.2, 22)},
    "ble": {"block_15_project_BN": (148.9, None), "block_16_project_BN": (272.9, 11)},
}

# Table IV ground truth (seconds).
TABLE4_RTT = {"udp": 5.8000, "tcp": 6.2022, "esp_now": 3.662, "ble": 10.44355}


class TestGraphShapes:
    def test_mbv2_split_point_shapes(self):
        g = mobilenet_v2_graph(0.35, 224)
        for name, want in ACT_BYTES.items():
            assert g.nodes[g.node_index(name) - 1].out_elems == want

    def test_mbv2_parameter_count(self):
        """MobileNet-V2 x0.35 has ~1.66 M params (public model card)."""
        g = mobilenet_v2_graph(0.35, 224)
        assert 1.5e6 < g.total_params < 1.8e6

    def test_resnet50_parameter_count(self):
        g = resnet50_graph(224)
        assert 25.0e6 < g.total_params < 26.5e6

    def test_mbv2_flops(self):
        """~59 M MACs = ~118 M FLOPs at 224x224 (public model card)."""
        g = mobilenet_v2_graph(0.35, 224)
        assert 1.0e8 < g.total_flops < 1.4e8


class TestTable2:
    @pytest.mark.parametrize("protocol", ["udp", "tcp", "esp_now"])
    def test_packet_counts_exact(self, protocol):
        link = PROTOCOLS[protocol]
        for split, (_, n_packets) in TABLE2[protocol].items():
            assert link.packets(ACT_BYTES[split]) == n_packets

    def test_ble_block16_packets(self):
        # 5488 B / 512 B GATT MTU = 11 packets (Table II BLE block_16 row).
        assert PROTOCOLS["ble"].packets(ACT_BYTES["block_16_project_BN"]) == 11

    @pytest.mark.parametrize("protocol,tol", [("udp", 0.25), ("tcp", 0.15),
                                              ("esp_now", 0.01), ("ble", 0.10)])
    def test_transmission_latency(self, protocol, tol):
        """Modeled Eq. 7 latency within tolerance of Table II at the two
        consistent split points (block_2 rows are buffer-stall anomalies
        the paper itself flags; ESP-NOW block_2 is consistent and exact)."""
        link = PROTOCOLS[protocol]
        rows = TABLE2[protocol]
        for split in ("block_15_project_BN", "block_16_project_BN"):
            want_ms = rows[split][0]
            got_ms = link.transmission_latency_s(ACT_BYTES[split]) * 1e3
            assert got_ms == pytest.approx(want_ms, rel=tol)

    def test_espnow_block2_near_exact(self):
        got = PROTOCOLS["esp_now"].transmission_latency_s(ACT_BYTES["block_2_expand"]) * 1e3
        assert got == pytest.approx(1897.0, rel=0.01)


class TestTable3:
    def test_inference_split_calibration(self):
        """Device-local inference at the block_16_project_BN split matches
        Table III: 3053.75 ms on device 1, 437 ms on device 2."""
        prof = mobilenet_cost_profile()
        idx = next(i for i, lc in enumerate(prof.layers) if lc.name == "block_16_project_BN") + 1
        part1 = sum(lc.t_infer_s for lc in prof.layers[:idx])
        part2 = sum(lc.t_infer_s for lc in prof.layers[idx:])
        assert part1 == pytest.approx(MBV2_PART1_INFER_S, rel=1e-6)
        assert part2 == pytest.approx(MBV2_PART2_INFER_S, rel=1e-6)

    def test_esp32_memory_feasibility(self):
        """The whole MobileNet fits the ESP32 budget; whole ResNet50 does
        not (int8 25.6 MB > 8.5 MB) — the Fig. 3 infeasibility mechanism."""
        mb = mobilenet_cost_profile()
        rn = resnet50_cost_profile()
        assert ESP32.local_latency_s(1.0, mb.segment_param_bytes(1, mb.num_layers), 0,
                                     mb.segment_work_bytes(1, mb.num_layers)) < math.inf
        assert ESP32.local_latency_s(1.0, rn.segment_param_bytes(1, rn.num_layers), 0,
                                     rn.segment_work_bytes(1, rn.num_layers)) == math.inf


class TestTable4:
    @pytest.mark.parametrize("protocol", list(TABLE4_RTT))
    def test_rtt_within_3pct(self, protocol):
        """End-to-end RTT (Eq. 8 + setup + feedback) reproduces Table IV."""
        m = paper_cost_model("mobilenet_v2", protocol)
        split_idx = next(
            i for i, lc in enumerate(m.profile.layers) if lc.name == "block_16_project_BN"
        ) + 1
        br = rtt_breakdown(m, (split_idx,))
        assert br.rtt_s == pytest.approx(TABLE4_RTT[protocol], rel=0.03)

    def test_espnow_best_rtt(self):
        """Paper's headline: ESP-NOW achieves the best RTT (3.6 s)."""
        rtts = {}
        for p in PROTOCOLS:
            m = paper_cost_model("mobilenet_v2", p)
            idx = next(i for i, lc in enumerate(m.profile.layers)
                       if lc.name == "block_16_project_BN") + 1
            rtts[p] = rtt_breakdown(m, (idx,)).rtt_s
        assert min(rtts, key=rtts.get) == "esp_now"
        assert max(rtts, key=rtts.get) == "ble"


class TestFig3Fig4:
    """Qualitative claims of the heuristic comparison figures."""

    @pytest.mark.parametrize("n_devices", [2, 3, 4, 5])
    def test_beam_at_most_greedy_at_most_firstfit_trend(self, n_devices):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        plans = compare_solvers(m, n_devices, solvers=("beam", "greedy", "first_fit"))
        assert plans["beam"].total_latency_s <= plans["greedy"].total_latency_s + 1e-9

    @pytest.mark.parametrize("n_devices", [2, 3, 4])
    def test_beam_matches_brute_force_within_5pct(self, n_devices):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        beam = plan_split(m, n_devices, solver="beam", beam_width=8)
        brute = plan_split(m, n_devices, solver="brute_force")
        assert beam.total_latency_s <= brute.total_latency_s * 1.05

    def test_beam_planner_under_quarter_second_at_5_devices(self):
        """Paper: ~0.1 s processing for 5 devices; we bound at 0.25 s."""
        m = paper_cost_model("mobilenet_v2", "esp_now")
        plan = plan_split(m, 5, solver="beam", beam_width=8)
        assert plan.planner_time_s < 0.25

    def test_beam_beats_random_fit_at_6_devices(self):
        """Paper: >600% latency reduction vs Random-Fit at 6 devices.
        Random placement on ESP-NOW ships huge early activations; we
        assert a conservative >=1.3x gap (seeded random draw)."""
        m = paper_cost_model("mobilenet_v2", "esp_now")
        beam = plan_split(m, 6, solver="beam", beam_width=8)
        rand = plan_split(m, 6, solver="random_fit", seed=1)
        assert rand.total_latency_s >= 1.3 * beam.total_latency_s

    def test_resnet50_has_infeasible_configs(self):
        """Fig. 3: ResNet50 latency fluctuates because some segments cannot
        run on a node (memory). Random splits should often be infeasible."""
        m = paper_cost_model("resnet50", "esp_now")
        # N=3 is genuinely infeasible: 25.5 MB int8 across 3x8.5 MB devices
        assert plan_split(m, 3, solver="optimal_dp").total_latency_s == math.inf
        infeasible = 0
        for seed in range(8):
            p = plan_split(m, 4, solver="random_fit", seed=seed)
            if p.total_latency_s == math.inf:
                infeasible += 1
        assert infeasible >= 1
        # while the planner still finds a feasible split (needs the
        # beyond-paper feasibility lookahead; vanilla Alg. 1 dead-ends)
        assert plan_split(m, 4, solver="beam").total_latency_s < math.inf
        assert plan_split(m, 4, solver="first_fit").total_latency_s < math.inf
