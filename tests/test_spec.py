"""Planner-tier contract tests: PlanSpec serialization + spec≡kwargs parity.

Three families pin the PR-10 contract:

* **Round-trip exactness** — ``PlanSpec.to_json``/``from_json`` is a
  field-exact bijection: finite floats bit-for-bit (``repr`` round-trip),
  non-finite floats through explicit tags (the payload itself stays
  strict, NaN-free JSON), tuples stay tuples, ``None`` loss entries stay
  ``None``, and every registered nested dataclass (cost model, variant
  bank, mesh) reconstructs ``==``-equal. Pickle round-trips too — the
  process-boundary contract.

* **Spec-path ≡ kwargs-path** — every public planning entry point is a
  shim that builds a spec and resolves it through ``PlannerService``;
  these tests call BOTH paths (and the retained ``_impl`` directly) and
  assert bitwise-identical results across all four ``DP_BACKENDS`` for
  the DP and both numpy-only solvers, plus multi-channel, variant-bank,
  cost-model-batch and surface-family solves.

* **Process boundary** — a spec serialized to JSON, shipped to a
  subprocess (spawn, so the child proves importability from scratch)
  and solved there returns bitwise-identical results; a
  ``ProcessPoolExecutor``-backed ``SurfaceRebuilder`` adopts a rebuilt
  surface node-identical to the synchronous build, with zero stale
  adoptions, end-to-end through ``FleetGateway``.
"""

import math
import multiprocessing as mp
import pickle
import time
from concurrent.futures import ProcessPoolExecutor

import numpy as np
import pytest

from repro.core import planner as PL
from repro.core import sweep as SW
from repro.core.latency import COST_CHANNELS
from repro.core.profiles import (
    ESP_NOW,
    PROTOCOLS,
    esp32_variant_bank,
    paper_cost_model,
)
from repro.core.spec import (
    MeshSpec,
    PlannerService,
    PlanSpec,
    ScenarioRef,
    SurfaceAxes,
    build_surfaces_from_spec,
    channels_spec,
    models_spec,
    solve_from_json,
    surfaces_spec,
    tensor_spec,
    variant_bank_spec,
)
from repro.runtime.gateway import FleetGateway

INF = float("inf")
GRID = {"pt_scale": (1.0, 4.0, 16.0), "loss_p": (0.0, 0.1)}
NBYTES = 5488


def rand_tensor(rng, S=5, N=3, L=6, inf_frac=0.1):
    """Random stacked cost tensor with the solver's invalid-entry
    convention (+inf outside 1 <= a <= b <= L) plus some infeasible
    valid entries."""
    C = rng.uniform(0.1, 9.0, size=(S, N, L, L))
    mask = rng.uniform(size=C.shape) < inf_frac
    C[mask] = INF
    a = np.arange(1, L + 1)
    invalid = a[:, None] > a[None, :]
    C[:, :, invalid] = INF
    return C


def assert_results_identical(a, b):
    assert a.solver == b.solver and a.backend == b.backend
    assert a.n_devices == b.n_devices
    assert np.array_equal(a.splits, b.splits)
    assert np.array_equal(a.cost_s, b.cost_s)
    assert np.array_equal(a.feasible, b.feasible)
    if a.n_devices_s is None:
        assert b.n_devices_s is None
    else:
        assert np.array_equal(a.n_devices_s, b.n_devices_s)
    if a.channel_cost_s is None:
        assert b.channel_cost_s is None
    else:
        assert a.channels == b.channels
        assert np.array_equal(a.channel_cost_s, b.channel_cost_s)
    if a.variant is None:
        assert b.variant is None
    else:
        assert np.array_equal(a.variant, b.variant)


def assert_surfaces_identical(a, b):
    assert sorted(a.protocols) == sorted(b.protocols)
    for name in a.protocols:
        pa, pb = a.protocols[name], b.protocols[name]
        assert pa.packet_time_s == pb.packet_time_s, name
        assert pa.loss_p == pb.loss_p, name
        assert np.array_equal(pa.splits, pb.splits), name
        assert np.array_equal(pa.chunk_bytes, pb.chunk_bytes), name
        assert np.array_equal(pa.latency_s, pb.latency_s), name
        assert np.array_equal(pa.runner_splits, pb.runner_splits), name
        assert np.array_equal(pa.runner_latency_s, pb.runner_latency_s), name


def rich_spec():
    """A spec exercising every field family: nested cost model, protocol
    pairs, variant bank, non-finite budget, awkward floats, mesh."""
    return surfaces_spec(
        paper_cost_model("mobilenet_v2", "esp_now"),
        PROTOCOLS, (2, 3, 5),
        pt_scale=(1.0, 0.1 + 0.2, 16.0),
        loss_p=(None, 0.0, 0.1),
        beam_width=6,
        chunk_candidates=(256, 1024),
        energy_budget=INF,
        variants=esp32_variant_bank(),
        accuracy_floor=0.9,
        mesh=MeshSpec(kind="local", n_shards=2),
    )


class TestRoundTrip:
    def test_rich_spec_json_round_trip_field_exact(self):
        spec = rich_spec()
        again = PlanSpec.from_json(spec.to_json())
        assert again == spec  # dataclass eq: every field, nested, exact
        # and the payload is strict JSON despite the inf budget
        assert "Infinity" not in spec.to_json()
        assert "NaN" not in spec.to_json()

    def test_awkward_floats_survive_bitwise(self):
        spec = PlanSpec(energy_budget=(0.1 + 0.2, 1e-308, INF, -INF),
                        accuracy_floor=1.0 / 3.0)
        again = PlanSpec.from_json(spec.to_json())
        for got, want in zip(again.energy_budget, spec.energy_budget):
            assert got == want and type(got) is float
        assert again.accuracy_floor == spec.accuracy_floor

    def test_nan_round_trips_as_nan(self):
        spec = PlanSpec(accuracy_floor=float("nan"))
        again = PlanSpec.from_json(spec.to_json())
        assert math.isnan(again.accuracy_floor)

    def test_bare_json_constants_rejected(self):
        with pytest.raises(ValueError, match="non-strict JSON constant"):
            PlanSpec.from_json('{"__type__": "PlanSpec", '
                               '"accuracy_floor": Infinity}')

    def test_unknown_type_tag_rejected(self):
        with pytest.raises(ValueError, match="unknown PlanSpec type tag"):
            PlanSpec.from_json('{"__type__": "os_system"}')

    def test_payload_must_decode_to_planspec(self):
        with pytest.raises(ValueError, match="not PlanSpec"):
            PlanSpec.from_json('{"__type__": "MeshSpec"}')

    def test_none_loss_entries_and_tuples_preserved(self):
        spec = rich_spec()
        again = PlanSpec.from_json(spec.to_json())
        assert again.surface.loss_p == (None, 0.0, 0.1)
        assert isinstance(again.surface.pt_scale, tuple)
        assert isinstance(again.protocols, tuple)
        assert isinstance(again.protocols[0], tuple)
        assert again.variants == esp32_variant_bank()

    def test_pickle_round_trip(self):
        spec = rich_spec()
        assert pickle.loads(pickle.dumps(spec)) == spec

    def test_scenario_and_mesh_validation(self):
        with pytest.raises(ValueError, match="unknown scenario kind"):
            ScenarioRef(kind="wat")
        with pytest.raises(ValueError, match="unknown mesh kind"):
            MeshSpec(kind="wat")

    def test_solver_options_order_insensitive(self):
        a = tensor_spec(np.zeros((1, 2, 3, 3)), beam_width=4, return_all_k=False)
        b = tensor_spec(np.zeros((1, 2, 3, 3)), return_all_k=False, beam_width=4)
        assert a == b
        assert a.options() == {"beam_width": 4, "return_all_k": False}


class TestSpecKwargsParity:
    """The shim path, the explicit spec path, and the retained _impl
    must agree bitwise — they ARE the same code by construction; these
    tests keep it that way."""

    @pytest.mark.parametrize("backend", sorted(SW.DP_BACKENDS))
    @pytest.mark.parametrize("combine", ["sum", "max"])
    def test_batched_dp_parity_all_backends(self, backend, combine):
        rng = np.random.default_rng(7)
        C = rand_tensor(rng)
        n = (2, 3, 2, 3, 2)
        via_kwargs = SW.solve_batched(C, solver="batched_dp",
                                      combine=combine, backend=backend,
                                      n_devices=n)
        spec = tensor_spec(C, solver="batched_dp", combine=combine,
                           backend=backend, n_devices=n)
        via_spec = PlannerService().solve(spec, C)
        via_impl = SW._solve_batched_impl(C, solver="batched_dp",
                                          combine=combine, backend=backend,
                                          n_devices=spec.n_devices)
        assert_results_identical(via_kwargs, via_spec)
        assert_results_identical(via_kwargs, via_impl)

    @pytest.mark.parametrize("solver", ["batched_beam", "batched_greedy"])
    def test_beam_and_greedy_parity(self, solver):
        rng = np.random.default_rng(11)
        C = rand_tensor(rng)
        kw = {"beam_width": 3} if solver == "batched_beam" else {}
        via_kwargs = SW.solve_batched(C, solver=solver, **kw)
        spec = tensor_spec(C, solver=solver, **kw)
        via_spec = PlannerService().solve(spec, C)
        assert_results_identical(via_kwargs, via_spec)

    def test_spec_survives_json_and_still_solves_identically(self):
        rng = np.random.default_rng(13)
        C = rand_tensor(rng)
        spec = tensor_spec(C, combine="max", n_devices=3)
        direct = PlannerService().solve(spec, C)
        rehydrated = PlannerService().solve(
            PlanSpec.from_json(spec.to_json()), C)
        assert_results_identical(direct, rehydrated)

    def test_multi_channel_parity(self):
        rng = np.random.default_rng(17)
        S, N, L = 4, 3, 5
        C = np.stack([rand_tensor(rng, S=S, N=N, L=L)
                      for _ in COST_CHANNELS])
        kwargs = dict(energy_budget=20.0, channel_weights=(1.0, 0.25))
        via_kwargs = SW.solve_multi_channel(C, **kwargs)
        spec = channels_spec(C, **kwargs)
        via_spec = PlannerService().solve_multi_channel(spec, C)
        assert_results_identical(via_kwargs, via_spec)

    def test_variant_bank_parity(self):
        rng = np.random.default_rng(19)
        V = 3
        C = np.stack([rand_tensor(rng) for _ in range(V)])
        kwargs = dict(accuracy_proxy=(1.0, 0.95, 0.85), accuracy_floor=0.9)
        via_kwargs = SW.solve_variant_bank(C, **kwargs)
        spec = variant_bank_spec(C, **kwargs)
        via_spec = PlannerService().solve_variant_bank(spec, C)
        assert_results_identical(via_kwargs, via_spec)

    def test_plan_split_batch_parity(self):
        models = [paper_cost_model("mobilenet_v2", p)
                  for p in ("esp_now", "ble")]
        via_kwargs = PL.plan_split_batch(models, (2, 3))
        spec = models_spec(models, n_devices=(2, 3))
        via_spec = PlannerService().plan(spec, models)
        for a, b in zip(via_kwargs, via_spec):
            assert a.splits == b.splits
            assert a.segments == b.segments
            assert a.total_latency_s == b.total_latency_s
            assert a.objective_cost_s == b.objective_cost_s
            assert (a.variant, a.accuracy_proxy) == (b.variant,
                                                     b.accuracy_proxy)

    def test_build_surfaces_parity(self):
        from repro.core.surface import build_surfaces

        model = paper_cost_model("mobilenet_v2", "esp_now")
        via_kwargs = build_surfaces(model, PROTOCOLS, (2, 3), **GRID)
        spec = surfaces_spec(model, PROTOCOLS, (2, 3), **GRID)
        via_spec = PlannerService().build_surfaces(spec)
        assert sorted(via_kwargs) == sorted(via_spec) == [2, 3]
        for n in via_kwargs:
            assert_surfaces_identical(via_kwargs[n], via_spec[n])
        # and the process-boundary worker is the same call again
        via_worker = build_surfaces_from_spec(spec.to_json())
        for n in via_kwargs:
            assert_surfaces_identical(via_kwargs[n], via_worker[n])

    def test_operand_validation(self):
        C = np.zeros((2, 2, 4, 4))
        spec = tensor_spec(C)
        with pytest.raises(ValueError, match="shape"):
            PlannerService().solve(spec, np.zeros((2, 2, 5, 5)))
        with pytest.raises(ValueError, match="kind"):
            PlannerService().solve_multi_channel(spec, C)
        with pytest.raises(ValueError, match="needs n_devices"):
            PlannerService().plan(
                models_spec([], n_devices=None), [])

    def test_mesh_spec_requires_sharded_backend(self):
        C = rand_tensor(np.random.default_rng(23))
        with pytest.raises(ValueError, match="backend='sharded' knob"):
            SW.solve_batched(C, mesh_spec=MeshSpec())
        with pytest.raises(ValueError, match="numpy only"):
            SW.solve_batched(C, solver="batched_beam", backend="numpy",
                             mesh_spec=MeshSpec())

    def test_local_mesh_spec_node_identical_to_default_sharded(self):
        C = rand_tensor(np.random.default_rng(29))
        plain = SW.solve_batched(C, backend="sharded")
        meshed = SW.solve_batched(C, backend="sharded",
                                  mesh_spec=MeshSpec(kind="local"))
        assert_results_identical(plain, meshed)


class TestManagersRouteThroughSpec:
    def test_adaptive_surface_spec_reproduces_auto_surface(self):
        from repro.core.adaptive import AdaptiveSplitManager

        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2,
            solver="optimal_dp", surface_grid=GRID)
        spec = mgr.surface_spec()
        assert spec.scenario.kind == "surface"
        rebuilt = PlannerService().build_surfaces(spec)[2]
        assert_surfaces_identical(mgr.surface, rebuilt)

    def test_gateway_plan_spec_reproduces_family(self):
        gw = FleetGateway(paper_cost_model("mobilenet_v2", "esp_now"),
                          PROTOCOLS, (2, 3), surface_grid=GRID)
        # the gateway's own family came FROM this spec; a JSON round
        # trip of it rebuilds the identical family
        again = build_surfaces_from_spec(gw.plan_spec.to_json())
        assert sorted(again) == sorted(gw.surfaces)
        for n in gw.surfaces:
            assert_surfaces_identical(gw.surfaces[n], again[n])


def _spawn_pool(workers=1):
    return ProcessPoolExecutor(max_workers=workers,
                               mp_context=mp.get_context("spawn"))


class TestProcessBoundary:
    def test_subprocess_solve_bitwise_identical(self):
        rng = np.random.default_rng(31)
        C = rand_tensor(rng)
        spec = tensor_spec(C, combine="max", n_devices=(2, 3, 2, 3, 2))
        local = PlannerService().solve(spec, C)
        with _spawn_pool() as pool:
            remote = pool.submit(solve_from_json, spec.to_json(), C).result()
        assert_results_identical(local, remote)

    def test_process_pool_rebuild_through_gateway(self):
        """End-to-end: a gateway whose rebuilder runs on a process pool
        adopts a rebuilt surface node-identical to the synchronous
        build, with zero stale adoptions."""
        pool = _spawn_pool()
        gw = FleetGateway(paper_cost_model("mobilenet_v2", "esp_now"),
                          PROTOCOLS, (2, 3), surface_grid=GRID,
                          executor=pool)
        try:
            pt = 24.0 * ESP_NOW.transmission_latency_s(NBYTES)
            states = {name: (pt, 0.05) for name in PROTOCOLS}
            assert gw.rebuilder.request(2, states) == "queued"
            handle = gw.fanout.view()
            got = None
            deadline = time.monotonic() + 120.0
            while got is None and time.monotonic() < deadline:
                got = handle.poll(2)  # first poll launches on the pool
                if got is None:
                    time.sleep(0.05)
            assert got is not None, "process-pool rebuild never adopted"
            req = gw.rebuilder.last_request
            assert_surfaces_identical(got, gw.rebuilder.build_sync(req)[2])
            assert gw.rebuilder.builds_completed == 1
            # zero stale adoptions: generations strictly increase
            gens = [g for (n, g) in handle.adoptions if n == 2]
            assert gens == sorted(set(gens))
        finally:
            gw.rebuilder.shutdown()
            pool.shutdown(wait=True)
