"""Degradation-surface tests: construction parity with the exact
re-solve path, switch-point extraction, bilinear interpolation, envelope
fallback, and the trace-replay oracle-equivalence contract."""

import math
from dataclasses import replace

import pytest

from repro.core.adaptive import (
    AdaptiveSplitManager,
    LinkEstimator,
    fleet_managers,
    surface_parity_report,
)
from repro.core.latency import (
    DeviceProfile,
    LayerCost,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
)
from repro.core.planner import plan_surface
from repro.core.profiles import ESP_NOW, PROTOCOLS, paper_cost_model
from repro.core.surface import (
    DegradationSurface,
    build_surface,
    build_surfaces,
    refit_link,
)
from repro.core.sweep import ScenarioGrid


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------


def switchy_cost_model() -> SplitCostModel:
    """A 3-layer model engineered so the optimal 2-device cut moves with
    the packet time: cutting after layer 1 sends 2 packets but avoids
    duplicating the big working set across devices; cutting after layer
    2 sends 1 packet but pays the working-set duplication. Cheap links
    prefer the local saving, degraded links the packet saving."""
    layers = (
        LayerCost("l1", t_infer_s=0.01, act_bytes=1500, param_bytes=100,
                  work_bytes=0),
        LayerCost("l2", t_infer_s=0.01, act_bytes=100, param_bytes=100,
                  work_bytes=10_000),
        LayerCost("l3", t_infer_s=0.01, act_bytes=0, param_bytes=100,
                  work_bytes=10_000),
    )
    prof = ModelCostProfile("switchy", layers)
    dev = DeviceProfile("d", tensor_alloc_s_per_byte=1e-6)
    link = LinkProfile("lk", mtu_bytes=1000, rate_bytes_per_s=1e6)
    return SplitCostModel(profile=prof, devices=(dev,), link=link)


SMALL_GRID = {"pt_scale": (1.0, 4.0, 16.0, 64.0, 256.0),
              "loss_p": (0.0, 0.1, 0.3)}


@pytest.fixture(scope="module")
def paper_surface_mgr():
    return AdaptiveSplitManager(
        cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
        protocols=dict(PROTOCOLS), n_devices=2, solver="optimal_dp",
        surface_grid=SMALL_GRID)


# ---------------------------------------------------------------------------
# Construction + structure
# ---------------------------------------------------------------------------


class TestBuildSurface:
    def test_axes_and_shapes(self):
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 8.0, 64.0), loss_p=(0.0, 0.2))
        ps = surf.protocols["lk"]
        assert ps.packet_time_s == tuple(
            m.link.packet_time_s() * s for s in (1.0, 8.0, 64.0))
        assert ps.loss_p == (0.0, 0.2)
        assert ps.splits.shape == (3, 2, 1)
        assert ps.latency_s.shape == (3, 2)
        assert surf.n_nodes == 6

    def test_nodes_are_feasible_and_priced(self):
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 64.0), loss_p=(0.0,))
        for i in range(2):
            node = surf.protocols["lk"].node(i, 0)
            assert node.feasible
            assert node.splits in ((1,), (2,))
            assert math.isfinite(node.latency_s)
            assert 0 < node.chunk_bytes <= m.link.mtu_bytes

    def test_runner_up_is_distinct_and_no_better(self):
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 4.0, 16.0, 64.0, 256.0),
                             loss_p=(0.0,))
        ps = surf.protocols["lk"]
        saw_runner = False
        for i in range(len(ps.packet_time_s)):
            best = tuple(int(x) for x in ps.splits[i, 0])
            runner = tuple(int(x) for x in ps.runner_splits[i, 0])
            if runner != (-1,):
                saw_runner = True
                assert runner != best
                assert ps.runner_latency_s[i, 0] >= ps.latency_s[i, 0]
        assert saw_runner  # the portfolio has >= 2 plans, so runner-ups exist

    def test_unknown_solver_rejected(self):
        m = switchy_cost_model()
        with pytest.raises(ValueError):
            build_surface(m, {"lk": m.link}, 2, solver="simplex")

    def test_planner_and_grid_exposure(self):
        m = switchy_cost_model()
        surf = plan_surface(m, {"lk": m.link}, 2, pt_scale=(1.0, 8.0),
                            loss_p=(0.0,))
        assert isinstance(surf, DegradationSurface)
        grid = ScenarioGrid(
            models={"switchy": m.profile}, links={"lk": m.link},
            n_devices=(2,), loss_p=(None, 0.1), rate_scale=(1.0, 0.25),
            devices=tuple(m.devices))
        surf2 = grid.degradation_surface()
        ps = surf2.protocols["lk"]
        # rate_scale 0.25 -> packet-time scale 4; loss axis {0.0, 0.1}
        assert ps.packet_time_s == tuple(
            m.link.packet_time_s() * s for s in (1.0, 4.0))
        assert ps.loss_p == (0.0, 0.1)
        assert surf2.n_devices == 2


# ---------------------------------------------------------------------------
# Multi-N families: one batched solve, every fleet size
# ---------------------------------------------------------------------------


def _assert_protocol_surfaces_equal(a, b, ctx=""):
    import numpy as np

    assert a.packet_time_s == b.packet_time_s, ctx
    assert a.loss_p == b.loss_p, ctx
    assert np.array_equal(a.splits, b.splits), ctx
    assert np.array_equal(a.chunk_bytes, b.chunk_bytes), ctx
    assert np.array_equal(a.latency_s, b.latency_s), ctx  # exact, incl +inf
    assert np.array_equal(a.runner_splits, b.runner_splits), ctx
    assert np.array_equal(a.runner_latency_s, b.runner_latency_s), ctx


FAMILY_GRID = {"pt_scale": (1.0, 8.0, 64.0), "loss_p": (0.0, 0.2)}


class TestMultiNSurfaceFamily:
    @pytest.mark.parametrize("solver",
                             ["batched_dp", "batched_beam", "batched_greedy"])
    def test_family_node_identical_to_single_builds(self, solver):
        """build_surfaces (ONE batched pass for all fleet sizes) must be
        node-for-node `==` to per-N build_surface calls — the multi-N
        extension of the bit-exactness contract."""
        m = switchy_cost_model()
        fam = build_surfaces(m, {"lk": m.link}, (1, 2, 3), solver=solver,
                             **FAMILY_GRID)
        assert sorted(fam) == [1, 2, 3]
        for n, surf in fam.items():
            assert surf.n_devices == n
            single = build_surface(m, {"lk": m.link}, n, solver=solver,
                                   **FAMILY_GRID)
            for name in surf.protocols:
                _assert_protocol_surfaces_equal(
                    surf.protocols[name], single.protocols[name],
                    ctx=f"{solver} n={n} {name}")

    def test_family_shares_one_solve(self):
        m = switchy_cost_model()
        fam = build_surfaces(m, {"lk": m.link}, (2, 3), **FAMILY_GRID)
        # one batched pass: every surface reports the SAME family wall
        assert fam[2].solve_time_s == fam[3].solve_time_s
        assert fam[2].build_time_s == fam[3].build_time_s

    def test_sizes_validated(self):
        m = switchy_cost_model()
        with pytest.raises(ValueError):
            build_surfaces(m, {"lk": m.link}, ())
        with pytest.raises(ValueError):
            build_surfaces(m, {"lk": m.link}, (2, 2))
        with pytest.raises(ValueError):
            build_surfaces(m, {"lk": m.link}, (0,))

    def test_grid_mix_errors_are_valueerrors(self):
        m = switchy_cost_model()
        plain = ScenarioGrid(models={"switchy": m.profile},
                             links={"lk": m.link}, n_devices=(2,),
                             devices=tuple(m.devices))
        with pytest.raises(ValueError, match="no device_mixes"):
            plain.degradation_surface(mix="gateway")
        mixed = ScenarioGrid(models={"switchy": m.profile},
                             links={"lk": m.link}, n_devices=(2,),
                             devices=tuple(m.devices),
                             device_mixes={"mx": tuple(m.devices)})
        with pytest.raises(ValueError, match="unknown device mix"):
            mixed.degradation_surface(mix="typo")
        # valid mix still works
        surf = mixed.degradation_surface(mix="mx")
        assert surf.n_devices == 2

    def test_grid_degradation_surfaces(self):
        m = switchy_cost_model()
        grid = ScenarioGrid(
            models={"switchy": m.profile}, links={"lk": m.link},
            n_devices=(2, 3), loss_p=(None, 0.1), rate_scale=(1.0, 0.25),
            devices=tuple(m.devices))
        fam = grid.degradation_surfaces()
        assert sorted(fam) == [2, 3]
        for n, surf in fam.items():
            single = grid.degradation_surface(n_devices=n)
            assert surf.n_devices == n
            for name in surf.protocols:
                _assert_protocol_surfaces_equal(
                    surf.protocols[name], single.protocols[name])

    def test_heterogeneous_devices_node_parity(self):
        """A per-position heterogeneous fleet (distinct DeviceProfiles
        per device) keeps the node-exact oracle-equivalence contract:
        the manager's surface matches its own exact re-solve at every
        node."""
        m = switchy_cost_model()
        hetero = replace(
            m, devices=(m.devices[0],
                        replace(m.devices[0], name="mid",
                                compute_scale=0.5),
                        replace(m.devices[0], name="srv",
                                compute_scale=0.05,
                                tensor_alloc_s_per_byte=0.0)))
        mgr = AdaptiveSplitManager(
            cost_model=hetero, protocols={"lk": m.link}, n_devices=3,
            solver="optimal_dp", surface_grid=FAMILY_GRID)
        assert surface_parity_report(mgr) == []

    def test_fleet_managers_one_pass_equals_auto(self):
        m = switchy_cost_model()
        mgrs = fleet_managers(m, {"lk": m.link}, (2, 3, 2),
                              solver="optimal_dp", surface_grid=FAMILY_GRID)
        assert sorted(mgrs) == [2, 3]
        for n, mgr in mgrs.items():
            auto = AdaptiveSplitManager(
                cost_model=m, protocols={"lk": m.link}, n_devices=n,
                solver="optimal_dp", surface_grid=FAMILY_GRID)
            for name in mgr.surface.protocols:
                _assert_protocol_surfaces_equal(
                    mgr.surface.protocols[name],
                    auto.surface.protocols[name], ctx=f"n={n}")
            assert mgr.current.splits == auto.current.splits
            assert surface_parity_report(mgr) == []

    def test_fleet_managers_rejects_scalar_only_solver(self):
        m = switchy_cost_model()
        with pytest.raises(ValueError):
            fleet_managers(m, {"lk": m.link}, (2,), solver="first_fit")


# ---------------------------------------------------------------------------
# Switch points
# ---------------------------------------------------------------------------


class TestSwitchPoints:
    def test_plan_switches_with_packet_time(self):
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 4.0, 16.0, 64.0, 256.0),
                             loss_p=(0.0,))
        ps = surf.protocols["lk"]
        cheap = tuple(int(x) for x in ps.splits[0, 0])
        degraded = tuple(int(x) for x in ps.splits[-1, 0])
        assert cheap == (1,)  # cheap link: avoid the work-set duplication
        assert degraded == (2,)  # degraded link: minimize packets
        sps = surf.switch_points("lk")
        assert len(sps) >= 1
        sp = sps[0]
        assert sp.axis == "packet_time_s"
        assert sp.plan_lo == (1,) and sp.plan_hi == (2,)
        assert ps.packet_time_s[0] <= sp.lo < sp.hi <= ps.packet_time_s[-1]

    def test_constant_plan_has_no_switch_points(self, paper_surface_mgr):
        # on the calibrated MobileNet the min-activation cut dominates the
        # whole envelope, so the surface must NOT invent boundaries
        surf = paper_surface_mgr.surface
        for name in surf.protocols:
            plans = {tuple(int(x) for x in surf.protocols[name].splits[i, j])
                     for i in range(len(surf.protocols[name].packet_time_s))
                     for j in range(len(surf.protocols[name].loss_p))}
            if len(plans) == 1:
                assert surf.switch_points(name) == []


# ---------------------------------------------------------------------------
# Lookup + interpolation
# ---------------------------------------------------------------------------


class TestLookupInterpolation:
    def test_node_lookup_is_exact(self):
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 8.0, 64.0), loss_p=(0.0, 0.2))
        ps = surf.protocols["lk"]
        for i, pt in enumerate(ps.packet_time_s):
            for j, lp in enumerate(ps.loss_p):
                hit = surf.lookup("lk", pt, lp)
                node = ps.node(i, j)
                assert hit.splits == node.splits
                assert hit.chunk_bytes == node.chunk_bytes
                assert hit.latency_s == node.latency_s  # bitwise, not approx
                assert hit.in_envelope

    def test_bilinear_midpoint_and_bounds(self):
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 3.0), loss_p=(0.0, 0.2))
        ps = surf.protocols["lk"]
        (p0, p1), (l0, l1) = (ps.packet_time_s, ps.loss_p)
        corners = [float(ps.latency_s[i, j]) for i in (0, 1) for j in (0, 1)]
        mid = surf.latency_at("lk", (p0 + p1) / 2, (l0 + l1) / 2)
        assert mid == pytest.approx(sum(corners) / 4)
        assert min(corners) - 1e-12 <= mid <= max(corners) + 1e-12
        # interpolation along one axis only
        edge = surf.latency_at("lk", (p0 + p1) / 2, l0)
        assert edge == pytest.approx(
            (float(ps.latency_s[0, 0]) + float(ps.latency_s[1, 0])) / 2)

    def test_same_plan_cell_interpolation_is_exact(self):
        """Within a cell whose corners share a plan, latency is affine in
        the packet time, so linear interpolation reproduces the exact
        re-solve latency (the interpolation-error contract's best case)."""
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(64.0, 256.0), loss_p=(0.0,))
        ps = surf.protocols["lk"]
        # the deep-degradation cell (the axis also contains the saturation
        # floor below the requested scales); both corners hold one plan
        assert tuple(ps.splits[-2, 0]) == tuple(ps.splits[-1, 0])
        pt = (ps.packet_time_s[-2] + ps.packet_time_s[-1]) / 2
        hit = surf.lookup("lk", pt, 0.0)
        link = refit_link(m.link, pt, 0.0)
        exact = replace(m, link=replace(link, mtu_bytes=hit.chunk_bytes)) \
            .end_to_end_s(hit.splits)
        assert hit.latency_s == pytest.approx(exact, rel=1e-12)

    def test_out_of_envelope_flagged(self):
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 8.0), loss_p=(0.0, 0.2))
        pt_hi = surf.protocols["lk"].packet_time_s[-1]
        assert not surf.lookup("lk", pt_hi * 2, 0.0).in_envelope
        assert not surf.lookup("lk", pt_hi, 0.5).in_envelope
        assert not surf.in_envelope("lk", pt_hi, 0.5)
        assert surf.in_envelope("lk", pt_hi, 0.2)

    def test_below_floor_packet_time_clamps_exactly(self):
        """Packet times at or below the axis minimum (the refit
        saturation floor) are inside the envelope and resolve to the
        floor node — refit_link maps them all to the identical link, so
        the clamp is exact, not an approximation."""
        m = switchy_cost_model()
        surf = build_surface(m, {"lk": m.link}, 2,
                             pt_scale=(1.0, 8.0), loss_p=(0.0,))
        ps = surf.protocols["lk"]
        floor = ps.packet_time_s[0]
        assert refit_link(m.link, floor / 3, 0.0) == refit_link(m.link, floor, 0.0)
        hit = surf.lookup("lk", floor / 3, 0.0)
        assert hit.in_envelope
        assert hit.latency_s == ps.node(0, 0).latency_s
        assert surf.in_envelope("lk", 0.0, 0.0)

    def test_faster_than_nominal_link_keeps_surface_engaged(self):
        """Regression: a protocol whose base profile carries loss (so its
        nominal packet time is loss-inflated) must not fall off the
        surface when clean hops measure FASTER than nominal — that was
        pushing the estimate below the old envelope minimum and silently
        disabling the O(1) path for every protocol, forever."""
        m = switchy_cost_model()
        lossy = replace(m.link, loss_p=0.10)  # nominal = serial/(1-0.1)
        mgr = AdaptiveSplitManager(
            cost_model=m, protocols={"lk": lossy}, n_devices=2,
            surface_grid={"pt_scale": (1.0, 8.0, 64.0),
                          "loss_p": (0.0, None)})  # span down to clean
        true_time = lossy.packets(1500) * (lossy.mtu_bytes
                                           / lossy.rate_bytes_per_s)
        for _ in range(20):
            mgr.observe("lk", 1500, true_time)  # retry-free, faster than nominal
        assert mgr.estimators["lk"].packet_time_estimate \
            < lossy.packet_time_s()
        assert mgr.surface_hits == 20
        assert mgr.exact_fallbacks == 0


# ---------------------------------------------------------------------------
# Oracle equivalence (the acceptance contract)
# ---------------------------------------------------------------------------


class TestOracleEquivalence:
    def test_every_grid_node_matches_resolve_oracle(self, paper_surface_mgr):
        """At every surface node, (splits, chunk, latency) equal the
        exact re-solve decision for the same estimator state — exact
        ``==`` on the NumPy float64 path (the same
        ``surface_parity_report`` gate ``benchmarks/surface_replan.py``
        asserts, so the two can never drift apart)."""
        assert surface_parity_report(paper_surface_mgr) == []
        # and the estimators were restored afterwards
        for name, est in paper_surface_mgr.estimators.items():
            assert est._packet_time_s == est.base.packet_time_s()
            assert est._loss == est.base.loss_p

    def test_trace_replay_matches_legacy_phase_ends(self):
        """Replaying the same hop-latency trace through the surface-driven
        manager and the legacy per-observe re-solve manager yields the
        same plan at the end of every drift phase, and the surface's
        interpolated latency stays within the interpolation-error bound
        (its cell's corner spread) of the legacy exact estimate."""
        mk = dict(cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
                  protocols=dict(PROTOCOLS), n_devices=2, solver="optimal_dp")
        surf_mgr = AdaptiveSplitManager(**mk, surface_grid=SMALL_GRID)
        leg_mgr = AdaptiveSplitManager(**mk, surface=None)
        assert surf_mgr.current.splits == leg_mgr.current.splits
        assert surf_mgr.current.protocol == leg_mgr.current.protocol

        nbytes = 5488
        surf = surf_mgr.surface
        for factor in (1, 40, 250):
            lat = factor * ESP_NOW.transmission_latency_s(nbytes)
            for _ in range(80):
                surf_mgr.observe("esp_now", nbytes, lat)
                leg_mgr.observe("esp_now", nbytes, lat)
                # interpolated latency of the legacy current plan's
                # protocol vs the exact estimate, bounded by cell spread
                est = leg_mgr.estimators[leg_mgr.current.protocol]
                exact = leg_mgr._current_latency_under_estimates()
                ps = surf.protocols[leg_mgr.current.protocol]
                interp = surf.latency_at(leg_mgr.current.protocol,
                                         est._packet_time_s, est._loss)
                spread = _cell_spread(ps, est._packet_time_s, est._loss)
                assert abs(interp - exact) <= spread + 1e-9 * max(1.0, exact)
            assert surf_mgr.current.protocol == leg_mgr.current.protocol
            assert surf_mgr.current.splits == leg_mgr.current.splits
        assert surf_mgr.exact_fallbacks == 0
        assert surf_mgr.surface_hits > 0


def _cell_spread(ps, pt, loss) -> float:
    """Worst-case interpolation error bound: the latency spread across
    the corners of the cell containing (pt, loss)."""
    from repro.core.surface import _cell

    i0, i1, _, _ = _cell(ps.packet_time_s, pt)
    j0, j1, _, _ = _cell(ps.loss_p, loss)
    vals = [float(ps.latency_s[i, j]) for i in (i0, i1) for j in (j0, j1)]
    return max(vals) - min(vals)


# ---------------------------------------------------------------------------
# Manager integration: hot path, hysteresis, envelope fallback
# ---------------------------------------------------------------------------


class TestSurfaceManager:
    def test_healthy_network_all_surface_hits(self):
        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2, surface_grid=SMALL_GRID)
        nbytes = 5488
        good = ESP_NOW.transmission_latency_s(nbytes)
        for _ in range(40):
            mgr.observe("esp_now", nbytes, good)
        assert mgr.surface_hits == 40
        assert mgr.exact_fallbacks == 0
        assert len(mgr.history) == 1  # no thrash on a stable network

    def test_envelope_breach_falls_back_to_exact(self):
        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2, surface_grid=SMALL_GRID)
        nbytes = 5488
        # 10^6x nominal: one EWMA step jumps far beyond the 256x envelope
        cataclysm = 1e6 * ESP_NOW.transmission_latency_s(nbytes)
        mgr.observe("esp_now", nbytes, cataclysm)
        assert mgr.exact_fallbacks == 1
        # the fallback still replans (protocol switch away from esp_now)
        assert mgr.current.protocol != "esp_now"
        assert "envelope re-solve" in mgr.history[-1].reason

    @pytest.mark.parametrize("objective", ["sum", "bottleneck"])
    def test_fast_current_latency_bitwise_matches_exact(self, objective):
        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now",
                                        objective=objective),
            protocols=dict(PROTOCOLS), n_devices=3, surface_grid=SMALL_GRID)
        est = mgr.estimators[mgr.current.protocol]
        for pt_f, loss in ((1.0, 0.0), (7.3, 0.02), (130.0, 0.25)):
            est._packet_time_s = est.base.packet_time_s() * pt_f
            est._loss = loss
            fast = mgr._fast_current_latency(est._packet_time_s, est._loss)
            exact = mgr._current_latency_under_estimates()
            assert fast == exact  # same float operation order, bitwise

    def test_prebuilt_surface_is_used_verbatim(self):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        surf = build_surface(m, dict(PROTOCOLS), 2, **SMALL_GRID,
                             solver="batched_beam")
        mgr = AdaptiveSplitManager(cost_model=m, protocols=dict(PROTOCOLS),
                                   n_devices=2, surface=surf)
        assert mgr.surface is surf

    def test_scalar_only_solvers_still_construct(self):
        """Regression: surface="auto" must not refuse solvers without a
        batched twin — they keep the legacy re-solve path."""
        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2, solver="first_fit")
        assert mgr.surface is None  # legacy path, as before this PR
        assert mgr.current is not None
        mgr.observe("esp_now", 5488, ESP_NOW.transmission_latency_s(5488))
        assert mgr.exact_fallbacks == 0 and mgr.surface_hits == 0

    def test_greedy_solver_maps_to_batched_surface(self):
        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2, solver="greedy",
            surface_grid=SMALL_GRID)
        assert isinstance(mgr.surface, DegradationSurface)
        assert mgr.surface.solver == "batched_greedy"

    def test_no_identical_readoption_across_switch_point(self):
        """Regression: mid-cell the interpolated best latency can undercut
        the exact current-plan estimate even when the nearest node holds
        the SAME plan; that must not re-record the identical decision on
        every observe."""
        m = switchy_cost_model()
        mgr = AdaptiveSplitManager(
            cost_model=m, protocols={"lk": m.link}, n_devices=2,
            surface_grid={"pt_scale": (1.0, 4.0, 16.0, 64.0),
                          "loss_p": (0.0,)})
        assert mgr.surface.switch_points("lk")  # the plan does move
        base_t = m.link.transmission_latency_s(1500)
        for factor in (1, 2, 5, 9, 12, 20, 40, 60):  # sweep across the switch
            for _ in range(30):
                mgr.observe("lk", 1500, factor * base_t)
        decisions = [(d.protocol, d.splits, d.chunk_bytes) for d in mgr.history]
        assert all(a != b for a, b in zip(decisions, decisions[1:]))
        assert len(mgr.history) <= 4  # a handful of real switches, no thrash
        assert mgr.current.splits == (2,)  # ended degraded: min-packet cut

    def test_base_loss_respected_by_none_axis(self):
        """Regression: ``loss_p=None`` entries resolve to each protocol's
        base loss (ScenarioGrid semantics), so a lossy link's estimator
        starts inside its surface envelope."""
        m = switchy_cost_model()
        lossy = replace(m.link, loss_p=1e-4)
        surf = build_surface(m, {"lk": lossy}, 2,
                             pt_scale=(1.0, 8.0), loss_p=(None, 0.2))
        assert surf.protocols["lk"].loss_p == (1e-4, 0.2)
        assert surf.in_envelope("lk", lossy.packet_time_s(), lossy.loss_p)
        grid = ScenarioGrid(
            models={"switchy": m.profile}, links={"lk": lossy},
            n_devices=(2,), devices=tuple(m.devices))  # loss_p=(None,)
        surf2 = grid.degradation_surface()
        assert surf2.protocols["lk"].loss_p == (1e-4,)

    def test_refit_link_matches_estimator_profile(self):
        est = LinkEstimator(ESP_NOW, alpha=0.5)
        for _ in range(5):
            est.observe_hop(5488, 17 * ESP_NOW.transmission_latency_s(5488),
                            retries=1)
        assert refit_link(ESP_NOW, est._packet_time_s, est._loss) \
            == est.current_profile()
