"""Docs stay truthful: the same gate CI's `docs` job runs
(tools/check_docs.py) — every ```python block in docs/*.md executes,
and docs/api.md names every public repro.core symbol."""

import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_tree_exists():
    for name in ("architecture.md", "api.md", "benchmarks.md"):
        assert (ROOT / "docs" / name).is_file(), name


def test_doc_code_blocks_execute():
    cd = _load_check_docs()
    assert cd.check_code_blocks() == []


def test_api_doc_covers_every_public_symbol():
    cd = _load_check_docs()
    symbols = cd.public_core_symbols()
    # sanity: the surface of repro.core really is in the list
    for expected in ("ScenarioGrid", "build_surfaces",
                     "AdaptiveSplitManager", "fleet_managers",
                     "batched_beam_search_all_k"):
        assert expected in symbols
    assert cd.check_api_coverage() == []


def test_readme_links_docs():
    readme = (ROOT / "README.md").read_text()
    for name in ("docs/architecture.md", "docs/api.md",
                 "docs/benchmarks.md"):
        assert name in readme, f"README does not link {name}"
