"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
this test suite uses.

The real ``hypothesis`` is declared in ``pyproject.toml`` under the
``test`` extra and is preferred whenever importable; ``conftest.py``
installs this fallback into ``sys.modules`` only when the import fails
(hermetic containers, air-gapped CI). The fallback keeps the tests
*property-style* — each ``@given`` test still runs against
``max_examples`` randomized draws — but with a deterministic per-test
seed and no shrinking.

Supported surface (exactly what the suite imports):
  given, settings, strategies.{integers, floats, booleans, sampled_from,
  sets, lists, tuples, data, composite}
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

_DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """A lazily-drawn value generator (mirrors hypothesis' SearchStrategy)."""

    def __init__(self, draw_fn, label="strategy"):
        self._draw = draw_fn
        self._label = label

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"<{self._label}>"


def integers(min_value, max_value):
    return Strategy(lambda rng: rng.randint(min_value, max_value),
                    f"integers({min_value}, {max_value})")


def floats(min_value, max_value, allow_nan=False, allow_infinity=False, **_kw):
    del allow_nan, allow_infinity  # bounded draws are always finite
    return Strategy(lambda rng: rng.uniform(min_value, max_value),
                    f"floats({min_value}, {max_value})")


def booleans():
    return Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")


def sampled_from(elements):
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty collection")
    return Strategy(lambda rng: pool[rng.randrange(len(pool))],
                    f"sampled_from(<{len(pool)}>)")


def lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 8

    def draw(rng):
        return [elements._draw(rng) for _ in range(rng.randint(min_size, hi))]

    return Strategy(draw, "lists(...)")


def tuples(*strategies):
    return Strategy(lambda rng: tuple(s._draw(rng) for s in strategies),
                    "tuples(...)")


def sets(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 8

    def draw(rng):
        target = rng.randint(min_size, hi)
        out: set = set()
        # Bounded retry loop: small element domains may not be able to
        # reach ``target`` distinct values.
        for _ in range(200 * max(1, target)):
            if len(out) >= target:
                break
            out.add(elements._draw(rng))
        if len(out) < min_size:
            raise ValueError(
                f"could not draw a set of >= {min_size} distinct elements")
        return out

    return Strategy(draw, "sets(...)")


class _DataObject:
    """Interactive draw handle (``st.data()``)."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        del label
        return strategy._draw(self._rng)


def data():
    return Strategy(lambda rng: _DataObject(rng), "data()")


def composite(fn):
    """``@st.composite`` — the wrapped function receives a ``draw`` callable."""

    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strat: strat._draw(rng), *args, **kwargs)

        return Strategy(draw_value, f"composite({fn.__name__})")

    return builder


class settings:
    """Decorator recording per-test example counts; other knobs ignored."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._mh_max_examples = self.max_examples
        return fn


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        max_examples = getattr(fn, "_mh_max_examples", _DEFAULT_MAX_EXAMPLES)
        # Deterministic per-test seed: stable across runs and machines.
        seed = zlib.crc32(fn.__qualname__.encode())

        # Positional strategies bind to the RIGHTMOST parameters (as in
        # hypothesis); everything is passed by keyword so pytest fixtures
        # (which arrive as kwargs) never collide with drawn values.
        sig0 = inspect.signature(fn)
        non_kw = [p.name for p in sig0.parameters.values()
                  if p.name not in kw_strategies]
        pos_names = non_kw[len(non_kw) - len(arg_strategies):] \
            if arg_strategies else []

        @functools.wraps(fn)
        def wrapper(*call_args, **call_kwargs):
            rng = random.Random(seed)
            for example in range(max_examples):
                drawn = {name: s._draw(rng)
                         for name, s in zip(pos_names, arg_strategies)}
                drawn.update((k, s._draw(rng)) for k, s in kw_strategies.items())
                try:
                    fn(*call_args, **call_kwargs, **drawn)
                except Exception as exc:  # annotate, no shrinking
                    raise AssertionError(
                        f"falsifying example #{example}: {drawn!r}"
                    ) from exc

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution: like hypothesis, positional strategies consume the
        # RIGHTMOST params (pytest fixtures stay on the left); keyword
        # strategies consume params by name.
        sig = inspect.signature(fn)
        params = [p for p in sig.parameters.values() if p.name not in kw_strategies]
        n_pos = len(arg_strategies)
        keep = params[: len(params) - n_pos] if n_pos else params
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__  # stop inspect from seeing fn's params
        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper.hypothesis_fallback = True
        return wrapper

    return decorate
