"""Unit tests for the Eq. 4-8 latency model."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.latency import (
    DeviceProfile,
    LayerCost,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
    rtt_breakdown,
    scale_profile,
)

LINK = LinkProfile("test", mtu_bytes=100, rate_bytes_per_s=1e4, loss_p=0.1,
                   t_prop_s=1e-3, t_ack_s=2e-3, t_setup_s=0.5, t_feedback_s=0.1)


def make_profile(n=6, act=500, param=1000, t=0.01):
    layers = [
        LayerCost(f"l{i}", t_infer_s=t * (i + 1), act_bytes=act, param_bytes=param,
                  work_bytes=act * 2, flops=1e6)
        for i in range(n)
    ]
    return ModelCostProfile("toy", tuple(layers), input_bytes=act)


class TestLink:
    def test_packet_count_ceil(self):
        assert LINK.packets(1) == 1
        assert LINK.packets(100) == 1
        assert LINK.packets(101) == 2
        assert LINK.packets(0) == 0

    def test_packet_time_eq7(self):
        # MTU/(r(1-p)) + T_prop + T_ack
        want = 100 / (1e4 * 0.9) + 1e-3 + 2e-3
        assert LINK.packet_time_s() == pytest.approx(want)

    def test_transmission_linear_in_packets(self):
        t1 = LINK.transmission_latency_s(100)
        t5 = LINK.transmission_latency_s(401)  # 5 packets
        assert t5 == pytest.approx(5 * t1)

    @given(nbytes=st.integers(1, 10**7), mtu=st.integers(1, 10**5))
    @settings(max_examples=200, deadline=None)
    def test_packets_property(self, nbytes, mtu):
        link = LinkProfile("x", mtu_bytes=mtu, rate_bytes_per_s=1e6)
        k = link.packets(nbytes)
        assert (k - 1) * mtu < nbytes <= k * mtu
        assert k == math.ceil(nbytes / mtu)

    @given(p=st.floats(0.0, 0.9))
    @settings(max_examples=50, deadline=None)
    def test_loss_monotone(self, p):
        """Higher loss -> longer expected transmission (Eq. 7 derating)."""
        base = LinkProfile("x", 100, 1e4, loss_p=0.0)
        lossy = LinkProfile("x", 100, 1e4, loss_p=p)
        assert lossy.transmission_latency_s(1000) >= base.transmission_latency_s(1000)


class TestDevice:
    def test_memory_feasibility_inf(self):
        dev = DeviceProfile("d", mem_limit_bytes=100)
        assert dev.local_latency_s(0.1, param_bytes=90, act_bytes=0, work_bytes=20) == float("inf")
        assert dev.local_latency_s(0.1, param_bytes=90, act_bytes=0, work_bytes=5) < float("inf")

    def test_eq4_decomposition(self):
        dev = DeviceProfile(
            "d", compute_scale=2.0, t_model_load_s=1.0, model_load_s_per_byte=0.1,
            t_input_load_s=5.0, t_tensor_alloc_s=2.0, tensor_alloc_s_per_byte=0.01,
            t_buffer_s=3.0, buffer_s_per_byte=0.001,
        )
        t = dev.local_latency_s(infer_s=10.0, param_bytes=10, act_bytes=100, work_bytes=200,
                                is_first=True)
        want = (1.0 + 0.1 * 10) + (2.0 + 0.01 * 200) + 10.0 * 2.0 + (3.0 + 0.001 * 100) + 5.0
        assert t == pytest.approx(want)


class TestCostModel:
    def test_sum_objective_decomposes(self):
        prof = make_profile()
        m = SplitCostModel(prof, (DeviceProfile("d"),), LINK)
        splits = (2, 4)
        total = m.end_to_end_s(splits, with_overheads=False)
        parts = [m.segment_cost_s(1, 2, 1), m.segment_cost_s(3, 4, 2), m.segment_cost_s(5, 6, 3)]
        assert total == pytest.approx(sum(parts))

    def test_overheads_add_setup_and_feedback(self):
        prof = make_profile()
        m = SplitCostModel(prof, (DeviceProfile("d"),), LINK)
        no = m.end_to_end_s((3,), with_overheads=False)
        yes = m.end_to_end_s((3,), with_overheads=True)
        assert yes == pytest.approx(no + 0.5 + 0.1)

    def test_last_segment_has_no_transmission(self):
        prof = make_profile()
        m = SplitCostModel(prof, (DeviceProfile("d"),), LINK)
        c_last = m.segment_cost_s(5, 6, 2)
        dev_only = DeviceProfile("d").local_latency_s(
            prof.segment_infer_s(5, 6), prof.segment_param_bytes(5, 6),
            prof.boundary_act_bytes(6), prof.segment_work_bytes(5, 6))
        assert c_last == pytest.approx(dev_only)

    def test_invalid_splits_inf(self):
        prof = make_profile()
        m = SplitCostModel(prof, (DeviceProfile("d"),), LINK)
        assert m.end_to_end_s((4, 2)) == float("inf")  # not increasing
        assert m.end_to_end_s((0, 3)) == float("inf")  # s_i >= 1

    def test_bottleneck_objective_is_max(self):
        prof = make_profile()
        m = SplitCostModel(prof, (DeviceProfile("d"),), LINK, objective="bottleneck")
        splits = (3,)
        parts = [m.segment_cost_s(1, 3, 1), m.segment_cost_s(4, 6, 2)]
        assert m.end_to_end_s(splits, with_overheads=False) == pytest.approx(max(parts))

    def test_rtt_breakdown_consistent(self):
        prof = make_profile()
        m = SplitCostModel(prof, (DeviceProfile("d"),), LINK)
        br = rtt_breakdown(m, (2, 4))
        assert br.rtt_s == pytest.approx(m.end_to_end_s((2, 4), with_overheads=True))
        assert len(br.device_s) == 3
        assert len(br.transmission_s) == 2

    def test_scale_profile(self):
        prof = make_profile()
        scaled = scale_profile(prof, 42.0)
        assert sum(lc.t_infer_s for lc in scaled.layers) == pytest.approx(42.0)
        # ratios preserved
        r0 = scaled.layers[1].t_infer_s / scaled.layers[0].t_infer_s
        assert r0 == pytest.approx(prof.layers[1].t_infer_s / prof.layers[0].t_infer_s)
