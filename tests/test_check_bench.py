"""Unit tests for the CI bench-regression gate (tools/check_bench.py).

The gate is pure dict-checking, so the suite drives it with synthetic
reports: a known-good report built from the gate's own key lists, then
single-fault mutants (missing section, tripped correctness flag,
collapsed speedup, grown overhead ratio) that must each fail. The
committed baselines themselves must pass as their own candidates —
that is exactly what CI runs.
"""

import copy
import importlib.util
import json
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

spec = importlib.util.spec_from_file_location(
    "check_bench", ROOT / "tools" / "check_bench.py")
CB = importlib.util.module_from_spec(spec)
spec.loader.exec_module(CB)


def _set(report, dotted, value):
    node = report
    *parents, leaf = dotted.split(".")
    for part in parents:
        node = node.setdefault(part, {})
    node[leaf] = value


def good_sweep():
    """A candidate satisfying every sweep key, flag and ratio."""
    r = {}
    for key in CB.SWEEP_KEYS:
        _set(r, key, 1.0)
    for key in CB.SWEEP_FLAGS:
        _set(r, key, True)
    _set(r, "benchmark", "sweep_grid")
    _set(r, "mode", "smoke")
    _set(r, "backend", "numpy")
    _set(r, "parity_ok", True)
    _set(r, "speedup_x", 90.0)
    _set(r, "pallas.interpret", True)
    _set(r, "pallas.node_identical_to_jax", False)  # informational
    _set(r, "pallas.n_tie_divergences", 33)
    _set(r, "multichannel.speedup_x", 90.0)
    _set(r, "frontier.speedup_x", 90.0)
    return r


def good_surface():
    r = {}
    for key in CB.SURFACE_KEYS:
        _set(r, key, 1.0)
    for key in CB.SURFACE_FLAGS:
        _set(r, key, True)
    _set(r, "benchmark", "surface")
    _set(r, "mode", "smoke")
    _set(r, "speedup_x", 130.0)
    _set(r, "async.inflight_over_steady_x", 0.8)
    return r


def good_gateway():
    r = {}
    for key in CB.GATEWAY_KEYS:
        _set(r, key, 1.0)
    for key in CB.GATEWAY_FLAGS:
        _set(r, key, True)
    _set(r, "benchmark", "gateway_load")
    _set(r, "mode", "smoke")
    _set(r, "n_sessions", 500)
    _set(r, "storm.coalesce_per_drifted", 4.0)
    return r


class TestCheckSweep:
    def test_good_report_is_green(self):
        assert CB.check_sweep(good_sweep(), good_sweep(), 3.0) == []

    def test_missing_section_fails(self):
        r = good_sweep()
        del r["pallas"]
        fails = CB.check_sweep(r, good_sweep(), 3.0)
        assert any("pallas.wall_s" in f for f in fails)
        assert any("pallas.costs_allclose_to_jax" in f for f in fails)

    def test_tripped_correctness_flag_fails(self):
        for flag in CB.SWEEP_FLAGS:
            r = good_sweep()
            _set(r, flag, False)
            fails = CB.check_sweep(r, good_sweep(), 3.0)
            assert any(flag in f for f in fails), flag

    def test_speedup_collapse_fails_but_noise_passes(self):
        base = good_sweep()
        r = good_sweep()
        _set(r, "speedup_x", 90.0 / 2)  # within 3x: noise
        assert CB.check_sweep(r, base, 3.0) == []
        _set(r, "speedup_x", 90.0 / 4)  # beyond 3x: collapse
        fails = CB.check_sweep(r, base, 3.0)
        assert any("speedup_x" in f and "collapsed" in f for f in fails)

    def test_parity_required_only_for_numpy_backend(self):
        # float32 backends may break exact-cost ties vs the f64 oracle
        r = good_sweep()
        _set(r, "backend", "pallas")
        _set(r, "parity_ok", False)
        assert CB.check_sweep(r, good_sweep(), 3.0) == []
        _set(r, "backend", "numpy")
        fails = CB.check_sweep(r, good_sweep(), 3.0)
        assert any("parity_ok" in f for f in fails)

    def test_no_baseline_skips_ratios_only(self):
        r = good_sweep()
        _set(r, "speedup_x", 0.001)
        assert CB.check_sweep(r, None, 3.0) == []
        _set(r, "sharded.node_identical_to_jax", False)
        assert CB.check_sweep(r, None, 3.0) != []

    def test_non_numeric_ratio_flagged(self):
        r = good_sweep()
        _set(r, "speedup_x", "fast")
        fails = CB.check_sweep(r, good_sweep(), 3.0)
        assert any("not numeric" in f for f in fails)


class TestCheckSweepMultichannel:
    """Doctored multichannel sections must each fail the gate."""

    def test_missing_multichannel_section_fails(self):
        r = good_sweep()
        del r["multichannel"]
        fails = CB.check_sweep(r, good_sweep(), 3.0)
        assert any("multichannel.speedup_x" in f for f in fails)
        assert any("multichannel.parity_ok" in f for f in fails)
        assert any("multichannel.budget_respected" in f for f in fails)

    def test_regressed_multichannel_ratio_fails(self):
        base = good_sweep()
        r = good_sweep()
        _set(r, "multichannel.speedup_x", 90.0 / 2)  # within 3x: noise
        assert CB.check_sweep(r, base, 3.0) == []
        _set(r, "multichannel.speedup_x", 90.0 / 4)  # beyond 3x: collapse
        fails = CB.check_sweep(r, base, 3.0)
        assert any("multichannel.speedup_x" in f and "collapsed" in f
                   for f in fails)

    def test_core_speedup_regression_still_caught_alongside(self):
        # the new ratio must not mask the original one
        base = good_sweep()
        r = good_sweep()
        _set(r, "speedup_x", 90.0 / 4)
        fails = CB.check_sweep(r, base, 3.0)
        assert any(f.startswith("sweep: speedup_x") for f in fails)
        assert not any("multichannel" in f for f in fails)

    @pytest.mark.parametrize("flag", ["multichannel.parity_ok",
                                      "multichannel.degenerate_bit_exact",
                                      "multichannel.budget_respected"])
    def test_false_multichannel_flag_fails(self, flag):
        r = good_sweep()
        _set(r, flag, False)
        fails = CB.check_sweep(r, good_sweep(), 3.0)
        assert any(flag in f for f in fails)

    def test_committed_baseline_has_multichannel_section(self):
        with open(ROOT / "BENCH_sweep.json") as f:
            rep = json.load(f)
        mc = rep["multichannel"]
        assert mc["parity_ok"] is True
        assert mc["degenerate_bit_exact"] is True
        assert mc["budget_respected"] is True
        assert mc["n_budgeted"] > 0


class TestCheckSweepFrontier:
    """Doctored frontier sections must each fail the gate."""

    def test_missing_frontier_section_fails(self):
        r = good_sweep()
        del r["frontier"]
        fails = CB.check_sweep(r, good_sweep(), 3.0)
        assert any("frontier.speedup_x" in f for f in fails)
        assert any("frontier.parity_ok" in f for f in fails)
        assert any("frontier.frontier_matches_bruteforce" in f
                   for f in fails)

    def test_regressed_frontier_ratio_fails(self):
        base = good_sweep()
        r = good_sweep()
        _set(r, "frontier.speedup_x", 90.0 / 2)  # within 3x: noise
        assert CB.check_sweep(r, base, 3.0) == []
        _set(r, "frontier.speedup_x", 90.0 / 4)  # beyond 3x: collapse
        fails = CB.check_sweep(r, base, 3.0)
        assert any("frontier.speedup_x" in f and "collapsed" in f
                   for f in fails)

    @pytest.mark.parametrize("flag", ["frontier.parity_ok",
                                      "frontier.loop_identical",
                                      "frontier.frontier_matches_bruteforce",
                                      "frontier.identity_on_every_frontier"])
    def test_false_frontier_flag_fails(self, flag):
        r = good_sweep()
        _set(r, flag, False)
        fails = CB.check_sweep(r, good_sweep(), 3.0)
        assert any(flag in f for f in fails)

    def test_committed_baseline_has_frontier_section(self):
        with open(ROOT / "BENCH_sweep.json") as f:
            rep = json.load(f)
        fr = rep["frontier"]
        assert fr["parity_ok"] is True
        assert fr["loop_identical"] is True
        assert fr["frontier_matches_bruteforce"] is True
        assert fr["identity_on_every_frontier"] is True
        assert fr["n_frontiers"] > 0
        assert fr["max_frontier_points"] >= 2  # a real trade-off exists


class TestCheckSurface:
    def test_good_report_is_green(self):
        assert CB.check_surface(good_surface(), good_surface(), 3.0) == []

    def test_lower_better_ratio_growth_fails(self):
        base = good_surface()
        r = good_surface()
        _set(r, "async.inflight_over_steady_x", 0.8 * 2)  # noise
        assert CB.check_surface(r, base, 3.0) == []
        _set(r, "async.inflight_over_steady_x", 0.8 * 4)  # regression
        fails = CB.check_surface(r, base, 3.0)
        assert any("inflight_over_steady_x" in f and "grew" in f
                   for f in fails)

    def test_tripped_flag_fails(self):
        r = good_surface()
        _set(r, "plans_agree_end_of_trace", False)
        assert CB.check_surface(r, good_surface(), 3.0) != []


class TestCheckGateway:
    def test_good_report_is_green(self):
        assert CB.check_gateway(good_gateway(), good_gateway(), 3.0) == []

    def test_tripped_audit_flag_fails(self):
        for flag in CB.GATEWAY_FLAGS:
            r = good_gateway()
            _set(r, flag, False)
            fails = CB.check_gateway(r, good_gateway(), 3.0)
            assert any(flag in f for f in fails), flag

    def test_missing_storm_section_fails(self):
        r = good_gateway()
        del r["storm"]
        fails = CB.check_gateway(r, good_gateway(), 3.0)
        assert any("storm.coalesce_x" in f for f in fails)

    def test_coalescing_collapse_fails_but_noise_passes(self):
        base = good_gateway()
        r = good_gateway()
        _set(r, "storm.coalesce_per_drifted", 4.0 / 2)  # noise
        assert CB.check_gateway(r, base, 3.0) == []
        _set(r, "storm.coalesce_per_drifted", 4.0 / 5)  # collapse
        fails = CB.check_gateway(r, base, 3.0)
        assert any("coalesce_per_drifted" in f and "collapsed" in f
                   for f in fails)


def good_planner():
    r = {}
    for key in CB.PLANNER_KEYS:
        _set(r, key, 1.0)
    for key in CB.PLANNER_FLAGS:
        _set(r, key, True)
    _set(r, "benchmark", "planner_scale")
    _set(r, "mode", "smoke")
    _set(r, "solve.n_scenarios", 2000)
    return r


class TestCheckPlanner:
    def test_good_report_is_green(self):
        assert CB.check_planner(good_planner(), good_planner(), 3.0) == []

    def test_tripped_flag_fails(self):
        for flag in CB.PLANNER_FLAGS:
            r = good_planner()
            _set(r, flag, False)
            fails = CB.check_planner(r, good_planner(), 3.0)
            assert any(flag in f for f in fails), flag

    def test_missing_rebuild_section_fails(self):
        r = good_planner()
        del r["rebuild"]
        fails = CB.check_planner(r, good_planner(), 3.0)
        assert any("rebuild.pool_parity_ok" in f for f in fails)

    def test_no_ratio_gate_by_design(self):
        # spawn cost varies >3x across hosts: the planner gate is
        # schema + flags only
        assert CB.PLANNER_RATIOS == ()


class TestCommittedBaselines:
    """The committed full-run reports must pass as their own candidates
    — the exact invocation the CI bench-smoke job makes, so a schema
    drift in the benchmarks breaks HERE first, not on main."""

    def test_bench_sweep_json_green(self):
        with open(ROOT / "BENCH_sweep.json") as f:
            rep = json.load(f)
        assert CB.check_sweep(rep, copy.deepcopy(rep), 3.0) == []

    def test_bench_surface_json_green(self):
        with open(ROOT / "BENCH_surface.json") as f:
            rep = json.load(f)
        assert CB.check_surface(rep, copy.deepcopy(rep), 3.0) == []

    def test_bench_gateway_json_green(self):
        with open(ROOT / "BENCH_gateway.json") as f:
            rep = json.load(f)
        assert CB.check_gateway(rep, copy.deepcopy(rep), 3.0) == []

    def test_bench_planner_json_green(self):
        with open(ROOT / "BENCH_planner.json") as f:
            rep = json.load(f)
        assert CB.check_planner(rep, copy.deepcopy(rep), 3.0) == []


class TestCli:
    def _dump(self, tmp_path, name, report):
        p = tmp_path / name
        p.write_text(json.dumps(report))
        return str(p)

    def test_green_run_exits_zero(self, tmp_path, capsys):
        sweep = self._dump(tmp_path, "s.json", good_sweep())
        surf = self._dump(tmp_path, "f.json", good_surface())
        rc = CB.main(["--sweep", sweep, "--sweep-baseline", sweep,
                      "--surface", surf, "--surface-baseline", surf])
        assert rc == 0
        assert "bench OK" in capsys.readouterr().out

    def test_regression_exits_one(self, tmp_path, capsys):
        base = self._dump(tmp_path, "base.json", good_sweep())
        bad = good_sweep()
        _set(bad, "pallas.divergences_are_exact_ties", False)
        cand = self._dump(tmp_path, "cand.json", bad)
        rc = CB.main(["--sweep", cand, "--sweep-baseline", base])
        assert rc == 1
        assert "regression" in capsys.readouterr().err

    def test_nothing_to_check_is_usage_error(self):
        with pytest.raises(SystemExit):
            CB.main([])

    def test_max_ratio_below_one_rejected(self, tmp_path):
        sweep = self._dump(tmp_path, "s.json", good_sweep())
        with pytest.raises(SystemExit):
            CB.main(["--sweep", sweep, "--sweep-baseline", sweep,
                     "--max-ratio", "0.5"])
