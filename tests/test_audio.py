"""MusicGen delay-pattern tests (audio-arch fidelity)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.audio import delay_mask, delay_pattern, undelay_pattern

PAD = -1


class TestDelayPattern:
    def test_known_small_case(self):
        codes = jnp.arange(6).reshape(1, 3, 2)  # T=3, K=2
        d = delay_pattern(codes, PAD)
        assert d.shape == (1, 4, 2)
        np.testing.assert_array_equal(d[0, :, 0], [0, 2, 4, PAD])
        np.testing.assert_array_equal(d[0, :, 1], [PAD, 1, 3, 5])

    @given(st.integers(1, 10), st.integers(1, 6), st.integers(1, 3))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip(self, T, K, B):
        codes = jax.random.randint(jax.random.PRNGKey(T * K), (B, T, K), 0, 100)
        back = undelay_pattern(delay_pattern(codes, PAD), T)
        np.testing.assert_array_equal(back, codes)

    @given(st.integers(1, 10), st.integers(1, 6))
    @settings(max_examples=30, deadline=None)
    def test_mask_matches_pad_positions(self, T, K):
        codes = jnp.zeros((1, T, K), dtype=jnp.int32)
        d = delay_pattern(codes, PAD)
        mask = delay_mask(T, K)
        np.testing.assert_array_equal(np.asarray(d[0] != PAD), np.asarray(mask))

    def test_each_step_reveals_at_most_one_new_frame_per_codebook(self):
        """The property that makes single-pass AR decoding work."""
        T, K = 5, 4
        mask = np.asarray(delay_mask(T, K))
        for t in range(T + K - 1):
            assert mask[t].sum() <= K
        # codebook k first appears at step k
        first = [int(np.argmax(mask[:, k])) for k in range(K)]
        assert first == list(range(K))
