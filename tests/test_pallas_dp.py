"""Pallas backend suite (interpret mode on CPU — the CI ``pallas`` job).

Dense mode (``backend="pallas"`` through :func:`repro.core.sweep.
batched_optimal_dp`) reorders no arithmetic vs the JAX backend, so the
contract here is exact ``==`` on splits, costs and feasibility —
including non-tile-multiple scenario counts and layer counts straddling
the 128-lane boundary, where the +inf lane padding and replica rows
must stay invisible.

Fused mode (:func:`repro.core.pallas_dp.pallas_fused_optimal_dp`, the
``sweep()``/``build_surfaces()`` path) folds ``C = local + tx``
construction into the kernel; the <=1 ulp construction rounding may
break EXACT-cost ties toward a different equally-optimal plan, so
fused assertions are: feasibility ``==``, costs allclose, and any
divergent plan must reprice (float64) to the same optimum.
"""

import numpy as np
import pytest

from repro.core import pallas_dp as PD
from repro.core import shard as SH
from repro.core import solvers as S
from repro.core import sweep as SW
from repro.core.latency import (
    DeviceProfile,
    LayerCost,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
)
from repro.core.surface import build_surfaces

INF = float("inf")

# (S, N, L) corners: non-multiple-of-block_s S, L straddling the
# 128-lane tile (130), single scenario, single device, L == N
SHAPES = [(7, 4, 13), (1, 2, 5), (16, 3, 130), (5, 1, 9), (3, 6, 6)]


def make_C(Sn, N, L, seed, inf_frac=0.15):
    """Random dense cost tensor with invalid segments at +inf."""
    rng = np.random.RandomState(seed)
    C = rng.uniform(1e-3, 10.0, size=(Sn, N, L, L))
    C[rng.random(size=C.shape) < inf_frac] = INF
    il = np.tril_indices(L, -1)
    C[:, :, il[0], il[1]] = INF  # a > b is not a segment
    return C


def make_ns(Sn, N, seed):
    return np.random.RandomState(seed ^ 0x5EED).randint(1, N + 1, size=Sn)


def reprice(C_s, splits, L, combine):
    """Float64 scalar-oracle cost of one scenario's plan."""
    return S.total_cost(
        lambda a, b, k: float(C_s[k - 1, a - 1, b - 1]), splits, L, combine)


def assert_same_or_exact_tie(a, b, C, combine, ctx=""):
    """Fused-mode plan contract vs a dense result: identical nodes
    except exact-cost ties (zero float64-repriced regret)."""
    assert np.array_equal(a.feasible, b.feasible), ctx
    fin = a.feasible
    assert np.allclose(a.cost_s[fin], b.cost_s[fin], rtol=1e-5), ctx
    L = C.shape[-1]
    for s in np.flatnonzero(fin):
        if a.splits_tuple(s) == b.splits_tuple(s):
            continue
        ra = reprice(C[s], a.splits_tuple(s), L, combine)
        rb = reprice(C[s], b.splits_tuple(s), L, combine)
        assert abs(ra - rb) <= 1e-12 * max(abs(ra), 1e-300), \
            f"{ctx}: scenario {s} diverged with regret {rb - ra!r}"


# ---------------------------------------------------------------------------
# Dense mode: bitwise node-identity to backend="jax"
# ---------------------------------------------------------------------------


class TestDenseNodeIdentity:
    @pytest.mark.parametrize("combine", ["sum", "max"])
    @pytest.mark.parametrize("shape", SHAPES)
    def test_bitwise_vs_jax(self, shape, combine):
        Sn, N, L = shape
        C = make_C(Sn, N, L, seed=hash(shape) & 0x7FFFFFFF)
        ns = make_ns(Sn, N, seed=Sn * 31 + N)
        for kw in ({}, {"n_devices": ns}):
            a = SW.batched_optimal_dp(C, combine=combine, backend="jax", **kw)
            b = SW.batched_optimal_dp(C, combine=combine, backend="pallas",
                                      **kw)
            assert b.backend == "pallas"
            assert np.array_equal(a.splits, b.splits), (shape, combine, kw)
            assert np.array_equal(a.cost_s, b.cost_s), (shape, combine, kw)
            assert np.array_equal(a.feasible, b.feasible), (shape, combine, kw)

    def test_all_k_bitwise_vs_jax(self):
        C = make_C(6, 4, 12, seed=7)
        ref = SW.batched_optimal_dp(C, return_all_k=True, backend="jax")
        got = SW.batched_optimal_dp(C, return_all_k=True, backend="pallas")
        assert sorted(got) == sorted(ref) == [1, 2, 3, 4]
        for n in ref:
            assert np.array_equal(ref[n].splits, got[n].splits), n
            assert np.array_equal(ref[n].cost_s, got[n].cost_s), n
            assert np.array_equal(ref[n].feasible, got[n].feasible), n

    def test_odd_block_s_exercises_replica_padding(self):
        """block_s=3 with S=7 pads to Sp=9: two replica rows that must
        never leak into the real scenarios' answers."""
        C = make_C(7, 3, 11, seed=11)
        a = SW.batched_optimal_dp(C, backend="jax")
        b = PD.pallas_optimal_dp(C, block_s=3)
        assert np.array_equal(a.splits, b.splits)
        assert np.array_equal(a.cost_s, b.cost_s)

    def test_explicit_interpret_true(self):
        C = make_C(4, 3, 9, seed=3)
        a = SW.batched_optimal_dp(C, backend="jax")
        b = PD.pallas_optimal_dp(C, interpret=True)
        assert np.array_equal(a.splits, b.splits)

    def test_empty_scenario_axis(self):
        C = make_C(0, 3, 8, seed=1)
        b = SW.batched_optimal_dp(C, backend="pallas")
        assert b.splits.shape == (0, 2)
        assert b.cost_s.shape == (0,)


# ---------------------------------------------------------------------------
# Fused mode: C never materialized; node-identical up to exact ties
# ---------------------------------------------------------------------------


def make_local_tx(Sn, N, L, seed):
    rng = np.random.RandomState(seed)
    local = rng.uniform(1e-3, 5.0, size=(N, L, L))
    il = np.tril_indices(L, -1)
    local[:, il[0], il[1]] = INF
    local[rng.random(size=local.shape) < 0.1] = INF
    tx = rng.uniform(0.0, 2.0, size=(Sn, L))
    return local, tx


class TestFusedKernel:
    @pytest.mark.parametrize("combine", ["sum", "max"])
    def test_matches_dense_on_materialized_C(self, combine):
        Sn, N, L = 9, 4, 14
        local, tx = make_local_tx(Sn, N, L, seed=21)
        C = local[None, :, :, :] + tx[:, None, None, :]
        a = SW.batched_optimal_dp(C, combine=combine, backend="jax")
        b = PD.pallas_fused_optimal_dp(local, None, tx, combine=combine)
        assert b.backend == "pallas"
        assert_same_or_exact_tie(a, b, C, combine, ctx=f"fused/{combine}")

    def test_frozen_rows_with_ns(self):
        Sn, N, L = 8, 4, 10
        local, tx = make_local_tx(Sn, N, L, seed=5)
        C = local[None] + tx[:, None, None, :]
        ns = make_ns(Sn, N, seed=5)
        a = SW.batched_optimal_dp(C, n_devices=ns, backend="jax")
        b = PD.pallas_fused_optimal_dp(local, None, tx, n_devices=ns)
        assert_same_or_exact_tie(a, b, C, "sum", ctx="fused/ns")
        assert np.array_equal(a.n_devices_s, b.n_devices_s)

    def test_all_k(self):
        Sn, N, L = 5, 4, 9
        local, tx = make_local_tx(Sn, N, L, seed=9)
        C = local[None] + tx[:, None, None, :]
        ref = SW.batched_optimal_dp(C, return_all_k=True, backend="jax")
        got = PD.pallas_fused_optimal_dp(local, None, tx, return_all_k=True)
        assert sorted(got) == sorted(ref)
        for n in ref:
            assert_same_or_exact_tie(ref[n], got[n], C, "sum",
                                     ctx=f"fused/all_k n={n}")

    def test_single_device_stack(self):
        local, tx = make_local_tx(6, 1, 7, seed=2)
        C = local[None] + tx[:, None, None, :]
        a = SW.batched_optimal_dp(C, backend="jax")
        b = PD.pallas_fused_optimal_dp(local, None, tx)
        assert np.array_equal(a.splits, b.splits)
        assert np.allclose(a.cost_s, b.cost_s, rtol=1e-6)

    def test_bank_idx_heterogeneous_mixes(self):
        """(bank, bank_idx) subgrouping: scenarios sharing a device
        stack share one fused launch; the scattered-back tables must
        match solving the gathered dense tensor."""
        Sn, N, L, B = 11, 3, 12, 4
        rng = np.random.RandomState(17)
        bank = rng.uniform(1e-3, 5.0, size=(B, L, L))
        il = np.tril_indices(L, -1)
        bank[:, il[0], il[1]] = INF
        tx = rng.uniform(0.0, 2.0, size=(Sn, L))
        bank_idx = rng.randint(0, B, size=(Sn, N))
        ns = make_ns(Sn, N, seed=17)
        C = bank[bank_idx] + tx[:, None, None, :]
        a = SW.batched_optimal_dp(C, n_devices=ns, backend="jax")
        b = PD.pallas_fused_optimal_dp(bank, bank_idx, tx, n_devices=ns)
        assert_same_or_exact_tie(a, b, C, "sum", ctx="bank_idx")

    def test_all_k_and_ns_mutually_exclusive(self):
        local, tx = make_local_tx(3, 2, 5, seed=1)
        with pytest.raises(ValueError, match="mutually exclusive"):
            PD.pallas_fused_optimal_dp(local, None, tx, return_all_k=True,
                                       n_devices=[1, 2, 2])
        bank_idx = np.zeros((3, 2), dtype=int)
        with pytest.raises(ValueError, match="mutually exclusive"):
            PD.pallas_fused_optimal_dp(local, bank_idx, tx,
                                       return_all_k=True, n_devices=2)

    def test_shape_validation(self):
        local, tx = make_local_tx(3, 2, 5, seed=1)
        with pytest.raises(ValueError, match="local must be"):
            PD.pallas_fused_dp_tables(local[:, :, :3], tx)
        with pytest.raises(ValueError, match="tx must be"):
            PD.pallas_fused_dp_tables(local, tx[:, :3])
        with pytest.raises(ValueError, match="bank_idx must be"):
            PD.pallas_fused_optimal_dp(local, np.zeros((4, 2), dtype=int), tx)


# ---------------------------------------------------------------------------
# Composition: sharded shard_map over the pallas tile kernel
# ---------------------------------------------------------------------------


class TestShardKernel:
    def test_sharded_pallas_node_identical(self):
        C = make_C(7, 3, 10, seed=13)
        ns = make_ns(7, 3, seed=13)
        a = SH.sharded_optimal_dp(C, n_devices=ns, kernel="jax")
        b = SH.sharded_optimal_dp(C, n_devices=ns, kernel="pallas")
        c = SW.batched_optimal_dp(C, n_devices=ns, backend="pallas")
        assert np.array_equal(a.splits, b.splits)
        assert np.array_equal(a.cost_s, b.cost_s)
        assert np.array_equal(a.feasible, b.feasible)
        assert np.array_equal(b.splits, c.splits)
        assert np.array_equal(b.cost_s, c.cost_s)

    def test_unknown_shard_kernel_rejected(self):
        C = make_C(2, 2, 5, seed=1)
        with pytest.raises(ValueError, match="unknown shard kernel"):
            SH.sharded_optimal_dp(C, kernel="mosaic")


# ---------------------------------------------------------------------------
# Integration: sweep() and build_surfaces() fused paths
# ---------------------------------------------------------------------------


def tiny_grid():
    layers = tuple(
        LayerCost(f"l{i}", t_infer_s=0.01 * (i + 1), act_bytes=200 * (5 - i),
                  param_bytes=1_000, work_bytes=500)
        for i in range(5)
    )
    prof = ModelCostProfile("toy", layers, input_bytes=128)
    links = {
        "fast": LinkProfile("fast", 512, 1e6, t_setup_s=0.1,
                            t_feedback_s=0.01),
        "slow": LinkProfile("slow", 256, 1e5, t_ack_s=1e-3, t_setup_s=0.02),
    }
    return SW.ScenarioGrid(
        models={"toy": prof},
        links=links,
        n_devices=(2, 3),
        loss_p=(None, 0.1),
        rate_scale=(1.0, 0.5),
        devices=(DeviceProfile("d", t_tensor_alloc_s=1e-3),
                 DeviceProfile("e", compute_scale=1.4),
                 DeviceProfile("f", compute_scale=0.8)),
    )


class TestSweepBackend:
    def test_sweep_pallas_vs_jax(self):
        grid = tiny_grid()
        rj = SW.sweep(grid, backend="jax")
        rp = SW.sweep(grid, backend="pallas")
        assert rp.n_scenarios == rj.n_scenarios == grid.size
        for a, b in zip(rj.rows, rp.rows):
            assert a.feasible == b.feasible
            if not a.feasible:
                continue
            assert b.objective_cost_s == pytest.approx(
                a.objective_cost_s, rel=1e-5)
            if a.splits == b.splits:
                assert b.total_latency_s == pytest.approx(
                    a.total_latency_s, rel=1e-5)
                continue
            # divergent plan: must be an exact-cost tie under the f64 oracle
            m = grid.cost_model(a.scenario)
            fn = m.cost_segment_fn()
            L = m.profile.num_layers
            ra = S.total_cost(fn, a.splits, L)
            rb = S.total_cost(fn, b.splits, L)
            assert abs(ra - rb) <= 1e-12 * max(abs(ra), 1e-300)

    def test_sweep_rejects_unknown_backend(self):
        grid = tiny_grid()
        with pytest.raises(ValueError, match="unknown backend"):
            SW.sweep(grid, backend="cuda")


def switchy_cost_model():
    layers = (
        LayerCost("l1", t_infer_s=0.01, act_bytes=1500, param_bytes=100),
        LayerCost("l2", t_infer_s=0.01, act_bytes=100, param_bytes=100,
                  work_bytes=10_000),
        LayerCost("l3", t_infer_s=0.01, act_bytes=0, param_bytes=100,
                  work_bytes=10_000),
    )
    prof = ModelCostProfile("switchy", layers)
    dev = DeviceProfile("d", tensor_alloc_s_per_byte=1e-6)
    link = LinkProfile("lk", mtu_bytes=1000, rate_bytes_per_s=1e6)
    return SplitCostModel(profile=prof, devices=(dev,), link=link)


FAMILY_GRID = {"pt_scale": (1.0, 8.0, 64.0), "loss_p": (0.0, 0.2)}


class TestSurfacesBackend:
    def test_build_surfaces_pallas_vs_jax(self):
        m = switchy_cost_model()
        fam_j = build_surfaces(m, {"lk": m.link}, (1, 2, 3),
                               solver="batched_dp", backend="jax",
                               **FAMILY_GRID)
        fam_p = build_surfaces(m, {"lk": m.link}, (1, 2, 3),
                               solver="batched_dp", backend="pallas",
                               **FAMILY_GRID)
        assert sorted(fam_p) == sorted(fam_j) == [1, 2, 3]
        for n in fam_j:
            for name in fam_j[n].protocols:
                a = fam_j[n].protocols[name]
                b = fam_p[n].protocols[name]
                assert a.packet_time_s == b.packet_time_s
                assert a.loss_p == b.loss_p
                # node latencies are host-f64 prices of the chosen plans:
                # equal-cost tie divergence keeps them allclose
                assert np.allclose(a.latency_s, b.latency_s, rtol=1e-9,
                                   equal_nan=True), (n, name)
                if not np.array_equal(a.splits, b.splits):
                    ties = a.splits != b.splits
                    assert np.allclose(a.latency_s[ties.any(axis=-1)],
                                       b.latency_s[ties.any(axis=-1)],
                                       rtol=1e-12), (n, name)


# ---------------------------------------------------------------------------
# jit caching, options, and the backend registry
# ---------------------------------------------------------------------------


class TestJitCaching:
    def test_same_shape_repeat_does_not_retrace(self):
        C = make_C(6, 3, 9, seed=23)
        SW.batched_optimal_dp(C, backend="pallas")  # warm (traces at most once)
        before = PD._PALLAS_TRACE_COUNT
        SW.batched_optimal_dp(C, backend="pallas")
        SW.batched_optimal_dp(make_C(6, 3, 9, seed=24), backend="pallas")
        assert PD._PALLAS_TRACE_COUNT == before


class TestOptionsAndRegistry:
    def test_block_s_validated(self):
        C = make_C(2, 2, 5, seed=1)
        with pytest.raises(ValueError, match="block_s"):
            PD.pallas_optimal_dp(C, block_s=0)

    def test_interpret_default_is_on_off_tpu(self):
        import jax

        if jax.default_backend() == "tpu":
            pytest.skip("TPU host: interpret defaults off")
        assert PD.pallas_interpret_default() is True

    def test_registry_is_the_backend_set(self):
        assert set(SW.DP_BACKENDS) == {"numpy", "jax", "sharded", "pallas"}
        for fn in SW.DP_BACKENDS.values():
            assert callable(fn)

    def test_unknown_backend_error_names_every_backend(self):
        """Regression: the ValueError must enumerate the live registry,
        not a hardcoded subset that rots when a backend lands."""
        C = make_C(2, 2, 5, seed=1)
        with pytest.raises(ValueError) as ei:
            SW.batched_optimal_dp(C, backend="tpu")
        msg = str(ei.value)
        assert "'tpu'" in msg
        for name in SW.DP_BACKENDS:
            assert name in msg, f"error message omits backend {name!r}"
