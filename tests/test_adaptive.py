"""Adaptive split-management tests (the paper's future-work section,
implemented): link estimation, chunk-size optimization, runtime re-planning."""

import pytest
from dataclasses import replace

from repro.core.adaptive import (
    AdaptiveSplitManager,
    LinkEstimator,
    optimize_chunk_size,
)
from repro.core.profiles import ESP_NOW, PROTOCOLS, UDP, paper_cost_model


class TestLinkEstimator:
    def test_converges_to_observed_per_packet_time(self):
        est = LinkEstimator(ESP_NOW, alpha=0.5)
        # network degraded: 10 ms/packet instead of the calibrated 3.15 ms
        for _ in range(30):
            est.observe_hop(nbytes=2500, latency_s=0.10)  # 10 packets x 10 ms
        prof = est.current_profile()
        assert prof.packet_time_s() == pytest.approx(0.010, rel=0.05)

    def test_loss_estimation_from_retries(self):
        est = LinkEstimator(UDP, alpha=0.5)
        for _ in range(30):
            est.observe_hop(nbytes=14600, latency_s=0.02, retries=2)
        assert est.current_profile().loss_p > 0.05

    def test_clean_observations_keep_profile(self):
        est = LinkEstimator(ESP_NOW, alpha=0.3)
        t = ESP_NOW.transmission_latency_s(5488)
        for _ in range(10):
            est.observe_hop(5488, t)
        prof = est.current_profile()
        assert prof.packet_time_s() == pytest.approx(ESP_NOW.packet_time_s(),
                                                     rel=0.02)

    def test_one_lucky_hop_does_not_erase_loss_prior(self):
        """Regression: the loss EWMA used to decay toward 0 by a full
        ``alpha`` step on the very first retry-free hop. With the
        warm-up seed the prior counts as ``loss_warmup`` virtual
        observations, so a single clean packet barely moves it."""
        lossy = replace(UDP, loss_p=0.10)  # calibrated prior: 10% loss
        est = LinkEstimator(lossy, alpha=0.2, loss_warmup=5)
        est.observe_hop(nbytes=1460, latency_s=0.001)  # one lucky packet
        assert est.loss_estimate >= 0.095  # kept >= 95% of the prior
        # the un-warmed estimator would have dropped to 0.08 here
        assert est.current_profile().loss_p == pytest.approx(
            est.loss_estimate)

    def test_warmup_still_converges_with_evidence(self):
        """Warm-up damps single observations, not sustained evidence:
        a long run of clean hops still drives the loss estimate down."""
        lossy = replace(UDP, loss_p=0.10)
        est = LinkEstimator(lossy, alpha=0.2, loss_warmup=5)
        for _ in range(60):
            est.observe_hop(nbytes=1460, latency_s=0.001)
        assert est.loss_estimate < 0.01

    def test_estimate_accessors_track_state(self):
        est = LinkEstimator(ESP_NOW, alpha=0.5)
        assert est.packet_time_estimate == ESP_NOW.packet_time_s()
        assert est.loss_estimate == ESP_NOW.loss_p
        est.observe_hop(5488, 10 * ESP_NOW.transmission_latency_s(5488))
        assert est.packet_time_estimate > ESP_NOW.packet_time_s()


class TestChunkOptimizer:
    def test_returned_chunk_is_argmin_of_eq7(self):
        """The optimizer returns the Eq.7-minimizing candidate. With zero
        per-packet overhead (UDP), SMALLER chunks win by reducing
        last-packet padding waste — a genuine Eq. 7 consequence the naive
        'always use full MTU' heuristic misses."""
        cuts = [150528]
        chunk, total = optimize_chunk_size(UDP, cuts)
        for cand in (250, 730, 1095, 1200, 1460):
            trial = replace(UDP, mtu_bytes=cand)
            assert total <= sum(trial.transmission_latency_s(b) for b in cuts) + 1e-12
        assert chunk < UDP.mtu_bytes  # padding waste beats fewer packets here

    def test_full_mtu_wins_when_ack_dominates(self):
        """With heavy per-packet overhead (TCP-like), fewer packets win."""
        from repro.core.profiles import TCP

        chunk, _ = optimize_chunk_size(TCP, [150528])
        assert chunk == TCP.mtu_bytes

    def test_small_payload_right_sizes_the_packet(self):
        # a 100 B payload rides one packet; a smaller chunk serializes less
        chunk, total = optimize_chunk_size(ESP_NOW, [100])
        assert 0 < chunk <= ESP_NOW.mtu_bytes
        assert total <= ESP_NOW.packet_time_s() + 1e-12


class TestAdaptiveManager:
    def _manager(self, threshold=0.10):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        return AdaptiveSplitManager(
            cost_model=m, protocols=dict(PROTOCOLS), n_devices=2,
            replan_threshold=threshold)

    def test_initial_plan_prefers_espnow(self):
        mgr = self._manager()
        # with calibrated profiles ESP-NOW has the best RTT (Table IV)
        assert mgr.current.protocol == "esp_now"
        assert mgr.current.splits  # non-trivial split for 2 devices

    def test_degraded_espnow_triggers_protocol_switch(self):
        """Runtime adaptation, two regimes (a real finding of the model):
        moderate degradation is absorbed by RE-SPLITTING (smaller cuts,
        same protocol — ESP-NOW's 48 ms setup still beats UDP's 2.13 s);
        only deep degradation (~400x) makes protocol switching pay."""
        mgr = self._manager()
        nbytes = 5488
        moderate = 100 * ESP_NOW.transmission_latency_s(nbytes)
        for _ in range(60):
            mgr.observe("esp_now", nbytes, moderate)
        assert mgr.current.protocol == "esp_now"  # re-split absorbs it

        deep = 400 * ESP_NOW.transmission_latency_s(nbytes)
        for _ in range(120):
            mgr.observe("esp_now", nbytes, deep)
        assert mgr.current.protocol != "esp_now"
        assert len(mgr.history) >= 2
        assert "available" in mgr.history[-1].reason

    def test_stable_network_does_not_thrash(self):
        mgr = self._manager()
        nbytes = 5488
        good = ESP_NOW.transmission_latency_s(nbytes)
        for _ in range(50):
            mgr.observe("esp_now", nbytes, good)
        assert len(mgr.history) == 1  # initial plan only

    def test_decisions_are_auditable(self):
        mgr = self._manager()
        d = mgr.current
        assert d.predicted_latency_s > 0
        assert d.chunk_bytes > 0
        assert d.reason == "initial"
