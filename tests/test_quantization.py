"""Quantization tests: PTQ round-trips, wire format, deployed sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quantization import (
    QTensor,
    decode_activation,
    dequantize_params,
    encode_activation,
    fake_quant,
    param_bytes,
    quantize,
    quantize_params,
)


class TestQuantize:
    @given(st.integers(0, 2**31 - 1), st.floats(0.1, 50.0))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_error_bounded_by_scale(self, seed, spread):
        x = jax.random.normal(jax.random.PRNGKey(seed % 2**31), (32, 16)) * spread
        qt = quantize(x)
        err = jnp.max(jnp.abs(qt.dequantize() - x))
        assert float(err) <= float(qt.scale) * 0.51 + 1e-6

    def test_symmetric_zero_point_is_zero(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
        qt = quantize(x, symmetric=True)
        assert int(qt.zero_point) == 0

    def test_per_channel_beats_per_tensor_on_skewed(self):
        """Per-channel scales win when channel magnitudes differ wildly."""
        rng = jax.random.PRNGKey(1)
        x = jax.random.normal(rng, (64, 4)) * jnp.array([0.01, 0.1, 1.0, 10.0])
        e_tensor = jnp.mean(jnp.abs(fake_quant(x) - x))
        e_channel = jnp.mean(jnp.abs(fake_quant(x, axis=1) - x))
        assert float(e_channel) < float(e_tensor)

    def test_constant_tensor(self):
        x = jnp.full((4, 4), 3.7)
        qt = quantize(x)
        np.testing.assert_allclose(qt.dequantize(), x, rtol=1e-2)

    def test_zeros(self):
        qt = quantize(jnp.zeros((5, 5)))
        np.testing.assert_array_equal(qt.dequantize(), jnp.zeros((5, 5)))


class TestParamsQuantization:
    def test_quantize_params_structure(self):
        params = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
            "b": jnp.zeros((8,)),
            "nested": {"k": jax.random.normal(jax.random.PRNGKey(1), (4, 4, 4))},
        }
        q = quantize_params(params)
        assert isinstance(q["w"], QTensor)
        assert isinstance(q["nested"]["k"], QTensor)
        assert not isinstance(q["b"], QTensor)  # vectors stay float

    def test_deployed_size_is_quarter_of_f32(self):
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 256))}
        raw = param_bytes(params)
        q = param_bytes(quantize_params(params))
        assert q < raw / 3.5  # int8 + scale overhead

    def test_dequantize_params_roundtrip(self):
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32)) * 0.1}
        deq = dequantize_params(quantize_params(params))
        rel = jnp.linalg.norm(deq["w"] - params["w"]) / jnp.linalg.norm(params["w"])
        assert float(rel) < 0.02


class TestWireFormat:
    def test_activation_wire_bytes_match_paper_convention(self):
        """int8 activation wire size = element count (Table II packets)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (7, 7, 112))
        qt = encode_activation(x)
        assert qt.nbytes == 7 * 7 * 112

    def test_encode_decode_small_error(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (56, 56, 48))
        back = decode_activation(encode_activation(x))
        assert float(jnp.max(jnp.abs(back - x))) < 0.05 * float(jnp.max(jnp.abs(x)))
