"""Fleet gateway + QoS telemetry tests.

Deterministic throughout: rebuilds run on a
:class:`~repro.core.async_replan.ManualExecutor`, so "a rebuild is in
flight" is an exact program state, and drift is injected by feeding
sessions observed latencies at a chosen multiple of their own modeled
nominal hop time.
"""

import asyncio
import math

import numpy as np
import pytest

from repro.core.async_replan import ManualExecutor
from repro.core.profiles import PROTOCOLS, paper_cost_model
from repro.runtime.gateway import FleetGateway
from repro.runtime.stats import QosMonitor, RollingWindow, percentile

GRID = {"pt_scale": (1.0, 4.0, 16.0), "loss_p": (0.0, 0.1)}
NBYTES = 5488
# one EWMA step at this multiple jumps the packet-time estimate past the
# 16x envelope edge: 0.8*1 + 0.2*100 = 20.8x nominal
STORM = 100.0


@pytest.fixture(scope="module")
def cost_model():
    return paper_cost_model("mobilenet_v2", "esp_now")


@pytest.fixture()
def gw(cost_model):
    ex = ManualExecutor()
    g = FleetGateway(cost_model, dict(PROTOCOLS), fleet_sizes=(2, 3),
                     executor=ex, surface_grid=GRID)
    yield g, ex
    g.close()


def _nominal(gw, sid):
    """The session's own modeled per-hop latency (an in-envelope
    observation)."""
    return gw.sessions[sid].meter.link.transmission_latency_s(NBYTES)


def _observe_round(gw, sids, factor=1.0):
    for sid in sids:
        gw.submit_observe(sid, NBYTES, _nominal(gw, sid) * factor)
    gw.pump()


class TestSessionLifecycle:
    def test_register_is_surface_lookup_not_solve(self, gw):
        g, _ = gw
        s = g.register("a", 2, bytes_per_token=NBYTES)
        assert s.manager.current is not None
        assert s.manager.exact_fallbacks == 0  # no per-registration solve
        assert s.manager.history[0].reason == "initial [surface]"
        assert s.meter.protocol == s.manager.current.protocol
        assert s.meter.link.mtu_bytes == s.manager.current.chunk_bytes

    def test_register_rejects_duplicates_and_unknown_sizes(self, gw):
        g, _ = gw
        g.register("a", 2)
        with pytest.raises(ValueError):
            g.register("a", 2)
        with pytest.raises(KeyError):
            g.register("b", 7)  # not in the prebuilt family

    def test_drop_releases_session_and_window(self, gw):
        g, _ = gw
        g.register("a", 2)
        _observe_round(g, ["a"])
        assert g.qos.window("a") is not None
        assert g.drop("a")
        assert not g.drop("a")  # idempotent-ish: unknown now
        assert "a" not in g.sessions
        assert g.qos.window("a") is None

    def test_orphaned_events_are_counted_not_crashed(self, gw):
        g, _ = gw
        g.register("a", 2)
        g.submit_observe("a", NBYTES, 1e-3)
        g.drop("a")
        assert g.pump() == 1
        assert g.qos.counters["events_orphaned"] == 1


class TestSharedRebuilderCoalescing:
    def test_n_drifting_sessions_one_build(self, gw):
        """The tentpole contract: N sessions drifting in the same cycle
        coalesce into ONE batched build_surfaces call on the single
        shared rebuilder, and every session adopts from that one
        build."""
        g, ex = gw
        n = 20
        sids = [f"s{i}" for i in range(n)]
        for sid in sids:
            g.register(sid, 2)
        _observe_round(g, sids, factor=STORM)
        assert g.rebuilder.requests >= n  # every session's drift arrived
        assert g.rebuilder.builds_started == 1  # ...as ONE launched build
        assert ex.submitted == 1
        ex.run_all()
        _observe_round(g, sids, factor=STORM)  # adoption round
        swaps = sum(g.sessions[sid].manager.surface_swaps for sid in sids)
        assert swaps == n
        adopted = {id(g.sessions[sid].manager.surface) for sid in sids}
        assert len(adopted) == 1  # the SAME surface object, one build
        assert g.rebuilder.builds_completed <= 2

    def test_mixed_sizes_batch_into_one_multisize_build(self, gw):
        g, ex = gw
        for i in range(6):
            g.register(f"s{i}", 2 + (i % 2))
        sids = [f"s{i}" for i in range(6)]
        _observe_round(g, sids, factor=STORM)
        assert g.rebuilder.builds_started == 1
        req = g.rebuilder.last_request
        assert req is not None and set(req.sizes) == {2, 3}

    def test_stale_policy_never_resolves_inline(self, gw):
        """Gateway sessions run offsurface_fallback='stale': once a
        decision exists, off-envelope drift requests a rebuild and
        serves stale — the event path never blocks on an inline exact
        re-solve."""
        g, ex = gw
        g.register("a", 2)
        for _ in range(5):
            _observe_round(g, ["a"], factor=STORM)
        m = g.sessions["a"].manager
        assert m.exact_fallbacks == 0
        assert m.stale_serves >= 1
        assert m.rebuild_requests >= 1


class TestChurnDuringRebuild:
    def test_drop_midflight_then_snapshot_publishes_result(self, gw):
        """Churn during an in-flight rebuild: the requesting session
        drops before the build lands. snapshot() sweeps the fanout so
        the completed surface is still published, and a session
        registered AFTER completion adopts it (newest generation) on
        its first observe."""
        g, ex = gw
        g.register("a", 2)
        # a lone session's poll precedes its own request, so round 1
        # queues and round 2's poll launches
        _observe_round(g, ["a"], factor=STORM)
        _observe_round(g, ["a"], factor=STORM)
        assert ex.pending() == 1  # build in flight
        g.drop("a")
        ex.run_all()
        snap = g.snapshot()  # sweeps fanout across sizes
        assert g.fanout.latest(2) is not None
        assert snap.counters["builds_completed"] == 1

        g.register("b", 2)
        _observe_round(g, ["b"])  # in-envelope observe still polls
        mb = g.sessions["b"].manager
        assert mb.surface_swaps == 1  # adopted the newer fleet surface
        assert g.sessions["b"].adoption_violations() == 0

    def test_stale_generation_never_readopted(self, gw):
        """Generation semantics per session: after adopting generation
        G, neither the fanout map nor a handle will hand back anything
        <= G — even if an older result is forced into the shared
        state."""
        g, ex = gw
        g.register("a", 2)
        _observe_round(g, ["a"], factor=STORM)  # queues
        _observe_round(g, ["a"], factor=STORM)  # poll launches
        ex.run_all()
        _observe_round(g, ["a"], factor=STORM)  # poll adopts
        sess = g.sessions["a"]
        assert sess.manager.surface_swaps == 1
        stale = g.surfaces[2]  # the original gen-0 family surface
        # try to regress the shared map with an older generation
        assert g.fanout.refresh(2) is False
        g.fanout._latest[2] = (0, stale)
        g.fanout.seq += 1
        assert sess.handle.poll(2) is None  # gen 0 <= adopted gen: refused
        assert sess.adoption_violations() == 0

    def test_churned_sessions_keep_generations_monotonic(self, gw):
        g, ex = gw
        sids = [f"s{i}" for i in range(8)]
        for sid in sids:
            g.register(sid, 2)
        for round_ in range(3):
            _observe_round(g, sids, factor=STORM * (round_ + 1))
            # churn half the fleet every round, mid-whatever-is-inflight
            for i in range(0, 8, 2):
                g.drop(f"s{i}")
                g.register(f"s{i}", 2)
            ex.run_all()
        _observe_round(g, sids)
        snap = g.snapshot()
        assert snap.counters["stale_adoption_violations"] == 0


class TestBackpressure:
    def test_shedding_is_counted(self, cost_model):
        ex = ManualExecutor()
        g = FleetGateway(cost_model, dict(PROTOCOLS), fleet_sizes=(2,),
                         executor=ex, surface_grid=GRID, max_pending=8)
        try:
            g.register("a", 2)
            accepted = sum(g.submit_observe("a", NBYTES, 1e-3)
                           for _ in range(20))
            assert accepted == 8
            assert g.qos.counters["events_shed"] == 12
            assert g.pending == 8
            assert g.pump() == 8
            assert g.qos.counters["events_processed"] == 8
            # the queue drained: admission opens again
            assert g.submit_observe("a", NBYTES, 1e-3)
        finally:
            g.close()

    def test_snapshot_reports_queue_depth(self, gw):
        g, _ = gw
        g.register("a", 2)
        g.submit_observe("a", NBYTES, 1e-3)
        snap = g.snapshot()
        assert snap.counters["queue_depth"] == 1


class TestQosStats:
    def test_percentile_matches_numpy_oracle(self):
        rng = np.random.default_rng(0)
        for n in (1, 2, 3, 7, 100, 257):
            vals = rng.exponential(1.0, size=n).tolist()
            for q in (0.0, 25.0, 50.0, 90.0, 99.0, 100.0):
                assert percentile(vals, q) == pytest.approx(
                    float(np.percentile(vals, q)), rel=1e-12, abs=0.0)

    def test_percentile_rejects_bad_inputs(self):
        with pytest.raises(ValueError):
            percentile([], 50.0)
        with pytest.raises(ValueError):
            percentile([1.0], 101.0)

    def test_rolling_window_retains_last_maxlen(self):
        w = RollingWindow(maxlen=4)
        for i in range(10):
            w.add(float(i))
        assert w.count == 10
        assert sorted(w.values()) == [6.0, 7.0, 8.0, 9.0]
        assert w.percentile(50.0) == 7.5
        assert w.percentiles((50.0, 100.0)) == (7.5, 9.0)

    def test_qos_monitor_keys_and_fleet(self):
        q = QosMonitor(key_window=4, global_window=16)
        for i in range(8):
            q.record("a", float(i))
        p50, p99 = q.key_percentiles("a")
        assert p50 == 5.5  # last 4 samples: 4..7
        assert q.fleet_percentiles((50.0,))[0] == 3.5  # all 8 retained
        assert math.isnan(q.key_percentiles("missing")[0])
        q.drop("a")
        assert q.window("a") is None

    def test_gateway_percentiles_against_numpy(self, cost_model):
        """End-to-end: observe timings recorded by a real pump match
        np.percentile over the same retained window."""
        ex = ManualExecutor()
        ticks = iter(range(10_000))
        g = FleetGateway(cost_model, dict(PROTOCOLS), fleet_sizes=(2,),
                         executor=ex, surface_grid=GRID,
                         clock=lambda: float(next(ticks)))
        try:
            g.register("a", 2)
            for _ in range(50):
                g.submit_observe("a", NBYTES, _nominal(g, "a"))
            g.pump()
            snap = g.snapshot()
            window = np.asarray(g.qos.global_window.values())
            assert snap.p50_s == float(np.percentile(window, 50.0))
            assert snap.p99_s == float(np.percentile(window, 99.0))
            assert snap.observes == 50
        finally:
            g.close()


class TestSnapshot:
    def test_counters_aggregate_across_sessions(self, gw):
        g, ex = gw
        sids = ["a", "b", "c"]
        for sid in sids:
            g.register(sid, 2)
        _observe_round(g, sids)
        snap = g.snapshot(include_sessions=True)
        assert snap.n_sessions == 3
        assert snap.counters["surface_hits"] == sum(
            g.sessions[s].manager.surface_hits for s in sids)
        assert snap.counters["registrations"] == 3
        assert len(snap.sessions) == 3
        by_id = {s.session_id: s for s in snap.sessions}
        assert by_id["a"].observes == 1
        assert not math.isnan(by_id["a"].p50_s)

    def test_snapshot_seq_increments(self, gw):
        g, _ = gw
        assert g.snapshot().seq == 1
        assert g.snapshot().seq == 2


class TestAsyncioServe:
    def test_serve_pumps_until_stopped(self, cost_model):
        ex = ManualExecutor()
        g = FleetGateway(cost_model, dict(PROTOCOLS), fleet_sizes=(2,),
                         executor=ex, surface_grid=GRID)

        async def scenario():
            task = asyncio.create_task(g.serve(batch=8, idle_sleep_s=0.0))
            g.register("a", 2, bytes_per_token=NBYTES)
            for _ in range(20):
                g.submit_observe("a", NBYTES, _nominal(g, "a"))
                g.submit_token("a")
            while g.pending:
                await asyncio.sleep(0)
            g.stop()
            await task

        try:
            asyncio.run(scenario())
            assert g.qos.counters["events_processed"] == 40
            assert g.qos.counters["tokens_processed"] == 20
            assert g.sessions["a"].tokens == 20
            assert len(g.token_window) == 20
        finally:
            g.close()
