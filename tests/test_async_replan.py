"""Async surface replanning tests: stale-while-revalidate semantics.

Everything here is deterministic — rebuild jobs run on a
:class:`ManualExecutor` only when the test says so, so "a rebuild is in
flight" is an exact program state (no sleeps, no races).
"""

import numpy as np
import pytest
from dataclasses import replace

from repro.core.adaptive import AdaptiveSplitManager, fleet_managers
from repro.core.async_replan import (
    ManualExecutor,
    SurfaceRebuilder,
    recentered_axes,
)
from repro.core.profiles import ESP_NOW, PROTOCOLS, paper_cost_model
from repro.core.surface import DegradationSurface

GRID = {"pt_scale": (1.0, 4.0, 16.0), "loss_p": (0.0, 0.1)}
NBYTES = 5488


def _mgr(executor, n_devices=2, **kw):
    return AdaptiveSplitManager(
        cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
        protocols=dict(PROTOCOLS), n_devices=n_devices,
        solver="optimal_dp", surface_grid=GRID, async_rebuild=executor, **kw)


def _drive(mgr, factor, steps, protocol="esp_now"):
    lat = factor * ESP_NOW.transmission_latency_s(NBYTES)
    for _ in range(steps):
        mgr.observe(protocol, NBYTES, lat)


def _settle_and_adopt(mgr, ex, factor, max_cycles=6):
    """Drive the drifted estimate to its EWMA fixed point, then run
    rebuild cycles until the (settled) state is covered by the adopted
    surface. Returns the number of cycles used."""
    _drive(mgr, factor, 80)  # EWMA converges; rebuilds queue meanwhile
    for cycle in range(1, max_cycles + 1):
        ex.run_all()
        _drive(mgr, factor, 2)  # poll: adopt / launch the re-centered build
        est = mgr.estimators["esp_now"]
        if mgr.surface.in_envelope("esp_now", est.packet_time_estimate,
                                   est.loss_estimate):
            return cycle
    raise AssertionError("drifted state never covered by a rebuilt surface")


def _assert_node_identical(a: DegradationSurface, b: DegradationSurface):
    assert sorted(a.protocols) == sorted(b.protocols)
    for name in a.protocols:
        pa, pb = a.protocols[name], b.protocols[name]
        assert pa.packet_time_s == pb.packet_time_s, name
        assert pa.loss_p == pb.loss_p, name
        assert np.array_equal(pa.splits, pb.splits), name
        assert np.array_equal(pa.chunk_bytes, pb.chunk_bytes), name
        assert np.array_equal(pa.latency_s, pb.latency_s), name
        assert np.array_equal(pa.runner_splits, pb.runner_splits), name
        assert np.array_equal(pa.runner_latency_s, pb.runner_latency_s), name


class TestManualExecutor:
    def test_fifo_and_counts(self):
        ex = ManualExecutor()
        order = []
        ex.submit(lambda: order.append("a"))
        ex.submit(lambda: order.append("b"))
        assert ex.pending() == 2 and ex.submitted == 2 and ex.executed == 0
        assert ex.run_next()
        assert order == ["a"]
        assert ex.run_all() == 1
        assert order == ["a", "b"]
        assert not ex.run_next()
        assert ex.executed == 2


class TestRecenteredAxes:
    def test_extends_base_axes_and_covers_state(self):
        base = dict(PROTOCOLS)
        pt = ESP_NOW.packet_time_s() * 300.0
        pts, losses = recentered_axes(
            base, {"esp_now": (pt, 0.25)},
            pt_scale=(1.0, 4.0), loss_p=(0.0, 0.1))
        assert set((1.0, 4.0)) <= set(pts)  # base axes preserved
        assert 300.0 in {round(s, 6) for s in pts}  # ratio * pt_pad 1.0
        assert max(pts) >= 300.0  # headroom above the drifted state
        assert 0.25 in losses and 0.5 in losses  # exact + padded loss

    def test_multiple_state_maps_merge(self):
        pt = ESP_NOW.packet_time_s()
        pts, _ = recentered_axes(
            dict(PROTOCOLS),
            [{"esp_now": (pt * 50, 0.0)}, {"esp_now": (pt * 900, 0.0)}],
            pt_scale=(1.0,), loss_p=(0.0,))
        rounded = {round(s, 6) for s in pts}
        assert 50.0 in rounded and 900.0 in rounded

    def test_pt_pad_must_reach_the_state(self):
        with pytest.raises(ValueError, match="pt_pad"):
            recentered_axes(dict(PROTOCOLS),
                            {"esp_now": (1.0, 0.0)}, pt_pad=(0.25, 0.5))


class TestNoBlocking:
    def test_observe_serves_stale_surface_while_rebuild_in_flight(self):
        """The core stale-while-revalidate contract: out-of-envelope
        observes keep returning (stale decision or bounded exact
        fallback) while the queued rebuild has NOT run."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        _drive(mgr, 1, 10)
        assert mgr.surface_hits == 10 and ex.pending() == 0
        pre_surface = mgr.surface
        _drive(mgr, 5000, 80)  # way beyond the 16x envelope
        # every observe returned; the rebuild is queued but NOT executed
        assert mgr._step == 90
        assert ex.pending() == 1
        assert mgr.surface is pre_surface  # no swap before the build ran
        assert mgr.stale_serves > 0  # the in-flight window served stale
        # the exact fallback is BOUNDED: it ran only on material moves,
        # not on every out-of-envelope observe
        assert 0 < mgr.exact_fallbacks < 20
        assert mgr.current is not None  # decisions kept flowing

    def test_sync_manager_resolves_every_observe(self):
        """Baseline contrast: without async_rebuild every out-of-envelope
        observe pays the exact re-solve."""
        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2,
            solver="optimal_dp", surface_grid=GRID)
        _drive(mgr, 5000, 30)
        assert mgr.exact_fallbacks == 30


class TestCoalescing:
    def test_n_drift_events_queue_at_most_one_rebuild(self):
        ex = ManualExecutor()
        mgr = _mgr(ex)
        _drive(mgr, 5000, 200)  # 200 drift events
        rb = mgr._rebuilder
        assert rb.builds_started == 1  # ONE build launched...
        assert ex.pending() == 1  # ...and at most one in the executor
        assert len(rb._queued) <= 1  # plus at most ONE coalesced follow-up
        assert rb.requests_coalesced >= 1

    def test_covered_requests_drop_into_inflight(self):
        """A request whose state the in-flight build already covers does
        not queue a follow-up."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        _drive(mgr, 30, 100)  # settles at ~30x; first build covers to 4x that
        rb = mgr._rebuilder
        assert rb.builds_started == 1
        assert rb._queued == {}  # follow-ups were covered, none queued
        assert rb.requests_coalesced >= 1


class TestAdoption:
    def test_async_adopted_surface_node_identical_to_sync_build(self):
        """Adoption parity: the swapped-in surface must be node-identical
        to the same build_surfaces call made synchronously."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        _drive(mgr, 30, 100)
        req = mgr._rebuilder.last_request
        ex.run_all()
        _drive(mgr, 30, 1)  # poll adopts
        assert mgr.surface_swaps == 1
        _assert_node_identical(mgr.surface,
                               mgr._rebuilder.build_sync(req)[2])

    def test_adopted_surface_covers_drift_and_restores_o1_path(self):
        ex = ManualExecutor()
        mgr = _mgr(ex)
        cycles = _settle_and_adopt(mgr, ex, 5000)
        assert cycles <= 3 and mgr.surface_swaps >= 1
        h0, f0, s0 = mgr.surface_hits, mgr.exact_fallbacks, mgr.stale_serves
        _drive(mgr, 5000, 40)
        assert mgr.surface_hits == h0 + 40  # O(1) lookups again
        assert mgr.exact_fallbacks == f0 and mgr.stale_serves == s0

    def test_adopted_decision_matches_sync_resolve_manager(self):
        """End state parity with the always-re-solve oracle manager."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        _settle_and_adopt(mgr, ex, 400)
        oracle = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2,
            solver="optimal_dp", surface=None)
        _drive(oracle, 400, 82)
        _drive(oracle, 400, 4)  # same total observe count as mgr
        assert mgr.current.protocol == oracle.current.protocol
        assert mgr.current.splits == oracle.current.splits

    def test_generation_versioning_never_readopts(self):
        """A completed build is adopted exactly once; polling again (or a
        re-posted stale generation) cannot swap the surface twice."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        _drive(mgr, 30, 100)
        ex.run_all()
        _drive(mgr, 30, 20)
        assert mgr.surface_swaps == 1
        rb = mgr._rebuilder
        assert rb.poll(2) is None  # nothing new
        # a stale generation posted late must NOT be handed out
        stale_surface = mgr.surface
        rb._results[2] = (0, stale_surface)  # older than the adopted gen
        rb._maybe_actionable = True
        assert rb.poll(2) is None
        _drive(mgr, 30, 5)
        assert mgr.surface_swaps == 1

    def test_rebuild_error_surfaces_on_poll(self, monkeypatch):
        ex = ManualExecutor()
        mgr = _mgr(ex)
        monkeypatch.setattr(mgr._rebuilder, "build_sync",
                            lambda req: (_ for _ in ()).throw(
                                RuntimeError("boom")))
        _drive(mgr, 5000, 10)
        ex.run_all()  # the job stashes the error
        with pytest.raises(RuntimeError, match="rebuild failed"):
            _drive(mgr, 5000, 2)

    def test_transient_failure_recovers_with_a_new_rebuild(self):
        """Regression: a failed build must not permanently disable
        revalidation. With the estimate SETTLED (inside the staleness
        tolerance) a transient failure once left the manager serving
        the stale surface forever; now the error resets the staleness
        window so the next drifted observe re-requests, and the retry
        build adopts normally."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        rb = mgr._rebuilder
        _drive(mgr, 30, 100)  # settle well inside the staleness window
        real_build = rb.build_sync
        fail_once = {"left": 1}

        def flaky(req):
            if fail_once["left"]:
                fail_once["left"] -= 1
                raise RuntimeError("transient solver failure")
            return real_build(req)

        rb.build_sync = flaky
        ex.run_all()  # build 1 fails; error stashed
        with pytest.raises(RuntimeError, match="rebuild failed"):
            _drive(mgr, 30, 1)
        # the estimate has NOT moved materially — recovery must not
        # depend on fresh drift
        _drive(mgr, 30, 5)
        assert rb.builds_started == 2  # re-requested after the failure
        ex.run_all()
        _drive(mgr, 30, 2)
        assert mgr.surface_swaps == 1  # the retry adopted
        est = mgr.estimators["esp_now"]
        assert mgr.surface.in_envelope("esp_now", est.packet_time_estimate,
                                       est.loss_estimate)

    def test_async_requires_surface_capable_solver(self):
        with pytest.raises(ValueError, match="async_rebuild"):
            AdaptiveSplitManager(
                cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
                protocols=dict(PROTOCOLS), n_devices=2,
                solver="first_fit", async_rebuild=True)


class TestFleetSharedRebuilder:
    def test_fleet_drift_batches_into_one_multi_size_solve(self):
        """Two managers drift while sharing one rebuilder: ONE
        build_surfaces call answers both fleet sizes, and each manager
        adopts its own node-identical surface."""
        ex = ManualExecutor()
        mgrs = fleet_managers(
            paper_cost_model("mobilenet_v2", "esp_now"), dict(PROTOCOLS),
            (2, 3), solver="optimal_dp", surface_grid=GRID,
            async_rebuild=ex)
        rb = mgrs[2]._rebuilder
        assert rb is mgrs[3]._rebuilder  # ONE shared rebuilder
        # both managers drift before any build launches: both sizes queue
        lat = 30 * ESP_NOW.transmission_latency_s(NBYTES)
        mgrs[2].observe("esp_now", NBYTES, lat * 167)  # jump past envelope
        mgrs[3].observe("esp_now", NBYTES, lat * 167)
        assert sorted(rb._queued) == [2, 3]
        # next polls launch ONE build carrying BOTH sizes
        _drive(mgrs[2], 5000, 30)
        _drive(mgrs[3], 5000, 30)
        assert rb.builds_started == 1
        assert rb.last_request.sizes == (2, 3)
        assert ex.pending() == 1
        req = rb.last_request
        ex.run_all()
        _drive(mgrs[2], 5000, 1)
        _drive(mgrs[3], 5000, 1)
        assert mgrs[2].surface_swaps == 1 and mgrs[3].surface_swaps == 1
        sync = rb.build_sync(req)
        _assert_node_identical(mgrs[2].surface, sync[2])
        _assert_node_identical(mgrs[3].surface, sync[3])
        assert mgrs[2].surface.n_devices == 2
        assert mgrs[3].surface.n_devices == 3

    def test_fleet_async_accepts_prebuilt_rebuilder(self):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        rb = SurfaceRebuilder(m, dict(PROTOCOLS), solver="batched_dp",
                              executor=ManualExecutor(), **GRID)
        mgrs = fleet_managers(m, dict(PROTOCOLS), (2, 3),
                              solver="optimal_dp", surface_grid=GRID,
                              async_rebuild=rb)
        assert mgrs[2]._rebuilder is rb and mgrs[3]._rebuilder is rb


class TestSurfaceCovers:
    def test_covers_matches_in_envelope(self):
        mgr = _mgr(ManualExecutor())
        surf = mgr.surface
        pt = ESP_NOW.packet_time_s()
        good = {name: (p.packet_time_s(), p.loss_p)
                for name, p in PROTOCOLS.items()}
        assert surf.covers(good)
        bad = dict(good, esp_now=(pt * 1e4, 0.0))
        assert not surf.covers(bad)

    def test_stale_window_resets_on_return_to_envelope(self):
        """After re-entering the envelope, the next excursion must
        re-solve immediately (fresh staleness window), not serve the
        previous excursion's stale state."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        _drive(mgr, 5000, 80)
        assert mgr._fallback_state is not None
        _drive(mgr, 1, 200)  # recover into the envelope
        assert mgr._fallback_state is None
        f0 = mgr.exact_fallbacks
        _drive(mgr, 5000, 5)
        assert mgr.exact_fallbacks > f0  # fresh excursion re-solved


class TestDefaultExecutor:
    def test_background_thread_rebuild_adopts(self):
        """async_rebuild=True uses a real worker thread; the build is
        awaited explicitly (executor shutdown barrier), never slept on."""
        mgr = _mgr(True)
        _drive(mgr, 30, 100)
        rb = mgr.rebuilder
        assert rb is mgr._rebuilder and rb.builds_started >= 1
        rb.shutdown()  # barrier: waits for the in-flight build
        _drive(mgr, 30, 2)
        assert mgr.surface_swaps >= 1
        est = mgr.estimators["esp_now"]
        assert mgr.surface.in_envelope("esp_now", est.packet_time_estimate,
                                       est.loss_estimate)
        mgr.close()  # idempotent with the earlier shutdown

    def test_shutdown_is_terminal(self):
        """Regression: after shutdown() a queued request must NOT
        resurrect a fresh thread pool on the next poll."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        rb = mgr._rebuilder
        rb.shutdown()
        _drive(mgr, 5000, 20)  # drift: requests queue...
        assert rb._queued  # ...but nothing ever launches
        assert rb.builds_started == 0
        assert ex.pending() == 0
        assert rb._executor is ex  # and no internal pool was created
        # observes still flow (stale serves + bounded fallbacks)
        assert mgr._step == 20

    def test_close_leaves_shared_rebuilder_running(self):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        rb = SurfaceRebuilder(m, dict(PROTOCOLS), solver="batched_dp",
                              executor=ManualExecutor(), **GRID)
        mgrs = fleet_managers(m, dict(PROTOCOLS), (2,),
                              solver="optimal_dp", surface_grid=GRID,
                              async_rebuild=rb)
        mgrs[2].close()
        assert not rb._closed  # shared: the owner shuts it down
        rb.shutdown()
        assert rb._closed


class TestLossClampCeiling:
    def test_loss_above_clamp_refits_identically(self):
        """refit_link maps every loss at or above LOSS_CLAMP to the
        identical link — the precondition for clamping lookups."""
        from repro.core.surface import LOSS_CLAMP, refit_link

        pt = ESP_NOW.packet_time_s() * 10
        assert refit_link(ESP_NOW, pt, 0.97) \
            == refit_link(ESP_NOW, pt, LOSS_CLAMP)

    def test_loss_above_clamp_stays_in_envelope(self):
        """Regression: a loss estimate above 0.9 could never land inside
        any envelope (axes cap at the clamp), so every rebuild cycle
        missed and re-queued forever. Lookups now clamp the loss
        coordinate exactly."""
        from repro.core.surface import build_surface

        m = paper_cost_model("mobilenet_v2", "esp_now")
        surf = build_surface(m, dict(PROTOCOLS), 2,
                             pt_scale=(1.0, 4.0), loss_p=(0.0, 0.9))
        assert surf.in_envelope("esp_now", ESP_NOW.packet_time_s(), 0.97)
        hit = surf.lookup("esp_now", ESP_NOW.packet_time_s(), 0.97)
        ref = surf.lookup("esp_now", ESP_NOW.packet_time_s(), 0.9)
        assert hit.in_envelope
        assert hit.splits == ref.splits
        assert hit.latency_s == ref.latency_s
        # but an axis BELOW the clamp still rejects heavier loss
        small = build_surface(m, dict(PROTOCOLS), 2,
                              pt_scale=(1.0, 4.0), loss_p=(0.0, 0.3))
        assert not small.in_envelope("esp_now", ESP_NOW.packet_time_s(), 0.5)

    def test_saturated_loss_rebuild_converges(self):
        """End to end: estimator loss forced past the clamp, drift
        triggers ONE re-centered rebuild whose adopted surface covers
        the saturated state — no endless rebuild cycle."""
        ex = ManualExecutor()
        mgr = _mgr(ex)
        est = mgr.estimators["esp_now"]
        est._loss = 0.95  # beyond the clamp; raw EWMA can reach this
        _drive(mgr, 30, 100)
        for _ in range(4):  # cycles enough for any re-centering
            ex.run_all()
            _drive(mgr, 30, 2)
        assert mgr.surface_swaps >= 1
        assert mgr.surface.in_envelope("esp_now", est.packet_time_estimate,
                                       est.loss_estimate)
        b0 = mgr._rebuilder.builds_started
        _drive(mgr, 30, 40)
        assert mgr._rebuilder.builds_started == b0  # no rebuild churn


class TestObserveStateSingleSourcing:
    def test_envelope_lookup_uses_estimate_accessors(self, monkeypatch):
        """Regression (warm-up window): observe() must read the estimator
        through packet_time_estimate/loss_estimate — the same accessors
        the re-solve path prices with — not the raw EWMA fields. With
        the accessors reporting an out-of-envelope state, a healthy raw
        field must NOT keep the lookup on the surface."""
        from repro.core.adaptive import LinkEstimator

        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2,
            solver="optimal_dp", surface_grid=GRID)
        far = ESP_NOW.packet_time_s() * 1e6
        monkeypatch.setattr(LinkEstimator, "packet_time_estimate",
                            property(lambda self: far))
        _drive(mgr, 1, 1)  # raw fields stay healthy/in-envelope
        assert mgr.surface_hits == 0
        assert mgr.exact_fallbacks == 1  # the accessor view won

    def test_warmup_loss_view_is_consistent_across_paths(self):
        """During the loss warm-up window the surface lookup and the
        exact re-solve must see the SAME loss value."""
        lossy = {name: replace(p, loss_p=0.10)
                 for name, p in PROTOCOLS.items()}
        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=lossy, n_devices=2, solver="optimal_dp",
            surface_grid={"pt_scale": (1.0, 4.0, 16.0),
                          "loss_p": (None, 0.0, 0.3)})
        est = mgr.estimators["esp_now"]
        # one lucky retry-free hop inside the warm-up window
        mgr.observe("esp_now", NBYTES,
                    ESP_NOW.transmission_latency_s(NBYTES))
        assert est.n_obs <= est.loss_warmup  # still warming up
        assert mgr.surface_hits == 1  # primed loss stayed in-envelope
        # the state the lookup used IS the state the re-solve prices
        assert est.current_profile().loss_p == pytest.approx(
            est.loss_estimate)


class TestPollVersioned:
    def _rebuilt(self, ex):
        """A rebuilder with one completed build for size 2."""
        rb = SurfaceRebuilder(paper_cost_model("mobilenet_v2", "esp_now"),
                              dict(PROTOCOLS), executor=ex, **GRID)
        drift = {"esp_now": (20 * ESP_NOW.packet_time_s(), 0.0)}
        rb.request(2, drift)
        assert rb.poll_versioned(2) is None  # launches
        ex.run_all()
        return rb

    def test_handover_carries_generation_exactly_once(self):
        ex = ManualExecutor()
        rb = self._rebuilt(ex)
        got = rb.poll_versioned(2)
        assert got is not None
        gen, surf = got
        assert gen == 1
        assert isinstance(surf, DegradationSurface)
        assert rb.poll_versioned(2) is None  # exactly once
        assert rb.poll(2) is None

    def test_legacy_poll_unwraps_the_same_handover(self):
        ex = ManualExecutor()
        rb = self._rebuilt(ex)
        surf = rb.poll(2)
        assert isinstance(surf, DegradationSurface)
        assert rb.poll_versioned(2) is None


class TestRebuildFanout:
    def _fanout_with_build(self):
        from repro.core.async_replan import RebuildFanout

        ex = ManualExecutor()
        rb = SurfaceRebuilder(paper_cost_model("mobilenet_v2", "esp_now"),
                              dict(PROTOCOLS), executor=ex, **GRID)
        fo = RebuildFanout(rb)
        drift = {"esp_now": (20 * ESP_NOW.packet_time_s(), 0.0)}
        rb.request(2, drift)
        assert fo.refresh(2) is False  # launches; nothing completed yet
        ex.run_all()
        return fo, ex, drift

    def test_one_build_redistributes_to_every_handle(self):
        fo, ex, _ = self._fanout_with_build()
        handles = [fo.view() for _ in range(5)]
        surfs = [h.poll(2) for h in handles]
        assert all(s is not None for s in surfs)
        assert len({id(s) for s in surfs}) == 1  # the SAME surface object
        assert [h.adoptions for h in handles] == [[(2, 1)]] * 5
        # steady state after adoption: every handle answers None
        assert all(h.poll(2) is None for h in handles)

    def test_refresh_publishes_then_is_idempotent(self):
        fo, ex, _ = self._fanout_with_build()
        assert fo.refresh(2) is True
        assert fo.latest(2)[0] == 1
        assert fo.refresh(2) is False  # drained: exactly-once upstream
        assert fo.seq == 1

    def test_refresh_rejects_older_generation(self):
        fo, ex, drift = self._fanout_with_build()
        assert fo.refresh(2) is True
        newer = fo.latest(2)
        # force an out-of-order completion into the upstream handover
        fo.rebuilder._results[2] = (0, newer[1])
        fo.rebuilder._maybe_actionable = True
        assert fo.refresh(2) is False  # gen 0 <= adopted gen 1 upstream
        assert fo.latest(2) == newer

    def test_handle_never_readopts_older_generation(self):
        fo, ex, _ = self._fanout_with_build()
        h = fo.view()
        assert h.poll(2) is not None  # adopted gen 1
        stale = fo.latest(2)[1]
        fo._latest[2] = (0, stale)  # regress the shared map by force
        fo.seq += 1
        assert h.poll(2) is None  # refused: gen 0 <= adopted gen 1
        assert h.adoptions == [(2, 1)]
        # a FRESH handle does adopt from the (regressed) map — per-handle
        # monotonicity, not global erasure
        assert fo.view().poll(2) is stale

    def test_handle_request_reaches_shared_rebuilder(self):
        fo, ex, drift = self._fanout_with_build()
        h = fo.view()
        assert h.request(3, drift) == "queued"
        assert h.poll(3) is None  # launches the size-3 build
        assert fo.rebuilder.builds_started == 2
        ex.run_all()
        assert h.poll(3) is not None
        assert h.shutdown() is None  # no-op: shared rebuilder stays up
        assert fo.rebuilder._closed is False


class TestBoundedQueuedStates:
    def test_overflow_folds_into_last_entry_by_max(self):
        rb = SurfaceRebuilder(paper_cost_model("mobilenet_v2", "esp_now"),
                              dict(PROTOCOLS), executor=ManualExecutor(),
                              max_queued_states=2, **GRID)
        pt = ESP_NOW.packet_time_s()
        rb.request(2, {"esp_now": (10 * pt, 0.01)})
        rb.request(2, {"esp_now": (20 * pt, 0.02)})
        # past the cap: folded into the LAST entry, per-protocol max
        assert rb.request(2, {"esp_now": (15 * pt, 0.05)}) == "coalesced"
        assert rb.request(2, {"esp_now": (40 * pt, 0.03)}) == "coalesced"
        assert len(rb._queued[2]) == 2
        assert rb._queued[2][0] == {"esp_now": (10 * pt, 0.01)}
        folded = rb._queued[2][1]["esp_now"]
        assert folded == (40 * pt, 0.05)  # max over the folded requests

    def test_distinct_requesters_all_recenter_the_build(self):
        """Regression: a single merged dict kept only the LAST
        requester's target — sessions drifting to different points got a
        surface centered on one of them. Every under-cap requester's
        state must reach recentered_axes."""
        ex = ManualExecutor()
        rb = SurfaceRebuilder(paper_cost_model("mobilenet_v2", "esp_now"),
                              dict(PROTOCOLS), executor=ex, **GRID)
        pt = ESP_NOW.packet_time_s()
        rb.request(2, {"esp_now": (10 * pt, 0.0)})
        rb.request(2, {"esp_now": (30 * pt, 0.0)})
        rb.poll(2)  # launch
        req = rb.last_request
        # both requesters' ratios survive (x the 1.0 pad factor)
        assert any(abs(s - 10.0) < 1e-9 for s in req.pt_scale)
        assert any(abs(s - 30.0) < 1e-9 for s in req.pt_scale)


class TestExecutorContract:
    """The executor protocol: submit() is required, shutdown() is
    optional, and a dead executor is a failed build — never a crash in
    the serving thread or in close()."""

    def _rb(self, executor):
        return SurfaceRebuilder(paper_cost_model("mobilenet_v2", "esp_now"),
                                dict(PROTOCOLS), executor=executor, **GRID)

    def test_dead_process_pool_surfaces_error_not_crash(self):
        """Regression: submitting to an already-terminated
        ProcessPoolExecutor raised out of poll() and left _inflight
        wedged. The submit failure must surface like any failed build
        (stashed, re-raised once) and leave the rebuilder serviceable."""
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor

        pool = ProcessPoolExecutor(max_workers=1,
                                   mp_context=mp.get_context("spawn"))
        pool.shutdown(wait=True)  # dead before the rebuilder ever submits
        rb = self._rb(pool)
        pt = ESP_NOW.packet_time_s()
        assert rb.request(2, {"esp_now": (10 * pt, 0.0)}) == "queued"
        with pytest.raises(RuntimeError,
                           match="async surface rebuild failed"):
            rb.poll(2)  # launches: submit raises, error is stashed
            rb.poll(2)  # stashed error re-raised here at the latest
        assert rb.inflight() is None  # not wedged on the failed launch
        # still serviceable: errors re-raise once, then polls are clean
        assert rb.poll(2) is None
        # and shutdown() tolerates the dead injected pool (and is
        # idempotent)
        rb.shutdown()
        rb.shutdown()

    def test_shutdown_tolerates_executor_without_shutdown(self):
        """ManualExecutor has no shutdown() — the contract says that is
        fine, including after the rebuilder created nothing itself."""
        rb = self._rb(ManualExecutor())
        pt = ESP_NOW.packet_time_s()
        rb.request(2, {"esp_now": (10 * pt, 0.0)})
        rb.shutdown()
        rb.shutdown()

    def test_shutdown_tolerates_broken_own_executor(self):
        """Even the internally created executor is closed defensively:
        a shutdown() that raises must not escape close()."""
        class _ExplodingExecutor:
            def submit(self, fn):  # pragma: no cover - never launched
                raise AssertionError("not used")

            def shutdown(self, wait=True):
                raise OSError("pool already reaped")

        rb = self._rb(None)
        rb._executor = _ExplodingExecutor()
        rb._own_executor = True
        rb.shutdown()  # must swallow the OSError
        assert rb._executor is None

    def test_process_pool_build_adopts_and_matches_sync(self):
        """Live process pool: the pickled-spec build path publishes with
        the same generation/swap semantics and the adopted surface is
        node-identical to the synchronous build."""
        import time as _time
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor

        pool = ProcessPoolExecutor(max_workers=1,
                                   mp_context=mp.get_context("spawn"))
        rb = self._rb(pool)
        try:
            pt = ESP_NOW.packet_time_s()
            rb.request(2, {"esp_now": (10 * pt, 0.01)})
            got = None
            deadline = _time.monotonic() + 120.0
            while got is None and _time.monotonic() < deadline:
                got = rb.poll(2)
                if got is None:
                    _time.sleep(0.05)
            assert got is not None, "process-pool rebuild never adopted"
            assert rb.builds_completed == 1 and rb.inflight() is None
            _assert_node_identical(got, rb.build_sync(rb.last_request)[2])
        finally:
            rb.shutdown()
            pool.shutdown(wait=True)
