"""Sweep-engine tests: cost-tensor export exactness, batched-vs-scalar
solver parity (property-style, randomized grids), and the ScenarioGrid
fleet API."""

import math
from dataclasses import replace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import solvers as S
from repro.core import sweep as SW
from repro.core.latency import (
    DeviceProfile,
    LayerCost,
    LinkProfile,
    ModelCostProfile,
    SplitCostModel,
)
from repro.core.planner import plan_split, plan_split_batch

INF = float("inf")


# ---------------------------------------------------------------------------
# Instance generators
# ---------------------------------------------------------------------------


def synthetic_model(draw, L):
    layers = tuple(
        LayerCost(
            name=f"l{i}",
            t_infer_s=draw(st.floats(1e-4, 0.5)),
            act_bytes=draw(st.integers(0, 20_000)),
            param_bytes=draw(st.integers(0, 200_000)),
            work_bytes=draw(st.integers(0, 50_000)),
            flops=draw(st.floats(0.0, 1e9)),
        )
        for i in range(L)
    )
    return ModelCostProfile(name="synth", layers=layers,
                            input_bytes=draw(st.integers(0, 5_000)))


def synthetic_link(draw):
    return LinkProfile(
        name="lk",
        mtu_bytes=draw(st.integers(64, 2048)),
        rate_bytes_per_s=draw(st.floats(1e4, 1e7)),
        loss_p=draw(st.floats(0.0, 0.3)),
        t_prop_s=draw(st.floats(0.0, 1e-3)),
        t_ack_s=draw(st.floats(0.0, 5e-3)),
        t_setup_s=draw(st.floats(0.0, 1.0)),
        t_feedback_s=draw(st.floats(0.0, 0.05)),
    )


def synthetic_device(draw, constrain_mem):
    mem = draw(st.integers(150_000, 400_000)) if constrain_mem else None
    return DeviceProfile(
        name="dev",
        compute_scale=draw(st.floats(0.5, 2.0)),
        t_model_load_s=draw(st.floats(0.0, 1e-3)),
        model_load_s_per_byte=draw(st.floats(0.0, 1e-9)),
        t_input_load_s=draw(st.floats(0.0, 1e-2)),
        t_tensor_alloc_s=draw(st.floats(0.0, 1e-2)),
        tensor_alloc_s_per_byte=draw(st.floats(0.0, 1e-7)),
        t_buffer_s=draw(st.floats(0.0, 1e-3)),
        buffer_s_per_byte=draw(st.floats(0.0, 1e-8)),
        mem_limit_bytes=mem,
    )


@st.composite
def cost_models(draw):
    L = draw(st.integers(3, 12))
    prof = synthetic_model(draw, L)
    dev = synthetic_device(draw, constrain_mem=draw(st.integers(0, 1)) == 1)
    link = synthetic_link(draw)
    return SplitCostModel(profile=prof, devices=(dev,), link=link)


@st.composite
def random_tensors(draw):
    """Raw stacked cost tensors with sprinkled +inf (device-independent
    of any physical model — pure solver-contract instances)."""
    L = draw(st.integers(3, 10))
    N = draw(st.integers(1, min(5, L)))
    Sn = draw(st.integers(2, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    inf_frac = draw(st.floats(0.0, 0.35))
    rng = np.random.RandomState(seed)
    C = rng.uniform(0.01, 100.0, size=(Sn, N, L, L))
    C[rng.uniform(size=C.shape) < inf_frac] = INF
    C[:, :, np.tril(np.ones((L, L), bool), k=-1)] = INF
    return C


def cost_fn_from(Cs):
    """Scalar cost_fn view of one scenario's tensor (broadcast device
    semantics: k beyond the tensor's device axis clamps to the last)."""
    Nn, L = Cs.shape[0], Cs.shape[-1]

    def fn(a, b, k):
        if not (1 <= a <= b <= L):
            return INF
        return float(Cs[min(k, Nn) - 1, a - 1, b - 1])

    return fn


def assert_scenario_matches(scalar_res, batched_res, s):
    assert scalar_res.splits == batched_res.splits_tuple(s)
    if math.isinf(scalar_res.cost_s):
        assert math.isinf(batched_res.cost_s[s])
    else:
        # bit-identical, not approx — the engine's core contract
        assert scalar_res.cost_s == batched_res.cost_s[s]


# ---------------------------------------------------------------------------
# Cost tensor export
# ---------------------------------------------------------------------------


class TestCostTensor:
    @given(cost_models(), st.integers(1, 5))
    @settings(max_examples=30, deadline=None)
    def test_tensor_matches_scalar_bitwise(self, m, N):
        L = m.profile.num_layers
        C = m.segment_cost_tensor(N)
        for k in range(1, N + 1):
            for a in range(1, L + 1):
                for b in range(1, L + 1):
                    want = m.segment_cost_s(a, b, k)
                    got = C[k - 1, a - 1, b - 1]
                    if math.isinf(want):
                        assert math.isinf(got), (k, a, b)
                    else:
                        assert want == got, (k, a, b)  # bit-identical

    @given(cost_models(), st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_shape_and_dtype(self, m, N):
        L = m.profile.num_layers
        C = m.segment_cost_tensor(N)
        assert C.shape == (N, L, L)
        assert C.dtype == np.float64
        # a > b is always invalid
        tril = np.tril(np.ones((L, L), bool), k=-1)
        assert np.isinf(C[:, tril]).all()
        # local + tx decomposition reassembles the full tensor
        local = m.local_cost_tensor(N)
        tx = m.transmission_cost_vector()
        assert tx.shape == (L,)
        assert tx[-1] == 0.0
        reassembled = local + tx[None, None, :]
        both = np.isfinite(C) & np.isfinite(reassembled)
        assert np.array_equal(np.isfinite(C), np.isfinite(reassembled))
        assert (C[both] == reassembled[both]).all()

    def test_include_setup_charged_per_cut(self):
        layers = tuple(LayerCost(f"l{i}", 0.01, 1000, 10) for i in range(4))
        prof = ModelCostProfile("t", layers)
        link = LinkProfile("lk", 500, 1e5, t_setup_s=0.25)
        base = SplitCostModel(prof, (DeviceProfile("d"),), link)
        with_setup = replace(base, include_setup=True)
        d = with_setup.transmission_cost_vector() - base.transmission_cost_vector()
        assert d[:-1] == pytest.approx([0.25] * 3)
        assert d[-1] == 0.0

    def test_segment_arrays_cached(self):
        layers = tuple(LayerCost(f"l{i}", 0.01, 100, 10) for i in range(3))
        prof = ModelCostProfile("t", layers)
        assert prof.segment_arrays is prof.segment_arrays


# ---------------------------------------------------------------------------
# Batched solver parity vs the scalar oracle
# ---------------------------------------------------------------------------


class TestBatchedParity:
    """Each @given case checks every stacked scenario against the scalar
    solver — ≥ 40 examples × 2-6 scenarios ≫ 100 randomized scenarios."""

    @given(random_tensors(), st.sampled_from(["sum", "max"]))
    @settings(max_examples=40, deadline=None)
    def test_dp_matches_scalar(self, C, combine):
        Sn, N, L, _ = C.shape
        res = SW.batched_optimal_dp(C, combine=combine)
        for s in range(Sn):
            assert_scenario_matches(
                S.optimal_dp(cost_fn_from(C[s]), L, N, combine=combine), res, s)

    @given(random_tensors(), st.sampled_from(["sum", "max"]),
           st.sampled_from([1, 2, 4, 8, 64]))
    @settings(max_examples=40, deadline=None)
    def test_beam_matches_scalar(self, C, combine, width):
        Sn, N, L, _ = C.shape
        res = SW.batched_beam_search(C, beam_width=width, combine=combine)
        for s in range(Sn):
            assert_scenario_matches(
                S.beam_search(cost_fn_from(C[s]), L, N,
                              beam_width=width, combine=combine), res, s)

    @given(random_tensors(), st.sampled_from(["sum", "max"]))
    @settings(max_examples=40, deadline=None)
    def test_greedy_matches_scalar(self, C, combine):
        Sn, N, L, _ = C.shape
        res = SW.batched_greedy_search(C, combine=combine)
        for s in range(Sn):
            assert_scenario_matches(
                S.greedy_search(cost_fn_from(C[s]), L, N, combine=combine), res, s)

    @given(cost_models(), st.integers(2, 5))
    @settings(max_examples=25, deadline=None)
    def test_dp_parity_on_physical_models(self, m, N):
        """End-to-end: profile -> tensor -> batched DP == scalar DP."""
        L = m.profile.num_layers
        N = min(N, L)
        res = SW.batched_optimal_dp(m.segment_cost_tensor(N)[None])
        assert_scenario_matches(
            S.optimal_dp(m.cost_segment_fn(), L, N), res, 0)

    @given(random_tensors())
    @settings(max_examples=20, deadline=None)
    def test_return_all_k_matches_per_k_solves(self, C):
        Sn, N, L, _ = C.shape
        all_k = SW.batched_optimal_dp(C, return_all_k=True)
        for n in range(1, N + 1):
            single = SW.batched_optimal_dp(C[:, :n], combine="sum")
            assert np.array_equal(all_k[n].splits, single.splits)
            fin = np.isfinite(single.cost_s)
            assert np.array_equal(fin, np.isfinite(all_k[n].cost_s))
            assert (all_k[n].cost_s[fin] == single.cost_s[fin]).all()

    @given(random_tensors(), st.sampled_from(["sum", "max"]))
    @settings(max_examples=25, deadline=None)
    def test_batched_total_cost_matches_scalar(self, C, combine):
        Sn, N, L, _ = C.shape
        rng = np.random.RandomState(7)
        cands = np.sort(
            np.stack([rng.choice(np.arange(1, L), size=max(N - 1, 0),
                                 replace=False)
                      for _ in range(5)]) if N > 1
            else np.zeros((5, 0), np.int64), axis=-1)
        costs = SW.batched_total_cost(C, cands, combine=combine)
        assert costs.shape == (Sn, len(cands))
        for s in range(Sn):
            fn = cost_fn_from(C[s])
            for m_i, cand in enumerate(cands):
                want = S.total_cost(fn, tuple(int(x) for x in cand), L, combine)
                got = costs[s, m_i]
                assert (want == got) or (math.isinf(want) and math.isinf(got))

    def test_jax_backend_matches_numpy_on_separated_costs(self):
        rng = np.random.RandomState(3)
        C = rng.randint(1, 10_000, size=(8, 4, 10, 10)).astype(np.float64)
        C[:, :, np.tril(np.ones((10, 10), bool), k=-1)] = INF
        a = SW.batched_optimal_dp(C, backend="numpy")
        b = SW.batched_optimal_dp(C, backend="jax")
        assert np.array_equal(a.splits, b.splits)
        assert a.cost_s == pytest.approx(b.cost_s, rel=1e-6)


# ---------------------------------------------------------------------------
# ScenarioGrid / sweep API
# ---------------------------------------------------------------------------


def tiny_grid(n_scenarios_axis=2):
    layers = tuple(
        LayerCost(f"l{i}", 0.01 * (i + 1), 400 * (i + 1), 50 * (i + 1), 100)
        for i in range(8)
    )
    prof = ModelCostProfile("toy", layers, input_bytes=128)
    links = {
        "fast": LinkProfile("fast", 512, 1e6, t_setup_s=0.1, t_feedback_s=0.01),
        "slow": LinkProfile("slow", 256, 1e5, t_ack_s=1e-3, t_setup_s=0.02),
    }
    return SW.ScenarioGrid(
        models={"toy": prof},
        links=links,
        n_devices=(2, 3),
        loss_p=(None, 0.1)[:n_scenarios_axis],
        rate_scale=(1.0, 0.5)[:n_scenarios_axis],
        devices=(DeviceProfile("d", t_tensor_alloc_s=1e-3),),
    )


class TestScenarioGridSweep:
    def test_grid_enumeration_and_size(self):
        grid = tiny_grid()
        scs = grid.scenarios()
        assert len(scs) == grid.size == 1 * 2 * 2 * 2 * 2
        assert len({(s.model, s.protocol, s.n_devices, s.loss_p, s.rate_scale)
                    for s in scs}) == len(scs)

    def test_sweep_rows_match_scalar_plans(self):
        grid = tiny_grid()
        result = SW.sweep(grid, solver="batched_dp")
        assert result.n_scenarios == grid.size
        for row in result.rows:
            plan = plan_split(grid.cost_model(row.scenario),
                              row.scenario.n_devices, solver="optimal_dp")
            assert row.splits == plan.splits
            assert row.total_latency_s == pytest.approx(plan.total_latency_s)
            assert row.device_s + row.transmission_s == pytest.approx(
                row.objective_cost_s)

    def test_sweep_scalar_parity_report_empty(self):
        grid = tiny_grid()
        assert SW.parity_report(SW.sweep(grid), SW.sweep_scalar(grid)) == []

    def test_batched_beam_sweep_matches_scalar_beam(self):
        grid = tiny_grid()
        batched = SW.sweep(grid, solver="batched_beam", beam_width=4)
        scalar = SW.sweep_scalar(grid, solver="beam")
        assert SW.parity_report(batched, scalar) == []

    def test_best_filters(self):
        grid = tiny_grid()
        result = SW.sweep(grid)
        best = result.best(n_devices=2)
        assert best.scenario.n_devices == 2
        assert all(best.total_latency_s <= r.total_latency_s
                   for r in result.rows
                   if r.feasible and r.scenario.n_devices == 2)
        with pytest.raises(LookupError):
            result.best(model="nope")

    def test_serialization_round_trips(self):
        import json

        result = SW.sweep(tiny_grid())
        payload = json.loads(result.to_json())
        assert payload["n_scenarios"] == result.n_scenarios
        assert len(payload["rows"]) == result.n_scenarios
        csv = result.to_csv()
        assert len(csv.strip().splitlines()) == result.n_scenarios + 1


class TestSweepResultSerialization:
    """Field-level round-trip coverage for ``to_json``/``to_csv``/
    ``best(**filters)`` (the previously untested serialization paths)."""

    @pytest.fixture(scope="class")
    def result(self):
        return SW.sweep(tiny_grid())

    def test_json_rows_reproduce_sweep_rows(self, result):
        import json

        payload = json.loads(result.to_json(indent=2))
        assert payload["solver"] == result.solver
        assert payload["backend"] == result.backend
        assert payload["solve_time_s"] == result.solve_time_s
        assert payload["build_time_s"] == result.build_time_s
        assert payload["scenarios_per_sec"] == result.scenarios_per_sec
        for row, d in zip(result.rows, payload["rows"]):
            assert d["model"] == row.scenario.model
            assert d["protocol"] == row.scenario.protocol
            assert d["n_devices"] == row.scenario.n_devices
            assert d["loss_p"] == row.scenario.loss_p
            assert d["rate_scale"] == row.scenario.rate_scale
            assert tuple(d["splits"]) == row.splits
            assert d["feasible"] == row.feasible
            assert d["total_latency_s"] == row.total_latency_s

    def test_json_cleans_non_finite_floats(self):
        import json

        layers = tuple(
            LayerCost(f"l{i}", 0.01, act_bytes=100, param_bytes=10_000)
            for i in range(5)
        )
        grid = SW.ScenarioGrid(
            models={"big": ModelCostProfile("big", layers)},
            links={"lk": LinkProfile("lk", 512, 1e6)},
            n_devices=(2,),
            devices=(DeviceProfile("d", mem_limit_bytes=5_000),),
        )
        result = SW.sweep(grid)
        assert not result.rows[0].feasible
        payload = json.loads(result.to_json())  # must not emit bare inf
        row = payload["rows"][0]
        assert row["total_latency_s"] is None
        assert row["objective_cost_s"] is None
        assert row["feasible"] is False

    def test_csv_parses_back_to_rows(self, result):
        lines = result.to_csv().strip().splitlines()
        header = lines[0].split(",")
        assert header[:3] == ["model", "protocol", "n_devices"]
        for row, line in zip(result.rows, lines[1:]):
            rec = dict(zip(header, line.split(",")))
            assert rec["model"] == row.scenario.model
            assert int(rec["n_devices"]) == row.scenario.n_devices
            assert rec["splits"] == "|".join(str(x) for x in row.splits)
            assert float(rec["total_latency_s"]) == row.total_latency_s
            assert rec["feasible"] == str(row.feasible)
        assert result.to_csv().endswith("\n")

    def test_best_multi_filter_and_ordering(self, result):
        best = result.best(n_devices=2, protocol="fast")
        pool = [r for r in result.rows if r.feasible
                and r.scenario.n_devices == 2 and r.scenario.protocol == "fast"]
        assert best.total_latency_s == min(r.total_latency_s for r in pool)
        # unfiltered best is the global argmin
        assert result.best().total_latency_s == min(
            r.total_latency_s for r in result.rows if r.feasible)

    def test_best_rejects_unmatched_filters(self, result):
        with pytest.raises(LookupError):
            result.best(protocol="carrier_pigeon")
        with pytest.raises(AttributeError):
            result.best(nonexistent_field=1)

    def test_plan_split_batch_matches_singletons(self):
        grid = tiny_grid()
        models = [grid.cost_model(sc) for sc in grid.scenarios()
                  if sc.n_devices == 3]
        plans = plan_split_batch(models, 3, solver="batched_dp")
        for m, p in zip(models, plans):
            ref = plan_split(m, 3, solver="optimal_dp")
            assert p.splits == ref.splits
            assert p.total_latency_s == pytest.approx(ref.total_latency_s)

    def test_plan_split_accepts_batched_solver_names(self):
        grid = tiny_grid()
        m = grid.cost_model(grid.scenarios()[0])
        a = plan_split(m, 2, solver="batched_dp")
        b = plan_split(m, 2, solver="optimal_dp")
        assert a.splits == b.splits
        assert a.solver == "batched_dp"

    def test_stack_rejects_mixed_layer_counts(self):
        grid = tiny_grid()
        m1 = grid.cost_model(grid.scenarios()[0])
        layers = tuple(LayerCost(f"l{i}", 0.01, 10, 10) for i in range(5))
        m2 = SplitCostModel(ModelCostProfile("other", layers),
                            (DeviceProfile("d"),), m1.link)
        with pytest.raises(ValueError):
            SW.stack_cost_tensors([m1, m2], 2)

    def test_heterogeneous_fleet_sizes_share_one_group_solve(self):
        """Mixed fleet sizes of one model batch in a single pass (no
        per-(model, N) grouping) and still match the scalar oracle."""
        grid = tiny_grid()
        assert len(set(grid.n_devices)) > 1
        result = SW.sweep(grid, solver="batched_dp")
        assert SW.parity_report(result, SW.sweep_scalar(grid)) == []

    def test_infeasible_scenarios_reported_not_dropped(self):
        # memory limit below any single layer's weight -> nothing fits
        layers = tuple(
            LayerCost(f"l{i}", 0.01, act_bytes=100, param_bytes=10_000)
            for i in range(5)
        )
        prof = ModelCostProfile("big", layers)
        grid = SW.ScenarioGrid(
            models={"big": prof},
            links={"lk": LinkProfile("lk", 512, 1e6)},
            n_devices=(2,),
            devices=(DeviceProfile("d", mem_limit_bytes=5_000),),
        )
        result = SW.sweep(grid)
        assert result.n_scenarios == 1
        assert not result.rows[0].feasible
        assert math.isinf(result.rows[0].total_latency_s)
        with pytest.raises(LookupError):
            result.best()


# ---------------------------------------------------------------------------
# Heterogeneous device mixes (per-scenario profile gather)
# ---------------------------------------------------------------------------


@st.composite
def hetero_grids(draw):
    """Grids whose scenarios mix device classes: a small bank of random
    DeviceProfiles, 1-2 named mixes drawing from it (broadcast or
    per-position), optional shared homogeneous fleet, 1-2 fleet sizes."""
    L = draw(st.integers(4, 9))
    prof = synthetic_model(draw, L)
    bank = [
        synthetic_device(draw, constrain_mem=draw(st.integers(0, 1)) == 1)
        for _ in range(draw(st.integers(2, 3)))
    ]
    sizes = tuple(sorted(draw(st.sets(st.integers(1, min(4, L)),
                                      min_size=1, max_size=2))))
    n_max = max(sizes)
    mixes = {}
    for mi in range(draw(st.integers(1, 2))):
        if draw(st.booleans()):  # broadcast mix (one profile, any N)
            mixes[f"mix{mi}"] = (bank[draw(st.integers(0, len(bank) - 1))],)
        else:  # per-position mix covering the largest fleet
            mixes[f"mix{mi}"] = tuple(
                bank[draw(st.integers(0, len(bank) - 1))]
                for _ in range(n_max))
    return SW.ScenarioGrid(
        models={"synth": prof},
        links={"lk": synthetic_link(draw)},
        n_devices=sizes,
        loss_p=(None, 0.1),
        rate_scale=(1.0, 0.5),
        devices=(bank[0],) if draw(st.booleans()) else (),
        device_mixes=mixes,
    )


class TestHeterogeneousMixes:
    """Per-scenario device-mix batched solves == a scalar loop over the
    mixed DeviceProfiles (the heterogeneous-fleet parity contract)."""

    @given(grid=hetero_grids())
    @settings(max_examples=15, deadline=None)
    def test_dp_and_greedy_match_scalar_loop(self, grid):
        for solver, oracle in (("batched_dp", "optimal_dp"),
                               ("batched_greedy", "greedy")):
            batched = SW.sweep(grid, solver=solver)
            scalar = SW.sweep_scalar(grid, solver=oracle)
            assert SW.parity_report(batched, scalar) == []
            for rb, rs in zip(batched.rows, scalar.rows):
                if rb.feasible:
                    # bit-identical objective, not approx
                    assert rb.objective_cost_s == rs.objective_cost_s
                    assert rb.total_latency_s == pytest.approx(
                        rs.total_latency_s, rel=1e-12)

    @given(grid=hetero_grids())
    @settings(max_examples=10, deadline=None)
    def test_beam_matches_standalone_batched_beam(self, grid):
        """Group-batched beam == one-scenario batched beam per scenario
        (exact, including ties — same arithmetic per scenario)."""
        batched = SW.sweep(grid, solver="batched_beam", beam_width=4)
        for row in batched.rows:
            single = plan_split(grid.cost_model(row.scenario),
                                row.scenario.n_devices,
                                solver="batched_beam", beam_width=4)
            assert row.splits == single.splits

    def test_mix_axis_enumeration_and_fields(self):
        grid = tiny_grid()
        dev2 = DeviceProfile("d2", compute_scale=0.5)
        mixed = SW.ScenarioGrid(
            models=grid.models, links=grid.links, n_devices=(2, 3),
            devices=grid.devices,
            device_mixes={"fast_head": (dev2, grid.devices[0],
                                        grid.devices[0])},
        )
        # shared fleet stays on the axis as mix=None
        assert mixed.mix_names == (None, "fast_head")
        assert mixed.size == len(mixed.scenarios()) == grid.size // 2
        mixes = {sc.mix for sc in mixed.scenarios()}
        assert mixes == {None, "fast_head"}
        assert mixed.devices_for(mixed.scenarios()[0]) == grid.devices
        result = SW.sweep(mixed)
        assert SW.parity_report(result, SW.sweep_scalar(mixed)) == []
        # mix lands in serialization + describe
        header = result.to_csv().splitlines()[0].split(",")
        assert "mix" in header
        d = result.rows[-1].to_dict()
        assert d["mix"] == "fast_head"
        assert "mix=fast_head" in result.rows[-1].scenario.describe()
        assert result.best(mix="fast_head").scenario.mix == "fast_head"

    def test_plan_split_batch_per_model_device_tuples(self):
        """Regression: per-scenario fleet sizes must not require every
        cost model's device tuple to cover the LARGEST fleet in the
        batch — each model's tuple only covers its own fleet."""
        grid = tiny_grid()
        base = grid.cost_model(grid.scenarios()[0])
        gw = DeviceProfile("gw", compute_scale=0.25)
        small = replace(base, devices=(grid.devices[0], gw))  # 2 devices
        big = replace(base, devices=(grid.devices[0], grid.devices[0], gw))
        plans = plan_split_batch([small, big], [2, 3], solver="batched_dp")
        for m, p, n in zip((small, big), plans, (2, 3)):
            ref = plan_split(m, n, solver="optimal_dp")
            assert p.splits == ref.splits
            assert p.n_devices == n

    def test_mix_validation(self):
        grid = tiny_grid()
        dev = grid.devices[0]
        with pytest.raises(ValueError):  # multi-profile mix too short
            SW.ScenarioGrid(models=grid.models, links=grid.links,
                            n_devices=(3,), devices=grid.devices,
                            device_mixes={"short": (dev, dev)})
        with pytest.raises(ValueError):  # empty mix
            SW.ScenarioGrid(models=grid.models, links=grid.links,
                            n_devices=(2,), devices=grid.devices,
                            device_mixes={"none": ()})
        with pytest.raises(ValueError):  # no devices at all
            SW.ScenarioGrid(models=grid.models, links=grid.links,
                            n_devices=(2,))
