"""Variant-bank property suite (bottleneck compression axis).

Four families of properties pin the (split point, variant) contract:

* **Degenerate single-variant bit-exactness** — ``solve_variant_bank``
  with a one-entry bank must return bit-identical (``==`` on splits AND
  costs) results to ``solve_batched`` on the raw tensor, for every
  batched solver, both combine modes, per-scenario fleet-size vectors,
  and every DP backend (numpy / jax / sharded / pallas).
* **Joint-oracle parity** — the folded variant-axis solve must match
  the scalar joint oracle (``optimal_dp(variants=...)``, which runs the
  exact DP once per bank member and keeps the cheapest with the
  lowest-index tie-break) on every random draw up to V=3, L=8, N=4:
  same splits, same cost bitwise, same winning variant index.
* **Accuracy-floor masking** — ``accuracy_floor`` must reproduce the
  oracle restricted to ``accuracy_proxy >= floor`` (strict ``<``
  masking), and a floor masking the whole bank yields the usual
  infeasible result with variant ``-1``.
* **Pareto frontier == brute force** — :func:`repro.core.sweep.
  pareto_frontier` must equal an independently written O(n^2)
  non-dominated filter on random row sets (ties both survive,
  infeasible rows never enter), and scaling every accuracy proxy by a
  positive constant is metamorphic: the frontier row identity set and
  order are invariant.

Plus the runtime regression for the serving meter: a mid-stream replan
that switches bottleneck variants must reprice subsequent hops at the
NEW variant's compressed payload (the payload is single-sourced from
the adopted plan, never from a stale static byte count).

Strategy arguments are keyword-bound in every ``@given`` (the vendored
minihypothesis shim binds positional strategies to the RIGHTMOST
parameters; keyword binding is explicit and reorder-proof).
"""

import math
from dataclasses import dataclass, replace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import solvers as S
from repro.core import sweep as SW
from repro.core.latency import bottleneck_variant, bottleneck_variants
from repro.core.profiles import ESP32, PROTOCOLS, paper_cost_model

INF = float("inf")


def tensor_cost_fn(T, L):
    """Scalar cost fn reading dense ``T[k-1, a-1, b-1]`` (the oracle's
    view of the exact same numbers the batched solver sees)."""

    def fn(a, b, k):
        if not (1 <= a <= b <= L) or k < 1 or k > T.shape[0]:
            return INF
        return float(T[k - 1, a - 1, b - 1])

    return fn


def joint_oracle(C, L, N, acc=None, floor=None, combine="sum"):
    """Scalar (split, variant) oracle for one scenario's (V, N, L, L)
    stack: the exact DP per bank member with lowest-index tie-break."""
    insts = [
        S.VariantInstance(
            cost_fn=tensor_cost_fn(C[v], L),
            accuracy_proxy=1.0 if acc is None else float(acc[v]),
        )
        for v in range(C.shape[0])
    ]
    return S.optimal_dp(None, L, N, combine=combine,
                        variants=insts, accuracy_floor=floor)


@st.composite
def variant_tensors(draw, max_V=3, max_L=8, max_N=4, max_scenarios=3):
    """Random (V, S, N, L, L) stacked variant tensors with sprinkled
    infeasibility (mirroring mem-limit masking) plus random accuracy
    proxies per variant."""
    V = draw(st.integers(1, max_V))
    L = draw(st.integers(3, max_L))
    N = draw(st.integers(1, min(max_N, L)))
    Sn = draw(st.integers(1, max_scenarios))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    C = rng.uniform(0.01, 100.0, size=(V, Sn, N, L, L))
    tril = np.tril_indices(L, -1)
    C[:, :, :, tril[0], tril[1]] = INF
    mask = rng.rand(V, Sn, N, L, L) < 0.1
    C = np.where(mask, INF, C)
    acc = rng.uniform(0.5, 1.0, size=V)
    return C, acc, V, Sn, N, L, seed


class TestDegenerateSingleVariant:
    """A one-entry bank must be the identity over solve_batched."""

    @given(data=st.data())
    @settings(max_examples=25)
    def test_numpy_all_solvers_all_combines(self, data):
        C, acc, V, Sn, N, L, seed = data.draw(variant_tensors(max_V=1))
        rng = np.random.RandomState(seed + 1)
        ns = rng.randint(1, N + 1, size=Sn).astype(np.int64)
        solver = data.draw(st.sampled_from(sorted(SW.BATCHED_SOLVERS)))
        combine = data.draw(st.sampled_from(("sum", "max")))
        use_ns = data.draw(st.booleans())
        kw = {"n_devices": ns} if use_ns else {}
        ref = SW.solve_batched(C[0], solver=solver, combine=combine, **kw)
        got = SW.solve_variant_bank(C, solver=solver, combine=combine, **kw)
        assert np.array_equal(got.splits, ref.splits)
        assert np.array_equal(got.cost_s, ref.cost_s)  # bit-exact, == not allclose
        assert np.array_equal(got.feasible, ref.feasible)
        assert got.variant is not None
        assert np.array_equal(got.variant,
                              np.where(ref.feasible, 0, -1))

    @pytest.mark.parametrize("backend", ["numpy", "jax", "sharded", "pallas"])
    @pytest.mark.parametrize("combine", ["sum", "max"])
    def test_every_backend_both_combines(self, backend, combine):
        rng = np.random.RandomState(11)
        Sn, N, L = 5, 3, 9
        C = rng.uniform(0.01, 100.0, size=(1, Sn, N, L, L))
        tril = np.tril_indices(L, -1)
        C[:, :, :, tril[0], tril[1]] = INF
        ns = rng.randint(1, N + 1, size=Sn).astype(np.int64)
        for kw in ({}, {"n_devices": ns}):
            ref = SW.solve_batched(C[0], combine=combine, backend=backend,
                                   **kw)
            got = SW.solve_variant_bank(C, combine=combine, backend=backend,
                                        **kw)
            assert np.array_equal(got.splits, ref.splits)
            assert np.array_equal(got.cost_s, ref.cost_s)
            assert np.array_equal(got.feasible, ref.feasible)


class TestJointOracleParity:
    """Folded variant solve == scalar joint oracle, bitwise."""

    @given(data=st.data())
    @settings(max_examples=25)
    def test_matches_scalar_joint_oracle(self, data):
        C, acc, V, Sn, N, L, seed = data.draw(variant_tensors())
        combine = data.draw(st.sampled_from(("sum", "max")))
        res = SW.solve_variant_bank(C, combine=combine)
        for s in range(Sn):
            oracle = joint_oracle(C[:, s], L, N, combine=combine)
            assert bool(res.feasible[s]) == oracle.feasible
            if not oracle.feasible:
                assert int(res.variant[s]) == -1
                continue
            assert res.cost_s[s] == oracle.cost_s  # zero regret, bitwise
            assert int(res.variant[s]) == oracle.variant
            assert tuple(int(x) for x in res.splits[s][:N - 1]) \
                == oracle.splits

    @given(data=st.data())
    @settings(max_examples=15)
    def test_scalar_solvers_agree_on_the_joint_space(self, data):
        """brute_force(variants=...) and optimal_dp(variants=...) are
        both exact over the joint space — they must agree exactly."""
        C, acc, V, Sn, N, L, seed = data.draw(
            variant_tensors(max_L=7, max_scenarios=1))
        insts = [S.VariantInstance(cost_fn=tensor_cost_fn(C[v, 0], L))
                 for v in range(V)]
        dp = S.optimal_dp(None, L, N, variants=insts)
        bf = S.brute_force(None, L, N, variants=insts)
        assert dp.cost_s == bf.cost_s
        assert dp.splits == bf.splits
        assert dp.variant == bf.variant

    @given(data=st.data())
    @settings(max_examples=15)
    def test_per_scenario_fleet_sizes_through_the_fold(self, data):
        C, acc, V, Sn, N, L, seed = data.draw(variant_tensors())
        rng = np.random.RandomState(seed + 2)
        ns = rng.randint(1, N + 1, size=Sn).astype(np.int64)
        res = SW.solve_variant_bank(C, n_devices=ns)
        for s in range(Sn):
            n = int(ns[s])
            oracle = joint_oracle(C[:, s, :n], L, n)
            assert bool(res.feasible[s]) == oracle.feasible
            if oracle.feasible:
                assert res.cost_s[s] == oracle.cost_s
                assert int(res.variant[s]) == oracle.variant


class TestAccuracyFloorMasking:
    """accuracy_floor == oracle restricted to acc >= floor."""

    @given(data=st.data())
    @settings(max_examples=25)
    def test_matches_floor_restricted_oracle(self, data):
        C, acc, V, Sn, N, L, seed = data.draw(variant_tensors())
        # floors spanning none-masked .. all-masked
        floor = data.draw(st.sampled_from(
            (0.0, float(np.min(acc)), float(np.median(acc)),
             float(np.max(acc)), 1.5)))
        res = SW.solve_variant_bank(C, accuracy_proxy=acc,
                                    accuracy_floor=floor)
        for s in range(Sn):
            oracle = joint_oracle(C[:, s], L, N, acc=acc, floor=floor)
            assert bool(res.feasible[s]) == oracle.feasible
            if not oracle.feasible:
                assert int(res.variant[s]) == -1
                continue
            assert res.cost_s[s] == oracle.cost_s
            assert int(res.variant[s]) == oracle.variant
            assert acc[int(res.variant[s])] >= floor

    def test_none_floor_returns_identical_tensor(self):
        rng = np.random.RandomState(3)
        C = rng.uniform(0.1, 1.0, size=(2, 2, 2, 4, 4))
        out = SW.apply_accuracy_floor(C, np.array([1.0, 0.9]), None)
        assert out is C  # the degenerate path hands back the SAME object

    def test_floor_without_proxy_raises(self):
        C = np.zeros((2, 1, 1, 3, 3))
        with pytest.raises(ValueError):
            SW.solve_variant_bank(C, accuracy_floor=0.9)


@dataclass(frozen=True)
class _Scenario:
    model: str = "m"
    protocol: str = "p"
    n_devices: int = 2


@dataclass(frozen=True)
class _Row:
    """Minimal row satisfying the pareto_frontier contract."""

    total_latency_s: float
    accuracy_proxy: float
    feasible: bool = True
    scenario: _Scenario = _Scenario()
    splits: tuple = ()


def brute_force_frontier(rows):
    """Independent O(n^2) non-dominated filter (the textbook
    definition, written separately from the implementation)."""
    feas = [r for r in rows if r.feasible]
    out = []
    for r in feas:
        if not any(
            (o.total_latency_s <= r.total_latency_s
             and o.accuracy_proxy >= r.accuracy_proxy
             and (o.total_latency_s, o.accuracy_proxy)
             != (r.total_latency_s, r.accuracy_proxy))
            for o in feas
        ):
            out.append(r)
    return sorted(out, key=lambda r: (r.total_latency_s, -r.accuracy_proxy))


@st.composite
def row_sets(draw, max_rows=12):
    n = draw(st.integers(0, max_rows))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    # quantized values so exact ties actually occur
    lats = rng.choice([0.5, 1.0, 1.5, 2.0, 3.0], size=n)
    accs = rng.choice([0.90, 0.94, 0.97, 1.0], size=n)
    feas = rng.rand(n) > 0.15
    return [
        _Row(total_latency_s=float(lats[i]), accuracy_proxy=float(accs[i]),
             feasible=bool(feas[i]))
        for i in range(n)
    ]


class TestParetoOracle:
    """pareto_frontier == brute-force non-dominated oracle."""

    @given(rows=row_sets())
    @settings(max_examples=50)
    def test_matches_brute_force(self, rows):
        got = SW.pareto_frontier(rows)
        want = brute_force_frontier(rows)
        assert list(got) == want

    @given(rows=row_sets())
    @settings(max_examples=25)
    def test_accuracy_scaling_is_metamorphic(self, rows):
        """Scaling every accuracy proxy by a positive constant changes
        no dominance relation: the frontier keeps the same rows (by
        original index) in the same order."""
        base = SW.pareto_frontier(rows)
        for factor in (0.5, 2.0, 100.0):
            scaled = [replace(r, accuracy_proxy=r.accuracy_proxy * factor)
                      for r in rows]
            got = SW.pareto_frontier(scaled)
            assert [scaled.index(g) for g in got] \
                == [rows.index(b) for b in base]

    def test_exact_ties_all_survive(self):
        a = _Row(1.0, 0.9)
        b = _Row(1.0, 0.9)
        c = _Row(2.0, 0.9)  # dominated by a/b
        assert list(SW.pareto_frontier([a, b, c])) == [a, b]

    def test_infeasible_rows_never_enter(self):
        a = _Row(1.0, 0.9)
        ghost = _Row(0.1, 1.0, feasible=False)
        assert list(SW.pareto_frontier([a, ghost])) == [a]


class TestSweepFrontierEndToEnd:
    """SweepResult.pareto on a real compression-axis sweep."""

    def test_frontier_groups_and_oracle(self):
        m = paper_cost_model("mobilenet_v2", "esp_now")
        grid = SW.ScenarioGrid(
            models={"mobilenet_v2": m.profile},
            links={"esp_now": PROTOCOLS["esp_now"]},
            n_devices=(2, 3),
            devices=(ESP32,),
            compression_factors=(1.0, 2.0, 4.0),
        )
        res = SW.sweep(grid)
        fronts = res.pareto()
        assert set(fronts) == {("mobilenet_v2", "esp_now", 2),
                               ("mobilenet_v2", "esp_now", 3)}
        for key, front in fronts.items():
            group = [r for r in res.rows
                     if (r.scenario.model, r.scenario.protocol,
                         r.scenario.n_devices) == key]
            assert list(front.rows) == brute_force_frontier(group)
            assert front.n_points >= 1
            # ascending latency, and accuracy strictly decreasing along
            # it (a true trade-off frontier)
            lats = [r.total_latency_s for r in front.rows]
            assert lats == sorted(lats)
            csv = front.to_csv()
            assert csv.splitlines()[0].startswith("model,protocol,n_devices")


class TestMeterVariantSwitch:
    """Serving-meter regression: a mid-stream replan that switches
    bottleneck variants reprices the remaining hops at the NEW
    variant's compressed payload."""

    def _manager(self, bank):
        from repro.core.adaptive import AdaptiveSplitManager

        m = paper_cost_model("mobilenet_v2", "esp_now")
        return AdaptiveSplitManager(
            cost_model=m, protocols={"esp_now": PROTOCOLS["esp_now"]},
            n_devices=3, solver="optimal_dp", surface=None,
            variants=bank, replan_threshold=0.05,
        )

    def test_hop_bytes_follow_the_adopted_variant(self):
        from repro.runtime.server import SplitLatencyMeter

        # a bank where compression must buy a HUGE encoder latency:
        # identity wins at the base link, cx4 wins once the link
        # degrades enough for airtime to dominate the encoder cost
        bank = (bottleneck_variant(1.0),
                bottleneck_variant(4.0, encoder_t_s=0.05))
        mgr = self._manager(bank)
        assert mgr.current.variant == 0  # encoder too costly at base link
        meter = SplitLatencyMeter(
            plan=mgr.current_plan(), link=PROTOCOLS["esp_now"],
            bytes_per_token=1024, manager=mgr, protocol="esp_now",
        )
        seg0 = meter.plan.segments[0]
        assert meter._hop_bytes(seg0) == 1024  # identity: raw payload
        before = meter.link.transmission_latency_s(meter._hop_bytes(seg0))

        # degrade the link until the re-solve flips to the compressed
        # variant; the meter must follow through its own observe path
        switched = False
        for _ in range(200):
            if meter.observe_hop(1024, 2.0) and mgr.current.variant == 1:
                switched = True
                break
        assert switched, "replan never switched variants"
        assert meter.plan.variant == 1
        seg0 = meter.plan.segments[0]
        assert meter._hop_bytes(seg0) == 256  # ceil(1024 / 4)
        after = meter.link.transmission_latency_s(meter._hop_bytes(seg0))
        assert after < before  # the hop really got cheaper to transmit

    def test_plan_tx_bytes_are_compressed_end_to_end(self):
        bank = bottleneck_variants((1.0, 2.0, 4.0), encoder_s_per_byte=2e-9)
        mgr = self._manager(bank)
        plan = mgr.current_plan()
        assert plan.variant == mgr.current.variant
        if plan.variant and plan.variant > 0:
            raw = mgr.cost_model.profile.boundary_act_bytes(plan.splits[0])
            assert plan.segments[0].tx_bytes == math.ceil(
                raw / bank[plan.variant].compression_factor)
