"""Sharding-rule unit tests: PartitionSpecs assigned to param/cache leaves.

These are pure spec-level tests (no devices needed beyond 1): the rules
module is deterministic shape math. Regression coverage for the
layer-stack-vs-expert-stack bug (M15 in the perf log)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import param_spec


class TestParamSpec:
    def test_dense_stacked_mlp_shards_hidden_not_layers(self):
        """(L, d, f) with L divisible by the axis MUST NOT shard L —
        the qwen2-vl 36 GB decode regression (M15)."""
        spec = param_spec("blocks/ff/w_in", (80, 8192, 29568), "model", 16)
        assert spec == P(None, None, "model")
        spec = param_spec("blocks/ff/w_out", (80, 29568, 8192), "model", 16)
        assert spec == P(None, "model", None)

    def test_moe_expert_stack_shards_experts(self):
        spec = param_spec("blocks/ff/w_in", (94, 128, 4096, 1536), "model", 16)
        assert spec == P(None, "model", None, None)

    def test_attention_heads_sharded_when_divisible(self):
        spec = param_spec("blocks/attn/wq", (30, 4096, 32, 128), "model", 16)
        assert spec == P(None, None, "model", None)

    def test_mqa_kv_falls_through_to_head_dim_or_replicates(self):
        # kv=1 head: 1 % 16 != 0; head_dim 128 divisible -> shard dim -1
        spec = param_spec("blocks/attn/wk", (88, 6144, 1, 128), "model", 16)
        assert spec == P(None, None, None, "model")

    def test_nondivisible_heads_fall_through(self):
        # minicpm3: 40 heads % 16 != 0 -> q_up falls to the lora-rank dim
        spec = param_spec("blocks/attn/q_up", (62, 768, 40, 96), "model", 16)
        assert spec == P(None, "model", None, None)

    def test_norms_replicated(self):
        assert param_spec("blocks/norm1/scale", (30, 4096), "model", 16) == P(None, None)

    def test_router_replicated(self):
        assert param_spec("blocks/ff/router", (24, 1024, 32), "model", 16) \
            == P(None, None, None)

    def test_embed_shards_d_model(self):
        assert param_spec("embed/table", (49155, 1024), "model", 16) == P(None, "model")

    def test_lm_head_shards_vocab(self):
        assert param_spec("lm_head/w", (4096, 151936), "model", 16) == P(None, "model")

    def test_wo_row_parallel(self):
        assert param_spec("blocks/attn/wo", (30, 4096, 4096), "model", 16) \
            == P(None, "model", None)


class TestCacheSharding:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_cache_spec_paths_exist(self):
        """cache_sharding handles every cache layout without error."""
        from repro.models.config import ModelConfig
        from repro.models.transformer import init_cache
        from repro.parallel.sharding import cache_sharding

        mesh = self._mesh()
        for kwargs in (
            dict(),  # plain GQA
            dict(kv_cache_dtype="int8"),
            dict(use_mla=True, q_lora_rank=32, kv_lora_rank=16,
                 qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        ):
            cfg = ModelConfig("t", "dense", n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, d_ff=128, vocab=64, head_dim=16,
                              dtype="float32", **kwargs)
            cache = jax.eval_shape(lambda c=cfg: init_cache(c, 4, 32))
            shardings = cache_sharding(cfg, cache, mesh, 4)
            assert jax.tree.structure(shardings, is_leaf=lambda x: hasattr(x, "spec")) \
                is not None
