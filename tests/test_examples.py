"""Examples must stay runnable — subprocess smoke tests (marked slow)."""

import os
import subprocess
import sys

import pytest


def _run(script, *args, timeout=420):
    env = {
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        # examples are CPU smoke tests; without this, hosts with libtpu
        # installed hang in TPU backend discovery inside the subprocess
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS") or "cpu",
    }
    res = subprocess.run(
        [sys.executable, script, *args], capture_output=True, text=True,
        timeout=timeout, env=env)
    assert res.returncode == 0, res.stderr[-2000:]
    return res.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run("examples/quickstart.py")
    assert "split executes correctly: top-1 agreement = True" in out
    assert "predicted end-to-end latency" in out


@pytest.mark.slow
def test_serve_split_llm():
    out = _run("examples/serve_split_llm.py")
    assert "served 8 requests" in out
    assert "modeled split-hop overhead" in out


@pytest.mark.slow
def test_adaptive_replanning():
    out = _run("examples/adaptive_replanning.py")
    assert "decision log" in out
    assert "udp" in out  # deep degradation ends in a protocol switch


@pytest.mark.slow
def test_train_pipeline_lm_short():
    out = _run("examples/train_pipeline_lm.py", "--steps", "24", "--batch", "4",
               "--seq", "32", "--vocab", "256", timeout=540)
    assert "restarting from checkpoint step" in out
    assert "beam PP plan over dcn" in out
