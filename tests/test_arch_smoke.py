"""Per-architecture smoke tests: reduced same-family configs, one forward
+ one train step + one decode step on CPU, asserting shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); these reduced variants keep every family's code path covered
in seconds."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init

B, S = 2, 32


def reduced(arch_id):
    return get_config(arch_id).reduced()


def make_batch(cfg, rng, train=True):
    N = cfg.train_microbatches if train else 1
    lead = (N, B) if N > 1 else (B,)
    ks = jax.random.split(rng, 3)
    if cfg.frontend == "audio_codes":
        codes = jax.random.randint(ks[0], (*lead, S, cfg.n_codebooks), 0, cfg.vocab)
        batch = {"codes": codes}
        if train:
            batch["labels"] = jax.random.randint(ks[1], (*lead, S, cfg.n_codebooks),
                                                 0, cfg.vocab)
    elif cfg.frontend == "vision_embeds":
        emb = jax.random.normal(ks[0], (*lead, S, cfg.d_model), dtype=jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        if N > 1:
            pos = jnp.broadcast_to(pos[None], (N, 3, B, S))
        batch = {"embeds": emb, "positions": pos}
        if train:
            batch["labels"] = jax.random.randint(ks[1], (*lead, S), 0, cfg.vocab)
    else:
        batch = {"tokens": jax.random.randint(ks[0], (*lead, S), 0, cfg.vocab)}
        if train:
            batch["labels"] = jax.random.randint(ks[1], (*lead, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch_id):
        cfg = reduced(arch_id)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg, jax.random.PRNGKey(1), train=False)
        logits, _ = T.forward(cfg, params, batch)
        Vp = cfg.vocab_padded
        want = (B, S, cfg.n_codebooks, Vp) if cfg.n_codebooks else (B, S, Vp)
        assert logits.shape == want
        real = logits[..., : cfg.vocab]
        assert bool(jnp.all(jnp.isfinite(real)))
        if Vp > cfg.vocab:  # padded slots masked, never win argmax
            assert bool(jnp.all(logits[..., cfg.vocab:] < -1e29))

    def test_train_step_decreases_nothing_nan(self, arch_id):
        cfg = reduced(arch_id)
        # keep the reduced smoke microbatched iff the real config is
        cfg = dataclasses.replace(
            cfg, train_microbatches=min(2, get_config(arch_id).train_microbatches))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
        batch = make_batch(cfg, jax.random.PRNGKey(1), train=True)
        params, opt, metrics = step(params, opt, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        # one more step must also be finite (state threading works)
        batch2 = make_batch(cfg, jax.random.PRNGKey(2), train=True)
        params, opt, metrics2 = step(params, opt, batch2)
        assert bool(jnp.isfinite(metrics2["loss"]))

    def test_decode_step(self, arch_id):
        cfg = reduced(arch_id)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
        if cfg.frontend == "audio_codes":
            inp = {"codes": jnp.zeros((B, 1, cfg.n_codebooks), jnp.int32),
                   "cur_index": jnp.int32(0)}
        elif cfg.frontend == "vision_embeds":
            inp = {"embeds": jnp.zeros((B, 1, cfg.d_model)),
                   "positions": jnp.zeros((3, B, 1), jnp.int32),
                   "cur_index": jnp.int32(0)}
        else:
            inp = {"tokens": jnp.zeros((B, 1), jnp.int32), "cur_index": jnp.int32(0)}
        logits, new_cache = T.serve_step(cfg, params, inp, cache)
        assert logits.shape[:2] == (B, 1)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)

    def test_full_config_matches_assignment(self, arch_id):
        """The full (non-reduced) config carries the published dims."""
        cfg = get_config(arch_id)
        published = {
            "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
            "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
            "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
            "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
            "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
            "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
            "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
            "granite-34b": (88, 6144, 48, 1, 24576, 49152),
            "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
            "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        }[arch_id]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == published
