"""Sharded scenario-axis sweep tests + JAX DP backend contract.

Two layers:

* In-process tests exercise the sharded path on whatever device count
  this session has (1 on a plain CPU host — the mesh degenerates but
  every code path still runs) and the JAX backend's solver contract
  (jit-cache reuse, per-scenario fleet sizes under +inf padding, all-k,
  shared timing scope).
* Multi-device tests spawn subprocesses with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — XLA pins the
  device count at first ``jax`` import, so a real >1-device mesh can
  only be created in a fresh interpreter. These assert the acceptance
  contract: sharded output node-identical to the single-device JAX path
  (and cost-close to the NumPy oracle) for scenario counts that do and
  do not divide the device count, plus x64 bit-parity with ties.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import shard as SH
from repro.core import sweep as SW
from repro.core.profiles import ESP32, PROTOCOLS, mobilenet_cost_profile
from repro.core.sweep import ScenarioGrid, sweep

INF = float("inf")


def random_tensor(seed, S=6, N=4, L=8, inf_frac=0.15):
    """Continuous uniform costs: exact float ties have probability zero,
    so float32 argmin agrees with the float64 oracle w.h.p."""
    rng = np.random.RandomState(seed)
    C = rng.uniform(0.01, 100.0, size=(S, N, L, L))
    C[rng.uniform(size=C.shape) < inf_frac] = INF
    C[:, :, np.tril(np.ones((L, L), bool), k=-1)] = INF
    return C


def assert_node_identical(a, b):
    """Two BatchedSolverResults agree node-for-node (exact ==)."""
    assert np.array_equal(a.splits, b.splits)
    assert np.array_equal(a.cost_s, b.cost_s)
    assert np.array_equal(a.feasible, b.feasible)


# ---------------------------------------------------------------------------
# Shard-count / padding plumbing
# ---------------------------------------------------------------------------


class TestPlumbing:
    def test_pad_to_multiple(self):
        assert SH._pad_to_multiple(8, 8) == 0
        assert SH._pad_to_multiple(5, 8) == 3
        assert SH._pad_to_multiple(9, 8) == 7
        assert SH._pad_to_multiple(1, 1) == 0
        assert SH._pad_to_multiple(17, 4) == 3

    def test_scenario_shards_default_and_validation(self):
        avail = SH.scenario_shards()
        assert avail >= 1
        assert SH.scenario_shards(1) == 1
        with pytest.raises(ValueError):
            SH.scenario_shards(0)
        with pytest.raises(ValueError):
            SH.scenario_shards(avail + 1)

    def test_input_validation_mirrors_batched_dp(self):
        with pytest.raises(ValueError):
            SH.sharded_optimal_dp(np.zeros((2, 3, 4)))  # not 4-D
        with pytest.raises(ValueError):
            SH.sharded_optimal_dp(np.zeros((2, 2, 4, 5)))  # non-square
        with pytest.raises(ValueError):
            SH.sharded_optimal_dp(np.full((2, 2, 4, 4), 1.0),
                                  n_devices=[1, 2], return_all_k=True)


# ---------------------------------------------------------------------------
# JAX DP backend contract (satellites: jit cache, n_devices, all-k, timing)
# ---------------------------------------------------------------------------


class TestJaxBackendContract:
    def test_repeat_same_shape_call_hits_jit_cache(self):
        """Two same-shape calls must compile exactly once: the second
        call's wall time excludes trace+compile. Trace counting is the
        deterministic proxy (compile wall-clock is noise)."""
        C = random_tensor(seed=7, S=5, N=3, L=7)
        SW.batched_optimal_dp(C, backend="jax")  # warm (traces at most once)
        before = SW._DP_JAX_TRACE_COUNT
        SW.batched_optimal_dp(C, backend="jax")
        SW.batched_optimal_dp(C, backend="jax", n_devices=[1, 2, 3, 1, 2])
        assert SW._DP_JAX_TRACE_COUNT == before  # cache hit, no retrace
        # a new shape MAY retrace (jit keys on shape); it must not
        # invalidate the old entry
        SW.batched_optimal_dp(random_tensor(seed=8, S=4, N=3, L=6),
                              backend="jax")
        after_new_shape = SW._DP_JAX_TRACE_COUNT
        SW.batched_optimal_dp(C, backend="jax")
        assert SW._DP_JAX_TRACE_COUNT == after_new_shape

    def test_sharded_repeat_call_hits_jit_cache(self):
        C = random_tensor(seed=9, S=6, N=3, L=7)
        SH.sharded_optimal_dp(C)  # warm
        before = SW._DP_JAX_TRACE_COUNT
        SH.sharded_optimal_dp(C)
        assert SW._DP_JAX_TRACE_COUNT == before

    @pytest.mark.parametrize("combine", ["sum", "max"])
    def test_n_devices_parity_under_inf_padding(self, combine):
        """The frozen-row contract on the JAX backend: device slices
        beyond a scenario's own fleet size are +inf (exactly what
        stack_cost_tensors emits for per-model sizes) and must never
        poison a live row — cost/feasibility/splits match the NumPy
        frozen-row path."""
        C = random_tensor(seed=11, S=8, N=5, L=9, inf_frac=0.1)
        ns = np.random.RandomState(11).randint(1, 6, size=8)
        for s in range(8):
            C[s, ns[s]:] = INF  # stack_cost_tensors-style padding
        a = SW.batched_optimal_dp(C, combine=combine, n_devices=ns)
        b = SW.batched_optimal_dp(C, combine=combine, n_devices=ns,
                                  backend="jax")
        assert np.array_equal(a.feasible, b.feasible)
        assert np.array_equal(a.splits, b.splits)
        fin = a.feasible
        assert np.allclose(a.cost_s[fin], b.cost_s[fin], rtol=1e-5)
        assert np.isinf(b.cost_s[~fin]).all()

    def test_all_k_on_jax_backend(self):
        C = random_tensor(seed=13, S=5, N=4, L=8)
        ref = SW.batched_optimal_dp(C, return_all_k=True)
        got = SW.batched_optimal_dp(C, return_all_k=True, backend="jax")
        assert sorted(got) == sorted(ref)
        for n in ref:
            assert np.array_equal(ref[n].splits, got[n].splits)
            assert np.allclose(ref[n].cost_s, got[n].cost_s, rtol=1e-5)

    def test_all_k_results_share_one_wall(self):
        """The documented timing scope: all-k results report the ONE
        family wall (stamped after reconstruction), on every solver."""
        C = random_tensor(seed=17, S=4, N=4, L=8)
        for all_k in (SW.batched_optimal_dp(C, return_all_k=True),
                      SW.batched_optimal_dp(C, return_all_k=True,
                                            backend="jax"),
                      SW.batched_beam_search_all_k(C),
                      SW.batched_greedy_search_all_k(C)):
            walls = {r.wall_time_s for r in all_k.values()}
            assert len(walls) == 1
            assert walls.pop() > 0.0


# ---------------------------------------------------------------------------
# Sharded path, current-process device count (1 on plain CPU hosts)
# ---------------------------------------------------------------------------


class TestShardedInProcess:
    @pytest.mark.parametrize("S", [1, 4, 7])
    def test_matches_single_device_jax_node_for_node(self, S):
        C = random_tensor(seed=S, S=S, N=4, L=8)
        assert_node_identical(SW.batched_optimal_dp(C, backend="jax"),
                              SH.sharded_optimal_dp(C))

    def test_backend_string_routes_through_batched_dp(self):
        C = random_tensor(seed=23, S=5, N=3, L=7)
        ns = np.array([1, 3, 2, 1, 3])
        via_backend = SW.batched_optimal_dp(C, backend="sharded",
                                            n_devices=ns)
        direct = SH.sharded_optimal_dp(C, n_devices=ns)
        assert via_backend.backend == "sharded"
        assert_node_identical(via_backend, direct)
        assert np.array_equal(via_backend.n_devices_s, ns)

    def test_all_k_sharded(self):
        C = random_tensor(seed=29, S=6, N=4, L=8)
        ref = SW.batched_optimal_dp(C, return_all_k=True, backend="jax")
        got = SH.sharded_optimal_dp(C, return_all_k=True)
        for n in ref:
            assert_node_identical(ref[n], got[n])

    def test_sweep_sharded_backend(self):
        grid = ScenarioGrid(
            models={"mobilenet_v2": mobilenet_cost_profile()},
            links=dict(PROTOCOLS), n_devices=(2, 4),
            loss_p=(None, 0.05), rate_scale=(1.0, 0.25),
            devices=(ESP32,),
        )
        rj = sweep(grid, backend="jax")
        rs = sweep(grid, backend="sharded")
        assert rs.backend == "sharded"
        for a, b in zip(rj.rows, rs.rows):
            assert a.splits == b.splits
            assert a.feasible == b.feasible
            assert a.objective_cost_s == b.objective_cost_s

    def test_build_surfaces_sharded_backend(self):
        from repro.core.latency import SplitCostModel
        from repro.core.surface import build_surfaces

        m = SplitCostModel(profile=mobilenet_cost_profile(),
                           devices=(ESP32,),
                           link=PROTOCOLS["esp_now"])
        kw = dict(pt_scale=(1.0, 8.0), loss_p=(0.0, 0.1),
                  solver="batched_dp")
        fam_j = build_surfaces(m, dict(PROTOCOLS), (2, 3), backend="jax", **kw)
        fam_s = build_surfaces(m, dict(PROTOCOLS), (2, 3),
                               backend="sharded", **kw)
        for n in (2, 3):
            for p in fam_j[n].protocols:
                pj, ps = fam_j[n].protocols[p], fam_s[n].protocols[p]
                assert np.array_equal(pj.splits, ps.splits)
                assert np.array_equal(pj.latency_s, ps.latency_s)
                assert np.array_equal(pj.chunk_bytes, ps.chunk_bytes)

    def test_non_dp_solvers_reject_non_numpy_backends(self):
        from repro.core.latency import SplitCostModel
        from repro.core.surface import build_surfaces

        m = SplitCostModel(profile=mobilenet_cost_profile(),
                           devices=(ESP32,), link=PROTOCOLS["esp_now"])
        with pytest.raises(ValueError):
            build_surfaces(m, dict(PROTOCOLS), (2,),
                           solver="batched_beam", backend="sharded")
        with pytest.raises(ValueError):
            SW.solve_batched(np.full((2, 2, 4, 4), 1.0),
                             solver="batched_greedy", backend="sharded")
        # sweep() carries the same contract: no silent downgrade of a
        # requested backend (the SweepResult records it)
        grid = ScenarioGrid(
            models={"mobilenet_v2": mobilenet_cost_profile()},
            links=dict(PROTOCOLS), n_devices=(2,), devices=(ESP32,),
        )
        with pytest.raises(ValueError):
            sweep(grid, solver="batched_beam", backend="sharded")
        with pytest.raises(ValueError):
            sweep(grid, solver="batched_greedy", backend="jax")


# ---------------------------------------------------------------------------
# Multi-device subprocesses (the real mesh)
# ---------------------------------------------------------------------------


def _run_forced_devices(code: str, n_devices: int = 8, x64: bool = False,
                        timeout: int = 300) -> str:
    env = {
        "PYTHONPATH": "src",
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
    }
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert res.returncode == 0, res.stderr[-2000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_eight_devices_node_identical():
    """Acceptance: on 8 local devices, sharded output is node-identical
    to the single-device JAX path — for scenario counts that divide the
    device count and counts that need padding — and the splits match
    the NumPy float64 oracle on tie-free tensors."""
    out = _run_forced_devices("""
        import jax, numpy as np
        assert jax.local_device_count() == 8, jax.devices()
        from repro.core import shard as SH
        from repro.core import sweep as SW
        rng = np.random.RandomState(0)
        for S in (3, 8, 13, 16):   # padded and exact multiples
            N, L = 4, 9
            C = rng.uniform(0.01, 100.0, size=(S, N, L, L))
            C[:, :, np.tril(np.ones((L, L), bool), k=-1)] = np.inf
            ns = rng.randint(1, N + 1, size=S)
            for kw in ({}, {"n_devices": ns}):
                b = SW.batched_optimal_dp(C, backend="jax", **kw)
                c = SH.sharded_optimal_dp(C, **kw)
                assert np.array_equal(b.splits, c.splits), (S, kw)
                assert np.array_equal(b.cost_s, c.cost_s), (S, kw)
                assert np.array_equal(b.feasible, c.feasible), (S, kw)
                a = SW.batched_optimal_dp(C, **kw)
                assert np.array_equal(a.splits, c.splits), (S, kw)
                fin = a.feasible
                assert np.allclose(a.cost_s[fin], c.cost_s[fin], rtol=1e-5)
            bk = SW.batched_optimal_dp(C, backend="jax", return_all_k=True)
            ck = SH.sharded_optimal_dp(C, return_all_k=True)
            for n in bk:
                assert np.array_equal(bk[n].splits, ck[n].splits)
                assert np.array_equal(bk[n].cost_s, ck[n].cost_s)
            sub = SH.sharded_optimal_dp(C, n_shards=3)  # partial mesh
            assert np.array_equal(sub.splits,
                                  SW.batched_optimal_dp(C, backend="jax").splits)
        print("OK8")
    """)
    assert "OK8" in out


@pytest.mark.slow
def test_sharded_sweep_eight_devices():
    """The full fleet API on a real mesh: sweep(backend='sharded') is
    node-identical to sweep(backend='jax') row by row."""
    out = _run_forced_devices("""
        import jax
        assert jax.local_device_count() == 8
        from repro.core.profiles import ESP32, PROTOCOLS, mobilenet_cost_profile
        from repro.core.sweep import ScenarioGrid, sweep
        grid = ScenarioGrid(
            models={"mobilenet_v2": mobilenet_cost_profile()},
            links=dict(PROTOCOLS), n_devices=(2, 3, 5),
            loss_p=(None, 0.05, 0.1), rate_scale=(1.0, 0.5),
            devices=(ESP32,),
        )
        rj = sweep(grid, backend="jax")
        rs = sweep(grid, backend="sharded")
        assert all(a.splits == b.splits and
                   a.objective_cost_s == b.objective_cost_s and
                   a.feasible == b.feasible
                   for a, b in zip(rj.rows, rs.rows))
        print("SWEEPOK", rs.n_scenarios)
    """)
    assert "SWEEPOK" in out


@pytest.mark.slow
def test_x64_recovers_bit_parity_with_ties():
    """With jax_enable_x64 the JAX and sharded backends run float64 in
    the NumPy operation order, so even exact-cost ties break
    identically to the scalar oracle (integer costs force ties)."""
    out = _run_forced_devices("""
        import jax, numpy as np
        assert jax.config.jax_enable_x64
        from repro.core import shard as SH
        from repro.core import sweep as SW
        rng = np.random.RandomState(2)
        S, N, L = 11, 3, 7
        C = rng.randint(1, 6, size=(S, N, L, L)).astype(np.float64)
        C[:, :, np.tril(np.ones((L, L), bool), k=-1)] = np.inf
        a = SW.batched_optimal_dp(C)
        for res in (SW.batched_optimal_dp(C, backend="jax"),
                    SH.sharded_optimal_dp(C)):
            assert np.array_equal(a.splits, res.splits)
            assert (a.cost_s == res.cost_s).all()  # bitwise, ties included
        print("X64OK")
    """, n_devices=4, x64=True)
    assert "X64OK" in out
