"""Solver tests: Algorithms 1-3 semantics + property-based optimality checks."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import solvers as S

INF = float("inf")


def table_cost_fn(seg_costs):
    """cost_fn from a dict {(a,b): cost} (device-independent)."""

    def fn(a, b, k):
        return seg_costs.get((a, b), INF)

    return fn


def random_instance(draw, max_L=9, max_N=4):
    L = draw(st.integers(3, max_L))
    N = draw(st.integers(2, min(max_N, L)))
    costs = {}
    for a in range(1, L + 1):
        for b in range(a, L + 1):
            costs[(a, b)] = draw(
                st.floats(0.01, 100.0, allow_nan=False, allow_infinity=False)
            )
    return L, N, costs


@st.composite
def instances(draw):
    return random_instance(draw)


def additive_cost_fn(layer_costs, boundary_costs):
    """Structured instance: segment cost = sum of per-layer costs + cost of
    the boundary after it (mirrors the real latency model)."""
    L = len(layer_costs)

    def fn(a, b, k):
        c = sum(layer_costs[a - 1 : b])
        if b < L:
            c += boundary_costs[b - 1]
        return c

    return fn


class TestBeamSearch:
    def test_single_device(self):
        fn = table_cost_fn({(1, 3): 5.0})
        r = S.beam_search(fn, L=3, N=1)
        assert r.splits == ()
        assert r.cost_s == 5.0

    def test_two_devices_exhaustive_window(self):
        costs = {(1, 1): 1.0, (1, 2): 3.0, (2, 3): 7.0, (3, 3): 2.0}
        # N=2, L=3: candidates splits=(1,): 1+7=8 ; (2,): 3+2=5
        r = S.beam_search(table_cost_fn(costs), L=3, N=2, beam_width=10)
        assert r.splits == (2,)
        assert r.cost_s == pytest.approx(5.0)

    def test_final_segment_ends_at_L(self):
        """The chosen configuration must cover all L layers (s_N = L)."""
        L, N = 7, 3
        fn = additive_cost_fn([1.0] * L, [0.5] * (L - 1))
        r = S.beam_search(fn, L, N)
        bounds = [0, *r.splits, L]
        assert all(bounds[i] < bounds[i + 1] for i in range(N))
        assert len(r.splits) == N - 1

    @given(instances())
    @settings(max_examples=60, deadline=None)
    def test_wide_beam_equals_brute_force(self, inst):
        """Beam width >= number of boundary positions makes Alg. 1 exact."""
        L, N, costs = inst
        fn = table_cost_fn(costs)
        wide = S.beam_search(fn, L, N, beam_width=10**6)
        brute = S.brute_force(fn, L, N)
        assert wide.cost_s == pytest.approx(brute.cost_s)

    @given(instances(), st.integers(1, 8))
    @settings(max_examples=60, deadline=None)
    def test_beam_never_beats_brute_force(self, inst, width):
        L, N, costs = inst
        fn = table_cost_fn(costs)
        beam = S.beam_search(fn, L, N, beam_width=width)
        brute = S.brute_force(fn, L, N)
        assert beam.cost_s >= brute.cost_s - 1e-9
        # and the reported cost matches recomputation from splits
        assert beam.cost_s == pytest.approx(S.total_cost(fn, beam.splits, L))

    @given(instances(), st.integers(1, 6))
    @settings(max_examples=40, deadline=None)
    def test_beam_monotone_in_width(self, inst, width):
        """Wider beams never do worse (superset of candidates kept)."""
        L, N, costs = inst
        fn = table_cost_fn(costs)
        narrow = S.beam_search(fn, L, N, beam_width=width)
        wider = S.beam_search(fn, L, N, beam_width=width * 4)
        assert wider.cost_s <= narrow.cost_s + 1e-9


class TestDPandBrute:
    @given(instances())
    @settings(max_examples=60, deadline=None)
    def test_dp_equals_brute_force_sum(self, inst):
        L, N, costs = inst
        fn = table_cost_fn(costs)
        assert S.optimal_dp(fn, L, N).cost_s == pytest.approx(S.brute_force(fn, L, N).cost_s)

    @given(instances())
    @settings(max_examples=40, deadline=None)
    def test_dp_equals_brute_force_max(self, inst):
        L, N, costs = inst
        fn = table_cost_fn(costs)
        dp = S.optimal_dp(fn, L, N, combine="max")
        bf = S.brute_force(fn, L, N, combine="max")
        assert dp.cost_s == pytest.approx(bf.cost_s)

    def test_brute_force_enumerates_all(self):
        L, N = 8, 3
        fn = additive_cost_fn([1.0] * L, [0.0] * (L - 1))
        r = S.brute_force(fn, L, N)
        # every combination visits every distinct (a,b,k) segment
        assert r.cost_s == pytest.approx(8.0)  # total layers, any split

    def test_infeasible_instance(self):
        fn = table_cost_fn({})  # everything INF
        for solver in (S.beam_search, S.optimal_dp, S.brute_force):
            assert not solver(fn, 5, 3).feasible


class TestGreedyFirstFit:
    @given(instances())
    @settings(max_examples=60, deadline=None)
    def test_greedy_valid_and_bounded_below_by_optimal(self, inst):
        L, N, costs = inst
        fn = table_cost_fn(costs)
        g = S.greedy_search(fn, L, N)
        opt = S.optimal_dp(fn, L, N)
        assert len(g.splits) == N - 1
        bounds = [0, *g.splits, L]
        assert all(bounds[i] < bounds[i + 1] for i in range(N))
        assert g.cost_s >= opt.cost_s - 1e-9

    @given(instances())
    @settings(max_examples=40, deadline=None)
    def test_first_fit_valid(self, inst):
        L, N, costs = inst
        fn = table_cost_fn(costs)
        f = S.first_fit_search(fn, L, N)
        opt = S.optimal_dp(fn, L, N)
        bounds = [0, *f.splits, L]
        assert all(bounds[i] < bounds[i + 1] for i in range(N))
        assert f.cost_s >= opt.cost_s - 1e-9

    def test_first_fit_threshold_accepts_early(self):
        L = 5
        fn = additive_cost_fn([1.0] * L, [0.0] * (L - 1))
        r = S.first_fit_search(fn, L, 2, thresholds=1.0)
        assert r.splits == (1,)  # first position already within budget

    def test_first_fit_fallback_when_no_fit(self):
        L = 5
        fn = additive_cost_fn([10.0] * L, [0.0] * (L - 1))
        r = S.first_fit_search(fn, L, 3, thresholds=0.001)
        # falls back to the latest feasible positions: L-(N-k)
        assert r.splits == (3, 4)


class TestRandomFit:
    @given(instances(), st.integers(0, 3))
    @settings(max_examples=30, deadline=None)
    def test_valid_configuration(self, inst, seed):
        L, N, costs = inst
        fn = table_cost_fn(costs)
        r = S.random_fit(fn, L, N, seed=seed)
        bounds = [0, *r.splits, L]
        assert all(bounds[i] < bounds[i + 1] for i in range(N))

    def test_more_trials_never_worse(self):
        L, N = 9, 3
        fn = additive_cost_fn(list(range(1, 10)), [5.0] * 8)
        r1 = S.random_fit(fn, L, N, trials=1, seed=7)
        r64 = S.random_fit(fn, L, N, trials=64, seed=7)
        assert r64.cost_s <= r1.cost_s


class TestComplexity:
    def test_beam_explores_fewer_nodes_than_brute(self):
        """The paper's scalability claim: beam is poly, brute exponential."""
        L, N = 20, 4
        fn = additive_cost_fn([1.0] * L, [0.5] * (L - 1))
        beam = S.beam_search(fn, L, N, beam_width=5)
        brute = S.brute_force(fn, L, N)
        assert beam.nodes_expanded <= brute.nodes_expanded
        assert beam.wall_time_s < brute.wall_time_s * 5  # generous, CI-safe

    def test_brute_force_candidate_count(self):
        """Brute force covers C(L-1, N-1) configurations."""
        L, N = 10, 3
        seen = []
        fn = lambda a, b, k: 1.0  # noqa: E731
        r = S.brute_force(fn, L, N)
        assert r.cost_s == pytest.approx(3.0)
        assert math.comb(L - 1, N - 1) == 36  # sanity of the formula itself


class TestEnergyBudget:
    """Scalar budget filtering: budget_masked / total_energy + the
    energy_fn=/energy_budget= kwargs every solver grew (PR 8)."""

    def test_budget_masked_identity_when_unconstrained(self):
        fn = table_cost_fn({(1, 3): 5.0})
        assert S.budget_masked(fn, None, None) is fn
        assert S.budget_masked(fn, lambda a, b, k: 1.0, None) is fn
        assert S.budget_masked(fn, None, 2.0) is fn
        assert S.budget_masked(fn, lambda a, b, k: 1.0, INF) is fn

    def test_budget_masked_strict_comparison(self):
        fn = table_cost_fn({(1, 2): 5.0, (3, 4): 6.0})
        efn = table_cost_fn({(1, 2): 1.0, (3, 4): 2.0})
        masked = S.budget_masked(fn, efn, 1.0)
        assert masked(1, 2, 1) == 5.0  # e == budget passes (strict >)
        assert masked(3, 4, 2) == INF  # e > budget masks

    def test_total_energy(self):
        efn = table_cost_fn({(1, 2): 1.0, (3, 4): 2.0, (5, 6): 4.0})
        assert S.total_energy(efn, (2, 4), 6) == 7.0
        assert S.total_energy(efn, (3,), 6) == INF  # unpriced segment

    def test_brute_force_filters_by_budget(self):
        # layers 1..4, 2 devices: (1,1)+(2,4) is fastest but device 1's
        # segment (2,4) blows the budget; the oracle must pick the
        # within-budget runner-up
        costs = {(1, 1): 1.0, (2, 4): 1.0, (1, 2): 2.0, (3, 4): 2.0,
                 (1, 3): 9.0, (4, 4): 9.0}
        energy = {(1, 1): 0.1, (2, 4): 9.0, (1, 2): 0.1, (3, 4): 0.1,
                  (1, 3): 0.1, (4, 4): 0.1}
        fn, efn = table_cost_fn(costs), table_cost_fn(energy)
        free = S.brute_force(fn, 4, 2)
        assert free.splits == (1,) and free.cost_s == 2.0
        capped = S.brute_force(fn, 4, 2, energy_fn=efn, energy_budget=1.0)
        assert capped.splits == (2,) and capped.cost_s == 4.0

    def test_optimal_dp_matches_filtered_brute(self):
        costs = {(1, 1): 1.0, (2, 4): 1.0, (1, 2): 2.0, (3, 4): 2.0,
                 (1, 3): 9.0, (4, 4): 9.0}
        energy = {(1, 1): 0.1, (2, 4): 9.0, (1, 2): 0.1, (3, 4): 0.1,
                  (1, 3): 0.1, (4, 4): 0.1}
        fn, efn = table_cost_fn(costs), table_cost_fn(energy)
        dp = S.optimal_dp(fn, 4, 2, energy_fn=efn, energy_budget=1.0)
        bf = S.brute_force(fn, 4, 2, energy_fn=efn, energy_budget=1.0)
        assert dp.splits == bf.splits
        assert dp.cost_s == bf.cost_s

    def test_infeasible_budget_reports_infeasible(self):
        fn = table_cost_fn({(1, 2): 1.0, (3, 4): 1.0})
        efn = table_cost_fn({(1, 2): 5.0, (3, 4): 5.0})
        for name in ("optimal_dp", "brute_force", "beam", "greedy"):
            r = S.SOLVERS[name](fn, 4, 2, energy_fn=efn, energy_budget=1.0)
            assert r.cost_s == INF

    def test_infinite_budget_bit_identical_to_unbudgeted(self):
        fn = additive_cost_fn(list(range(1, 8)), [0.5] * 6)
        efn = additive_cost_fn([0.1] * 7, [0.0] * 6)
        for name in S.SOLVERS:
            kwargs = {"seed": 3} if name == "random_fit" else {}
            base = S.SOLVERS[name](fn, 7, 3, **kwargs)
            capped = S.SOLVERS[name](fn, 7, 3, energy_fn=efn,
                                     energy_budget=INF, **kwargs)
            assert base.splits == capped.splits
            assert base.cost_s == capped.cost_s
