"""Pipeline-parallel runtime tests (shard_map + ppermute execution of
planner splits) — requires >1 local device, so these tests spawn a
subprocess with forced host devices."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.core.planner import plan_pipeline, uniform_split
    from repro.models.graph import transformer_layer_graph
    from repro.parallel.pipeline import run_pipeline, stage_assignment

    L, D, M, mb = 8, 16, 6, 2
    Ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1
    params = {"w": Ws}
    def block_apply(lp, x):
        return x + x @ lp["w"]

    class Plan: pass
    plan = Plan()
    plan.splits = uniform_split(L, 4)
    mesh = jax.make_mesh((4,), ("stage",))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
    out = run_pipeline(plan, block_apply, params, L, x, mesh, axis="stage")
    ref = x
    for i in range(L):
        ref = block_apply({"w": Ws[i]}, ref)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, err

    # uneven beam-style splits (stage depths 3/2/2/1) must also be exact
    plan.splits = (3, 5, 7)
    out2 = run_pipeline(plan, block_apply, params, L, x, mesh, axis="stage")
    err2 = float(jnp.max(jnp.abs(out2 - ref)))
    assert err2 < 1e-5, err2

    # stage assignment bookkeeping
    ranges = stage_assignment(plan, L)
    assert ranges == [(0, 2), (3, 4), (5, 6), (7, 7)]
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_pipeline_parallel_exactness():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              # hosts with libtpu installed otherwise hang in
                              # TPU discovery; this test forces host devices
                              "JAX_PLATFORMS":
                                  os.environ.get("JAX_PLATFORMS") or "cpu"})
    assert "PIPELINE_OK" in res.stdout, res.stderr[-2000:]
