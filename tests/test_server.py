"""Serving-runtime tests: slot batching, draining, split metering."""

import jax
import numpy as np
import pytest

from repro.core.planner import plan_pipeline
from repro.core.profiles import ESP_NOW, ICI
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.graph import arch_layer_graph
from repro.runtime.server import Request, Server, SplitLatencyMeter

CFG = ModelConfig("srv", "dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                  d_ff=64, vocab=64, head_dim=8, dtype="float32", remat=False,
                  kv_chunk=16, pad_vocab_to=0)


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


class TestServer:
    def test_serves_all_requests(self, params):
        server = Server(CFG, params, slots=2, max_seq=64)
        for rid in range(5):
            server.submit(Request(rid, np.array([1, 2, 3], np.int32),
                                  max_new_tokens=4))
        out = server.run_until_drained()
        assert sorted(out) == list(range(5))
        assert all(len(v) == 4 for v in out.values())

    def test_tokens_in_vocab(self, params):
        server = Server(CFG, params, slots=2, max_seq=64)
        server.submit(Request(0, np.array([5], np.int32), max_new_tokens=6))
        out = server.run_until_drained()
        assert all(0 <= t < CFG.vocab for t in out[0])

    def test_deterministic_greedy(self, params):
        def run():
            s = Server(CFG, params, slots=1, max_seq=64)
            s.submit(Request(0, np.array([7, 8], np.int32), max_new_tokens=5))
            return s.run_until_drained()[0]

        assert run() == run()

    def test_split_meter_accounts_hops(self, params):
        g = arch_layer_graph(CFG, batch=2, seq=32)
        plan = plan_pipeline(g, 2, link=ICI)
        meter = SplitLatencyMeter(plan=plan, link=ESP_NOW,
                                  bytes_per_token=CFG.d_model * 2)
        server = Server(CFG, params, slots=1, max_seq=64, meter=meter)
        server.submit(Request(0, np.array([1], np.int32), max_new_tokens=3))
        server.run_until_drained()
        assert meter.hops == 3  # one hop per token for a 2-way split
        assert meter.hop_seconds > 0

    def test_split_meter_replan_hook(self, params):
        """The meter feeds metered hops to a surface-driven adaptive
        manager; when the link collapses mid-serve the manager replans
        and the meter swaps in the re-materialized plan."""
        from dataclasses import replace

        from repro.core.adaptive import AdaptiveSplitManager
        from repro.core.profiles import PROTOCOLS, paper_cost_model

        mgr = AdaptiveSplitManager(
            cost_model=paper_cost_model("mobilenet_v2", "esp_now"),
            protocols=dict(PROTOCOLS), n_devices=2,
            surface_grid={"pt_scale": (1.0, 16.0, 256.0),
                          "loss_p": (0.0, 0.1)})
        meter = SplitLatencyMeter(plan=mgr.current_plan(), link=ESP_NOW,
                                  bytes_per_token=5488,
                                  manager=mgr, protocol="esp_now")
        server = Server(CFG, params, slots=1, max_seq=64, meter=meter)
        server.submit(Request(0, np.array([1], np.int32), max_new_tokens=4))
        server.run_until_drained()
        assert mgr._step >= 4  # every metered hop reached the manager
        assert meter.replans == 0  # healthy modeled link: no thrash

        # collapse the metered link 200x: the hook must swap the plan
        meter.link = replace(ESP_NOW,
                             rate_bytes_per_s=ESP_NOW.rate_bytes_per_s / 200)
        server.submit(Request(1, np.array([2], np.int32), max_new_tokens=40))
        server.run_until_drained()
        assert meter.replans >= 1
        assert meter.plan.splits == mgr.current.splits
        assert meter.plan.solver == "surface"
